//! End-to-end serving driver (EXPERIMENTS.md §E2E): start the worker
//! pool with FP32 + SWIS weight variants, replay a bursty open-loop
//! request trace against it, and report accuracy (when the trained
//! weights + test set are present), latency percentiles, throughput and
//! shed/backpressure counts.
//!
//! Dispatch path exercised here (the new serving stack end to end):
//!
//! ```text
//!   this driver ─submit─▶ AdmissionQueue ─▶ WorkerPool(N) ─▶ Backend
//! ```
//!
//! The backend is selected at start-up: compiled PJRT artifacts when
//! `make artifacts` has run, the native SWIS engine otherwise — so this
//! example is the proof that the serving stack composes end to end in
//! EVERY environment: admission control, batching, variant routing and
//! packed-operand execution with Python nowhere on the request path.
//!
//! Run: cargo run --release --example serve_tinycnn \
//!          [-- --requests 512 --workers 4 --queue-depth 256 \
//!              --priority interactive --rate 300 --backend auto]

use anyhow::Result;
use std::collections::HashMap;
use std::path::Path;
use std::time::{Duration, Instant};

use swis::coordinator::{
    BackendKind, BatchPolicy, InferRequest, PoolConfig, Priority, VariantSpec, WorkerPool,
};
use swis::loadgen::exp_gap;
use swis::util::cli;
use swis::util::npy;
use swis::util::rng::Rng;

fn main() -> Result<()> {
    // cargo strips the "--" separator itself; direct invocation may pass
    // it through — drop it either way so flags are never swallowed
    let argv: Vec<String> = std::env::args().skip(1).filter(|a| a != "--").collect();
    let args = cli::parse(
        &argv,
        &[
            "requests", "max-batch", "max-wait-ms", "rate", "backend", "workers", "queue-depth",
            "priority",
        ],
    )?;
    let n_req = args.get_usize("requests", 512)?;
    let rate = args.get_f64("rate", 300.0)?; // offered load, req/s; 0 = one burst
    let backend = BackendKind::parse(args.get_or("backend", "auto"))?;
    let workers = args.get_usize("workers", 1)?;
    let queue_depth = args.get_usize("queue-depth", 1024)?;
    let priority = Priority::parse(args.get_or("priority", "interactive"))?;

    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let variants = vec![
        VariantSpec::fp32(),
        VariantSpec::swis(3.0, 4),
        VariantSpec::swis(2.5, 4),
        VariantSpec::swis_c(3.0, 4),
    ];
    let names: Vec<String> = variants.iter().map(|v| v.name.clone()).collect();
    let policy = BatchPolicy {
        max_batch: args.get_usize("max-batch", 64)?,
        max_wait: Duration::from_millis(args.get_usize("max-wait-ms", 2)? as u64),
    };

    println!("starting {workers}-worker pool with variants {names:?} ...");
    let t_start = Instant::now();
    let cfg = PoolConfig { workers, policy, queue_depth, ..PoolConfig::default() };
    let pool = WorkerPool::start(&dir, cfg, variants, backend)?;
    println!(
        "backend '{}' warm-up (compile/quantize) took {:.2} s",
        pool.backend(),
        t_start.elapsed().as_secs_f64()
    );

    // real test images when the build-time dataset exists (accuracy is
    // reportable), synthetic images otherwise (plumbing + perf only);
    // one flat buffer either way, sliced per request — no per-image Vecs
    let per = 32 * 32 * 3;
    let dataset = dir.join("dataset.npz");
    let (images, labels): (Vec<f32>, Option<Vec<usize>>) = if dataset.exists() {
        let npz = npy::load_npz(&dataset)?;
        let y = npz["y_test"].as_i64();
        let labels = y.data().iter().map(|&v| v as usize).collect();
        (npz["x_test"].as_f32().into_data(), Some(labels))
    } else {
        println!("(no dataset.npz — synthetic images, accuracy not reportable)");
        let mut rng = Rng::new(11);
        ((0..64 * per).map(|_| rng.f64() as f32).collect(), None)
    };
    let n_avail = images.len() / per;

    // open-loop Poisson arrivals at `rate` req/s
    let mut rng = Rng::new(2026);
    let mut handles = Vec::with_capacity(n_req);
    let t0 = Instant::now();
    for i in 0..n_req {
        let img_idx = i % n_avail;
        let image = images[img_idx * per..(img_idx + 1) * per].to_vec();
        let variant = names[i % names.len()].clone();
        let rx = pool.submit(
            InferRequest::new(variant.clone()).image(image).priority(priority),
        )?;
        handles.push((variant, img_idx, rx));
        if rate > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(exp_gap(&mut rng, rate)));
        }
    }

    // collect + score
    let mut correct: HashMap<String, (usize, usize)> = HashMap::new();
    for (variant, img_idx, rx) in handles {
        let resp = rx.recv()?.map_err(|e| anyhow::anyhow!(e))?;
        let arg = resp
            .logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        let e = correct.entry(variant).or_insert((0, 0));
        e.1 += 1;
        if labels.as_ref().is_some_and(|y| arg == y[img_idx]) {
            e.0 += 1;
        }
    }
    let wall = t0.elapsed();

    if labels.is_some() {
        println!("\n== per-variant accuracy (synth-CIFAR test images) ==");
        let mut keys: Vec<&String> = correct.keys().collect();
        keys.sort();
        for k in keys {
            let (ok, n) = correct[k];
            println!("  {:<10} {:>5.1}%  ({ok}/{n})", k, 100.0 * ok as f64 / n as f64);
        }
    }

    let snap = pool.metrics.snapshot();
    println!("\n== serving metrics ==");
    println!("  backend         : {}", pool.backend());
    println!("  workers         : {}", pool.workers());
    println!("  requests        : {n_req} in {:.2} s", wall.as_secs_f64());
    println!("  throughput      : {:.0} req/s (offered {rate:.0})", n_req as f64 / wall.as_secs_f64());
    println!("  batches         : {} (mean size {:.1})", snap.batches, snap.mean_batch);
    println!("  shed / rejected : {} / {}", snap.shed, snap.rejected);
    println!("  exec  p50       : {:.0} us/batch", snap.exec_us.p50);
    println!("  queue p50       : {:.0} us", snap.queue_us.p50);
    println!("  total p50 / p99 : {:.0} / {:.0} us", snap.p50_total_us, snap.p99_total_us);
    pool.shutdown()?;
    println!("\nserve_tinycnn OK");
    Ok(())
}
