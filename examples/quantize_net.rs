//! Per-layer quantization study on a zoo network: SWIS vs SWIS-C vs
//! weight truncation RMSE across shift budgets, plus the effect of the
//! Sec. 4.3 filter scheduler at fractional budgets — the offline workflow
//! a deployment would run before flashing weights to a SWIS accelerator.
//!
//! Run: cargo run --release --example quantize_net -- --net mobilenet_v2

use anyhow::{Context, Result};

use swis::nets::{by_name, surrogate_weights};
use swis::quant::truncation::truncate_weights;
use swis::quant::{Alpha, quantize, QuantConfig};
use swis::schedule::{schedule_layer, ScheduleConfig};
use swis::util::cli;
use swis::util::stats::rmse;

fn main() -> Result<()> {
    // cargo strips the "--" separator itself; direct invocation may pass
    // it through -- drop it either way so flags are never swallowed
    let argv: Vec<String> = std::env::args().skip(1).filter(|a| a != "--").collect();
    let args = cli::parse(&argv, &["net", "group", "seed"])?;
    let net_name = args.get_or("net", "resnet18");
    let group = args.get_usize("group", 4)?;
    let seed = args.get_usize("seed", 1)? as u64;
    let net = by_name(net_name).with_context(|| format!("unknown network '{net_name}'"))?;

    println!("# {} — per-layer quantization RMSE (group={group})", net.name);
    println!(
        "{:<22} {:>7} | {:>9} {:>9} {:>9} | {:>9} {:>9}",
        "layer", "shifts", "SWIS", "SWIS-C", "trunc", "compr(S)", "compr(C)"
    );

    // a representative subset: first, a middle, and the largest layer
    let mut picks = vec![0usize, net.layers.len() / 2, net.layers.len() - 1];
    picks.dedup();
    for &li in &picks {
        let layer = &net.layers[li];
        let w = surrogate_weights(layer, seed);
        let shape = layer.weight_shape();
        for n in [2usize, 3, 4, 5] {
            let ps = quantize(&w, &shape, &QuantConfig::swis(n, group))?;
            let pc = quantize(&w, &shape, &QuantConfig::swis_c(n, group))?;
            let wt = truncate_weights(&w, n);
            println!(
                "{:<22} {:>7} | {:>9.5} {:>9.5} {:>9.5} | {:>8.2}x {:>8.2}x",
                if n == 2 { layer.name.as_str() } else { "" },
                n,
                rmse(&w, &ps.to_f64()),
                rmse(&w, &pc.to_f64()),
                rmse(&w, &wt),
                ps.compression_ratio(),
                pc.compression_ratio(),
            );
        }
    }

    // scheduling study on the middle layer: fractional budgets
    let layer = &net.layers[net.layers.len() / 2];
    let w = surrogate_weights(layer, seed);
    let shape = layer.weight_shape();
    println!("\n# filter scheduling on {} (Sec. 4.3)", layer.name);
    println!(
        "{:>7} {:>16} {:>16} {:>16}",
        "target", "err uniform@floor", "err sched@target", "err uniform@ceil"
    );
    for target in [2.5, 3.5, 4.5] {
        let mut cfg = ScheduleConfig::new(target, group);
        cfg.alpha = Alpha::ONE;
        let s = schedule_layer(&w, &shape, &cfg)?;
        let at = |n: f64| -> anyhow::Result<i64> {
            let mut c = ScheduleConfig::new(n, group);
            c.alpha = Alpha::ONE;
            Ok(schedule_layer(&w, &shape, &c)?.err_scheduled)
        };
        let lo = at(target.floor())?;
        let hi = at(target.ceil())?;
        println!("{:>7} {:>16} {:>16} {:>16}", target, lo, s.err_scheduled, hi);
        // the scheduled fractional point interpolates the uniform ends —
        // the accuracy/latency trade the paper's Table 2 demonstrates
        assert!(s.err_scheduled <= lo && s.err_scheduled >= hi.min(lo));
    }
    println!("\nquantize_net OK");
    Ok(())
}
