//! Quickstart: the SWIS pipeline in ~60 lines.
//!
//!   1. quantize a weight tensor with SWIS (3 shifts, group 4),
//!   2. inspect the packed format + compression,
//!   3. load the AOT-compiled TinyCNN and compare FP32 vs SWIS logits
//!      through the real PJRT runtime.
//!
//! Run: cargo run --release --example quickstart

use anyhow::Result;
use std::path::Path;

use swis::coordinator::{quantize_jax_weight, VariantSpec};
use swis::quant::{quantize, QuantConfig};
use swis::runtime::{ModelBundle, Runtime};
use swis::util::npy;
use swis::util::rng::Rng;
use swis::util::stats::rmse;
use swis::util::tensor::Tensor;

fn main() -> Result<()> {
    // --- 1. quantize a random conv-like layer ---------------------------
    let mut rng = Rng::new(42);
    let w = rng.normal_vec(64 * 144, 0.0, 0.05); // 64 filters, fan-in 144
    let packed = quantize(&w, &[64, 144], &QuantConfig::swis(3, 4))?;
    println!("SWIS @ 3 shifts, group 4:");
    println!("  bits/weight      : {:.2} (8.0 baseline)", packed.bits_per_weight());
    println!("  compression      : {:.2}x", packed.compression_ratio());
    println!("  rmse             : {:.5}", rmse(&w, &packed.to_f64()));

    // SWIS-C trades a little accuracy for a smaller format
    let packed_c = quantize(&w, &[64, 144], &QuantConfig::swis_c(3, 4))?;
    println!(
        "SWIS-C @ 3 shifts : {:.2} bits/weight, rmse {:.5}",
        packed_c.bits_per_weight(),
        rmse(&w, &packed_c.to_f64())
    );

    // --- 2. run the AOT model through PJRT ------------------------------
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let rt = Runtime::cpu()?;
    println!("\nPJRT platform: {}", rt.platform());
    let bundle = ModelBundle::load(&rt, &dir, "model")?;

    let npz = npy::load_npz(&dir.join("dataset.npz"))?;
    let x = npz["x_test"].as_f32();
    let imgs = Tensor::new(&[8, 32, 32, 3], x.data()[..8 * 3072].to_vec())?;

    let fp32 = bundle.infer(&imgs, None)?;

    // quantize every weight to SWIS@3 and run the same graph
    let spec = VariantSpec::swis(3.0, 4);
    let mut wq = bundle.weights.clone();
    for (name, t) in &bundle.weights {
        if !name.ends_with("_b") {
            wq.insert(name.clone(), quantize_jax_weight(t, &spec)?);
        }
    }
    let swis3 = bundle.infer(&imgs, Some(&wq))?;

    println!("\nlogits (image 0):");
    println!("  fp32   : {:?}", &fp32.data()[..5]);
    println!("  swis@3 : {:?}", &swis3.data()[..5]);
    let drift = fp32
        .data()
        .iter()
        .zip(swis3.data())
        .map(|(a, b)| (a - b).abs() as f64)
        .sum::<f64>()
        / fp32.len() as f64;
    println!("mean |logit drift| = {drift:.4}");
    println!("\nquickstart OK");
    Ok(())
}
