//! Accelerator design-space sweep (the paper's "ongoing work" Sec. 6):
//! sweep PE flavor x group size x shift budget x array size on the
//! systolic simulator for a chosen network, reporting frames/s, frames/J
//! and DRAM traffic — the data a hardware architect would use to pick an
//! operating point.
//!
//! Run: cargo run --release --example accelerator_sweep -- --net resnet18

use anyhow::{Context, Result};

use swis::arch::pe::PeKind;
use swis::nets::by_name;
use swis::sim::{simulate_network, ArrayConfig, ExecScheme, SchemeKind};
use swis::util::cli;

fn main() -> Result<()> {
    // cargo strips the "--" separator itself; direct invocation may pass
    // it through -- drop it either way so flags are never swallowed
    let argv: Vec<String> = std::env::args().skip(1).filter(|a| a != "--").collect();
    let args = cli::parse(&argv, &["net"])?;
    let net_name = args.get_or("net", "resnet18");
    let net = by_name(net_name).with_context(|| format!("unknown network '{net_name}'"))?;

    println!("# accelerator sweep — {}", net.name);
    println!(
        "{:<12} {:>5} {:>6} {:>7} | {:>9} {:>9} {:>10} {:>8}",
        "pe", "G", "array", "shifts", "F/s", "F/J", "DRAM MB", "mm2"
    );

    let fixed = simulate_network(
        &net,
        &ArrayConfig::paper_baseline(PeKind::Fixed),
        &ExecScheme::new(SchemeKind::Fixed8, 8.0),
    );

    for kind in [PeKind::SingleShift, PeKind::DoubleShift] {
        for g in [2usize, 4, 8, 16] {
            for sa in [8usize, 16] {
                for n in [2.0, 3.0, 4.0] {
                    let mut cfg = ArrayConfig::paper_baseline(kind).with_size(sa, sa);
                    cfg.group_size = g;
                    let sim = simulate_network(&net, &cfg, &ExecScheme::swis(n));
                    println!(
                        "{:<12} {:>5} {:>4}x{:<2} {:>7} | {:>9.1} {:>9.1} {:>10.2} {:>8.2}",
                        format!("{kind:?}"),
                        g,
                        sa,
                        sa,
                        n,
                        sim.frames_per_s(),
                        sim.frames_per_j(),
                        sim.dram_bytes() / 1e6,
                        cfg.area_mm2()
                    );
                }
            }
        }
    }

    println!("\n# reference: 8-bit fixed-point, 8x8, G=4");
    println!(
        "F/s {:.1}   F/J {:.1}   DRAM {:.2} MB   {:.2} mm2",
        fixed.frames_per_s(),
        fixed.frames_per_j(),
        fixed.dram_bytes() / 1e6,
        ArrayConfig::paper_baseline(PeKind::Fixed).area_mm2()
    );
    println!("\naccelerator_sweep OK");
    Ok(())
}
