//! The facade pipeline in one file: **config → plan → `.swisplan` →
//! session** — written against ONLY `swis::api` re-exports. This example
//! doubles as the public-API smoke test: the CI `docs` job compiles and
//! runs it, so a facade regression (a type falling out of the re-export
//! surface, a signature break) fails fast here.
//!
//! Run: cargo run --release --example api_pipeline

use std::sync::Arc;

use swis::api::{
    prepare_call_count, Engine, EngineConfig, EnginePlan, Session, SwisResult, Tensor,
    VariantSpec,
};

fn main() -> SwisResult<()> {
    // 1. typed config — builder-style; the string grammar is optional
    //    sugar that parses into the same typed spec
    let cfg = EngineConfig::for_net("tinycnn")?
        .variant(VariantSpec::fp32())
        .variant(VariantSpec::swis(3.0, 4))
        .variant("swis_c@2".parse()?)
        .threads(2);

    // 2. offline: ONE prepare (quantize + schedule + pack + bind), one
    //    shippable artifact
    let plan = Engine::prepare(cfg)?;
    let path = std::env::temp_dir().join("api_pipeline_tinycnn.swisplan");
    plan.save(&path)?;
    println!(
        "prepared '{}': {} variants, {} packed payload bits -> {}",
        plan.net_name(),
        plan.variants().len(),
        plan.packed_payload_bits(),
        path.display()
    );

    // 3. online: load the artifact and serve — zero quantization from
    //    here on, provable via the planner-work odometer
    let odometer = prepare_call_count();
    let loaded = Arc::new(EnginePlan::load(&path)?);
    let session = Session::new(Arc::clone(&loaded));
    let [h, w, c] = loaded.input_shape();
    let image: Vec<f32> = (0..h * w * c).map(|i| (i % 17) as f32 / 17.0).collect();

    // the batched streaming handle: push requests as they arrive, flush
    // to execute the accumulated batch in one kernel dispatch
    let mut stream = session.stream("swis@3")?;
    stream.push(&image)?;
    stream.push(&image)?;
    let streamed = stream.flush()?;
    println!("swis@3 logits (image 0): {:?}", &streamed.data()[..4]);

    // the sync whole-batch entry agrees bit-for-bit
    let batch = Tensor::new(&[2, h, w, c], [image.clone(), image].concat())
        .expect("well-formed batch");
    let direct = session.run("swis@3", &batch)?;
    assert_eq!(direct.data(), streamed.data(), "stream and run must agree");
    assert_eq!(prepare_call_count(), odometer, "serving a loaded plan must not quantize");

    let _ = std::fs::remove_file(&path);
    println!("api_pipeline OK (zero quantization after plan load)");
    Ok(())
}
