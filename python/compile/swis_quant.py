"""SWIS quantization — reference implementation (numpy).

Implements the paper's offline weight decomposition (Sec. 2.2, 4.1):

  * symmetric int8 pre-quantization (sign-magnitude, B = 8 magnitude bits
    clipped to 127),
  * SWIS sparse shift selection: enumerate all C(8, N) shift subsets per
    group, quantize each weight magnitude to the nearest value in the
    2^N-entry subset-sum codebook, score with MSE++ (Eq. 12),
  * SWIS-C consecutive selection: enumerate the 9-N offsets,
  * layer-wise truncation baselines (weight LSB-truncation + clipping,
    activation truncation),
  * the filter scheduling heuristic of Sec. 4.3.

Conventions shared with the Rust implementation (cross-checked by golden
tests in rust/tests/golden.rs):

  * shift subsets are enumerated in lexicographically ascending order of
    positions, e.g. (0,1) < (0,2) < ... < (6,7);
  * nearest-codebook ties round DOWN (pick the smaller magnitude);
  * MSE++ comparisons use exact integer arithmetic on int magnitudes
    (errors are ints, alpha is rational), so combo selection is
    bit-identical across languages; strict `<` keeps the earliest combo
    on ties.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field

import numpy as np

BITS = 8  # underlying magnitude bitwidth
MAG_MAX = 127  # symmetric int8


# --------------------------------------------------------------------------
# shift subset enumeration + codebooks
# --------------------------------------------------------------------------


def shift_combos(n_shifts: int, bits: int = BITS) -> list[tuple[int, ...]]:
    """All C(bits, n_shifts) shift-position subsets, lexicographic order."""
    if not 1 <= n_shifts <= bits:
        raise ValueError(f"n_shifts must be in [1, {bits}], got {n_shifts}")
    return list(itertools.combinations(range(bits), n_shifts))


def consecutive_combos(n_shifts: int, bits: int = BITS) -> list[tuple[int, ...]]:
    """The 9-N consecutive shift windows used by SWIS-C."""
    return [tuple(range(o, o + n_shifts)) for o in range(bits - n_shifts + 1)]


def codebook(combo: tuple[int, ...]) -> np.ndarray:
    """Sorted, deduplicated subset sums of {2^s : s in combo} (incl. 0)."""
    vals = {0}
    for r in range(1, len(combo) + 1):
        for sub in itertools.combinations(combo, r):
            vals.add(sum(1 << s for s in sub))
    return np.array(sorted(vals), dtype=np.int64)


def nearest(cb: np.ndarray, mags: np.ndarray) -> np.ndarray:
    """Nearest codebook entry for each magnitude; ties round DOWN."""
    idx = np.searchsorted(cb, mags)  # first cb[i] >= mag
    idx_hi = np.clip(idx, 0, len(cb) - 1)
    idx_lo = np.clip(idx - 1, 0, len(cb) - 1)
    lo, hi = cb[idx_lo], cb[idx_hi]
    # tie (mag - lo == hi - mag) -> lo
    pick_hi = (hi - mags) < (mags - lo)
    return np.where(pick_hi, hi, lo)


# --------------------------------------------------------------------------
# error metric (Eq. 11/12) — exact integer core
# --------------------------------------------------------------------------


def msepp_int(err: np.ndarray, alpha_num: int = 1, alpha_den: int = 1) -> np.ndarray:
    """MSE++ numerator over the last axis, as exact integers scaled by
    alpha_den (the 1/N normalization is a shared constant and dropped for
    comparisons): alpha_den * sum(e^2) + alpha_num * (sum e)^2.

    err: (..., G) int64 quantization errors. Returns (...,) int64.
    """
    e = err.astype(np.int64)
    se = e.sum(axis=-1)
    return alpha_den * (e * e).sum(axis=-1) + alpha_num * se * se


def msepp(x: np.ndarray, xq: np.ndarray, alpha: float = 1.0) -> float:
    """Float MSE++ (Eq. 12) for reporting."""
    e = (x - xq).astype(np.float64)
    n = e.shape[-1] if e.ndim else e.size
    return float((alpha * e.sum(axis=-1) ** 2 + (e * e).sum(axis=-1)).mean() / n)


def rmse(x: np.ndarray, xq: np.ndarray) -> float:
    return float(np.sqrt(np.mean((x.astype(np.float64) - xq) ** 2)))


# --------------------------------------------------------------------------
# int8 pre-quantization
# --------------------------------------------------------------------------


@dataclass
class Int8Layer:
    """Symmetric int8 view of a float weight tensor."""

    mags: np.ndarray  # uint8 magnitudes in [0, 127], shape = w.shape
    signs: np.ndarray  # int8 in {-1, +1}
    scale: float

    def to_float(self) -> np.ndarray:
        return self.mags.astype(np.float64) * self.signs * self.scale


def to_int8(w: np.ndarray) -> Int8Layer:
    amax = float(np.max(np.abs(w))) or 1.0
    scale = amax / MAG_MAX
    q = np.clip(np.round(w / scale), -MAG_MAX, MAG_MAX).astype(np.int64)
    signs = np.where(q < 0, -1, 1).astype(np.int8)
    return Int8Layer(np.abs(q).astype(np.uint8), signs, scale)


# --------------------------------------------------------------------------
# SWIS / SWIS-C group quantization
# --------------------------------------------------------------------------


@dataclass
class PackedLayer:
    """SWIS-packed weight layer (the storage format of Sec. 3.3).

    Grouping is row-major over the (filters, fan_in) matrix: each filter's
    fan-in dimension is split into groups of `group_size` (padded with
    zeros when fan_in % group_size != 0; padded lanes carry sign +1).
    """

    shape: tuple[int, ...]  # original weight shape (K first = filters)
    group_size: int
    n_shifts: int
    scale: float
    shifts: np.ndarray  # (n_groups, n_shifts) uint8, ascending
    masks: np.ndarray  # (n_groups, group_size, n_shifts) uint8 in {0,1}
    signs: np.ndarray  # (n_groups, group_size) int8 in {-1,+1}
    consecutive: bool = False
    # scheduling metadata: per-filter shifts (for reporting)
    filter_shifts: np.ndarray | None = None

    @property
    def n_groups(self) -> int:
        return self.shifts.shape[0]

    def mags(self) -> np.ndarray:
        """Reconstructed magnitudes per group lane, (n_groups, group_size)."""
        pw = (1 << self.shifts.astype(np.int64))[:, None, :]  # (g,1,n)
        return (self.masks.astype(np.int64) * pw).sum(axis=-1)

    def to_float(self) -> np.ndarray:
        """Dequantize back to the original float shape."""
        k = self.shape[0]
        fan_in = int(np.prod(self.shape[1:]))
        vals = (self.mags() * self.signs).astype(np.float64) * self.scale
        flat = vals.reshape(k, -1)[:, :fan_in]
        return flat.reshape(self.shape)

    def storage_bits(self) -> int:
        """Bits needed by the packed format (Sec. 3.3 accounting)."""
        g, gs, n = self.masks.shape
        sign_bits = g * gs
        mask_bits = g * gs * n
        shift_bits = 3 if self.consecutive else 3 * n  # per group
        return sign_bits + mask_bits + g * shift_bits


def _group_mags(
    w: np.ndarray, group_size: int
) -> tuple[np.ndarray, np.ndarray, float, int]:
    """int8-quantize + reshape into (n_groups, group_size) mags/signs."""
    q = to_int8(w)
    k = w.shape[0]
    fan_in = int(np.prod(w.shape[1:]))
    pad = (-fan_in) % group_size
    mags = q.mags.reshape(k, fan_in).astype(np.int64)
    signs = q.signs.reshape(k, fan_in).astype(np.int64)
    if pad:
        mags = np.pad(mags, ((0, 0), (0, pad)))
        signs = np.pad(signs, ((0, 0), (0, pad)), constant_values=1)
    gpf = (fan_in + pad) // group_size  # groups per filter
    return (
        mags.reshape(k * gpf, group_size),
        signs.reshape(k * gpf, group_size).astype(np.int8),
        q.scale,
        gpf,
    )


def _select_per_group(
    mags: np.ndarray,
    combos: list[tuple[int, ...]],
    alpha_num: int = 1,
    alpha_den: int = 1,
) -> tuple[np.ndarray, np.ndarray]:
    """Core enumeration: pick the best combo per group.

    mags: (n_groups, G) int64. Returns (best_combo_idx (n_groups,),
    best_qmags (n_groups, G)).
    """
    n_groups, _ = mags.shape
    best_err = np.full(n_groups, np.iinfo(np.int64).max, dtype=np.int64)
    best_idx = np.zeros(n_groups, dtype=np.int64)
    best_q = np.zeros_like(mags)
    for ci, combo in enumerate(combos):
        cb = codebook(combo)
        qm = nearest(cb, mags)
        err = msepp_int(mags - qm, alpha_num, alpha_den)
        upd = err < best_err  # strict: earliest combo wins ties
        best_err = np.where(upd, err, best_err)
        best_idx = np.where(upd, ci, best_idx)
        best_q = np.where(upd[:, None], qm, best_q)
    return best_idx, best_q


def _masks_for(
    combo: tuple[int, ...], qmags: np.ndarray
) -> np.ndarray:
    """Decompose quantized magnitudes into per-shift mask bits.

    qmags values are subset sums of the combo's powers, so the binary
    representation restricted to the combo's positions IS the mask.
    """
    shifts = np.array(combo, dtype=np.int64)
    return ((qmags[..., None] >> shifts) & 1).astype(np.uint8)


def quantize_swis(
    w: np.ndarray,
    n_shifts: int,
    group_size: int = 4,
    alpha: float = 1.0,
    consecutive: bool = False,
) -> PackedLayer:
    """SWIS (or SWIS-C) quantization of a weight tensor.

    w: float weights, filters on axis 0. alpha: MSE++ coefficient; must be
    rational-friendly (we use alpha = num/den with den=100 internally).
    """
    alpha_num, alpha_den = _alpha_ratio(alpha)
    mags, signs, scale, _ = _group_mags(w, group_size)
    combos = (
        consecutive_combos(n_shifts) if consecutive else shift_combos(n_shifts)
    )
    best_idx, best_q = _select_per_group(mags, combos, alpha_num, alpha_den)
    n_groups = mags.shape[0]
    shifts = np.zeros((n_groups, n_shifts), dtype=np.uint8)
    masks = np.zeros((n_groups, group_size, n_shifts), dtype=np.uint8)
    for ci, combo in enumerate(combos):
        sel = best_idx == ci
        if not np.any(sel):
            continue
        shifts[sel] = np.array(combo, dtype=np.uint8)
        masks[sel] = _masks_for(combo, best_q[sel])
    return PackedLayer(
        shape=w.shape,
        group_size=group_size,
        n_shifts=n_shifts,
        scale=scale,
        shifts=shifts,
        masks=masks,
        signs=signs,
        consecutive=consecutive,
    )


def _alpha_ratio(alpha: float) -> tuple[int, int]:
    """Rational (num, den) for exact-integer MSE++ comparisons."""
    den = 100
    num = int(round(alpha * den))
    return num, den


# --------------------------------------------------------------------------
# truncation baselines
# --------------------------------------------------------------------------


def truncate_weights(w: np.ndarray, n_bits: int) -> np.ndarray:
    """Layer-wise weight truncation + clipping (the paper's conventional
    baseline): keep the top `n_bits` of the 8-bit magnitude by zeroing the
    low 8-n bits (with round-to-nearest), i.e. consecutive MSB shifts with
    a shared layer-wide offset of 8-n.
    """
    q = to_int8(w)
    drop = BITS - n_bits
    step = 1 << drop
    mags = q.mags.astype(np.int64)
    t = np.clip((mags + step // 2) // step * step, 0, MAG_MAX)
    return (t * q.signs).astype(np.float64) * q.scale


def truncate_activations(a: np.ndarray, n_bits: int, amax: float) -> np.ndarray:
    """Layer-wise activation LSB truncation (as in Stripes [8]): quantize
    to 8 bits with range [0, amax] (post-ReLU), then drop the low 8-n bits.
    """
    scale = amax / 255.0 if amax > 0 else 1.0
    q = np.clip(np.round(a / scale), 0, 255).astype(np.int64)
    drop = BITS - n_bits
    t = (q >> drop) << drop
    return t.astype(np.float64) * scale


# --------------------------------------------------------------------------
# filter scheduling (Sec. 4.3)
# --------------------------------------------------------------------------


@dataclass
class ScheduleResult:
    filter_shifts: np.ndarray  # (K,) shifts per filter after phase 2
    packed: PackedLayer  # layer packed with per-filter shift counts
    err_scheduled: float
    err_uniform: float


def _layer_err_at(
    mags: np.ndarray, n_shifts: int, consecutive: bool, alpha_num: int, alpha_den: int
) -> tuple[np.ndarray, np.ndarray]:
    combos = (
        consecutive_combos(n_shifts) if consecutive else shift_combos(n_shifts)
    )
    idx, q = _select_per_group(mags, combos, alpha_num, alpha_den)
    err = msepp_int(mags - q, alpha_num, alpha_den)
    return err, q


def schedule_filters(
    w: np.ndarray,
    target_shifts: float,
    group_size: int = 4,
    alpha: float = 1.0,
    consecutive: bool = False,
    sa_cols: int = 8,
    max_shifts: int = BITS,
) -> ScheduleResult:
    """Sec. 4.3 two-phase scheduling.

    Phase 1: start every filter at ceil(target)+1 shifts; repeatedly demote
    the filters whose MSE++ cost of losing one shift is smallest, until the
    layer-average number of shifts hits `target_shifts`.

    Phase 2: filters sorted by allotted shifts are mapped to systolic-array
    column groups of size `sa_cols`; enumerate non-decreasing per-group
    assignments that preserve the target average and keep the one with the
    lowest total MSE++.
    """
    alpha_num, alpha_den = _alpha_ratio(alpha)
    mags, signs, scale, gpf = _group_mags(w, group_size)
    k = w.shape[0]
    mags_f = mags.reshape(k, gpf, group_size)

    hi = min(max_shifts, int(np.ceil(target_shifts)) + 1)
    # per-filter error at each shift count 1..hi (computed lazily)
    err_cache: dict[int, np.ndarray] = {}

    def filt_err(n: int) -> np.ndarray:
        if n not in err_cache:
            if n == 0:
                err_cache[n] = np.array(
                    [
                        msepp_int(mags_f[f].reshape(-1, group_size), alpha_num, alpha_den).sum()
                        for f in range(k)
                    ]
                )
            else:
                e, _ = _layer_err_at(
                    mags.reshape(-1, group_size), n, consecutive, alpha_num, alpha_den
                )
                err_cache[n] = e.reshape(k, gpf).sum(axis=1)
        return err_cache[n]

    shifts = np.full(k, hi, dtype=np.int64)
    target_total = int(round(target_shifts * k))
    while shifts.sum() > target_total:
        # cost of demoting each filter by one shift
        cost = np.full(k, np.iinfo(np.int64).max, dtype=np.int64)
        for n in np.unique(shifts):
            if n <= 1:
                continue
            sel = shifts == n
            cost[sel] = (filt_err(int(n) - 1) - filt_err(int(n)))[sel]
        order = np.argsort(cost, kind="stable")
        n_demote = min(int(shifts.sum() - target_total), max(1, k // 8))
        demoted = [f for f in order if shifts[f] > 1][:n_demote]
        if not demoted:
            break
        shifts[demoted] -= 1

    err_uniform = None
    # uniform reference at ceil(target)
    e_u, _ = _layer_err_at(
        mags.reshape(-1, group_size),
        max(1, int(np.ceil(target_shifts))),
        consecutive,
        alpha_num,
        alpha_den,
    )
    err_uniform = float(e_u.sum())

    # ---- phase 2: group filters into SA column blocks with equal shifts
    order = np.argsort(shifts, kind="stable")
    n_blocks = (k + sa_cols - 1) // sa_cols
    best = None
    for seq in _nondecreasing_seqs(n_blocks, 1, hi, target_total, k, sa_cols):
        tot = 0
        for b, n in enumerate(seq):
            filt = order[b * sa_cols : (b + 1) * sa_cols]
            tot += int(filt_err(n)[filt].sum())
        if best is None or tot < best[0]:
            best = (tot, seq)
    assert best is not None
    _, seq = best
    final = np.zeros(k, dtype=np.int64)
    for b, n in enumerate(seq):
        final[order[b * sa_cols : (b + 1) * sa_cols]] = n

    packed = _pack_with_filter_shifts(
        w, final, group_size, alpha_num, alpha_den, consecutive
    )
    return ScheduleResult(
        filter_shifts=final,
        packed=packed,
        err_scheduled=float(best[0]),
        err_uniform=err_uniform,
    )


def _nondecreasing_seqs(
    n_blocks: int, lo: int, hi: int, target_total: int, k: int, sa_cols: int
):
    """Non-decreasing shift sequences over filter blocks whose weighted sum
    approximates the layer target (exact when k % sa_cols == 0)."""
    block_sizes = [min(sa_cols, k - b * sa_cols) for b in range(n_blocks)]

    def rec(b: int, prev: int, acc: list[int], tot: int):
        if b == n_blocks:
            if tot == target_total:
                yield tuple(acc)
            return
        rem = sum(block_sizes[b:])
        for n in range(prev, hi + 1):
            nt = tot + n * block_sizes[b]
            # prune: even max/min fill can't reach target
            if nt + (rem - block_sizes[b]) * hi < target_total:
                continue
            if nt + (rem - block_sizes[b]) * lo > target_total:
                break
            yield from rec(b + 1, n, acc + [n], nt)

    seqs = list(rec(0, lo, [], 0))
    if not seqs:  # fall back: closest achievable total
        base = int(round(target_total / k))
        seqs = [tuple([max(lo, min(hi, base))] * n_blocks)]
    return seqs


def _pack_with_filter_shifts(
    w: np.ndarray,
    filter_shifts: np.ndarray,
    group_size: int,
    alpha_num: int,
    alpha_den: int,
    consecutive: bool,
) -> PackedLayer:
    """Pack a layer where each filter may use a different shift count.
    Storage uses the per-layer max N; filters with fewer shifts leave the
    tail mask planes zero (hardware skips them via the schedule)."""
    mags, signs, scale, gpf = _group_mags(w, group_size)
    k = w.shape[0]
    n_max = int(filter_shifts.max())
    n_groups = mags.shape[0]
    shifts = np.zeros((n_groups, n_max), dtype=np.uint8)
    masks = np.zeros((n_groups, group_size, n_max), dtype=np.uint8)
    for n in np.unique(filter_shifts):
        n = int(n)
        fsel = filter_shifts == n
        gsel = np.repeat(fsel, gpf)
        combos = consecutive_combos(n) if consecutive else shift_combos(n)
        idx, q = _select_per_group(mags[gsel], combos, alpha_num, alpha_den)
        sh = np.zeros((int(gsel.sum()), n_max), dtype=np.uint8)
        mk = np.zeros((int(gsel.sum()), group_size, n_max), dtype=np.uint8)
        for ci, combo in enumerate(combos):
            s = idx == ci
            if not np.any(s):
                continue
            sh[s, :n] = np.array(combo, dtype=np.uint8)
            mk[s, :, :n] = _masks_for(combo, q[s])
        shifts[gsel] = sh
        masks[gsel] = mk
    return PackedLayer(
        shape=w.shape,
        group_size=group_size,
        n_shifts=n_max,
        scale=scale,
        shifts=shifts,
        masks=masks,
        signs=signs,
        consecutive=consecutive,
        filter_shifts=filter_shifts.astype(np.int64),
    )
