"""synth-CIFAR: a deterministic procedural 10-class 32x32x3 dataset.

Substitute for ImageNet/CIFAR-100 (DESIGN.md §4): each class is a distinct
parametric texture (oriented gratings x color palettes x blob layouts) with
per-sample jitter and additive noise, so the task is learnable but not
trivial — a trained TinyCNN reaches high accuracy, and post-training
quantization degrades it in the same way it degrades real CNNs (the
mechanism SWIS exploits — bit-sparse near-zero weights — is distributional,
not dataset-specific).
"""

from __future__ import annotations

import numpy as np

IMG = 32
NCLASS = 10


def _grating(theta: float, freq: float, phase: float) -> np.ndarray:
    ys, xs = np.mgrid[0:IMG, 0:IMG].astype(np.float64) / IMG
    u = np.cos(theta) * xs + np.sin(theta) * ys
    return np.sin(2 * np.pi * freq * u + phase)


def _blobs(rng: np.random.Generator, cx: float, cy: float, r: float) -> np.ndarray:
    ys, xs = np.mgrid[0:IMG, 0:IMG].astype(np.float64) / IMG
    jx, jy = rng.uniform(-0.08, 0.08, size=2)
    d2 = (xs - cx - jx) ** 2 + (ys - cy - jy) ** 2
    return np.exp(-d2 / (2 * r * r))


# class archetypes: (grating angle, frequency, palette rgb, blob center)
_ARCHETYPES = [
    (0.0, 3.0, (1.0, 0.2, 0.2), (0.25, 0.25)),
    (np.pi / 4, 3.0, (0.2, 1.0, 0.2), (0.75, 0.25)),
    (np.pi / 2, 3.0, (0.2, 0.2, 1.0), (0.25, 0.75)),
    (3 * np.pi / 4, 3.0, (1.0, 1.0, 0.2), (0.75, 0.75)),
    (0.0, 6.0, (1.0, 0.2, 1.0), (0.5, 0.5)),
    (np.pi / 4, 6.0, (0.2, 1.0, 1.0), (0.5, 0.2)),
    (np.pi / 2, 6.0, (1.0, 0.6, 0.2), (0.2, 0.5)),
    (3 * np.pi / 4, 6.0, (0.6, 0.2, 1.0), (0.8, 0.5)),
    (np.pi / 8, 1.5, (0.7, 0.7, 0.7), (0.5, 0.8)),
    (5 * np.pi / 8, 9.0, (0.3, 0.8, 0.5), (0.35, 0.6)),
]


def make_batch(
    rng: np.random.Generator, n: int, noise: float = 0.9
) -> tuple[np.ndarray, np.ndarray]:
    """Returns (images NHWC float32 in [-1,1], labels int32).

    Signal amplitude is kept low relative to the noise floor and each
    sample mixes in a random distractor archetype, so the Bayes-optimal
    accuracy sits well below 100% and small weight perturbations (i.e.
    aggressive quantization) measurably move test accuracy.
    """
    labels = rng.integers(0, NCLASS, size=n)
    imgs = np.zeros((n, IMG, IMG, 3), dtype=np.float64)
    for i, y in enumerate(labels):
        theta, freq, rgb, (cx, cy) = _ARCHETYPES[int(y)]
        theta = theta + rng.uniform(-0.35, 0.35)
        freq = freq * rng.uniform(0.8, 1.2)
        g = _grating(theta, freq, rng.uniform(0, 2 * np.pi))
        b = _blobs(rng, cx, cy, 0.18)
        base = 0.35 * g + 0.45 * b
        # distractor: a different class's texture at low amplitude
        dy = int(rng.integers(0, NCLASS))
        dtheta, dfreq, drgb, (dcx, dcy) = _ARCHETYPES[dy]
        dg = _grating(dtheta + rng.uniform(-0.3, 0.3), dfreq, rng.uniform(0, 2 * np.pi))
        db = _blobs(rng, dcx, dcy, 0.18)
        dbase = 0.2 * dg + 0.25 * db
        for c in range(3):
            imgs[i, :, :, c] = rgb[c] * base + drgb[c] * dbase
    imgs += rng.normal(0, noise, size=imgs.shape)
    return np.clip(imgs, -1.5, 1.5).astype(np.float32), labels.astype(np.int32)


def make_dataset(
    seed: int, n_train: int = 4096, n_test: int = 512
) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    xtr, ytr = make_batch(rng, n_train)
    xte, yte = make_batch(rng, n_test)
    return {"x_train": xtr, "y_train": ytr, "x_test": xte, "y_test": yte}
