"""Build-time training of the TinyCNN proxy on synth-CIFAR.

Runs once under `make artifacts` (skipped when artifacts/tinycnn_weights.npz
exists). Plain Adam in JAX; a few hundred steps reach >90% test accuracy,
which gives the PTQ experiments (Tables 2/3, Fig. 6) headroom to resolve
the SWIS vs SWIS-C vs truncation ordering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import model as model_mod


def loss_fn(params, x, y):
    logits = model_mod.forward(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, y[:, None], axis=1).mean()


@functools.partial(jax.jit, donate_argnums=(0, 1, 2))
def adam_step(params, m, v, step, x, y, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        g = grads[k]
        new_m[k] = b1 * m[k] + (1 - b1) * g
        new_v[k] = b2 * v[k] + (1 - b2) * g * g
        mhat = new_m[k] / (1 - b1**step)
        vhat = new_v[k] / (1 - b2**step)
        new_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
    return new_p, new_m, new_v, loss


def train(
    seed: int = 0,
    steps: int = 600,
    batch: int = 128,
    log_every: int = 50,
    dataset: dict | None = None,
    params: dict | None = None,
    lr: float = 1e-3,
) -> tuple[dict, dict, list[tuple[int, float, float]]]:
    """Returns (params, dataset, log[(step, loss, test_acc)])."""
    ds = dataset or data_mod.make_dataset(seed)
    p = params or model_mod.init_params(seed)
    p = {k: jnp.asarray(v) for k, v in p.items()}
    m = {k: jnp.zeros_like(v) for k, v in p.items()}
    v = {k: jnp.zeros_like(x) for k, x in p.items()}
    rng = np.random.default_rng(seed + 1)
    ntr = ds["x_train"].shape[0]
    log = []
    for step in range(1, steps + 1):
        idx = rng.integers(0, ntr, size=batch)
        x = jnp.asarray(ds["x_train"][idx])
        y = jnp.asarray(ds["y_train"][idx])
        p, m, v, loss = adam_step(p, m, v, step, x, y, lr=lr)
        if step % log_every == 0 or step == steps:
            acc = model_mod.accuracy(p, jnp.asarray(ds["x_test"]), jnp.asarray(ds["y_test"]))
            log.append((step, float(loss), acc))
            print(f"  step {step:4d}  loss {float(loss):.4f}  test_acc {acc:.4f}")
    return {k: np.asarray(x) for k, x in p.items()}, ds, log
