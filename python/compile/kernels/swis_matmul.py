"""Layer-1 Pallas kernel: SWIS bit-serial grouped MAC (paper Eq. 7).

The kernel mirrors the SWIS PE pipeline of Sec. 3.1/3.2:

  * the grid's innermost dimension iterates SHIFT CYCLES (the staggered
    schedule: the activation tile stays resident — the "activation fed in
    repeatedly" of Sec. 3.2 — while mask planes stream through);
  * each step ANDs activations with the shift's mask plane (here a masked
    matmul on the MXU), applies conditional sign inversion, reduces across
    the group dimension (the K contraction), and accumulates the reduced
    sum shifted by 2^{s_j} (a scalar multiply).

TPU mapping (DESIGN.md §3): activation tile ↔ VMEM act buffer, mask-plane
stream ↔ weight stream, shift loop ↔ bit-serial cycles. interpret=True is
mandatory here — CPU PJRT cannot execute Mosaic custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BM = 128  # activation-tile rows  (paper: SA rows * unroll)
DEFAULT_BN = 128  # output columns        (paper: SA columns)


def _kernel(a_ref, m_ref, s_ref, powers_ref, o_ref):
    """One (i, n, j) grid step: o[i,n] += 2^{s_j} * (a[i] @ (sign*mask_j)[n])."""
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # conditional sign inversion + masking = the PE's AND + negate stage
    plane = s_ref[...] * m_ref[...]
    # group reduction on the MXU (the PE adder tree), then barrel shift
    o_ref[...] += powers_ref[j] * jnp.dot(
        a_ref[...], plane, preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def swis_matmul(a, masks, signs, powers, bm=DEFAULT_BM, bn=DEFAULT_BN):
    """SWIS grouped bit-serial matmul.

    a:      (M, K) float32 activations
    masks:  (S, K, N) {0,1} mask planes (shift-major, as stored by the
            PackedLayer format — one plane per shift cycle)
    signs:  (K, N) ±1 weight signs
    powers: (S,) float32 shift powers 2^{s_j}
    returns (M, N) float32

    Block decomposition: (M, N) output tiles of (bm, bn); the K dimension
    (weight-group fan-in) is kept whole per tile, matching the paper's PE
    which reduces a full group per cycle.
    """
    m, k = a.shape
    s, k2, n = masks.shape
    assert k == k2 and signs.shape == (k, n) and powers.shape == (s,)
    bm = min(bm, m)
    bn = min(bn, n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), s)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, nn, j: (i, 0)),  # act tile resident
            pl.BlockSpec((None, k, bn), lambda i, nn, j: (j, 0, nn)),  # mask plane
            pl.BlockSpec((k, bn), lambda i, nn, j: (0, nn)),  # signs
            pl.BlockSpec((s,), lambda i, nn, j: (0,)),  # shift powers (SMEM-like)
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, nn, j: (i, nn)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(
        a.astype(jnp.float32),
        masks.astype(jnp.float32),
        signs.astype(jnp.float32),
        powers.astype(jnp.float32),
    )


def swis_matmul_nokernel(a, masks, signs, powers):
    """jnp fallback with identical semantics (used when shapes are too
    small/ragged to justify the kernel; kept in the same module so L2 can
    switch transparently)."""
    planes = signs[None] * masks  # (S, K, N)
    eff = (planes * powers[:, None, None]).sum(axis=0)
    return (a.astype(jnp.float32) @ eff.astype(jnp.float32)).astype(jnp.float32)


# --------------------------------------------------------------------------
# Double-shift variant (paper Sec. 3.1): two shift planes per grid step,
# amortizing the resident activation tile the way the DS PE amortizes its
# activation buffer and sign stage. Shift planes are padded to an even
# count with a zero plane (the "wasted slot" of an odd shift budget).
# --------------------------------------------------------------------------


def _kernel_ds(a_ref, m_ref, s_ref, powers_ref, o_ref):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    plane0 = s_ref[...] * m_ref[0]
    plane1 = s_ref[...] * m_ref[1]
    acc = powers_ref[2 * j] * jnp.dot(
        a_ref[...], plane0, preferred_element_type=jnp.float32
    )
    acc += powers_ref[2 * j + 1] * jnp.dot(
        a_ref[...], plane1, preferred_element_type=jnp.float32
    )
    o_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def swis_matmul_ds(a, masks, signs, powers, bm=DEFAULT_BM, bn=DEFAULT_BN):
    """Double-shift SWIS matmul: identical semantics to swis_matmul, half
    the grid steps along the shift dimension (odd S pays a padded slot)."""
    m, k = a.shape
    s, k2, n = masks.shape
    assert k == k2 and signs.shape == (k, n) and powers.shape == (s,)
    if s % 2 == 1:  # pad the wasted DS slot
        masks = jnp.concatenate([masks, jnp.zeros((1, k, n), masks.dtype)], axis=0)
        powers = jnp.concatenate([powers, jnp.zeros((1,), powers.dtype)], axis=0)
        s += 1
    bm = min(bm, m)
    bn = min(bn, n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), s // 2)
    return pl.pallas_call(
        _kernel_ds,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, nn, j: (i, 0)),
            pl.BlockSpec((2, k, bn), lambda i, nn, j: (j, 0, nn)),  # plane pair
            pl.BlockSpec((k, bn), lambda i, nn, j: (0, nn)),
            pl.BlockSpec((s,), lambda i, nn, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, nn, j: (i, nn)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(
        a.astype(jnp.float32),
        masks.astype(jnp.float32),
        signs.astype(jnp.float32),
        powers.astype(jnp.float32),
    )
