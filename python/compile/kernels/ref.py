"""Pure-jnp oracle for the SWIS bit-serial grouped MAC (Eq. 7).

The decomposed weight operand is dense here (mask planes as 0/1 floats,
signs as ±1 floats, shift powers as floats); the Pallas kernel in
swis_matmul.py must match this to float32 accuracy.
"""

import jax.numpy as jnp


def swis_matmul_ref(a, masks, signs, powers):
    """Eq. 7:  out = sum_j 2^{s_j} * (a @ (signs * masks[j])).

    a:      (M, K)      activations
    masks:  (S, K, N)   per-shift-plane mask bits (0/1)
    signs:  (K, N)      weight signs (±1)
    powers: (S,)        2^{s_j} shift powers
    returns (M, N)
    """
    s = masks.shape[0]
    out = jnp.zeros((a.shape[0], masks.shape[2]), dtype=jnp.float32)
    for j in range(s):
        plane = signs * masks[j]
        out = out + powers[j] * (a.astype(jnp.float32) @ plane.astype(jnp.float32))
    return out


def swis_dequant_ref(masks, signs, powers):
    """Effective dense weight matrix implied by the decomposition."""
    w = (masks * powers[:, None, None]).sum(axis=0)
    return signs * w
