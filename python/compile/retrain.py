"""Quantization-aware retraining (paper Sec. 5.1.2, Table 5).

Shift-value selection is treated as a special quantization: every
`reselect_every` steps the SWIS decomposition is recomputed from the
current master weights (the paper reselects per batch; we amortize
slightly for build-time cost), the forward pass runs on the quantized
weights, and the straight-through estimator routes gradients to the FP32
master copy.

Scheduled fractional shift targets (e.g. 2.5) use the Sec. 4.3 scheduler
to assign per-filter shift counts before packing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import model as model_mod
from . import swis_quant as sq
from .train import loss_fn as _plain_loss


def _quantize_convs(
    params: dict[str, np.ndarray],
    n_shifts: float,
    group_size: int,
    consecutive: bool,
    alpha: float = 1.0,
) -> dict[str, np.ndarray]:
    """Dequantized conv weights at the target (possibly fractional) shifts."""
    out = {}
    for name in model_mod.conv_names():
        w = np.asarray(params[name])
        wm = np.moveaxis(w, -1, 0)  # filters-first for grouping
        if float(n_shifts).is_integer():
            pk = sq.quantize_swis(wm, int(n_shifts), group_size, alpha, consecutive)
        else:
            pk = sq.schedule_filters(
                wm, n_shifts, group_size, alpha, consecutive
            ).packed
        out[name] = np.moveaxis(pk.to_float(), 0, -1).astype(np.float32)
    return out


def qat_loss(params, qweights, x, y):
    """Loss at straight-through quantized weights."""
    p = dict(params)
    for k, wq in qweights.items():
        p[k] = params[k] + jax.lax.stop_gradient(wq - params[k])
    logits = model_mod.forward(p, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, y[:, None], axis=1).mean()


@functools.partial(jax.jit, donate_argnums=(0, 1, 2))
def qat_step(params, m, v, step, qweights, x, y, lr=2e-4, b1=0.9, b2=0.999, eps=1e-8):
    loss, grads = jax.value_and_grad(qat_loss)(params, qweights, x, y)
    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        g = grads[k]
        new_m[k] = b1 * m[k] + (1 - b1) * g
        new_v[k] = b2 * v[k] + (1 - b2) * g * g
        mhat = new_m[k] / (1 - b1**step)
        vhat = new_v[k] / (1 - b2**step)
        new_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
    return new_p, new_m, new_v, loss


def retrain(
    params: dict[str, np.ndarray],
    dataset: dict[str, np.ndarray],
    n_shifts: float,
    group_size: int = 4,
    consecutive: bool = False,
    mode: str = "swis",  # "swis" | "trunc"
    steps: int = 150,
    batch: int = 128,
    reselect_every: int = 5,
    seed: int = 7,
    lr: float = 2e-4,
) -> tuple[float, dict[str, np.ndarray]]:
    """Returns (quantized test accuracy after retraining, final params)."""
    p = {k: jnp.asarray(v) for k, v in params.items()}
    m = {k: jnp.zeros_like(v) for k, v in p.items()}
    v = {k: jnp.zeros_like(x) for k, x in p.items()}
    rng = np.random.default_rng(seed)
    ntr = dataset["x_train"].shape[0]
    qw = None
    for step in range(1, steps + 1):
        if qw is None or step % reselect_every == 0:
            pn = {k: np.asarray(x) for k, x in p.items()}
            if mode == "trunc":
                qw = {
                    name: sq.truncate_weights(pn[name], int(n_shifts)).astype(np.float32)
                    for name in model_mod.conv_names()
                }
            else:
                qw = _quantize_convs(pn, n_shifts, group_size, consecutive)
        idx = rng.integers(0, ntr, size=batch)
        x = jnp.asarray(dataset["x_train"][idx])
        y = jnp.asarray(dataset["y_train"][idx])
        qwj = {k: jnp.asarray(w) for k, w in qw.items()}
        p, m, v, _ = qat_step(p, m, v, step, qwj, x, y, lr=lr)
    # final evaluation at quantized weights
    pn = {k: np.asarray(x) for k, x in p.items()}
    if mode == "trunc":
        qw = {
            name: sq.truncate_weights(pn[name], int(n_shifts)).astype(np.float32)
            for name in model_mod.conv_names()
        }
    else:
        qw = _quantize_convs(pn, n_shifts, group_size, consecutive)
    peval = dict(pn)
    peval.update(qw)
    acc = model_mod.accuracy(
        {k: jnp.asarray(v) for k, v in peval.items()},
        jnp.asarray(dataset["x_test"]),
        jnp.asarray(dataset["y_test"]),
    )
    return float(acc), pn


def quantized_accuracy(
    params: dict[str, np.ndarray],
    dataset: dict[str, np.ndarray],
    n_shifts: float,
    mode: str = "swis",
    consecutive: bool = False,
    group_size: int = 4,
) -> float:
    """Test accuracy with conv weights quantized (no retraining) — the
    post-training starting point Table 5's retrained numbers improve on."""
    pn = {k: np.asarray(v) for k, v in params.items()}
    if mode == "trunc":
        qw = {
            name: sq.truncate_weights(pn[name], int(n_shifts)).astype(np.float32)
            for name in model_mod.conv_names()
        }
    else:
        qw = _quantize_convs(pn, n_shifts, group_size, consecutive)
    peval = dict(pn)
    peval.update(qw)
    return float(
        model_mod.accuracy(
            {k: jnp.asarray(v) for k, v in peval.items()},
            jnp.asarray(dataset["x_test"]),
            jnp.asarray(dataset["y_test"]),
        )
    )
