"""Layer-2 JAX model: TinyCNN — the accuracy-proxy network (DESIGN.md §4).

A VGG-style CNN (6 conv + 2 FC, ~150k params) on 32x32x3 inputs. The
forward pass takes WEIGHTS AS ARGUMENTS so a single AOT-lowered HLO
artifact executes both the FP32 baseline and any quantized weight set the
Rust coordinator feeds it — quantization is a pure weight transform
(paper Sec. 2), so the graph is shared.

`forward_swis_conv1` additionally routes the first convolution through the
Layer-1 Pallas kernel (im2col + swis_matmul) to prove kernel-in-model
composition end to end.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.swis_matmul import swis_matmul

# (name, (kh, kw, cin, cout), stride)
CONV_SPECS = [
    ("conv1", (3, 3, 3, 32), 1),
    ("conv2", (3, 3, 32, 32), 2),
    ("conv3", (3, 3, 32, 64), 1),
    ("conv4", (3, 3, 64, 64), 2),
    ("conv5", (3, 3, 64, 128), 1),
    ("conv6", (3, 3, 128, 128), 2),
]
FC_SPECS = [("fc1", (128, 64)), ("fc2", (64, 10))]
PARAM_ORDER = [n for n, *_ in CONV_SPECS] + [n for n, _ in FC_SPECS]


def init_params(seed: int = 0) -> dict[str, np.ndarray]:
    """He-normal initialization; numpy so the trainer owns the buffers."""
    rng = np.random.default_rng(seed)
    params: dict[str, np.ndarray] = {}
    for name, (kh, kw, cin, cout), _ in CONV_SPECS:
        fan_in = kh * kw * cin
        params[name] = (rng.standard_normal((kh, kw, cin, cout)) * np.sqrt(2.0 / fan_in)).astype(np.float32)
        params[name + "_b"] = np.zeros(cout, dtype=np.float32)
    for name, (din, dout) in FC_SPECS:
        params[name] = (rng.standard_normal((din, dout)) * np.sqrt(2.0 / din)).astype(np.float32)
        params[name + "_b"] = np.zeros(dout, dtype=np.float32)
    return params


def conv_names() -> list[str]:
    return [n for n, *_ in CONV_SPECS]


def _conv(x, w, b, stride):
    y = jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    return y + b


def forward(params, x):
    """Logits for NHWC input batch. params: dict name -> array."""
    h = x
    for name, _, stride in CONV_SPECS:
        h = jax.nn.relu(_conv(h, params[name], params[name + "_b"], stride))
    h = h.mean(axis=(1, 2))  # global average pool -> (B, 128)
    h = jax.nn.relu(h @ params["fc1"] + params["fc1_b"])
    return h @ params["fc2"] + params["fc2_b"]


def forward_flat(x, *flat_params):
    """forward() with a flat positional param list (AOT artifact signature:
    images first, then conv1, conv1_b, ..., fc2, fc2_b in PARAM_ORDER)."""
    params = {}
    it = iter(flat_params)
    for name in PARAM_ORDER:
        params[name] = next(it)
        params[name + "_b"] = next(it)
    return forward(params, x)


def flat_param_list(params) -> list[np.ndarray]:
    out = []
    for name in PARAM_ORDER:
        out.append(params[name])
        out.append(params[name + "_b"])
    return out


# --------------------------------------------------------------------------
# Pallas-kernel-backed first convolution (L1 composition proof)
# --------------------------------------------------------------------------


def _im2col(x, kh, kw, stride):
    """NHWC -> (B*Ho*Wo, kh*kw*C) patches with SAME padding."""
    b, h, w, c = x.shape
    ph, pw = kh // 2, kw // 2
    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    ho = (h + stride - 1) // stride
    wo = (w + stride - 1) // stride
    cols = []
    for i in range(kh):
        for j in range(kw):
            patch = jax.lax.slice(
                xp, (0, i, j, 0), (b, i + h, j + w, c), (1, stride, stride, 1)
            )
            cols.append(patch)
    stacked = jnp.stack(cols, axis=3)  # (B, Ho, Wo, kh*kw, C)
    return stacked.reshape(b * ho * wo, kh * kw * c), (b, ho, wo)


def forward_swis_conv1(x, masks1, signs1, powers1, scale1, b1, *rest_flat):
    """Forward pass with conv1 executed by the Layer-1 SWIS Pallas kernel.

    masks1: (S, 27, 32) mask planes for conv1's (3*3*3, 32) weight matrix;
    signs1: (27, 32); powers1: (S,); scale1: scalar dequant scale.
    rest_flat: conv2, conv2_b, ... in PARAM_ORDER order (conv1 omitted).
    """
    cols, (b, ho, wo) = _im2col(x, 3, 3, 1)
    y = swis_matmul(cols, masks1, signs1, powers1) * scale1
    h = jax.nn.relu(y.reshape(b, ho, wo, -1) + b1)
    params = {}
    it = iter(rest_flat)
    for name in PARAM_ORDER[1:]:
        params[name] = next(it)
        params[name + "_b"] = next(it)
    for name, _, stride in CONV_SPECS[1:]:
        h = jax.nn.relu(_conv(h, params[name], params[name + "_b"], stride))
    h = h.mean(axis=(1, 2))
    h = jax.nn.relu(h @ params["fc1"] + params["fc1_b"])
    return h @ params["fc2"] + params["fc2_b"]


def accuracy(params, x, y) -> float:
    logits = forward(params, x)
    return float((jnp.argmax(logits, -1) == y).mean())


# --------------------------------------------------------------------------
# Activation truncation baseline (paper Sec. 5: layer-wise LSB truncation on
# all activations, simulating Stripes-style bit-serial act quantization [8])
# --------------------------------------------------------------------------


def act_trunc(a, bits: int):
    """Quantize activations to 8-bit codes (dynamic layer-wise max scaling)
    and truncate the last 8-bits LSBs — Eq. analog of the paper's
    activation-truncation comparison. Static `bits`; post-ReLU inputs."""
    amax = jnp.maximum(jnp.max(a), 1e-6)
    code = jnp.clip(jnp.round(a / amax * 255.0), 0.0, 255.0)
    step = float(1 << (8 - bits))
    code = jnp.floor(code / step) * step
    return code / 255.0 * amax


def forward_act_trunc(bits: int):
    """Factory: forward pass with every activation truncated to `bits`."""

    def fwd(x, *flat_params):
        params = {}
        it = iter(flat_params)
        for name in PARAM_ORDER:
            params[name] = next(it)
            params[name + "_b"] = next(it)
        h = x  # input images are zero-centered; truncation applies to
        # the unsigned post-ReLU activations only
        for name, _, stride in CONV_SPECS:
            h = act_trunc(jax.nn.relu(_conv(h, params[name], params[name + "_b"], stride)), bits)
        h = h.mean(axis=(1, 2))
        h = act_trunc(jax.nn.relu(h @ params["fc1"] + params["fc1_b"]), bits)
        return h @ params["fc2"] + params["fc2_b"]

    return fwd
