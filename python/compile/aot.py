"""AOT build: train the proxy model, lower Layer-2 graphs (and the Layer-1
Pallas kernel inside them) to HLO *text*, and emit cross-language goldens.

HLO text — not `.serialize()` — is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids that xla_extension 0.5.1 (the
version behind the Rust `xla` crate) rejects; the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Everything here runs ONCE at build time (`make artifacts`); the Rust binary
is self-contained afterwards.

Artifacts written to --out (default ../artifacts):
  tinycnn_weights.npz   trained FP32 weights (+ biases)
  dataset.npz           synth-CIFAR test set + a train subset
  train_log.json        training curve of the build-time run
  model_b{1,8,64}.hlo.txt        forward(images, *weights) -> logits
  swis_conv1_b8.hlo.txt          forward with conv1 on the Pallas kernel
  swis_matmul.hlo.txt            standalone Layer-1 kernel artifact
  golden_quant.npz      SWIS/SWIS-C packing goldens for rust/tests/golden.rs
  retrain_results.json  Table-5 QAT accuracies (skipped with --skip-retrain)
  manifest.json         artifact index: inputs, shapes, dtypes
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as data_mod
from . import model as model_mod
from . import retrain as retrain_mod
from . import swis_quant as sq
from . import train as train_mod
from .kernels.swis_matmul import swis_matmul


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(a) -> dict:
    return {"shape": list(np.shape(a)), "dtype": str(np.asarray(a).dtype)}


def lower_model(params, batch: int, path: str) -> dict:
    flat = model_mod.flat_param_list(params)
    x = jax.ShapeDtypeStruct((batch, data_mod.IMG, data_mod.IMG, 3), jnp.float32)
    specs = [x] + [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in flat]
    lowered = jax.jit(model_mod.forward_flat).lower(*specs)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    inputs = [{"name": "images", **_spec(np.zeros((batch, 32, 32, 3), np.float32))}]
    for name, arr in zip(
        [n for nm in model_mod.PARAM_ORDER for n in (nm, nm + "_b")], flat
    ):
        inputs.append({"name": name, **_spec(arr)})
    return {
        "file": os.path.basename(path),
        "kind": "model",
        "batch": batch,
        "inputs": inputs,
        "output": {"shape": [batch, data_mod.NCLASS], "dtype": "float32"},
    }


def lower_act_trunc(params, batch: int, bits: int, path: str) -> dict:
    """Activation-truncation baseline artifact (Table 3 'Act.' column)."""
    flat = model_mod.flat_param_list(params)
    x = jax.ShapeDtypeStruct((batch, data_mod.IMG, data_mod.IMG, 3), jnp.float32)
    specs = [x] + [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in flat]
    lowered = jax.jit(model_mod.forward_act_trunc(bits)).lower(*specs)
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    inputs = [{"name": "images", **_spec(np.zeros((batch, 32, 32, 3), np.float32))}]
    for name, arr in zip(
        [n for nm in model_mod.PARAM_ORDER for n in (nm, nm + "_b")], flat
    ):
        inputs.append({"name": name, **_spec(arr)})
    return {
        "file": os.path.basename(path),
        "kind": f"model_act_trunc{bits}",
        "batch": batch,
        "act_bits": bits,
        "inputs": inputs,
        "output": {"shape": [batch, data_mod.NCLASS], "dtype": "float32"},
    }


def lower_swis_conv1(params, batch: int, n_shifts: int, path: str) -> dict:
    """Forward pass with conv1 through the Pallas kernel (L1∘L2 proof)."""
    rest = []
    for name in model_mod.PARAM_ORDER[1:]:
        rest.append(params[name])
        rest.append(params[name + "_b"])
    k_in = 27  # 3*3*3
    cout = 32
    specs = [
        jax.ShapeDtypeStruct((batch, 32, 32, 3), jnp.float32),  # x
        jax.ShapeDtypeStruct((n_shifts, k_in, cout), jnp.float32),  # masks
        jax.ShapeDtypeStruct((k_in, cout), jnp.float32),  # signs
        jax.ShapeDtypeStruct((n_shifts,), jnp.float32),  # powers
        jax.ShapeDtypeStruct((), jnp.float32),  # scale
        jax.ShapeDtypeStruct((cout,), jnp.float32),  # conv1 bias
    ] + [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in rest]
    lowered = jax.jit(model_mod.forward_swis_conv1).lower(*specs)
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    inputs = [
        {"name": "images", "shape": [batch, 32, 32, 3], "dtype": "float32"},
        {"name": "conv1_masks", "shape": [n_shifts, k_in, cout], "dtype": "float32"},
        {"name": "conv1_signs", "shape": [k_in, cout], "dtype": "float32"},
        {"name": "conv1_powers", "shape": [n_shifts], "dtype": "float32"},
        {"name": "conv1_scale", "shape": [], "dtype": "float32"},
        {"name": "conv1_b", "shape": [cout], "dtype": "float32"},
    ]
    for name, arr in zip(
        [n for nm in model_mod.PARAM_ORDER[1:] for n in (nm, nm + "_b")], rest
    ):
        inputs.append({"name": name, **_spec(arr)})
    return {
        "file": os.path.basename(path),
        "kind": "model_swis_conv1",
        "batch": batch,
        "n_shifts": n_shifts,
        "inputs": inputs,
        "output": {"shape": [batch, data_mod.NCLASS], "dtype": "float32"},
    }


def lower_kernel(path: str, m=64, k=128, n=64, s=4) -> dict:
    specs = [
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((s, k, n), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32),
        jax.ShapeDtypeStruct((s,), jnp.float32),
    ]
    lowered = jax.jit(swis_matmul).lower(*specs)
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    return {
        "file": os.path.basename(path),
        "kind": "swis_matmul",
        "inputs": [
            {"name": "a", "shape": [m, k], "dtype": "float32"},
            {"name": "masks", "shape": [s, k, n], "dtype": "float32"},
            {"name": "signs", "shape": [k, n], "dtype": "float32"},
            {"name": "powers", "shape": [s], "dtype": "float32"},
        ],
        "output": {"shape": [m, n], "dtype": "float32"},
    }


def write_goldens(path: str, seed: int = 42) -> None:
    """Cross-language packing goldens consumed by rust/tests/golden.rs.

    For each case: input float weights + every packed field + dequantized
    floats. The Rust quantizer must match the integer fields EXACTLY
    (shared tie-breaking conventions, see swis_quant.py docstring).
    """
    rng = np.random.default_rng(seed)
    out: dict[str, np.ndarray] = {}
    cases = []
    cid = 0
    for shape in [(8, 64), (16, 36)]:
        for gs in (1, 4):
            for ns in (2, 3):
                for consecutive in (False, True):
                    w = rng.normal(0, 0.05, size=shape)
                    # heavier tail like real conv weights
                    w += rng.normal(0, 0.15, size=shape) * (rng.random(shape) < 0.1)
                    pk = sq.quantize_swis(w, ns, gs, 1.0, consecutive)
                    key = f"case{cid}"
                    out[f"{key}_w"] = w.astype(np.float64)
                    out[f"{key}_shifts"] = pk.shifts
                    out[f"{key}_masks"] = pk.masks
                    out[f"{key}_signs"] = pk.signs
                    out[f"{key}_dequant"] = pk.to_float()
                    out[f"{key}_scale"] = np.array([pk.scale])
                    cases.append(
                        {
                            "key": key,
                            "shape": list(shape),
                            "group_size": gs,
                            "n_shifts": ns,
                            "consecutive": bool(consecutive),
                        }
                    )
                    cid += 1
    out["n_cases"] = np.array([cid])
    np.savez(path, **out)
    with open(path.replace(".npz", ".json"), "w") as f:
        json.dump(cases, f, indent=1)


RETRAIN_CONFIGS = [
    # (label, mode, consecutive, n_shifts)
    ("swis_ss_2", "swis", False, 2.0),
    ("swis_ss_2.5", "swis", False, 2.5),
    ("swis_ss_3", "swis", False, 3.0),
    ("swis_c_ss_2", "swis", True, 2.0),
    ("swis_c_ss_3", "swis", True, 3.0),
    ("trunc_2", "trunc", False, 2.0),
    ("trunc_3", "trunc", False, 3.0),
]


def run_retrain(params, ds, steps: int) -> dict:
    results = {}
    for label, mode, consecutive, ns in RETRAIN_CONFIGS:
        t0 = time.time()
        acc, _ = retrain_mod.retrain(
            params, ds, ns, mode=mode, consecutive=consecutive, steps=steps
        )
        results[label] = {"n_shifts": ns, "accuracy": acc}
        print(f"  retrain {label}: acc={acc:.4f} ({time.time()-t0:.1f}s)")
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--train-steps", type=int, default=500)
    ap.add_argument("--retrain-steps", type=int, default=120)
    ap.add_argument("--skip-retrain", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    wpath = os.path.join(args.out, "tinycnn_weights.npz")
    dpath = os.path.join(args.out, "dataset.npz")
    if os.path.exists(wpath) and os.path.exists(dpath):
        print("== reusing trained weights")
        params = dict(np.load(wpath))
        ds = dict(np.load(dpath))
        log = []
    else:
        print("== training TinyCNN on synth-CIFAR")
        params, ds, log = train_mod.train(seed=args.seed, steps=args.train_steps)
        np.savez(wpath, **params)
        np.savez(
            dpath,
            x_test=ds["x_test"],
            y_test=ds["y_test"],
            x_train=ds["x_train"][:1024],
            y_train=ds["y_train"][:1024],
        )
        with open(os.path.join(args.out, "train_log.json"), "w") as f:
            json.dump([{"step": s, "loss": l, "acc": a} for s, l, a in log], f, indent=1)

    baseline = model_mod.accuracy(
        {k: jnp.asarray(v) for k, v in params.items()},
        jnp.asarray(ds["x_test"]),
        jnp.asarray(ds["y_test"]),
    )
    print(f"== baseline FP32 test accuracy: {baseline:.4f}")

    print("== lowering HLO artifacts")
    manifest: dict = {"baseline_accuracy": float(baseline), "artifacts": []}
    for b in (1, 8, 64):
        p = os.path.join(args.out, f"model_b{b}.hlo.txt")
        manifest["artifacts"].append(lower_model(params, b, p))
        print(f"  wrote {p}")
    p = os.path.join(args.out, "swis_conv1_b8.hlo.txt")
    manifest["artifacts"].append(lower_swis_conv1(params, 8, 3, p))
    print(f"  wrote {p}")
    for bits in (2, 3, 4, 6, 7):
        p = os.path.join(args.out, f"model_act{bits}_b64.hlo.txt")
        manifest["artifacts"].append(lower_act_trunc(params, 64, bits, p))
        print(f"  wrote {p}")
    p = os.path.join(args.out, "swis_matmul.hlo.txt")
    manifest["artifacts"].append(lower_kernel(p))
    print(f"  wrote {p}")

    print("== writing quantization goldens")
    write_goldens(os.path.join(args.out, "golden_quant.npz"))

    rpath = os.path.join(args.out, "retrain_results.json")
    if args.skip_retrain:
        print("== skipping retraining (--skip-retrain)")
    elif os.path.exists(rpath):
        print("== reusing retrain results")
    else:
        print("== quantization-aware retraining (Table 5 proxy)")
        results = run_retrain(params, ds, args.retrain_steps)
        results["baseline"] = {"n_shifts": 8, "accuracy": float(baseline)}
        with open(rpath, "w") as f:
            json.dump(results, f, indent=1)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print("== done")


if __name__ == "__main__":
    main()
