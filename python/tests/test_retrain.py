"""Build-time training / QAT-retraining path (Table 5 machinery)."""

import numpy as np
import pytest

from compile import data as data_mod
from compile import model as model_mod
from compile import retrain as retrain_mod
from compile import swis_quant as sq


@pytest.fixture(scope="module")
def tiny_setup():
    ds = data_mod.make_dataset(seed=0, n_train=512, n_test=256)
    params = model_mod.init_params(seed=0)
    return params, ds


def test_dataset_deterministic_and_balanced():
    a = data_mod.make_dataset(seed=3)
    b = data_mod.make_dataset(seed=3)
    np.testing.assert_array_equal(a["x_test"], b["x_test"])
    # roughly class-balanced test labels
    counts = np.bincount(a["y_test"], minlength=data_mod.NCLASS)
    assert counts.min() > 0.5 * counts.mean()
    # zero-centered images
    assert abs(float(a["x_train"].mean())) < 0.25


def test_dataset_classes_separable():
    # the procedural classes must be learnable: nearest-class-mean on raw
    # pixels should already beat chance by a wide margin
    ds = data_mod.make_dataset(seed=1)
    xtr = ds["x_train"].reshape(len(ds["x_train"]), -1)
    ytr = ds["y_train"]
    xte = ds["x_test"][:256].reshape(256, -1)
    yte = ds["y_test"][:256]
    means = np.stack([xtr[ytr == c].mean(0) for c in range(data_mod.NCLASS)])
    pred = np.argmin(((xte[:, None] - means[None]) ** 2).sum(-1), axis=1)
    acc = (pred == yte).mean()
    assert acc > 0.3, f"nearest-mean accuracy {acc}"


def test_quantize_convs_matches_reference(tiny_setup):
    params, _ = tiny_setup
    q = retrain_mod._quantize_convs(params, 3, 4, False)
    for name in model_mod.conv_names():
        w = np.asarray(params[name])
        wm = np.moveaxis(w, -1, 0)
        pk = sq.quantize_swis(wm, 3, 4)
        expect = np.moveaxis(pk.to_float(), 0, -1).astype(np.float32)
        np.testing.assert_allclose(q[name], expect, rtol=1e-6)
        assert q[name].shape == w.shape


def test_short_retrain_improves_low_shift_accuracy(tiny_setup):
    params, ds = tiny_setup
    # untrained net: retraining a few steps at 2 shifts must improve the
    # quantized loss/accuracy measurably over the starting point
    acc0 = retrain_mod.quantized_accuracy(params, ds, 2.0, "swis", False)
    acc1, _ = retrain_mod.retrain(params, ds, 2.0, mode="swis", consecutive=False, steps=30)
    assert acc1 >= acc0 - 0.02, f"retraining regressed: {acc0} -> {acc1}"
