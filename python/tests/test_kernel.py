"""Layer-1 correctness: the SWIS Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes / shift counts / mask densities; every case must
match ref.py to float32 tolerance. The kernel runs interpret=True (CPU
PJRT cannot execute Mosaic custom-calls)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.ref import swis_matmul_ref, swis_dequant_ref
from compile.kernels.swis_matmul import swis_matmul, swis_matmul_nokernel


def _case(rng, m, k, n, s):
    a = rng.standard_normal((m, k)).astype(np.float32)
    masks = (rng.random((s, k, n)) < 0.4).astype(np.float32)
    signs = np.where(rng.random((k, n)) < 0.5, -1.0, 1.0).astype(np.float32)
    powers = (2.0 ** rng.integers(0, 8, size=s)).astype(np.float32)
    return a, masks, signs, powers


def test_kernel_matches_ref_basic():
    rng = np.random.default_rng(0)
    a, masks, signs, powers = _case(rng, 64, 128, 64, 4)
    out = swis_matmul(a, masks, signs, powers)
    ref = swis_matmul_ref(a, masks, signs, powers)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-4)


def test_nokernel_fallback_matches_ref():
    rng = np.random.default_rng(1)
    a, masks, signs, powers = _case(rng, 16, 32, 8, 3)
    out = swis_matmul_nokernel(a, masks, signs, powers)
    ref = swis_matmul_ref(a, masks, signs, powers)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    m=st.sampled_from([1, 7, 32, 130]),
    k=st.sampled_from([8, 27, 64]),
    n=st.sampled_from([4, 16, 33]),
    s=st.integers(min_value=1, max_value=6),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kernel_matches_ref_swept(m, k, n, s, seed):
    rng = np.random.default_rng(seed)
    a, masks, signs, powers = _case(rng, m, k, n, s)
    out = swis_matmul(a, masks, signs, powers)
    ref = swis_matmul_ref(a, masks, signs, powers)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(
    bm=st.sampled_from([8, 32, 128]),
    bn=st.sampled_from([8, 64, 128]),
    seed=st.integers(min_value=0, max_value=1000),
)
def test_block_shape_invariance(bm, bn, seed):
    """Output must not depend on the BlockSpec tiling."""
    rng = np.random.default_rng(seed)
    a, masks, signs, powers = _case(rng, 48, 36, 24, 3)
    base = swis_matmul(a, masks, signs, powers)
    tiled = swis_matmul(a, masks, signs, powers, bm=bm, bn=bn)
    np.testing.assert_allclose(np.asarray(base), np.asarray(tiled), rtol=1e-5, atol=1e-4)


def test_kernel_equals_dense_matmul_of_dequant():
    """Eq. 7 == a @ dequant(w): the bit-serial sum is exactly a matmul
    against the implied dense weights."""
    rng = np.random.default_rng(7)
    a, masks, signs, powers = _case(rng, 32, 64, 16, 4)
    w = swis_dequant_ref(jnp.asarray(masks), jnp.asarray(signs), jnp.asarray(powers))
    dense = np.asarray(a @ np.asarray(w, dtype=np.float32))
    out = np.asarray(swis_matmul(a, masks, signs, powers))
    np.testing.assert_allclose(out, dense, rtol=1e-4, atol=1e-3)


def test_zero_masks_give_zero():
    a = np.ones((8, 16), np.float32)
    masks = np.zeros((3, 16, 4), np.float32)
    signs = np.ones((16, 4), np.float32)
    powers = np.array([1.0, 2.0, 4.0], np.float32)
    out = np.asarray(swis_matmul(a, masks, signs, powers))
    assert np.all(out == 0.0)


def test_single_shift_plane_is_scaled_matmul():
    rng = np.random.default_rng(9)
    a = rng.standard_normal((16, 32)).astype(np.float32)
    mask = (rng.random((1, 32, 8)) < 0.5).astype(np.float32)
    signs = np.ones((32, 8), np.float32)
    powers = np.array([8.0], np.float32)  # shift 3
    out = np.asarray(swis_matmul(a, mask, signs, powers))
    np.testing.assert_allclose(out, 8.0 * (a @ mask[0]), rtol=1e-5, atol=1e-4)


def test_shape_mismatch_asserts():
    a = np.zeros((4, 8), np.float32)
    masks = np.zeros((2, 9, 4), np.float32)  # K mismatch
    signs = np.ones((8, 4), np.float32)
    powers = np.ones(2, np.float32)
    with pytest.raises(AssertionError):
        swis_matmul(a, masks, signs, powers)


# ------------------------------------------------------------------ DS kernel


def test_double_shift_kernel_matches_ref():
    from compile.kernels.swis_matmul import swis_matmul_ds

    rng = np.random.default_rng(21)
    for s in (2, 3, 4, 5):
        a, masks, signs, powers = _case(rng, 32, 48, 16, s)
        out = swis_matmul_ds(a, masks, signs, powers)
        ref = swis_matmul_ref(a, masks, signs, powers)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(
    s=st.integers(min_value=1, max_value=6),
    seed=st.integers(min_value=0, max_value=10000),
)
def test_double_shift_equals_single_shift(s, seed):
    from compile.kernels.swis_matmul import swis_matmul_ds

    rng = np.random.default_rng(seed)
    a, masks, signs, powers = _case(rng, 16, 24, 8, s)
    ss = swis_matmul(a, masks, signs, powers)
    ds = swis_matmul_ds(a, masks, signs, powers)
    np.testing.assert_allclose(np.asarray(ss), np.asarray(ds), rtol=1e-5, atol=1e-4)
