"""Layer-2 model graph tests: shapes, the Pallas-backed conv1 path, and
the activation-truncation baseline."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import model as m
from compile import swis_quant as sq


@pytest.fixture(scope="module")
def params():
    return m.init_params(seed=0)


def test_forward_shapes(params):
    x = jnp.zeros((4, 32, 32, 3), jnp.float32)
    logits = m.forward(params, x)
    assert logits.shape == (4, 10)


def test_forward_flat_matches_dict(params):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 32, 32, 3)).astype(np.float32))
    a = m.forward(params, x)
    b = m.forward_flat(x, *m.flat_param_list(params))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_im2col_reconstructs_conv(params):
    """conv1 via im2col + dense matmul == lax.conv (stride 1, SAME)."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((2, 32, 32, 3)).astype(np.float32))
    w = params["conv1"]  # (3,3,3,32) HWIO
    cols, (b, ho, wo) = m._im2col(x, 3, 3, 1)
    y2 = (cols @ w.reshape(-1, 32)).reshape(b, ho, wo, 32)
    import jax

    y1 = jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)


def test_swis_conv1_path_close_to_dequant(params):
    """forward_swis_conv1 (Pallas kernel on packed operands) must equal
    forward() run on the dequantized conv1 weights."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((2, 32, 32, 3)).astype(np.float32))

    w1 = np.asarray(params["conv1"])  # HWIO (3,3,3,32)
    wm = np.moveaxis(w1, -1, 0).reshape(32, -1)  # filters-first (32, 27)

    # The kernel shares one `powers` vector across every output column, so
    # quantize the whole matrix as a single group (one shared shift set) —
    # exactly the operand layout aot.py's swis_conv1 artifact expects.
    pk = sq.quantize_swis(wm.reshape(1, -1), 3, 32 * 27)
    s = pk.masks.shape[-1]
    # mask bits laid out filters-first (32, 27, S) -> kernel (S, 27, 32)
    masks_flat = pk.masks.reshape(32, 27, s)
    masks_k = np.transpose(masks_flat, (2, 1, 0)).astype(np.float32)
    signs = pk.signs.reshape(32, 27).T.astype(np.float32)
    powers = (2.0 ** pk.shifts[0]).astype(np.float32)
    scale = np.float32(pk.scale)

    rest = []
    for name in m.PARAM_ORDER[1:]:
        rest.append(params[name])
        rest.append(params[name + "_b"])
    out_kernel = m.forward_swis_conv1(
        x, masks_k, signs, powers, scale, params["conv1_b"], *rest
    )

    # reference: dequantized conv1 through the plain forward
    deq = pk.to_float().reshape(32, 27)
    p2 = dict(params)
    p2["conv1"] = np.moveaxis(deq.reshape(32, 3, 3, 3), 0, -1).astype(np.float32)
    out_ref = m.forward(p2, x)
    np.testing.assert_allclose(
        np.asarray(out_kernel), np.asarray(out_ref), rtol=1e-3, atol=1e-3
    )


def test_act_trunc_monotone(params):
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((8, 32, 32, 3)).astype(np.float32))
    base = np.asarray(m.forward(params, x))
    drift = []
    for bits in (7, 4, 2):
        out = np.asarray(m.forward_act_trunc(bits)(x, *m.flat_param_list(params)))
        drift.append(np.abs(out - base).mean())
    assert drift[0] < drift[1] < drift[2]
    assert drift[0] < 0.1  # 7 bits is nearly lossless


def test_act_trunc_preserves_zero_and_max():
    a = jnp.asarray(np.array([0.0, 0.5, 1.0], np.float32))
    q = np.asarray(m.act_trunc(a, 8))
    np.testing.assert_allclose(q, [0.0, 0.5, 1.0], atol=1e-2)
