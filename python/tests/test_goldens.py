"""Golden-artifact self-consistency: the packed fields written for the
Rust cross-check must reconstruct their own dequantized floats, and the
manifest must index every HLO artifact on disk."""

import json
import os

import numpy as np
import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def goldens():
    path = os.path.join(ART, "golden_quant.npz")
    if not os.path.exists(path):
        pytest.skip("run `make artifacts` first")
    return dict(np.load(path)), json.load(open(os.path.join(ART, "golden_quant.json")))


def test_goldens_reconstruct(goldens):
    data, cases = goldens
    assert int(data["n_cases"][0]) == len(cases) > 0
    for c in cases:
        key = c["key"]
        shifts = data[f"{key}_shifts"].astype(np.int64)  # (g, n)
        masks = data[f"{key}_masks"].astype(np.int64)  # (g, gs, n)
        signs = data[f"{key}_signs"].astype(np.float64)  # (g, gs)
        scale = float(data[f"{key}_scale"][0])
        deq = data[f"{key}_dequant"]
        mags = (masks * (1 << shifts)[:, None, :]).sum(axis=-1)
        rebuilt = (mags * signs * scale).reshape(-1)[: deq.size]
        np.testing.assert_allclose(rebuilt[: deq.size], deq.reshape(-1), rtol=1e-12)


def test_goldens_shift_sets_sorted_and_bounded(goldens):
    data, cases = goldens
    for c in cases:
        shifts = data[f"{c['key']}_shifts"]
        assert shifts.min() >= 0 and shifts.max() <= 7
        assert np.all(np.diff(shifts, axis=1) >= 0), "shifts ascend in-group"
        if c["consecutive"]:
            d = np.diff(shifts, axis=1)
            assert np.all(d == 1), "SWIS-C shifts must be consecutive"


def test_manifest_indexes_all_artifacts():
    mpath = os.path.join(ART, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("run `make artifacts` first")
    manifest = json.load(open(mpath))
    assert 0.5 < manifest["baseline_accuracy"] <= 1.0
    for a in manifest["artifacts"]:
        path = os.path.join(ART, a["file"])
        assert os.path.exists(path), f"missing {a['file']}"
        assert a["inputs"], a["file"]
        # HLO text must at least parse as an HloModule header
        head = open(path).read(200)
        assert "HloModule" in head, a["file"]
    kinds = {a["kind"] for a in manifest["artifacts"]}
    assert "model" in kinds and "swis_matmul" in kinds
    for bits in (2, 3, 4, 6, 7):
        assert f"model_act_trunc{bits}" in kinds
