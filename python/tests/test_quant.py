"""SWIS quantizer properties (the Python reference implementation that the
Rust quantizer must match exactly — see golden tests on both sides)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import swis_quant as sq


def test_lossless_when_bits_fit():
    # scale chosen so int8 mags equal the values
    w = np.array([[3.0, 65.0, 17.0, 127.0]])
    pk = sq.quantize_swis(w, 2, 1)
    mags = pk.mags().reshape(-1)
    assert list(mags[:3]) == [3, 65, 17]
    # 127 = 7 set bits -> nearest 2-shift value is 128
    assert mags[3] == 128


def test_swis_error_le_swis_c():
    rng = np.random.default_rng(3)
    w = rng.normal(0, 0.05, size=(8, 32))
    for n in (2, 3, 4):
        es = sq.rmse(w, sq.quantize_swis(w, n, 4, consecutive=False).to_float())
        ec = sq.rmse(w, sq.quantize_swis(w, n, 4, consecutive=True).to_float())
        assert es <= ec + 1e-12


@settings(max_examples=20, deadline=None)
@given(
    k=st.sampled_from([2, 5, 8]),
    fan_in=st.sampled_from([4, 30, 64]),
    gs=st.sampled_from([1, 2, 4]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_more_shifts_never_hurt(k, fan_in, gs, seed):
    rng = np.random.default_rng(seed)
    w = rng.normal(0, 0.1, size=(k, fan_in))
    last = np.inf
    for n in (1, 2, 3, 4):
        e = sq.rmse(w, sq.quantize_swis(w, n, gs).to_float())
        assert e <= last + 1e-12
        last = e


@settings(max_examples=20, deadline=None)
@given(
    gs=st.sampled_from([1, 4, 8]),
    n=st.integers(min_value=1, max_value=5),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_dequant_values_representable(gs, n, seed):
    """Every dequantized magnitude must be a sum of <= n powers of two
    from the group's selected shift set."""
    rng = np.random.default_rng(seed)
    w = rng.normal(0, 0.08, size=(4, 16))
    pk = sq.quantize_swis(w, n, gs)
    mags = pk.mags()
    for g in range(pk.n_groups):
        cb = sq.codebook(tuple(pk.shifts[g]))
        for v in mags[g]:
            assert v in cb, f"group {g}: {v} not representable"


def test_group_error_beats_finer_never():
    """Bigger groups can only match or worsen quantization error."""
    rng = np.random.default_rng(11)
    w = rng.normal(0, 0.06, size=(8, 64))
    errs = [
        sq.rmse(w, sq.quantize_swis(w, 3, gs).to_float()) for gs in (1, 4, 16)
    ]
    assert errs[0] <= errs[1] + 1e-12
    assert errs[1] <= errs[2] + 1e-12


def test_truncation_is_worse_than_swis():
    rng = np.random.default_rng(13)
    w = rng.normal(0, 0.05, size=(16, 36))
    for n in (2, 3, 4):
        es = sq.rmse(w, sq.quantize_swis(w, n, 4).to_float())
        et = sq.rmse(w, sq.truncate_weights(w, n))
        assert es < et


def test_storage_bits_formula():
    rng = np.random.default_rng(17)
    w = rng.normal(0, 0.05, size=(8, 16))
    pk = sq.quantize_swis(w, 3, 4)
    g, gs, n = pk.masks.shape
    expected = g * (gs + 3 * n + gs * n)  # signs + shifts + masks
    assert pk.storage_bits() == expected
    pkc = sq.quantize_swis(w, 3, 4, consecutive=True)
    expected_c = g * (gs + 3 + gs * n)
    assert pkc.storage_bits() == expected_c


def test_schedule_hits_fractional_target():
    rng = np.random.default_rng(19)
    w = rng.normal(0, 0.05, size=(16, 36))
    res = sq.schedule_filters(w, 2.5, 4, 1.0, False)
    assert abs(np.mean(res.filter_shifts) - 2.5) < 1e-9
    # scheduled error must interpolate the uniform ends
    e2 = sq.msepp(w, sq.quantize_swis(w, 2, 4).to_float())
    e3 = sq.msepp(w, sq.quantize_swis(w, 3, 4).to_float())
    es = sq.msepp(w, res.packed.to_float())
    assert e3 - 1e-12 <= es <= e2 + 1e-12


def test_msepp_penalizes_signed_drift():
    x = np.zeros(8)
    biased = np.full(8, 0.1)  # all errors same sign
    balanced = np.array([0.1, -0.1] * 4)  # same MSE, zero drift
    assert sq.msepp(x, biased) > sq.msepp(x, balanced)
    assert abs(sq.msepp(x, biased, alpha=0.0) - sq.msepp(x, balanced, alpha=0.0)) < 1e-12


def test_rejects_bad_args():
    w = np.zeros((2, 4))
    with pytest.raises(Exception):
        sq.quantize_swis(w, 0, 4)
    with pytest.raises(Exception):
        sq.quantize_swis(w, 9, 4)
