//! Fixture: exercises every pattern's near-miss and must stay silent.

/// Looks like trouble only inside strings and comments: `.unwrap()`,
/// `todo!`, `Err(format!`, none of them count.
pub fn tidy(p: *mut u8) -> Result<u32, Error> {
    let v = std::env::var("HOME").unwrap_or_default();
    let s = "call .unwrap() and dbg!"; // .expect( in a comment
    let r = r#"raw todo! and unimplemented!"#;
    // SAFETY: p is non-null and valid for a one-byte write; the caller
    // upholds this by construction in the fixture.
    unsafe {
        *p = 1;
    }
    let _ = FLAG.load(std::sync::atomic::Ordering::Acquire);
    FLAG.store(true, std::sync::atomic::Ordering::Release);
    if v.is_empty() && s.len() + r.len() > 0 {
        return Err(Error::Empty);
    }
    Ok(0)
}

/// Doc-commented unsafe fn with the required section.
///
/// # Safety
///
/// `p` must be non-null and valid for reads of one byte.
pub unsafe fn documented(p: *const u8) -> u8 {
    *p
}

pub enum Error {
    Empty,
}

static FLAG: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_unwrap_and_relax() {
        let x: Option<u8> = Some(1);
        assert_eq!(x.expect("present"), 1);
        let _ = super::FLAG.load(std::sync::atomic::Ordering::Relaxed);
    }
}
