//! Fixture: trips every rule at least once. Never compiled — the
//! `fixtures` path component keeps it out of real scans.

pub fn all_the_sins(p: *mut u8) -> Result<u32, String> {
    let v = std::env::var("HOME").unwrap();
    let w = std::env::var("PATH").expect("path");
    unsafe {
        *p = 1;
    }
    let _ = FLAG.load(std::sync::atomic::Ordering::Relaxed);
    FLAG.store(true, std::sync::atomic::Ordering::SeqCst);
    if v.is_empty() {
        return Err(format!("empty: {w}"));
    }
    dbg!(&v);
    todo!()
}

pub unsafe fn no_safety_doc(p: *const u8) -> u8 {
    *p
}

static FLAG: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        let x: Option<u8> = Some(1);
        assert_eq!(x.unwrap(), 1);
    }
}
