//! `swis-lint` CLI: scan the crate, print `file:line: [rule] msg`
//! diagnostics, exit 1 on findings. `--fix-list` additionally prints
//! the allowlisted debt (every budgeted unwrap site, stale budgets,
//! dead manifest entries) so burn-down work has a worklist.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut fix_list = false;
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--fix-list" => fix_list = true,
            "--help" | "-h" => {
                println!("usage: swis-lint [--fix-list] [root]");
                println!("  root defaults to '.'; may be the repo root or the rust/ crate dir");
                return ExitCode::SUCCESS;
            }
            other => root = PathBuf::from(other),
        }
    }
    let Some(rust_dir) = swis_lint::resolve_rust_dir(&root) else {
        eprintln!("swis-lint: no Rust crate found under {}", root.display());
        return ExitCode::FAILURE;
    };
    let report = match swis_lint::run(&rust_dir) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("swis-lint: scan failed: {e}");
            return ExitCode::FAILURE;
        }
    };
    for f in &report.findings {
        println!("{f}");
    }
    if fix_list && !report.fix_list.is_empty() {
        println!("-- fix list ({} entries) --", report.fix_list.len());
        for item in &report.fix_list {
            println!("{item}");
        }
    }
    eprintln!(
        "swis-lint: {} files, {} non-test unwrap/expect sites, {} findings",
        report.files_scanned,
        report.unwrap_total,
        report.findings.len()
    );
    if report.clean() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
