//! `swis-lint`: the repo's dependency-free static pass.
//!
//! Five rules, each born from a real failure mode of this codebase:
//!
//! * **unwrap-burndown** — `.unwrap()` / `.expect(` outside test scope
//!   must fit the per-file budgets in `lint/unwrap.allow`, and the total
//!   must fit `total_ceiling`. Budgets only shrink: lowering a count is a
//!   one-line allowlist edit, raising one is a review conversation.
//! * **safety-comment** — every `unsafe` block needs an adjacent
//!   `// SAFETY:` comment; every `unsafe fn` needs a `# Safety` doc
//!   section. The comment must exist where the obligation is discharged,
//!   not in a far-away module doc.
//! * **atomics-manifest** — `Ordering::Relaxed` / `Ordering::SeqCst`
//!   sites must match `lint/atomics.allow`, which pairs every site count
//!   with a one-line justification. Acquire/Release/AcqRel are the
//!   reviewed default and need no entry.
//! * **stringly-error** — `Err(format!`, `anyhow!(`, `bail!(` on the
//!   public seams (`src/api/`, `src/coordinator/`, `src/edge/`,
//!   `src/obs/`) are refused outright: seams speak `SwisError`.
//! * **debug-macro** — `todo!`, `unimplemented!`, `dbg!` anywhere.
//!
//! The scanner is textual but comment/string aware: a tokenizer-grade
//! masking pass blanks line/block comments, cooked/raw/byte strings and
//! char literals before any rule pattern runs, so `"call .unwrap()"` in
//! a doc string never trips a rule. `#[cfg(test)]` items are tracked by
//! brace depth; `tests/`, `benches/`, `examples/` trees are test scope
//! wholesale. `vendor/`, `target/` and the lint's own `fixtures/` are
//! never scanned.

use std::collections::BTreeMap;
use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// One diagnostic. `file` is relative to the crate root (`rust/`),
/// `line` is 1-based (0 = whole-file finding).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.line > 0 {
            write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
        } else {
            write!(f, "{}: [{}] {}", self.file, self.rule, self.msg)
        }
    }
}

/// Everything one lint run learned.
#[derive(Clone, Debug, Default)]
pub struct Report {
    pub findings: Vec<Finding>,
    /// `--fix-list` payload: every allowlisted debt site plus stale
    /// budget notes, as ready-to-print lines.
    pub fix_list: Vec<String>,
    pub files_scanned: usize,
    /// Non-test unwrap/expect sites found across the tree.
    pub unwrap_total: usize,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }
}

/// Blank comments, strings and char literals with spaces, preserving
/// line structure exactly (newlines survive, masked columns align).
/// Lifetimes (`'a`) are recognized and kept; nested block comments and
/// `r#".."#` / `b".."` / `br#".."#` literals are handled.
pub fn mask_source(src: &str) -> String {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut out: Vec<char> = Vec::with_capacity(n);
    let mut i = 0usize;
    let blank = |c: char| if c == '\n' { '\n' } else { ' ' };
    while i < n {
        let c = b[i];
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            while i < n && b[i] != '\n' {
                out.push(' ');
                i += 1;
            }
            continue;
        }
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let mut depth = 1usize;
            out.push(' ');
            out.push(' ');
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                } else {
                    out.push(blank(b[i]));
                    i += 1;
                }
            }
            continue;
        }
        // raw / byte string prefixes: r"", r#""#, b"", br#""#
        if (c == 'r' || c == 'b') && !(i > 0 && (b[i - 1].is_alphanumeric() || b[i - 1] == '_')) {
            let mut j = i;
            if b[j] == 'b' {
                j += 1;
            }
            let raw = j < n && b[j] == 'r';
            if raw {
                j += 1;
            }
            let mut hashes = 0usize;
            while raw && j < n && b[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && b[j] == '"' && (raw || b[i] == 'b') {
                for _ in i..j {
                    out.push(' ');
                }
                i = j;
                if raw {
                    out.push('"');
                    i += 1;
                    'raw: while i < n {
                        if b[i] == '"' {
                            let mut k = 0usize;
                            while k < hashes && i + 1 + k < n && b[i + 1 + k] == '#' {
                                k += 1;
                            }
                            if k == hashes {
                                out.push('"');
                                for _ in 0..hashes {
                                    out.push(' ');
                                }
                                i += 1 + hashes;
                                break 'raw;
                            }
                        }
                        out.push(blank(b[i]));
                        i += 1;
                    }
                } else {
                    i = mask_cooked_string(&b, i, &mut out);
                }
                continue;
            }
        }
        if c == '"' {
            i = mask_cooked_string(&b, i, &mut out);
            continue;
        }
        if c == '\'' {
            // lifetime/label heuristic: 'ident not followed by a quote
            let is_lifetime = i + 1 < n
                && (b[i + 1].is_alphabetic() || b[i + 1] == '_')
                && !(i + 2 < n && b[i + 2] == '\'');
            if is_lifetime {
                out.push('\'');
                i += 1;
                continue;
            }
            out.push(' ');
            i += 1;
            if i < n && b[i] == '\\' {
                out.push(' ');
                i += 1;
                if i < n && b[i] == 'u' {
                    // \u{...}
                    while i < n && b[i] != '}' && b[i] != '\'' {
                        out.push(' ');
                        i += 1;
                    }
                    if i < n && b[i] == '}' {
                        out.push(' ');
                        i += 1;
                    }
                } else if i < n {
                    out.push(' ');
                    i += 1;
                }
            } else if i < n && b[i] != '\'' {
                out.push(' ');
                i += 1;
            }
            if i < n && b[i] == '\'' {
                out.push(' ');
                i += 1;
            }
            continue;
        }
        out.push(c);
        i += 1;
    }
    out.into_iter().collect()
}

/// Mask a cooked (escape-bearing) string starting at the opening quote;
/// returns the index just past the closing quote.
fn mask_cooked_string(b: &[char], mut i: usize, out: &mut Vec<char>) -> usize {
    let n = b.len();
    out.push('"');
    i += 1;
    while i < n {
        if b[i] == '\\' {
            out.push(' ');
            i += 1;
            if i < n {
                out.push(if b[i] == '\n' { '\n' } else { ' ' });
                i += 1;
            }
            continue;
        }
        if b[i] == '"' {
            out.push('"');
            i += 1;
            break;
        }
        out.push(if b[i] == '\n' { '\n' } else { ' ' });
        i += 1;
    }
    i
}

/// Per-line test-scope flags: a `#[cfg(test)]` attribute gates the next
/// item's whole brace span (module, fn, impl — whatever opens first).
pub fn test_scope(masked_lines: &[&str]) -> Vec<bool> {
    let mut flags = vec![false; masked_lines.len()];
    let mut depth: i64 = 0;
    let mut pending = false;
    for (idx, line) in masked_lines.iter().enumerate() {
        let opens = line.matches('{').count() as i64;
        let closes = line.matches('}').count() as i64;
        if depth > 0 {
            flags[idx] = true;
            depth += opens - closes;
            if depth < 0 {
                depth = 0;
            }
            continue;
        }
        if line.contains("#[cfg(test)]") {
            pending = true;
        }
        if pending {
            flags[idx] = true;
            if opens > 0 {
                depth = opens - closes;
                if depth < 0 {
                    depth = 0;
                }
                pending = false;
            }
        }
    }
    flags
}

/// Count non-overlapping occurrences of `pat` in `hay` that are not
/// preceded by an identifier character (so `expect_err(` never matches
/// a hunt for `expect(` — callers include the leading `.` anyway, this
/// guards macro names like `bail!`).
fn count_token(hay: &str, pat: &str) -> usize {
    let mut count = 0usize;
    let mut from = 0usize;
    while let Some(p) = hay[from..].find(pat) {
        let at = from + p;
        let boundary = at == 0
            || hay[..at]
                .chars()
                .next_back()
                .map(|c| !(c.is_alphanumeric() || c == '_'))
                .unwrap_or(true);
        if boundary {
            count += 1;
        }
        from = at + pat.len();
    }
    count
}

/// Lines (1-based) on which `pat` occurs with the boundary rule above.
fn token_lines(lines: &[&str], skip: &[bool], pat: &str) -> Vec<usize> {
    let mut out = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        if skip.get(idx).copied().unwrap_or(false) {
            continue;
        }
        for _ in 0..count_token(line, pat) {
            out.push(idx + 1);
        }
    }
    out
}

/// True when `line` contains the word `unsafe` outside identifiers.
fn has_unsafe_kw(line: &str) -> bool {
    let mut from = 0usize;
    while let Some(p) = line[from..].find("unsafe") {
        let at = from + p;
        let pre_ok = at == 0
            || line[..at]
                .chars()
                .next_back()
                .map(|c| !(c.is_alphanumeric() || c == '_'))
                .unwrap_or(true);
        let end = at + "unsafe".len();
        let post_ok = line[end..]
            .chars()
            .next()
            .map(|c| !(c.is_alphanumeric() || c == '_'))
            .unwrap_or(true);
        if pre_ok && post_ok {
            return true;
        }
        from = end;
    }
    false
}

/// The parsed `unwrap.allow` budget file.
#[derive(Clone, Debug, Default)]
pub struct UnwrapAllow {
    pub total_ceiling: usize,
    pub per_file: BTreeMap<String, usize>,
}

/// The parsed `atomics.allow` manifest: `(file, ordering) -> (count,
/// justification)`.
#[derive(Clone, Debug, Default)]
pub struct AtomicsAllow {
    pub entries: BTreeMap<(String, String), (usize, String)>,
}

/// Parse `unwrap.allow`. Unparseable lines become findings against the
/// allowlist file itself (a broken budget must not silently allow).
pub fn parse_unwrap_allow(text: &str, file: &str, findings: &mut Vec<Finding>) -> UnwrapAllow {
    let mut allow = UnwrapAllow::default();
    for (idx, raw) in text.lines().enumerate() {
        let code = raw.split('#').next().unwrap_or("").trim();
        if code.is_empty() {
            continue;
        }
        let Some((key, val)) = code.split_once('=') else {
            findings.push(Finding {
                rule: "allowlist-syntax",
                file: file.to_string(),
                line: idx + 1,
                msg: format!("expected '<path> = <count>', got '{code}'"),
            });
            continue;
        };
        let key = key.trim();
        let Ok(count) = val.trim().parse::<usize>() else {
            findings.push(Finding {
                rule: "allowlist-syntax",
                file: file.to_string(),
                line: idx + 1,
                msg: format!("count '{}' is not a number", val.trim()),
            });
            continue;
        };
        if key == "total_ceiling" {
            allow.total_ceiling = count;
        } else {
            allow.per_file.insert(key.to_string(), count);
        }
    }
    allow
}

/// Parse `atomics.allow`; a missing justification is itself a finding.
pub fn parse_atomics_allow(text: &str, file: &str, findings: &mut Vec<Finding>) -> AtomicsAllow {
    let mut allow = AtomicsAllow::default();
    for (idx, raw) in text.lines().enumerate() {
        let (code, note) = match raw.split_once('#') {
            Some((c, j)) => (c.trim(), j.trim()),
            None => (raw.trim(), ""),
        };
        if code.is_empty() {
            continue;
        }
        let parsed = code.split_once('=').and_then(|(key, val)| {
            let (path, ord) = key.trim().rsplit_once(':')?;
            let count = val.trim().parse::<usize>().ok()?;
            Some((path.trim().to_string(), ord.trim().to_string(), count))
        });
        let Some((path, ord, count)) = parsed else {
            findings.push(Finding {
                rule: "allowlist-syntax",
                file: file.to_string(),
                line: idx + 1,
                msg: format!("expected '<path>:<Relaxed|SeqCst> = <count>  # why', got '{code}'"),
            });
            continue;
        };
        if ord != "Relaxed" && ord != "SeqCst" {
            findings.push(Finding {
                rule: "allowlist-syntax",
                file: file.to_string(),
                line: idx + 1,
                msg: format!("ordering '{ord}' is not Relaxed or SeqCst"),
            });
            continue;
        }
        if note.is_empty() {
            findings.push(Finding {
                rule: "atomics-manifest",
                file: file.to_string(),
                line: idx + 1,
                msg: format!("entry '{path}:{ord}' has no justification comment"),
            });
        }
        allow.entries.insert((path, ord), (count, note.to_string()));
    }
    allow
}

/// What one scanned file contributed.
#[derive(Clone, Debug, Default)]
struct FileScan {
    unwrap_lines: Vec<usize>,
    relaxed_lines: Vec<usize>,
    seqcst_lines: Vec<usize>,
}

/// Scan one file's source, pushing immediate findings (safety-comment,
/// stringly-error, debug-macro) and returning the counted sites the
/// allowlist comparison needs.
fn scan_file(rel: &str, src: &str, findings: &mut Vec<Finding>) -> FileScan {
    let masked = mask_source(src);
    let masked_lines: Vec<&str> = masked.lines().collect();
    let raw_lines: Vec<&str> = src.lines().collect();
    let in_test_item = test_scope(&masked_lines);
    let tree_testish = is_testish_path(rel);
    // per-line "skip for budgeted rules": test item or test tree
    let skip: Vec<bool> = (0..masked_lines.len())
        .map(|i| tree_testish || in_test_item.get(i).copied().unwrap_or(false))
        .collect();
    let no_skip = vec![false; masked_lines.len()];

    let mut scan = FileScan::default();
    if !tree_testish {
        let mut lines = token_lines(&masked_lines, &skip, ".unwrap()");
        lines.extend(token_lines(&masked_lines, &skip, ".expect("));
        lines.sort_unstable();
        scan.unwrap_lines = lines;
        scan.relaxed_lines = token_lines(&masked_lines, &skip, "Ordering::Relaxed");
        scan.seqcst_lines = token_lines(&masked_lines, &skip, "Ordering::SeqCst");
    }

    // safety-comment: everywhere, tests included
    for (idx, line) in masked_lines.iter().enumerate() {
        if !has_unsafe_kw(line) {
            continue;
        }
        let lineno = idx + 1;
        if line.contains("unsafe fn") {
            if !doc_walk_has(&raw_lines, idx, "# Safety") {
                findings.push(Finding {
                    rule: "safety-comment",
                    file: rel.to_string(),
                    line: lineno,
                    msg: "unsafe fn without a '# Safety' doc section".to_string(),
                });
            }
        } else if !comment_walk_has(&raw_lines, idx, "SAFETY:") {
            findings.push(Finding {
                rule: "safety-comment",
                file: rel.to_string(),
                line: lineno,
                msg: "unsafe block without an adjacent '// SAFETY:' comment".to_string(),
            });
        }
    }

    // stringly-error: seams only, non-test scope
    if is_seam_path(rel) {
        for pat in ["Err(format!", "anyhow!(", "bail!("] {
            for lineno in token_lines(&masked_lines, &skip, pat) {
                findings.push(Finding {
                    rule: "stringly-error",
                    file: rel.to_string(),
                    line: lineno,
                    msg: format!("'{pat}' on a public seam — construct a SwisError instead"),
                });
            }
        }
    }

    // debug-macro: everywhere, tests included
    for pat in ["todo!", "unimplemented!", "dbg!"] {
        for lineno in token_lines(&masked_lines, &no_skip, pat) {
            findings.push(Finding {
                rule: "debug-macro",
                file: rel.to_string(),
                line: lineno,
                msg: format!("'{pat}' must not be committed"),
            });
        }
    }
    scan
}

/// Walk upward from `idx` through comment lines (raw view), looking for
/// `needle`. The line itself also counts (trailing `// SAFETY: ...`).
fn comment_walk_has(raw_lines: &[&str], idx: usize, needle: &str) -> bool {
    if raw_lines.get(idx).is_some_and(|l| l.contains(needle)) {
        return true;
    }
    let mut i = idx;
    while i > 0 {
        i -= 1;
        let t = raw_lines[i].trim_start();
        if t.starts_with("//") {
            if t.contains(needle) {
                return true;
            }
        } else {
            break;
        }
    }
    false
}

/// Walk upward through doc comments AND attributes (an `unsafe fn`
/// carries `#[target_feature]`/`#[allow]` lines between it and its doc).
fn doc_walk_has(raw_lines: &[&str], idx: usize, needle: &str) -> bool {
    let mut i = idx;
    while i > 0 {
        i -= 1;
        let t = raw_lines[i].trim_start();
        if t.starts_with("//") || t.starts_with("#[") || t.starts_with("#!") || t.ends_with(']') {
            if t.contains(needle) {
                return true;
            }
        } else {
            break;
        }
    }
    false
}

/// `tests/`, `benches/`, `examples/` trees are test scope wholesale.
fn is_testish_path(rel: &str) -> bool {
    rel.split('/').any(|c| c == "tests" || c == "benches" || c == "examples")
}

/// The public seams that must speak `SwisError`.
fn is_seam_path(rel: &str) -> bool {
    ["src/api", "src/coordinator", "src/edge", "src/obs"]
        .iter()
        .any(|p| rel.starts_with(p))
}

fn is_skipped_dir(name: &str) -> bool {
    name == "vendor" || name == "target" || name == "fixtures" || name.starts_with('.')
}

/// Collect every lintable `.rs` under `root`, as (relative path with
/// `/` separators, absolute path), sorted for deterministic output.
fn collect_rs(root: &Path) -> io::Result<Vec<(String, PathBuf)>> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let mut entries: Vec<_> = fs::read_dir(&dir)?.collect::<Result<_, _>>()?;
        entries.sort_by_key(|e| e.file_name());
        for e in entries {
            let path = e.path();
            let name = e.file_name().to_string_lossy().into_owned();
            if path.is_dir() {
                if !is_skipped_dir(&name) {
                    stack.push(path);
                }
            } else if name.ends_with(".rs") {
                let rel = path
                    .strip_prefix(root)
                    .unwrap_or(&path)
                    .components()
                    .map(|c| c.as_os_str().to_string_lossy().into_owned())
                    .collect::<Vec<_>>()
                    .join("/");
                out.push((rel, path));
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Run every rule over the crate at `rust_dir` (the directory holding
/// `src/` and `lint/`). Allowlists are read from `lint/unwrap.allow`
/// and `lint/atomics.allow`; a missing allowlist means a zero budget.
pub fn run(rust_dir: &Path) -> io::Result<Report> {
    let mut report = Report::default();
    let unwrap_allow = {
        let p = rust_dir.join("lint").join("unwrap.allow");
        let text = fs::read_to_string(&p).unwrap_or_default();
        parse_unwrap_allow(&text, "lint/unwrap.allow", &mut report.findings)
    };
    let atomics_allow = {
        let p = rust_dir.join("lint").join("atomics.allow");
        let text = fs::read_to_string(&p).unwrap_or_default();
        parse_atomics_allow(&text, "lint/atomics.allow", &mut report.findings)
    };

    let mut unwrap_counts: BTreeMap<String, Vec<usize>> = BTreeMap::new();
    let mut ordering_counts: BTreeMap<(String, String), Vec<usize>> = BTreeMap::new();
    for (rel, path) in collect_rs(rust_dir)? {
        let src = fs::read_to_string(&path)?;
        let scan = scan_file(&rel, &src, &mut report.findings);
        report.files_scanned += 1;
        if !scan.unwrap_lines.is_empty() {
            unwrap_counts.insert(rel.clone(), scan.unwrap_lines);
        }
        if !scan.relaxed_lines.is_empty() {
            ordering_counts
                .insert((rel.clone(), "Relaxed".to_string()), scan.relaxed_lines);
        }
        if !scan.seqcst_lines.is_empty() {
            ordering_counts.insert((rel.clone(), "SeqCst".to_string()), scan.seqcst_lines);
        }
    }

    // unwrap-burndown: per-file budgets, then the global ceiling
    let mut total = 0usize;
    for (rel, lines) in &unwrap_counts {
        total += lines.len();
        let budget = unwrap_allow.per_file.get(rel).copied().unwrap_or(0);
        if lines.len() > budget {
            report.findings.push(Finding {
                rule: "unwrap-burndown",
                file: rel.clone(),
                line: lines.get(budget).copied().unwrap_or(0),
                msg: format!(
                    "{} non-test unwrap/expect sites, budget is {budget} \
                     (lint/unwrap.allow) — convert to SwisError or raise the budget in review",
                    lines.len()
                ),
            });
        } else {
            for l in lines {
                report.fix_list.push(format!(
                    "{rel}:{l}: allowlisted unwrap/expect (file budget {budget})"
                ));
            }
            if lines.len() < budget {
                report.fix_list.push(format!(
                    "{rel}: budget {budget} but only {} sites remain — ratchet down",
                    lines.len()
                ));
            }
        }
    }
    for (rel, budget) in &unwrap_allow.per_file {
        if *budget > 0 && !unwrap_counts.contains_key(rel) {
            report
                .fix_list
                .push(format!("{rel}: budget {budget} but 0 sites remain — drop the entry"));
        }
    }
    report.unwrap_total = total;
    if total > unwrap_allow.total_ceiling {
        report.findings.push(Finding {
            rule: "unwrap-burndown",
            file: "lint/unwrap.allow".to_string(),
            line: 0,
            msg: format!(
                "{total} non-test unwrap/expect sites exceed total_ceiling {} — \
                 the ceiling only ratchets down",
                unwrap_allow.total_ceiling
            ),
        });
    }

    // atomics-manifest
    for ((rel, ord), lines) in &ordering_counts {
        match atomics_allow.entries.get(&(rel.clone(), ord.clone())) {
            Some((budget, _why)) if lines.len() <= *budget => {}
            Some((budget, _why)) => {
                report.findings.push(Finding {
                    rule: "atomics-manifest",
                    file: rel.clone(),
                    line: lines.get(*budget).copied().unwrap_or(0),
                    msg: format!(
                        "{} Ordering::{ord} sites, manifest allows {budget} \
                         (lint/atomics.allow) — justify the new site or fix its ordering",
                        lines.len()
                    ),
                });
            }
            None => {
                report.findings.push(Finding {
                    rule: "atomics-manifest",
                    file: rel.clone(),
                    line: lines.first().copied().unwrap_or(0),
                    msg: format!(
                        "Ordering::{ord} site not in lint/atomics.allow — add an entry \
                         with a one-line justification or use Acquire/Release"
                    ),
                });
            }
        }
    }
    for ((rel, ord), (budget, _)) in &atomics_allow.entries {
        if !ordering_counts.contains_key(&(rel.clone(), ord.clone())) {
            report
                .fix_list
                .push(format!("{rel}: manifest allows {budget} {ord} but 0 remain — drop it"));
        }
    }

    report.findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Ok(report)
}

/// Resolve the crate dir from a repo or crate root: accepts either the
/// repo root (containing `rust/src`) or the crate dir itself.
pub fn resolve_rust_dir(root: &Path) -> Option<PathBuf> {
    if root.join("src").is_dir() && root.join("lint").is_dir() {
        return Some(root.to_path_buf());
    }
    let nested = root.join("rust");
    if nested.join("src").is_dir() {
        return Some(nested);
    }
    if root.join("src").is_dir() {
        return Some(root.to_path_buf());
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masking_blanks_comments_strings_chars() {
        let src = "let a = \"x.unwrap()\"; // .unwrap()\nlet b = 'x';\nlet c: &'a str = r#\"dbg!\"#;\n/* todo! */ let d = 1;";
        let m = mask_source(src);
        assert!(!m.contains("unwrap"), "masked: {m}");
        assert!(!m.contains("dbg!"), "masked: {m}");
        assert!(!m.contains("todo!"), "masked: {m}");
        assert!(m.contains("let a"), "code survives: {m}");
        assert!(m.contains("&'a str"), "lifetimes survive: {m}");
        assert_eq!(m.lines().count(), src.lines().count(), "line structure preserved");
    }

    #[test]
    fn masking_handles_escapes_and_byte_strings() {
        let src = "let q = \"\\\".unwrap()\"; let b = b\"dbg!\"; let e = '\\'';\nlet x = 1;";
        let m = mask_source(src);
        assert!(!m.contains("unwrap"));
        assert!(!m.contains("dbg"));
        assert!(m.contains("let x = 1;"));
    }

    #[test]
    fn test_scope_tracks_cfg_test_braces() {
        let src = "fn a() { x.unwrap(); }\n#[cfg(test)]\nmod tests {\n    fn t() { y.unwrap(); }\n}\nfn b() {}\n";
        let masked = mask_source(src);
        let lines: Vec<&str> = masked.lines().collect();
        let flags = test_scope(&lines);
        assert!(!flags[0], "fn a is live code");
        assert!(flags[1] && flags[2] && flags[3] && flags[4], "test mod is scoped");
        assert!(!flags[5], "fn b after the mod is live again");
    }

    #[test]
    fn token_counting_respects_boundaries() {
        assert_eq!(count_token("x.unwrap().unwrap()", ".unwrap()"), 2);
        assert_eq!(count_token("x.expect_err(e)", ".expect("), 0);
        assert_eq!(count_token("anyhow::bail!(\"x\")", "bail!("), 1);
        assert_eq!(count_token("self.unwrap_or(1)", ".unwrap()"), 0);
        assert_eq!(count_token("std::cmp::Ordering::Equal", "Ordering::Relaxed"), 0);
    }

    #[test]
    fn unsafe_keyword_is_word_matched() {
        assert!(has_unsafe_kw("let x = unsafe { y };"));
        assert!(has_unsafe_kw("pub(super) unsafe fn f()"));
        assert!(!has_unsafe_kw("#![forbid(unsafe_code)]"));
        assert!(!has_unsafe_kw("let unsafety = 1;"));
    }

    #[test]
    fn allowlist_parsers_round_trip_and_flag_syntax() {
        let mut f = Vec::new();
        let ua = parse_unwrap_allow(
            "# hdr\ntotal_ceiling = 10\nsrc/a.rs = 3  # note\nbroken line\n",
            "lint/unwrap.allow",
            &mut f,
        );
        assert_eq!(ua.total_ceiling, 10);
        assert_eq!(ua.per_file.get("src/a.rs"), Some(&3));
        assert_eq!(f.len(), 1, "the broken line is a finding: {f:?}");

        let mut f = Vec::new();
        let aa = parse_atomics_allow(
            "src/t.rs:Relaxed = 2  # ids only\nsrc/u.rs:SeqCst = 1\n",
            "lint/atomics.allow",
            &mut f,
        );
        assert_eq!(aa.entries.get(&("src/t.rs".into(), "Relaxed".into())).map(|e| e.0), Some(2));
        assert_eq!(f.len(), 1, "missing justification is a finding: {f:?}");
    }

    #[test]
    fn scan_flags_each_rule_on_bad_source() {
        let bad = "fn f() {\n    let v = x.unwrap();\n    unsafe { *p = 1; }\n    todo!()\n}\n";
        let mut findings = Vec::new();
        let scan = scan_file("src/api/bad.rs", bad, &mut findings);
        assert_eq!(scan.unwrap_lines, vec![2]);
        assert!(findings.iter().any(|f| f.rule == "safety-comment" && f.line == 3));
        assert!(findings.iter().any(|f| f.rule == "debug-macro" && f.line == 4));
    }

    #[test]
    fn scan_is_silent_on_clean_source() {
        let clean = "fn f() -> Result<(), E> {\n    // SAFETY: p is valid for writes, checked above.\n    unsafe { *p = 1; }\n    let v = x.unwrap_or_default();\n    Ok(())\n}\n";
        let mut findings = Vec::new();
        let scan = scan_file("src/api/clean.rs", clean, &mut findings);
        assert!(scan.unwrap_lines.is_empty());
        assert!(findings.is_empty(), "findings: {findings:?}");
    }

    #[test]
    fn seam_rule_only_fires_on_seams() {
        let s = "fn f() { return Err(format!(\"x\")); }\n";
        let mut on_seam = Vec::new();
        scan_file("src/edge/x.rs", s, &mut on_seam);
        assert!(on_seam.iter().any(|f| f.rule == "stringly-error"));
        let mut off_seam = Vec::new();
        scan_file("src/quant/x.rs", s, &mut off_seam);
        assert!(!off_seam.iter().any(|f| f.rule == "stringly-error"));
    }
}
