//! Fixture-driven acceptance tests: the bad tree must trip every rule
//! with the right file:line anchors, the clean tree must be silent.

use std::path::PathBuf;

fn fixture(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("fixtures").join(name)
}

fn run_fixture(name: &str) -> swis_lint::Report {
    let root = fixture(name);
    let dir = swis_lint::resolve_rust_dir(&root).expect("fixture has a src/ tree");
    swis_lint::run(&dir).expect("fixture scan")
}

#[test]
fn bad_fixture_trips_every_rule() {
    let report = run_fixture("bad");
    let rules: Vec<&str> = report.findings.iter().map(|f| f.rule).collect();
    for expected in [
        "unwrap-burndown",
        "safety-comment",
        "atomics-manifest",
        "stringly-error",
        "debug-macro",
    ] {
        assert!(rules.contains(&expected), "missing {expected}; got {rules:?}");
    }
    // the two non-test unwrap/expect sites are counted, the test one is not
    assert_eq!(report.unwrap_total, 2, "findings: {:?}", report.findings);
    // unsafe block (no SAFETY) and unsafe fn (no # Safety) both flagged
    let safety = report.findings.iter().filter(|f| f.rule == "safety-comment").count();
    assert_eq!(safety, 2, "findings: {:?}", report.findings);
    // both unreviewed orderings flagged (Relaxed and SeqCst)
    let atomics = report
        .findings
        .iter()
        .filter(|f| f.rule == "atomics-manifest" && f.file.contains("offender"))
        .count();
    assert_eq!(atomics, 2, "findings: {:?}", report.findings);
    // dbg! and todo! each produce a diagnostic
    let debug = report.findings.iter().filter(|f| f.rule == "debug-macro").count();
    assert_eq!(debug, 2, "findings: {:?}", report.findings);
    // diagnostics carry real line anchors
    assert!(
        report.findings.iter().all(|f| f.line > 0 || f.file.ends_with(".allow")),
        "findings: {:?}",
        report.findings
    );
}

#[test]
fn clean_fixture_is_silent() {
    let report = run_fixture("clean");
    assert!(report.findings.is_empty(), "findings: {:?}", report.findings);
    assert_eq!(report.unwrap_total, 0);
    assert!(report.files_scanned >= 1);
}

#[test]
fn diagnostics_render_as_file_line_rule() {
    let report = run_fixture("bad");
    let first = report.findings.iter().find(|f| f.line > 0).expect("anchored finding");
    let rendered = first.to_string();
    assert!(
        rendered.contains(&format!(":{}: [", first.line)),
        "rendered: {rendered}"
    );
}

#[test]
fn real_repo_stays_clean_under_its_allowlists() {
    // CARGO_MANIFEST_DIR is rust/lint — the crate root is one up.
    let crate_root = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("lint crate lives inside rust/")
        .to_path_buf();
    let dir = swis_lint::resolve_rust_dir(&crate_root).expect("rust/ crate");
    let report = swis_lint::run(&dir).expect("repo scan");
    assert!(
        report.findings.is_empty(),
        "the repo must lint clean; findings:\n{}",
        report
            .findings
            .iter()
            .map(|f| f.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
}
