//! im2col lowering for the native conv path: NHWC feature maps to
//! `(batch * out_hw^2, k*k*in_c)` patch matrices whose fan-in ordering
//! `(kh, kw, cin)` matches the filters-first weight matrices the
//! quantizer consumes (HWIO weights transposed to `[O, HWI]`).
//!
//! Padding follows XLA's SAME convention — `out = ceil(in / stride)`,
//! `pad_total = max((out-1)*stride + k - in, 0)`, split low = total/2,
//! high = rest — so the native engine computes the same geometry the
//! AOT-lowered PJRT graph does (for stride 2 on even maps the padding is
//! asymmetric: 0 on top/left, 1 on bottom/right).

use anyhow::{bail, Result};

/// Geometry of one SAME-padded square convolution.
#[derive(Clone, Copy, Debug)]
pub struct ConvGeom {
    pub in_hw: usize,
    pub in_c: usize,
    pub k: usize,
    pub stride: usize,
    pub out_hw: usize,
    /// Low-side (top/left) padding; the high side is implied by `out_hw`.
    pub pad_lo: usize,
}

impl ConvGeom {
    /// XLA SAME geometry for a square map / kernel / stride.
    pub fn same(in_hw: usize, in_c: usize, k: usize, stride: usize) -> Result<ConvGeom> {
        if in_hw == 0 || in_c == 0 || k == 0 || stride == 0 {
            bail!("degenerate conv geometry");
        }
        let out_hw = in_hw.div_ceil(stride);
        let pad_total = ((out_hw - 1) * stride + k).saturating_sub(in_hw);
        Ok(ConvGeom { in_hw, in_c, k, stride, out_hw, pad_lo: pad_total / 2 })
    }

    /// Geometry for a zoo layer, cross-checked against the shape table:
    /// XLA-SAME must reproduce the table's own `out_hw()` (it does for
    /// every layer in the zoo) or the descriptor is rejected.
    ///
    /// The check covers OUTPUT GEOMETRY only — padding *alignment* is
    /// always XLA-SAME, the convention of this repo's own jax/AOT weight
    /// pipeline (`<net>_weights.npz` artifacts are trained under it).
    /// On stride-2 layers XLA-SAME pads asymmetrically (low 0/2, high
    /// 1/3) where the tables' torch-style `pad` field is symmetric;
    /// weights trained under torch padding would see a one-pixel-shifted
    /// window here, so do not feed torchvision checkpoints through the
    /// npz path without re-exporting them through the repo pipeline.
    pub fn for_layer(l: &crate::nets::ConvLayer) -> Result<ConvGeom> {
        let g = ConvGeom::same(l.in_hw, l.in_c, l.k, l.stride)?;
        if g.out_hw != l.out_hw() {
            bail!(
                "layer '{}': XLA-SAME yields {}x{} but the table (pad {}) says {}x{}",
                l.name,
                g.out_hw,
                g.out_hw,
                l.pad,
                l.out_hw(),
                l.out_hw()
            );
        }
        Ok(g)
    }

    pub fn fan_in(&self) -> usize {
        self.k * self.k * self.in_c
    }

    pub fn rows(&self, batch: usize) -> usize {
        batch * self.out_hw * self.out_hw
    }
}

/// Lower an NHWC batch `(batch, in_hw, in_hw, in_c)` into the patch
/// matrix. Out-of-map taps read as zero. Row order is `(b, oh, ow)`
/// row-major, so the GEMM result `(rows, out_c)` IS the next layer's
/// NHWC map.
pub fn im2col(x: &[f32], batch: usize, g: &ConvGeom) -> Result<Vec<f32>> {
    let hw = g.in_hw;
    let c = g.in_c;
    if x.len() != batch * hw * hw * c {
        bail!("input {} != {batch} x {hw} x {hw} x {c}", x.len());
    }
    let fan_in = g.fan_in();
    let o = g.out_hw;
    let mut cols = vec![0f32; batch * o * o * fan_in];
    for b in 0..batch {
        let img = &x[b * hw * hw * c..(b + 1) * hw * hw * c];
        for oh in 0..o {
            for ow in 0..o {
                let dst0 = ((b * o + oh) * o + ow) * fan_in;
                for kh in 0..g.k {
                    let ih = (oh * g.stride + kh) as isize - g.pad_lo as isize;
                    if ih < 0 || ih >= hw as isize {
                        continue; // whole kernel row out of map: stays zero
                    }
                    for kw in 0..g.k {
                        let iw = (ow * g.stride + kw) as isize - g.pad_lo as isize;
                        if iw < 0 || iw >= hw as isize {
                            continue;
                        }
                        let src = (ih as usize * hw + iw as usize) * c;
                        let dst = dst0 + (kh * g.k + kw) * c;
                        cols[dst..dst + c].copy_from_slice(&img[src..src + c]);
                    }
                }
            }
        }
    }
    Ok(cols)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_geometry_matches_xla() {
        // stride 1, 3x3: symmetric pad 1, out = in
        let g1 = ConvGeom::same(32, 3, 3, 1).unwrap();
        assert_eq!((g1.out_hw, g1.pad_lo), (32, 1));
        // stride 2 on an even map: pad_total 1 -> low 0, high 1
        let g2 = ConvGeom::same(32, 32, 3, 2).unwrap();
        assert_eq!((g2.out_hw, g2.pad_lo), (16, 0));
        assert_eq!(g2.fan_in(), 9 * 32);
    }

    #[test]
    fn identity_kernel_recovers_map() {
        // 1x1 kernel, stride 1: cols == input
        let g = ConvGeom::same(4, 2, 1, 1).unwrap();
        let x: Vec<f32> = (0..4 * 4 * 2).map(|v| v as f32).collect();
        let cols = im2col(&x, 1, &g).unwrap();
        assert_eq!(cols, x);
    }

    #[test]
    fn stride1_3x3_center_and_corner_taps() {
        // 3x3 map, single channel, values 0..9
        let g = ConvGeom::same(3, 1, 3, 1).unwrap();
        let x: Vec<f32> = (0..9).map(|v| v as f32).collect();
        let cols = im2col(&x, 1, &g).unwrap();
        assert_eq!(cols.len(), 9 * 9);
        // center output pixel (1,1) sees the whole map in order
        let center = &cols[(1 * 3 + 1) * 9..(1 * 3 + 1) * 9 + 9];
        assert_eq!(center, &x[..]);
        // corner (0,0): top-left taps are zero padding
        let corner = &cols[..9];
        assert_eq!(corner, &[0., 0., 0., 0., 0., 1., 0., 3., 4.]);
    }

    #[test]
    fn stride2_uses_low_zero_padding() {
        // 4x4 map, k=3, s=2 -> out 2, pad_lo 0: output (0,0) taps (0..3)^2
        let g = ConvGeom::same(4, 1, 3, 2).unwrap();
        assert_eq!(g.pad_lo, 0);
        let x: Vec<f32> = (0..16).map(|v| v as f32).collect();
        let cols = im2col(&x, 1, &g).unwrap();
        assert_eq!(&cols[..9], &[0., 1., 2., 4., 5., 6., 8., 9., 10.]);
        // output (1,1) starts at (2,2) and runs off the map: high padding
        let last = &cols[3 * 9..4 * 9];
        assert_eq!(last, &[10., 11., 0., 14., 15., 0., 0., 0., 0.]);
    }

    #[test]
    fn batch_rows_are_contiguous() {
        let g = ConvGeom::same(2, 1, 1, 1).unwrap();
        let x: Vec<f32> = (0..8).map(|v| v as f32).collect(); // batch 2
        let cols = im2col(&x, 2, &g).unwrap();
        assert_eq!(cols, x);
        assert_eq!(g.rows(2), 8);
    }
}
