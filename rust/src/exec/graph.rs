//! The op-graph IR the native executor runs: a small, validated graph of
//! conv / depthwise-conv / FC / pool / residual-add nodes lowered from
//! any [`crate::nets::Network`] shape table. This is what generalizes
//! [`super::model::NativeModel`] beyond the hardcoded TinyCNN forward —
//! MobileNet-v2's inverted-residual bottlenecks, ResNet-18's basic
//! blocks with downsample projections, and the sequential VGG/TinyCNN
//! stacks all lower to the same six ops.
//!
//! Lowering is structural: the layer tables carry geometry only, so
//! topology is recovered from the zoo's documented naming conventions
//! plus shape continuity —
//!
//! * `layer{s}.{b}.conv1/conv2` (+ optional `.downsample`) is a ResNet
//!   basic block: `relu(conv2(relu(conv1(x))) + skip(x))` with `skip`
//!   the 1x1/2 projection when present, identity otherwise. The stem
//!   conv is followed by the standard 3x3/2 max-pool.
//! * `block{b}.expand/dw/project` is a MobileNet-v2 inverted residual:
//!   expand (ReLU) -> depthwise (ReLU) -> project (LINEAR — the paper's
//!   linear bottleneck), with an identity residual add (no activation)
//!   whenever the block preserves shape (stride 1, `cin == cout`).
//! * Anything else lowers sequentially; a drop in the next layer's
//!   `in_hw` becomes a max-pool of that ratio (VGG's stage pools). An
//!   FC head (`in_hw == 1, k == 1`) on a still-spatial map is preceded
//!   by the net's final stage max-pool when stage pools were inferred
//!   (VGG's implicit pool5), by a global average pool otherwise
//!   (TinyCNN — identical to the pre-graph executor).
//!
//! Every conv node's geometry is XLA-SAME ([`ConvGeom::for_layer`])
//! cross-checked against the table's own `out_hw()`, and every edge is
//! shape-checked at lowering time — a malformed descriptor fails here,
//! not mid-forward.

use anyhow::{bail, Context, Result};

use super::im2col::ConvGeom;
use crate::nets::{ConvKind, ConvLayer, Network};

/// Where a node reads its input from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Src {
    /// The graph input (the NHWC image batch).
    Input,
    /// The output of an earlier node.
    Node(usize),
}

/// Shape of a value flowing through the graph: a square NHWC map.
/// `hw == 1` doubles as the flat `(batch, c)` vectors of the FC head —
/// the row-major layouts coincide.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ValShape {
    pub hw: usize,
    pub c: usize,
}

/// One executable operation. Weighted ops carry the index of their layer
/// in the source [`Network::layers`] table; the model binds weights to
/// them by the layer's name.
#[derive(Clone, Debug)]
pub enum GraphOp {
    /// Standard convolution: im2col + (packed | dense) GEMM.
    Conv { layer: usize, geom: ConvGeom, relu: bool },
    /// Depthwise convolution: per-channel packed bit-serial dot.
    Depthwise { layer: usize, geom: ConvGeom, relu: bool },
    /// Fully-connected head over a flat `(batch, c)` vector.
    Fc { layer: usize, relu: bool },
    MaxPool { k: usize, stride: usize },
    GlobalAvgPool,
    /// Elementwise residual add of this node's `src` and `rhs`.
    Add { rhs: Src, relu: bool },
}

#[derive(Clone, Debug)]
pub struct GraphNode {
    pub op: GraphOp,
    pub src: Src,
    /// Output shape (computed and validated at lowering time).
    pub shape: ValShape,
}

/// A lowered, shape-checked executable graph.
#[derive(Clone, Debug)]
pub struct Graph {
    /// Source network name (for labels/diagnostics).
    pub net: String,
    pub nodes: Vec<GraphNode>,
    /// Expected input map shape.
    pub input: ValShape,
}

impl Graph {
    /// Shape of the graph output (the last node's output).
    pub fn output(&self) -> ValShape {
        self.nodes.last().map_or(self.input, |n| n.shape)
    }

    /// Human label for node `i`: the layer name for weighted ops, a
    /// synthesized `op@i` tag otherwise (used by eval traces).
    pub fn label(&self, net: &Network, i: usize) -> String {
        match &self.nodes[i].op {
            GraphOp::Conv { layer, .. }
            | GraphOp::Depthwise { layer, .. }
            | GraphOp::Fc { layer, .. } => net.layers[*layer].name.clone(),
            GraphOp::MaxPool { .. } => format!("maxpool@{i}"),
            GraphOp::GlobalAvgPool => format!("gap@{i}"),
            GraphOp::Add { .. } => format!("add@{i}"),
        }
    }
}

/// Incremental graph builder tracking the "current" value + shape.
struct Builder<'n> {
    net: &'n Network,
    nodes: Vec<GraphNode>,
    input: ValShape,
    cur: Src,
    shape: ValShape,
    /// Inter-stage max-pools inferred so far (`pool_to`): a net that
    /// pools between stages (VGG) also ends its trunk with a stage pool
    /// rather than GAP.
    stage_pools: usize,
}

impl<'n> Builder<'n> {
    fn new(net: &'n Network) -> Result<Builder<'n>> {
        let first = net
            .layers
            .first()
            .with_context(|| format!("network '{}' has no layers", net.name))?;
        let input = ValShape { hw: first.in_hw, c: first.in_c };
        Ok(Builder { net, nodes: Vec::new(), input, cur: Src::Input, shape: input, stage_pools: 0 })
    }

    /// Push a node reading the current value; it becomes current.
    fn push(&mut self, op: GraphOp, shape: ValShape) -> Src {
        self.nodes.push(GraphNode { op, src: self.cur, shape });
        self.cur = Src::Node(self.nodes.len() - 1);
        self.shape = shape;
        self.cur
    }

    /// Lower conv layer `li` (standard or depthwise) with `relu`.
    fn conv(&mut self, li: usize, relu: bool) -> Result<()> {
        let l = &self.net.layers[li];
        if (self.shape.hw, self.shape.c) != (l.in_hw, l.in_c) {
            bail!(
                "layer '{}' expects a {}x{}x{} map but the graph carries {}x{}x{}",
                l.name,
                l.in_hw,
                l.in_hw,
                l.in_c,
                self.shape.hw,
                self.shape.hw,
                self.shape.c
            );
        }
        let geom = ConvGeom::for_layer(l)?;
        let out = ValShape { hw: geom.out_hw, c: l.out_c };
        let op = match l.kind {
            ConvKind::Standard => GraphOp::Conv { layer: li, geom, relu },
            ConvKind::Depthwise => GraphOp::Depthwise { layer: li, geom, relu },
        };
        self.push(op, out);
        Ok(())
    }

    /// Lower FC layer `li`, inserting a global average pool first when the
    /// map is still spatial (the zoo's conv trunks all end in GAP).
    fn fc(&mut self, li: usize, relu: bool) -> Result<()> {
        let l = &self.net.layers[li];
        if self.shape.hw > 1 {
            self.global_pool();
        }
        if self.shape.c != l.in_c {
            bail!(
                "FC '{}' expects {} inputs but the pooled map has {} channels",
                l.name,
                l.in_c,
                self.shape.c
            );
        }
        self.push(GraphOp::Fc { layer: li, relu }, ValShape { hw: 1, c: l.out_c });
        Ok(())
    }

    fn max_pool(&mut self, k: usize, stride: usize) -> Result<()> {
        if self.shape.hw < 2 {
            bail!("max-pool on a {}x{} map in '{}'", self.shape.hw, self.shape.hw, self.net.name);
        }
        let g = ConvGeom::same(self.shape.hw, self.shape.c, k, stride)?;
        self.push(
            GraphOp::MaxPool { k, stride },
            ValShape { hw: g.out_hw, c: self.shape.c },
        );
        Ok(())
    }

    fn global_pool(&mut self) {
        self.push(GraphOp::GlobalAvgPool, ValShape { hw: 1, c: self.shape.c });
    }

    /// Residual add of the current value and `rhs` — the shapes must
    /// match exactly (this is the lowering-time residual shape check).
    fn add(&mut self, rhs: Src, rhs_shape: ValShape, relu: bool) -> Result<()> {
        if rhs_shape != self.shape {
            bail!(
                "residual add in '{}' joins {}x{}x{} with {}x{}x{}",
                self.net.name,
                self.shape.hw,
                self.shape.hw,
                self.shape.c,
                rhs_shape.hw,
                rhs_shape.hw,
                rhs_shape.c
            );
        }
        self.push(GraphOp::Add { rhs, relu }, self.shape);
        Ok(())
    }

    /// If the next conv layer's `in_hw` is below the current map, insert
    /// the implied inter-stage max-pool (VGG convention: k == stride ==
    /// the reduction ratio).
    fn pool_to(&mut self, want_hw: usize) -> Result<()> {
        if want_hw == self.shape.hw {
            return Ok(());
        }
        if want_hw == 0 || self.shape.hw % want_hw != 0 || want_hw > self.shape.hw {
            bail!(
                "cannot pool a {0}x{0} map down to {1}x{1} in '{2}'",
                self.shape.hw,
                want_hw,
                self.net.name
            );
        }
        let ratio = self.shape.hw / want_hw;
        self.max_pool(ratio, ratio)?;
        self.stage_pools += 1;
        if self.shape.hw != want_hw {
            bail!("stage pool produced {}x{}, wanted {want_hw}", self.shape.hw, self.shape.hw);
        }
        Ok(())
    }

    fn finish(self) -> Graph {
        Graph { net: self.net.name.clone(), nodes: self.nodes, input: self.input }
    }
}

/// True for the zoo's FC-head rows ([`ConvLayer::fc`]).
fn is_fc(l: &ConvLayer) -> bool {
    l.k == 1 && l.in_hw == 1 && l.stride == 1 && l.kind == ConvKind::Standard
}

/// Lower a network descriptor into an executable graph. Handles the
/// whole zoo: ResNet-18 (basic blocks + downsample skips), MobileNet-v2
/// (inverted residual bottlenecks, linear projections), and sequential
/// stacks (TinyCNN, VGG-16 with inferred stage pools). FC heads (from
/// [`Network::with_fc`]) lower behind a global average pool; every conv
/// geometry and residual edge is shape-checked here.
pub fn lower(net: &Network) -> Result<Graph> {
    let resnet_like = net
        .layers
        .iter()
        .any(|l| l.name.starts_with("layer") && l.name.contains(".conv"));
    let bottleneck = net.layers.iter().any(|l| l.kind == ConvKind::Depthwise);
    if resnet_like {
        lower_resnet(net)
    } else if bottleneck {
        lower_bottleneck(net)
    } else {
        lower_sequential(net)
    }
    .with_context(|| format!("lowering '{}'", net.name))
}

/// Sequential stacks: convs in table order, inter-stage max-pools
/// inferred from `in_hw` drops, then the head. A net that pools between
/// stages (VGG) also ends its trunk with one more stage pool — the
/// table's implicit pool5, whose output IS the flattened FC input — so
/// the collapse to the FC vector is a max-pool there and GAP elsewhere
/// (TinyCNN, matching the pre-graph executor bit-for-bit).
fn lower_sequential(net: &Network) -> Result<Graph> {
    let mut b = Builder::new(net)?;
    let n = net.layers.len();
    for (li, l) in net.layers.iter().enumerate() {
        if is_fc(l) {
            if b.stage_pools > 0 && b.shape.hw > 1 && b.shape.c == l.in_c {
                b.max_pool(b.shape.hw, b.shape.hw)?; // final stage pool -> 1x1
            }
            b.fc(li, li + 1 < n)?; // last FC emits raw logits
        } else {
            b.pool_to(l.in_hw)?;
            b.conv(li, true)?;
        }
    }
    Ok(b.finish())
}

/// ResNet basic blocks. Layer roles come from the torchvision naming the
/// table uses: `conv1` stem, `layer{s}.{b}.conv1/conv2[/downsample]`
/// blocks, then the FC head. The stem is followed by the standard 3x3/2
/// max-pool (the one pool the table leaves implicit).
fn lower_resnet(net: &Network) -> Result<Graph> {
    let mut b = Builder::new(net)?;
    let n = net.layers.len();
    let find = |name: &str| net.layers.iter().position(|l| l.name == name);
    let mut done = vec![false; n];

    for (li, l) in net.layers.iter().enumerate() {
        if done[li] {
            continue;
        }
        if is_fc(l) {
            b.fc(li, li + 1 < n)?;
            done[li] = true;
        } else if let Some(prefix) = l.name.strip_suffix(".conv1") {
            let c2 = find(&format!("{prefix}.conv2"))
                .with_context(|| format!("block '{prefix}' has conv1 but no conv2"))?;
            let ds = find(&format!("{prefix}.downsample"));
            let (saved, saved_shape) = (b.cur, b.shape);
            b.conv(li, true)?;
            b.conv(c2, false)?; // ReLU applies after the add
            let (main, main_shape) = (b.cur, b.shape);
            let (skip, skip_shape) = match ds {
                Some(d) => {
                    b.cur = saved;
                    b.shape = saved_shape;
                    b.conv(d, false)?;
                    (b.cur, b.shape)
                }
                None => (saved, saved_shape),
            };
            b.cur = main;
            b.shape = main_shape;
            b.add(skip, skip_shape, true)?;
            done[li] = true;
            done[c2] = true;
            if let Some(d) = ds {
                done[d] = true;
            }
        } else if l.name.contains(".conv2") || l.name.contains(".downsample") {
            bail!("block layer '{}' appears before its conv1", l.name);
        } else {
            // the stem; the implicit 3x3/2 max-pool follows when the next
            // block expects a halved map
            b.conv(li, true)?;
            done[li] = true;
            if let Some(next) = net.layers.iter().find(|x| !is_fc(x) && x.name != l.name) {
                if next.in_hw * 2 == b.shape.hw {
                    b.max_pool(3, 2)?;
                }
            }
        }
    }
    Ok(b.finish())
}

/// MobileNet-v2-style stacks: consecutive layers sharing a `block{b}.`
/// prefix form an inverted-residual bottleneck (expand? -> depthwise ->
/// project); the projection is linear, and an identity residual joins
/// input to output whenever the block preserves shape. Standalone convs
/// (stem/head) are plain ReLU convs; FC heads lower behind GAP.
fn lower_bottleneck(net: &Network) -> Result<Graph> {
    let mut b = Builder::new(net)?;
    let n = net.layers.len();
    let prefix_of = |l: &ConvLayer| l.name.split_once('.').map(|(p, _)| p.to_string());
    let mut li = 0usize;
    while li < n {
        let l = &net.layers[li];
        if is_fc(l) {
            b.fc(li, li + 1 < n)?;
            li += 1;
        } else if let Some(prefix) = prefix_of(l) {
            // collect the whole block: consecutive layers with this prefix
            let mut end = li;
            while end < n && prefix_of(&net.layers[end]).as_deref() == Some(prefix.as_str()) {
                end += 1;
            }
            let (saved, saved_shape) = (b.cur, b.shape);
            let mut saw_dw = false;
            for bi in li..end {
                let bl = &net.layers[bi];
                if bl.kind == ConvKind::Depthwise {
                    saw_dw = true;
                    b.conv(bi, true)?;
                } else {
                    // convs after the depthwise are linear projections;
                    // the expand conv before it is ReLU
                    b.conv(bi, !saw_dw)?;
                }
            }
            if !saw_dw {
                bail!("bottleneck '{prefix}' has no depthwise layer");
            }
            if b.shape == saved_shape {
                b.add(saved, saved_shape, false)?; // linear residual
            }
            li = end;
        } else {
            b.conv(li, true)?;
            li += 1;
        }
    }
    Ok(b.finish())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::{all_networks, by_name, mobilenet_v2, resnet18, tinycnn, vgg16_cifar100};

    fn count<F: Fn(&GraphOp) -> bool>(g: &Graph, f: F) -> usize {
        g.nodes.iter().filter(|n| f(&n.op)).count()
    }

    #[test]
    fn zoo_lowering_node_census() {
        // residual-add and pool counts pin the recovered topologies
        let r = lower(&resnet18().with_fc()).unwrap();
        assert_eq!(count(&r, |o| matches!(o, GraphOp::Add { .. })), 8, "resnet blocks");
        assert_eq!(count(&r, |o| matches!(o, GraphOp::MaxPool { .. })), 1, "resnet stem pool");
        assert_eq!(count(&r, |o| matches!(o, GraphOp::Fc { .. })), 1);
        assert_eq!(r.output(), ValShape { hw: 1, c: 1000 });

        let m = lower(&mobilenet_v2().with_fc()).unwrap();
        // 17 bottlenecks, residual when a repeat preserves shape:
        // 0+1+2+3+2+2+0 = 10
        assert_eq!(count(&m, |o| matches!(o, GraphOp::Add { .. })), 10, "mbv2 residuals");
        assert_eq!(count(&m, |o| matches!(o, GraphOp::Depthwise { .. })), 17);
        assert_eq!(m.output(), ValShape { hw: 1, c: 1000 });

        // 4 inter-stage pools + the implicit pool5 collapsing 2x2 -> fc
        // input (real VGG flattens after pool5; no GAP anywhere)
        let v = lower(&vgg16_cifar100().with_fc()).unwrap();
        assert_eq!(count(&v, |o| matches!(o, GraphOp::MaxPool { .. })), 5, "vgg stage pools");
        assert_eq!(count(&v, |o| matches!(o, GraphOp::GlobalAvgPool)), 0);
        assert_eq!(v.output(), ValShape { hw: 1, c: 100 });

        let t = lower(&tinycnn().with_fc()).unwrap();
        assert_eq!(count(&t, |o| matches!(o, GraphOp::MaxPool { .. })), 0);
        assert_eq!(count(&t, |o| matches!(o, GraphOp::Conv { .. })), 6);
        assert_eq!(t.output(), ValShape { hw: 1, c: 10 });
    }

    #[test]
    fn conv_geometry_matches_shape_tables() {
        // every lowered conv/depthwise node's XLA-SAME geometry must agree
        // with the table's own out_hw() — incl. all stride-2 layers
        for net in all_networks() {
            let net = net.with_fc();
            let g = lower(&net).unwrap();
            for node in &g.nodes {
                if let GraphOp::Conv { layer, geom, .. } | GraphOp::Depthwise { layer, geom, .. } =
                    &node.op
                {
                    let l = &net.layers[*layer];
                    assert_eq!(geom.out_hw, l.out_hw(), "{}: {}", net.name, l.name);
                    assert_eq!(node.shape, ValShape { hw: l.out_hw(), c: l.out_c });
                }
            }
        }
    }

    #[test]
    fn residual_adds_are_shape_checked() {
        // a resnet-named table whose downsample emits the wrong channel
        // count must fail at lowering, not mid-forward
        let mut net = Network {
            name: "resnet_bad".into(),
            layers: vec![
                ConvLayer::new("conv1", 8, 3, 3, 1, 1, 4),
                ConvLayer::new("layer1.0.conv1", 8, 4, 3, 2, 1, 8),
                ConvLayer::new("layer1.0.conv2", 4, 8, 3, 1, 1, 8),
                ConvLayer::new("layer1.0.downsample", 8, 4, 1, 2, 0, 6), // 6 != 8
            ],
        };
        let e = lower(&net).unwrap_err();
        assert!(format!("{e:#}").contains("residual add"), "{e:#}");
        net.layers[3].out_c = 8;
        lower(&net).unwrap();
    }

    #[test]
    fn shape_continuity_is_checked() {
        let net = Network {
            name: "broken".into(),
            layers: vec![
                ConvLayer::new("a", 8, 3, 3, 1, 1, 4),
                ConvLayer::new("b", 8, 5, 3, 1, 1, 4), // in_c 5 != 4
            ],
        };
        assert!(lower(&net).is_err());
    }

    #[test]
    fn fc_head_requires_matching_width() {
        let net = Network {
            name: "badfc".into(),
            layers: vec![
                ConvLayer::new("a", 8, 3, 3, 1, 1, 4),
                ConvLayer::fc("fc", 5, 10), // 5 != 4 channels after GAP
            ],
        };
        assert!(lower(&net).is_err());
        assert!(by_name("tinycnn").is_some()); // zoo untouched
    }

    #[test]
    fn labels_name_weighted_nodes() {
        let net = tinycnn().with_fc();
        let g = lower(&net).unwrap();
        assert_eq!(g.label(&net, 0), "conv1");
        let gap = g
            .nodes
            .iter()
            .position(|n| matches!(n.op, GraphOp::GlobalAvgPool))
            .unwrap();
        assert!(g.label(&net, gap).starts_with("gap@"));
    }
}
