//! Native SWIS execution engine — the third execution tier.
//!
//! The repo executes packed SWIS operands at three fidelities:
//!
//! * [`crate::sim`] — analytic cycle/energy model (fast, no data);
//! * [`crate::sim::functional`] / [`crate::arch::pe_functional`] —
//!   bit-accurate, cycle-faithful machines (slow, authoritative for
//!   hardware semantics);
//! * **this module** — the same integer semantics at software speed:
//!   [`kernel::PreparedGemm`] executes [`crate::quant::PackedLayer`]
//!   directly (cache-blocked, thread-parallel, bit-sparsity-aware) and
//!   [`model::NativeModel`] composes it into the full TinyCNN forward
//!   pass the coordinator serves when PJRT artifacts are absent.
//!
//! [`core`] holds the single definition of the packed group-op that all
//! three tiers share; the equivalence suite (`tests/native_equiv.rs`)
//! pins the kernel bit-exactly to the functional simulator.

pub mod core;
pub mod im2col;
pub mod kernel;
pub mod model;

pub use im2col::{im2col, ConvGeom};
pub use kernel::{dense_gemm, naive_gemm, quantize_acts, quantize_acts_rows, PreparedGemm};
pub use model::{
    filters_first, surrogate_tinycnn_weights, tinycnn_weights, NativeModel, WeightTransform,
};
