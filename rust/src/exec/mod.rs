//! Native SWIS execution engine — the third execution tier.
//!
//! The repo executes packed SWIS operands at three fidelities:
//!
//! * [`crate::sim`] — analytic cycle/energy model (fast, no data);
//! * [`crate::sim::functional`] / [`crate::arch::pe_functional`] —
//!   bit-accurate, cycle-faithful machines (slow, authoritative for
//!   hardware semantics);
//! * **this module** — the same integer semantics at software speed:
//!   [`kernel::PreparedGemm`] / [`kernel::PreparedDepthwise`] execute
//!   [`crate::quant::PackedLayer`] directly (cache-blocked,
//!   thread-parallel, bit-sparsity-aware) and [`model::NativeModel`]
//!   composes them into full forward passes over the op-graph IR in
//!   [`graph`] — lowered from any `nets::Network` descriptor, so the
//!   whole zoo (TinyCNN, MobileNet-v2 with depthwise + inverted
//!   residuals, ResNet-18 with skips, VGG-16) serves natively when PJRT
//!   artifacts are absent.
//!
//! [`core`] holds the single definition of the packed group-op that all
//! three tiers share; the equivalence suites (`tests/native_equiv.rs`,
//! `tests/graph_equiv.rs`) pin the kernels bit-exactly to the functional
//! simulator and the graph executor to the sequential reference.
//!
//! [`simd`] supplies the runtime-dispatched vector backends (AVX2 /
//! NEON / portable) for the kernel inner loop, and [`tune`] the
//! bench-driven autotuner whose machine-tuned [`simd::TuneParams`]
//! travel inside `.swisplan` containers; `tests/simd_equiv.rs` pins
//! every variant bit-identical to the scalar walk.

pub mod core;
pub mod graph;
pub mod im2col;
pub mod kernel;
pub mod model;
pub mod simd;
pub mod tune;

pub use im2col::{im2col, ConvGeom};
pub use kernel::{
    dense_depthwise, dense_gemm, naive_depthwise, naive_gemm, quantize_acts, quantize_acts_rows,
    quantize_taps, PreparedDepthwise, PreparedGemm,
};
pub use simd::{best_available, detected_isa, KernelVariant, TuneParams};
pub use tune::{tune_gemm, MaskAxis, TuneOptions, TuneReport};
pub use model::{
    filters_first, net_weights, surrogate_network_weights, surrogate_tinycnn_weights,
    tinycnn_weights, LayerOperand, NativeModel, PreparedLayer, WeightProvenance,
    WeightTransform,
};
