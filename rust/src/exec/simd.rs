//! SIMD backends for the packed bit-serial kernels.
//!
//! The scalar kernel in [`super::kernel`] walks each prepared shift
//! plane's pos/neg lane bitmask with `trailing_zeros` and does one
//! gather-add per set bit per row — serializing exactly the work SWIS's
//! shared bit sparsity exposes as data-parallel. This module vectorizes
//! the OTHER axis: the activation tile is transposed into a contiguous
//! scratch block (column-major, one cache line per fan-in column), so
//! each set lane bit becomes a single unit-stride vector load covering
//! 8–16 output rows at once, and the pos/neg plane passes fuse into one
//! signed accumulation per plane:
//!
//! ```text
//!   per plane:  part[0..W] += at[lane] (pos bits) − at[lane] (neg bits)
//!               acc[0..W]  += (part as i64) << shift
//! ```
//!
//! All-integer adds/shifts in a fixed order per row — bit-identical to
//! the scalar walk for any tile/chunk size (pinned by
//! `tests/simd_equiv.rs`).
//!
//! # Activation zero-skipping
//!
//! Weight bit sparsity drops empty shift planes at prepare time; the
//! *activation* side is handled here at dispatch time. Each tile pass
//! receives a per-group zero-lane mask (`masks[gl]`): bit `i` is set iff
//! lane `i`'s activation column is non-zero for at least one row of the
//! tile. Every plane's pos/neg bitmasks are ANDed with it before the
//! walk, and a plane that goes empty under the mask is skipped entirely
//! — a zero column (post-ReLU dead channel) contributes exactly 0 to
//! every partial, so dropping its loads is bit-identical by
//! construction. The caller computes the mask in the same pass that
//! transposes the tile (see [`super::kernel`]) and passes all-ones when
//! masking is off or the tile is dense.
//!
//! # Variant dispatch
//!
//! | detected ISA | [`KernelVariant`] | tile width |
//! |--------------|-------------------|------------|
//! | x86_64 + AVX-512 | `Avx2Wide` (2x interleaved AVX2) | 16 rows |
//! | x86_64 + AVX2 | `Avx2` | 8 rows |
//! | aarch64 (NEON baseline) | `Neon` | 8 rows |
//! | anything else | `Portable` (autovectorizable arrays) | 8 rows |
//!
//! AVX-512 hosts route to `Avx2Wide` rather than native 512-bit
//! intrinsics: the pinned toolchain (Rust 1.84) predates AVX-512
//! `std::arch` stabilization, and two interleaved 256-bit accumulator
//! chains recover most of the win (wider OoO window, same loads/cycle)
//! without nightly features. `SWIS_FORCE_SCALAR=1` in the environment
//! forces the scalar walk everywhere — the escape hatch CI exercises on
//! every test run.
//!
//! # Overflow contract
//!
//! Vector partials are 32-bit (the scalar path uses 64-bit partials).
//! With at most [`super::kernel::MAX_GROUP_SIZE`] = 16 lanes per group,
//! any `|activation| <= 2^26` keeps a partial within `i32` exactly;
//! [`super::kernel::PreparedGemm::gemm`] screens its input once against
//! [`MAX_SIMD_ACT`] and falls back to the scalar path above it. Real
//! activations are int8 codes (|a| <= 127), so the guard never trips on
//! the serving path.

use super::kernel::Plane;

/// Largest `|activation|` the 32-bit vector partials accept exactly
/// (16 lanes x 2^26 = 2^30 < i32::MAX). Inputs above this run scalar.
pub const MAX_SIMD_ACT: u32 = 1 << 26;

/// Upper bound on the tunable row tile; scratch/accumulator sizing and
/// the autotuner grid both respect it.
pub const MAX_ROW_BLOCK: usize = 64;

/// One executable flavor of the packed bit-serial inner loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelVariant {
    /// The original mask-walk with 64-bit partials: always correct, the
    /// fallback for unsupported hosts, forced mode and oversized acts.
    Scalar,
    /// Array-based 8-row tile the compiler autovectorizes — available on
    /// every target, the floor the explicit ISA paths must beat.
    Portable,
    /// Explicit AVX2: one 8 x i32 partial, two 4 x i64 accumulators.
    Avx2,
    /// Two interleaved AVX2 chains over a 16-row tile — what AVX-512
    /// hosts select (see the module docs for why not native 512-bit).
    Avx2Wide,
    /// Explicit NEON (aarch64 baseline): two 4 x i32 partials, four
    /// 2 x i64 accumulators over an 8-row tile.
    Neon,
}

impl KernelVariant {
    /// Rows one vector pass covers (1 for the scalar walk).
    pub fn width(self) -> usize {
        match self {
            KernelVariant::Scalar => 1,
            KernelVariant::Avx2Wide => 16,
            _ => 8,
        }
    }

    /// Can this variant execute on the current host?
    pub fn available(self) -> bool {
        match self {
            KernelVariant::Scalar | KernelVariant::Portable => true,
            KernelVariant::Avx2 | KernelVariant::Avx2Wide => {
                #[cfg(target_arch = "x86_64")]
                {
                    std::arch::is_x86_feature_detected!("avx2")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            KernelVariant::Neon => cfg!(target_arch = "aarch64"),
        }
    }

    /// Every variant, dispatch-preference order (used by the tuner grid
    /// and the equivalence tests).
    pub fn all() -> [KernelVariant; 5] {
        [
            KernelVariant::Scalar,
            KernelVariant::Portable,
            KernelVariant::Avx2,
            KernelVariant::Avx2Wide,
            KernelVariant::Neon,
        ]
    }

    /// Stable name (serialization-independent; the `.swisplan` container
    /// uses [`KernelVariant::tag`]).
    pub fn as_str(self) -> &'static str {
        match self {
            KernelVariant::Scalar => "scalar",
            KernelVariant::Portable => "portable",
            KernelVariant::Avx2 => "avx2",
            KernelVariant::Avx2Wide => "avx2_wide",
            KernelVariant::Neon => "neon",
        }
    }

    /// Dense index into per-variant counter arrays
    /// (`crate::obs::N_VARIANTS` entries, same order as the enum).
    pub fn index(self) -> usize {
        match self {
            KernelVariant::Scalar => 0,
            KernelVariant::Portable => 1,
            KernelVariant::Avx2 => 2,
            KernelVariant::Avx2Wide => 3,
            KernelVariant::Neon => 4,
        }
    }

    /// Inverse of [`KernelVariant::index`], for rendering counter arrays.
    pub fn from_index(i: usize) -> Option<KernelVariant> {
        Some(match i {
            0 => KernelVariant::Scalar,
            1 => KernelVariant::Portable,
            2 => KernelVariant::Avx2,
            3 => KernelVariant::Avx2Wide,
            4 => KernelVariant::Neon,
            _ => return None,
        })
    }

    /// Container tag byte (`.swisplan` TuneParams section).
    pub fn tag(self) -> u8 {
        match self {
            KernelVariant::Scalar => 0,
            KernelVariant::Portable => 1,
            KernelVariant::Avx2 => 2,
            KernelVariant::Avx2Wide => 3,
            KernelVariant::Neon => 4,
        }
    }

    /// Inverse of [`KernelVariant::tag`].
    pub fn from_tag(t: u8) -> Option<KernelVariant> {
        Some(match t {
            0 => KernelVariant::Scalar,
            1 => KernelVariant::Portable,
            2 => KernelVariant::Avx2,
            3 => KernelVariant::Avx2Wide,
            4 => KernelVariant::Neon,
            _ => return None,
        })
    }
}

/// The best variant the current host can run (ignores the forced-scalar
/// escape hatch — dispatch applies that separately, per call).
pub fn best_available() -> KernelVariant {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx512f") {
            return KernelVariant::Avx2Wide;
        }
        if std::arch::is_x86_feature_detected!("avx2") {
            return KernelVariant::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        return KernelVariant::Neon;
    }
    #[allow(unreachable_code)]
    KernelVariant::Portable
}

/// Human-readable detected ISA (stamped into `BENCH_native_gemm.json`'s
/// `simd_vs_scalar` records and the tuner report).
pub fn detected_isa() -> String {
    let arch = std::env::consts::ARCH;
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx512f") {
            return format!("{arch}/avx512");
        }
        if std::arch::is_x86_feature_detected!("avx2") {
            return format!("{arch}/avx2");
        }
    }
    if cfg!(target_arch = "aarch64") {
        return format!("{arch}/neon");
    }
    format!("{arch}/baseline")
}

/// The `SWIS_FORCE_SCALAR=1` escape hatch. Read per dispatch (one env
/// lookup per kernel call, not per row), so tests and operators can flip
/// it at runtime.
pub fn force_scalar() -> bool {
    matches!(std::env::var("SWIS_FORCE_SCALAR"), Ok(v) if v != "0" && !v.is_empty())
}

/// Host signature a [`TuneParams`] is pinned to: arch + detected vector
/// ISA + core count. Cheap, deterministic, and different whenever the
/// tuned argmin could plausibly differ — a loaded plan whose signature
/// mismatches drops its params and re-derives.
pub fn cpu_signature() -> String {
    let cores =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    format!("{}/{}c", detected_isa(), cores)
}

/// Machine-tuned kernel parameters — the artifact `swis tune` persists
/// into the `.swisplan` container and every kernel entry point consumes.
#[derive(Clone, Debug, PartialEq)]
pub struct TuneParams {
    /// Inner-loop flavor ([`KernelVariant`]).
    pub variant: KernelVariant,
    /// Rows per activation tile (multiple of the variant width).
    pub row_block: usize,
    /// Groups per transposed-scratch chunk (the lane-chunk axis: how
    /// many fan-in lanes stream through L1 per tile pass).
    pub group_chunk: usize,
    /// Preferred intra-op thread split (0 = resolve at session build).
    pub threads: usize,
    /// [`cpu_signature`] of the host the sweep ran on.
    pub cpu: String,
    /// Activation zero-skipping: AND a per-tile zero-lane mask into each
    /// plane's lane bitmasks before the walk. Runtime-only (NOT
    /// serialized in `.swisplan` — the density screen makes the dense
    /// case regression-free, so persisted plans always re-enable it);
    /// the bench and the equivalence tests toggle it to measure/pin the
    /// masked path against the unmasked one.
    pub act_mask: bool,
}

impl TuneParams {
    /// Untuned defaults for the current host: best detected variant,
    /// conservative blocking.
    pub fn host_default() -> TuneParams {
        let variant = best_available();
        TuneParams {
            variant,
            row_block: (2 * variant.width()).max(8),
            group_chunk: 8,
            threads: 0,
            cpu: cpu_signature(),
            act_mask: true,
        }
    }

    /// Scalar-walk params (the forced/fallback mode).
    pub fn scalar() -> TuneParams {
        TuneParams {
            variant: KernelVariant::Scalar,
            row_block: super::kernel::ROW_BLOCK,
            group_chunk: usize::MAX,
            threads: 0,
            cpu: cpu_signature(),
            act_mask: true,
        }
    }

    /// Did the sweep that produced these params run on this machine?
    pub fn matches_host(&self) -> bool {
        self.cpu == cpu_signature()
    }

    /// Clamp to what this host can execute: unavailable variants fall to
    /// the best available one, the row tile is rounded to a multiple of
    /// the variant width within [8, [`MAX_ROW_BLOCK`]], the chunk floor
    /// is 1. Sanitized params are always safe to dispatch.
    pub fn sanitized(mut self) -> TuneParams {
        if !self.variant.available() {
            self.variant = best_available();
        }
        if self.variant != KernelVariant::Scalar {
            let w = self.variant.width();
            let rb = self.row_block.clamp(w, MAX_ROW_BLOCK);
            self.row_block = rb.div_ceil(w) * w;
        } else if self.row_block == 0 {
            self.row_block = super::kernel::ROW_BLOCK;
        }
        self.group_chunk = self.group_chunk.max(1);
        self
    }
}

/// Accumulate every prepared plane of groups
/// `[g_base, g_base + n_groups)` over one W-row sub-tile of the
/// transposed scratch, adding into `acc` (`W = acc.len()`, a multiple
/// of 8 fixed by the caller from the variant width).
///
/// Scratch layout contract: fan-in column `c` of the chunk lives at
/// `at[c * stride + 0..stride]`, group `g_base + j` covers columns
/// `[j * gs, j * gs + gs)`, and `row_off + W <= stride`. Prepared masks
/// only carry bits for real fan-in lanes (pad bits are dropped at
/// prepare time), so every dereferenced column is in bounds.
///
/// `masks[j]` is group `g_base + j`'s zero-lane mask for the whole tile
/// (bit `i` set = lane column non-zero somewhere in the tile); pass
/// all-ones to disable activation skipping. ANDing it into each plane's
/// pos/neg bitmasks only ever removes loads of all-zero columns, so the
/// result is bit-identical for any mask that satisfies that contract.
#[allow(clippy::too_many_arguments)]
pub(crate) fn accumulate_tile(
    variant: KernelVariant,
    planes: &[Plane],
    plane_ofs: &[u32],
    g_base: usize,
    n_groups: usize,
    gs: usize,
    at: &[i32],
    stride: usize,
    row_off: usize,
    masks: &[u16],
    acc: &mut [i64],
) {
    debug_assert!(acc.len() % 8 == 0 && row_off + acc.len() <= stride);
    debug_assert!(n_groups * gs * stride <= at.len());
    debug_assert!(masks.len() >= n_groups);
    match variant {
        #[cfg(target_arch = "x86_64")]
        KernelVariant::Avx2 | KernelVariant::Avx2Wide if variant.available() => {
            // SAFETY: CPU feature — `variant.available()` (checked in the
            // guard above) is `is_x86_feature_detected!("avx2")`, the only
            // feature `tile_avx2` enables. Slice lengths — the scratch
            // layout contract holds here: `row_off + acc.len() <= stride`
            // and `n_groups * gs * stride <= at.len()` (debug-asserted
            // above), `acc.len()` is 8 or 16 (the variant width the caller
            // sized `acc` to, a multiple of 8 per the assert), masks has
            // >= n_groups entries, and `plane_ofs[g..=g+1]` is in bounds
            // for every group because prepare() emits n_groups+1 offsets
            // into `planes`. These are exactly the preconditions
            // `tile_avx2` documents.
            unsafe {
                x86::tile_avx2(
                    planes, plane_ofs, g_base, n_groups, gs, at, stride, row_off, masks, acc,
                )
            }
        }
        #[cfg(target_arch = "aarch64")]
        KernelVariant::Neon => {
            // SAFETY: CPU feature — NEON is mandatory on aarch64, so the
            // `#[target_feature(enable = "neon")]` on `tile_neon` is
            // always satisfied under this `cfg(target_arch = "aarch64")`.
            // Slice lengths — same scratch layout contract as the AVX2
            // arm: `row_off + acc.len() <= stride`, `n_groups * gs *
            // stride <= at.len()` (both debug-asserted above),
            // `acc.len() == 8` (the NEON width the caller sized `acc`
            // to), `masks.len() >= n_groups`, and `plane_ofs` has
            // n_groups+1 in-bounds offsets into `planes` from prepare().
            unsafe {
                arm::tile_neon(
                    planes, plane_ofs, g_base, n_groups, gs, at, stride, row_off, masks, acc,
                )
            }
        }
        // Portable covers itself, plus any variant the cfg above compiled
        // out — process the sub-tile in 8-row slices.
        _ => {
            let mut o = 0;
            while o + 8 <= acc.len() {
                tile_portable(
                    planes,
                    plane_ofs,
                    g_base,
                    n_groups,
                    gs,
                    at,
                    stride,
                    row_off + o,
                    masks,
                    &mut acc[o..o + 8],
                );
                o += 8;
            }
        }
    }
}

/// The autovectorizable 8-row tile: same loop shape as the ISA paths,
/// plain arrays — the correctness anchor the explicit paths are pinned
/// against on hosts without them.
#[allow(clippy::too_many_arguments)]
fn tile_portable(
    planes: &[Plane],
    plane_ofs: &[u32],
    g_base: usize,
    n_groups: usize,
    gs: usize,
    at: &[i32],
    stride: usize,
    row_off: usize,
    masks: &[u16],
    acc: &mut [i64],
) {
    const W: usize = 8;
    let mut a = [0i64; W];
    a.copy_from_slice(&acc[..W]);
    for gl in 0..n_groups {
        let g = g_base + gl;
        let a0 = gl * gs;
        let lm = masks[gl];
        for pl in &planes[plane_ofs[g] as usize..plane_ofs[g + 1] as usize] {
            let pos = pl.pos & lm;
            let neg = pl.neg & lm;
            if (pos | neg) == 0 {
                continue; // plane is empty under the zero-lane mask
            }
            let mut part = [0i32; W];
            let mut m = pos;
            while m != 0 {
                let lane = m.trailing_zeros() as usize;
                m &= m - 1;
                let col = &at[(a0 + lane) * stride + row_off..][..W];
                for r in 0..W {
                    part[r] += col[r];
                }
            }
            let mut m = neg;
            while m != 0 {
                let lane = m.trailing_zeros() as usize;
                m &= m - 1;
                let col = &at[(a0 + lane) * stride + row_off..][..W];
                for r in 0..W {
                    part[r] -= col[r];
                }
            }
            for r in 0..W {
                a[r] += (part[r] as i64) << pl.shift;
            }
        }
    }
    acc[..W].copy_from_slice(&a);
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::Plane;
    use std::arch::x86_64::*;

    /// AVX2 tile: per set lane, one 8 x i32 unit-stride load; the fused
    /// signed pass keeps one partial register per plane; widen + shift
    /// happens once per plane, not per lane. `acc.len()` selects the
    /// tile: 8 runs one chain, 16 runs two interleaved chains (the
    /// `Avx2Wide` shape AVX-512 hosts pick).
    ///
    /// # Safety
    /// CPU feature: the caller must have verified AVX2 support
    /// (`is_x86_feature_detected!("avx2")`) — every `_mm256_*` intrinsic
    /// below is AVX2 or baseline SSE2. Slice lengths (the scratch layout
    /// contract of [`super::accumulate_tile`]):
    /// * `acc.len()` is 8 or 16; the unaligned i64 loads/stores at
    ///   `ap .. ap+4 (+8, +12 when wide)` each cover 4 elements, so the
    ///   furthest store ends at `acc.len()`.
    /// * every `base.add((a0 + lane) * stride + row_off)` load reads 8
    ///   (16 when wide) i32s; in-bounds because `lane < gs` (prepared
    ///   masks carry bits only for real fan-in lanes), `a0 + lane <
    ///   n_groups * gs`, `row_off + acc.len() <= stride`, and
    ///   `n_groups * gs * stride <= at.len()`.
    /// * `masks.len() >= n_groups` and `plane_ofs[g_base ..=
    ///   g_base + n_groups]` are in-bounds indices into `planes`
    ///   (prepare() emits one offset per group plus a terminator) — the
    ///   `get_unchecked` calls rely on exactly these bounds.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn tile_avx2(
        planes: &[Plane],
        plane_ofs: &[u32],
        g_base: usize,
        n_groups: usize,
        gs: usize,
        at: &[i32],
        stride: usize,
        row_off: usize,
        masks: &[u16],
        acc: &mut [i64],
    ) {
        let base = at.as_ptr();
        let wide = acc.len() >= 16;
        let ap = acc.as_mut_ptr();
        let mut acc0 = _mm256_loadu_si256(ap as *const __m256i);
        let mut acc1 = _mm256_loadu_si256(ap.add(4) as *const __m256i);
        let mut acc2 = _mm256_setzero_si256();
        let mut acc3 = _mm256_setzero_si256();
        if wide {
            acc2 = _mm256_loadu_si256(ap.add(8) as *const __m256i);
            acc3 = _mm256_loadu_si256(ap.add(12) as *const __m256i);
        }
        for gl in 0..n_groups {
            let g = g_base + gl;
            let a0 = gl * gs;
            let lm = *masks.get_unchecked(gl);
            let lo = *plane_ofs.get_unchecked(g) as usize;
            let hi = *plane_ofs.get_unchecked(g + 1) as usize;
            for pl in planes.get_unchecked(lo..hi) {
                let pos = pl.pos & lm;
                let neg = pl.neg & lm;
                if (pos | neg) == 0 {
                    continue; // plane is empty under the zero-lane mask
                }
                let mut part0 = _mm256_setzero_si256();
                let mut part1 = _mm256_setzero_si256();
                let mut m = pos;
                while m != 0 {
                    let lane = m.trailing_zeros() as usize;
                    m &= m - 1;
                    let p = base.add((a0 + lane) * stride + row_off);
                    part0 = _mm256_add_epi32(part0, _mm256_loadu_si256(p as *const __m256i));
                    if wide {
                        part1 = _mm256_add_epi32(
                            part1,
                            _mm256_loadu_si256(p.add(8) as *const __m256i),
                        );
                    }
                }
                let mut m = neg;
                while m != 0 {
                    let lane = m.trailing_zeros() as usize;
                    m &= m - 1;
                    let p = base.add((a0 + lane) * stride + row_off);
                    part0 = _mm256_sub_epi32(part0, _mm256_loadu_si256(p as *const __m256i));
                    if wide {
                        part1 = _mm256_sub_epi32(
                            part1,
                            _mm256_loadu_si256(p.add(8) as *const __m256i),
                        );
                    }
                }
                let cnt = _mm_cvtsi32_si128(pl.shift as i32);
                let w0 = _mm256_cvtepi32_epi64(_mm256_castsi256_si128(part0));
                let w1 = _mm256_cvtepi32_epi64(_mm256_extracti128_si256::<1>(part0));
                acc0 = _mm256_add_epi64(acc0, _mm256_sll_epi64(w0, cnt));
                acc1 = _mm256_add_epi64(acc1, _mm256_sll_epi64(w1, cnt));
                if wide {
                    let w2 = _mm256_cvtepi32_epi64(_mm256_castsi256_si128(part1));
                    let w3 = _mm256_cvtepi32_epi64(_mm256_extracti128_si256::<1>(part1));
                    acc2 = _mm256_add_epi64(acc2, _mm256_sll_epi64(w2, cnt));
                    acc3 = _mm256_add_epi64(acc3, _mm256_sll_epi64(w3, cnt));
                }
            }
        }
        _mm256_storeu_si256(ap as *mut __m256i, acc0);
        _mm256_storeu_si256(ap.add(4) as *mut __m256i, acc1);
        if wide {
            _mm256_storeu_si256(ap.add(8) as *mut __m256i, acc2);
            _mm256_storeu_si256(ap.add(12) as *mut __m256i, acc3);
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod arm {
    use super::Plane;
    use std::arch::aarch64::*;

    /// NEON tile (8 rows): two 4 x i32 partials, four 2 x i64
    /// accumulators; `vshlq_s64` applies the plane shift after widening.
    ///
    /// # Safety
    /// CPU feature: NEON is architecturally mandatory on aarch64, so the
    /// `target_feature(enable = "neon")` requirement is satisfied on any
    /// aarch64 host. Slice lengths (the scratch layout contract of
    /// [`super::accumulate_tile`]):
    /// * `acc.len() == 8`: the `vld1q_s64`/`vst1q_s64` pairs at
    ///   `ap, ap+2, ap+4, ap+6` each cover 2 i64s, ending at element 8.
    /// * every `base.add((a0 + lane) * stride + row_off)` load reads 8
    ///   i32s (`vld1q_s32` at `p` and `p+4`); in-bounds because
    ///   `lane < gs` (prepared masks carry bits only for real fan-in
    ///   lanes), `a0 + lane < n_groups * gs`, `row_off + 8 <= stride`,
    ///   and `n_groups * gs * stride <= at.len()`.
    /// * `masks.len() >= n_groups` and `plane_ofs[g_base ..=
    ///   g_base + n_groups]` are in-bounds indices into `planes` — the
    ///   `get_unchecked` calls rely on exactly these bounds.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn tile_neon(
        planes: &[Plane],
        plane_ofs: &[u32],
        g_base: usize,
        n_groups: usize,
        gs: usize,
        at: &[i32],
        stride: usize,
        row_off: usize,
        masks: &[u16],
        acc: &mut [i64],
    ) {
        let base = at.as_ptr();
        let ap = acc.as_mut_ptr();
        let mut acc0 = vld1q_s64(ap);
        let mut acc1 = vld1q_s64(ap.add(2));
        let mut acc2 = vld1q_s64(ap.add(4));
        let mut acc3 = vld1q_s64(ap.add(6));
        for gl in 0..n_groups {
            let g = g_base + gl;
            let a0 = gl * gs;
            let lm = *masks.get_unchecked(gl);
            let lo = *plane_ofs.get_unchecked(g) as usize;
            let hi = *plane_ofs.get_unchecked(g + 1) as usize;
            for pl in planes.get_unchecked(lo..hi) {
                let pos = pl.pos & lm;
                let neg = pl.neg & lm;
                if (pos | neg) == 0 {
                    continue; // plane is empty under the zero-lane mask
                }
                let mut p0 = vdupq_n_s32(0);
                let mut p1 = vdupq_n_s32(0);
                let mut m = pos;
                while m != 0 {
                    let lane = m.trailing_zeros() as usize;
                    m &= m - 1;
                    let p = base.add((a0 + lane) * stride + row_off);
                    p0 = vaddq_s32(p0, vld1q_s32(p));
                    p1 = vaddq_s32(p1, vld1q_s32(p.add(4)));
                }
                let mut m = neg;
                while m != 0 {
                    let lane = m.trailing_zeros() as usize;
                    m &= m - 1;
                    let p = base.add((a0 + lane) * stride + row_off);
                    p0 = vsubq_s32(p0, vld1q_s32(p));
                    p1 = vsubq_s32(p1, vld1q_s32(p.add(4)));
                }
                let sh = vdupq_n_s64(pl.shift as i64);
                acc0 = vaddq_s64(acc0, vshlq_s64(vmovl_s32(vget_low_s32(p0)), sh));
                acc1 = vaddq_s64(acc1, vshlq_s64(vmovl_s32(vget_high_s32(p0)), sh));
                acc2 = vaddq_s64(acc2, vshlq_s64(vmovl_s32(vget_low_s32(p1)), sh));
                acc3 = vaddq_s64(acc3, vshlq_s64(vmovl_s32(vget_high_s32(p1)), sh));
            }
        }
        vst1q_s64(ap, acc0);
        vst1q_s64(ap.add(2), acc1);
        vst1q_s64(ap.add(4), acc2);
        vst1q_s64(ap.add(6), acc3);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn widths_tags_and_names_round_trip() {
        for v in KernelVariant::all() {
            assert_eq!(KernelVariant::from_tag(v.tag()), Some(v));
            assert!(!v.as_str().is_empty());
            assert!(v.width() == 1 || v.width() % 8 == 0);
        }
        assert_eq!(KernelVariant::from_tag(99), None);
        assert!(KernelVariant::Scalar.available());
        assert!(KernelVariant::Portable.available());
        assert!(best_available().available());
    }

    #[test]
    fn sanitize_clamps_to_host() {
        let tp = TuneParams {
            variant: KernelVariant::Neon, // unavailable on x86 (and vice versa)
            row_block: 1000,
            group_chunk: 0,
            threads: 2,
            cpu: "elsewhere".into(),
            act_mask: true,
        }
        .sanitized();
        assert!(tp.variant.available());
        assert!(tp.group_chunk >= 1);
        if tp.variant != KernelVariant::Scalar {
            assert!(tp.row_block <= MAX_ROW_BLOCK);
            assert_eq!(tp.row_block % tp.variant.width(), 0);
        }
        // host defaults are always dispatchable as-is
        let d = TuneParams::host_default();
        assert_eq!(d.clone().sanitized(), d);
        assert!(d.matches_host());
        assert!(!tp.matches_host());
    }

    #[test]
    fn isa_and_signature_are_stable() {
        assert_eq!(detected_isa(), detected_isa());
        assert_eq!(cpu_signature(), cpu_signature());
        assert!(cpu_signature().contains(std::env::consts::ARCH));
    }
}
