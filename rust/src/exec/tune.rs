//! Bench-driven kernel autotuner: sweep kernel-variant x row-block x
//! group-chunk x thread-split over a REAL prepared operand on the local
//! CPU, pick the argmin by median wall time, and hand back
//! [`TuneParams`] that `.swisplan` containers persist (versioned
//! `TuneParams` section) and every [`super::kernel`] entry point
//! consumes.
//!
//! Design points:
//!
//! * **Real planes, not microbenchmarks** — the probe is the plan's own
//!   largest prepared GEMM (or any [`PreparedGemm`] handed to
//!   [`tune_gemm`]), so plane sparsity, group geometry and fan-in match
//!   what serving will run.
//! * **Scalar is in the grid** — the scalar walk is timed in the SAME
//!   sweep as the vector candidates, so the reported
//!   [`TuneReport::speedup`] (best scalar median / best overall median)
//!   is >= 1.0 by construction: the argmin can never lose to a
//!   candidate it already contains.
//! * **Bit-identity is asserted, not assumed** — every candidate's
//!   output is compared against the scalar reference; a diverging
//!   candidate aborts the sweep with a typed error instead of persisting
//!   a wrong-but-fast configuration.
//! * **Deterministic probe** — activations come from the crate's seeded
//!   [`Rng`](crate::util::rng::Rng) in int8 range, so sweeps are
//!   reproducible and the vector overflow screen never demotes them.

use std::collections::HashSet;
use std::time::Instant;

use super::kernel::PreparedGemm;
use super::simd::{self, KernelVariant, TuneParams};
use crate::error::{SwisError, SwisResult};
use crate::util::rng::Rng;

/// Sweep shape knobs (`swis tune` flags map 1:1 onto these).
#[derive(Clone, Debug)]
pub struct TuneOptions {
    /// Probe rows (im2col patch rows) per timed pass.
    pub rows: usize,
    /// Timed repetitions per candidate; the median is scored.
    pub reps: usize,
    /// Thread-split axis of the grid (deduped, floored at 1).
    pub threads: Vec<usize>,
}

impl Default for TuneOptions {
    fn default() -> TuneOptions {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let mut threads = vec![1usize, cores.min(8)];
        threads.dedup();
        TuneOptions { rows: 192, reps: 3, threads }
    }
}

/// One swept configuration and its score.
#[derive(Clone, Debug)]
pub struct Candidate {
    /// The (sanitized, as-dispatched) parameters that were timed.
    pub params: TuneParams,
    /// Median wall time of one probe pass, milliseconds.
    pub median_ms: f64,
    /// Weight-MACs per second at the median, in millions.
    pub mws: f64,
}

/// Activation-mask axis measurements: the winning candidate re-timed
/// with [`TuneParams::act_mask`] on/off, on the dense probe AND on a
/// 50%-dead-column sparse probe. The grid itself is scored on the dense
/// probe — scoring the mask there would always pick mask-off, because a
/// dense probe never lets the mask win — so the axis is measured
/// separately and reported for the bench and CLI to show.
#[derive(Clone, Debug)]
pub struct MaskAxis {
    /// Winner's median on the dense probe, mask on, milliseconds.
    pub dense_on_ms: f64,
    /// Winner's median on the dense probe, mask off, milliseconds.
    pub dense_off_ms: f64,
    /// Winner's median on the 50%-dead-column probe, mask on.
    pub sparse_on_ms: f64,
    /// Winner's median on the 50%-dead-column probe, mask off.
    pub sparse_off_ms: f64,
    /// `sparse_off_ms / sparse_on_ms` — the zero-skipping win.
    pub sparse_speedup: f64,
    /// `dense_on_ms / dense_off_ms` — ~1.0 when the density screen holds.
    pub dense_overhead: f64,
}

/// The sweep's outcome: the winning [`TuneParams`] plus everything a
/// bench record or CLI report needs to justify it.
#[derive(Clone, Debug)]
pub struct TuneReport {
    /// Argmin-by-median winner across the whole grid (scalar included).
    pub best: TuneParams,
    /// Best scalar candidate's median (the baseline), milliseconds.
    pub scalar_median_ms: f64,
    /// Winner's median, milliseconds.
    pub best_median_ms: f64,
    /// `scalar_median_ms / best_median_ms` — >= 1.0 by construction.
    pub speedup: f64,
    /// [`simd::detected_isa`] of the machine the sweep ran on.
    pub isa: String,
    /// Probe geometry, e.g. `"128x576 rows=192 reps=3"`.
    pub probe: String,
    /// Every timed candidate (sweep order), for full bench records.
    pub candidates: Vec<Candidate>,
    /// Activation zero-skipping on/off, measured on the winner.
    pub mask: MaskAxis,
}

/// The candidate grid for one prepared operand: scalar at every thread
/// split, plus each host-available vector variant crossed with row-block
/// multiples of its width and fan-in chunk sizes.
fn candidate_grid(gpf: usize, threads: &[usize]) -> Vec<TuneParams> {
    let mut grid = Vec::new();
    for &nt in threads {
        grid.push(TuneParams { threads: nt, ..TuneParams::scalar() });
    }
    // chunk axis: small L1-friendly chunks up to the whole fan-in
    let mut chunks: Vec<usize> = [2usize, 4, 8, gpf].iter().map(|&c| c.clamp(1, gpf)).collect();
    chunks.sort_unstable();
    chunks.dedup();
    for v in KernelVariant::all() {
        if v == KernelVariant::Scalar || !v.available() {
            continue;
        }
        let w = v.width();
        for mult in [1usize, 2, 4] {
            let rb = (w * mult).min(simd::MAX_ROW_BLOCK);
            for &gc in &chunks {
                for &nt in threads {
                    grid.push(TuneParams {
                        variant: v,
                        row_block: rb,
                        group_chunk: gc,
                        threads: nt,
                        cpu: simd::cpu_signature(),
                        act_mask: true,
                    });
                }
            }
        }
    }
    grid
}

fn median(xs: &mut [f64]) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

/// Sweep one prepared GEMM. Every candidate is verified bit-identical to
/// the scalar reference before its median counts; returns the argmin
/// winner with the full grid attached.
pub fn tune_gemm(prep: &PreparedGemm, opts: &TuneOptions) -> SwisResult<TuneReport> {
    let rows = opts.rows.max(1);
    let reps = opts.reps.max(1);
    let mut threads: Vec<usize> = opts.threads.iter().map(|&t| t.max(1)).collect();
    if threads.is_empty() {
        threads.push(1);
    }
    threads.sort_unstable();
    threads.dedup();

    let fan_in = prep.fan_in();
    let mut rng = Rng::new(0x5EED_7A11);
    let acts: Vec<i32> =
        (0..rows * fan_in).map(|_| rng.range_u64(0, 255) as i32 - 128).collect();

    // the correctness anchor every candidate is compared against
    let mut scalar_prep = prep.clone();
    scalar_prep.set_tune(TuneParams::scalar());
    let reference = scalar_prep.gemm(&acts, rows, 1)?;

    let macs = prep.macs(rows) as f64;
    let mut seen: HashSet<(u8, usize, usize, usize)> = HashSet::new();
    let mut candidates: Vec<Candidate> = Vec::new();
    for params in candidate_grid(prep.groups_per_filter(), &threads) {
        let mut p = prep.clone();
        p.set_tune(params.clone());
        let tuned = p.tune().clone(); // sanitized form actually dispatched
        let key =
            (tuned.variant.tag(), tuned.row_block, tuned.group_chunk, params.threads.max(1));
        if !seen.insert(key) {
            continue; // sanitize collapsed it onto an already-timed point
        }
        let nt = params.threads.max(1);
        let mut times = Vec::with_capacity(reps);
        let mut first = None;
        for _ in 0..reps {
            let t0 = Instant::now();
            let out = p.gemm(&acts, rows, nt)?;
            times.push(t0.elapsed().as_secs_f64() * 1e3);
            first.get_or_insert(out);
        }
        if first.as_deref() != Some(reference.as_slice()) {
            return Err(SwisError::backend(format!(
                "tuner candidate {} (rb={} gc={} nt={nt}) diverged from the scalar reference",
                tuned.variant.as_str(),
                tuned.row_block,
                tuned.group_chunk
            )));
        }
        let med = median(&mut times);
        candidates.push(Candidate {
            params: TuneParams { threads: nt, ..tuned },
            median_ms: med,
            mws: macs / 1e6 / (med / 1e3),
        });
    }

    let best_ix = candidates
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.median_ms.partial_cmp(&b.1.median_ms).unwrap())
        .map(|(i, _)| i)
        .ok_or_else(|| SwisError::backend("tuner produced an empty candidate grid"))?;
    let scalar_median_ms = candidates
        .iter()
        .filter(|c| c.params.variant == KernelVariant::Scalar)
        .map(|c| c.median_ms)
        .fold(f64::INFINITY, f64::min);
    let best = candidates[best_ix].clone();

    // mask axis: re-time the winner with zero-skipping on/off, on the
    // dense probe and on a 50%-dead-column variant of it (whole fan-in
    // columns zeroed — the shape ReLU-dead channels take), asserting
    // bit-identity between the two modes on both probes.
    let mut sparse_acts = acts.clone();
    for c in (0..fan_in).step_by(2) {
        for r in 0..rows {
            sparse_acts[r * fan_in + c] = 0;
        }
    }
    let nt = best.params.threads.max(1);
    let time_mode = |probe_acts: &[i32], mask_on: bool| -> SwisResult<(f64, Vec<i64>)> {
        let mut p = prep.clone();
        p.set_tune(TuneParams { act_mask: mask_on, ..best.params.clone() });
        let mut times = Vec::with_capacity(reps);
        let mut first = None;
        for _ in 0..reps {
            let t0 = Instant::now();
            let out = p.gemm(probe_acts, rows, nt)?;
            times.push(t0.elapsed().as_secs_f64() * 1e3);
            first.get_or_insert(out);
        }
        Ok((median(&mut times), first.unwrap()))
    };
    let (dense_on_ms, dense_on) = time_mode(&acts, true)?;
    let (dense_off_ms, dense_off) = time_mode(&acts, false)?;
    let (sparse_on_ms, sparse_on) = time_mode(&sparse_acts, true)?;
    let (sparse_off_ms, sparse_off) = time_mode(&sparse_acts, false)?;
    if dense_on != dense_off || sparse_on != sparse_off {
        return Err(SwisError::backend(
            "activation-masked kernel diverged from the unmasked path on the tuner probe",
        ));
    }
    let mask = MaskAxis {
        dense_on_ms,
        dense_off_ms,
        sparse_on_ms,
        sparse_off_ms,
        sparse_speedup: sparse_off_ms / sparse_on_ms,
        dense_overhead: dense_on_ms / dense_off_ms,
    };

    Ok(TuneReport {
        best: best.params.clone(),
        scalar_median_ms,
        best_median_ms: best.median_ms,
        speedup: scalar_median_ms / best.median_ms,
        isa: simd::detected_isa(),
        probe: format!("{}x{fan_in} rows={rows} reps={reps}", prep.n_filters()),
        candidates,
        mask,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize, Alpha, QuantConfig};

    fn prep(k: usize, fan_in: usize) -> PreparedGemm {
        let mut rng = Rng::new(42);
        let w = rng.normal_vec(k * fan_in, 0.0, 0.06);
        let cfg = QuantConfig { n_shifts: 3, group_size: 4, alpha: Alpha::ONE, consecutive: false };
        PreparedGemm::from_packed(&quantize(&w, &[k, fan_in], &cfg).unwrap()).unwrap()
    }

    #[test]
    fn sweep_picks_a_dispatchable_argmin_with_speedup_at_least_one() {
        let p = prep(8, 36);
        let opts = TuneOptions { rows: 24, reps: 1, threads: vec![1] };
        let r = tune_gemm(&p, &opts).unwrap();
        assert!(!r.candidates.is_empty());
        assert!(r.best.variant.available());
        // scalar is in the grid, so the argmin can never lose to it
        assert!(r.speedup >= 1.0, "speedup {} < 1", r.speedup);
        let min = r.candidates.iter().map(|c| c.median_ms).fold(f64::INFINITY, f64::min);
        assert_eq!(r.best_median_ms, min);
        assert!(r.candidates.iter().all(|c| c.mws > 0.0 && c.median_ms >= 0.0));
        assert!(r.probe.contains("8x36"));
        assert_eq!(r.isa, simd::detected_isa());
        // the mask axis was measured on both probes (bit-identity between
        // masked/unmasked modes is asserted inside the sweep itself)
        assert!(r.mask.dense_on_ms >= 0.0 && r.mask.dense_off_ms >= 0.0);
        assert!(r.mask.sparse_on_ms >= 0.0 && r.mask.sparse_off_ms >= 0.0);
        assert!(r.mask.sparse_speedup.is_finite() || r.mask.sparse_on_ms == 0.0);
    }

    #[test]
    fn grid_covers_scalar_and_every_available_vector_variant() {
        let grid = candidate_grid(9, &[1, 2]);
        assert!(grid.iter().any(|t| t.variant == KernelVariant::Scalar && t.threads == 2));
        for v in KernelVariant::all() {
            if v != KernelVariant::Scalar && v.available() {
                assert!(
                    grid.iter().any(|t| t.variant == v && t.group_chunk == 9),
                    "grid misses full-fan-in chunk for {}",
                    v.as_str()
                );
            }
        }
        // chunk axis is clamped to groups-per-filter
        assert!(candidate_grid(2, &[1]).iter().all(|t| t.group_chunk <= 2));
    }
}
