//! The native TinyCNN executor: the same 6-conv + GAP + 2-FC graph
//! `python/compile/model.py` lowers for PJRT, executed by the native
//! kernels in this module tree — packed bit-serial GEMM for SWIS
//! variants, dense fp32 GEMM for the baseline — with bias + ReLU fused
//! into each layer. This is what lets the coordinator serve with no PJRT
//! and no build-time artifacts present.
//!
//! Weight layout contract (shared with the AOT path): conv weights HWIO
//! `(3,3,cin,cout)`, FC `(din,dout)`, biases `<name>_b`; both put the
//! filter axis LAST, so one transpose yields the filters-first `(K,
//! fan_in)` matrices the quantizer and kernels consume.

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

use super::im2col::{im2col, ConvGeom};
use super::kernel::{dense_gemm, PreparedGemm};
use crate::nets::surrogate_weights;
use crate::quant::truncation::truncate_weights;
use crate::quant::Alpha;
use crate::schedule::quantize_or_schedule;
use crate::util::npy;
use crate::util::tensor::Tensor;

/// How a layer's fp32 weights become the served operand — the
/// backend-agnostic form of a serving variant (the coordinator's
/// `VariantSpec` maps onto this). This enum is the ONE variant-to-math
/// dispatch: the native backend executes it directly and the PJRT
/// backend's weight swap goes through [`WeightTransform::dequantize`].
#[derive(Clone, Copy, Debug)]
pub enum WeightTransform {
    /// Serve the fp32 weights unchanged (dense kernel).
    Fp32,
    /// SWIS / SWIS-C quantize and execute the packed format directly;
    /// fractional `n_shifts` routes through the Sec. 4.3 scheduler.
    Swis { n_shifts: f64, group_size: usize, consecutive: bool },
    /// Weight-truncation baseline (dense kernel over truncated floats).
    Truncate { bits: usize },
}

impl WeightTransform {
    /// Apply the transform to a filters-first `(k, fan_in)` weight matrix
    /// and return the dequantized floats — the weight-swap form the PJRT
    /// backend feeds its weight-agnostic graph. (For `Swis` the native
    /// backend executes the packed form instead of these floats.)
    pub fn dequantize(&self, wf: &[f64], k: usize, fan_in: usize) -> Result<Vec<f64>> {
        Ok(match *self {
            WeightTransform::Fp32 => wf.to_vec(),
            WeightTransform::Truncate { bits } => truncate_weights(wf, bits),
            WeightTransform::Swis { n_shifts, group_size, consecutive } => {
                quantize_or_schedule(wf, &[k, fan_in], n_shifts, group_size, consecutive, Alpha::ONE)?
                    .to_f64()
            }
        })
    }
}

enum Kernel {
    Packed(PreparedGemm),
    Dense { w: Vec<f32>, k: usize, fan_in: usize },
}

struct Layer {
    name: String,
    kernel: Kernel,
    bias: Vec<f32>,
    relu: bool,
    /// `Some` for conv layers (SAME geometry precomputed at prepare
    /// time); `None` for the FC head.
    conv: Option<ConvGeom>,
    out_c: usize,
}

impl Layer {
    fn matmul(&self, acts: &[f32], rows: usize, threads: usize) -> Result<Vec<f32>> {
        match &self.kernel {
            Kernel::Packed(p) => p.gemm_f32(acts, rows, threads),
            Kernel::Dense { w, k, fan_in } => dense_gemm(w, *k, *fan_in, acts, rows, threads),
        }
    }

    /// Matmul + fused bias + activation.
    fn run(&self, acts: &[f32], rows: usize, threads: usize) -> Result<Vec<f32>> {
        let mut y = self
            .matmul(acts, rows, threads)
            .with_context(|| format!("layer {}", self.name))?;
        let k = self.out_c;
        for r in 0..rows {
            for f in 0..k {
                let v = y[r * k + f] + self.bias[f];
                y[r * k + f] = if self.relu && v < 0.0 { 0.0 } else { v };
            }
        }
        Ok(y)
    }
}

/// A ready-to-run TinyCNN for one weight variant.
pub struct NativeModel {
    layers: Vec<Layer>,
    /// Weight storage bits across packed layers (0 for dense variants).
    pub packed_bits: u64,
}

/// Transpose a fan-in-major tensor (HWIO conv or `(din,dout)` FC — filter
/// axis last) into filters-first f64 `(k, fan_in)` — the layout the
/// quantizer and kernels consume. Shared with the PJRT weight-swap path.
pub fn filters_first(t: &Tensor<f32>) -> (Vec<f64>, usize, usize) {
    let shape = t.shape();
    let k = *shape.last().unwrap();
    let fan_in: usize = shape[..shape.len() - 1].iter().product();
    let mut wf = vec![0.0f64; k * fan_in];
    for i in 0..fan_in {
        for o in 0..k {
            wf[o * fan_in + i] = t.data()[i * k + o] as f64;
        }
    }
    (wf, k, fan_in)
}

impl NativeModel {
    /// Build the executable graph from an fp32 weight map under one
    /// transform. Biases pass through untouched (the paper quantizes
    /// weights only).
    pub fn prepare(
        weights: &HashMap<String, Tensor<f32>>,
        transform: WeightTransform,
    ) -> Result<NativeModel> {
        let mut layers = Vec::new();
        let mut packed_bits = 0u64;
        // the plan comes from the zoo's own shape table (conv trunk +
        // with_fc head) — the SAME source the surrogate generator uses,
        // so the two cannot drift apart
        let net = crate::nets::tinycnn().with_fc();
        let n_layers = net.layers.len();
        let mut hw = 32usize;
        let mut plan: Vec<(String, Option<ConvGeom>, usize, bool)> = Vec::new();
        for (idx, layer) in net.layers.iter().enumerate() {
            if layer.k > 1 {
                let g = ConvGeom::same(hw, layer.in_c, layer.k, layer.stride)?;
                hw = g.out_hw;
                plan.push((layer.name.clone(), Some(g), layer.out_c, true));
            } else {
                let relu = idx + 1 < n_layers; // last FC: raw logits
                plan.push((layer.name.clone(), None, layer.out_c, relu));
            }
        }

        for (name, conv, out_c, relu) in plan {
            let t = weights
                .get(&name)
                .with_context(|| format!("missing weight '{name}'"))?;
            let (wf, k, fan_in) = filters_first(t);
            if k != out_c {
                bail!("weight '{name}' has {k} filters, expected {out_c}");
            }
            let kernel = match transform {
                WeightTransform::Swis { n_shifts, group_size, consecutive } => {
                    let packed = quantize_or_schedule(
                        &wf,
                        &[k, fan_in],
                        n_shifts,
                        group_size,
                        consecutive,
                        Alpha::ONE,
                    )
                    .with_context(|| format!("quantizing '{name}'"))?;
                    packed_bits += packed.storage_bits();
                    Kernel::Packed(PreparedGemm::from_packed(&packed)?)
                }
                // fp32 / truncation serve dense floats via the shared
                // dequantize path
                _ => Kernel::Dense {
                    w: transform
                        .dequantize(&wf, k, fan_in)
                        .with_context(|| format!("transforming '{name}'"))?
                        .iter()
                        .map(|&v| v as f32)
                        .collect(),
                    k,
                    fan_in,
                },
            };
            let bias = weights
                .get(&format!("{name}_b"))
                .with_context(|| format!("missing bias '{name}_b'"))?
                .data()
                .to_vec();
            if bias.len() != out_c {
                bail!("bias '{name}_b' has {} entries, expected {out_c}", bias.len());
            }
            layers.push(Layer { name, kernel, bias, relu, conv, out_c });
        }
        Ok(NativeModel { layers, packed_bits })
    }

    /// Forward a `(batch, 32, 32, 3)` NHWC image batch to `(batch, 10)`
    /// logits.
    pub fn forward(&self, images: &Tensor<f32>, threads: usize) -> Result<Tensor<f32>> {
        let shape = images.shape();
        if shape.len() != 4 || shape[1] != 32 || shape[2] != 32 || shape[3] != 3 {
            bail!("expected (b, 32, 32, 3) images, got {shape:?}");
        }
        let batch = shape[0];
        let mut h = images.data().to_vec();
        let mut hw = 32usize;
        let mut c = 3usize;
        // conv trunk: im2col -> GEMM; the (b, oh, ow)-major GEMM output IS
        // the next NHWC map
        for layer in self.layers.iter().filter(|l| l.conv.is_some()) {
            let g = layer.conv.as_ref().unwrap();
            debug_assert_eq!((g.in_hw, g.in_c), (hw, c));
            let cols = im2col(&h, batch, g)?;
            h = layer.run(&cols, g.rows(batch), threads)?;
            hw = g.out_hw;
            c = layer.out_c;
        }
        // global average pool -> (batch, c)
        let px = hw * hw;
        let mut pooled = vec![0f32; batch * c];
        for b in 0..batch {
            for p in 0..px {
                let src = (b * px + p) * c;
                for ch in 0..c {
                    pooled[b * c + ch] += h[src + ch];
                }
            }
        }
        let inv = 1.0 / px as f32;
        pooled.iter_mut().for_each(|v| *v *= inv);
        // FC head
        let mut x = pooled;
        for layer in self.layers.iter().filter(|l| l.conv.is_none()) {
            x = layer.run(&x, batch, threads)?;
        }
        let classes = self.layers.last().map_or(0, |l| l.out_c);
        Tensor::new(&[batch, classes], x)
    }
}

/// Load the TinyCNN fp32 weight set: `tinycnn_weights.npz` when the
/// artifact directory has one, else a deterministic He-initialized
/// surrogate (DESIGN.md §4 — statistics stand in for identity, so the
/// serving stack exercises the exact shapes and dataflow of the trained
/// net even on a machine that never ran `make artifacts`).
pub fn tinycnn_weights(dir: Option<&Path>) -> Result<HashMap<String, Tensor<f32>>> {
    if let Some(d) = dir {
        let npz = d.join("tinycnn_weights.npz");
        if npz.exists() {
            let loaded = npy::load_npz(&npz)?;
            return Ok(loaded.into_iter().map(|(k, v)| (k, v.as_f32())).collect());
        }
    }
    // loud on purpose: predictions from surrogate weights are structurally
    // real but semantically meaningless — never let that pass for a
    // trained model
    eprintln!(
        "tinycnn_weights.npz not found{}; using UNTRAINED He-init surrogate weights \
         (serving plumbing/latency are real, accuracy is not)",
        dir.map_or(String::new(), |d| format!(" in {}", d.display()))
    );
    Ok(surrogate_tinycnn_weights(2021))
}

/// Surrogate weights in the jax layouts (conv HWIO, FC `(din,dout)`),
/// biases zero — deterministic in `seed`. Draws come from
/// [`crate::nets::surrogate_weights`] on the zoo's own TinyCNN shape
/// table, so the native backend's stand-in weights follow the same
/// documented convention (tagged RNG, `SIGMA_SCALE`-adjusted He sigma)
/// as every simulator/compression experiment — just transposed from the
/// filters-first draw into the serving layouts.
pub fn surrogate_tinycnn_weights(seed: u64) -> HashMap<String, Tensor<f32>> {
    let mut out = HashMap::new();
    for layer in &crate::nets::tinycnn().with_fc().layers {
        let fan_in = layer.fan_in();
        let k = layer.out_c;
        let wf = surrogate_weights(layer, seed); // filters-first (k, fan_in)
        let mut data = vec![0f32; fan_in * k];
        for o in 0..k {
            for i in 0..fan_in {
                data[i * k + o] = wf[o * fan_in + i] as f32;
            }
        }
        let shape: Vec<usize> = if layer.k > 1 {
            vec![layer.k, layer.k, layer.in_c, k] // conv HWIO
        } else {
            vec![fan_in, k] // FC (din, dout)
        };
        out.insert(layer.name.clone(), Tensor::new(&shape, data).unwrap());
        out.insert(format!("{}_b", layer.name), Tensor::new(&[k], vec![0.0; k]).unwrap());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn images(batch: usize, seed: u64) -> Tensor<f32> {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..batch * 32 * 32 * 3)
            .map(|_| rng.range_f64(0.0, 1.0) as f32)
            .collect();
        Tensor::new(&[batch, 32, 32, 3], data).unwrap()
    }

    #[test]
    fn fp32_forward_shapes_and_determinism() {
        let w = surrogate_tinycnn_weights(7);
        let m = NativeModel::prepare(&w, WeightTransform::Fp32).unwrap();
        let x = images(3, 1);
        let a = m.forward(&x, 1).unwrap();
        assert_eq!(a.shape(), &[3, 10]);
        assert!(a.data().iter().all(|v| v.is_finite()));
        // thread-count invariance end to end
        let b = m.forward(&x, 4).unwrap();
        assert_eq!(a.data(), b.data());
    }

    #[test]
    fn swis_variant_tracks_fp32() {
        let w = surrogate_tinycnn_weights(7);
        let fp = NativeModel::prepare(&w, WeightTransform::Fp32).unwrap();
        let sw = NativeModel::prepare(
            &w,
            WeightTransform::Swis { n_shifts: 6.0, group_size: 4, consecutive: false },
        )
        .unwrap();
        assert!(sw.packed_bits > 0);
        let x = images(2, 2);
        let a = fp.forward(&x, 2).unwrap();
        let b = sw.forward(&x, 2).unwrap();
        // 6 shifts on 8-bit mags is near-lossless; act quantization adds
        // a little more — logits must stay close, not identical
        let mut max_abs = 0f32;
        let mut max_diff = 0f32;
        for (p, q) in a.data().iter().zip(b.data()) {
            max_abs = max_abs.max(p.abs());
            max_diff = max_diff.max((p - q).abs());
        }
        assert!(max_diff < 0.25 * max_abs.max(1.0), "drift {max_diff} vs scale {max_abs}");
        assert_ne!(a.data(), b.data());
    }

    #[test]
    fn fractional_and_truncated_variants_run() {
        let w = surrogate_tinycnn_weights(3);
        let sched = NativeModel::prepare(
            &w,
            WeightTransform::Swis { n_shifts: 2.5, group_size: 4, consecutive: false },
        )
        .unwrap();
        let tr = NativeModel::prepare(&w, WeightTransform::Truncate { bits: 3 }).unwrap();
        let x = images(1, 5);
        assert_eq!(sched.forward(&x, 2).unwrap().shape(), &[1, 10]);
        assert_eq!(tr.forward(&x, 2).unwrap().shape(), &[1, 10]);
    }

    #[test]
    fn forward_is_batch_composition_invariant() {
        // per-row activation quantization: image A's logits are identical
        // whether A runs alone or co-batched with a wildly different B
        let w = surrogate_tinycnn_weights(7);
        let m = NativeModel::prepare(
            &w,
            WeightTransform::Swis { n_shifts: 3.0, group_size: 4, consecutive: false },
        )
        .unwrap();
        let a = images(1, 4);
        let mut both = a.data().to_vec();
        let mut rng = Rng::new(8);
        both.extend((0..32 * 32 * 3).map(|_| rng.range_f64(0.0, 90.0) as f32));
        let pair = Tensor::new(&[2, 32, 32, 3], both).unwrap();
        let alone = m.forward(&a, 2).unwrap();
        let paired = m.forward(&pair, 2).unwrap();
        assert_eq!(alone.data(), &paired.data()[..10]);
    }

    #[test]
    fn missing_weight_is_a_clear_error() {
        let mut w = surrogate_tinycnn_weights(1);
        w.remove("conv3");
        let e = NativeModel::prepare(&w, WeightTransform::Fp32).unwrap_err();
        assert!(format!("{e:#}").contains("conv3"));
    }

    #[test]
    fn rejects_bad_image_shape() {
        let w = surrogate_tinycnn_weights(1);
        let m = NativeModel::prepare(&w, WeightTransform::Fp32).unwrap();
        let bad = Tensor::new(&[1, 16, 16, 3], vec![0.0; 16 * 16 * 3]).unwrap();
        assert!(m.forward(&bad, 1).is_err());
    }
}
