//! The graph-driven native executor: any zoo descriptor
//! ([`crate::nets::Network`]) lowers to the op-graph IR in
//! [`super::graph`] and executes here — packed bit-serial GEMM /
//! depthwise kernels for SWIS variants, dense fp32 kernels for the
//! baselines — with bias + ReLU fused into each weighted node. This is
//! what lets the coordinator serve the whole model zoo (TinyCNN,
//! MobileNet-v2, ResNet-18, VGG-16) with no PJRT and no build-time
//! artifacts present.
//!
//! Weight layout contract (shared with the AOT path): conv weights HWIO
//! `(k,k,cin,cout)`, depthwise `(k,k,c)`, FC `(din,dout)`, biases
//! `<name>_b`; all put the filter axis LAST, so one transpose yields the
//! filters-first `(K, fan_in)` matrices the quantizer and kernels
//! consume.

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

use super::graph::{self, Graph, GraphOp, Src, ValShape};
use super::im2col::{im2col, ConvGeom};
use super::kernel::{dense_depthwise, dense_gemm, PreparedDepthwise, PreparedGemm};
use super::simd::TuneParams;
use crate::nets::{surrogate_weights, ConvKind, Network};
use crate::quant::serialize;
use crate::quant::truncation::truncate_weights;
use crate::quant::{Alpha, PackedLayer};
use crate::schedule::quantize_or_schedule;
use crate::util::npy;
use crate::util::tensor::Tensor;

/// How a layer's fp32 weights become the served operand — the
/// backend-agnostic form of a serving variant (the coordinator's
/// `VariantSpec` maps onto this). This enum is the ONE variant-to-math
/// dispatch: the native backend executes it directly and the PJRT
/// backend's weight swap goes through [`WeightTransform::dequantize`].
#[derive(Clone, Copy, Debug)]
pub enum WeightTransform {
    /// Serve the fp32 weights unchanged (dense kernel).
    Fp32,
    /// SWIS / SWIS-C quantize and execute the packed format directly;
    /// fractional `n_shifts` routes through the Sec. 4.3 scheduler.
    Swis { n_shifts: f64, group_size: usize, consecutive: bool },
    /// Weight-truncation baseline (dense kernel over truncated floats).
    Truncate { bits: usize },
}

impl WeightTransform {
    /// Apply the transform to a filters-first `(k, fan_in)` weight matrix
    /// and return the dequantized floats — the weight-swap form the PJRT
    /// backend feeds its weight-agnostic graph. (For `Swis` the native
    /// backend executes the packed form instead of these floats.)
    pub fn dequantize(&self, wf: &[f64], k: usize, fan_in: usize) -> Result<Vec<f64>> {
        Ok(match *self {
            WeightTransform::Fp32 => wf.to_vec(),
            WeightTransform::Truncate { bits } => truncate_weights(wf, bits),
            WeightTransform::Swis { n_shifts, group_size, consecutive } => {
                let shape = [k, fan_in];
                quantize_or_schedule(wf, &shape, n_shifts, group_size, consecutive, Alpha::ONE)?
                    .to_f64()
            }
        })
    }
}

/// The executable kernel bound to one weighted graph node.
enum OpKernel {
    Gemm(PreparedGemm),
    Dw(PreparedDepthwise),
    Dense { w: Arc<Vec<f32>>, k: usize, fan_in: usize },
    DenseDw { w: Arc<Vec<f32>>, c: usize },
}

struct LayerExec {
    kernel: OpKernel,
    bias: Vec<f32>,
}

/// The served operand of one weighted layer — exactly what a deployment
/// ships for that layer. This is the unit the `.swisplan` container
/// stores ([`crate::api::EnginePlan`]): reloading a plan binds kernels
/// straight from these operands, with NO quantization on the load path.
#[derive(Clone, Debug)]
pub enum LayerOperand {
    /// Dense fp32 weights, filters-first `(k, fan_in)` row-major — the
    /// fp32 and truncation variants. `Arc`-shared so a plan that keeps
    /// the operand for serialization and the bound kernel that executes
    /// it hold ONE copy of a large fp32 weight set, not two.
    Dense(Arc<Vec<f32>>),
    /// The packed SWIS/SWIS-C operand, executed directly.
    Packed(PackedLayer),
}

/// One weighted layer of a prepared plan: name + operand + bias.
#[derive(Clone, Debug)]
pub struct PreparedLayer {
    /// Layer name in the [`Network`] descriptor (binds operand to node).
    pub name: String,
    pub operand: LayerOperand,
    pub bias: Vec<f32>,
}

/// A ready-to-run network for one weight variant: the lowered graph plus
/// one prepared kernel per weighted node.
pub struct NativeModel {
    graph: Graph,
    labels: Vec<String>,
    /// Parallel to `graph.nodes`; `Some` for conv/depthwise/FC nodes.
    execs: Vec<Option<LayerExec>>,
    /// Weight storage bits across packed layers (0 for dense variants) —
    /// the Sec. 3.3 accounting.
    pub packed_bits: u64,
    /// Bit-packed `.swis` container payload bits across packed layers
    /// ([`serialize::payload_bits`]) — what a deployment actually
    /// flashes; the numerator of the measured compression ratio.
    pub packed_payload_bits: u64,
    /// Total weights in quantizable (non-bias) layers.
    pub quantized_weights: u64,
}

/// Transpose a fan-in-major tensor (HWIO conv, `(k,k,c)` depthwise or
/// `(din,dout)` FC — filter axis last) into filters-first f64
/// `(k, fan_in)` — the layout the quantizer and kernels consume. Shared
/// with the PJRT weight-swap path.
pub fn filters_first(t: &Tensor<f32>) -> (Vec<f64>, usize, usize) {
    let shape = t.shape();
    let k = *shape.last().unwrap();
    let fan_in: usize = shape[..shape.len() - 1].iter().product();
    let mut wf = vec![0.0f64; k * fan_in];
    for i in 0..fan_in {
        for o in 0..k {
            wf[o * fan_in + i] = t.data()[i * k + o] as f64;
        }
    }
    (wf, k, fan_in)
}

impl NativeModel {
    /// Build the executable graph for the TinyCNN accuracy proxy — the
    /// pre-zoo entry point, kept for every existing caller; equivalent to
    /// `prepare_net(&tinycnn().with_fc(), ...)`.
    pub fn prepare(
        weights: &HashMap<String, Tensor<f32>>,
        transform: WeightTransform,
    ) -> Result<NativeModel> {
        NativeModel::prepare_net(&crate::nets::tinycnn().with_fc(), weights, transform)
    }

    /// Lower `net` to the op graph and bind one prepared kernel per
    /// weighted node under `transform`. Biases pass through untouched
    /// (the paper quantizes weights only). This is the quantize-and-bind
    /// composition of [`NativeModel::plan_parts`] (the expensive planner
    /// sweep) and [`NativeModel::from_parts`] (cheap kernel binding) —
    /// plan-aware callers run the two halves separately so a reloaded
    /// `.swisplan` never re-quantizes.
    pub fn prepare_net(
        net: &Network,
        weights: &HashMap<String, Tensor<f32>>,
        transform: WeightTransform,
    ) -> Result<NativeModel> {
        let parts = NativeModel::plan_parts(net, weights, transform, Alpha::ONE)?;
        NativeModel::from_parts(net, &parts)
    }

    /// The OFFLINE half of preparation: quantize/transform every
    /// weighted layer of `net` into its served operand
    /// ([`PreparedLayer`]), in graph order. This is where all planner
    /// work happens; the result is what a `.swisplan` persists.
    pub fn plan_parts(
        net: &Network,
        weights: &HashMap<String, Tensor<f32>>,
        transform: WeightTransform,
        alpha: Alpha,
    ) -> Result<Vec<PreparedLayer>> {
        let graph = graph::lower(net)?;
        let mut parts = Vec::new();
        for node in &graph.nodes {
            let li = match node.op {
                GraphOp::Conv { layer, .. }
                | GraphOp::Fc { layer, .. }
                | GraphOp::Depthwise { layer, .. } => layer,
                _ => continue,
            };
            let l = &net.layers[li];
            let name = l.name.as_str();
            let t = weights
                .get(name)
                .with_context(|| format!("missing weight '{name}'"))?;
            let (wf, k, fan_in) = filters_first(t);
            if k != l.out_c || fan_in != l.fan_in() {
                bail!(
                    "weight '{name}' is ({k}, {fan_in}), expected ({}, {})",
                    l.out_c,
                    l.fan_in()
                );
            }
            let operand = match transform {
                WeightTransform::Swis { n_shifts, group_size, consecutive } => {
                    let packed = quantize_or_schedule(
                        &wf,
                        &[k, fan_in],
                        n_shifts,
                        group_size,
                        consecutive,
                        alpha,
                    )
                    .with_context(|| format!("quantizing '{name}'"))?;
                    LayerOperand::Packed(packed)
                }
                // fp32 / truncation serve dense floats via the shared
                // dequantize path
                _ => LayerOperand::Dense(Arc::new(
                    transform
                        .dequantize(&wf, k, fan_in)
                        .with_context(|| format!("transforming '{name}'"))?
                        .iter()
                        .map(|&v| v as f32)
                        .collect(),
                )),
            };
            let bias = weights
                .get(&format!("{name}_b"))
                .with_context(|| format!("missing bias '{name}_b'"))?
                .data()
                .to_vec();
            if bias.len() != l.out_c {
                bail!("bias '{name}_b' has {} entries, expected {}", bias.len(), l.out_c);
            }
            parts.push(PreparedLayer { name: name.to_string(), operand, bias });
        }
        Ok(parts)
    }

    /// The ONLINE half of preparation: bind one executable kernel per
    /// weighted node from already-prepared operands. No quantization
    /// happens here — only the cheap per-plane lane-mask prep — so
    /// loading a `.swisplan` and warming a pool worker from it performs
    /// zero planner work. Operands are matched to weighted graph nodes
    /// positionally and cross-checked by layer name and shape.
    pub fn from_parts(net: &Network, parts: &[PreparedLayer]) -> Result<NativeModel> {
        let graph = graph::lower(net)?;
        let labels: Vec<String> =
            (0..graph.nodes.len()).map(|i| graph.label(net, i)).collect();
        let mut execs: Vec<Option<LayerExec>> = Vec::with_capacity(graph.nodes.len());
        let mut packed_bits = 0u64;
        let mut packed_payload_bits = 0u64;
        let mut quantized_weights = 0u64;
        let mut next = 0usize;
        for node in &graph.nodes {
            let (li, depthwise) = match node.op {
                GraphOp::Conv { layer, .. } | GraphOp::Fc { layer, .. } => (layer, false),
                GraphOp::Depthwise { layer, .. } => (layer, true),
                _ => {
                    execs.push(None);
                    continue;
                }
            };
            let l = &net.layers[li];
            let part = parts
                .get(next)
                .with_context(|| format!("plan is missing an operand for layer '{}'", l.name))?;
            next += 1;
            if part.name != l.name {
                bail!(
                    "plan operand {} is for layer '{}', expected '{}'",
                    next - 1,
                    part.name,
                    l.name
                );
            }
            let (k, fan_in) = (l.out_c, l.fan_in());
            quantized_weights += (k * fan_in) as u64;
            let kernel = match &part.operand {
                LayerOperand::Packed(packed) => {
                    if packed.n_filters() != k || packed.fan_in() != fan_in {
                        bail!(
                            "packed operand '{}' is ({}, {}), expected ({k}, {fan_in})",
                            l.name,
                            packed.n_filters(),
                            packed.fan_in()
                        );
                    }
                    packed_bits += packed.storage_bits();
                    packed_payload_bits += serialize::payload_bits(packed);
                    if depthwise {
                        OpKernel::Dw(PreparedDepthwise::from_packed(packed)?)
                    } else {
                        OpKernel::Gemm(PreparedGemm::from_packed(packed)?)
                    }
                }
                LayerOperand::Dense(w) => {
                    if w.len() != k * fan_in {
                        bail!(
                            "dense operand '{}' has {} weights, expected {}",
                            l.name,
                            w.len(),
                            k * fan_in
                        );
                    }
                    // pointer clone: plan and kernel share the weights
                    if depthwise {
                        OpKernel::DenseDw { w: Arc::clone(w), c: k }
                    } else {
                        OpKernel::Dense { w: Arc::clone(w), k, fan_in }
                    }
                }
            };
            if part.bias.len() != l.out_c {
                bail!(
                    "bias for '{}' has {} entries, expected {}",
                    l.name,
                    part.bias.len(),
                    l.out_c
                );
            }
            execs.push(Some(LayerExec { kernel, bias: part.bias.clone() }));
        }
        if next != parts.len() {
            bail!(
                "plan carries {} operands but '{}' has {next} weighted layers",
                parts.len(),
                net.name
            );
        }
        Ok(NativeModel {
            graph,
            labels,
            execs,
            packed_bits,
            packed_payload_bits,
            quantized_weights,
        })
    }

    /// Expected input map as `[hw, hw, c]` (what one request carries).
    pub fn input_shape(&self) -> [usize; 3] {
        let ValShape { hw, c } = self.graph.input;
        [hw, hw, c]
    }

    pub fn n_classes(&self) -> usize {
        self.graph.output().c
    }

    pub fn net_name(&self) -> &str {
        &self.graph.net
    }

    /// Install machine-tuned kernel parameters on every bound packed
    /// kernel (GEMM and depthwise); dense fp32 kernels are unaffected.
    /// Parameters are sanitized per kernel, so applying params swept on
    /// another machine is safe (if pointless — callers should gate on
    /// [`TuneParams::matches_host`]).
    pub fn set_tune(&mut self, tp: &TuneParams) {
        for e in self.execs.iter_mut().flatten() {
            match &mut e.kernel {
                OpKernel::Gemm(p) => p.set_tune(tp.clone()),
                OpKernel::Dw(p) => p.set_tune(tp.clone()),
                OpKernel::Dense { .. } | OpKernel::DenseDw { .. } => {}
            }
        }
    }

    /// The largest bound packed GEMM by per-row MAC count — the operand
    /// the autotuner probes, so swept parameters reflect the layer that
    /// dominates this model's serving time. `None` for dense-only
    /// variants (fp32 / truncation), which have nothing to tune.
    pub fn largest_gemm(&self) -> Option<&PreparedGemm> {
        self.execs
            .iter()
            .flatten()
            .filter_map(|e| match &e.kernel {
                OpKernel::Gemm(p) => Some(p),
                _ => None,
            })
            .max_by_key(|p| p.macs(1))
    }

    /// Forward a `(batch, hw, hw, c)` NHWC image batch to
    /// `(batch, n_classes)` logits.
    pub fn forward(&self, images: &Tensor<f32>, threads: usize) -> Result<Tensor<f32>> {
        self.run(images, threads, None)
    }

    /// [`NativeModel::forward`] that streams every node's output
    /// (labelled by layer name, or `op@i` for pools/adds) through
    /// `observe` as it is produced — the hook the accuracy sweep uses to
    /// fold per-layer MSE vs fp32 WITHOUT retaining a second full
    /// activation trace of a 224x224 net.
    pub fn forward_observed(
        &self,
        images: &Tensor<f32>,
        threads: usize,
        observe: &mut dyn FnMut(&str, &[f32]),
    ) -> Result<Tensor<f32>> {
        self.run(images, threads, Some(observe))
    }

    /// [`NativeModel::forward_observed`] collecting the outputs into an
    /// owned labelled trace (the reference side of an MSE comparison).
    pub fn forward_trace(
        &self,
        images: &Tensor<f32>,
        threads: usize,
    ) -> Result<(Tensor<f32>, Vec<(String, Vec<f32>)>)> {
        let mut trace = Vec::with_capacity(self.graph.nodes.len());
        let mut obs = |label: &str, y: &[f32]| trace.push((label.to_string(), y.to_vec()));
        let logits = self.run(images, threads, Some(&mut obs))?;
        Ok((logits, trace))
    }

    fn run(
        &self,
        images: &Tensor<f32>,
        threads: usize,
        mut observe: Option<&mut dyn FnMut(&str, &[f32])>,
    ) -> Result<Tensor<f32>> {
        let shape = images.shape();
        let ValShape { hw, c } = self.graph.input;
        if shape.len() != 4 || shape[1] != hw || shape[2] != hw || shape[3] != c {
            bail!("expected (b, {hw}, {hw}, {c}) images for '{}', got {shape:?}", self.graph.net);
        }
        let batch = shape[0];
        let nodes = &self.graph.nodes;
        // consumer counts drive value lifetimes: a node's buffer is
        // dropped as soon as its last consumer ran (MobileNet at 224x224
        // would otherwise hold every intermediate map live)
        let mut uses = vec![0usize; nodes.len()];
        for node in nodes {
            if let Src::Node(i) = node.src {
                uses[i] += 1;
            }
            if let GraphOp::Add { rhs: Src::Node(i), .. } = node.op {
                uses[i] += 1;
            }
        }
        if let Some(u) = uses.last_mut() {
            *u += 1; // the graph output itself
        }
        // layer-scoped sparsity accounting: each node's kernel tallies
        // (recorded on this thread after the scoped-thread join inside
        // the kernels) are diffed per node and labelled with the layer
        // name. No-ops entirely when the obs level is Off.
        crate::obs::forward_begin();
        let mut vals: Vec<Option<Vec<f32>>> = (0..nodes.len()).map(|_| None).collect();
        for (ni, node) in nodes.iter().enumerate() {
            let lt = crate::obs::layer_begin();
            let y = {
                let (x, in_shape): (&[f32], ValShape) = match node.src {
                    Src::Input => (images.data(), self.graph.input),
                    Src::Node(i) => (
                        vals[i].as_deref().context("graph value consumed too early")?,
                        nodes[i].shape,
                    ),
                };
                self.eval_node(ni, node, x, in_shape, images.data(), &vals, batch, threads)
                    .with_context(|| format!("node '{}'", self.labels[ni]))?
            };
            crate::obs::layer_end(lt, &self.labels[ni]);
            if let Some(obs) = observe.as_mut() {
                obs(&self.labels[ni], &y);
            }
            vals[ni] = Some(y);
            if let Src::Node(i) = node.src {
                uses[i] -= 1;
                if uses[i] == 0 {
                    vals[i] = None;
                }
            }
            if let GraphOp::Add { rhs: Src::Node(i), .. } = node.op {
                uses[i] -= 1;
                if uses[i] == 0 {
                    vals[i] = None;
                }
            }
        }
        let out = vals
            .last_mut()
            .and_then(Option::take)
            .context("empty graph")?;
        Tensor::new(&[batch, self.graph.output().c], out)
    }

    /// Evaluate one node over its gathered input.
    #[allow(clippy::too_many_arguments)]
    fn eval_node(
        &self,
        ni: usize,
        node: &graph::GraphNode,
        x: &[f32],
        in_shape: ValShape,
        input: &[f32],
        vals: &[Option<Vec<f32>>],
        batch: usize,
        threads: usize,
    ) -> Result<Vec<f32>> {
        Ok(match &node.op {
            GraphOp::Conv { geom, relu, .. } => {
                let exec = self.execs[ni].as_ref().expect("conv node without kernel");
                let cols = im2col(x, batch, geom)?;
                let rows = geom.rows(batch);
                let mut y = match &exec.kernel {
                    OpKernel::Gemm(p) => p.gemm_f32(&cols, rows, threads)?,
                    OpKernel::Dense { w, k, fan_in } => {
                        dense_gemm(w.as_slice(), *k, *fan_in, &cols, rows, threads)?
                    }
                    _ => bail!("conv node bound to a depthwise kernel"),
                };
                bias_relu(&mut y, rows, &exec.bias, *relu);
                y
            }
            GraphOp::Depthwise { geom, relu, .. } => {
                let exec = self.execs[ni].as_ref().expect("depthwise node without kernel");
                let rows = geom.rows(batch);
                let mut y = match &exec.kernel {
                    OpKernel::Dw(p) => p.forward(x, batch, geom, threads)?,
                    OpKernel::DenseDw { w, c } => {
                        dense_depthwise(w.as_slice(), *c, x, batch, geom, threads)?
                    }
                    _ => bail!("depthwise node bound to a dense-conv kernel"),
                };
                bias_relu(&mut y, rows, &exec.bias, *relu);
                y
            }
            GraphOp::Fc { relu, .. } => {
                let exec = self.execs[ni].as_ref().expect("fc node without kernel");
                let mut y = match &exec.kernel {
                    OpKernel::Gemm(p) => p.gemm_f32(x, batch, threads)?,
                    OpKernel::Dense { w, k, fan_in } => {
                        dense_gemm(w.as_slice(), *k, *fan_in, x, batch, threads)?
                    }
                    _ => bail!("fc node bound to a depthwise kernel"),
                };
                bias_relu(&mut y, batch, &exec.bias, *relu);
                y
            }
            GraphOp::MaxPool { k, stride } => {
                maxpool_nhwc(x, batch, in_shape.hw, in_shape.c, *k, *stride)?
            }
            GraphOp::GlobalAvgPool => global_avg_pool(x, batch, in_shape.hw, in_shape.c),
            GraphOp::Add { rhs, relu } => {
                let r: &[f32] = match rhs {
                    Src::Input => input,
                    Src::Node(i) => {
                        vals[*i].as_deref().context("residual value consumed too early")?
                    }
                };
                if r.len() != x.len() {
                    bail!("residual add over {} vs {} elements", x.len(), r.len());
                }
                let mut y: Vec<f32> = x.iter().zip(r).map(|(a, b)| a + b).collect();
                if *relu {
                    for v in y.iter_mut() {
                        if *v < 0.0 {
                            *v = 0.0;
                        }
                    }
                }
                y
            }
        })
    }
}

/// Fused bias + optional ReLU over a `(rows, k)` buffer.
fn bias_relu(y: &mut [f32], rows: usize, bias: &[f32], relu: bool) {
    let k = bias.len();
    debug_assert_eq!(y.len(), rows * k);
    for r in 0..rows {
        for f in 0..k {
            let v = y[r * k + f] + bias[f];
            y[r * k + f] = if relu && v < 0.0 { 0.0 } else { v };
        }
    }
}

/// XLA-SAME max-pool over an NHWC batch; out-of-map taps are ignored
/// (never dominate), matching padding semantics over post-ReLU maps.
fn maxpool_nhwc(
    x: &[f32],
    batch: usize,
    hw: usize,
    c: usize,
    k: usize,
    stride: usize,
) -> Result<Vec<f32>> {
    let g = ConvGeom::same(hw, c, k, stride)?;
    if x.len() != batch * hw * hw * c {
        bail!("pool input {} != {batch} x {hw} x {hw} x {c}", x.len());
    }
    let o = g.out_hw;
    let mut out = vec![0f32; batch * o * o * c];
    for b in 0..batch {
        let img = &x[b * hw * hw * c..(b + 1) * hw * hw * c];
        for oh in 0..o {
            for ow in 0..o {
                let dst = ((b * o + oh) * o + ow) * c;
                let cell = &mut out[dst..dst + c];
                cell.fill(f32::NEG_INFINITY);
                let mut any = false;
                for kh in 0..k {
                    let ih = (oh * stride + kh) as isize - g.pad_lo as isize;
                    if ih < 0 || ih >= hw as isize {
                        continue;
                    }
                    for kw in 0..k {
                        let iw = (ow * stride + kw) as isize - g.pad_lo as isize;
                        if iw < 0 || iw >= hw as isize {
                            continue;
                        }
                        any = true;
                        let src = (ih as usize * hw + iw as usize) * c;
                        for (ch, m) in cell.iter_mut().enumerate() {
                            if img[src + ch] > *m {
                                *m = img[src + ch];
                            }
                        }
                    }
                }
                if !any {
                    cell.fill(0.0);
                }
            }
        }
    }
    Ok(out)
}

/// Global average pool: `(batch, hw, hw, c)` -> `(batch, c)`.
fn global_avg_pool(x: &[f32], batch: usize, hw: usize, c: usize) -> Vec<f32> {
    let px = hw * hw;
    let mut pooled = vec![0f32; batch * c];
    for b in 0..batch {
        for p in 0..px {
            let src = (b * px + p) * c;
            for ch in 0..c {
                pooled[b * c + ch] += x[src + ch];
            }
        }
    }
    let inv = 1.0 / px as f32;
    pooled.iter_mut().for_each(|v| *v *= inv);
    pooled
}

/// Where a served weight set came from — stamped into every BENCH
/// trajectory record so surrogate-backed points are never silently
/// compared against trained-model points.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WeightProvenance {
    /// Loaded from `<net>_weights.npz` in the artifact directory.
    Npz,
    /// Deterministic He-init stand-ins (structure real, accuracy not).
    Surrogate,
}

impl WeightProvenance {
    pub fn as_str(self) -> &'static str {
        match self {
            WeightProvenance::Npz => "npz",
            WeightProvenance::Surrogate => "surrogate",
        }
    }
}

/// Load a network's fp32 weight set: `<net>_weights.npz` when the
/// artifact directory has one, else a deterministic He-initialized
/// surrogate (DESIGN.md §4 — statistics stand in for identity, so the
/// serving stack exercises the exact shapes and dataflow of the trained
/// net even on a machine that never ran `make artifacts`). The returned
/// provenance tags which one happened.
pub fn net_weights(
    dir: Option<&Path>,
    net: &Network,
) -> Result<(HashMap<String, Tensor<f32>>, WeightProvenance)> {
    if let Some(d) = dir {
        let npz = d.join(format!("{}_weights.npz", net.name));
        if npz.exists() {
            let loaded = npy::load_npz(&npz)?;
            let map = loaded.into_iter().map(|(k, v)| (k, v.as_f32())).collect();
            return Ok((map, WeightProvenance::Npz));
        }
    }
    // loud on purpose, for EVERY zoo net: predictions from surrogate
    // weights are structurally real but semantically meaningless — never
    // let that pass for a trained model
    eprintln!(
        "{}_weights.npz not found{}; using UNTRAINED He-init surrogate weights for '{}' \
         (serving plumbing/latency are real, accuracy is not; trajectory records carry \
         \"weights\": \"surrogate\")",
        net.name,
        dir.map_or(String::new(), |d| format!(" in {}", d.display())),
        net.name
    );
    Ok((surrogate_network_weights(net, 2021), WeightProvenance::Surrogate))
}

/// TinyCNN convenience over [`net_weights`] (the pre-zoo API).
pub fn tinycnn_weights(dir: Option<&Path>) -> Result<HashMap<String, Tensor<f32>>> {
    net_weights(dir, &crate::nets::tinycnn().with_fc()).map(|(w, _)| w)
}

/// Surrogate weights for any zoo network in the serving layouts (conv
/// HWIO, depthwise `(k,k,c)`, FC `(din,dout)`), biases zero —
/// deterministic in `seed`. Draws come from
/// [`crate::nets::surrogate_weights`] on the network's own shape table,
/// so the native backend's stand-in weights follow the same documented
/// convention (tagged RNG, `SIGMA_SCALE`-adjusted He sigma) as every
/// simulator/compression experiment — just transposed from the
/// filters-first draw into the serving layouts.
pub fn surrogate_network_weights(net: &Network, seed: u64) -> HashMap<String, Tensor<f32>> {
    let mut out = HashMap::new();
    for layer in &net.layers {
        let fan_in = layer.fan_in();
        let k = layer.out_c;
        let wf = surrogate_weights(layer, seed); // filters-first (k, fan_in)
        let mut data = vec![0f32; fan_in * k];
        for o in 0..k {
            for i in 0..fan_in {
                data[i * k + o] = wf[o * fan_in + i] as f32;
            }
        }
        let shape: Vec<usize> = if layer.kind == ConvKind::Depthwise {
            vec![layer.k, layer.k, k] // depthwise (k, k, c)
        } else if layer.k > 1 || layer.in_hw > 1 {
            vec![layer.k, layer.k, layer.in_c, k] // conv HWIO
        } else {
            vec![fan_in, k] // FC (din, dout)
        };
        out.insert(layer.name.clone(), Tensor::new(&shape, data).unwrap());
        out.insert(format!("{}_b", layer.name), Tensor::new(&[k], vec![0.0; k]).unwrap());
    }
    out
}

/// TinyCNN convenience over [`surrogate_network_weights`].
pub fn surrogate_tinycnn_weights(seed: u64) -> HashMap<String, Tensor<f32>> {
    surrogate_network_weights(&crate::nets::tinycnn().with_fc(), seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn images(batch: usize, seed: u64) -> Tensor<f32> {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..batch * 32 * 32 * 3)
            .map(|_| rng.range_f64(0.0, 1.0) as f32)
            .collect();
        Tensor::new(&[batch, 32, 32, 3], data).unwrap()
    }

    #[test]
    fn fp32_forward_shapes_and_determinism() {
        let w = surrogate_tinycnn_weights(7);
        let m = NativeModel::prepare(&w, WeightTransform::Fp32).unwrap();
        assert_eq!(m.input_shape(), [32, 32, 3]);
        assert_eq!(m.n_classes(), 10);
        assert_eq!(m.net_name(), "tinycnn");
        let x = images(3, 1);
        let a = m.forward(&x, 1).unwrap();
        assert_eq!(a.shape(), &[3, 10]);
        assert!(a.data().iter().all(|v| v.is_finite()));
        // thread-count invariance end to end
        let b = m.forward(&x, 4).unwrap();
        assert_eq!(a.data(), b.data());
    }

    #[test]
    fn swis_variant_tracks_fp32() {
        let w = surrogate_tinycnn_weights(7);
        let fp = NativeModel::prepare(&w, WeightTransform::Fp32).unwrap();
        let sw = NativeModel::prepare(
            &w,
            WeightTransform::Swis { n_shifts: 6.0, group_size: 4, consecutive: false },
        )
        .unwrap();
        assert!(sw.packed_bits > 0);
        assert!(sw.packed_payload_bits >= sw.packed_bits);
        assert_eq!(fp.packed_bits, 0);
        let x = images(2, 2);
        let a = fp.forward(&x, 2).unwrap();
        let b = sw.forward(&x, 2).unwrap();
        // 6 shifts on 8-bit mags is near-lossless; act quantization adds
        // a little more — logits must stay close, not identical
        let mut max_abs = 0f32;
        let mut max_diff = 0f32;
        for (p, q) in a.data().iter().zip(b.data()) {
            max_abs = max_abs.max(p.abs());
            max_diff = max_diff.max((p - q).abs());
        }
        assert!(max_diff < 0.25 * max_abs.max(1.0), "drift {max_diff} vs scale {max_abs}");
        assert_ne!(a.data(), b.data());
    }

    #[test]
    fn fractional_and_truncated_variants_run() {
        let w = surrogate_tinycnn_weights(3);
        let sched = NativeModel::prepare(
            &w,
            WeightTransform::Swis { n_shifts: 2.5, group_size: 4, consecutive: false },
        )
        .unwrap();
        let tr = NativeModel::prepare(&w, WeightTransform::Truncate { bits: 3 }).unwrap();
        let x = images(1, 5);
        assert_eq!(sched.forward(&x, 2).unwrap().shape(), &[1, 10]);
        assert_eq!(tr.forward(&x, 2).unwrap().shape(), &[1, 10]);
    }

    #[test]
    fn forward_is_batch_composition_invariant() {
        // per-row activation quantization: image A's logits are identical
        // whether A runs alone or co-batched with a wildly different B
        let w = surrogate_tinycnn_weights(7);
        let m = NativeModel::prepare(
            &w,
            WeightTransform::Swis { n_shifts: 3.0, group_size: 4, consecutive: false },
        )
        .unwrap();
        let a = images(1, 4);
        let mut both = a.data().to_vec();
        let mut rng = Rng::new(8);
        both.extend((0..32 * 32 * 3).map(|_| rng.range_f64(0.0, 90.0) as f32));
        let pair = Tensor::new(&[2, 32, 32, 3], both).unwrap();
        let alone = m.forward(&a, 2).unwrap();
        let paired = m.forward(&pair, 2).unwrap();
        assert_eq!(alone.data(), &paired.data()[..10]);
    }

    #[test]
    fn parts_split_is_bit_identical_and_validated() {
        // plan_parts + from_parts (the .swisplan load path) must produce
        // the same logits as the one-shot prepare
        let w = surrogate_tinycnn_weights(7);
        let tf = WeightTransform::Swis { n_shifts: 3.0, group_size: 4, consecutive: false };
        let net = crate::nets::tinycnn().with_fc();
        let parts = NativeModel::plan_parts(&net, &w, tf, crate::quant::Alpha::ONE).unwrap();
        assert_eq!(parts.len(), 8); // 6 convs + 2 fc (gap carries no weights)
        let direct = NativeModel::prepare_net(&net, &w, tf).unwrap();
        let rebound = NativeModel::from_parts(&net, &parts).unwrap();
        assert_eq!(direct.packed_bits, rebound.packed_bits);
        assert_eq!(direct.packed_payload_bits, rebound.packed_payload_bits);
        let x = images(2, 3);
        assert_eq!(
            direct.forward(&x, 2).unwrap().data(),
            rebound.forward(&x, 2).unwrap().data()
        );
        // a dropped operand, a misnamed operand and a wrong-shape bias
        // are clear errors, not garbage models
        let mut short = parts.clone();
        short.pop();
        assert!(NativeModel::from_parts(&net, &short).is_err());
        let mut renamed = parts.clone();
        renamed[0].name = "nope".into();
        assert!(NativeModel::from_parts(&net, &renamed).is_err());
        let mut badbias = parts;
        badbias[0].bias.pop();
        assert!(NativeModel::from_parts(&net, &badbias).is_err());
    }

    #[test]
    fn missing_weight_is_a_clear_error() {
        let mut w = surrogate_tinycnn_weights(1);
        w.remove("conv3");
        let e = NativeModel::prepare(&w, WeightTransform::Fp32).unwrap_err();
        assert!(format!("{e:#}").contains("conv3"));
    }

    #[test]
    fn rejects_bad_image_shape() {
        let w = surrogate_tinycnn_weights(1);
        let m = NativeModel::prepare(&w, WeightTransform::Fp32).unwrap();
        let bad = Tensor::new(&[1, 16, 16, 3], vec![0.0; 16 * 16 * 3]).unwrap();
        assert!(m.forward(&bad, 1).is_err());
    }

    #[test]
    fn trace_covers_every_node_and_matches_forward() {
        let w = surrogate_tinycnn_weights(5);
        let m = NativeModel::prepare(&w, WeightTransform::Fp32).unwrap();
        let x = images(2, 9);
        let (logits, trace) = m.forward_trace(&x, 2).unwrap();
        assert_eq!(logits.data(), m.forward(&x, 2).unwrap().data());
        // 6 convs + gap + 2 fc
        assert_eq!(trace.len(), 9);
        assert_eq!(trace[0].0, "conv1");
        assert_eq!(trace.last().unwrap().0, "fc2");
        assert_eq!(trace.last().unwrap().1, logits.data());
    }

    #[test]
    fn net_weights_reports_surrogate_provenance() {
        let net = crate::nets::tinycnn().with_fc();
        let (w, prov) = net_weights(None, &net).unwrap();
        assert_eq!(prov, WeightProvenance::Surrogate);
        assert_eq!(prov.as_str(), "surrogate");
        assert!(w.contains_key("conv1") && w.contains_key("fc2_b"));
    }

    #[test]
    fn surrogate_zoo_weights_have_serving_layouts() {
        let net = crate::nets::mobilenet_v2().with_fc();
        let w = surrogate_network_weights(&net, 3);
        assert_eq!(w["stem"].shape(), &[3, 3, 3, 32]); // HWIO
        assert_eq!(w["block0.dw"].shape(), &[3, 3, 32]); // depthwise (k,k,c)
        assert_eq!(w["classifier"].shape(), &[1280, 1000]); // FC (din,dout)
        assert_eq!(w["block0.dw_b"].shape(), &[32]);
    }
}
