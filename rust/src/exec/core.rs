//! The shared packed-execution semantics — the ONE definition of what a
//! SWIS group-op computes, extracted from `sim::functional` /
//! `arch::pe_functional` so the cycle-faithful machines and the fast
//! native kernel cannot drift apart.
//!
//! A packed group (paper Sec. 3.3) stores, for `group_size` weight lanes,
//! a sign per lane, up to `n_shifts` shift values (ascending; SWIS-C
//! stores a consecutive window, i.e. an expanded offset — see
//! [`swis_c_offset`]), and one mask bit per (lane, shift plane). The
//! group's contribution to an output is Eq. 7 evaluated plane-major:
//!
//! ```text
//!   dot(g, a) = sum_j ( sum_i mask[g,i,j] * sign[g,i] * a[i] ) << shift[g,j]
//! ```
//!
//! Everything here is exact integer arithmetic, so any evaluation order
//! (plane-major here, lane-major via [`crate::quant::PackedLayer::mag`])
//! yields bit-identical results — the property the native engine's
//! equivalence suite pins against the functional simulator.

use crate::quant::PackedLayer;

/// Adder-tree partial of one shift plane `j` of group `g`:
/// `sum_i mask[g,i,j] * sign[g,i] * acts[i]` (before the barrel shift).
///
/// `acts` holds the group's `group_size` activation lanes.
#[inline]
pub fn plane_partial(layer: &PackedLayer, g: usize, j: usize, acts: &[i32]) -> i64 {
    let gs = layer.group_size;
    debug_assert!(acts.len() >= gs);
    let mut tree = 0i64;
    for i in 0..gs {
        if layer.masks[(g * gs + i) * layer.n_shifts + j] != 0 {
            let a = acts[i] as i64;
            tree += if layer.signs[g * gs + i] < 0 { -a } else { a };
        }
    }
    tree
}

/// Full group dot product, plane-major over the group's ACTIVE planes
/// (scheduled layers store trailing inactive planes; see
/// [`PackedLayer::active_shifts`]).
pub fn group_dot(layer: &PackedLayer, g: usize, acts: &[i32]) -> i64 {
    let n = layer.active_shifts(g);
    let row = &layer.shifts[g * layer.n_shifts..g * layer.n_shifts + n];
    let mut acc = 0i64;
    for (j, &s) in row.iter().enumerate() {
        acc += plane_partial(layer, g, j, acts) << s;
    }
    acc
}

/// Gather group `gl`'s activation lanes from a fan-in-major activation
/// row, zero-padding past the fan-in tail (the staggered-feed contract of
/// the systolic array and the ragged-group contract of the kernel).
#[inline]
pub fn gather_lanes(row: &[i32], gl: usize, group_size: usize, lanes: &mut [i32]) {
    let fan_in = row.len();
    for i in 0..group_size {
        let idx = gl * group_size + i;
        lanes[i] = if idx < fan_in { row[idx] } else { 0 };
    }
}

/// SWIS-C groups store shifts as one 3-bit offset expanded to the
/// consecutive window `offset..offset+n`; returns that offset when the
/// group's active shifts form such a window (always true for layers
/// quantized with `consecutive: true`), `None` otherwise.
pub fn swis_c_offset(layer: &PackedLayer, g: usize) -> Option<u8> {
    let n = layer.active_shifts(g);
    if n == 0 {
        return None;
    }
    let row = &layer.shifts[g * layer.n_shifts..g * layer.n_shifts + n];
    for (j, &s) in row.iter().enumerate() {
        if s != row[0] + j as u8 {
            return None;
        }
    }
    Some(row[0])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize, QuantConfig};
    use crate::util::rng::Rng;

    fn packed(seed: u64, n: usize, g: usize, consecutive: bool) -> PackedLayer {
        let mut rng = Rng::new(seed);
        let w = rng.normal_vec(8 * 24, 0.0, 0.07);
        let cfg = QuantConfig {
            n_shifts: n,
            group_size: g,
            alpha: crate::quant::Alpha::ONE,
            consecutive,
        };
        quantize(&w, &[8, 24], &cfg).unwrap()
    }

    #[test]
    fn group_dot_matches_lane_major_mag_form() {
        let p = packed(1, 3, 4, false);
        let mut rng = Rng::new(2);
        for g in 0..p.n_groups() {
            let acts: Vec<i32> = (0..4).map(|_| rng.range_u64(0, 255) as i32 - 128).collect();
            let lane_major: i64 = (0..4)
                .map(|i| acts[i] as i64 * p.signs[g * 4 + i] as i64 * p.mag(g, i))
                .sum();
            assert_eq!(group_dot(&p, g, &acts), lane_major, "group {g}");
        }
    }

    #[test]
    fn swis_c_groups_expose_offsets() {
        let p = packed(3, 3, 4, true);
        for g in 0..p.n_groups() {
            let off = swis_c_offset(&p, g).expect("SWIS-C group must have an offset");
            assert!(off <= 5, "offset {off} leaves no room for 3 consecutive shifts");
        }
    }

    #[test]
    fn non_consecutive_groups_usually_lack_offsets() {
        // force shifts {0, 2}: not a consecutive window
        let p = PackedLayer {
            shape: vec![1, 2],
            group_size: 2,
            n_shifts: 2,
            scale: 1.0,
            shifts: vec![0, 2],
            masks: vec![1, 1, 0, 1],
            signs: vec![1, -1],
            consecutive: false,
            filter_shifts: None,
        };
        assert_eq!(swis_c_offset(&p, 0), None);
    }

    #[test]
    fn gather_lanes_zero_pads_tail() {
        let row = vec![5, -3, 7]; // fan_in 3
        let mut lanes = [9i32; 4];
        gather_lanes(&row, 0, 4, &mut lanes[..]);
        assert_eq!(lanes, [5, -3, 7, 0]);
    }
}
