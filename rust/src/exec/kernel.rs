//! The native SWIS GEMM kernel: executes [`PackedLayer`] operands
//! directly — no dequantized weight matrix is ever materialized — at
//! memory-bandwidth-class speed instead of the cycle-faithful pace of
//! [`crate::sim::functional`].
//!
//! Strategy (paper Fig. 4 datapath, software-shaped):
//!
//! 1. **Prepare once per layer** ([`PreparedGemm::from_packed`]): for
//!    every (group, active shift plane) precompute two lane bitmasks —
//!    positive-sign and negative-sign lanes whose mask bit is set — plus
//!    the plane's shift. Planes with no set bits are dropped, so *bit
//!    sparsity directly removes work* (the paper's premise: fewer shift
//!    planes, fewer operations).
//! 2. **Plane-major accumulation**: per output, iterate the group's
//!    prepared planes; each contributes `(Σ pos-lanes − Σ neg-lanes) <<
//!    shift`. All-integer adds/shifts — bit-exact against the functional
//!    simulator for any loop order or thread count.
//! 3. **Cache blocking + SIMD tiles**: rows (output pixels) are
//!    processed in machine-tuned blocks ([`super::simd::TuneParams`]).
//!    The vector path transposes each block's activations into a
//!    contiguous scratch so every set lane bit becomes one unit-stride
//!    vector load across the whole row tile ([`super::simd`]); results
//!    are staged in a block buffer and written row-contiguous. The
//!    scalar walk remains as the always-correct fallback
//!    (`SWIS_FORCE_SCALAR=1`, unsupported hosts, oversized activations).
//! 4. **`std::thread::scope` parallelism**: row ranges are disjoint
//!    output slices handed to scoped threads (no locks, results
//!    thread-count invariant).
//!
//! The int8 entry point ([`PreparedGemm::gemm`]) returns the exact
//! integer MACs (the serving contract with `sim::functional::run_matmul`);
//! the fp32 entry ([`PreparedGemm::gemm_f32`]) adds symmetric int8
//! activation quantization and the dequant rescale (paper's 8-bit
//! activations). Every dispatch flavor is bit-identical — pinned by
//! `tests/simd_equiv.rs`.
//!
//! **Activation zero-skipping** (EIE's observation, applied to the SWIS
//! plane walk): post-ReLU activations are 50–70% zero, and an int8 code
//! of 0 contributes exactly 0 through every shift plane. Both row cores
//! therefore derive a per-row-block *zero-lane mask* per group — bit `i`
//! set iff lane `i`'s activation column is non-zero for at least one row
//! of the block — and AND it into each plane's pos/neg bitmasks before
//! the walk ([`super::simd::accumulate_tile`]); planes that go empty
//! under the mask are skipped outright. The mask falls out of the
//! transpose pass the blocked path already makes, and a *density screen*
//! (tiles over ~90% dense run unmasked) keeps the dense worst case
//! regression-free. [`TuneParams::act_mask`] switches the whole
//! mechanism off for benchmarking; results are bit-identical either way
//! because only exactly-zero contributions are dropped.

use super::core;
use super::im2col::ConvGeom;
use super::simd::{self, KernelVariant, TuneParams, MAX_SIMD_ACT};
use crate::error::{SwisError, SwisResult};
use crate::obs::{self, ExecTally};
use crate::quant::int8::round_half_even;
use crate::quant::PackedLayer;

/// Rows per cache block on the scalar path: small enough for the block's
/// i64 accumulators and partials to live in registers, large enough to
/// amortize the prepared-operand stream. The vector path's row tile is
/// machine-tuned instead ([`TuneParams::row_block`]).
pub const ROW_BLOCK: usize = 8;

/// Largest group size the u16 lane bitmasks cover.
pub const MAX_GROUP_SIZE: usize = 16;

/// One prepared shift plane: lanes split by sign, only set mask bits.
#[derive(Clone, Copy, Debug)]
pub(crate) struct Plane {
    pub(crate) shift: u8,
    pub(crate) pos: u16,
    pub(crate) neg: u16,
}

/// Density screen threshold: a row block runs masked only when more than
/// ~10% of its activation columns are all-zero. Below that the mask
/// can't pay for its own AND/test per plane, so the block runs unmasked
/// (all-ones) and the dense worst case stays regression-free.
const MASK_MIN_ZERO_TENTHS: usize = 1;

/// Fold per-column non-zero flags (`nzc[c] != 0` = column `c` live
/// somewhere in the row block) into per-group zero-lane masks, applying
/// the density screen: returns `false` (and leaves `masks` untouched)
/// when the block is too dense for masking to pay. `ncols` counts the
/// real (non-padding) columns; group `gl` covers columns
/// `[gl * gs, gl * gs + gs)`. Padding lanes get a 0 bit, which is
/// harmless — prepared planes carry no bits for them.
fn fold_zero_lane_masks(nzc: &[i32], ncols: usize, gs: usize, masks: &mut [u16]) -> bool {
    let zeros = nzc[..ncols].iter().filter(|&&v| v == 0).count();
    if zeros * 10 < ncols * MASK_MIN_ZERO_TENTHS {
        return false; // > ~90% dense: masking won't pay for itself
    }
    for (gl, m) in masks.iter_mut().enumerate() {
        let base = gl * gs;
        let valid = ncols.saturating_sub(base).min(gs);
        let mut bits = 0u16;
        for (i, &nz) in nzc[base..base + valid].iter().enumerate() {
            if nz != 0 {
                bits |= 1 << i;
            }
        }
        *m = bits;
    }
    true
}

/// A packed layer prepared for native execution. Holds only the
/// *non-empty* shift planes per group — the executable form of the
/// operand format in Sec. 3.3.
#[derive(Clone, Debug)]
pub struct PreparedGemm {
    n_filters: usize,
    fan_in: usize,
    group_size: usize,
    groups_per_filter: usize,
    /// Dequantization scale of the packed weights (max|w| / 127).
    pub scale: f64,
    /// Group `g`'s planes live at `planes[plane_ofs[g]..plane_ofs[g+1]]`.
    plane_ofs: Vec<u32>,
    planes: Vec<Plane>,
    /// Planes dropped empty at prepare time, summed over every group —
    /// the weight-bit-sparsity win the sparsity counters attribute per
    /// walk of the full group range.
    dropped_planes: u64,
    tune: TuneParams,
}

/// Precompute the per-(group, active shift plane) sign-split lane
/// bitmasks for a packed layer — the ONE prepare step shared by the GEMM
/// ([`PreparedGemm`]) and depthwise ([`PreparedDepthwise`]) kernels.
/// Empty planes are dropped (bit sparsity == less work) and pad-lane
/// bits are cleared so the plane walk stays in bounds and bit-identical
/// to the gather-based oracles. Fails on group sizes beyond the bitmask
/// width.
fn prepare_planes(p: &PackedLayer) -> SwisResult<(Vec<u32>, Vec<Plane>, u64)> {
    if p.group_size == 0 || p.group_size > MAX_GROUP_SIZE {
        return Err(SwisError::config(format!(
            "native kernel supports group sizes 1..={MAX_GROUP_SIZE}, got {}",
            p.group_size
        )));
    }
    p.validate().map_err(SwisError::config_from)?;
    let n_groups = p.n_groups();
    let gs = p.group_size;
    let gpf = p.groups_per_filter();
    let fan_in = p.fan_in();
    let mut plane_ofs = Vec::with_capacity(n_groups + 1);
    let mut planes = Vec::new();
    let mut dropped = 0u64;
    plane_ofs.push(0u32);
    for g in 0..n_groups {
        // SWIS-C layers must keep the consecutive-window property the
        // 3-bit offset storage accounting relies on (Sec. 3.3)
        debug_assert!(
            !p.consecutive || p.active_shifts(g) == 0 || core::swis_c_offset(p, g).is_some(),
            "SWIS-C group {g} has non-consecutive shifts"
        );
        // lanes of this group that map to real fan-in positions; the
        // quantizer zeroes pad-lane masks, but a hand-built or
        // deserialized layer may not — pad lanes feed activation 0 in
        // the gather-based paths, so DROPPING their bits here keeps
        // the kernel bit-identical to those oracles (and in bounds)
        let lane0 = (g % gpf) * gs;
        let valid = fan_in.saturating_sub(lane0).min(gs);
        for j in 0..p.active_shifts(g) {
            let mut pos = 0u16;
            let mut neg = 0u16;
            for i in 0..valid {
                if p.masks[(g * gs + i) * p.n_shifts + j] != 0 {
                    if p.signs[g * gs + i] < 0 {
                        neg |= 1 << i;
                    } else {
                        pos |= 1 << i;
                    }
                }
            }
            // empty planes contribute nothing: bit sparsity == less work
            if pos | neg != 0 {
                planes.push(Plane { shift: p.shifts[g * p.n_shifts + j], pos, neg });
            } else {
                dropped += 1;
            }
        }
        plane_ofs.push(planes.len() as u32);
    }
    Ok((plane_ofs, planes, dropped))
}

/// Lanes the zero-lane fold screened out of one tile: per group, the
/// valid (non-padding) lanes whose mask bit is clear.
fn count_lanes_masked(masks: &[u16], ncols: usize, gs: usize) -> u64 {
    let mut n = 0u64;
    for (gl, &m) in masks.iter().enumerate() {
        let valid = ncols.saturating_sub(gl * gs).min(gs) as u64;
        n += valid - u64::from(m.count_ones()).min(valid);
    }
    n
}

/// Metadata-only replay of one masked walk over groups `[g0, g0+n)` of
/// every filter: applies the exact skip predicate the compute loops use
/// (`(pos | neg) & mask == 0`) to the prepared `Plane` structs — no
/// activation data touched — and charges `reps` walks into `t`. Runs
/// only when sparsity counters are on AND the tile actually masked, so
/// the hot loops stay uninstrumented.
#[allow(clippy::too_many_arguments)]
fn count_plane_walk(
    planes: &[Plane],
    plane_ofs: &[u32],
    k: usize,
    gpf: usize,
    g0: usize,
    n: usize,
    masks: &[u16],
    reps: u64,
    t: &mut ExecTally,
) {
    let (mut visited, mut skipped) = (0u64, 0u64);
    for f in 0..k {
        for (gl, &lm) in masks[..n].iter().enumerate() {
            let g = f * gpf + g0 + gl;
            let lo = plane_ofs[g] as usize;
            let hi = plane_ofs[g + 1] as usize;
            for pl in &planes[lo..hi] {
                if ((pl.pos | pl.neg) & lm) == 0 {
                    skipped += 1;
                } else {
                    visited += 1;
                }
            }
        }
    }
    t.planes_visited += visited * reps;
    t.planes_skipped_masked += skipped * reps;
}

impl PreparedGemm {
    /// Prepare a packed layer. Fails on group sizes beyond the bitmask
    /// width; callers fall back to [`naive_gemm`] there. Starts on the
    /// host's default [`TuneParams`]; [`PreparedGemm::set_tune`] installs
    /// swept parameters.
    pub fn from_packed(p: &PackedLayer) -> SwisResult<PreparedGemm> {
        let (plane_ofs, planes, dropped_planes) = prepare_planes(p)?;
        Ok(PreparedGemm {
            n_filters: p.n_filters(),
            fan_in: p.fan_in(),
            group_size: p.group_size,
            groups_per_filter: p.groups_per_filter(),
            scale: p.scale,
            plane_ofs,
            planes,
            dropped_planes,
            tune: TuneParams::host_default(),
        })
    }

    pub fn n_filters(&self) -> usize {
        self.n_filters
    }

    pub fn fan_in(&self) -> usize {
        self.fan_in
    }

    /// Groups each filter's fan-in splits into (the tuner's chunk axis).
    pub fn groups_per_filter(&self) -> usize {
        self.groups_per_filter
    }

    /// Weight-MACs one full pass performs (for Mw/s reporting).
    pub fn macs(&self, p_rows: usize) -> u64 {
        p_rows as u64 * self.n_filters as u64 * self.fan_in as u64
    }

    /// Install machine-tuned kernel parameters (sanitized to what this
    /// host can dispatch — see [`TuneParams::sanitized`]).
    pub fn set_tune(&mut self, tp: TuneParams) {
        self.tune = tp.sanitized();
    }

    /// The kernel parameters dispatch currently uses.
    pub fn tune(&self) -> &TuneParams {
        &self.tune
    }

    /// The variant/blocking this call will actually run: the forced
    /// scalar escape hatch and the i32-partial overflow screen (see
    /// [`MAX_SIMD_ACT`]) both demote to the scalar walk.
    fn effective_tune(&self, acts: &[i32]) -> TuneParams {
        if self.tune.variant == KernelVariant::Scalar || simd::force_scalar() {
            return TuneParams { variant: KernelVariant::Scalar, ..self.tune.clone() };
        }
        let amax = acts.iter().fold(0u32, |m, &a| m.max(a.unsigned_abs()));
        if amax > MAX_SIMD_ACT {
            return TuneParams { variant: KernelVariant::Scalar, ..self.tune.clone() };
        }
        self.tune.clone()
    }

    /// `acts (p_rows, fan_in) x packed^T -> (p_rows, n_filters)` exact
    /// integer MACs, identical to `sim::functional::run_matmul` output.
    /// `n_threads <= 1` runs inline; row partitions make any thread count
    /// bit-identical, and so does every [`KernelVariant`].
    pub fn gemm(&self, acts: &[i32], p_rows: usize, n_threads: usize) -> SwisResult<Vec<i64>> {
        if acts.len() != p_rows * self.fan_in {
            return Err(SwisError::backend(format!(
                "acts {} != {} x {}",
                acts.len(),
                p_rows,
                self.fan_in
            )));
        }
        let tune = self.effective_tune(acts);
        // one relaxed atomic load per call; when counters are off the
        // row cores take `None` and skip every accounting branch
        let obs_on = obs::counters_on();
        let tally = std::sync::Mutex::new(ExecTally::default());
        let mut out = vec![0i64; p_rows * self.n_filters];
        par_rows(&mut out, p_rows, self.n_filters, n_threads, |start, rows, slice| {
            let mut t = if obs_on { Some(ExecTally::default()) } else { None };
            if tune.variant == KernelVariant::Scalar {
                self.gemm_rows_scalar(acts, start, rows, slice, tune.act_mask, t.as_mut());
            } else {
                self.gemm_rows_blocked(acts, start, rows, slice, &tune, t.as_mut());
            }
            if let Some(t) = t {
                tally.lock().unwrap().add(&t);
            }
        });
        if obs_on {
            let mut t = tally.into_inner().unwrap();
            t.dispatch[tune.variant.index()] += 1;
            if tune.variant == KernelVariant::Scalar && self.tune.variant != KernelVariant::Scalar
            {
                t.scalar_demotions += 1;
            }
            obs::record_exec(&t);
        }
        Ok(out)
    }

    /// fp32 activations: symmetric int8 quantization PER ROW (each row's
    /// own amax/127 scale), integer kernel, dequant rescale. Per-row
    /// scales keep a request's logits independent of whatever else shares
    /// its dispatch batch — every im2col row belongs to exactly one image
    /// — so serving is deterministic under any batching policy (and the
    /// finer scales only reduce quantization error vs one batch-wide
    /// scale). Returns `(p_rows, n_filters)`.
    pub fn gemm_f32(&self, acts: &[f32], p_rows: usize, n_threads: usize) -> SwisResult<Vec<f32>> {
        let (codes, scales) = quantize_acts_rows(acts, p_rows)?;
        let raw = self.gemm(&codes, p_rows, n_threads)?;
        let k = self.n_filters;
        let mut out = vec![0f32; p_rows * k];
        for r in 0..p_rows {
            let s = self.scale * scales[r];
            for f in 0..k {
                out[r * k + f] = (raw[r * k + f] as f64 * s) as f32;
            }
        }
        Ok(out)
    }

    /// The scalar single-thread core over rows `[start, start+rows)`;
    /// `out` is that range's output slice. Results are staged in a
    /// row-major block buffer so the store to `out` is row-contiguous
    /// (the per-filter scatter only ever touches the hot 8-row staging
    /// block). When `use_mask` is set, one scan per row block derives
    /// the per-group zero-lane masks (shared by all `k` filters, so the
    /// scan amortizes) and dead columns are skipped in the plane walk.
    ///
    /// Sparsity accounting (`tally`, `Some` only when counters are on)
    /// never touches the compute loop: an unmasked block charges O(1)
    /// from the prepared-plane totals, a masked block takes one
    /// metadata pass over the `Plane` structs with the exact skip
    /// predicate the walk uses.
    fn gemm_rows_scalar(
        &self,
        acts: &[i32],
        start: usize,
        rows: usize,
        out: &mut [i64],
        use_mask: bool,
        mut tally: Option<&mut ExecTally>,
    ) {
        let k = self.n_filters;
        let fi = self.fan_in;
        let gs = self.group_size;
        let gpf = self.groups_per_filter;
        debug_assert_eq!(out.len(), rows * k);
        let mut obuf = vec![0i64; ROW_BLOCK * k];
        let mut nzc = vec![0i32; fi];
        let mut masks = vec![0xFFFFu16; gpf];
        let mut r0 = 0usize;
        while r0 < rows {
            let rb = ROW_BLOCK.min(rows - r0);
            let mut masked = false;
            if use_mask {
                nzc.fill(0);
                for r in 0..rb {
                    let arow = &acts[(start + r0 + r) * fi..][..fi];
                    for (c, &v) in arow.iter().enumerate() {
                        nzc[c] |= v;
                    }
                }
                masked = fold_zero_lane_masks(&nzc, fi, gs, &mut masks);
            }
            if let Some(t) = tally.as_deref_mut() {
                t.tiles_total += 1;
                // every filter walks its own groups once per block, so
                // the full prepared-plane list is one block's walk
                t.planes_dropped_empty += self.dropped_planes;
                if masked {
                    t.tiles_masked += 1;
                    t.lanes_masked += count_lanes_masked(&masks, fi, gs);
                    count_plane_walk(&self.planes, &self.plane_ofs, k, gpf, 0, gpf, &masks, 1, t);
                } else {
                    t.planes_visited += self.planes.len() as u64;
                }
            }
            for f in 0..k {
                let mut acc = [0i64; ROW_BLOCK];
                for gl in 0..gpf {
                    let g = f * gpf + gl;
                    let a0 = gl * gs; // group's first lane in the act row
                    let lm = if masked { masks[gl] } else { 0xFFFF };
                    let lo = self.plane_ofs[g] as usize;
                    let hi = self.plane_ofs[g + 1] as usize;
                    for pl in &self.planes[lo..hi] {
                        let pos = pl.pos & lm;
                        let neg = pl.neg & lm;
                        if (pos | neg) == 0 {
                            continue; // every surviving lane reads zero
                        }
                        let mut partial = [0i64; ROW_BLOCK];
                        // prepared masks cover only real lanes (pad-lane
                        // bits are dropped at prepare time), so a0 + lane
                        // < fan_in always holds here
                        let mut m = pos;
                        while m != 0 {
                            let lane = m.trailing_zeros() as usize;
                            m &= m - 1;
                            let col = a0 + lane;
                            for r in 0..rb {
                                partial[r] += acts[(start + r0 + r) * fi + col] as i64;
                            }
                        }
                        let mut m = neg;
                        while m != 0 {
                            let lane = m.trailing_zeros() as usize;
                            m &= m - 1;
                            let col = a0 + lane;
                            for r in 0..rb {
                                partial[r] -= acts[(start + r0 + r) * fi + col] as i64;
                            }
                        }
                        for r in 0..rb {
                            acc[r] += partial[r] << pl.shift;
                        }
                    }
                }
                for r in 0..rb {
                    obuf[r * k + f] = acc[r];
                }
            }
            for r in 0..rb {
                out[(r0 + r) * k..(r0 + r) * k + k].copy_from_slice(&obuf[r * k..r * k + k]);
            }
            r0 += rb;
        }
    }

    /// The vector single-thread core: row tiles of `tune.row_block`,
    /// fan-in chunks of `tune.group_chunk` groups. Each chunk's
    /// activations are transposed into a contiguous scratch
    /// (`at[col * row_block + row]`, tail rows zero-padded) so the plane
    /// walk in [`simd::accumulate_tile`] issues one unit-stride vector
    /// load per set lane bit; per-tile results accumulate in a row-major
    /// block buffer and store row-contiguous. Bit-identical to the
    /// scalar walk: same integer adds and shifts per output, reordered
    /// associatively over exact arithmetic.
    fn gemm_rows_blocked(
        &self,
        acts: &[i32],
        start: usize,
        rows: usize,
        out: &mut [i64],
        tune: &TuneParams,
        mut tally: Option<&mut ExecTally>,
    ) {
        let k = self.n_filters;
        let fi = self.fan_in;
        let gs = self.group_size;
        let gpf = self.groups_per_filter;
        debug_assert_eq!(out.len(), rows * k);
        let w = tune.variant.width();
        let rbp = tune.row_block.max(w);
        let gc = tune.group_chunk.clamp(1, gpf);
        let mut at = vec![0i32; gc * gs * rbp];
        let mut obuf = vec![0i64; rbp * k];
        let mut nzc = vec![0i32; gc * gs];
        let mut masks = vec![0xFFFFu16; gc];
        let ones = vec![0xFFFFu16; gc];
        let mut r0 = 0usize;
        while r0 < rows {
            let rb = rbp.min(rows - r0);
            // sub-tiles per row tile: every group's plane list is walked
            // once per sub-tile by accumulate_tile
            let n_sub = rb.div_ceil(w) as u64;
            if let Some(t) = tally.as_deref_mut() {
                t.planes_dropped_empty += self.dropped_planes * n_sub;
            }
            obuf.fill(0);
            let mut g0 = 0usize;
            while g0 < gpf {
                let gce = gc.min(gpf - g0);
                let cols = gce * gs;
                let base_col = g0 * gs;
                // columns past fan_in exist only as zero padding — the
                // prepared masks carry no bits for them
                let ncols = cols.min(fi.saturating_sub(base_col));
                at[..cols * rbp].fill(0);
                if tune.act_mask {
                    // fuse the zero-lane scan into the transpose pass
                    nzc[..ncols].fill(0);
                    for r in 0..rb {
                        let arow = &acts[(start + r0 + r) * fi + base_col..][..ncols];
                        for (cidx, &v) in arow.iter().enumerate() {
                            at[cidx * rbp + r] = v;
                            nzc[cidx] |= v;
                        }
                    }
                } else {
                    for r in 0..rb {
                        let arow = &acts[(start + r0 + r) * fi + base_col..][..ncols];
                        for (cidx, &v) in arow.iter().enumerate() {
                            at[cidx * rbp + r] = v;
                        }
                    }
                }
                let masked =
                    tune.act_mask && fold_zero_lane_masks(&nzc, ncols, gs, &mut masks[..gce]);
                let tmasks: &[u16] = if masked {
                    &masks[..gce]
                } else {
                    &ones[..gce] // dense tile (or masking off): no-op mask
                };
                if let Some(t) = tally.as_deref_mut() {
                    t.tiles_total += 1;
                    if masked {
                        t.tiles_masked += 1;
                        t.lanes_masked += count_lanes_masked(&masks[..gce], ncols, gs);
                        count_plane_walk(
                            &self.planes,
                            &self.plane_ofs,
                            k,
                            gpf,
                            g0,
                            gce,
                            tmasks,
                            n_sub,
                            t,
                        );
                    } else {
                        // unmasked chunk: O(k) from the plane offsets
                        let mut walked = 0u64;
                        for f in 0..k {
                            let gb = f * gpf + g0;
                            walked += (self.plane_ofs[gb + gce] - self.plane_ofs[gb]) as u64;
                        }
                        t.planes_visited += walked * n_sub;
                    }
                }
                for f in 0..k {
                    let g_base = f * gpf + g0;
                    let mut sub = 0usize;
                    while sub < rb {
                        let mut acc = [0i64; simd::MAX_ROW_BLOCK];
                        simd::accumulate_tile(
                            tune.variant,
                            &self.planes,
                            &self.plane_ofs,
                            g_base,
                            gce,
                            gs,
                            &at,
                            rbp,
                            sub,
                            tmasks,
                            &mut acc[..w],
                        );
                        for r in 0..w.min(rb - sub) {
                            obuf[(sub + r) * k + f] += acc[r];
                        }
                        sub += w;
                    }
                }
                g0 += gce;
            }
            for r in 0..rb {
                out[(r0 + r) * k..(r0 + r) * k + k].copy_from_slice(&obuf[r * k..r * k + k]);
            }
            r0 += rb;
        }
    }
}

/// Symmetric int8 activation quantization: `code = round(x / (amax/127))`
/// (half-to-even, matching [`crate::quant::int8`]); all-zero input keeps
/// unit scale.
pub fn quantize_acts(x: &[f32]) -> (Vec<i32>, f64) {
    let amax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs())) as f64;
    let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
    let codes = x
        .iter()
        .map(|&v| round_half_even(v as f64 / scale).clamp(-127.0, 127.0) as i32)
        .collect();
    (codes, scale)
}

/// Row-wise [`quantize_acts`] over a `(p_rows, fan_in)` matrix: one scale
/// per row, so a row's codes depend only on that row's data.
pub fn quantize_acts_rows(x: &[f32], p_rows: usize) -> SwisResult<(Vec<i32>, Vec<f64>)> {
    if p_rows == 0 {
        return if x.is_empty() {
            Ok((Vec::new(), Vec::new()))
        } else {
            Err(SwisError::backend(format!("{} activations with 0 rows", x.len())))
        };
    }
    if x.len() % p_rows != 0 {
        return Err(SwisError::backend(format!(
            "{} activations do not split into {p_rows} rows",
            x.len()
        )));
    }
    let per = x.len() / p_rows;
    let mut codes = Vec::with_capacity(x.len());
    let mut scales = Vec::with_capacity(p_rows);
    for r in 0..p_rows {
        let row = &x[r * per..(r + 1) * per];
        let (c, s) = quantize_acts(row);
        codes.extend_from_slice(&c);
        scales.push(s);
    }
    Ok((codes, scales))
}

/// The naive per-group scalar loop — the pre-kernel baseline the bench
/// reports speedup against, and an independent oracle for the tests:
/// gathers each group's lanes and evaluates [`core::group_dot`].
pub fn naive_gemm(p: &PackedLayer, acts: &[i32], p_rows: usize) -> SwisResult<Vec<i64>> {
    let fan_in = p.fan_in();
    if acts.len() != p_rows * fan_in {
        return Err(SwisError::backend(format!(
            "acts {} != {} x {}",
            acts.len(),
            p_rows,
            fan_in
        )));
    }
    let k = p.n_filters();
    let gpf = p.groups_per_filter();
    let gs = p.group_size;
    let mut out = vec![0i64; p_rows * k];
    let mut lanes = vec![0i32; gs];
    for row in 0..p_rows {
        let arow = &acts[row * fan_in..(row + 1) * fan_in];
        for f in 0..k {
            let mut acc = 0i64;
            for gl in 0..gpf {
                core::gather_lanes(arow, gl, gs, &mut lanes);
                acc += core::group_dot(p, f * gpf + gl, &lanes);
            }
            out[row * k + f] = acc;
        }
    }
    Ok(out)
}

/// Plain fp32 GEMM over a filters-first dense weight matrix `(k, fan_in)`
/// — the native path for the `fp32` / truncation variants and the
/// float reference the packed path is toleranced against. Same row
/// blocking and scoped-thread partitioning as the packed kernel.
pub fn dense_gemm(
    w: &[f32],
    k: usize,
    fan_in: usize,
    acts: &[f32],
    p_rows: usize,
    n_threads: usize,
) -> SwisResult<Vec<f32>> {
    if w.len() != k * fan_in {
        return Err(SwisError::backend(format!("weights {} != {k} x {fan_in}", w.len())));
    }
    if acts.len() != p_rows * fan_in {
        return Err(SwisError::backend(format!("acts {} != {p_rows} x {fan_in}", acts.len())));
    }
    let mut out = vec![0f32; p_rows * k];
    par_rows(&mut out, p_rows, k, n_threads, |start, rows, o| {
        for r in 0..rows {
            let arow = &acts[(start + r) * fan_in..(start + r + 1) * fan_in];
            for f in 0..k {
                let wrow = &w[f * fan_in..(f + 1) * fan_in];
                let mut s = 0f64;
                for i in 0..fan_in {
                    s += arow[i] as f64 * wrow[i] as f64;
                }
                o[r * k + f] = s as f32;
            }
        }
    });
    Ok(out)
}

/// Symmetric int8 quantization of one tap patch into `codes` (same
/// half-to-even rule as [`quantize_acts`], no allocation); returns the
/// scale. The depthwise kernel quantizes each (output pixel, channel)
/// patch independently, so a pixel's result depends on nothing else in
/// the batch — the same composition-invariance contract as the per-row
/// GEMM path, one granularity finer.
pub fn quantize_taps(taps: &[f32], codes: &mut [i32]) -> f64 {
    let amax = taps.iter().fold(0.0f32, |m, &v| m.max(v.abs())) as f64;
    let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
    for (c, &v) in codes.iter_mut().zip(taps) {
        *c = round_half_even(v as f64 / scale).clamp(-127.0, 127.0) as i32;
    }
    scale
}

/// Gather one channel's `k x k` tap patch for output pixel `(oh, ow)`
/// from an NHWC image (out-of-map taps read zero — XLA-SAME padding).
#[inline]
fn gather_taps(
    img: &[f32],
    g: &ConvGeom,
    ch: usize,
    c: usize,
    oh: usize,
    ow: usize,
    taps: &mut [f32],
) {
    let hw = g.in_hw as isize;
    for kh in 0..g.k {
        let ih = (oh * g.stride + kh) as isize - g.pad_lo as isize;
        for kw in 0..g.k {
            let iw = (ow * g.stride + kw) as isize - g.pad_lo as isize;
            taps[kh * g.k + kw] = if ih < 0 || ih >= hw || iw < 0 || iw >= hw {
                0.0
            } else {
                img[(ih as usize * g.in_hw + iw as usize) * c + ch]
            };
        }
    }
}

/// A packed depthwise layer prepared for native execution: one filter
/// per channel, fan-in `k*k`, executed as a per-channel packed
/// bit-serial dot over the SAME prepared shift planes the GEMM kernel
/// uses ([`prepare_planes`]) — so bit sparsity drops work here exactly
/// as it does in the dense-conv path. This is the kernel MobileNet-v2's
/// 17 depthwise layers run on (the layers the SWIS systolic array
/// underutilizes, paper Sec. 3.2; in software the per-channel dot keeps
/// every plane walk useful).
#[derive(Clone, Debug)]
pub struct PreparedDepthwise {
    channels: usize,
    /// Per-channel fan-in (`k * k`).
    kk: usize,
    group_size: usize,
    groups_per_filter: usize,
    /// Dequantization scale of the packed weights (max|w| / 127).
    pub scale: f64,
    plane_ofs: Vec<u32>,
    planes: Vec<Plane>,
    /// Planes dropped empty at prepare time (see [`PreparedGemm`]).
    dropped_planes: u64,
    tune: TuneParams,
}

impl PreparedDepthwise {
    /// Prepare a `(channels, k*k)` filters-first packed layer.
    pub fn from_packed(p: &PackedLayer) -> SwisResult<PreparedDepthwise> {
        let (plane_ofs, planes, dropped_planes) = prepare_planes(p)?;
        Ok(PreparedDepthwise {
            channels: p.n_filters(),
            kk: p.fan_in(),
            group_size: p.group_size,
            groups_per_filter: p.groups_per_filter(),
            scale: p.scale,
            plane_ofs,
            planes,
            dropped_planes,
            tune: TuneParams::host_default(),
        })
    }

    pub fn channels(&self) -> usize {
        self.channels
    }

    /// Weight-MACs one full pass performs (for Mw/s reporting).
    pub fn macs(&self, batch: usize, g: &ConvGeom) -> u64 {
        (batch * g.out_hw * g.out_hw) as u64 * self.channels as u64 * self.kk as u64
    }

    /// Install machine-tuned kernel parameters (sanitized; the depthwise
    /// tile width follows the variant, so only the variant matters here).
    pub fn set_tune(&mut self, tp: TuneParams) {
        self.tune = tp.sanitized();
    }

    /// The kernel parameters dispatch currently uses.
    pub fn tune(&self) -> &TuneParams {
        &self.tune
    }

    fn check_geom(&self, g: &ConvGeom) -> SwisResult<()> {
        if g.k * g.k != self.kk || g.in_c != self.channels {
            return Err(SwisError::backend(format!(
                "depthwise geometry {}x{} over {} channels does not match packed ({} taps, {} channels)",
                g.k, g.k, g.in_c, self.kk, self.channels
            )));
        }
        Ok(())
    }

    /// Depthwise conv over an NHWC batch `(batch, in_hw, in_hw, c)` to
    /// `(batch, out_hw, out_hw, c)`. Each (pixel, channel) patch is int8
    /// quantized on its own scale, reduced through the prepared shift
    /// planes in exact integer arithmetic, and rescaled — bit-identical
    /// to [`naive_depthwise`] for any thread count and any
    /// [`KernelVariant`] (tap codes are int8, so the i32 overflow screen
    /// never applies here).
    pub fn forward(
        &self,
        x: &[f32],
        batch: usize,
        g: &ConvGeom,
        n_threads: usize,
    ) -> SwisResult<Vec<f32>> {
        self.check_geom(g)?;
        let c = self.channels;
        if x.len() != batch * g.in_hw * g.in_hw * c {
            return Err(SwisError::backend(format!(
                "input {} != {batch} x {} x {} x {c}",
                x.len(),
                g.in_hw,
                g.in_hw
            )));
        }
        let variant = if simd::force_scalar() { KernelVariant::Scalar } else { self.tune.variant };
        let use_mask = self.tune.act_mask;
        let obs_on = obs::counters_on();
        let tally = std::sync::Mutex::new(ExecTally::default());
        let o = g.out_hw;
        let rows = batch * o * o;
        let mut out = vec![0f32; rows * c];
        par_rows(&mut out, rows, c, n_threads, |start, nrows, slice| {
            let mut t = if obs_on { Some(ExecTally::default()) } else { None };
            if variant == KernelVariant::Scalar {
                self.forward_rows_scalar(x, g, start, nrows, slice, use_mask, t.as_mut());
            } else {
                self.forward_rows_blocked(x, g, start, nrows, slice, variant, use_mask, t.as_mut());
            }
            if let Some(t) = t {
                tally.lock().unwrap().add(&t);
            }
        });
        if obs_on {
            let mut t = tally.into_inner().unwrap();
            t.dispatch[variant.index()] += 1;
            if variant == KernelVariant::Scalar && self.tune.variant != KernelVariant::Scalar {
                t.scalar_demotions += 1;
            }
            obs::record_exec(&t);
        }
        Ok(out)
    }

    /// Scalar single-thread core over output pixels `[start, start+nrows)`.
    ///
    /// Sparsity accounting here is a coarse approximation: [`Self::dot`]
    /// derives its zero-tap mask per (pixel, channel) inside the hot
    /// loop, so this path charges every prepared plane as visited (one
    /// full walk per pixel) and reports no masked-plane split — the
    /// blocked core is the accounted path. Dispatch counts and
    /// prepare-time dropped planes stay exact.
    #[allow(clippy::too_many_arguments)]
    fn forward_rows_scalar(
        &self,
        x: &[f32],
        g: &ConvGeom,
        start: usize,
        nrows: usize,
        slice: &mut [f32],
        use_mask: bool,
        tally: Option<&mut ExecTally>,
    ) {
        let c = self.channels;
        let o = g.out_hw;
        let mut taps = vec![0f32; self.kk];
        let mut codes = vec![0i32; self.kk];
        let img_len = g.in_hw * g.in_hw * c;
        if let Some(t) = tally {
            t.tiles_total += nrows as u64;
            t.planes_visited += self.planes.len() as u64 * nrows as u64;
            t.planes_dropped_empty += self.dropped_planes * nrows as u64;
        }
        for r in 0..nrows {
            let pix = start + r;
            let b = pix / (o * o);
            let oh = (pix / o) % o;
            let ow = pix % o;
            let img = &x[b * img_len..(b + 1) * img_len];
            for ch in 0..c {
                gather_taps(img, g, ch, c, oh, ow, &mut taps);
                let s = quantize_taps(&taps, &mut codes);
                let acc = self.dot(ch, &codes, use_mask);
                slice[r * c + ch] = (acc as f64 * (self.scale * s)) as f32;
            }
        }
    }

    /// Vector single-thread core: pixel tiles of the variant width. Per
    /// (tile, channel), each pixel's tap patch is gathered + quantized
    /// into a transposed codes scratch (`ct[tap * width + pixel]`, tail
    /// pixels zero-padded), reduced with one [`simd::accumulate_tile`]
    /// call over all the channel's groups, and rescaled per pixel. The
    /// per-(pixel, channel) integer math is unchanged, so results stay
    /// bit-identical to the scalar dot.
    #[allow(clippy::too_many_arguments)]
    fn forward_rows_blocked(
        &self,
        x: &[f32],
        g: &ConvGeom,
        start: usize,
        nrows: usize,
        slice: &mut [f32],
        variant: KernelVariant,
        use_mask: bool,
        mut tally: Option<&mut ExecTally>,
    ) {
        let c = self.channels;
        let o = g.out_hw;
        let gs = self.group_size;
        let gpf = self.groups_per_filter;
        let w = variant.width();
        let img_len = g.in_hw * g.in_hw * c;
        let mut taps = vec![0f32; self.kk];
        let mut codes = vec![0i32; self.kk];
        // scratch spans the full group range (gpf * gs >= kk); columns
        // past kk are zero padding with no mask bits pointing at them
        let mut ct = vec![0i32; gpf * gs * w];
        let mut scales = vec![0f64; w];
        let mut nzc = vec![0i32; self.kk];
        let mut masks = vec![0xFFFFu16; gpf];
        let ones = vec![0xFFFFu16; gpf];
        if let Some(t) = tally.as_deref_mut() {
            // every pixel tile walks every channel's group range once
            t.planes_dropped_empty += self.dropped_planes * nrows.div_ceil(w) as u64;
        }
        let mut t0 = 0usize;
        while t0 < nrows {
            let tb = w.min(nrows - t0);
            if tb < w {
                // zero the pad-pixel columns once; full tiles overwrite
                // every real pixel's codes each channel
                ct.fill(0);
            }
            for ch in 0..c {
                if use_mask {
                    nzc.fill(0);
                }
                for r in 0..tb {
                    let pix = start + t0 + r;
                    let b = pix / (o * o);
                    let oh = (pix / o) % o;
                    let ow = pix % o;
                    let img = &x[b * img_len..(b + 1) * img_len];
                    gather_taps(img, g, ch, c, oh, ow, &mut taps);
                    scales[r] = quantize_taps(&taps, &mut codes);
                    if use_mask {
                        for (t, &code) in codes.iter().enumerate() {
                            ct[t * w + r] = code;
                            nzc[t] |= code;
                        }
                    } else {
                        for (t, &code) in codes.iter().enumerate() {
                            ct[t * w + r] = code;
                        }
                    }
                }
                let masked = use_mask && fold_zero_lane_masks(&nzc, self.kk, gs, &mut masks);
                let tmasks: &[u16] = if masked { &masks } else { &ones };
                if let Some(t) = tally.as_deref_mut() {
                    t.tiles_total += 1;
                    let gb = ch * gpf;
                    if masked {
                        t.tiles_masked += 1;
                        t.lanes_masked += count_lanes_masked(&masks, self.kk, gs);
                        // one "filter" (this channel) walks its groups once
                        count_plane_walk(
                            &self.planes,
                            &self.plane_ofs,
                            1,
                            gpf,
                            gb,
                            gpf,
                            tmasks,
                            1,
                            t,
                        );
                    } else {
                        t.planes_visited +=
                            (self.plane_ofs[gb + gpf] - self.plane_ofs[gb]) as u64;
                    }
                }
                let mut acc = [0i64; simd::MAX_ROW_BLOCK];
                simd::accumulate_tile(
                    variant,
                    &self.planes,
                    &self.plane_ofs,
                    ch * gpf,
                    gpf,
                    gs,
                    &ct,
                    w,
                    0,
                    tmasks,
                    &mut acc[..w],
                );
                for r in 0..tb {
                    slice[(t0 + r) * c + ch] = (acc[r] as f64 * (self.scale * scales[r])) as f32;
                }
            }
            t0 += tb;
        }
    }

    /// Exact integer per-channel dot over the prepared planes. With
    /// `use_mask`, tap codes that quantized to 0 (SAME-padding borders,
    /// dead inputs) are masked out of the plane walk — one `kk`-wide
    /// scan per call, then the same AND/skip as the tile paths.
    fn dot(&self, ch: usize, codes: &[i32], use_mask: bool) -> i64 {
        let gs = self.group_size;
        let mut acc = 0i64;
        for gl in 0..self.groups_per_filter {
            let g = ch * self.groups_per_filter + gl;
            let a0 = gl * gs;
            let lm = if use_mask {
                let valid = codes.len().saturating_sub(a0).min(gs);
                let mut bits = 0u16;
                for (i, &cd) in codes[a0..a0 + valid].iter().enumerate() {
                    if cd != 0 {
                        bits |= 1 << i;
                    }
                }
                bits
            } else {
                0xFFFF
            };
            let lo = self.plane_ofs[g] as usize;
            let hi = self.plane_ofs[g + 1] as usize;
            for pl in &self.planes[lo..hi] {
                let pos = pl.pos & lm;
                let neg = pl.neg & lm;
                if (pos | neg) == 0 {
                    continue;
                }
                let mut partial = 0i64;
                let mut m = pos;
                while m != 0 {
                    let lane = m.trailing_zeros() as usize;
                    m &= m - 1;
                    partial += codes[a0 + lane] as i64;
                }
                let mut m = neg;
                while m != 0 {
                    let lane = m.trailing_zeros() as usize;
                    m &= m - 1;
                    partial -= codes[a0 + lane] as i64;
                }
                acc += partial << pl.shift;
            }
        }
        acc
    }
}

/// The naive per-channel depthwise reference: gathers each channel's
/// group lanes and evaluates [`core::group_dot`] — an independent oracle
/// for [`PreparedDepthwise::forward`] (identical quantization, identical
/// integer semantics, single-threaded).
pub fn naive_depthwise(
    p: &PackedLayer,
    x: &[f32],
    batch: usize,
    g: &ConvGeom,
) -> SwisResult<Vec<f32>> {
    let c = p.n_filters();
    let kk = p.fan_in();
    if g.k * g.k != kk || g.in_c != c {
        return Err(SwisError::backend("depthwise geometry does not match packed layer"));
    }
    if x.len() != batch * g.in_hw * g.in_hw * c {
        return Err(SwisError::backend(format!(
            "input {} != {batch} x {} x {} x {c}",
            x.len(),
            g.in_hw,
            g.in_hw
        )));
    }
    let o = g.out_hw;
    let gs = p.group_size;
    let gpf = p.groups_per_filter();
    let img_len = g.in_hw * g.in_hw * c;
    let mut taps = vec![0f32; kk];
    let mut codes = vec![0i32; kk];
    let mut lanes = vec![0i32; gs];
    let mut out = vec![0f32; batch * o * o * c];
    for pix in 0..batch * o * o {
        let b = pix / (o * o);
        let oh = (pix / o) % o;
        let ow = pix % o;
        let img = &x[b * img_len..(b + 1) * img_len];
        for ch in 0..c {
            gather_taps(img, g, ch, c, oh, ow, &mut taps);
            let s = quantize_taps(&taps, &mut codes);
            let mut acc = 0i64;
            for gl in 0..gpf {
                core::gather_lanes(&codes, gl, gs, &mut lanes);
                acc += core::group_dot(p, ch * gpf + gl, &lanes);
            }
            out[pix * c + ch] = (acc as f64 * (p.scale * s)) as f32;
        }
    }
    Ok(out)
}

/// Dense fp32 depthwise conv over a filters-first `(c, k*k)` weight
/// matrix — the native path for the fp32 / truncation variants. Same
/// pixel partitioning as the packed kernel; f64 accumulation.
pub fn dense_depthwise(
    w: &[f32],
    c: usize,
    x: &[f32],
    batch: usize,
    g: &ConvGeom,
    n_threads: usize,
) -> SwisResult<Vec<f32>> {
    let kk = g.k * g.k;
    if w.len() != c * kk {
        return Err(SwisError::backend(format!("weights {} != {c} x {kk}", w.len())));
    }
    if g.in_c != c || x.len() != batch * g.in_hw * g.in_hw * c {
        return Err(SwisError::backend(format!(
            "input {} != {batch} x {} x {} x {c}",
            x.len(),
            g.in_hw,
            g.in_hw
        )));
    }
    let o = g.out_hw;
    let rows = batch * o * o;
    let mut out = vec![0f32; rows * c];
    par_rows(&mut out, rows, c, n_threads, |start, nrows, slice| {
        let mut taps = vec![0f32; kk];
        let img_len = g.in_hw * g.in_hw * c;
        for r in 0..nrows {
            let pix = start + r;
            let b = pix / (o * o);
            let oh = (pix / o) % o;
            let ow = pix % o;
            let img = &x[b * img_len..(b + 1) * img_len];
            for ch in 0..c {
                gather_taps(img, g, ch, c, oh, ow, &mut taps);
                let wrow = &w[ch * kk..(ch + 1) * kk];
                let mut s = 0f64;
                for i in 0..kk {
                    s += taps[i] as f64 * wrow[i] as f64;
                }
                slice[r * c + ch] = s as f32;
            }
        }
    });
    Ok(out)
}

/// Partition a `(p_rows, k)` output buffer into contiguous row ranges and
/// run `f(start_row, n_rows, out_slice)` on scoped threads — the ONE
/// row-parallel harness for both the packed and dense kernels. Disjoint
/// output slices, no locks; `n_threads <= 1` runs inline. Results are
/// identical for any thread count because partitioning never changes
/// per-row work.
fn par_rows<T: Send>(
    out: &mut [T],
    p_rows: usize,
    k: usize,
    n_threads: usize,
    f: impl Fn(usize, usize, &mut [T]) + Sync,
) {
    let nt = n_threads.clamp(1, p_rows.max(1));
    if nt <= 1 {
        f(0, p_rows, out);
        return;
    }
    let chunk = p_rows.div_ceil(nt);
    let f = &f; // share across scoped threads
    std::thread::scope(|s| {
        let mut rest: &mut [T] = out;
        let mut r0 = 0usize;
        while r0 < p_rows {
            let take = chunk.min(p_rows - r0);
            let tmp = std::mem::take(&mut rest);
            let (slice, rr) = tmp.split_at_mut(take * k);
            rest = rr;
            let start = r0;
            s.spawn(move || f(start, take, slice));
            r0 += take;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize, Alpha, QuantConfig};
    use crate::util::rng::Rng;

    fn setup(
        seed: u64,
        k: usize,
        fan_in: usize,
        n: usize,
        gs: usize,
        consecutive: bool,
    ) -> (PackedLayer, Vec<i32>, usize) {
        let mut rng = Rng::new(seed);
        let w = rng.normal_vec(k * fan_in, 0.0, 0.06);
        let cfg = QuantConfig { n_shifts: n, group_size: gs, alpha: Alpha::ONE, consecutive };
        let p = quantize(&w, &[k, fan_in], &cfg).unwrap();
        let rows = 13usize;
        let acts: Vec<i32> =
            (0..rows * fan_in).map(|_| rng.range_u64(0, 255) as i32 - 128).collect();
        (p, acts, rows)
    }

    #[test]
    fn prepared_matches_naive_exactly() {
        for (seed, k, fi, n, gs, cons) in
            [(1, 12, 36, 3, 4, false), (2, 8, 30, 2, 4, false), (3, 8, 32, 4, 16, true)]
        {
            let (p, acts, rows) = setup(seed, k, fi, n, gs, cons);
            let prep = PreparedGemm::from_packed(&p).unwrap();
            let fast = prep.gemm(&acts, rows, 1).unwrap();
            let slow = naive_gemm(&p, &acts, rows).unwrap();
            assert_eq!(fast, slow, "k={k} fi={fi} n={n} gs={gs}");
        }
    }

    #[test]
    fn thread_count_invariant() {
        let (p, acts, rows) = setup(7, 16, 48, 3, 4, false);
        let prep = PreparedGemm::from_packed(&p).unwrap();
        let t1 = prep.gemm(&acts, rows, 1).unwrap();
        for nt in [2usize, 3, 8, 32] {
            assert_eq!(prep.gemm(&acts, rows, nt).unwrap(), t1, "nt={nt}");
        }
    }

    #[test]
    fn oversized_activations_fall_back_to_scalar_exactly() {
        // |act| beyond MAX_SIMD_ACT must demote to the 64-bit-partial
        // scalar walk and still match the gather-based oracle
        let (p, mut acts, rows) = setup(8, 6, 24, 3, 4, false);
        acts[0] = (MAX_SIMD_ACT + 1) as i32;
        acts[5] = -((MAX_SIMD_ACT as i32) + 77);
        let prep = PreparedGemm::from_packed(&p).unwrap();
        assert_eq!(prep.effective_tune(&acts).variant, KernelVariant::Scalar);
        let fast = prep.gemm(&acts, rows, 2).unwrap();
        assert_eq!(fast, naive_gemm(&p, &acts, rows).unwrap());
    }

    #[test]
    fn tune_params_are_sanitized_on_install() {
        let (p, acts, rows) = setup(15, 6, 20, 2, 4, false);
        let mut prep = PreparedGemm::from_packed(&p).unwrap();
        let base = prep.gemm(&acts, rows, 1).unwrap();
        let mut tp = TuneParams::host_default();
        tp.row_block = 5000; // clamped to MAX_ROW_BLOCK (and width-aligned)
        tp.group_chunk = 0; // floored to 1
        prep.set_tune(tp);
        assert!(prep.tune().row_block <= simd::MAX_ROW_BLOCK);
        assert!(prep.tune().group_chunk >= 1);
        assert_eq!(prep.gemm(&acts, rows, 1).unwrap(), base);
    }

    #[test]
    fn f32_path_tracks_dequantized_reference() {
        let (p, _, _) = setup(9, 8, 27, 4, 4, false);
        let prep = PreparedGemm::from_packed(&p).unwrap();
        let mut rng = Rng::new(10);
        let rows = 6usize;
        let acts: Vec<f32> = (0..rows * 27).map(|_| rng.range_f64(0.0, 1.0) as f32).collect();
        let got = prep.gemm_f32(&acts, rows, 1).unwrap();
        // reference: per-row int8-quantized acts x dequantized weights
        let (codes, scales) = quantize_acts_rows(&acts, rows).unwrap();
        let deq = p.to_f64();
        for r in 0..rows {
            for f in 0..8 {
                let want: f64 = (0..27)
                    .map(|i| codes[r * 27 + i] as f64 * scales[r] * deq[f * 27 + i])
                    .sum();
                assert!(
                    (got[r * 8 + f] as f64 - want).abs() < 1e-4,
                    "({r},{f}): {} vs {want}",
                    got[r * 8 + f]
                );
            }
        }
    }

    #[test]
    fn f32_rows_are_batch_composition_invariant() {
        // a row's result must not depend on what else is in the batch
        let (p, _, _) = setup(12, 8, 27, 3, 4, false);
        let prep = PreparedGemm::from_packed(&p).unwrap();
        let mut rng = Rng::new(14);
        let a: Vec<f32> = (0..27).map(|_| rng.range_f64(0.0, 1.0) as f32).collect();
        let b: Vec<f32> = (0..27).map(|_| rng.range_f64(0.0, 50.0) as f32).collect();
        let alone = prep.gemm_f32(&a, 1, 1).unwrap();
        let mut both = a.clone();
        both.extend_from_slice(&b);
        let paired = prep.gemm_f32(&both, 2, 1).unwrap();
        assert_eq!(alone[..], paired[..8], "row result changed when co-batched");
    }

    #[test]
    fn adversarial_pad_lane_mask_bits_are_dropped_not_read() {
        // the quantizer zeroes pad-lane masks, but PackedLayer's fields
        // are pub: a hand-built layer with a set bit on a pad lane must
        // still match the gather-based oracle (pad act = 0), not read
        // past fan_in or panic
        let p = PackedLayer {
            shape: vec![2, 3], // fan_in 3, group 4 -> lane 3 of each group is padding
            group_size: 4,
            n_shifts: 2,
            scale: 1.0,
            shifts: vec![0, 2, 1, 3],
            masks: vec![
                1, 0, 0, 1, 1, 1, 0, 1, // filter 0: pad lane has bit set in plane 1
                0, 1, 1, 0, 1, 0, 1, 1, // filter 1: pad lane set in both planes
            ],
            signs: vec![1, -1, 1, -1, -1, 1, 1, 1],
            consecutive: false,
            filter_shifts: None,
        };
        p.validate().unwrap();
        let prep = PreparedGemm::from_packed(&p).unwrap();
        let acts: Vec<i32> = vec![10, -20, 30, 40, -50, 60]; // 2 rows x fan_in 3
        let fast = prep.gemm(&acts, 2, 1).unwrap();
        assert_eq!(fast, naive_gemm(&p, &acts, 2).unwrap());
    }

    #[test]
    fn rejects_bad_shapes_and_groups() {
        let (p, acts, rows) = setup(11, 8, 32, 2, 4, false);
        let prep = PreparedGemm::from_packed(&p).unwrap();
        assert!(prep.gemm(&acts[..10], rows, 1).is_err());
        let mut big = p.clone();
        big.group_size = 32; // beyond the bitmask width
        let e = PreparedGemm::from_packed(&big).unwrap_err();
        assert!(matches!(e, SwisError::Config(_)), "got {e:?}");
    }

    fn dw_setup(
        seed: u64,
        c: usize,
        n: usize,
        gs: usize,
        cons: bool,
    ) -> (PackedLayer, Vec<f32>, ConvGeom) {
        let mut rng = Rng::new(seed);
        let w = rng.normal_vec(c * 9, 0.0, 0.4);
        let cfg = QuantConfig { n_shifts: n, group_size: gs, alpha: Alpha::ONE, consecutive: cons };
        let p = quantize(&w, &[c, 9], &cfg).unwrap();
        let g = ConvGeom::same(6, c, 3, 1).unwrap();
        let x: Vec<f32> = (0..2 * 6 * 6 * c).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
        (p, x, g)
    }

    #[test]
    fn depthwise_matches_naive_across_configs() {
        // G spans ragged (4 over fan-in 9), exact (9), and oversized (16)
        for (seed, c, n, gs, cons) in
            [(21, 8, 3, 4, false), (22, 5, 2, 9, false), (23, 6, 4, 16, false), (24, 8, 3, 4, true)]
        {
            let (p, x, g) = dw_setup(seed, c, n, gs, cons);
            let prep = PreparedDepthwise::from_packed(&p).unwrap();
            let fast = prep.forward(&x, 2, &g, 1).unwrap();
            let slow = naive_depthwise(&p, &x, 2, &g).unwrap();
            assert_eq!(fast, slow, "c={c} n={n} gs={gs} cons={cons}");
            assert_eq!(fast.len(), 2 * 6 * 6 * c);
        }
    }

    #[test]
    fn depthwise_thread_count_invariant() {
        let (p, x, g) = dw_setup(25, 8, 3, 4, false);
        let prep = PreparedDepthwise::from_packed(&p).unwrap();
        let t1 = prep.forward(&x, 2, &g, 1).unwrap();
        for nt in [2usize, 5, 16] {
            assert_eq!(prep.forward(&x, 2, &g, nt).unwrap(), t1, "nt={nt}");
        }
    }

    #[test]
    fn depthwise_stride2_geometry_and_padding() {
        // 4x4 map, k=3, s=2, pad_lo 0: same asymmetric padding as im2col
        let mut rng = Rng::new(26);
        let c = 4usize;
        let w = rng.normal_vec(c * 9, 0.0, 0.3);
        let cfg = QuantConfig { n_shifts: 4, group_size: 4, alpha: Alpha::ONE, consecutive: false };
        let p = quantize(&w, &[c, 9], &cfg).unwrap();
        let g = ConvGeom::same(4, c, 3, 2).unwrap();
        assert_eq!((g.out_hw, g.pad_lo), (2, 0));
        let x: Vec<f32> = (0..4 * 4 * c).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
        let prep = PreparedDepthwise::from_packed(&p).unwrap();
        let fast = prep.forward(&x, 1, &g, 2).unwrap();
        assert_eq!(fast, naive_depthwise(&p, &x, 1, &g).unwrap());
        assert_eq!(fast.len(), 2 * 2 * c);
    }

    #[test]
    fn depthwise_rejects_mismatched_geometry() {
        let (p, x, _) = dw_setup(27, 8, 3, 4, false);
        let prep = PreparedDepthwise::from_packed(&p).unwrap();
        let bad_c = ConvGeom::same(6, 7, 3, 1).unwrap(); // 7 != 8 channels
        assert!(prep.forward(&x, 2, &bad_c, 1).is_err());
        let bad_k = ConvGeom::same(6, 8, 5, 1).unwrap(); // 25 taps != 9
        assert!(prep.forward(&x, 2, &bad_k, 1).is_err());
    }

    #[test]
    fn dense_depthwise_matches_scalar_taps() {
        // identity-ish check: 1-tap-hot filters pick out the center tap
        let c = 3usize;
        let mut w = vec![0f32; c * 9];
        for ch in 0..c {
            w[ch * 9 + 4] = 1.0; // center of the 3x3 kernel
        }
        let g = ConvGeom::same(4, c, 3, 1).unwrap();
        let x: Vec<f32> = (0..4 * 4 * c).map(|v| v as f32).collect();
        let y = dense_depthwise(&w, c, &x, 1, &g, 2).unwrap();
        // stride 1, pad 1: center tap of pixel (oh, ow) IS the input pixel
        assert_eq!(y, x);
        assert_eq!(
            dense_depthwise(&w, c, &x, 1, &g, 1).unwrap(),
            dense_depthwise(&w, c, &x, 1, &g, 4).unwrap()
        );
    }

    fn sparsify(acts: &mut [i32]) {
        // zero 3 of every 4 columns so the zero-lane fold engages
        for (i, a) in acts.iter_mut().enumerate() {
            if i % 4 != 0 {
                *a = 0;
            }
        }
    }

    #[test]
    fn sparsity_counters_reconcile_on_the_scalar_path() {
        let _g = crate::obs::test_level_guard();
        crate::obs::set_level(crate::obs::ObsLevel::Counters);
        let (p, mut acts, rows) = setup(31, 8, 32, 3, 4, false);
        sparsify(&mut acts);
        let mut prep = PreparedGemm::from_packed(&p).unwrap();
        let mut tp = TuneParams::host_default();
        tp.variant = KernelVariant::Scalar;
        prep.set_tune(tp);
        let before = obs::current();
        let out = prep.gemm(&acts, rows, 1).unwrap();
        let d = obs::current().diff(&before);
        crate::obs::set_level(crate::obs::ObsLevel::Off);
        // accounting must never perturb results
        assert_eq!(out, naive_gemm(&p, &acts, rows).unwrap());
        // every row block walks the full plane list (+ the prepare-dropped
        // planes it never sees): visited + masked + dropped reconciles
        let blocks = rows.div_ceil(ROW_BLOCK) as u64;
        assert_eq!(d.planes_total(), blocks * (prep.planes.len() as u64 + prep.dropped_planes));
        assert!(d.lanes_masked > 0, "sparse acts must mask lanes: {d:?}");
        assert!(d.planes_skipped_masked > 0, "sparse acts must skip planes: {d:?}");
        assert_eq!(d.tiles_total, blocks);
        assert_eq!(d.tiles_masked, blocks);
        assert_eq!(d.dispatch[KernelVariant::Scalar.index()], 1);
        assert_eq!(d.scalar_demotions, 0);
    }

    #[test]
    fn sparsity_counters_reconcile_on_the_blocked_path() {
        if simd::force_scalar() {
            return; // env forces the scalar walk; nothing blocked to count
        }
        let _g = crate::obs::test_level_guard();
        crate::obs::set_level(crate::obs::ObsLevel::Counters);
        let (p, mut acts, rows) = setup(32, 8, 32, 3, 4, false);
        sparsify(&mut acts);
        let prep = PreparedGemm::from_packed(&p).unwrap();
        let tune = prep.effective_tune(&acts);
        assert_ne!(tune.variant, KernelVariant::Scalar);
        let before = obs::current();
        let out = prep.gemm(&acts, rows, 1).unwrap();
        let d = obs::current().diff(&before);
        crate::obs::set_level(crate::obs::ObsLevel::Off);
        assert_eq!(out, naive_gemm(&p, &acts, rows).unwrap());
        // per row tile every group's plane list is walked once per
        // sub-tile, so the reconciliation scales by the sub-tile count
        let w = tune.variant.width();
        let rbp = tune.row_block.max(w);
        let mut walks = 0u64;
        let mut r0 = 0usize;
        while r0 < rows {
            let rb = rbp.min(rows - r0);
            walks += rb.div_ceil(w) as u64;
            r0 += rb;
        }
        assert_eq!(d.planes_total(), walks * (prep.planes.len() as u64 + prep.dropped_planes));
        assert!(d.lanes_masked > 0, "sparse acts must mask lanes: {d:?}");
        assert_eq!(d.dispatch[tune.variant.index()], 1);
    }

    #[test]
    fn counters_off_records_nothing() {
        let _g = crate::obs::test_level_guard();
        crate::obs::set_level(crate::obs::ObsLevel::Off);
        let (p, acts, rows) = setup(33, 6, 24, 3, 4, false);
        let prep = PreparedGemm::from_packed(&p).unwrap();
        let before = obs::current();
        prep.gemm(&acts, rows, 2).unwrap();
        assert_eq!(obs::current().diff(&before), ExecTally::default());
    }

    #[test]
    fn depthwise_counters_record_dispatch_and_planes() {
        let _g = crate::obs::test_level_guard();
        crate::obs::set_level(crate::obs::ObsLevel::Counters);
        let (p, x, g) = dw_setup(34, 8, 3, 4, false);
        let prep = PreparedDepthwise::from_packed(&p).unwrap();
        let before = obs::current();
        let out = prep.forward(&x, 2, &g, 1).unwrap();
        let d = obs::current().diff(&before);
        crate::obs::set_level(crate::obs::ObsLevel::Off);
        assert_eq!(out, naive_depthwise(&p, &x, 2, &g).unwrap());
        assert!(d.planes_visited > 0);
        assert_eq!(d.dispatch.iter().sum::<u64>(), 1);
    }

    #[test]
    fn dense_gemm_matches_scalar() {
        let mut rng = Rng::new(5);
        let (k, fi, rows) = (6usize, 17usize, 9usize);
        let w: Vec<f32> = (0..k * fi).map(|_| rng.normal_ms(0.0, 0.1) as f32).collect();
        let a: Vec<f32> = (0..rows * fi).map(|_| rng.normal_ms(0.0, 1.0) as f32).collect();
        let one = dense_gemm(&w, k, fi, &a, rows, 1).unwrap();
        let four = dense_gemm(&w, k, fi, &a, rows, 4).unwrap();
        assert_eq!(one, four);
        let want = (0..fi).map(|i| a[i] as f64 * w[i] as f64).sum::<f64>() as f32;
        assert!((one[0] - want).abs() < 1e-4);
    }
}
