//! `swis` — leader entrypoint for the SWIS reproduction.
//!
//! Subcommands:
//!   quantize  — SWIS/SWIS-C/truncation quantization report for a network
//!   simulate  — systolic-array simulation: cycles, F/s, F/J, DRAM traffic
//!   plan      — run the offline pipeline once (quantize + schedule +
//!               pack + bind) and emit a versioned .swisplan artifact
//!   serve     — start a worker pool and drive a synthetic request load
//!               (--net picks any zoo model on the native backend;
//!               --plan warms workers from a .swisplan, zero quantization)
//!   loadgen   — SLO sweep (workers x policy x arrival rate), emits
//!               BENCH_serving.json at the repo root (--plan supported)
//!   eval      — zoo accuracy/compression sweep (nets x schemes x bits on
//!               the native executor), emits BENCH_accuracy.json
//!               (--plan evaluates a shipped plan's exact operands)
//!   tune      — bench-driven kernel autotune on the local CPU (sweep
//!               SIMD variant x row-block x chunk x threads over a real
//!               prepared operand; -o persists the winner into the
//!               .swisplan); --alpha runs the MSE++ alpha sweep instead
//!   prob      — Fig. 2 lossless-quantization probability curves
//!   info      — model zoo + accelerator configuration summary
//!
//! Examples:
//!   swis quantize --net resnet18 --shifts 3 --group 4
//!   swis simulate --net mobilenet_v2 --scheme swis --shifts 3.5 --pe ds
//!   swis plan --net tinycnn --scheme swis_c -o plan.swisplan
//!   swis serve --plan plan.swisplan --requests 256 --workers 4
//!   swis serve --requests 256 --variants fp32,swis@3 --backend native \
//!              --workers 4 --queue-depth 256 --priority batch --rate 300
//!   swis serve --net mobilenet_v2 --requests 8 --backend native
//!   swis loadgen --workers 1,2,4 --rates 150,300 --duration-ms 400
//!   swis eval --nets tinycnn,mobilenet_v2 --schemes swis,wgt_trunc --bits 3,4
//!   swis prob

use anyhow::{bail, Context, Result};
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use swis::analysis::fig2_rows;
use swis::api::{Engine, EngineConfig, EnginePlan, Scheme};
use swis::arch::pe::PeKind;
use swis::coordinator::{
    BatchPolicy, InferRequest, PoolConfig, Priority, VariantSpec, WorkerPool,
};
use swis::edge::{EdgeClient, EdgeConfig, EdgeServer, PlanCache};
use swis::flags;
use swis::loadgen::{
    exp_gap, gen_images_mode, run_scenario_inproc, run_scenario_tcp, run_sweep, run_sweep_with,
    write_bench_json, Arrival, ProbeMode, ScenarioConfig, ScenarioKind, SweepConfig, SweepPoint,
};
use swis::nets::{all_networks, by_name, surrogate_weights};
use swis::quant::truncation::truncate_weights;
use swis::runtime::{create_factory, BackendFactory, NativeFactory};
use swis::schedule::quantize_or_schedule;
use swis::sim::{simulate_network, ArrayConfig, ExecScheme, SchemeKind};
use swis::util::cli;
use swis::util::rng::Rng;
use swis::util::stats::rmse;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    // one flag table (swis::flags) feeds the parser's value-key list,
    // typo validation, and the generated --help
    let args = cli::parse(argv, &flags::value_keys())?;
    flags::validate(&args)?;
    flags::setup_obs(&args)?;
    if args.flag("help") {
        print!("{}", flags::help(args.subcommand()));
        return Ok(());
    }
    match args.subcommand() {
        Some("quantize") => cmd_quantize(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("plan") => cmd_plan(&args),
        Some("serve") => cmd_serve(&args),
        Some("loadgen") => cmd_loadgen(&args),
        Some("eval") => cmd_eval(&args),
        Some("prob") => cmd_prob(),
        Some("tune") => cmd_tune(&args),
        Some("info") => cmd_info(),
        Some("lint") => cmd_lint(&args),
        Some("verify-plan") => cmd_verify_plan(&args),
        Some(other) => {
            let known = "quantize simulate plan serve loadgen eval tune prob info lint verify-plan";
            bail!("unknown subcommand '{other}' (try: {known})")
        }
        None => {
            print!("{}", flags::help(None));
            Ok(())
        }
    }
}

fn pe_kind(s: &str) -> Result<PeKind> {
    Ok(match s {
        "ss" | "single" => PeKind::SingleShift,
        "ds" | "double" => PeKind::DoubleShift,
        "fixed" | "fx" => PeKind::Fixed,
        _ => bail!("--pe expects ss|ds|fixed, got '{s}'"),
    })
}

fn scheme_of(s: &str, shifts: f64) -> Result<ExecScheme> {
    Ok(match s {
        "swis" => ExecScheme::swis(shifts),
        "swis_c" | "swisc" => ExecScheme::swis_c(shifts),
        "wgt_trunc" | "wgt" => ExecScheme::new(SchemeKind::WgtTrunc, shifts),
        "act_trunc" | "act" => ExecScheme::new(SchemeKind::ActTrunc, shifts),
        "fixed8" | "fx8" => ExecScheme::new(SchemeKind::Fixed8, 8.0),
        "bitfusion" | "bf" => ExecScheme::new(SchemeKind::BitFusion4x8, 4.0),
        _ => bail!("--scheme expects swis|swis_c|wgt_trunc|act_trunc|fixed8|bitfusion"),
    })
}

fn cmd_quantize(args: &cli::Args) -> Result<()> {
    let net_name = args.get_or("net", "resnet18");
    let net = by_name(net_name).with_context(|| format!("unknown network '{net_name}'"))?;
    let shifts = args.get_f64("shifts", 3.0)?;
    let group = args.get_usize("group", 4)?;
    let seed = args.get_usize("seed", 1)? as u64;
    // --save DIR writes one bit-packed .swis container per layer
    let save_dir = args.get("save").map(std::path::PathBuf::from);
    if let Some(d) = &save_dir {
        std::fs::create_dir_all(d)?;
    }

    println!(
        "# SWIS quantization report — {} (shifts={shifts}, group={group})",
        net.name
    );
    println!(
        "{:<22} {:>10} {:>12} {:>12} {:>12} {:>9}",
        "layer", "weights", "rmse(SWIS)", "rmse(SWIS-C)", "rmse(trunc)", "compr."
    );
    for layer in &net.layers {
        let w = surrogate_weights(layer, seed);
        let shape = layer.weight_shape();
        let ps = quantize_or_schedule(&w, &shape, shifts, group, false, swis::quant::Alpha::ONE)?;
        let pc = quantize_or_schedule(&w, &shape, shifts, group, true, swis::quant::Alpha::ONE)?;
        let es = rmse(&w, &ps.to_f64());
        let ec = rmse(&w, &pc.to_f64());
        let et = rmse(&w, &truncate_weights(&w, shifts.round() as usize));
        println!(
            "{:<22} {:>10} {:>12.5} {:>12.5} {:>12.5} {:>8.2}x",
            layer.name,
            layer.n_weights(),
            es,
            ec,
            et,
            ps.compression_ratio()
        );
        if let Some(d) = &save_dir {
            let bytes = swis::quant::serialize::to_bytes(&ps)?;
            let path = d.join(format!("{}.swis", layer.name.replace('/', "_")));
            std::fs::write(&path, &bytes)?;
        }
    }
    if let Some(d) = &save_dir {
        println!("wrote packed .swis containers to {}", d.display());
    }
    Ok(())
}

fn cmd_simulate(args: &cli::Args) -> Result<()> {
    let net_name = args.get_or("net", "resnet18");
    let mut net = by_name(net_name).with_context(|| format!("unknown network '{net_name}'"))?;
    if args.flag("fc") {
        net = net.with_fc(); // include FC heads (paper future-work ext.)
    }
    let shifts = args.get_f64("shifts", 3.0)?;
    let scheme = scheme_of(args.get_or("scheme", "swis"), shifts)?;
    let kind = pe_kind(args.get_or("pe", "ss"))?;
    let mut cfg = ArrayConfig::paper_baseline(kind);
    cfg.rows = args.get_usize("rows", 8)?;
    cfg.cols = args.get_usize("cols", 8)?;
    cfg.group_size = args.get_usize("group", 4)?;
    if args.flag("naive") {
        cfg.staggered = false;
    }

    let sim = simulate_network(&net, &cfg, &scheme);
    println!(
        "# simulate — {} on {}x{} {:?} (G={}, {})",
        net.name, cfg.rows, cfg.cols, kind, cfg.group_size, sim.scheme
    );
    if args.flag("layers") {
        println!(
            "{:<22} {:>12} {:>8} {:>12} {:>12}",
            "layer", "cycles", "util", "dram B", "energy uJ"
        );
        for l in &sim.layers {
            println!(
                "{:<22} {:>12.0} {:>7.1}% {:>12.0} {:>12.2}",
                l.name,
                l.cycles,
                l.utilization * 100.0,
                l.traffic.dram_total(),
                l.total_pj() / 1e6
            );
        }
    }
    println!("total cycles     : {:.3e}", sim.total_cycles);
    println!("latency          : {:.3} ms", sim.latency_s() * 1e3);
    println!("frames/s         : {:.1}", sim.frames_per_s());
    println!("frames/J         : {:.1}", sim.frames_per_j());
    println!("DRAM bytes/frame : {:.3e}", sim.dram_bytes());
    println!("area estimate    : {:.2} mm2", cfg.area_mm2());
    Ok(())
}

/// Run the offline pipeline ONCE and emit the reusable `.swisplan`
/// artifact: quantize/schedule every variant, pack the operands, bind
/// the kernels, serialize. `swis serve --plan`, `swis eval --plan` and
/// `swis loadgen --plan` then load it instead of re-deriving any of it.
fn cmd_plan(args: &cli::Args) -> Result<()> {
    let net_name = args.get_or("net", "tinycnn");
    let mut variants: Vec<VariantSpec> = if let Some(listed) = args.get("variants") {
        EngineConfig::parse_variant_list(listed)?
    } else {
        // --scheme swis_c [--shifts 3 --group 4]
        let shifts = args.get_f64("shifts", 3.0)?;
        let group = args.get_usize("group", 4)?;
        let mut v = Vec::new();
        for sc in args.get_or("scheme", "swis").split(',') {
            let scheme: Scheme = sc.trim().parse()?;
            if scheme != Scheme::Fp32 {
                v.push(VariantSpec::new(scheme, shifts, group)?);
            }
        }
        v
    };
    // the fp32 baseline is ALWAYS included (as the usage text promises):
    // it is what lets `swis eval --plan` anchor comparisons and `swis
    // serve --plan` offer the reference variant
    if !variants.iter().any(|v| v.scheme == Scheme::Fp32) {
        variants.insert(0, VariantSpec::fp32());
    }
    let cfg = EngineConfig::for_net(net_name)?
        .variants(variants)
        .threads(args.get_usize("threads", 0)?)
        .artifacts(args.get_or("artifacts", "artifacts"));
    let out = args.get("o").or_else(|| args.get("out")).unwrap_or("plan.swisplan");
    let t0 = std::time::Instant::now();
    let mut plan = Engine::prepare(cfg)?;
    // --tiers measures every quantized variant's worst-layer MSE and
    // embeds a precision ladder (highest tier first) with a degradation
    // floor at --tier-cap x the top tier's error; the pool then serves
    // down-tiered responses under queue pressure instead of shedding
    if args.flag("tiers") || args.get("tier-cap").is_some() {
        let cap = args.get_f64("tier-cap", swis::eval::DEFAULT_TIER_MSE_CAP)?;
        let policy = swis::eval::derive_tier_policy(
            &plan,
            args.get_usize("batch", 4)?,
            args.get_usize("seed", 1)? as u64,
            args.get_usize("threads", 0)?,
            cap,
        )?;
        println!("# tier ladder (worst-layer MSE ratio vs top tier)");
        for (i, (name, ratio)) in policy.tier_names().iter().zip(policy.mse_ratios()).enumerate() {
            let mark = if i == policy.floor() { "  <= floor" } else { "" };
            println!("  tier {i}: {name:<14} x{ratio:.2}{mark}");
        }
        plan.set_tier_policy(policy)?;
    }
    let prep_s = t0.elapsed().as_secs_f64();
    plan.save(Path::new(out))?;
    let size = std::fs::metadata(out).map(|m| m.len()).unwrap_or(0);
    println!("# plan — {} ({} variants)", plan.net_name(), plan.variants().len());
    for v in plan.variants() {
        println!("  variant {}", v.name);
    }
    println!("weights          : {}", plan.provenance().as_str());
    println!("packed payload   : {} bits", plan.packed_payload_bits());
    println!("prepare took     : {prep_s:.2} s (amortized across every serve/eval)");
    println!("wrote {out} ({size} bytes)");
    Ok(())
}

fn cmd_serve(args: &cli::Args) -> Result<()> {
    // --listen switches serve from the synthetic in-process driver to
    // the SWIS1 TCP edge (multi-model, tenant quotas, rebalancing)
    if let Some(listen) = args.get("listen") {
        let listen = listen.to_string();
        return cmd_serve_edge(args, &listen);
    }
    let dir = args.get_or("artifacts", "artifacts");
    let n_req = args.get_usize("requests", 128)?;
    let policy = flags::batch_policy(args)?;
    let workers = args.get_usize("workers", 1)?;
    let queue_depth = args.get_usize("queue-depth", 1024)?;
    let priority = Priority::parse(args.get_or("priority", "interactive"))?;
    // open-loop pacing of the synthetic driver; 0 submits one burst
    let rate = args.get_f64("rate", 0.0)?;
    let deadline = flags::deadline(args, 0.0)?;
    let trace_sample = flags::trace_sample(args)?;
    let cfg = PoolConfig { workers, policy, queue_depth, trace_sample: trace_sample.max(1) };

    // --metrics-addr HOST:PORT exposes the live Prometheus endpoint for
    // the lifetime of the serve run
    let metrics_export = match args.get("metrics-addr") {
        Some(addr) => {
            let registry = swis::obs::registry::MetricsRegistry::new();
            let server = swis::obs::http::MetricsServer::serve(addr, registry.clone())?;
            println!("metrics          : http://{}/ (Prometheus text)", server.addr());
            Some((server, registry))
        }
        None => None,
    };

    // --plan warms the pool from a prepared .swisplan artifact: the
    // offline step already ran, so worker start-up performs ZERO
    // quantization; net and variants come from the plan itself
    let (pool, names) = if let Some(plan) =
        flags::load_plan(args, &["net", "variants", "backend"])?
    {
        let names: Vec<String> = plan.variants().iter().map(|v| v.name.clone()).collect();
        println!(
            "# serve — starting pool ({workers} workers, {} variants, net {})",
            names.len(),
            plan.net_name()
        );
        let factory: Arc<dyn BackendFactory> = Arc::new(NativeFactory::from_plan(plan));
        (WorkerPool::start_with_factory(factory, cfg)?, names)
    } else {
        let net_name = args.get_or("net", "tinycnn");
        let net = by_name(net_name)
            .with_context(|| format!("unknown network '{net_name}'"))?
            .with_fc();
        let variants = flags::variants_or(args, "fp32,swis@3")?;
        let backend = swis::runtime::BackendKind::parse(args.get_or("backend", "auto"))?;
        let names: Vec<String> = variants.iter().map(|v| v.name.clone()).collect();
        println!(
            "# serve — starting pool ({workers} workers, {} variants, net {})",
            names.len(),
            net.name
        );
        (WorkerPool::start_net(Path::new(dir), cfg, &net, variants, backend)?, names)
    };
    println!("backend          : {}", pool.backend());
    let per = pool.image_len();
    let mut rng = Rng::new(7);
    let mut rxs = Vec::with_capacity(n_req);
    let t0 = std::time::Instant::now();
    for i in 0..n_req {
        let image: Vec<f32> = (0..per).map(|_| rng.f64() as f32).collect();
        let variant = names[i % names.len()].clone();
        rxs.push(pool.submit(
            InferRequest::new(variant).image(image).priority(priority).deadline_opt(deadline),
        )?);
        // keep the exported snapshot current while the load runs, so a
        // scrape mid-run sees live counters and queue depths
        if let Some((_, registry)) = &metrics_export {
            registry.update_pool(pool.metrics.snapshot(), pool.queue_depths());
        }
        if rate > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(exp_gap(&mut rng, rate)));
        }
    }
    let mut ok = 0usize;
    let mut shed = 0usize;
    for rx in rxs {
        match rx.recv()? {
            Ok(_) => ok += 1,
            Err(e) if e.is_shed() => shed += 1,
            Err(_) => {}
        }
        if let Some((_, registry)) = &metrics_export {
            registry.update_pool(pool.metrics.snapshot(), pool.queue_depths());
        }
    }
    let wall = t0.elapsed();
    let snap = pool.metrics.snapshot();
    println!("requests         : {ok}/{n_req} ok in {:.1} ms", wall.as_secs_f64() * 1e3);
    println!("throughput       : {:.0} req/s", n_req as f64 / wall.as_secs_f64());
    println!("batches          : {} (mean size {:.1})", snap.batches, snap.mean_batch);
    println!("shed / rejected  : {shed} / {}", snap.rejected);
    println!("queue p50        : {:.0} us", snap.queue_us.p50);
    println!("total p50 / p99  : {:.0} / {:.0} us", snap.p50_total_us, snap.p99_total_us);
    if trace_sample > 0 {
        let traces = pool.drain_traces();
        let mean_q = traces.iter().map(|t| t.queue_us() as f64).sum::<f64>()
            / traces.len().max(1) as f64;
        let mean_c = traces.iter().map(|t| t.compute_us() as f64).sum::<f64>()
            / traces.len().max(1) as f64;
        println!(
            "traces           : {} sampled (1/{trace_sample}) — mean queue {:.0} us, \
             mean compute {:.0} us",
            traces.len(),
            mean_q,
            mean_c
        );
    }
    if let Some((server, registry)) = metrics_export {
        registry.update_pool(pool.metrics.snapshot(), pool.queue_depths());
        server.stop();
    }
    pool.shutdown()?;
    Ok(())
}

/// `swis serve --listen HOST:PORT` — the SWIS1 TCP edge: a model table
/// of prepared plans (deduplicated through one [`PlanCache`]), a
/// per-model worker pool under one shared worker budget, per-tenant
/// token-bucket quotas, and an optional queue-depth-driven rebalancer.
fn cmd_serve_edge(args: &cli::Args, listen: &str) -> Result<()> {
    let trace_sample = flags::trace_sample(args)?;
    let pool_cfg = PoolConfig {
        // per-model counts come from the edge's worker budget, not here
        workers: 1,
        policy: flags::batch_policy(args)?,
        queue_depth: args.get_usize("queue-depth", 1024)?,
        trace_sample: trace_sample.max(1),
    };
    let stall = Duration::from_millis(args.get_usize("stall-ms", 2000)? as u64);
    let rebalance_ms = args.get_usize("rebalance-ms", 0)?;
    let cfg = EdgeConfig {
        quota: flags::quota(args)?,
        read_stall: stall,
        write_stall: stall,
        worker_budget: args.get_usize("workers", 2)?,
        rebalance: (rebalance_ms > 0).then(|| Duration::from_millis(rebalance_ms as u64)),
        ..EdgeConfig::default()
    };
    let quota_label = match &cfg.quota {
        Some(q) => format!("{:.0}/s burst {:.0}", q.rate, q.burst),
        None => "off".to_string(),
    };
    let cache = PlanCache::new();
    let mut models = Vec::new();
    for (id, path) in flags::model_table(args)? {
        models.push((id, cache.load(&path)?));
    }
    let server = EdgeServer::serve(listen, models, pool_cfg, cfg)?;
    println!(
        "# edge — SWIS1 on {} ({} plan(s) cached, quota {quota_label})",
        server.addr(),
        cache.len()
    );
    for (id, workers) in server.worker_split() {
        println!("  model {id}: {workers} worker(s)");
    }
    let metrics_export = match args.get("metrics-addr") {
        Some(addr) => {
            let registry = swis::obs::registry::MetricsRegistry::new();
            let http = swis::obs::http::MetricsServer::serve(addr, registry.clone())?;
            println!("metrics          : http://{}/ (Prometheus text)", http.addr());
            Some((http, registry))
        }
        None => None,
    };
    // --serve-ms bounds the serving window (0 = run until killed); the
    // exported snapshot is refreshed every tick so scrapes stay live
    let serve_ms = args.get_usize("serve-ms", 0)?;
    let t0 = std::time::Instant::now();
    loop {
        std::thread::sleep(Duration::from_millis(100));
        if let Some((_, registry)) = &metrics_export {
            registry.update_pool(server.metrics().snapshot(), [0, 0]);
        }
        if serve_ms > 0 && t0.elapsed() >= Duration::from_millis(serve_ms as u64) {
            break;
        }
    }
    let totals = server.pool_totals();
    let wire = server.metrics().snapshot().wire;
    println!(
        "requests         : {} ({} batches, {} degraded)",
        totals.requests, totals.batches, totals.degraded
    );
    println!("shed / rejected  : {} / {}", totals.shed, totals.rejected);
    println!("errors / panics  : {} / {}", totals.errors, totals.panics);
    println!(
        "wire faults      : magic {} frame {} oversized {} stall r/w {}/{}",
        wire.bad_magic, wire.bad_frame, wire.oversized, wire.stalled_read, wire.stalled_write
    );
    println!("quota rejected   : {}", wire.quota_rejected);
    println!("connections      : {} opened / {} closed", wire.conns_opened, wire.conns_closed);
    println!("tenants seen     : {}", server.tenants_seen());
    if let Some((http, registry)) = metrics_export {
        registry.update_pool(server.metrics().snapshot(), [0, 0]);
        http.stop();
    }
    server.stop();
    Ok(())
}

/// SLO sweep over worker count x batch policy x arrival process; emits
/// the repo-root `BENCH_serving.json` trajectory record.
fn cmd_loadgen(args: &cli::Args) -> Result<()> {
    // --scenario switches from the classic grid sweep to the shaped
    // traffic suite (optionally replayed over TCP with --connect)
    if let Some(kinds) = flags::scenarios(args)? {
        return cmd_loadgen_scenarios(args, kinds);
    }
    let dir = args.get_or("artifacts", "artifacts");
    // with --plan the sweep measures a prepared artifact: variants come
    // from the plan and every grid point shares its operands
    let plan = flags::load_plan(args, &["backend", "variants"])?;
    let variants: Vec<VariantSpec> = match &plan {
        Some(p) => p.variants().to_vec(),
        None => flags::variants_or(args, "fp32,swis@3")?,
    };
    let workers = args.get_usize_list("workers", &[1, 2, 4])?;
    let rates = args.get_f64_list("rates", &[150.0, 300.0])?;
    let concurrency = args.get_usize_list("concurrency", &[4])?;
    let mode = args.get_or("mode", "open");
    let mut arrivals: Vec<Arrival> = Vec::new();
    if mode == "open" || mode == "both" {
        arrivals.extend(rates.iter().map(|&rate| Arrival::Poisson { rate }));
    }
    if mode == "closed" || mode == "both" {
        arrivals.extend(concurrency.iter().map(|&c| Arrival::Closed { concurrency: c }));
    }
    if arrivals.is_empty() {
        bail!("--mode expects open|closed|both (got '{mode}')");
    }
    // --trace-sample N samples every Nth request's span trace into
    // BENCH_observability.json; implies the full obs level
    let trace_sample = flags::trace_sample(args)?;
    let cfg = SweepConfig {
        workers,
        arrivals,
        max_waits: args
            .get_usize_list("max-waits-ms", &[2])?
            .into_iter()
            .map(|ms| Duration::from_millis(ms as u64))
            .collect(),
        max_batch: args.get_usize("max-batch", 64)?,
        duration: Duration::from_millis(args.get_usize("duration-ms", 400)? as u64),
        queue_depth: args.get_usize("queue-depth", 256)?,
        deadline: flags::deadline(args, 100.0)?,
        variants,
        seed: args.get_usize("seed", 2026)? as u64,
        probe: ProbeMode::parse(args.get_or("probe", "dense"))?,
        trace_sample,
    };

    println!(
        "# loadgen — {} point(s): workers {:?} x waits {:?} x arrivals {:?}",
        cfg.workers.len() * cfg.max_waits.len() * cfg.arrivals.len(),
        cfg.workers,
        cfg.max_waits,
        cfg.arrivals.iter().map(|a| a.label()).collect::<Vec<_>>()
    );
    let (points, served_on) = match plan {
        Some(p) => {
            let factory: Arc<dyn BackendFactory> = Arc::new(NativeFactory::from_plan(p));
            run_sweep_with(factory, &cfg)?
        }
        None => {
            // parsed only here, so an overridden --backend is truly
            // ignored in plan mode (not validated then discarded)
            let backend = swis::runtime::BackendKind::parse(args.get_or("backend", "auto"))?;
            run_sweep(Path::new(dir), backend, &cfg)?
        }
    };
    println!("backend: {served_on} (probe: {})", cfg.probe.as_str());
    println!(
        "{:>7} {:>14} {:>8} {:>10} {:>10} {:>10} {:>6} {:>6} {:>6} {:>6}",
        "workers",
        "arrival",
        "wait ms",
        "ok req/s",
        "p50 us",
        "p99 us",
        "shed",
        "busy",
        "degr",
        "err"
    );
    for p in &points {
        println!(
            "{:>7} {:>14} {:>8.1} {:>10.1} {:>10.0} {:>10.0} {:>6} {:>6} {:>6} {:>6}",
            p.workers,
            p.arrival,
            p.max_wait_ms,
            p.stats.throughput_rps,
            p.stats.p50_us,
            p.stats.p99_us,
            p.shed,
            p.rejected,
            p.degraded,
            p.stats.error + p.stats.timeout
        );
    }
    let out = flags::bench_out(args, "BENCH_serving.json");
    write_bench_json(&points, &cfg, served_on, &out)?;
    println!("wrote {}", out.display());
    if trace_sample > 0 {
        // per-layer kernel sparsity accounting + span-trace latency
        // decomposition, from the same run that produced the sweep
        let traces: Vec<_> = points.iter().flat_map(|p| p.traces.iter().cloned()).collect();
        let mut j =
            swis::obs::registry::observability_json(&swis::obs::global_layers(), &traces);
        j.set("backend", served_on);
        j.set("probe", cfg.probe.as_str());
        j.set("trace_sample", trace_sample as u64);
        let obs_out = match args.get("out") {
            // an explicit --out relocates the trace record beside it
            Some(_) => out.with_file_name(format!(
                "{}_observability.json",
                out.file_stem().and_then(|s| s.to_str()).unwrap_or("BENCH")
            )),
            None => Path::new(env!("CARGO_MANIFEST_DIR"))
                .join("..")
                .join("BENCH_observability.json"),
        };
        swis::util::bench::Emitter::at(&obs_out).write(&j)?;
        println!("wrote {} ({} traces)", obs_out.display(), traces.len());
    }
    Ok(())
}

/// `swis loadgen --scenario a,b[,...]` — the shaped-traffic suite.
/// In-process by default (a fresh pool per scenario over ONE shared
/// factory); `--connect HOST:PORT` replays the same pre-drawn schedules
/// over TCP against a serving edge. Same scenario + same seed means the
/// same offered load on both paths, so the records are comparable.
fn cmd_loadgen_scenarios(args: &cli::Args, kinds: Vec<ScenarioKind>) -> Result<()> {
    let rate = args.get_f64("rate", 150.0)?;
    let base = ScenarioConfig {
        kind: ScenarioKind::Steady, // replaced per trial below
        duration: Duration::from_millis(args.get_usize("duration-ms", 400)? as u64),
        rate,
        peak_rate: args.get_f64("peak-rate", rate * 4.0)?,
        seed: args.get_usize("seed", 2026)? as u64,
        deadline: flags::deadline(args, 100.0)?,
        ..ScenarioConfig::default()
    };
    // scenarios run one batch policy / queue depth (the grid sweep is
    // where those knobs get swept)
    let max_wait =
        Duration::from_millis(args.get_usize_list("max-waits-ms", &[2])?[0] as u64);
    let policy = BatchPolicy { max_batch: args.get_usize("max-batch", 64)?, max_wait };
    let max_wait_ms = max_wait.as_secs_f64() * 1e3;
    let queue_depth = args.get_usize("queue-depth", 256)?;
    let probe = ProbeMode::parse(args.get_or("probe", "dense"))?;

    let mut points: Vec<SweepPoint> = Vec::new();
    let mut protocol_errors = 0u64;
    let mut abuse_sent = 0u64;
    let mut served_on = String::new();
    let variants: Vec<VariantSpec>;
    if let Some(addr) = args.get("connect") {
        let model = args.get_or("model", "default");
        let conns = args.get_usize("conns", 4)?;
        // ask the edge what it serves: variant names + input shape
        let mut info_client = EdgeClient::connect(addr, Duration::from_secs(5))?;
        let infos = info_client.info()?;
        drop(info_client);
        let info = infos
            .iter()
            .find(|m| m.id == model)
            .with_context(|| format!("edge at {addr} does not serve model '{model}'"))?;
        let names = info.variants.clone();
        let image_len: usize = info.input.iter().product();
        let images = gen_images_mode(16, image_len, base.seed, probe);
        println!(
            "# loadgen — {} scenario(s) over TCP to {addr} (model {model}, {conns} conns)",
            kinds.len()
        );
        for kind in &kinds {
            let scfg = ScenarioConfig { kind: *kind, ..base.clone() };
            let run = run_scenario_tcp(addr, model, &scfg, &names, &images, conns)?;
            protocol_errors += run.protocol_errors;
            abuse_sent += run.abuse_sent;
            let s = run.stats;
            points.push(SweepPoint {
                workers: conns,
                scenario: kind.as_str().to_string(),
                arrival: format!("scenario@{:.0}", scfg.rate),
                rate: scfg.rate,
                max_wait_ms,
                // the pool-side split lives in the server's metrics; the
                // client-side record keeps its own observed counts
                shed: s.shed,
                rejected: s.busy,
                shed_by_lane: [0, 0],
                rejected_by_lane: [0, 0],
                degraded: s.degraded,
                mean_batch: 0.0,
                traces: Vec::new(),
                stats: s,
            });
        }
        served_on = format!("tcp:{addr}");
        // the wire carries variant NAMES; parse them back into specs for
        // the record header (best effort — names round-trip by design)
        variants = EngineConfig::parse_variant_list(&names.join(",")).unwrap_or_default();
    } else {
        let dir = args.get_or("artifacts", "artifacts");
        let plan = flags::load_plan(args, &["backend", "variants"])?;
        let specs: Vec<VariantSpec> = match &plan {
            Some(p) => p.variants().to_vec(),
            None => flags::variants_or(args, "fp32,swis@3")?,
        };
        let names: Vec<String> = specs.iter().map(|v| v.name.clone()).collect();
        let workers = args.get_usize_list("workers", &[2])?[0];
        let trace_sample = flags::trace_sample(args)?;
        let factory: Arc<dyn BackendFactory> = match plan {
            Some(p) => Arc::new(NativeFactory::from_plan(p)),
            None => {
                let backend = swis::runtime::BackendKind::parse(args.get_or("backend", "auto"))?;
                Arc::from(create_factory(backend, Path::new(dir), &specs)?)
            }
        };
        println!(
            "# loadgen — {} scenario(s) in-process ({workers} workers)",
            kinds.len()
        );
        let mut images: Option<Vec<Vec<f32>>> = None;
        for kind in &kinds {
            let pool = WorkerPool::start_with_factory(
                Arc::clone(&factory),
                PoolConfig { workers, policy, queue_depth, trace_sample: trace_sample.max(1) },
            )?;
            served_on = pool.backend().to_string();
            let imgs = images
                .get_or_insert_with(|| gen_images_mode(16, pool.image_len(), base.seed, probe));
            let scfg = ScenarioConfig { kind: *kind, ..base.clone() };
            let run = run_scenario_inproc(&pool, &scfg, &names, imgs)?;
            let snap = pool.metrics.snapshot();
            points.push(SweepPoint {
                workers,
                scenario: kind.as_str().to_string(),
                arrival: format!("scenario@{:.0}", scfg.rate),
                rate: scfg.rate,
                max_wait_ms,
                shed: snap.shed,
                rejected: snap.rejected,
                shed_by_lane: snap.shed_by_lane,
                rejected_by_lane: snap.rejected_by_lane,
                degraded: snap.degraded,
                mean_batch: snap.mean_batch,
                traces: Vec::new(),
                stats: run.stats,
            });
            pool.shutdown()?;
        }
        variants = specs;
    }

    println!(
        "{:>14} {:>10} {:>10} {:>10} {:>6} {:>6} {:>6} {:>6}",
        "scenario", "ok req/s", "p50 us", "p99 us", "shed", "busy", "degr", "err"
    );
    for p in &points {
        println!(
            "{:>14} {:>10.1} {:>10.0} {:>10.0} {:>6} {:>6} {:>6} {:>6}",
            p.scenario,
            p.stats.throughput_rps,
            p.stats.p50_us,
            p.stats.p99_us,
            p.shed,
            p.rejected,
            p.degraded,
            p.stats.error + p.stats.timeout
        );
    }
    let cfg = SweepConfig {
        workers: vec![points.first().map(|p| p.workers).unwrap_or(0)],
        arrivals: Vec::new(),
        max_waits: vec![max_wait],
        max_batch: policy.max_batch,
        duration: base.duration,
        queue_depth,
        deadline: base.deadline,
        variants,
        seed: base.seed,
        probe,
        trace_sample: 0,
    };
    let out = flags::bench_out(args, "BENCH_serving.json");
    write_bench_json(&points, &cfg, &served_on, &out)?;
    println!("wrote {}", out.display());
    if protocol_errors > 0 || abuse_sent > 0 {
        println!(
            "wire             : {abuse_sent} abusive conn(s) sent, \
             {protocol_errors} protocol error(s) observed"
        );
    }
    Ok(())
}

/// Zoo accuracy/compression sweep on the native executor: nets x schemes
/// x bit-widths, per-layer MSE vs fp32, top-1 agreement on a fixed probe
/// batch, measured packed compression. Emits the repo-root
/// `BENCH_accuracy.json` trajectory record.
fn cmd_eval(args: &cli::Args) -> Result<()> {
    use swis::eval::{run_eval, run_eval_plan, write_bench_json, EvalConfig};
    let d = EvalConfig::default();
    let list = |key: &str, dflt: &[String]| -> Vec<String> {
        match args.get(key) {
            None => dflt.to_vec(),
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
        }
    };
    // with --plan the sweep measures a shipped artifact's exact
    // operands instead of re-quantizing a (nets x schemes x bits) grid
    let plan = flags::load_plan(args, &["nets", "schemes", "bits", "group"])?;
    let cfg = match &plan {
        None => EvalConfig {
            nets: list("nets", &d.nets),
            // parsed only in grid mode: in plan mode the overridden
            // --schemes is ignored, not validated then discarded
            schemes: match args.get("schemes") {
                None => d.schemes.clone(),
                Some(v) => {
                    let schemes: Vec<Scheme> = v
                        .split(',')
                        .map(|s| s.trim().parse::<Scheme>())
                        .collect::<swis::SwisResult<_>>()?;
                    if schemes.contains(&Scheme::Fp32) {
                        // silently emitting only reference rows would
                        // look like a sweep that measured nothing
                        bail!(
                            "--schemes lists quantized schemes only (the fp32 \
                             reference row is always emitted)"
                        );
                    }
                    schemes
                }
            },
            bits: args.get_f64_list("bits", &d.bits)?,
            group_size: args.get_usize("group", d.group_size)?,
            batch: args.get_usize("batch", d.batch)?,
            seed: args.get_usize("seed", d.seed as usize)? as u64,
            threads: args.get_usize("threads", d.threads)?,
            artifacts: Some(std::path::PathBuf::from(args.get_or("artifacts", "artifacts"))),
        },
        Some(p) => {
            let quantized: Vec<&VariantSpec> =
                p.variants().iter().filter(|v| v.scheme != Scheme::Fp32).collect();
            // the config block must label what actually ran: the plan's
            // own group size when uniform, 0 ("mixed") otherwise
            let group_size = match quantized.split_first() {
                Some((first, rest)) if rest.iter().all(|v| v.group_size == first.group_size) => {
                    first.group_size
                }
                _ => 0,
            };
            EvalConfig {
                nets: vec![p.net_name().to_string()],
                schemes: quantized.iter().map(|v| v.scheme).collect(),
                bits: quantized.iter().map(|v| v.n_shifts).collect(),
                group_size,
                batch: args.get_usize("batch", d.batch)?,
                seed: args.get_usize("seed", d.seed as usize)? as u64,
                threads: args.get_usize("threads", d.threads)?,
                artifacts: None,
            }
        }
    };
    println!(
        "# eval — {:?} x {:?} x {:?} bits, probe batch {} (native executor)",
        cfg.nets,
        cfg.schemes.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
        cfg.bits,
        cfg.batch
    );
    let recs = match &plan {
        Some(p) => run_eval_plan(p, cfg.batch, cfg.seed, cfg.threads)?,
        None => run_eval(&cfg)?,
    };
    println!(
        "{:<16} {:<10} {:>5} {:>12} {:>9} {:>8} {:>10}",
        "net", "scheme", "bits", "logits mse", "top1 agr", "compr.", "weights"
    );
    for r in &recs {
        println!(
            "{:<16} {:<10} {:>5} {:>12.3e} {:>9.2} {:>7.2}x {:>10}",
            r.net,
            r.scheme,
            r.bits,
            r.mse,
            r.top1_agree,
            r.compression_ratio,
            r.weights.as_str()
        );
    }
    let out = flags::bench_out(args, "BENCH_accuracy.json");
    write_bench_json(&recs, &cfg, &out)?;
    println!("wrote {}", out.display());
    Ok(())
}

/// Bench-driven kernel autotune (default), or the MSE++ alpha sweep of
/// paper Sec. 4.1.2 behind `--alpha`.
///
/// The kernel path sweeps SIMD variant x row-block x group-chunk x
/// thread-split over the plan's own largest packed GEMM, prints the full
/// candidate table, and (with `-o`) persists the winning [`TuneParams`]
/// into a machine-tuned `.swisplan` that `serve`/`eval`/`loadgen`
/// consume automatically on this host.
///
/// [`TuneParams`]: swis::api::TuneParams
fn cmd_tune(args: &cli::Args) -> Result<()> {
    if args.flag("alpha") {
        return cmd_tune_alpha(args);
    }
    use swis::api::TuneOptions;
    // --plan loads a shipped artifact; otherwise prepare one in-process
    // (same defaulting as `swis plan`, minus serialization)
    let mut plan = if let Some(p) = args.get("plan") {
        EnginePlan::load(Path::new(p))?
    } else {
        let net_name = args.get_or("net", "tinycnn");
        let shifts = args.get_f64("shifts", 3.0)?;
        let group = args.get_usize("group", 4)?;
        let mut variants = vec![VariantSpec::fp32()];
        for sc in args.get_or("scheme", "swis").split(',') {
            let scheme: Scheme = sc.trim().parse()?;
            if scheme != Scheme::Fp32 {
                variants.push(VariantSpec::new(scheme, shifts, group)?);
            }
        }
        let cfg = EngineConfig::for_net(net_name)?
            .variants(variants)
            .artifacts(args.get_or("artifacts", "artifacts"));
        Engine::prepare(cfg)?
    };
    let dflt = TuneOptions::default();
    let opts = TuneOptions {
        rows: args.get_usize("rows", dflt.rows)?,
        reps: args.get_usize("reps", dflt.reps)?,
        threads: match args.get("threads") {
            Some(_) => args.get_usize_list("threads", &[1])?,
            None => dflt.threads,
        },
    };
    let report = plan.autotune(&opts)?;
    println!(
        "# kernel autotune — {} on {} (probe {})",
        plan.net_name(),
        report.isa,
        report.probe
    );
    println!(
        "{:<10} {:>4} {:>6} {:>4} {:>12} {:>10}",
        "variant", "rb", "chunk", "thr", "median ms", "Mw/s"
    );
    for c in &report.candidates {
        let mark = if c.params == report.best { " <= best" } else { "" };
        println!(
            "{:<10} {:>4} {:>6} {:>4} {:>12.4} {:>10.1}{mark}",
            c.params.variant.as_str(),
            c.params.row_block,
            c.params.group_chunk,
            c.params.threads,
            c.median_ms,
            c.mws
        );
    }
    println!("scalar median    : {:.4} ms", report.scalar_median_ms);
    println!(
        "best median      : {:.4} ms ({:.2}x vs scalar)",
        report.best_median_ms, report.speedup
    );
    if let Some(out) = args.get("o").or_else(|| args.get("out")) {
        plan.save(Path::new(out))?;
        println!("wrote {out} (tuned for {})", report.best.cpu);
    } else {
        println!("(re-run with -o tuned.swisplan to persist the winner)");
    }
    Ok(())
}

/// Sweep the MSE++ alpha coefficient for a network (paper Sec. 4.1.2).
fn cmd_tune_alpha(args: &cli::Args) -> Result<()> {
    use swis::quant::alpha_tune::{tune_alpha, DEFAULT_GRID};
    use swis::quant::QuantConfig;
    let net_name = args.get_or("net", "resnet18");
    let net = by_name(net_name).with_context(|| format!("unknown network '{net_name}'"))?;
    let shifts = args.get_usize("shifts", 3)?;
    let group = args.get_usize("group", 4)?;
    let layer = &net.layers[net.layers.len() / 2];
    let w = surrogate_weights(layer, args.get_usize("seed", 1)? as u64);
    let cfg = QuantConfig::swis(shifts, group);
    let (best, scores) = tune_alpha(&w, &layer.weight_shape(), &cfg, DEFAULT_GRID)?;
    println!("# MSE++ alpha sweep — {} {} ({} shifts, G={})", net.name, layer.name, shifts, group);
    println!("{:>7} {:>10} {:>12} {:>12}", "alpha", "rmse", "|drift|", "objective");
    for s in &scores {
        let mark = if s.alpha == best { " <= best" } else { "" };
        println!("{:>7} {:>10.5} {:>12.3e} {:>12.5}{mark}", s.alpha, s.rmse, s.drift, s.objective());
    }
    Ok(())
}

fn cmd_prob() -> Result<()> {
    println!("# Fig. 2 — P(lossless) of an 8-bit value vs number of shifts");
    println!("{:>7} {:>12} {:>12} {:>12}", "shifts", "layer-wise", "SWIS-C", "SWIS");
    for r in fig2_rows() {
        println!(
            "{:>7} {:>12.4} {:>12.4} {:>12.4}",
            r.n_shifts, r.layerwise, r.swis_c, r.swis
        );
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("# model zoo");
    for net in all_networks() {
        println!(
            "{:<16} {:>3} conv layers {:>12} weights {:>8.2} GMAC",
            net.name,
            net.layers.len(),
            net.total_weights(),
            net.total_macs() as f64 / 1e9
        );
    }
    println!("\n# paper-baseline accelerator");
    for kind in [PeKind::Fixed, PeKind::SingleShift, PeKind::DoubleShift] {
        let cfg = ArrayConfig::paper_baseline(kind);
        println!(
            "{:?}: 8x8, G=4, 64+64+16 KB SRAM, area ~{:.2} mm2",
            kind,
            cfg.area_mm2()
        );
    }
    Ok(())
}

/// `swis lint [--root DIR] [--fix-list]` — run the repo's static pass
/// (the `swis-lint` crate) and fail on any finding. The default root is
/// the working directory; `rust/` is resolved automatically so the
/// command works from the repo root and from inside the crate alike.
fn cmd_lint(args: &cli::Args) -> Result<()> {
    let root = Path::new(args.get_or("root", "."));
    let rust_dir = swis_lint::resolve_rust_dir(root)
        .with_context(|| format!("no Rust crate found under '{}'", root.display()))?;
    let report = swis_lint::run(&rust_dir)
        .with_context(|| format!("scanning '{}'", rust_dir.display()))?;
    for f in &report.findings {
        println!("{f}");
    }
    if args.flag("fix-list") && !report.fix_list.is_empty() {
        println!("-- fix list ({} entries) --", report.fix_list.len());
        for item in &report.fix_list {
            println!("{item}");
        }
    }
    eprintln!(
        "swis lint: {} files, {} non-test unwrap/expect sites, {} findings",
        report.files_scanned,
        report.unwrap_total,
        report.findings.len()
    );
    if report.findings.is_empty() {
        Ok(())
    } else {
        bail!("{} lint findings", report.findings.len())
    }
}

/// `swis verify-plan FILE...` — statically verify `.swisplan`
/// containers (every structural invariant, zero execution). Exits
/// nonzero on the first malformed container.
fn cmd_verify_plan(args: &cli::Args) -> Result<()> {
    let paths: Vec<&String> = args.positional().iter().skip(1).collect();
    if paths.is_empty() {
        bail!("usage: swis verify-plan FILE.swisplan [MORE...]");
    }
    for p in paths {
        let check = swis::api::verify_plan_file(Path::new(p))?;
        println!("{p}: OK — {check}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    /// Tests that raise the process-global obs level serialize here, so
    /// one test's restore-to-Off can't land mid-run in another.
    fn obs_guard() -> std::sync::MutexGuard<'static, ()> {
        static G: std::sync::Mutex<()> = std::sync::Mutex::new(());
        G.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    #[test]
    fn quantize_and_simulate_run() {
        run(&sv(&["quantize", "--net", "tinycnn", "--shifts", "3"])).unwrap();
        run(&sv(&["simulate", "--net", "tinycnn", "--scheme", "swis_c", "--pe", "ds"])).unwrap();
        run(&sv(&["prob"])).unwrap();
        run(&sv(&["info"])).unwrap();
        run(&sv(&["tune", "--alpha", "--net", "tinycnn", "--shifts", "2"])).unwrap();
    }

    #[test]
    fn kernel_tune_persists_a_machine_tuned_plan() {
        let out = std::env::temp_dir().join(format!("swis_tune_{}.swisplan", std::process::id()));
        run(&sv(&[
            "tune", "--net", "tinycnn", "--scheme", "swis", "--shifts", "2", "--rows", "8",
            "--reps", "1", "--threads", "1", "-o", out.to_str().unwrap(),
        ]))
        .unwrap();
        // the persisted plan carries host-matching TuneParams back in
        let plan = EnginePlan::load(&out).unwrap();
        let tp = plan.tune_params().expect("tuned plan must round-trip its TuneParams");
        assert!(tp.matches_host());
        let _ = std::fs::remove_file(&out);
    }

    #[test]
    fn serve_smoke() {
        // end-to-end through the CLI path (artifacts built by `make
        // artifacts`; cargo test runs at the package root)
        run(&sv(&[
            "serve", "--requests", "8", "--variants", "fp32,swis@2", "--max-wait-ms", "1",
        ]))
        .unwrap();
        // the pool path: multiple workers, bounded queue, batch lane
        run(&sv(&[
            "serve", "--requests", "8", "--variants", "swis@2", "--max-wait-ms", "1",
            "--workers", "2", "--queue-depth", "16", "--priority", "batch",
        ]))
        .unwrap();
    }

    #[test]
    fn plan_pipeline_through_cli() {
        // plan -> serve --plan -> eval --plan -> loadgen --plan: the one
        // facade pipeline end to end through the CLI surface
        let pid = std::process::id();
        let plan_out = std::env::temp_dir().join(format!("swis_cli_{pid}.swisplan"));
        let plan_str = plan_out.to_str().unwrap();
        run(&sv(&[
            "plan", "--net", "tinycnn", "--scheme", "swis_c", "--shifts", "2", "-o", plan_str,
        ]))
        .unwrap();
        run(&sv(&[
            "serve", "--plan", plan_str, "--requests", "6", "--max-wait-ms", "1", "--workers",
            "2",
        ]))
        .unwrap();
        let eval_out = std::env::temp_dir().join(format!("swis_cli_eval_{pid}.json"));
        run(&sv(&[
            "eval", "--plan", plan_str, "--batch", "1", "--threads", "2", "--out",
            eval_out.to_str().unwrap(),
        ]))
        .unwrap();
        let j = swis::util::json::parse(&std::fs::read_to_string(&eval_out).unwrap()).unwrap();
        assert_eq!(j.path(&["records", "0", "scheme"]).unwrap().as_str(), Some("fp32"));
        assert_eq!(j.path(&["records", "1", "scheme"]).unwrap().as_str(), Some("swis_c"));
        let lg_out = std::env::temp_dir().join(format!("swis_cli_lg_{pid}.json"));
        run(&sv(&[
            "loadgen", "--plan", plan_str, "--workers", "1", "--rates", "150",
            "--duration-ms", "80", "--deadline-ms", "5000", "--out", lg_out.to_str().unwrap(),
        ]))
        .unwrap();
        let j = swis::util::json::parse(&std::fs::read_to_string(&lg_out).unwrap()).unwrap();
        assert_eq!(j.get("backend").unwrap().as_str(), Some("native"));
        // the sweep's variant list came from the plan, not a default
        let variants = j.get("variants").unwrap().as_arr().unwrap();
        assert!(variants.iter().any(|v| v.as_str() == Some("swis_c@2")));
        for f in [&plan_out, &eval_out, &lg_out] {
            let _ = std::fs::remove_file(f);
        }
    }

    #[test]
    fn tiered_plan_and_sparse_probe_through_cli() {
        let pid = std::process::id();
        let plan_out = std::env::temp_dir().join(format!("swis_cli_tier_{pid}.swisplan"));
        let plan_str = plan_out.to_str().unwrap();
        run(&sv(&[
            "plan", "--net", "tinycnn", "--variants", "swis@4,swis@3,swis@2", "--tiers",
            "--batch", "1", "-o", plan_str,
        ]))
        .unwrap();
        let plan = EnginePlan::load(&plan_out).unwrap();
        let pol = plan.tier_policy().expect("--tiers must embed a ladder");
        assert_eq!(pol.tier_names(), ["swis@4", "swis@3", "swis@2"]);
        // a tiered plan degrades under pressure through the whole
        // loadgen stack; the record carries probe + degraded columns
        let lg_out = std::env::temp_dir().join(format!("swis_cli_tier_lg_{pid}.json"));
        run(&sv(&[
            "loadgen", "--plan", plan_str, "--workers", "1", "--rates", "150",
            "--duration-ms", "80", "--deadline-ms", "5000", "--probe", "sparse",
            "--out", lg_out.to_str().unwrap(),
        ]))
        .unwrap();
        let j = swis::util::json::parse(&std::fs::read_to_string(&lg_out).unwrap()).unwrap();
        assert_eq!(j.get("probe").unwrap().as_str(), Some("sparse"));
        assert!(j.path(&["records", "0", "degraded"]).is_some());
        for f in [&plan_out, &lg_out] {
            let _ = std::fs::remove_file(f);
        }
    }

    #[test]
    fn serve_exports_metrics_and_traces() {
        let _g = obs_guard();
        // ephemeral port: the endpoint must bind, serve the exposition
        // page during the run, and the driver must drain traces
        run(&sv(&[
            "serve", "--requests", "8", "--variants", "swis@2", "--max-wait-ms", "1",
            "--metrics-addr", "127.0.0.1:0", "--trace-sample", "1", "--obs", "full",
        ]))
        .unwrap();
        swis::obs::set_level(swis::obs::ObsLevel::Off);
    }

    #[test]
    fn loadgen_trace_sample_emits_observability_json() {
        let _g = obs_guard();
        let pid = std::process::id();
        let out = std::env::temp_dir().join(format!("swis_lg_obs_{pid}.json"));
        run(&sv(&[
            "loadgen", "--workers", "1", "--rates", "150", "--duration-ms", "80",
            "--variants", "swis@2", "--backend", "native", "--deadline-ms", "5000",
            "--probe", "sparse", "--trace-sample", "1", "--out", out.to_str().unwrap(),
        ]))
        .unwrap();
        swis::obs::set_level(swis::obs::ObsLevel::Off);
        let obs_out = out.with_file_name(format!(
            "{}_observability.json",
            out.file_stem().and_then(|s| s.to_str()).unwrap()
        ));
        let j = swis::util::json::parse(&std::fs::read_to_string(&obs_out).unwrap()).unwrap();
        assert_eq!(j.get("bench").unwrap().as_str(), Some("observability"));
        // the sweep ran real kernels with counters on: the per-layer
        // accounting and the trace decomposition must both be populated
        assert!(j.path(&["layers", "0", "planes_total"]).is_some(), "no layer accounting");
        let sampled = j.path(&["traces", "sampled"]).unwrap().as_f64().unwrap();
        assert!(sampled > 0.0, "no traces sampled");
        let q = j.path(&["traces", "decomposition", "queue_wait_us_mean"]).unwrap();
        assert!(q.as_f64().unwrap() >= 0.0);
        for f in [&out, &obs_out] {
            let _ = std::fs::remove_file(f);
        }
    }

    #[test]
    fn loadgen_smoke_writes_wellformed_json() {
        let out = std::env::temp_dir().join(format!("swis_loadgen_{}.json", std::process::id()));
        run(&sv(&[
            "loadgen", "--workers", "1", "--rates", "150", "--duration-ms", "80",
            "--variants", "swis@2", "--backend", "native", "--deadline-ms", "5000",
            "--out", out.to_str().unwrap(),
        ]))
        .unwrap();
        let j = swis::util::json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        assert_eq!(j.get("bench").unwrap().as_str(), Some("serving"));
        for key in ["workers", "throughput_rps", "p50_us", "p99_us", "shed"] {
            assert!(j.path(&["records", "0", key]).is_some(), "missing {key}");
        }
        let _ = std::fs::remove_file(&out);
    }

    #[test]
    fn eval_smoke_writes_wellformed_json_with_trend() {
        let out = std::env::temp_dir().join(format!("swis_eval_{}.json", std::process::id()));
        run(&sv(&[
            "eval", "--nets", "tinycnn", "--schemes", "swis,wgt_trunc", "--bits", "3",
            "--batch", "2", "--threads", "2", "--out", out.to_str().unwrap(),
        ]))
        .unwrap();
        let j = swis::util::json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        assert_eq!(j.get("bench").unwrap().as_str(), Some("accuracy"));
        let recs = j.get("records").unwrap().as_arr().unwrap();
        assert_eq!(recs.len(), 3); // fp32 + swis@3 + wgt_trunc@3
        for key in
            ["net", "scheme", "bits", "mse", "top1_agree", "compression_ratio", "weights"]
        {
            assert!(recs[0].get(key).is_some(), "missing {key}");
        }
        // the paper's trend, machine-checkable from the emitted record
        let mse = |scheme: &str| {
            recs.iter()
                .find(|r| r.get("scheme").unwrap().as_str() == Some(scheme))
                .unwrap()
                .get("mse")
                .unwrap()
                .as_f64()
                .unwrap()
        };
        assert!(mse("swis") < mse("wgt_trunc"));
        assert_eq!(
            recs[0].get("weights").unwrap().as_str(),
            Some("surrogate"),
            "provenance must be stamped"
        );
        let _ = std::fs::remove_file(&out);
    }

    #[test]
    fn bad_inputs_error() {
        assert!(run(&sv(&["bogus"])).is_err());
        assert!(run(&sv(&["simulate", "--net", "nope"])).is_err());
        assert!(run(&sv(&["simulate", "--pe", "warp"])).is_err());
        assert!(run(&sv(&["simulate", "--scheme", "int4"])).is_err());
        assert!(run(&sv(&["serve", "--priority", "warp"])).is_err());
        assert!(run(&sv(&["serve", "--net", "nope"])).is_err());
        assert!(run(&sv(&["loadgen", "--mode", "sideways"])).is_err());
        assert!(run(&sv(&["loadgen", "--probe", "noisy"])).is_err());
        assert!(run(&sv(&["eval", "--nets", "nope"])).is_err());
        assert!(run(&sv(&["eval", "--nets", "tinycnn", "--schemes", "int4"])).is_err());
        // fp32 in --schemes would sweep nothing: loud error, not a no-op
        assert!(run(&sv(&["eval", "--nets", "tinycnn", "--schemes", "fp32"])).is_err());
        assert!(run(&sv(&["serve", "--plan", "/nope.swisplan"])).is_err());
        assert!(run(&sv(&["plan", "--net", "nope"])).is_err());
        // table-driven validation: a typo fails loudly instead of being
        // silently ignored, and --help flows through the flag table
        assert!(run(&sv(&["serve", "--workerz", "2"])).is_err());
        assert!(run(&sv(&["loadgen", "--scenario", "rush_hour"])).is_err());
        run(&sv(&["serve", "--help"])).unwrap();
        run(&sv(&["--help"])).unwrap();
    }

    #[test]
    fn loadgen_scenario_suite_through_cli() {
        let out =
            std::env::temp_dir().join(format!("swis_lg_scen_{}.json", std::process::id()));
        run(&sv(&[
            "loadgen", "--scenario", "steady,flash_crowd", "--workers", "1", "--rate", "120",
            "--duration-ms", "80", "--variants", "swis@2", "--backend", "native",
            "--deadline-ms", "5000", "--out", out.to_str().unwrap(),
        ]))
        .unwrap();
        let j = swis::util::json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        assert_eq!(j.get("bench").unwrap().as_str(), Some("serving"));
        let recs = j.get("records").unwrap().as_arr().unwrap();
        assert_eq!(recs.len(), 2, "one record per scenario");
        assert_eq!(recs[0].get("scenario").unwrap().as_str(), Some("steady"));
        assert_eq!(recs[1].get("scenario").unwrap().as_str(), Some("flash_crowd"));
        let _ = std::fs::remove_file(&out);
    }
}
