//! The typed public facade: **config → plan → session**, one pipeline
//! for every consumer.
//!
//! ```text
//!   EngineConfig (typed, builder)          offline, expensive
//!        │  net + variants + alpha + threads
//!        ▼
//!   Engine::prepare ──▶ EnginePlan ◀──▶ .swisplan (versioned container)
//!        │                  │  planner output + packed layers +
//!        │                  │  prepared GEMM/depthwise planes
//!        ▼                  ▼
//!   Session::run / Session::stream         online, cheap
//!        ▲                  ▲
//!   swis eval / benches     NativeBackend → WorkerPool (swis serve)
//! ```
//!
//! The paper's whole premise (PAPER.md §3) is that the SWIS
//! decomposition/scheduling step runs ONCE, offline, and its output is
//! reused forever after. [`EnginePlan`] is that output as a first-class
//! object: prepare it here (or load it from a `.swisplan` file), then
//! hand an `Arc<EnginePlan>` to as many [`Session`]s, backends or pool
//! workers as needed — none of them ever re-quantize (provable via
//! [`prepare_call_count`]). Serving (`swis serve --plan`), evaluation
//! (`swis eval`), load generation and the benches all enter through
//! this module instead of re-deriving quantize/plan/prepare/pack
//! pipelines of their own.
//!
//! Errors on every facade seam are the typed [`SwisError`] taxonomy —
//! match on the failure class (`Config`/`Plan`/`Io`/`Backend`/
//! `Admission`/`Eval`), not on message strings.
//!
//! # Example
//!
//! ```no_run
//! use swis::api::{Engine, EngineConfig, Session, VariantSpec};
//! use std::sync::Arc;
//!
//! let cfg = EngineConfig::for_net("tinycnn")?
//!     .variant(VariantSpec::fp32())
//!     .variant(VariantSpec::swis(3.0, 4))
//!     .threads(4);
//! let plan = Arc::new(Engine::prepare(cfg)?);
//! plan.save("tinycnn.swisplan".as_ref())?;          // ship this file
//! let session = Session::new(Arc::clone(&plan));
//! # let images = swis::util::tensor::Tensor::new(&[1, 32, 32, 3], vec![0.0; 32 * 32 * 3]).unwrap();
//! let logits = session.run("swis@3", &images)?;
//! # Ok::<(), swis::api::SwisError>(())
//! ```

mod plan;
mod verify;

pub use crate::coordinator::{InferRequest, Scheme, TierPolicy, VariantSpec};
pub use crate::error::{AdmissionReason, SwisError, SwisResult};
pub use crate::exec::{KernelVariant, TuneOptions, TuneParams, TuneReport, WeightProvenance};
pub use crate::quant::Alpha;
pub use crate::util::tensor::Tensor;
pub use plan::EnginePlan;
pub use verify::{verify_plan_bytes, verify_plan_file, PlanCheck};

use std::path::PathBuf;
use std::sync::Arc;

use crate::exec::{net_weights, NativeModel};
use crate::nets::{by_name, Network};
use crate::quant::planner;
use crate::util::sync::{lock_unpoisoned, Mutex};

/// Planner-work odometer: how many layer quantize/schedule calls this
/// process has made. Warm-up paths that load a `.swisplan` must not
/// move it — pinned by `tests/plan_warmup.rs`.
pub fn prepare_call_count() -> u64 {
    crate::schedule::prepare_call_count()
}

/// Typed, builder-style engine configuration — what the stringly
/// `VariantSpec::parse` call sites construct now. A config names the
/// network, the weight variants to prepare (scheme, shift budget, group
/// size each), the MSE++ alpha and the execution thread budget; feed it
/// to [`Engine::prepare`].
#[derive(Clone, Debug)]
pub struct EngineConfig {
    net: Network,
    variants: Vec<VariantSpec>,
    alpha: Alpha,
    threads: usize,
    artifacts: Option<PathBuf>,
}

impl EngineConfig {
    /// Config for a zoo network by name (`tinycnn`, `mobilenet_v2`,
    /// `resnet18`, `vgg16`), with its FC head — the serving topology.
    pub fn for_net(name: &str) -> SwisResult<EngineConfig> {
        let net = by_name(name)
            .ok_or_else(|| SwisError::config(format!("unknown network '{name}'")))?;
        Ok(EngineConfig::with_network(net.with_fc()))
    }

    /// Config for an explicit network descriptor (custom topologies;
    /// pass the net with its FC head if it should serve logits).
    pub fn with_network(net: Network) -> EngineConfig {
        EngineConfig {
            net,
            variants: Vec::new(),
            alpha: Alpha::ONE,
            threads: 0,
            artifacts: None,
        }
    }

    /// Add one weight variant. Specs are validated at
    /// [`Engine::prepare`] time (one validation point for builder- and
    /// string-built configs alike).
    pub fn variant(mut self, spec: VariantSpec) -> EngineConfig {
        self.variants.push(spec);
        self
    }

    /// Add several variants at once.
    pub fn variants(mut self, specs: impl IntoIterator<Item = VariantSpec>) -> EngineConfig {
        self.variants.extend(specs);
        self
    }

    /// Parse a comma-separated variant list (`"fp32,swis@3,swis_c@2"`,
    /// the CLI grammar) into typed specs.
    pub fn parse_variant_list(list: &str) -> SwisResult<Vec<VariantSpec>> {
        list.split(',').map(|s| s.trim().parse()).collect()
    }

    /// MSE++ alpha for SWIS quantization (paper Sec. 4.1.2; default 1).
    pub fn alpha(mut self, alpha: Alpha) -> EngineConfig {
        self.alpha = alpha;
        self
    }

    /// Execution thread budget recorded on the plan (0 = resolve to the
    /// machine default at session/backend build; pools split it across
    /// workers).
    pub fn threads(mut self, threads: usize) -> EngineConfig {
        self.threads = threads;
        self
    }

    /// Artifact directory probed for trained `<net>_weights.npz`
    /// (deterministic surrogates otherwise — loudly).
    pub fn artifacts(mut self, dir: impl Into<PathBuf>) -> EngineConfig {
        self.artifacts = Some(dir.into());
        self
    }

    pub fn net(&self) -> &Network {
        &self.net
    }

    pub fn variant_specs(&self) -> &[VariantSpec] {
        &self.variants
    }

    fn validate(&self) -> SwisResult<()> {
        if self.variants.is_empty() {
            return Err(SwisError::config(format!(
                "engine config for '{}' has no variants (add .variant(..))",
                self.net.name
            )));
        }
        let mut seen = std::collections::HashSet::new();
        for spec in &self.variants {
            // re-validate through the typed constructor: builder-made and
            // parsed specs meet the same bar
            let canon = VariantSpec::new(spec.scheme, spec.n_shifts, spec.group_size)
                .map_err(|e| e.context(format!("variant '{}'", spec.name)))?;
            if canon.name != spec.name {
                return Err(SwisError::config(format!(
                    "variant name '{}' does not match its config (canonical '{}')",
                    spec.name, canon.name
                )));
            }
            if !seen.insert(spec.name.clone()) {
                return Err(SwisError::config(format!("duplicate variant '{}'", spec.name)));
            }
        }
        Ok(())
    }
}

/// The offline pipeline entry: turns an [`EngineConfig`] into an
/// [`EnginePlan`].
pub struct Engine;

impl Engine {
    /// Run the full offline step — load weights (trained npz when
    /// present, loud deterministic surrogates otherwise), quantize/
    /// schedule every variant, pack operands, bind kernels — and return
    /// the reusable plan. This is the ONLY place in the pipeline where
    /// planner work happens.
    pub fn prepare(cfg: EngineConfig) -> SwisResult<EnginePlan> {
        cfg.validate()?;
        let (weights, provenance) = net_weights(cfg.artifacts.as_deref(), &cfg.net)
            .map_err(|e| {
                SwisError::plan_from(e).context(format!("loading weights for '{}'", cfg.net.name))
            })?;
        let mut parts = Vec::with_capacity(cfg.variants.len());
        for spec in &cfg.variants {
            let transform = spec.transform()?;
            let vp = NativeModel::plan_parts(&cfg.net, &weights, transform, cfg.alpha)
                .map_err(|e| {
                    SwisError::plan_from(e).context(format!(
                        "preparing variant '{}' of '{}'",
                        spec.name, cfg.net.name
                    ))
                })?;
            parts.push(vp);
        }
        EnginePlan::assemble(cfg.net, cfg.threads, provenance, cfg.variants, parts, None, None)
    }
}

/// The single inference entry over a prepared plan: synchronous
/// [`Session::run`], or the batched [`SessionStream`] handle for callers
/// that accumulate requests before dispatch (the shape the pool's
/// per-worker batcher drives through [`crate::runtime::NativeBackend`]).
/// Sessions are cheap — an `Arc` clone of the plan plus a thread budget
/// — so every worker/caller holds its own.
pub struct Session {
    plan: Arc<EnginePlan>,
    threads: usize,
    /// Per-layer breakdown of this session's most recent forward, kept
    /// only while the obs level enables counters ([`crate::obs`]).
    stats: Mutex<Option<crate::obs::ForwardStats>>,
}

impl Session {
    /// Session with the plan's recorded thread budget; a plan left on
    /// auto (0) resolves through the autotuner's swept thread split when
    /// the plan carries host-matching [`TuneParams`](crate::exec::TuneParams),
    /// else the machine default.
    pub fn new(plan: Arc<EnginePlan>) -> Session {
        let threads = plan.preferred_threads();
        Session::with_threads(plan, threads)
    }

    /// Session with an explicit intra-op thread budget (pools pass their
    /// per-worker split so N workers never oversubscribe).
    pub fn with_threads(plan: Arc<EnginePlan>, threads: usize) -> Session {
        let threads = if threads == 0 { planner::default_threads() } else { threads };
        Session { plan, threads, stats: Mutex::new(None) }
    }

    pub fn plan(&self) -> &Arc<EnginePlan> {
        &self.plan
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run one `(n, hw, hw, c)` image batch under `variant`, returning
    /// `(n, n_classes)` logits. Bit-identical for any thread count and
    /// batch composition (per-row activation quantization).
    pub fn run(&self, variant: &str, images: &Tensor<f32>) -> SwisResult<Tensor<f32>> {
        let model = self.plan.model(variant).ok_or_else(|| {
            SwisError::backend(format!(
                "unknown variant '{variant}' (plan has: {})",
                self.plan
                    .variants()
                    .iter()
                    .map(|v| v.name.as_str())
                    .collect::<Vec<_>>()
                    .join(", ")
            ))
        })?;
        let t0 = std::time::Instant::now();
        let out = model
            .forward(images, self.threads)
            .map_err(|e| SwisError::backend_from(e).context(format!("variant '{variant}'")));
        // aggregate this forward's per-layer tallies (collected on this
        // thread by exec::model's layer scopes); None when counters off
        if let Some(fwd) = crate::obs::take_forward(t0.elapsed().as_secs_f64() * 1e3) {
            *lock_unpoisoned(&self.stats) = Some(fwd);
        }
        out
    }

    /// Per-layer sparsity/time breakdown of this session's most recent
    /// [`Session::run`] — `None` when the [`crate::obs`] level has
    /// counters off (the default) or before the first run.
    pub fn last_stats(&self) -> Option<crate::obs::ForwardStats> {
        lock_unpoisoned(&self.stats).clone()
    }

    /// Serve one typed [`InferRequest`] — the same submission type the
    /// worker pool and the network edge consume, so a request built once
    /// behaves identically through every entry. The request's
    /// `tier_hint` is the tier depth the caller will tolerate (0 = full
    /// precision): when the plan carries a [`TierPolicy`] and the
    /// requested variant sits higher on the ladder than the hint, the
    /// request executes at the deeper, cheaper tier instead — precision
    /// is only ever *lowered*, and never past the policy floor. The
    /// single image rides in `req.image`; priority/deadline/tenant are
    /// pool- and edge-level concerns and are ignored here. Returns the
    /// `(1, n_classes)` logits plus the name of the variant that
    /// actually served them.
    pub fn serve(&self, req: &InferRequest) -> SwisResult<(Tensor<f32>, String)> {
        let [h, w, c] = self.plan.input_shape();
        let per = h * w * c;
        if req.image.len() != per {
            return Err(SwisError::admission(
                AdmissionReason::Invalid,
                format!("image must have {per} elements, got {}", req.image.len()),
            ));
        }
        let (effective, _) = self.plan.resolve_tier(&req.variant, req.tier_hint);
        let effective = effective.to_string();
        let images = Tensor::new(&[1, h, w, c], req.image.clone())
            .map_err(SwisError::backend_from)?;
        let logits = self.run(&effective, &images)?;
        Ok((logits, effective))
    }

    /// Open a batched streaming handle for `variant`: push/feed images
    /// as they arrive, flush to execute the accumulated batch in one
    /// kernel dispatch.
    pub fn stream(&self, variant: &str) -> SwisResult<SessionStream<'_>> {
        if !self.plan.has_variant(variant) {
            return Err(SwisError::backend(format!("unknown variant '{variant}'")));
        }
        let [h, w, c] = self.plan.input_shape();
        Ok(SessionStream {
            session: self,
            variant: variant.to_string(),
            per_image: h * w * c,
            rows: 0,
            data: Vec::new(),
        })
    }
}

/// Accumulates a batch for one variant, then executes it in a single
/// dispatch on [`SessionStream::flush`]. Results are independent of how
/// the batch was fed (batch-composition invariance is pinned in
/// `exec::model` tests).
pub struct SessionStream<'s> {
    session: &'s Session,
    variant: String,
    per_image: usize,
    rows: usize,
    data: Vec<f32>,
}

impl SessionStream<'_> {
    /// Append one flattened `hw * hw * c` image. Malformed requests are
    /// `Admission { reason: Invalid }` — the SAME class the pool's edge
    /// refuses them with, so callers classify identically whichever
    /// entry the request came through.
    pub fn push(&mut self, image: &[f32]) -> SwisResult<()> {
        if image.len() != self.per_image {
            return Err(SwisError::admission(
                AdmissionReason::Invalid,
                format!("image must have {} elements, got {}", self.per_image, image.len()),
            ));
        }
        self.data.extend_from_slice(image);
        self.rows += 1;
        Ok(())
    }

    /// Append a whole `(n, hw, hw, c)` batch.
    pub fn feed(&mut self, images: &Tensor<f32>) -> SwisResult<()> {
        let shape = images.shape();
        let [h, w, c] = self.session.plan.input_shape();
        if shape.len() != 4 || shape[1] != h || shape[2] != w || shape[3] != c {
            return Err(SwisError::admission(
                AdmissionReason::Invalid,
                format!("expected (n, {h}, {w}, {c}) images, got {shape:?}"),
            ));
        }
        self.data.extend_from_slice(images.data());
        self.rows += shape[0];
        Ok(())
    }

    /// Images accumulated since the last flush.
    pub fn pending(&self) -> usize {
        self.rows
    }

    /// Execute the accumulated batch and reset the stream for reuse.
    pub fn flush(&mut self) -> SwisResult<Tensor<f32>> {
        if self.rows == 0 {
            return Err(SwisError::admission(
                AdmissionReason::Invalid,
                "flush of an empty stream (push images first)",
            ));
        }
        let [h, w, c] = self.session.plan.input_shape();
        let images = Tensor::new(&[self.rows, h, w, c], std::mem::take(&mut self.data))
            .map_err(SwisError::backend_from)?;
        self.rows = 0;
        self.session.run(&self.variant, &images)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tinycnn_cfg() -> EngineConfig {
        EngineConfig::for_net("tinycnn")
            .unwrap()
            .variant(VariantSpec::fp32())
            .variant(VariantSpec::swis(3.0, 4))
            .threads(2)
    }

    fn images(batch: usize, seed: u64) -> Tensor<f32> {
        let mut rng = crate::util::rng::Rng::new(seed);
        let data: Vec<f32> =
            (0..batch * 32 * 32 * 3).map(|_| rng.range_f64(0.0, 1.0) as f32).collect();
        Tensor::new(&[batch, 32, 32, 3], data).unwrap()
    }

    #[test]
    fn config_builds_and_validates() {
        assert!(matches!(
            EngineConfig::for_net("nope").unwrap_err(),
            SwisError::Config(_)
        ));
        // no variants
        let empty = EngineConfig::for_net("tinycnn").unwrap();
        assert!(matches!(Engine::prepare(empty).unwrap_err(), SwisError::Config(_)));
        // duplicates
        let dup = EngineConfig::for_net("tinycnn")
            .unwrap()
            .variant(VariantSpec::swis(3.0, 4))
            .variant(VariantSpec::swis(3.0, 4));
        assert!(matches!(Engine::prepare(dup).unwrap_err(), SwisError::Config(_)));
        // out-of-range knobs surface as Config even from the builder path
        let mut bad = VariantSpec::swis(3.0, 4);
        bad.n_shifts = 12.0;
        let cfg = EngineConfig::for_net("tinycnn").unwrap().variant(bad);
        assert!(matches!(Engine::prepare(cfg).unwrap_err(), SwisError::Config(_)));
    }

    #[test]
    fn parse_variant_list_round_trips_the_cli_grammar() {
        let specs = EngineConfig::parse_variant_list("fp32, swis@3, swis_c@2.5/g8").unwrap();
        assert_eq!(specs.len(), 3);
        assert_eq!(specs[2].group_size, 8);
        assert!(EngineConfig::parse_variant_list("fp32,bogus@3").is_err());
    }

    #[test]
    fn prepare_run_and_stream_agree() {
        let plan = Arc::new(Engine::prepare(tinycnn_cfg()).unwrap());
        assert_eq!(plan.net_name(), "tinycnn");
        assert_eq!(plan.input_shape(), [32, 32, 3]);
        assert_eq!(plan.n_classes(), 10);
        assert_eq!(plan.variants().len(), 2);
        assert!(plan.packed_payload_bits() > 0);
        let session = Session::new(Arc::clone(&plan));
        assert_eq!(session.threads(), 2);
        let x = images(3, 9);
        let direct = session.run("swis@3", &x).unwrap();
        assert_eq!(direct.shape(), &[3, 10]);
        // the streaming handle is batch-assembly sugar over the same
        // kernels: identical logits however the batch was fed
        let mut stream = session.stream("swis@3").unwrap();
        for b in 0..3 {
            stream.push(&x.data()[b * 32 * 32 * 3..(b + 1) * 32 * 32 * 3]).unwrap();
        }
        assert_eq!(stream.pending(), 3);
        let streamed = stream.flush().unwrap();
        assert_eq!(streamed.data(), direct.data());
        assert_eq!(stream.pending(), 0);
        // feed() takes whole tensors; flush on empty is a typed error
        stream.feed(&x).unwrap();
        assert_eq!(stream.flush().unwrap().data(), direct.data());
        // malformed-request failures carry the pool's own class
        assert!(matches!(
            stream.flush().unwrap_err(),
            SwisError::Admission { reason: AdmissionReason::Invalid, .. }
        ));
        // unknown variants are typed Backend errors
        assert!(matches!(session.run("nope", &x).unwrap_err(), SwisError::Backend(_)));
        assert!(matches!(session.stream("nope").unwrap_err(), SwisError::Backend(_)));
    }

    #[test]
    fn session_is_thread_count_invariant() {
        let plan = Arc::new(Engine::prepare(tinycnn_cfg()).unwrap());
        let x = images(2, 4);
        let a = Session::with_threads(Arc::clone(&plan), 1).run("swis@3", &x).unwrap();
        let b = Session::with_threads(Arc::clone(&plan), 4).run("swis@3", &x).unwrap();
        assert_eq!(a.data(), b.data());
    }

    #[test]
    fn tiered_sessions_degrade_along_the_plan_ladder() {
        let cfg = EngineConfig::for_net("tinycnn")
            .unwrap()
            .variant(VariantSpec::swis(4.0, 4))
            .variant(VariantSpec::swis(3.0, 4))
            .variant(VariantSpec::swis(2.0, 4))
            .threads(2);
        let mut plan = Engine::prepare(cfg).unwrap();
        // a ladder naming a variant the plan does not serve is refused
        let foreign =
            TierPolicy::new(vec!["swis@4".into(), "swis@5".into()], vec![1.0, 9.0], 1).unwrap();
        assert!(matches!(plan.set_tier_policy(foreign).unwrap_err(), SwisError::Config(_)));
        let ladder = TierPolicy::new(
            vec!["swis@4".into(), "swis@3".into(), "swis@2".into()],
            vec![1.0, 4.0, 16.0],
            2,
        )
        .unwrap();
        plan.set_tier_policy(ladder).unwrap();
        let plan = Arc::new(plan);
        let s = Session::new(Arc::clone(&plan));
        let x = images(1, 3);
        let req = |variant: &str, hint: usize| {
            InferRequest::new(variant).image(x.data().to_vec()).tier_hint(hint)
        };
        // hint 0 = full precision, identical to plain run
        let (full, v) = s.serve(&req("swis@4", 0)).unwrap();
        assert_eq!(v, "swis@4");
        assert_eq!(full.data(), s.run("swis@4", &x).unwrap().data());
        // a deep hint serves the floor tier's exact logits
        let (down, v) = s.serve(&req("swis@4", 99)).unwrap();
        assert_eq!(v, "swis@2");
        assert_eq!(down.data(), s.run("swis@2", &x).unwrap().data());
        // a hint shallower than the variant's own tier never raises it
        let (_, v) = s.serve(&req("swis@3", 0)).unwrap();
        assert_eq!(v, "swis@3");
        // a malformed image is the pool's own Invalid admission class
        assert!(matches!(
            s.serve(&InferRequest::new("swis@4").image(vec![0.0; 7])).unwrap_err(),
            SwisError::Admission { reason: AdmissionReason::Invalid, .. }
        ));
    }

    #[test]
    fn session_exposes_per_layer_stats_when_counters_on() {
        let _g = crate::obs::test_level_guard();
        crate::obs::set_level(crate::obs::ObsLevel::Counters);
        let plan = Arc::new(Engine::prepare(tinycnn_cfg()).unwrap());
        let s = Session::new(Arc::clone(&plan));
        let x = images(1, 5);
        assert!(s.last_stats().is_none(), "no stats before the first run");
        s.run("swis@3", &x).unwrap();
        let st = s.last_stats().unwrap();
        crate::obs::set_level(crate::obs::ObsLevel::Off);
        assert!(!st.layers.is_empty());
        assert!(st.tally().planes_total() > 0, "SWIS layers must count plane work");
        assert!(st.layers.iter().all(|l| l.time_ms >= 0.0));
        // with counters off the snapshot stays whatever it was; runs are
        // unobserved
        s.run("swis@3", &x).unwrap();
        assert_eq!(s.last_stats().unwrap().layers.len(), st.layers.len());
    }

    #[test]
    fn plan_round_trips_in_memory() {
        let plan = Engine::prepare(tinycnn_cfg()).unwrap();
        let bytes = plan.to_bytes().unwrap();
        let back = EnginePlan::from_bytes(&bytes).unwrap();
        assert_eq!(back.net_name(), plan.net_name());
        assert_eq!(back.threads(), plan.threads());
        assert_eq!(back.provenance(), plan.provenance());
        assert_eq!(back.variants(), plan.variants());
        let x = images(2, 11);
        let a = Session::new(Arc::new(plan)).run("swis@3", &x).unwrap();
        let b = Session::new(Arc::new(back)).run("swis@3", &x).unwrap();
        assert_eq!(a.data(), b.data());
    }
}
