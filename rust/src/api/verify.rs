//! `swis verify-plan` — a *static* `.swisplan` analyzer.
//!
//! [`EnginePlan::from_bytes`](super::EnginePlan::from_bytes) proves a
//! container loadable by loading it: binding kernels, allocating every
//! operand, and silently *dropping* sections that don't fit this host
//! (foreign-CPU tune params) or this plan (foreign tier ladders). That
//! is the right behavior for serving — and the wrong tool for CI, where
//! a plan that quietly lost its ladder should fail the build, and where
//! verifying an artifact must not cost a model bind.
//!
//! This module walks the container byte-by-byte and checks every
//! structural invariant **without executing anything**:
//!
//! * magic, version window, and the trailing fnv1a64 checksum;
//! * header enums (provenance, layer kind, scheme, operand tags);
//! * per-variant shift counts within the scheme's representable bounds;
//! * packed `.swis` operands: magic/version, header sanity, and the
//!   plane-accounting identity — the payload must hold exactly the bits
//!   the Sec. 3.3 accounting promises (`need <= 8*(len-26) < need+8`);
//! * operand/layer-table consistency: a part named after a conv layer
//!   must match its geometry (filters = out_c, fan-in from kind/k/in_c,
//!   bias length = out_c); parts off the table (FC heads) are noted;
//! * the tagged trailer: section lengths, tune-section shape (kernel
//!   variant tag, CPU signature string), tier ladders that name only
//!   declared variants (a foreign ladder is an ERROR here, not a silent
//!   drop), MSE ratios ordered along the ladder, floor in range;
//! * version coherence: a version-3 container must actually carry a
//!   tier section, and nothing may trail the checksum.
//!
//! Wired into CI right after every plan-building step: the artifact the
//! smoke jobs ship is proven well-formed before anything serves it.

use std::path::Path;

use crate::coordinator::Scheme;
use crate::error::{SwisError, SwisResult};
use crate::exec::KernelVariant;

const MAGIC: &[u8; 8] = b"SWISPLAN";
const VERSION_MIN: u16 = 1;
const VERSION_MAX: u16 = 3;
const SECTION_TUNE: u8 = 1;
const SECTION_TIERS: u8 = 2;
/// Fixed `.swis` packed-container header (quant::serialize layout).
const SWIS_HEADER: usize = 26;

/// What a successful verification learned — enough for a CI log line
/// and for asserting over in tests.
#[derive(Clone, Debug)]
pub struct PlanCheck {
    pub version: u16,
    pub net: String,
    pub n_layers: usize,
    pub n_variants: usize,
    pub dense_parts: usize,
    pub packed_parts: usize,
    pub packed_payload_bytes: usize,
    pub has_tune: bool,
    pub has_tiers: bool,
    /// Non-fatal observations (unknown trailer sections skipped, parts
    /// off the conv table, ...).
    pub notes: Vec<String>,
}

impl std::fmt::Display for PlanCheck {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "version {} net '{}': {} layers, {} variants, {} dense + {} packed operands \
             ({} packed payload bytes), tune={}, tiers={}",
            self.version,
            self.net,
            self.n_layers,
            self.n_variants,
            self.dense_parts,
            self.packed_parts,
            self.packed_payload_bytes,
            self.has_tune,
            self.has_tiers
        )?;
        for n in &self.notes {
            write!(f, "\n  note: {n}")?;
        }
        Ok(())
    }
}

/// Verify a `.swisplan` file on disk. See [`verify_plan_bytes`].
pub fn verify_plan_file(path: &Path) -> SwisResult<PlanCheck> {
    let bytes = std::fs::read(path).map_err(|e| SwisError::io_at(path, e))?;
    verify_plan_bytes(&bytes).map_err(|e| e.context(format!("verifying {}", path.display())))
}

/// Statically verify a `.swisplan` container. Returns the summary on
/// success; any violated invariant is a typed [`SwisError::Plan`]
/// naming the offending field and byte offset. Nothing is executed,
/// bound, or allocated proportional to claimed (unverified) counts.
pub fn verify_plan_bytes(bytes: &[u8]) -> SwisResult<PlanCheck> {
    if bytes.len() < MAGIC.len() + 2 + 8 {
        return Err(SwisError::plan(format!(
            "container is {} bytes — too short for magic + version + checksum",
            bytes.len()
        )));
    }
    if &bytes[..MAGIC.len()] != MAGIC {
        return Err(SwisError::plan("bad magic (not a .swisplan container)"));
    }
    let version = u16::from_le_bytes([bytes[8], bytes[9]]);
    if !(VERSION_MIN..=VERSION_MAX).contains(&version) {
        return Err(SwisError::plan(format!(
            "unsupported version {version} (verifier knows {VERSION_MIN}..={VERSION_MAX})"
        )));
    }
    let body = &bytes[..bytes.len() - 8];
    let tail = &bytes[bytes.len() - 8..];
    let stored = u64::from_le_bytes([
        tail[0], tail[1], tail[2], tail[3], tail[4], tail[5], tail[6], tail[7],
    ]);
    let computed = fnv1a64(body);
    if computed != stored {
        return Err(SwisError::plan(format!(
            "checksum mismatch: stored {stored:#018x}, computed {computed:#018x} \
             (bit flip or truncation)"
        )));
    }

    let mut r = Rd { b: body, pos: MAGIC.len() + 2 };
    let mut check = PlanCheck {
        version,
        net: String::new(),
        n_layers: 0,
        n_variants: 0,
        dense_parts: 0,
        packed_parts: 0,
        packed_payload_bytes: 0,
        has_tune: false,
        has_tiers: false,
        notes: Vec::new(),
    };

    let flags = r.u16("flags")?;
    if flags != 0 {
        check.notes.push(format!("reserved flags field is {flags:#06x} (writer emits 0)"));
    }
    let _threads = r.u16("thread budget")?;
    let prov = r.u8("provenance tag")?;
    if prov > 1 {
        return Err(SwisError::plan(format!("unknown provenance tag {prov}")));
    }
    check.net = r.str("net name")?;
    if check.net.is_empty() {
        return Err(SwisError::plan("empty network name"));
    }

    // conv layer table: (name, kind) -> (out_c, fan_in)
    check.n_layers = r.u32("layer count")? as usize;
    let mut table: Vec<(String, usize, usize)> = Vec::new();
    for li in 0..check.n_layers {
        let name = r.str("layer name")?;
        let kind = r.u8("layer kind tag")?;
        if kind > 1 {
            return Err(SwisError::plan(format!(
                "layer '{name}' (index {li}): unknown kind tag {kind}"
            )));
        }
        let mut dims = [0usize; 6];
        for d in dims.iter_mut() {
            *d = r.u32("layer dimension")? as usize;
        }
        let [_in_hw, in_c, k, stride, _pad, out_c] = dims;
        if k == 0 || stride == 0 || in_c == 0 || out_c == 0 {
            return Err(SwisError::plan(format!(
                "layer '{name}': degenerate geometry {dims:?} (zero kernel/stride/channels)"
            )));
        }
        // fan-in exactly as exec::model computes it from the descriptor
        let fan_in = if kind == 1 { k * k } else { in_c * k * k };
        if table.iter().any(|(n, _, _)| n == &name) {
            return Err(SwisError::plan(format!("duplicate layer name '{name}' in the table")));
        }
        table.push((name, out_c, fan_in));
    }

    let input = [
        r.u32("input dim")? as usize,
        r.u32("input dim")? as usize,
        r.u32("input dim")? as usize,
    ];
    if input.iter().any(|&d| d == 0) {
        return Err(SwisError::plan(format!("degenerate input shape {input:?}")));
    }
    let n_classes = r.u32("class count")? as usize;
    if n_classes == 0 {
        return Err(SwisError::plan("zero classes"));
    }

    check.n_variants = r.u16("variant count")? as usize;
    if check.n_variants == 0 {
        return Err(SwisError::plan("a plan needs at least one variant"));
    }
    let mut variant_names: Vec<String> = Vec::new();
    for _ in 0..check.n_variants {
        let vname = r.str("variant name")?;
        let scheme_tag = r.u8("scheme tag")?;
        let scheme = match scheme_tag {
            0 => Scheme::Fp32,
            1 => Scheme::Swis,
            2 => Scheme::SwisC,
            3 => Scheme::WgtTrunc,
            other => {
                return Err(SwisError::plan(format!(
                    "variant '{vname}': unknown scheme tag {other}"
                )))
            }
        };
        let n_shifts = r.f64("shift count")?;
        let group = r.u16("group size")? as usize;
        // shift budget within the scheme's representable bounds: shift
        // magnitudes travel in 3-bit fields and weights are 8-bit, so a
        // packed scheme serves 1..=8 planes; fp32 carries no planes
        if scheme != Scheme::Fp32 {
            if !n_shifts.is_finite() || n_shifts < 1.0 || n_shifts > 8.0 {
                return Err(SwisError::plan(format!(
                    "variant '{vname}': shift count {n_shifts} outside the scheme's 1..=8"
                )));
            }
            if group == 0 {
                return Err(SwisError::plan(format!("variant '{vname}': zero group size")));
            }
        }
        if variant_names.iter().any(|n| n == &vname) {
            return Err(SwisError::plan(format!("duplicate variant '{vname}'")));
        }

        let n_parts = r.u32("operand count")? as usize;
        let mut part_names: Vec<String> = Vec::new();
        for _ in 0..n_parts {
            let lname = r.str("operand layer name")?;
            if part_names.iter().any(|n| n == &lname) {
                return Err(SwisError::plan(format!(
                    "variant '{vname}': duplicate operand for layer '{lname}'"
                )));
            }
            let row = table.iter().find(|(n, _, _)| n == &lname);
            let tag = r.u8("operand tag")?;
            match tag {
                0 => {
                    let n = r.u32("dense length")? as usize;
                    let raw = r.take(n.checked_mul(4).ok_or_else(|| {
                        SwisError::plan(format!("dense operand '{lname}': length overflows"))
                    })?, "dense weights")?;
                    check.dense_parts += 1;
                    if let Some((_, out_c, fan_in)) = row {
                        let want = out_c * fan_in;
                        if n != want {
                            return Err(SwisError::plan(format!(
                                "variant '{vname}', layer '{lname}': dense operand has {n} \
                                 weights, the layer table implies {want} ({out_c} x {fan_in})"
                            )));
                        }
                    }
                    let _ = raw;
                }
                1 => {
                    let len = r.u32("packed length")? as usize;
                    let raw = r.take(len, "packed container")?;
                    let (n_filters, fan_in) = verify_swis_container(raw)
                        .map_err(|e| e.context(format!(
                            "variant '{vname}', layer '{lname}' packed operand"
                        )))?;
                    check.packed_parts += 1;
                    check.packed_payload_bytes += len;
                    if let Some((_, out_c, table_fan_in)) = row {
                        if n_filters != *out_c || fan_in != *table_fan_in {
                            return Err(SwisError::plan(format!(
                                "variant '{vname}', layer '{lname}': packed shape \
                                 {n_filters}x{fan_in} disagrees with the layer table \
                                 {out_c}x{table_fan_in}"
                            )));
                        }
                    }
                }
                other => {
                    return Err(SwisError::plan(format!(
                        "variant '{vname}', layer '{lname}': unknown operand tag {other}"
                    )))
                }
            }
            let bias_len = r.u32("bias length")? as usize;
            let _bias = r.take(bias_len.checked_mul(4).ok_or_else(|| {
                SwisError::plan(format!("bias of '{lname}': length overflows"))
            })?, "bias")?;
            if let Some((_, out_c, _)) = row {
                if bias_len != *out_c {
                    return Err(SwisError::plan(format!(
                        "variant '{vname}', layer '{lname}': {bias_len} bias terms, the \
                         layer table implies {out_c}"
                    )));
                }
            } else {
                check.notes.push(format!(
                    "variant '{vname}': part '{lname}' is off the conv table (FC head or \
                     auxiliary operand) — geometry not cross-checked"
                ));
            }
            part_names.push(lname);
        }
        variant_names.push(vname);
    }

    // tagged section trailer (version >= 2)
    if version >= 2 {
        let n_sections = r.u16("section count")? as usize;
        for si in 0..n_sections {
            let tag = r.u8("section tag")?;
            let len = r.u32("section length")? as usize;
            let raw = r.take(len, "section payload")?;
            match tag {
                SECTION_TUNE => {
                    verify_tune_section(raw)
                        .map_err(|e| e.context(format!("tune section (trailer entry {si})")))?;
                    check.has_tune = true;
                }
                SECTION_TIERS => {
                    verify_tier_section(raw, &variant_names)
                        .map_err(|e| e.context(format!("tier section (trailer entry {si})")))?;
                    check.has_tiers = true;
                }
                other => {
                    check.notes.push(format!(
                        "unknown trailer section tag {other} ({len} bytes) — a loader skips it"
                    ));
                }
            }
        }
    }
    if version == 3 && !check.has_tiers {
        return Err(SwisError::plan(
            "version 3 container carries no tier-ladder section (writers only emit \
             version 3 for tiered plans)",
        ));
    }
    if r.pos != body.len() {
        return Err(SwisError::plan(format!(
            "{} trailing bytes between the last field (offset {}) and the checksum",
            body.len() - r.pos,
            r.pos
        )));
    }
    Ok(check)
}

/// Verify one packed `.swis` operand WITHOUT materializing its planes:
/// magic, version, header sanity, and the plane-accounting identity —
/// the payload must be exactly `ceil(need_bits / 8)` bytes for the
/// header's promised signs/shifts/masks(/filter-shifts). Returns the
/// `(n_filters, fan_in)` shape for cross-checking the layer table.
fn verify_swis_container(bytes: &[u8]) -> SwisResult<(usize, usize)> {
    if bytes.len() < SWIS_HEADER {
        return Err(SwisError::plan(format!(
            "{} bytes is shorter than the {SWIS_HEADER}-byte .swis header",
            bytes.len()
        )));
    }
    if &bytes[..4] != b"SWIS" {
        return Err(SwisError::plan("bad .swis magic"));
    }
    if bytes[4] != 1 {
        return Err(SwisError::plan(format!("unsupported .swis version {}", bytes[4])));
    }
    let flags = bytes[5];
    if flags & !0b11 != 0 {
        return Err(SwisError::plan(format!("unknown .swis flag bits {flags:#010b}")));
    }
    let consecutive = flags & 1 != 0;
    let scheduled = flags & 2 != 0;
    let group_size = u16::from_le_bytes([bytes[6], bytes[7]]) as usize;
    let n_shifts = u16::from_le_bytes([bytes[8], bytes[9]]) as usize;
    let n_filters =
        u32::from_le_bytes([bytes[10], bytes[11], bytes[12], bytes[13]]) as usize;
    let fan_in = u32::from_le_bytes([bytes[14], bytes[15], bytes[16], bytes[17]]) as usize;
    let scale = f64::from_le_bytes([
        bytes[18], bytes[19], bytes[20], bytes[21], bytes[22], bytes[23], bytes[24], bytes[25],
    ]);
    if group_size == 0 || n_shifts == 0 || n_shifts > 8 {
        return Err(SwisError::plan(format!(
            "corrupt .swis header: G={group_size} N={n_shifts} (want G>=1, 1<=N<=8)"
        )));
    }
    if n_filters == 0 || fan_in == 0 {
        return Err(SwisError::plan(format!(
            "degenerate .swis shape {n_filters}x{fan_in}"
        )));
    }
    if !scale.is_finite() || scale <= 0.0 {
        return Err(SwisError::plan(format!(".swis scale {scale} is not a finite positive")));
    }
    let gpf = fan_in.div_ceil(group_size);
    let g = n_filters as u128 * gpf as u128;
    let lanes = g * group_size as u128;
    let mut need_bits = lanes // signs
        + lanes * n_shifts as u128 // masks
        + if consecutive { g * 3 } else { g * n_shifts as u128 * 3 };
    if scheduled {
        need_bits += n_filters as u128 * 4;
    }
    let avail_bits = (bytes.len() as u128 - SWIS_HEADER as u128) * 8;
    // the Sec. 3.3 accounting identity: the payload is the promised
    // planes and nothing else (under a byte of bit-packing slack)
    if avail_bits < need_bits || avail_bits >= need_bits + 8 {
        return Err(SwisError::plan(format!(
            "plane accounting broken: header promises {need_bits} payload bits, container \
             holds {avail_bits} (want {need_bits} <= held < {})",
            need_bits + 8
        )));
    }
    Ok((n_filters, fan_in))
}

/// Verify the version-2 tune section's shape: a known kernel-variant
/// tag, the three u16 parameters, and a well-formed CPU signature
/// string. Trailing bytes are legal (forward extensions).
fn verify_tune_section(raw: &[u8]) -> SwisResult<()> {
    let mut r = Rd { b: raw, pos: 0 };
    let tag = r.u8("kernel variant tag")?;
    if KernelVariant::from_tag(tag).is_none() {
        return Err(SwisError::plan(format!("unknown kernel variant tag {tag}")));
    }
    let _row_block = r.u16("row block")?;
    let _group_chunk = r.u16("group chunk")?;
    let _threads = r.u16("thread split")?;
    let cpu = r.str("cpu signature")?;
    if cpu.is_empty() {
        return Err(SwisError::plan(
            "empty CPU signature (tuned params would never match any host)",
        ));
    }
    Ok(())
}

/// Verify the version-3 tier section against the declared variant set:
/// >= 2 tiers, every tier a declared variant (a foreign ladder is an
/// ERROR here — the loader's silent drop is exactly what CI must
/// catch), no duplicates, finite MSE ratios that never *decrease* down
/// the ladder, and the floor within range.
fn verify_tier_section(raw: &[u8], variants: &[String]) -> SwisResult<()> {
    let mut r = Rd { b: raw, pos: 0 };
    let n = r.u16("tier count")? as usize;
    if n < 2 {
        return Err(SwisError::plan(format!("a ladder needs >= 2 tiers, got {n}")));
    }
    let mut prev_ratio = f64::NEG_INFINITY;
    let mut seen: Vec<String> = Vec::new();
    for ti in 0..n {
        let name = r.str("tier name")?;
        let ratio = r.f64("tier MSE ratio")?;
        if !variants.iter().any(|v| v == &name) {
            return Err(SwisError::plan(format!(
                "tier {ti} '{name}' is not a variant of this plan (foreign ladder; the \
                 loader would silently drop the whole policy)"
            )));
        }
        if seen.iter().any(|s| s == &name) {
            return Err(SwisError::plan(format!("duplicate tier '{name}'")));
        }
        if !ratio.is_finite() || ratio < 0.0 {
            return Err(SwisError::plan(format!(
                "tier {ti} '{name}': MSE ratio {ratio} is not a finite >= 0"
            )));
        }
        if ratio < prev_ratio {
            return Err(SwisError::plan(format!(
                "tier {ti} '{name}': MSE ratio {ratio} is lower than the tier above it \
                 ({prev_ratio}) — the ladder must degrade monotonically"
            )));
        }
        prev_ratio = ratio;
        seen.push(name);
    }
    let floor = r.u16("tier floor")? as usize;
    if floor >= n {
        return Err(SwisError::plan(format!(
            "tier floor {floor} out of range (ladder has {n} tiers)"
        )));
    }
    if r.pos != raw.len() {
        return Err(SwisError::plan(format!(
            "{} trailing bytes in the tier section",
            raw.len() - r.pos
        )));
    }
    Ok(())
}

/// FNV-1a 64 (mirrors plan.rs — the checksum contract is the format).
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Bounds-checked little-endian reader; every failure names the field
/// and the offset where the container ran out.
struct Rd<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    fn take(&mut self, n: usize, what: &str) -> SwisResult<&'a [u8]> {
        let end = self.pos.checked_add(n).ok_or_else(|| {
            SwisError::plan(format!("{what}: length overflows at byte {}", self.pos))
        })?;
        if end > self.b.len() {
            return Err(SwisError::plan(format!(
                "truncated reading {what}: need {n} bytes at offset {}, container body \
                 has {}",
                self.pos,
                self.b.len()
            )));
        }
        let s = &self.b[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> SwisResult<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u16(&mut self, what: &str) -> SwisResult<u16> {
        let s = self.take(2, what)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }

    fn u32(&mut self, what: &str) -> SwisResult<u32> {
        let s = self.take(4, what)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn f64(&mut self, what: &str) -> SwisResult<f64> {
        let s = self.take(8, what)?;
        Ok(f64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
    }

    fn str(&mut self, what: &str) -> SwisResult<String> {
        let n = self.u16(what)? as usize;
        let raw = self.take(n, what)?;
        String::from_utf8(raw.to_vec())
            .map_err(|_| SwisError::plan(format!("{what}: invalid UTF-8 at byte {}", self.pos)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_garbage_and_short_input() {
        assert!(verify_plan_bytes(b"").is_err());
        assert!(verify_plan_bytes(b"SWISPLAN\x01\x00").is_err());
        assert!(verify_plan_bytes(&[0u8; 64]).is_err());
    }

    #[test]
    fn swis_container_plane_accounting() {
        // hand-build a minimal consecutive header: G=4 N=2, 4 filters,
        // fan_in 4 -> gpf=1, g=4, lanes=16
        // need = 16 (signs) + 32 (masks) + 12 (shifts) = 60 bits -> 8 bytes
        let mut h = Vec::new();
        h.extend_from_slice(b"SWIS");
        h.push(1); // version
        h.push(1); // FLAG_CONSECUTIVE
        h.extend_from_slice(&4u16.to_le_bytes()); // group
        h.extend_from_slice(&2u16.to_le_bytes()); // n_shifts
        h.extend_from_slice(&4u32.to_le_bytes()); // n_filters
        h.extend_from_slice(&4u32.to_le_bytes()); // fan_in
        h.extend_from_slice(&1.0f64.to_le_bytes()); // scale
        let mut ok = h.clone();
        ok.extend_from_slice(&[0u8; 8]);
        assert_eq!(verify_swis_container(&ok).unwrap(), (4, 4));
        // a byte short: accounting identity broken
        let mut short = h.clone();
        short.extend_from_slice(&[0u8; 7]);
        assert!(verify_swis_container(&short).is_err());
        // a byte long: padding beyond the slack is also an error
        let mut long = h.clone();
        long.extend_from_slice(&[0u8; 9]);
        assert!(verify_swis_container(&long).is_err());
        // n_shifts out of bounds
        let mut bad = ok.clone();
        bad[8] = 9;
        assert!(verify_swis_container(&bad).is_err());
    }

    #[test]
    fn tier_section_rules() {
        fn sect(tiers: &[(&str, f64)], floor: u16) -> Vec<u8> {
            let mut s = Vec::new();
            s.extend_from_slice(&(tiers.len() as u16).to_le_bytes());
            for (name, ratio) in tiers {
                s.extend_from_slice(&(name.len() as u16).to_le_bytes());
                s.extend_from_slice(name.as_bytes());
                s.extend_from_slice(&ratio.to_le_bytes());
            }
            s.extend_from_slice(&floor.to_le_bytes());
            s
        }
        let vs = vec!["swis@4".to_string(), "swis@2".to_string()];
        let good = sect(&[("swis@4", 1.0), ("swis@2", 4.0)], 1);
        assert!(verify_tier_section(&good, &vs).is_ok());
        // foreign ladder: named tier is not a plan variant
        let foreign = sect(&[("swis@4", 1.0), ("ghost@2", 4.0)], 1);
        assert!(verify_tier_section(&foreign, &vs).is_err());
        // ratios must not improve down the ladder
        let unordered = sect(&[("swis@4", 4.0), ("swis@2", 1.0)], 1);
        assert!(verify_tier_section(&unordered, &vs).is_err());
        // floor out of range
        let deep = sect(&[("swis@4", 1.0), ("swis@2", 4.0)], 2);
        assert!(verify_tier_section(&deep, &vs).is_err());
    }
}
