//! [`EnginePlan`] — the deployable artifact of the SWIS pipeline — and
//! its versioned binary `.swisplan` container.
//!
//! SWIS's value proposition is an *offline* decomposition/scheduling
//! step whose output is reused across every inference (PAPER.md §3).
//! The plan is that output as a first-class object: the full network
//! descriptor, plus — per weight variant — every layer's served operand
//! (packed SWIS containers or dense floats) and bias. It is
//! self-contained: loading a plan needs no weight files, no artifact
//! directory and NO quantization work (only the cheap per-plane
//! lane-mask binding in [`NativeModel::from_parts`]), which is what
//! lets pool workers warm from a cached plan instead of re-quantizing
//! per process.
//!
//! Container layout (little-endian, bytes):
//!
//! ```text
//!   magic "SWISPLAN"   version:u16   flags:u16   threads:u16
//!   provenance:u8      net name:str  layer table (kind/geometry rows)
//!   input [hw,hw,c]:u32x3            n_classes:u32
//!   n_variants:u16
//!   per variant: name:str scheme:u8 n_shifts:f64 group:u16
//!     n_parts:u32, per part: layer:str tag:u8
//!       dense:  count:u32 + f32 weights (filters-first)
//!       packed: len:u32 + `.swis` container (quant::serialize)
//!     bias: count:u32 + f32
//!   [version >= 2] n_sections:u16, per section: tag:u8 len:u32 payload
//!   fnv1a64 checksum of everything above: u64
//! ```
//!
//! `str` is `u16` length + UTF-8. The checksum is verified before any
//! BODY field is trusted (magic and version are read first so mismatch
//! errors stay legible); a flipped bit, a truncation or a version bump
//! all reject with a typed [`SwisError::Plan`].
//!
//! **Versioning / TuneParams.** An untuned plan serializes as version 1,
//! byte-identical to what pre-autotuner builds wrote and read. A plan
//! carrying machine-tuned kernel parameters serializes as version 2:
//! the version-1 body followed by a tagged section trailer; section tag
//! 1 is [`TuneParams`] (`variant:u8 row_block:u16 group_chunk:u16
//! threads:u16 cpu:str`). Unknown section tags are skipped, so a future
//! v2 writer's extra sections load fine here. Tuned params are pinned
//! to a CPU signature ([`crate::exec::simd::cpu_signature`]): loading a
//! plan on a different machine drops them (kernels fall back to host
//! defaults, [`EnginePlan::autotune`] re-derives) instead of dispatching
//! another machine's argmin.
//!
//! **Multi-tier plans (version 3).** A plan carrying a [`TierPolicy`] —
//! an ordered precision ladder over its own variants plus the measured
//! per-tier accuracy ratios and a degradation floor — serializes as
//! version 3: the same tagged trailer framing as version 2, with
//! section tag 2 holding the policy (`n_tiers:u16, per tier name:str
//! mse_ratio:f64, floor:u16`). Tier-less plans never write version 3,
//! so single-tier containers stay byte-identical to version 1/2
//! output. A loaded policy whose tier names do not all resolve to plan
//! variants (a "foreign" policy, e.g. after variants were re-prepared)
//! is dropped at assembly rather than served.

use std::collections::HashMap;
use std::path::Path;

use crate::coordinator::{Scheme, TierPolicy, VariantSpec};
use crate::error::{SwisError, SwisResult};
use crate::exec::tune::{tune_gemm, TuneOptions, TuneReport};
use crate::exec::{
    KernelVariant, LayerOperand, NativeModel, PreparedLayer, TuneParams, WeightProvenance,
};
use crate::nets::{ConvKind, ConvLayer, Network};
use crate::quant::serialize;

const MAGIC: &[u8; 8] = b"SWISPLAN";
/// The untuned container layout (and the newest layout pre-autotuner
/// builds can read).
const VERSION_BASE: u16 = 1;
/// Version 1 body + tagged section trailer (TuneParams et al).
const VERSION_TUNED: u16 = 2;
/// Version 2 trailer framing with the multi-tier [`TierPolicy`] section
/// present. Written only when a plan actually carries tiers.
const VERSION_TIERED: u16 = 3;
/// Section tag for [`TuneParams`] in the version-2 trailer.
const SECTION_TUNE: u8 = 1;
/// Section tag for [`TierPolicy`] in the version-3 trailer.
const SECTION_TIERS: u8 = 2;

/// A prepared engine: the planner output, packed layers and per-variant
/// operands for one network — everything [`super::Session`] and the
/// serving backends execute, serializable to/from `.swisplan`.
pub struct EnginePlan {
    net: Network,
    input: [usize; 3],
    n_classes: usize,
    /// Requested execution thread budget (0 = auto at session build).
    threads: usize,
    provenance: WeightProvenance,
    variants: Vec<VariantSpec>,
    /// Parallel to `variants`: each variant's served operands.
    parts: Vec<Vec<PreparedLayer>>,
    /// Ready-to-run models (callers share the whole plan via
    /// `Arc<EnginePlan>`; replicas are pointer clones of that).
    models: HashMap<String, NativeModel>,
    /// Machine-tuned kernel parameters, when a sweep ran (or a loaded
    /// container carried host-matching ones).
    tune: Option<TuneParams>,
    /// Precision ladder over this plan's own variants (version-3
    /// containers): ordered tier names, measured per-tier accuracy
    /// ratios, and the lowest tier admission may degrade to.
    tiers: Option<TierPolicy>,
}

impl EnginePlan {
    /// Assemble a plan from prepared per-variant operands (the tail of
    /// [`super::Engine::prepare`] and of [`EnginePlan::from_bytes`]).
    pub(crate) fn assemble(
        net: Network,
        threads: usize,
        provenance: WeightProvenance,
        variants: Vec<VariantSpec>,
        parts: Vec<Vec<PreparedLayer>>,
        tune: Option<TuneParams>,
        tiers: Option<TierPolicy>,
    ) -> SwisResult<EnginePlan> {
        if variants.is_empty() {
            return Err(SwisError::config("a plan needs at least one variant"));
        }
        if variants.len() != parts.len() {
            return Err(SwisError::plan(format!(
                "{} variants but {} operand sets",
                variants.len(),
                parts.len()
            )));
        }
        // params swept on a different machine are dropped here — kernels
        // keep host defaults and `autotune` re-derives on this CPU
        let tune = tune.filter(|t| t.matches_host()).map(|t| t.sanitized());
        // a policy naming tiers this plan does not actually serve (e.g.
        // stale after variants were re-prepared) is dropped, not served
        let tiers = tiers
            .filter(|p| p.tier_names().iter().all(|t| variants.iter().any(|v| &v.name == t)));
        let mut models = HashMap::new();
        let mut input = [0usize; 3];
        let mut n_classes = 0usize;
        for (spec, vp) in variants.iter().zip(&parts) {
            let mut model = NativeModel::from_parts(&net, vp).map_err(|e| {
                SwisError::plan_from(e)
                    .context(format!("binding variant '{}' of '{}'", spec.name, net.name))
            })?;
            if let Some(tp) = &tune {
                model.set_tune(tp);
            }
            input = model.input_shape();
            n_classes = model.n_classes();
            if models.insert(spec.name.clone(), model).is_some() {
                return Err(SwisError::config(format!("duplicate variant '{}'", spec.name)));
            }
        }
        Ok(EnginePlan {
            net,
            input,
            n_classes,
            threads,
            provenance,
            variants,
            parts,
            models,
            tune,
            tiers,
        })
    }

    pub fn net(&self) -> &Network {
        &self.net
    }

    pub fn net_name(&self) -> &str {
        &self.net.name
    }

    /// Per-request image shape `[hw, hw, c]`.
    pub fn input_shape(&self) -> [usize; 3] {
        self.input
    }

    pub fn n_classes(&self) -> usize {
        self.n_classes
    }

    /// Requested execution thread budget (0 = auto).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Thread budget a session/worker should resolve: an explicit plan
    /// budget wins; otherwise the autotuner's swept thread split (when
    /// its params were swept on this machine); otherwise 0 (= auto).
    pub fn preferred_threads(&self) -> usize {
        if self.threads != 0 {
            return self.threads;
        }
        match &self.tune {
            Some(t) if t.matches_host() && t.threads != 0 => t.threads,
            _ => 0,
        }
    }

    /// The machine-tuned kernel parameters this plan carries, if any.
    pub fn tune_params(&self) -> Option<&TuneParams> {
        self.tune.as_ref()
    }

    /// Record machine-tuned kernel parameters on this plan. Params whose
    /// CPU signature matches this host are sanitized and applied to
    /// every bound packed kernel immediately; foreign-host params are
    /// recorded verbatim for serialization (their origin host applies
    /// them at load; any other host drops them and re-derives).
    pub fn set_tune_params(&mut self, tp: TuneParams) {
        if tp.matches_host() {
            let tp = tp.sanitized();
            for m in self.models.values_mut() {
                m.set_tune(&tp);
            }
            self.tune = Some(tp);
        } else {
            self.tune = Some(tp);
        }
    }

    /// The precision ladder this plan carries, if any (version-3
    /// containers, or [`EnginePlan::set_tier_policy`]).
    pub fn tier_policy(&self) -> Option<&TierPolicy> {
        self.tiers.as_ref()
    }

    /// Record a precision ladder on this plan. Every tier must name a
    /// variant the plan actually serves; the container becomes
    /// version 3 on the next [`EnginePlan::to_bytes`].
    pub fn set_tier_policy(&mut self, policy: TierPolicy) -> SwisResult<()> {
        if let Some(missing) =
            policy.tier_names().iter().find(|t| !self.models.contains_key(t.as_str()))
        {
            return Err(SwisError::config(format!(
                "tier '{missing}' is not a variant of this plan (has: {})",
                self.variants.iter().map(|v| v.name.as_str()).collect::<Vec<_>>().join(", ")
            )));
        }
        self.tiers = Some(policy);
        Ok(())
    }

    /// Resolve the variant to actually execute for a request under
    /// down-tier pressure: `floor_tier` is the deepest tier index the
    /// caller will tolerate (admission derives it from queue pressure).
    /// Returns `(effective variant, degraded?)` — the request's own
    /// variant untouched when the plan has no policy, the variant is
    /// outside the ladder, or no degradation is needed.
    pub fn resolve_tier<'p>(&'p self, variant: &'p str, floor_tier: usize) -> (&'p str, bool) {
        match &self.tiers {
            Some(p) => p.resolve(variant, floor_tier),
            None => (variant, false),
        }
    }

    /// Run the bench-driven kernel autotuner ([`tune_gemm`]) against
    /// this plan's largest prepared GEMM, install the winning
    /// [`TuneParams`] on every bound packed kernel, and record them for
    /// serialization (the container becomes version 2). Fails with
    /// [`SwisError::Config`] when the plan has no packed layers (fp32 /
    /// truncation variants execute dense kernels with nothing to tune).
    pub fn autotune(&mut self, opts: &TuneOptions) -> SwisResult<TuneReport> {
        let probe = self
            .models
            .values()
            .filter_map(|m| m.largest_gemm())
            .max_by_key(|p| p.macs(1))
            .cloned()
            .ok_or_else(|| {
                SwisError::config(
                    "plan has no packed layers to autotune (fp32/truncation variants are dense)",
                )
            })?;
        let report = tune_gemm(&probe, opts)?;
        self.set_tune_params(report.best.clone());
        Ok(report)
    }

    pub fn provenance(&self) -> WeightProvenance {
        self.provenance
    }

    pub fn variants(&self) -> &[VariantSpec] {
        &self.variants
    }

    pub fn has_variant(&self, name: &str) -> bool {
        self.models.contains_key(name)
    }

    /// The ready-to-run model for a variant name.
    pub fn model(&self, variant: &str) -> Option<&NativeModel> {
        self.models.get(variant)
    }

    /// Total packed payload bits across all packed variants (the
    /// Sec. 3.3 accounting, summed).
    pub fn packed_payload_bits(&self) -> u64 {
        self.models.values().map(|m| m.packed_payload_bits).sum()
    }

    // ----------------------------------------------------------------
    // serialization
    // ----------------------------------------------------------------

    /// Serialize to the versioned `.swisplan` container. Every count and
    /// length field is RANGE-CHECKED before narrowing — a value that
    /// cannot fit its field is a loud [`SwisError::Plan`], never a
    /// silent truncation that would checksum as valid and decode to a
    /// different configuration.
    pub fn to_bytes(&self) -> SwisResult<Vec<u8>> {
        let mut w = Writer::new();
        w.bytes_raw(MAGIC);
        // untuned, tier-less plans keep the version-1 layout
        // byte-identical (and tuned single-tier plans the version-2
        // layout): each version bump is paid only by plans that carry
        // the new section
        w.u16(if self.tiers.is_some() {
            VERSION_TIERED
        } else if self.tune.is_some() {
            VERSION_TUNED
        } else {
            VERSION_BASE
        });
        w.u16(0); // flags, reserved
        w.u16(fit_u16(self.threads, "thread budget")?);
        w.u8(match self.provenance {
            WeightProvenance::Npz => 0,
            WeightProvenance::Surrogate => 1,
        });
        w.str(&self.net.name)?;
        w.u32(fit_u32(self.net.layers.len(), "layer count")?);
        for l in &self.net.layers {
            w.str(&l.name)?;
            w.u8(match l.kind {
                ConvKind::Standard => 0,
                ConvKind::Depthwise => 1,
            });
            for dim in [l.in_hw, l.in_c, l.k, l.stride, l.pad, l.out_c] {
                w.u32(fit_u32(dim, "layer dimension")?);
            }
        }
        for dim in self.input {
            w.u32(fit_u32(dim, "input dimension")?);
        }
        w.u32(fit_u32(self.n_classes, "class count")?);
        w.u16(fit_u16(self.variants.len(), "variant count")?);
        for (spec, parts) in self.variants.iter().zip(&self.parts) {
            w.str(&spec.name)?;
            w.u8(scheme_tag(spec.scheme));
            w.f64(spec.n_shifts);
            w.u16(fit_u16(spec.group_size, "group size")?);
            w.u32(fit_u32(parts.len(), "operand count")?);
            for p in parts {
                w.str(&p.name)?;
                match &p.operand {
                    LayerOperand::Dense(d) => {
                        w.u8(0);
                        w.u32(fit_u32(d.len(), "dense operand length")?);
                        for &v in d.iter() {
                            w.bytes_raw(&v.to_le_bytes());
                        }
                    }
                    LayerOperand::Packed(packed) => {
                        w.u8(1);
                        let bytes = serialize::to_bytes(packed).map_err(|e| {
                            SwisError::plan_from(e)
                                .context(format!("packing layer '{}'", p.name))
                        })?;
                        w.u32(fit_u32(bytes.len(), "packed operand length")?);
                        w.bytes_raw(&bytes);
                    }
                }
                w.u32(fit_u32(p.bias.len(), "bias length")?);
                for &v in &p.bias {
                    w.bytes_raw(&v.to_le_bytes());
                }
            }
        }
        let n_sections = self.tune.is_some() as u16 + self.tiers.is_some() as u16;
        if n_sections > 0 {
            // version-2/3 tagged section trailer
            w.u16(n_sections);
            if let Some(tp) = &self.tune {
                let mut s = Writer::new();
                s.u8(tp.variant.tag());
                s.u16(fit_u16(tp.row_block.min(u16::MAX as usize), "tuned row block")?);
                s.u16(fit_u16(tp.group_chunk.min(u16::MAX as usize), "tuned group chunk")?);
                s.u16(fit_u16(tp.threads.min(u16::MAX as usize), "tuned thread split")?);
                s.str(&tp.cpu)?;
                w.u8(SECTION_TUNE);
                w.u32(fit_u32(s.out.len(), "tune section length")?);
                w.bytes_raw(&s.out);
            }
            if let Some(pol) = &self.tiers {
                let mut s = Writer::new();
                s.u16(fit_u16(pol.tier_names().len(), "tier count")?);
                for (name, ratio) in pol.tier_names().iter().zip(pol.mse_ratios()) {
                    s.str(name)?;
                    s.f64(*ratio);
                }
                s.u16(fit_u16(pol.floor(), "tier floor")?);
                w.u8(SECTION_TIERS);
                w.u32(fit_u32(s.out.len(), "tier section length")?);
                w.bytes_raw(&s.out);
            }
        }
        let sum = fnv1a64(&w.out);
        w.bytes_raw(&sum.to_le_bytes());
        Ok(w.out)
    }

    /// Deserialize a `.swisplan` container: header, version and checksum
    /// are verified before anything is trusted, then kernels are bound
    /// from the stored operands (no quantization).
    pub fn from_bytes(bytes: &[u8]) -> SwisResult<EnginePlan> {
        if bytes.len() < MAGIC.len() + 2 || &bytes[..MAGIC.len()] != MAGIC {
            return Err(SwisError::plan("not a .swisplan container (bad magic)"));
        }
        let version = u16::from_le_bytes([bytes[8], bytes[9]]);
        if !(VERSION_BASE..=VERSION_TIERED).contains(&version) {
            return Err(SwisError::plan(format!(
                "unsupported .swisplan version {version} (this build reads versions \
                 {VERSION_BASE}..={VERSION_TIERED})"
            )));
        }
        if bytes.len() < MAGIC.len() + 2 + 8 {
            return Err(SwisError::plan("truncated .swisplan container"));
        }
        let body = &bytes[..bytes.len() - 8];
        let stored = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
        if fnv1a64(body) != stored {
            return Err(SwisError::plan("corrupt .swisplan container (checksum mismatch)"));
        }
        let mut r = Reader { b: body, pos: MAGIC.len() + 2 };
        let _flags = r.u16()?;
        let threads = r.u16()? as usize;
        let provenance = match r.u8()? {
            0 => WeightProvenance::Npz,
            1 => WeightProvenance::Surrogate,
            other => {
                return Err(SwisError::plan(format!("unknown weight provenance tag {other}")))
            }
        };
        let net_name = r.str()?;
        // count fields are untrusted until their entries actually parse:
        // clamp every pre-reservation by what the container could even
        // hold (min entry width 8 bytes), so a forged count is a typed
        // parse error downstream, never a multi-GB allocation attempt
        let max_entries = body.len() / 8;
        let cap = move |n: usize| n.min(max_entries);
        let n_layers = r.u32()? as usize;
        let mut layers = Vec::with_capacity(cap(n_layers));
        for _ in 0..n_layers {
            let name = r.str()?;
            let kind = match r.u8()? {
                0 => ConvKind::Standard,
                1 => ConvKind::Depthwise,
                other => return Err(SwisError::plan(format!("unknown layer kind tag {other}"))),
            };
            let dims: Vec<usize> = (0..6)
                .map(|_| r.u32().map(|v| v as usize))
                .collect::<SwisResult<_>>()?;
            layers.push(ConvLayer {
                name,
                kind,
                in_hw: dims[0],
                in_c: dims[1],
                k: dims[2],
                stride: dims[3],
                pad: dims[4],
                out_c: dims[5],
            });
        }
        let net = Network { name: net_name, layers };
        let input = [r.u32()? as usize, r.u32()? as usize, r.u32()? as usize];
        let n_classes = r.u32()? as usize;
        let n_variants = r.u16()? as usize;
        let mut variants = Vec::with_capacity(cap(n_variants));
        let mut parts = Vec::with_capacity(cap(n_variants));
        for _ in 0..n_variants {
            let name = r.str()?;
            let scheme = scheme_from_tag(r.u8()?)?;
            let n_shifts = r.f64()?;
            let group = r.u16()? as usize;
            let spec = VariantSpec::new(scheme, n_shifts, group)
                .map_err(|e| e.context(format!("variant '{name}' in plan")))?;
            if spec.name != name {
                return Err(SwisError::plan(format!(
                    "variant name '{name}' does not match its config '{}'",
                    spec.name
                )));
            }
            let n_parts = r.u32()? as usize;
            let mut vp = Vec::with_capacity(cap(n_parts));
            for _ in 0..n_parts {
                let lname = r.str()?;
                let operand = match r.u8()? {
                    0 => LayerOperand::Dense(std::sync::Arc::new(r.f32_vec()?)),
                    1 => {
                        let len = r.u32()? as usize;
                        let raw = r.take(len)?;
                        LayerOperand::Packed(serialize::from_bytes(raw).map_err(|e| {
                            SwisError::plan_from(e)
                                .context(format!("packed operand '{lname}'"))
                        })?)
                    }
                    other => {
                        return Err(SwisError::plan(format!("unknown operand tag {other}")))
                    }
                };
                let bias = r.f32_vec()?;
                vp.push(PreparedLayer { name: lname, operand, bias });
            }
            variants.push(spec);
            parts.push(vp);
        }
        let mut tune = None;
        let mut tiers = None;
        if version >= VERSION_TUNED {
            let n_sections = r.u16()? as usize;
            for _ in 0..n_sections {
                let tag = r.u8()?;
                let len = r.u32()? as usize;
                let raw = r.take(len)?;
                if tag == SECTION_TUNE {
                    let mut s = Reader { b: raw, pos: 0 };
                    let variant = KernelVariant::from_tag(s.u8()?).ok_or_else(|| {
                        SwisError::plan("unknown kernel variant tag in TuneParams section")
                    })?;
                    let row_block = s.u16()? as usize;
                    let group_chunk = s.u16()? as usize;
                    let threads = s.u16()? as usize;
                    let cpu = s.str()?;
                    // bytes past the known fields are future extensions;
                    // act_mask is a runtime knob, never serialized
                    tune = Some(TuneParams {
                        variant,
                        row_block,
                        group_chunk,
                        threads,
                        cpu,
                        act_mask: true,
                    });
                } else if tag == SECTION_TIERS {
                    let mut s = Reader { b: raw, pos: 0 };
                    let n = s.u16()? as usize;
                    let mut names = Vec::with_capacity(cap(n));
                    let mut ratios = Vec::with_capacity(cap(n));
                    for _ in 0..n {
                        names.push(s.str()?);
                        ratios.push(s.f64()?);
                    }
                    let floor = s.u16()? as usize;
                    tiers = Some(
                        TierPolicy::new(names, ratios, floor)
                            .map_err(|e| e.context("tier section in .swisplan"))?,
                    );
                }
                // unknown tags skip cleanly: length-prefixed sections keep
                // this reader forward-compatible within a version
            }
        }
        if r.pos != body.len() {
            return Err(SwisError::plan(format!(
                "trailing bytes in .swisplan at offset {}",
                r.pos
            )));
        }
        let plan = EnginePlan::assemble(net, threads, provenance, variants, parts, tune, tiers)?;
        if plan.input != input || plan.n_classes != n_classes {
            return Err(SwisError::plan(format!(
                "stored shape ({input:?} -> {n_classes}) disagrees with the descriptor \
                 ({:?} -> {})",
                plan.input, plan.n_classes
            )));
        }
        Ok(plan)
    }

    /// Write the container to `path` atomically (the shared
    /// [`crate::util::bench::write_atomic`] temp-file + rename, so a
    /// crash mid-write can never leave a half-plan behind).
    pub fn save(&self, path: &Path) -> SwisResult<()> {
        crate::util::bench::write_atomic(path, &self.to_bytes()?)
    }

    /// Read a `.swisplan` container from disk.
    pub fn load(path: &Path) -> SwisResult<EnginePlan> {
        let bytes = std::fs::read(path).map_err(|e| SwisError::io_at(path, e))?;
        EnginePlan::from_bytes(&bytes)
            .map_err(|e| e.context(format!("loading {}", path.display())))
    }
}

fn scheme_tag(s: Scheme) -> u8 {
    match s {
        Scheme::Fp32 => 0,
        Scheme::Swis => 1,
        Scheme::SwisC => 2,
        Scheme::WgtTrunc => 3,
    }
}

fn scheme_from_tag(t: u8) -> SwisResult<Scheme> {
    Ok(match t {
        0 => Scheme::Fp32,
        1 => Scheme::Swis,
        2 => Scheme::SwisC,
        3 => Scheme::WgtTrunc,
        other => return Err(SwisError::plan(format!("unknown scheme tag {other}"))),
    })
}

/// Range-check a count/length into a u16 container field.
fn fit_u16(v: usize, what: &str) -> SwisResult<u16> {
    u16::try_from(v)
        .map_err(|_| SwisError::plan(format!("{what} {v} exceeds the container's u16 field")))
}

/// Range-check a count/length into a u32 container field.
fn fit_u32(v: usize, what: &str) -> SwisResult<u32> {
    u32::try_from(v)
        .map_err(|_| SwisError::plan(format!("{what} {v} exceeds the container's u32 field")))
}

/// FNV-1a 64-bit — cheap corruption detection, not cryptography.
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

struct Writer {
    out: Vec<u8>,
}

impl Writer {
    fn new() -> Writer {
        Writer { out: Vec::new() }
    }

    fn bytes_raw(&mut self, b: &[u8]) {
        self.out.extend_from_slice(b);
    }

    fn u8(&mut self, v: u8) {
        self.out.push(v);
    }

    fn u16(&mut self, v: u16) {
        self.bytes_raw(&v.to_le_bytes());
    }

    fn u32(&mut self, v: u32) {
        self.bytes_raw(&v.to_le_bytes());
    }

    fn f64(&mut self, v: f64) {
        self.bytes_raw(&v.to_le_bytes());
    }

    fn str(&mut self, s: &str) -> SwisResult<()> {
        self.u16(fit_u16(s.len(), "string length")?);
        self.bytes_raw(s.as_bytes());
        Ok(())
    }
}

struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Engine, EngineConfig};

    /// A version-3 container whose ladder names variants THIS plan does
    /// not serve (hand-edited file, or a plan re-assembled against a
    /// different variant set): the loader must drop the ladder silently
    /// and serve untiered, not refuse the whole plan.
    #[test]
    fn loading_a_ladder_naming_unknown_variants_drops_it() {
        let cfg = EngineConfig::for_net("tinycnn")
            .unwrap()
            .variant(VariantSpec::swis(2.0, 4))
            .threads(1);
        let mut plan = Engine::prepare(cfg).unwrap();
        // bypass set_tier_policy's validation to emulate the foreign file
        plan.tiers = Some(
            TierPolicy::new(vec!["ghost@4".into(), "ghost@2".into()], vec![1.0, 5.0], 1).unwrap(),
        );
        let bytes = plan.to_bytes().unwrap();
        assert_eq!(bytes[8], 3, "the foreign ladder still travels as version 3");
        let loaded = EnginePlan::from_bytes(&bytes).unwrap();
        assert!(loaded.tier_policy().is_none(), "unknown-variant ladder must drop at load");
        assert_eq!(loaded.variants().len(), plan.variants().len());
    }
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> SwisResult<&'a [u8]> {
        if self.pos + n > self.b.len() {
            return Err(SwisError::plan(format!("truncated .swisplan at byte {}", self.pos)));
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> SwisResult<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> SwisResult<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> SwisResult<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> SwisResult<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str(&mut self) -> SwisResult<String> {
        let n = self.u16()? as usize;
        let raw = self.take(n)?;
        String::from_utf8(raw.to_vec())
            .map_err(|_| SwisError::plan(format!("invalid UTF-8 string at byte {}", self.pos)))
    }

    fn f32_vec(&mut self) -> SwisResult<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n.checked_mul(4).ok_or_else(|| {
            SwisError::plan("overflowing f32 vector length in .swisplan")
        })?)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}
