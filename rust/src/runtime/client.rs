//! Thin wrapper over the `xla` crate's PJRT CPU client.

use anyhow::{bail, Context, Result};
use std::path::Path;

use crate::util::tensor::Tensor;

/// A PJRT client owning compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
}

/// One compiled HLO module.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Number of leaves in the (tupled) result.
    pub n_outputs: usize,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact.
    pub fn compile_hlo_text(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe, n_outputs: 1 })
    }
}

impl Executable {
    /// Execute on f32 tensors; returns the flattened data of each output
    /// leaf. Inputs must match the lowered arity/shapes (the manifest is
    /// the source of truth; [`super::ModelBundle`] enforces it).
    pub fn run_f32(&self, inputs: &[Tensor<f32>]) -> Result<Vec<Tensor<f32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(t.data()).reshape(&dims).map_err(anyhow::Error::from)
            })
            .collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .context("PJRT execute")?;
        if result.is_empty() || result[0].is_empty() {
            bail!("PJRT returned no buffers");
        }
        let lit = result[0][0].to_literal_sync().context("device->host")?;
        // aot.py lowers with return_tuple=True: unwrap the tuple leaves.
        let leaves = lit.to_tuple()?;
        let mut out = Vec::with_capacity(leaves.len());
        for leaf in leaves {
            let shape = leaf.array_shape()?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let data = leaf.to_vec::<f32>()?;
            out.push(Tensor::new(&dims, data)?);
        }
        Ok(out)
    }
}
