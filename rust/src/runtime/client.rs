//! Thin wrapper over the `xla` crate's PJRT CPU client.

use anyhow::{bail, Context, Result};
use std::path::Path;

use crate::util::tensor::Tensor;

/// A PJRT client owning compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
}

/// One compiled HLO module.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Number of leaves in the (tupled) result, derived from the HLO
    /// text's entry computation at compile time; `None` when the text
    /// was not recognizable (arity checks are then skipped rather than
    /// guessed — a wrong guess would reject working artifacts).
    pub n_outputs: Option<usize>,
}

/// Output arity of an HLO-text module: the number of leaves in the entry
/// computation's result type. Prefers the `entry_computation_layout`
/// header (always present in jax-serialized text); falls back to the
/// `ENTRY` computation's `ROOT` instruction type. `None` when neither is
/// recognizable.
///
/// This is how [`Runtime::compile_hlo_text`] sizes `n_outputs` instead of
/// hardcoding 1 — a tupled multi-output artifact would otherwise be
/// silently truncated by callers trusting the field.
pub fn hlo_output_arity(text: &str) -> Option<usize> {
    // entry_computation_layout={(f32[8,32,32,3]{...}, ...)->(f32[8,10]{...})}
    if let Some(pos) = text.find("entry_computation_layout=") {
        let rest = &text[pos..];
        if let Some(arrow) = rest.find("->") {
            return type_arity(rest[arrow + 2..].trim_start());
        }
    }
    // ENTRY %main ... { ... ROOT %t = (f32[...], f32[...]) tuple(...) }
    let entry = text.find("\nENTRY ").map(|p| p + 1).or_else(|| {
        if text.starts_with("ENTRY ") {
            Some(0)
        } else {
            None
        }
    })?;
    let body = &text[entry..];
    let root = body.find("ROOT ")?;
    let after_eq = body[root..].find(" = ").map(|p| root + p + 3)?;
    type_arity(body[after_eq..].trim_start())
}

/// Arity of an HLO type string starting at `s`: a parenthesized tuple
/// counts its top-level elements (commas inside `[]`/`{}` dim lists are
/// nested); anything else is one leaf.
fn type_arity(s: &str) -> Option<usize> {
    let s = s.trim_start();
    if !s.starts_with('(') {
        return Some(1);
    }
    let mut depth = 0usize;
    let mut elems = 1usize;
    let mut saw_any = false;
    for (i, c) in s.char_indices() {
        match c {
            '(' | '[' | '{' => depth += 1,
            ')' | ']' | '}' => {
                depth = depth.checked_sub(1)?;
                if depth == 0 {
                    // empty tuple "()" has zero leaves
                    return Some(if saw_any { elems } else { 0 });
                }
            }
            ',' if depth == 1 => elems += 1,
            c if !c.is_whitespace() && i > 0 => saw_any = true,
            _ => {}
        }
    }
    None
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact. The executable's output
    /// arity is derived from the module text (see [`hlo_output_arity`]).
    pub fn compile_hlo_text(&self, path: &Path) -> Result<Executable> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading HLO text {}", path.display()))?;
        let n_outputs = hlo_output_arity(&text);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe, n_outputs })
    }
}

impl Executable {
    /// Execute on f32 tensors; returns the flattened data of each output
    /// leaf. Inputs must match the lowered arity/shapes (the manifest is
    /// the source of truth; [`super::ModelBundle`] enforces it).
    pub fn run_f32(&self, inputs: &[Tensor<f32>]) -> Result<Vec<Tensor<f32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(t.data()).reshape(&dims).map_err(anyhow::Error::from)
            })
            .collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .context("PJRT execute")?;
        if result.is_empty() || result[0].is_empty() {
            bail!("PJRT returned no buffers");
        }
        let lit = result[0][0].to_literal_sync().context("device->host")?;
        // aot.py lowers with return_tuple=True: unwrap the tuple leaves.
        let leaves = lit.to_tuple()?;
        if let Some(n) = self.n_outputs {
            if leaves.len() != n {
                bail!(
                    "executable returned {} leaves but the module declares {n} outputs",
                    leaves.len()
                );
            }
        }
        let mut out = Vec::with_capacity(leaves.len());
        for leaf in leaves {
            let shape = leaf.array_shape()?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let data = leaf.to_vec::<f32>()?;
            out.push(Tensor::new(&dims, data)?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arity_from_entry_computation_layout() {
        let text = "HloModule jit_forward, \
                    entry_computation_layout={(f32[8,32,32,3]{3,2,1,0}, \
                    f32[3,3,3,32]{3,2,1,0})->(f32[8,10]{1,0})}\n\
                    ENTRY %main {}\n";
        assert_eq!(hlo_output_arity(text), Some(1));
    }

    #[test]
    fn arity_counts_tuple_leaves_not_dim_commas() {
        let text = "HloModule m, entry_computation_layout=\
                    {(f32[4,4]{1,0})->(f32[8,10]{1,0}, f32[2,3,4]{2,1,0}, s32[7]{0})}\n";
        assert_eq!(hlo_output_arity(text), Some(3));
    }

    #[test]
    fn arity_non_tuple_result_is_one() {
        let text = "HloModule m, entry_computation_layout={(f32[2]{0})->f32[2,5]{1,0}}\n";
        assert_eq!(hlo_output_arity(text), Some(1));
    }

    #[test]
    fn arity_from_entry_root_fallback() {
        let text = "HloModule m\n\
                    %helper (a: f32[2]) -> f32[2] {\n  ROOT %a = f32[2]{0} parameter(0)\n}\n\
                    ENTRY %main (p: f32[2]) -> (f32[2], f32[2]) {\n\
                    ROOT %t = (f32[2]{0}, f32[2]{0}) tuple(%p, %p)\n}\n";
        assert_eq!(hlo_output_arity(text), Some(2));
    }

    #[test]
    fn arity_unparseable_is_none() {
        assert_eq!(hlo_output_arity("not hlo at all"), None);
        assert_eq!(hlo_output_arity(""), None);
    }
}
