//! Artifact manifest + model bundle loading.
//!
//! `make artifacts` writes `artifacts/manifest.json` indexing every
//! HLO-text module with its input names/shapes/dtypes. The coordinator
//! loads the bundle once at startup: weights from `tinycnn_weights.npz`,
//! one compiled executable per batch-size variant.

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use super::client::{Executable, Runtime};
use crate::util::json::{self, Json};
use crate::util::npy;
use crate::util::tensor::Tensor;

/// Shape/dtype of one executable input.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One entry of manifest.json.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub file: String,
    pub kind: String,
    pub batch: Option<usize>,
    pub inputs: Vec<TensorSpec>,
    /// ALL output leaves, in tuple order (never empty). Singular-`output`
    /// manifests get one entry; multi-output artifacts list them under
    /// `outputs`.
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactSpec {
    /// First (primary) output leaf — what single-output callers consume.
    /// Derived, so it can never disagree with `outputs`.
    pub fn output(&self) -> &TensorSpec {
        &self.outputs[0]
    }
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub baseline_accuracy: f64,
    pub artifacts: Vec<ArtifactSpec>,
    pub dir: PathBuf,
}

fn tensor_spec(j: &Json, name: &str) -> Result<TensorSpec> {
    let shape = j
        .get("shape")
        .and_then(|s| s.as_arr())
        .context("spec missing shape")?
        .iter()
        .map(|d| d.as_usize().context("bad dim"))
        .collect::<Result<Vec<_>>>()?;
    Ok(TensorSpec {
        name: name.to_string(),
        shape,
        dtype: j
            .get("dtype")
            .and_then(|d| d.as_str())
            .unwrap_or("float32")
            .to_string(),
    })
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let raw = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json — run `make artifacts`", dir.display()))?;
        let j = json::parse(&raw)?;
        let mut artifacts = Vec::new();
        for a in j.get("artifacts").and_then(|a| a.as_arr()).context("manifest: no artifacts")? {
            let inputs = a
                .get("inputs")
                .and_then(|i| i.as_arr())
                .context("artifact: no inputs")?
                .iter()
                .map(|i| {
                    let name = i.get("name").and_then(|n| n.as_str()).unwrap_or("");
                    tensor_spec(i, name)
                })
                .collect::<Result<Vec<_>>>()?;
            // "outputs" (tuple order) when present, else singular "output"
            let outputs: Vec<TensorSpec> = match a.get("outputs").and_then(|o| o.as_arr()) {
                Some(arr) => arr
                    .iter()
                    .enumerate()
                    .map(|(i, o)| {
                        let name = o
                            .get("name")
                            .and_then(|n| n.as_str())
                            .map_or_else(|| format!("output{i}"), str::to_string);
                        tensor_spec(o, &name)
                    })
                    .collect::<Result<Vec<_>>>()?,
                None => vec![tensor_spec(
                    a.get("output").context("artifact: no output(s)")?,
                    "output",
                )?],
            };
            if outputs.is_empty() {
                bail!("artifact: empty outputs list");
            }
            artifacts.push(ArtifactSpec {
                file: a.get("file").and_then(|f| f.as_str()).context("artifact: no file")?.to_string(),
                kind: a.get("kind").and_then(|k| k.as_str()).unwrap_or("model").to_string(),
                batch: a.get("batch").and_then(|b| b.as_usize()),
                inputs,
                outputs,
            });
        }
        Ok(Manifest {
            baseline_accuracy: j
                .get("baseline_accuracy")
                .and_then(|b| b.as_f64())
                .unwrap_or(f64::NAN),
            artifacts,
            dir: dir.to_path_buf(),
        })
    }

    pub fn find(&self, kind: &str, batch: Option<usize>) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.kind == kind && (batch.is_none() || a.batch == batch))
    }

    /// All batch sizes available for a given artifact kind, ascending.
    pub fn batches(&self, kind: &str) -> Vec<usize> {
        let mut b: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == kind)
            .filter_map(|a| a.batch)
            .collect();
        b.sort_unstable();
        b
    }
}

/// A ready-to-serve model: compiled executables per batch size plus the
/// FP32 weight set (which callers may substitute with SWIS-dequantized
/// weights — the graph takes weights as arguments by design).
pub struct ModelBundle {
    pub manifest: Manifest,
    pub weights: HashMap<String, Tensor<f32>>,
    /// Input names after the leading `images` input, in lowering order.
    pub weight_order: Vec<String>,
    executables: HashMap<usize, Executable>,
    pub kind: String,
}

impl ModelBundle {
    /// Load manifest + weights and compile all `kind` variants.
    pub fn load(rt: &Runtime, dir: &Path, kind: &str) -> Result<ModelBundle> {
        let manifest = Manifest::load(dir)?;
        let npz = npy::load_npz(&dir.join("tinycnn_weights.npz"))?;
        let weights: HashMap<String, Tensor<f32>> =
            npz.into_iter().map(|(k, v)| (k, v.as_f32())).collect();
        let batches = manifest.batches(kind);
        if batches.is_empty() {
            bail!("no '{kind}' artifacts in manifest");
        }
        let mut executables = HashMap::new();
        let mut weight_order = Vec::new();
        for &b in &batches {
            let spec = manifest.find(kind, Some(b)).unwrap();
            if weight_order.is_empty() {
                weight_order = spec.inputs[1..].iter().map(|i| i.name.clone()).collect();
            }
            let exe = rt.compile_hlo_text(&dir.join(&spec.file))?;
            // when the module text yields an arity, it must agree with
            // the manifest — a mismatch means stale artifacts or a wrong
            // manifest, and trusting either silently truncates tupled
            // results (undetectable text parses skip the check)
            if let Some(n) = exe.n_outputs {
                if n != spec.outputs.len() {
                    bail!(
                        "{}: HLO declares {n} output leaves, manifest lists {}",
                        spec.file,
                        spec.outputs.len()
                    );
                }
            }
            executables.insert(b, exe);
        }
        Ok(ModelBundle {
            manifest,
            weights,
            weight_order,
            executables,
            kind: kind.to_string(),
        })
    }

    pub fn batch_sizes(&self) -> Vec<usize> {
        let mut b: Vec<usize> = self.executables.keys().copied().collect();
        b.sort_unstable();
        b
    }

    /// Smallest compiled batch >= n, or the largest available.
    pub fn pick_batch(&self, n: usize) -> usize {
        let sizes = self.batch_sizes();
        *sizes.iter().find(|&&b| b >= n).unwrap_or(sizes.last().unwrap())
    }

    /// Split `n` requests into compiled-size chunks, greedily taking the
    /// largest variant that fits and covering the remainder exactly with
    /// smaller ones — avoids padding a half-full batch up to the largest
    /// compiled size (PJRT cost is ~affine in batch, so padding 20
    /// requests to 64 wastes ~2x compute; see EXPERIMENTS.md §Perf).
    pub fn plan_chunks(&self, n: usize) -> Vec<usize> {
        let sizes = self.batch_sizes(); // ascending
        let mut out = Vec::new();
        let mut left = n;
        while left > 0 {
            // largest compiled size that fits; if none fits, the smallest
            // compiled size serves the tail as a padded chunk
            let b = *sizes.iter().rev().find(|&&b| b <= left).unwrap_or(&sizes[0]);
            out.push(b);
            left = left.saturating_sub(b);
        }
        out
    }

    /// Run a batch of images through the compiled graph with the given
    /// weight set (falls back to the bundled FP32 weights).
    pub fn infer(
        &self,
        images: &Tensor<f32>,
        weights: Option<&HashMap<String, Tensor<f32>>>,
    ) -> Result<Tensor<f32>> {
        let n = images.shape()[0];
        let b = self.pick_batch(n);
        let exe = self.executables.get(&b).context("no executable")?;
        let spec = self.manifest.find(&self.kind, Some(b)).context("no spec")?;
        // pad the image batch up to the compiled size
        let img_spec = &spec.inputs[0];
        let per = img_spec.shape[1..].iter().product::<usize>();
        let mut data = images.data().to_vec();
        if n != b {
            if n > b {
                bail!("batch {n} exceeds largest compiled variant {b}");
            }
            data.resize(b * per, 0.0);
        }
        let mut inputs = vec![Tensor::new(&img_spec.shape, data)?];
        let w = weights.unwrap_or(&self.weights);
        for name in &self.weight_order {
            inputs.push(w.get(name).with_context(|| format!("missing weight {name}"))?.clone());
        }
        let mut out = exe.run_f32(&inputs)?;
        let logits = out.remove(0);
        if n == b {
            return Ok(logits);
        }
        // strip padding rows
        let classes = logits.shape()[1];
        Ok(Tensor::new(
            &[n, classes],
            logits.data()[..n * classes].to_vec(),
        )?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn art_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn manifest_parses() {
        if !art_dir().join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        let m = Manifest::load(&art_dir()).unwrap();
        assert!(m.baseline_accuracy > 0.5, "baseline {}", m.baseline_accuracy);
        assert_eq!(m.batches("model"), vec![1, 8, 64]);
        let b8 = m.find("model", Some(8)).unwrap();
        assert_eq!(b8.inputs[0].shape, vec![8, 32, 32, 3]);
        assert_eq!(b8.output().shape, vec![8, 10]);
        assert_eq!(b8.outputs.len(), 1);
    }

    #[test]
    fn missing_dir_errors() {
        assert!(Manifest::load(Path::new("/nonexistent")).is_err());
    }

    #[test]
    fn outputs_list_parses_with_singular_fallback() {
        let dir = std::env::temp_dir().join("swis_manifest_outputs_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"baseline_accuracy": 0.9, "artifacts": [
                {"file": "multi.hlo.txt", "kind": "multi", "batch": 1,
                 "inputs": [{"name": "images", "shape": [1, 32, 32, 3]}],
                 "outputs": [{"name": "logits", "shape": [1, 10]},
                             {"shape": [1, 128]}]},
                {"file": "single.hlo.txt", "kind": "model", "batch": 1,
                 "inputs": [{"name": "images", "shape": [1, 32, 32, 3]}],
                 "output": {"shape": [1, 10]}}
            ]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        let multi = m.find("multi", Some(1)).unwrap();
        assert_eq!(multi.outputs.len(), 2);
        assert_eq!(multi.output().shape, vec![1, 10]);
        assert_eq!(multi.outputs[0].name, "logits");
        assert_eq!(multi.outputs[1].name, "output1");
        assert_eq!(multi.outputs[1].shape, vec![1, 128]);
        let single = m.find("model", Some(1)).unwrap();
        assert_eq!(single.outputs.len(), 1);
        assert_eq!(single.output().shape, vec![1, 10]);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
