//! PJRT runtime (build-time Python, run-time Rust): loads the HLO-text
//! artifacts `python/compile/aot.py` emits, compiles them on the PJRT CPU
//! client, and executes them from the coordinator's hot path. Python is
//! never on the request path — the Rust binary is self-contained once
//! `make artifacts` has run.
//!
//! HLO *text* is the interchange format: jax >= 0.5 serializes
//! HloModuleProto with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README).

mod artifacts;
mod client;

pub use artifacts::{ArtifactSpec, Manifest, ModelBundle, TensorSpec};
pub use client::{Executable, Runtime};
