//! The execution runtime behind the coordinator: the [`Backend`] trait
//! plus its two implementations — the PJRT/AOT path (this module's
//! [`Runtime`] / [`ModelBundle`], loading the HLO-text artifacts
//! `python/compile/aot.py` emits) and the native SWIS engine
//! ([`crate::exec`], packed-operand execution with no PJRT and no
//! artifacts). Python is never on the request path on either backend.
//!
//! HLO *text* is the interchange format: jax >= 0.5 serializes
//! HloModuleProto with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README).

mod artifacts;
mod backend;
mod client;

pub use artifacts::{ArtifactSpec, Manifest, ModelBundle, TensorSpec};
pub use backend::{
    create_backend, create_factory, create_factory_net, Backend, BackendFactory, BackendKind,
    NativeBackend, NativeFactory, PjrtBackend, PjrtFactory,
};
pub use client::{hlo_output_arity, Executable, Runtime};
