//! Execution backends behind the serving coordinator.
//!
//! [`Backend`] is the compile/load/execute seam: the coordinator's worker
//! thread owns one backend and routes every dispatched batch through it.
//! Two implementations ship:
//!
//! * [`PjrtBackend`] — the AOT path: compiled HLO-text artifacts executed
//!   by the PJRT client, weight variants as dequantized fp32 sets fed to
//!   the weight-agnostic graph ([`super::ModelBundle`]).
//! * [`NativeBackend`] — the SWIS-native path: a [`Session`] over an
//!   `Arc<`[`EnginePlan`]`>`, executing packed operands directly. Needs
//!   no PJRT and no artifacts, and is the default whenever the AOT path
//!   is unavailable.
//!
//! [`BackendKind::Auto`] picks PJRT when the artifacts + runtime are
//! present and falls back to native, so `Coordinator::start` serves in
//! every environment.
//!
//! Scale-out seam: a [`BackendFactory`] is the `Send + Sync` *recipe* for
//! a backend. The worker pool hands one factory to N worker threads;
//! each thread calls [`BackendFactory::make`] so thread-affine handles
//! (PJRT) are constructed where they execute, while the native factory
//! shares ONE prepared [`EnginePlan`] across all workers through an
//! `Arc` — quantization and warm-up happen exactly once per pool, and a
//! factory built with [`NativeFactory::from_plan`] (e.g. from a loaded
//! `.swisplan` file) performs zero quantization at warm-up (pinned by
//! `tests/plan_warmup.rs`).
//!
//! Every trait method fails with the typed [`SwisError`] taxonomy so the
//! pool can route failures by class instead of by message string.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use super::{ModelBundle, Runtime};
use crate::api::{Engine, EngineConfig, EnginePlan, Session};
use crate::coordinator::{TierPolicy, VariantSpec, WeightVariants};
use crate::error::{SwisError, SwisResult};
use crate::nets::Network;
use crate::util::tensor::Tensor;

/// A loaded model able to execute image batches for named weight
/// variants. Implementations are created AND consumed on the coordinator
/// worker thread (PJRT handles are thread-affine), so the trait requires
/// neither `Send` nor `Sync` — the real xla-rs types need not provide
/// them.
pub trait Backend {
    /// Short identifier for logs/metrics ("pjrt" | "native").
    fn name(&self) -> &'static str;

    fn has_variant(&self, name: &str) -> bool;

    /// Split a group of `n` same-variant requests into execution batch
    /// sizes (PJRT: compiled variants; native: one dynamic batch).
    fn plan_chunks(&self, n: usize) -> Vec<usize>;

    /// Per-request image shape `[hw, hw, c]` this backend executes. The
    /// default is the TinyCNN 32x32x3 contract (the PJRT artifacts and
    /// every pre-zoo caller); the native backend reports whichever zoo
    /// net its plan was prepared for, and the pool sizes admission
    /// checks off it.
    fn input_shape(&self) -> [usize; 3] {
        [32, 32, 3]
    }

    /// Execute a `(n, hw, hw, c)` image batch under `variant`, returning
    /// `(n, n_classes)` logits. Failures are typed: callers match
    /// [`SwisError::Backend`] instead of grepping messages.
    fn infer(&self, variant: &str, images: &Tensor<f32>) -> SwisResult<Tensor<f32>>;
}

/// Which backend the coordinator should build.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// PJRT when artifacts + runtime exist, else native.
    Auto,
    Pjrt,
    Native,
}

impl BackendKind {
    pub fn parse(s: &str) -> SwisResult<BackendKind> {
        Ok(match s {
            "auto" => BackendKind::Auto,
            "pjrt" => BackendKind::Pjrt,
            "native" => BackendKind::Native,
            other => {
                return Err(SwisError::config(format!(
                    "unknown backend '{other}' (expected auto|pjrt|native)"
                )))
            }
        })
    }
}

/// A `Send + Sync` recipe for constructing per-worker backends. The pool
/// clones one factory across its worker threads; `make` runs on the
/// worker thread itself so thread-affine handles (PJRT) are owned where
/// they execute.
pub trait BackendFactory: Send + Sync {
    /// Short identifier for logs ("pjrt" | "native" | test doubles).
    fn name(&self) -> &'static str;

    /// Build one backend on the CALLING thread. `pool_workers` is the
    /// total worker count of the pool being assembled, so implementations
    /// can split intra-op thread budgets instead of oversubscribing
    /// `workers x default_threads` OS threads.
    fn make(&self, pool_workers: usize) -> SwisResult<Box<dyn Backend>>;

    /// The precision ladder the pool's admission should degrade along
    /// under queue pressure, when the underlying plan carries one
    /// (multi-tier version-3 `.swisplan`). Default: none — admission
    /// never rewrites a request's variant.
    fn tier_policy(&self) -> Option<TierPolicy> {
        None
    }
}

/// Native recipe: one shared prepared [`EnginePlan`] — built here (once)
/// or loaded from a `.swisplan` file — handed to each worker as an `Arc`
/// clone. Workers never quantize.
pub struct NativeFactory {
    plan: Arc<EnginePlan>,
}

impl NativeFactory {
    /// TinyCNN factory (the pre-zoo entry point).
    pub fn load(dir: Option<&Path>, variants: &[VariantSpec]) -> SwisResult<NativeFactory> {
        NativeFactory::load_net(dir, &crate::nets::tinycnn().with_fc(), variants)
    }

    /// Factory for any zoo network (pass the net with its FC head, e.g.
    /// `by_name("mobilenet_v2").unwrap().with_fc()`): runs the offline
    /// [`Engine::prepare`] step once, on the caller.
    pub fn load_net(
        dir: Option<&Path>,
        net: &Network,
        variants: &[VariantSpec],
    ) -> SwisResult<NativeFactory> {
        let mut cfg = EngineConfig::with_network(net.clone()).variants(variants.to_vec());
        if let Some(d) = dir {
            cfg = cfg.artifacts(d);
        }
        Ok(NativeFactory::from_plan(Arc::new(Engine::prepare(cfg)?)))
    }

    /// Factory over an already-prepared plan (in-memory or loaded from a
    /// `.swisplan` container) — the zero-quantization warm-up path.
    pub fn from_plan(plan: Arc<EnginePlan>) -> NativeFactory {
        NativeFactory { plan }
    }

    /// The shared plan this factory replicates.
    pub fn plan(&self) -> &Arc<EnginePlan> {
        &self.plan
    }
}

impl BackendFactory for NativeFactory {
    fn name(&self) -> &'static str {
        "native"
    }

    fn make(&self, pool_workers: usize) -> SwisResult<Box<dyn Backend>> {
        Ok(Box::new(NativeBackend::replicated(Arc::clone(&self.plan), pool_workers)))
    }

    fn tier_policy(&self) -> Option<TierPolicy> {
        self.plan.tier_policy().cloned()
    }
}

/// PJRT recipe: every worker compiles/loads its own executable set on its
/// own thread (PJRT handles are thread-affine, so the prepared state
/// cannot be shared the way the native plan is).
pub struct PjrtFactory {
    dir: PathBuf,
    variants: Vec<VariantSpec>,
}

impl BackendFactory for PjrtFactory {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn make(&self, _pool_workers: usize) -> SwisResult<Box<dyn Backend>> {
        Ok(Box::new(PjrtBackend::load(&self.dir, &self.variants)?))
    }
}

/// Resolve a [`BackendKind`] into a factory. `Auto` probes the manifest
/// and PJRT client availability here (once, on the caller) and falls back
/// to the native factory; artifact *content* errors then surface at
/// worker warm-up as hard failures rather than silent fallbacks. The
/// probe constructs one throwaway PJRT client per pool START (not per
/// worker) — the price of deciding the backend uniformly before any
/// worker spawns, so an N-worker pool can never split across backends.
pub fn create_factory(
    kind: BackendKind,
    dir: &Path,
    variants: &[VariantSpec],
) -> SwisResult<Box<dyn BackendFactory>> {
    create_factory_net(kind, dir, &crate::nets::tinycnn().with_fc(), variants)
}

/// [`create_factory`] for any zoo network. The PJRT artifacts compile
/// the TinyCNN graph only, so a non-TinyCNN net forces the native
/// engine: explicit `Pjrt` is a hard error and `Auto` skips the probe.
pub fn create_factory_net(
    kind: BackendKind,
    dir: &Path,
    net: &Network,
    variants: &[VariantSpec],
) -> SwisResult<Box<dyn BackendFactory>> {
    if net.name != "tinycnn" {
        return match kind {
            BackendKind::Pjrt => Err(SwisError::config(format!(
                "PJRT artifacts are TinyCNN-only; '{}' needs --backend native",
                net.name
            ))),
            _ => Ok(Box::new(NativeFactory::load_net(Some(dir), net, variants)?)),
        };
    }
    match kind {
        BackendKind::Pjrt => {
            Ok(Box::new(PjrtFactory { dir: dir.to_path_buf(), variants: variants.to_vec() }))
        }
        BackendKind::Native => Ok(Box::new(NativeFactory::load(Some(dir), variants)?)),
        BackendKind::Auto => {
            if dir.join("manifest.json").exists() {
                match Runtime::cpu() {
                    Ok(_probe) => {
                        return Ok(Box::new(PjrtFactory {
                            dir: dir.to_path_buf(),
                            variants: variants.to_vec(),
                        }))
                    }
                    Err(e) => {
                        eprintln!("PJRT backend unavailable ({e:#}); falling back to native")
                    }
                }
            } else {
                // loud on purpose: a mistyped --artifacts path must not
                // silently look like a healthy trained-model deployment
                eprintln!(
                    "no PJRT artifacts at {}; serving on the native backend",
                    dir.display()
                );
            }
            Ok(Box::new(NativeFactory::load(Some(dir), variants)?))
        }
    }
}

/// Build one backend for an artifact directory + variant list (the
/// 1-worker convenience over [`create_factory`]).
pub fn create_backend(
    kind: BackendKind,
    dir: &Path,
    variants: &[VariantSpec],
) -> SwisResult<Box<dyn Backend>> {
    create_factory(kind, dir, variants)?.make(1)
}

/// The AOT/PJRT execution path.
pub struct PjrtBackend {
    /// Owns the PJRT client the executables were compiled on.
    _rt: Runtime,
    bundle: ModelBundle,
    sets: WeightVariants,
}

impl PjrtBackend {
    pub fn load(dir: &Path, variants: &[VariantSpec]) -> SwisResult<PjrtBackend> {
        let build = || -> anyhow::Result<PjrtBackend> {
            let rt = Runtime::cpu()?;
            let bundle = ModelBundle::load(&rt, dir, "model")?;
            let sets = WeightVariants::build(&bundle.weights, variants)?;
            Ok(PjrtBackend { _rt: rt, bundle, sets })
        };
        build().map_err(SwisError::backend_from)
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn has_variant(&self, name: &str) -> bool {
        self.sets.get(name).is_some()
    }

    fn plan_chunks(&self, n: usize) -> Vec<usize> {
        self.bundle.plan_chunks(n)
    }

    fn infer(&self, variant: &str, images: &Tensor<f32>) -> SwisResult<Tensor<f32>> {
        let weights = self
            .sets
            .get(variant)
            .ok_or_else(|| SwisError::backend(format!("unknown variant '{variant}'")))?;
        self.bundle
            .infer(images, Some(weights))
            .map_err(SwisError::backend_from)
    }
}

/// The native SWIS execution path: a [`Session`] over the shared
/// prepared plan — for ANY zoo network, not just TinyCNN — executing
/// packed operands directly. Replicating the backend across pool workers
/// is an `Arc` pointer clone of the plan plus a per-worker thread split;
/// quantization and packing ran once (or not at all, when the plan came
/// from a `.swisplan` file).
pub struct NativeBackend {
    session: Session,
}

impl NativeBackend {
    /// TinyCNN backend (the pre-zoo entry point).
    pub fn load(dir: Option<&Path>, variants: &[VariantSpec]) -> SwisResult<NativeBackend> {
        NativeBackend::load_net(dir, &crate::nets::tinycnn().with_fc(), variants)
    }

    /// Prepare a plan for a zoo network (trained npz weights when
    /// present, loud deterministic surrogates otherwise) and build the
    /// backend over it.
    pub fn load_net(
        dir: Option<&Path>,
        net: &Network,
        variants: &[VariantSpec],
    ) -> SwisResult<NativeBackend> {
        Ok(NativeFactory::load_net(dir, net, variants)?.into_backend())
    }

    /// Backend over an existing plan with the plan's own thread budget.
    pub fn from_plan(plan: Arc<EnginePlan>) -> NativeBackend {
        NativeBackend { session: Session::new(plan) }
    }

    /// Per-worker replica sharing the prepared plan; the intra-op thread
    /// budget is split across the pool so N workers do not oversubscribe
    /// N x `default_threads` OS threads. Results are thread-count
    /// invariant (pinned by `tests/native_equiv.rs`), so the split never
    /// changes logits.
    pub fn replicated(plan: Arc<EnginePlan>, pool_workers: usize) -> NativeBackend {
        let base = match plan.preferred_threads() {
            0 => crate::quant::planner::default_threads(),
            t => t,
        };
        let split = (base / pool_workers.max(1)).max(1);
        NativeBackend { session: Session::with_threads(plan, split) }
    }

    /// The session this backend drives.
    pub fn session(&self) -> &Session {
        &self.session
    }
}

impl NativeFactory {
    /// One backend over this factory's plan (1-worker convenience).
    fn into_backend(self) -> NativeBackend {
        NativeBackend::from_plan(self.plan)
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn has_variant(&self, name: &str) -> bool {
        self.session.plan().has_variant(name)
    }

    fn plan_chunks(&self, n: usize) -> Vec<usize> {
        // the kernel parallelizes inside a batch; one dynamic chunk
        if n == 0 {
            vec![]
        } else {
            vec![n]
        }
    }

    fn input_shape(&self) -> [usize; 3] {
        self.session.plan().input_shape()
    }

    fn infer(&self, variant: &str, images: &Tensor<f32>) -> SwisResult<Tensor<f32>> {
        // the pool's run_chunk already assembled the batch tensor, so
        // dispatch goes straight to the session's sync entry (the
        // SessionStream handle is for callers still accumulating rows —
        // re-feeding an assembled batch through it would copy it again)
        self.session.run(variant, images)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<VariantSpec> {
        vec![VariantSpec::fp32(), VariantSpec::swis(3.0, 4), VariantSpec::swis_c(2.0, 4)]
    }

    #[test]
    fn native_backend_serves_without_artifacts() {
        let b = NativeBackend::load(None, &specs()).unwrap();
        assert_eq!(b.name(), "native");
        assert!(b.has_variant("fp32") && b.has_variant("swis@3") && b.has_variant("swis_c@2"));
        assert!(!b.has_variant("nope"));
        assert_eq!(b.plan_chunks(5), vec![5]);
        assert_eq!(b.plan_chunks(0), Vec::<usize>::new());
        let imgs = Tensor::new(&[2, 32, 32, 3], vec![0.5; 2 * 32 * 32 * 3]).unwrap();
        let logits = b.infer("swis@3", &imgs).unwrap();
        assert_eq!(logits.shape(), &[2, 10]);
        // failures are typed, not stringly
        assert!(matches!(b.infer("nope", &imgs).unwrap_err(), SwisError::Backend(_)));
    }

    #[test]
    fn auto_falls_back_to_native() {
        // no manifest at this path and the xla stub has no PJRT: Auto
        // must yield the native backend rather than an error
        let b = create_backend(BackendKind::Auto, Path::new("/nonexistent"), &specs()).unwrap();
        assert_eq!(b.name(), "native");
        // explicit PJRT stays a hard, typed failure in offline builds
        let e = create_backend(BackendKind::Pjrt, Path::new("/nonexistent"), &specs())
            .unwrap_err();
        assert!(matches!(e, SwisError::Backend(_)));
    }

    #[test]
    fn native_factory_shares_prepared_plan_across_replicas() {
        let f = NativeFactory::load(None, &specs()).unwrap();
        assert_eq!(f.name(), "native");
        assert_eq!(f.plan().net_name(), "tinycnn");
        let a = f.make(1).unwrap();
        let b = f.make(8).unwrap();
        assert!(a.has_variant("swis@3") && b.has_variant("swis_c@2"));
        // replicas share the SAME prepared operands; the worker-count
        // thread split must never change logits
        let imgs = Tensor::new(&[1, 32, 32, 3], vec![0.25; 32 * 32 * 3]).unwrap();
        let la = a.infer("swis@3", &imgs).unwrap();
        let lb = b.infer("swis@3", &imgs).unwrap();
        assert_eq!(la.data(), lb.data());
    }

    #[test]
    fn factory_from_plan_round_trips_serialization() {
        // a factory built from a serialized+reloaded plan serves the
        // exact logits of the factory that prepared it
        let f = NativeFactory::load(None, &specs()).unwrap();
        let bytes = f.plan().to_bytes().unwrap();
        let reloaded = NativeFactory::from_plan(Arc::new(EnginePlan::from_bytes(&bytes).unwrap()));
        let imgs = Tensor::new(&[1, 32, 32, 3], vec![0.75; 32 * 32 * 3]).unwrap();
        for v in ["fp32", "swis@3", "swis_c@2"] {
            assert_eq!(
                f.make(1).unwrap().infer(v, &imgs).unwrap().data(),
                reloaded.make(1).unwrap().infer(v, &imgs).unwrap().data(),
                "variant {v} diverged across the .swisplan round-trip"
            );
        }
    }

    #[test]
    fn auto_factory_falls_back_to_native() {
        let f = create_factory(BackendKind::Auto, Path::new("/nonexistent"), &specs()).unwrap();
        assert_eq!(f.name(), "native");
        assert_eq!(f.make(2).unwrap().name(), "native");
    }

    /// A tiny depthwise-bearing net with mobilenet-style names, cheap
    /// enough for debug-mode tests (the real zoo runs in the release CI
    /// zoo-smoke job).
    fn mini_net() -> Network {
        use crate::nets::ConvLayer;
        Network {
            name: "mini_dw".into(),
            layers: vec![
                ConvLayer::new("stem", 8, 3, 3, 2, 1, 8),
                ConvLayer::depthwise("block0.dw", 4, 8, 3, 1, 1),
                ConvLayer::new("block0.project", 4, 8, 1, 1, 0, 8),
                ConvLayer::fc("classifier", 8, 5),
            ],
        }
    }

    #[test]
    fn native_backend_serves_zoo_nets_by_descriptor() {
        let net = mini_net();
        let b = NativeBackend::load_net(None, &net, &specs()).unwrap();
        assert_eq!(b.input_shape(), [8, 8, 3]);
        let imgs = Tensor::new(&[2, 8, 8, 3], vec![0.5; 2 * 8 * 8 * 3]).unwrap();
        let logits = b.infer("swis@3", &imgs).unwrap();
        assert_eq!(logits.shape(), &[2, 5]);
        // wrong-sized images are a routed typed error, not a panic
        let bad = Tensor::new(&[1, 32, 32, 3], vec![0.5; 32 * 32 * 3]).unwrap();
        assert!(b.infer("swis@3", &bad).is_err());
    }

    #[test]
    fn zoo_factories_refuse_pjrt_and_share_replicas() {
        let net = mini_net();
        // PJRT artifacts compile TinyCNN only: explicit pjrt is a hard
        // typed Config error for zoo nets, auto goes native w/o probing
        let e = create_factory_net(BackendKind::Pjrt, Path::new("/nonexistent"), &net, &specs())
            .unwrap_err();
        assert!(matches!(e, SwisError::Config(_)));
        let f =
            create_factory_net(BackendKind::Auto, Path::new("/nonexistent"), &net, &specs())
                .unwrap();
        assert_eq!(f.name(), "native");
        let a = f.make(1).unwrap();
        let b = f.make(4).unwrap();
        assert_eq!(a.input_shape(), [8, 8, 3]);
        let imgs = Tensor::new(&[1, 8, 8, 3], vec![0.25; 8 * 8 * 3]).unwrap();
        assert_eq!(
            a.infer("swis@3", &imgs).unwrap().data(),
            b.infer("swis@3", &imgs).unwrap().data()
        );
    }

    #[test]
    fn kind_parses() {
        assert_eq!(BackendKind::parse("auto").unwrap(), BackendKind::Auto);
        assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Pjrt);
        assert_eq!(BackendKind::parse("native").unwrap(), BackendKind::Native);
        assert!(matches!(BackendKind::parse("tpu").unwrap_err(), SwisError::Config(_)));
    }
}
