//! Per-network MSE++ coefficient tuning (paper Sec. 4.1.2: "we also
//! added a coefficient to the signed error term to allow us to fine-tune
//! its contribution for each network").
//!
//! The offline tuner sweeps alpha over a small grid and picks the value
//! minimizing a proxy objective on the layer's weights. RMSE alone is
//! blind to alpha by construction (alpha trades absolute error for drift
//! control), so the objective combines reconstruction RMSE with the
//! group-level signed drift that MSE++ exists to suppress.

use anyhow::Result;

use super::metrics::Alpha;
use super::swis::{quantize, QuantConfig};

/// The default sweep grid (paper: alpha = 1 when tuning is impractical).
pub const DEFAULT_GRID: &[f64] = &[0.0, 0.5, 1.0, 2.0, 4.0];

/// Tuning objective for one candidate alpha.
#[derive(Clone, Copy, Debug)]
pub struct AlphaScore {
    pub alpha: f64,
    /// Reconstruction RMSE over the layer.
    pub rmse: f64,
    /// Mean |group drift|: |sum of signed errors| per group, averaged.
    pub drift: f64,
}

impl AlphaScore {
    /// Combined objective: RMSE plus drift weighted to the same scale.
    /// Drift matters because MAC outputs sum per-group errors (Sec.
    /// 4.1.2's motivation); lambda = 1 keeps both in weight units.
    pub fn objective(&self) -> f64 {
        self.rmse + self.drift
    }
}

/// Score one alpha on a filters-first weight tensor.
pub fn score_alpha(w: &[f64], shape: &[usize], cfg: &QuantConfig, alpha: f64) -> Result<AlphaScore> {
    let mut c = *cfg;
    c.alpha = Alpha::from_f64(alpha);
    let p = quantize(w, shape, &c)?;
    let deq = p.to_f64();
    let n = w.len() as f64;
    let rmse = (w
        .iter()
        .zip(&deq)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        / n)
        .sqrt();
    // Group-level signed drift, in the magnitude domain the selector
    // scores (sign-factored: sign*(w - deq) = scale * (mag - qmag), so
    // this is exactly the SignedError of Eq. 11 as implemented by the
    // quantizer and its Python golden twin).
    let gs = p.group_size;
    let fan_in = p.fan_in();
    let gpf = p.groups_per_filter();
    let mut drift = 0.0;
    let mut groups = 0usize;
    for f in 0..p.n_filters() {
        for gl in 0..gpf {
            let mut d = 0.0;
            for i in 0..gs {
                let c = gl * gs + i;
                if c >= fan_in {
                    break;
                }
                let idx = f * fan_in + c;
                let sign = p.signs[(f * gpf + gl) * gs + i] as f64;
                d += sign * (w[idx] - deq[idx]);
            }
            drift += d.abs();
            groups += 1;
        }
    }
    Ok(AlphaScore { alpha, rmse, drift: drift / groups as f64 })
}

/// Sweep `grid` and return every score plus the argmin of the combined
/// objective — the per-network alpha the paper fine-tunes.
pub fn tune_alpha(
    w: &[f64],
    shape: &[usize],
    cfg: &QuantConfig,
    grid: &[f64],
) -> Result<(f64, Vec<AlphaScore>)> {
    let scores: Vec<AlphaScore> = grid
        .iter()
        .map(|&a| score_alpha(w, shape, cfg, a))
        .collect::<Result<_>>()?;
    let best = scores
        .iter()
        .min_by(|a, b| a.objective().partial_cmp(&b.objective()).unwrap())
        .map(|s| s.alpha)
        .unwrap_or(1.0);
    Ok((best, scores))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn weights(seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        // mildly skewed weights so signed drift is non-trivial
        (0..16 * 64)
            .map(|_| rng.normal_ms(0.003, 0.05))
            .collect()
    }

    #[test]
    fn sweep_returns_grid_scores() {
        let w = weights(1);
        let cfg = QuantConfig::swis(2, 4);
        let (best, scores) = tune_alpha(&w, &[16, 64], &cfg, DEFAULT_GRID).unwrap();
        assert_eq!(scores.len(), DEFAULT_GRID.len());
        assert!(DEFAULT_GRID.contains(&best));
    }

    #[test]
    fn alpha_trades_rmse_for_drift() {
        // raising alpha must not increase drift; pure MSE (alpha 0) must
        // have the lowest RMSE (it optimizes exactly that)
        let w = weights(2);
        let cfg = QuantConfig::swis(2, 4);
        let s0 = score_alpha(&w, &[16, 64], &cfg, 0.0).unwrap();
        let s4 = score_alpha(&w, &[16, 64], &cfg, 4.0).unwrap();
        assert!(s0.rmse <= s4.rmse + 1e-12, "alpha=0 should minimize RMSE");
        assert!(s4.drift <= s0.drift + 1e-12, "alpha=4 should minimize drift");
    }

    #[test]
    fn objective_finite_and_positive() {
        let w = weights(3);
        let cfg = QuantConfig::swis(3, 4);
        for &a in DEFAULT_GRID {
            let s = score_alpha(&w, &[16, 64], &cfg, a).unwrap();
            assert!(s.objective().is_finite() && s.objective() > 0.0);
        }
    }
}
