//! Layer-wise truncation baselines (paper Sec. 2.3 / 5): the conventional
//! quantization SWIS is compared against.
//!
//! * Weight truncation + clipping: keep the top `n` of the 8 magnitude
//!   bits (round-to-nearest at the dropped boundary, clip to 127) — i.e. a
//!   single layer-wide consecutive window anchored at the MSB.
//! * Activation truncation: zero the low `8-n` bits of the unsigned 8-bit
//!   activation code (as Stripes-style accelerators do at runtime).

use super::int8::{Int8Layer, BITS, MAG_MAX};

/// Layer-wise weight truncation + clipping to `n_bits` (1..=8).
/// Returns dequantized floats (same shape/order as input).
pub fn truncate_weights(w: &[f64], n_bits: usize) -> Vec<f64> {
    assert!((1..=BITS as usize).contains(&n_bits));
    let q = Int8Layer::from_f64(w);
    truncate_int8(&q, n_bits)
}

pub(crate) fn truncate_int8(q: &Int8Layer, n_bits: usize) -> Vec<f64> {
    let drop = BITS as usize - n_bits;
    let step = 1i64 << drop;
    q.mags
        .iter()
        .zip(&q.signs)
        .map(|(&m, &s)| {
            let t = ((m as i64 + step / 2) / step * step).min(MAG_MAX);
            (t * s as i64) as f64 * q.scale
        })
        .collect()
}

/// Integer magnitudes after truncation (for storage/error accounting).
pub fn truncate_mags(mags: &[u8], n_bits: usize) -> Vec<u8> {
    let drop = BITS as usize - n_bits;
    let step = 1i64 << drop;
    mags.iter()
        .map(|&m| (((m as i64 + step / 2) / step * step).min(MAG_MAX)) as u8)
        .collect()
}

/// Layer-wise activation truncation: quantize to unsigned 8-bit over
/// [0, amax] (post-ReLU activations), zero the low 8-n bits.
pub fn truncate_activations(a: &[f32], n_bits: usize, amax: f32) -> Vec<f32> {
    assert!((1..=BITS as usize).contains(&n_bits));
    let scale = if amax > 0.0 { amax / 255.0 } else { 1.0 };
    let drop = BITS as usize - n_bits;
    a.iter()
        .map(|&x| {
            let q = (x / scale).round().clamp(0.0, 255.0) as i64;
            (((q >> drop) << drop) as f32) * scale
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::metrics::rmse;

    #[test]
    fn full_precision_is_identity_on_int8_grid() {
        let w = vec![1.0, -0.5, 0.25, 127.0 / 127.0];
        let t = truncate_weights(&w, 8);
        let q = Int8Layer::from_f64(&w);
        let base = q.to_f64();
        for (a, b) in t.iter().zip(&base) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn truncation_error_grows_as_bits_drop() {
        let mut rng = crate::util::rng::Rng::new(2);
        let w: Vec<f64> = (0..512).map(|_| rng.normal_ms(0.0, 0.1)).collect();
        let mut last = -1.0;
        for n in (1..=8).rev() {
            let e = rmse(&w, &truncate_weights(&w, n));
            assert!(e >= last - 1e-15, "error shrank when dropping bits");
            last = e;
        }
    }

    #[test]
    fn truncate_mags_rounds_and_clips() {
        // n=4 -> step 16: 129 impossible (mag<=127); 127 -> clip to 127? (127+8)/16*16 = 128 -> clip 127
        assert_eq!(truncate_mags(&[127], 4), vec![127]);
        assert_eq!(truncate_mags(&[7], 4), vec![0]); // (7+8)/16=0 -> 0? (15)/16=0 -> 0
        assert_eq!(truncate_mags(&[8], 4), vec![16]); // (8+8)/16=1 -> 16
        assert_eq!(truncate_mags(&[100], 8), vec![100]);
    }

    #[test]
    fn activation_truncation_zeroes_lsbs() {
        let a = vec![0.0f32, 130.0, 255.0];
        let t = truncate_activations(&a, 2, 255.0);
        // 8-bit codes 0,130,255 -> top-2-bit codes 0,128,192
        assert!((t[0] - 0.0).abs() < 1e-6);
        assert!((t[1] - 128.0).abs() < 1e-4);
        assert!((t[2] - 192.0).abs() < 1e-4);
    }

    #[test]
    fn swis_dominates_truncation() {
        // the paper's core claim at the RMSE level (Table 1)
        let mut rng = crate::util::rng::Rng::new(9);
        let w: Vec<f64> = (0..1024).map(|_| rng.normal_ms(0.0, 0.05)).collect();
        for n in 2..=4 {
            let cfg = crate::quant::swis::QuantConfig::swis(n, 4);
            let p = crate::quant::swis::quantize(&w, &[16, 64], &cfg).unwrap();
            let es = rmse(&w, &p.to_f64());
            let et = rmse(&w, &truncate_weights(&w, n));
            assert!(es < et, "SWIS {es} not better than truncation {et} at n={n}");
        }
    }
}
