//! Shift-subset enumeration and subset-sum codebooks (paper Sec. 4.1.1).
//!
//! Enumeration order is lexicographically ascending over shift positions —
//! identical to `itertools.combinations(range(8), n)` on the Python side;
//! ties in the error metric resolve to the earliest combo, so order is
//! part of the cross-language contract.

use super::int8::BITS;

/// All C(bits, n) shift subsets in lexicographic order.
pub fn shift_combos(n: usize, bits: u32) -> Vec<Vec<u8>> {
    assert!(n >= 1 && n <= bits as usize, "n_shifts out of range: {n}");
    let mut out = Vec::new();
    let mut cur = Vec::with_capacity(n);
    fn rec(out: &mut Vec<Vec<u8>>, cur: &mut Vec<u8>, start: u8, n: usize, bits: u8) {
        if cur.len() == n {
            out.push(cur.clone());
            return;
        }
        let remaining = n - cur.len();
        for s in start..=(bits - remaining as u8) {
            cur.push(s);
            rec(out, cur, s + 1, n, bits);
            cur.pop();
        }
    }
    rec(&mut out, &mut cur, 0, n, bits as u8);
    out
}

/// The 9-N consecutive windows used by SWIS-C.
pub fn consecutive_combos(n: usize, bits: u32) -> Vec<Vec<u8>> {
    assert!(n >= 1 && n <= bits as usize);
    (0..=(bits as usize - n))
        .map(|o| (o..o + n).map(|s| s as u8).collect())
        .collect()
}

/// Sorted, deduplicated subset sums of {2^s : s in combo}, including 0.
/// For distinct shift positions the 2^N sums are already unique, but we
/// dedup anyway to stay robust (and to mirror the Python set semantics).
pub fn codebook(combo: &[u8]) -> Vec<i64> {
    let n = combo.len();
    let mut vals = Vec::with_capacity(1 << n);
    for bitsel in 0..(1u32 << n) {
        let mut v = 0i64;
        for (j, &s) in combo.iter().enumerate() {
            if bitsel >> j & 1 == 1 {
                v += 1i64 << s;
            }
        }
        vals.push(v);
    }
    vals.sort_unstable();
    vals.dedup();
    vals
}

/// Nearest codebook value to `mag`; ties round DOWN (numpy-searchsorted
/// convention shared with the Python reference).
#[inline]
pub fn nearest(cb: &[i64], mag: i64) -> i64 {
    // first index with cb[i] >= mag (searchsorted 'left')
    let idx = cb.partition_point(|&v| v < mag);
    let hi = cb[idx.min(cb.len() - 1)];
    let lo = cb[idx.saturating_sub(1)];
    if (hi - mag) < (mag - lo) {
        hi
    } else {
        lo
    }
}

/// Decompose a quantized magnitude into per-shift mask bits for `combo`.
/// `qmag` must be a subset sum of the combo's powers, so its binary
/// representation restricted to the combo positions is exactly the mask.
#[inline]
pub fn mask_bits(combo: &[u8], qmag: i64) -> Vec<u8> {
    combo.iter().map(|&s| ((qmag >> s) & 1) as u8).collect()
}

/// Number of shift subsets for a given N (binomial coefficient).
pub fn n_combos(n: usize, bits: u32) -> usize {
    let mut num = 1usize;
    let mut den = 1usize;
    for i in 0..n {
        num *= bits as usize - i;
        den *= i + 1;
    }
    num / den
}

pub fn default_bits() -> u32 {
    BITS
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn combo_count_and_order() {
        let c = shift_combos(2, 8);
        assert_eq!(c.len(), 28);
        assert_eq!(c[0], vec![0, 1]);
        assert_eq!(c[1], vec![0, 2]);
        assert_eq!(c[27], vec![6, 7]);
        assert_eq!(shift_combos(4, 8).len(), 70);
        assert_eq!(n_combos(2, 8), 28);
        assert_eq!(n_combos(4, 8), 70);
    }

    #[test]
    fn consecutive_windows() {
        let c = consecutive_combos(3, 8);
        assert_eq!(c.len(), 6);
        assert_eq!(c[0], vec![0, 1, 2]);
        assert_eq!(c[5], vec![5, 6, 7]);
    }

    #[test]
    fn codebook_contents() {
        assert_eq!(codebook(&[0, 2]), vec![0, 1, 4, 5]);
        assert_eq!(codebook(&[7]), vec![0, 128]);
        assert_eq!(codebook(&[0, 1, 2]).len(), 8);
    }

    #[test]
    fn nearest_ties_round_down() {
        let cb = vec![0i64, 1, 4, 5];
        assert_eq!(nearest(&cb, 0), 0);
        assert_eq!(nearest(&cb, 2), 1); // |2-1|=1 < |4-2|=2
        assert_eq!(nearest(&cb, 3), 4); // |4-3|=1 < |3-1|=2
        assert_eq!(nearest(&cb, 100), 5); // clamps to max
        // tie: mag=2.5 impossible (ints); construct tie mag between 1 and 4 is 2.5;
        // integer tie: cb {0,2}: mag 1 -> lo 0, hi 2, tie -> 0
        assert_eq!(nearest(&[0, 2], 1), 0);
    }

    #[test]
    fn mask_roundtrip() {
        let combo = vec![1u8, 3, 6];
        for &q in codebook(&combo).iter() {
            let m = mask_bits(&combo, q);
            let rec: i64 = combo
                .iter()
                .zip(&m)
                .map(|(&s, &b)| (b as i64) << s)
                .sum();
            assert_eq!(rec, q);
        }
    }
}
