//! The SWIS quantizer (paper Sec. 4.1): per-group enumeration over shift
//! subsets with nearest-codebook weight quantization, scored by MSE++.
//!
//! Hot-path notes: for each combo we precompute a 128-entry lookup table
//! mag -> (qmag, err, err^2), so the inner loop per (group, combo) is
//! `group_size` table reads plus integer adds (the packed-u32
//! accumulator below); selection over combos is a strict-less argmin,
//! ties resolving to the earliest (lexicographic) combo — the
//! cross-language contract with the Python reference.
//!
//! This module owns the DATA of the hot path (LUT construction, the
//! packed accumulator, the storage packer); the ENGINE lives in
//! [`super::planner`]: a process-global LUT bank (LUTs are
//! data-independent, so they are built once per combo family instead of
//! once per call), a single all-`n` sweep feeding the scheduler's cost
//! oracle, and a parallel group sweep chunked over `std::thread::scope`.
//! `quantize` and `per_filter_cost` here are thin planner front-ends.

use anyhow::{bail, Result};

use super::combos::{consecutive_combos, mask_bits, nearest, shift_combos, codebook};
use super::int8::{Int8Layer, BITS, MAG_MAX};
use super::metrics::Alpha;
use super::packed::PackedLayer;
use super::planner;

/// Quantizer configuration.
#[derive(Clone, Copy, Debug)]
pub struct QuantConfig {
    pub n_shifts: usize,
    pub group_size: usize,
    pub alpha: Alpha,
    /// true = SWIS-C (consecutive shift windows, offset-only storage)
    pub consecutive: bool,
}

impl QuantConfig {
    pub fn swis(n_shifts: usize, group_size: usize) -> Self {
        QuantConfig { n_shifts, group_size, alpha: Alpha::ONE, consecutive: false }
    }

    pub fn swis_c(n_shifts: usize, group_size: usize) -> Self {
        QuantConfig { n_shifts, group_size, alpha: Alpha::ONE, consecutive: true }
    }

    pub fn combos(&self) -> Vec<Vec<u8>> {
        if self.consecutive {
            consecutive_combos(self.n_shifts, BITS)
        } else {
            shift_combos(self.n_shifts, BITS)
        }
    }
}

/// Weight magnitudes reorganized into (n_groups, group_size) with zero
/// padding (padded lanes sign +1), filters-first grouping.
#[derive(Clone, Debug)]
pub struct GroupedMags {
    pub mags: Vec<u8>,
    pub signs: Vec<i8>,
    pub scale: f64,
    pub n_filters: usize,
    pub groups_per_filter: usize,
    pub group_size: usize,
}

impl GroupedMags {
    pub fn n_groups(&self) -> usize {
        self.n_filters * self.groups_per_filter
    }

    pub fn group(&self, g: usize) -> &[u8] {
        &self.mags[g * self.group_size..(g + 1) * self.group_size]
    }
}

/// int8-quantize + regroup a filters-first weight tensor.
pub fn group_mags(w: &[f64], shape: &[usize], group_size: usize) -> Result<GroupedMags> {
    if shape.is_empty() || group_size == 0 {
        bail!("bad shape/group_size");
    }
    let k = shape[0];
    let fan_in: usize = shape[1..].iter().product();
    if k * fan_in != w.len() {
        bail!("shape {:?} does not match {} weights", shape, w.len());
    }
    let q = Int8Layer::from_f64(w);
    let gpf = fan_in.div_ceil(group_size);
    let padded = gpf * group_size;
    let mut mags = vec![0u8; k * padded];
    let mut signs = vec![1i8; k * padded];
    for f in 0..k {
        let src = f * fan_in;
        let dst = f * padded;
        mags[dst..dst + fan_in].copy_from_slice(&q.mags[src..src + fan_in]);
        signs[dst..dst + fan_in].copy_from_slice(&q.signs[src..src + fan_in]);
    }
    Ok(GroupedMags {
        mags,
        signs,
        scale: q.scale,
        n_filters: k,
        groups_per_filter: gpf,
        group_size,
    })
}

/// Per-combo lookup table: for every magnitude 0..=127 the nearest
/// codebook value and its error.
pub struct ComboLut {
    pub combo: Vec<u8>,
    /// qmag per magnitude
    pub q: [u8; 129],
    /// err = mag - qmag per magnitude (i16 fits; |err| <= 127)
    pub e: [i16; 129],
    /// Packed (err^2 << 12) | (err + 128): the scoring loop accumulates
    /// one u32 add per lane, then unpacks sum_e and sum_e2. Valid for
    /// group sizes <= 16 (low field <= 255*16 < 2^12, high <= 16129*16 <
    /// 2^18; 12+18 <= 32).
    pub packed: [u32; 129],
}

/// Bit position of the squared-error field in [`ComboLut::packed`].
const PACK_SHIFT: u32 = 12;
/// Largest group size the packed accumulator supports without overflow.
pub(crate) const PACK_MAX_GS: usize = 16;

pub fn build_luts(combos: &[Vec<u8>]) -> Vec<ComboLut> {
    combos
        .iter()
        .map(|c| {
            let cb = codebook(c);
            let mut q = [0u8; 129];
            let mut e = [0i16; 129];
            let mut packed = [0u32; 129];
            for m in 0..=(MAG_MAX as usize + 1) {
                let mm = m.min(MAG_MAX as usize) as i64;
                let nv = nearest(&cb, mm).min(255);
                q[m] = nv as u8;
                e[m] = (mm - nv) as i16;
                let err = (mm - nv) as i32;
                packed[m] = ((err * err) as u32) << PACK_SHIFT | (err + 128) as u32;
            }
            ComboLut { combo: c.clone(), q, e, packed }
        })
        .collect()
}

/// Accumulate the packed score fields over a group's lanes.
#[inline(always)]
pub(crate) fn packed_sums(lut: &ComboLut, mags: &[u8]) -> (i64, i64) {
    let mut acc = 0u32;
    for &m in mags {
        acc = acc.wrapping_add(lut.packed[m as usize]);
    }
    let se = (acc & ((1 << PACK_SHIFT) - 1)) as i64 - 128 * mags.len() as i64;
    let sq = (acc >> PACK_SHIFT) as i64;
    (se, sq)
}

/// Select the best combo per group. Returns (combo index, per-lane qmags).
///
/// Thin front-end over [`planner::select_groups_chunked`]: strict-less
/// argmin, earliest combo wins ties, parallel over the planner's default
/// thread count (results are thread-count invariant).
pub fn select_groups(
    gm: &GroupedMags,
    luts: &[ComboLut],
    alpha: Alpha,
) -> (Vec<u32>, Vec<u8>) {
    planner::select_groups_chunked(gm, luts, alpha, planner::auto_threads(gm.mags.len()))
}

/// Quantize a filters-first weight tensor with SWIS or SWIS-C.
pub fn quantize(w: &[f64], shape: &[usize], cfg: &QuantConfig) -> Result<PackedLayer> {
    if cfg.n_shifts == 0 || cfg.n_shifts > BITS as usize {
        bail!("n_shifts must be in [1,8], got {}", cfg.n_shifts);
    }
    let gm = group_mags(w, shape, cfg.group_size)?;
    let luts = planner::luts(cfg.n_shifts, cfg.consecutive);
    let (best_idx, best_q) = select_groups(&gm, luts, cfg.alpha);
    Ok(pack(&gm, luts, &best_idx, &best_q, shape, cfg, None))
}

/// Pack selection results into the storage format.
pub(crate) fn pack(
    gm: &GroupedMags,
    luts: &[ComboLut],
    best_idx: &[u32],
    best_q: &[u8],
    shape: &[usize],
    cfg: &QuantConfig,
    filter_shifts: Option<Vec<usize>>,
) -> PackedLayer {
    let n_groups = gm.n_groups();
    let gs = gm.group_size;
    let n = cfg.n_shifts;
    let mut shifts = vec![0u8; n_groups * n];
    let mut masks = vec![0u8; n_groups * gs * n];
    for g in 0..n_groups {
        let combo = &luts[best_idx[g] as usize].combo;
        shifts[g * n..g * n + combo.len()].copy_from_slice(combo);
        for i in 0..gs {
            let q = best_q[g * gs + i] as i64;
            let mb = mask_bits(combo, q);
            let base = (g * gs + i) * n;
            masks[base..base + combo.len()].copy_from_slice(&mb);
        }
    }
    PackedLayer {
        shape: shape.to_vec(),
        group_size: gs,
        n_shifts: n,
        scale: gm.scale,
        shifts,
        masks,
        signs: gm.signs.clone(),
        consecutive: cfg.consecutive,
        filter_shifts,
    }
}

/// Layer MSE++ (integer score summed over groups) at a given shift count —
/// the scheduler's cost oracle. Returns per-filter sums.
///
/// Routed through the planner's LUT bank and shared argmin helper; when
/// the scheduler needs MANY shift counts, [`planner::cost_table`]
/// computes all of them in one pass instead of calling this per `n`.
pub fn per_filter_cost(gm: &GroupedMags, n_shifts: usize, consecutive: bool, alpha: Alpha) -> Vec<i64> {
    planner::per_filter_cost_at(gm, n_shifts, consecutive, alpha)
}

/// Convenience: quantize and return (packed, dequantized floats, rmse).
pub fn quantize_with_stats(
    w: &[f64],
    shape: &[usize],
    cfg: &QuantConfig,
) -> Result<(PackedLayer, Vec<f64>, f64)> {
    let packed = quantize(w, shape, cfg)?;
    let deq = packed.to_f64();
    let r = super::metrics::rmse(w, &deq);
    Ok((packed, deq, r))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lossless_when_bits_fit() {
        // weights scaled so int8 mags are the values themselves (max 127)
        let w2 = vec![3.0, 65.0, 17.0, 127.0];
        let p2 = quantize(&w2, &[4, 1], &QuantConfig::swis(2, 1)).unwrap();
        // mags 3 (0b11), 65 (0b1000001), 17 (0b10001) have 2 set bits ->
        // lossless at N=2; 127 (7 set bits) is lossy: nearest 2-shift value
        // is 128 = {6,7} (|128-127| = 1).
        assert_eq!(p2.mag(0, 0), 3);
        assert_eq!(p2.mag(1, 0), 65);
        assert_eq!(p2.mag(2, 0), 17);
        assert_eq!(p2.mag(3, 0), 128);
    }

    #[test]
    fn swis_beats_swis_c_beats_nothing() {
        // SWIS error <= SWIS-C error on the same data (superset search)
        let mut rng = crate::util::rng::Rng::new(11);
        let w: Vec<f64> = (0..256).map(|_| rng.normal_ms(0.0, 0.05)).collect();
        let shape = [8usize, 32];
        for n in 2..=4 {
            let ps = quantize(&w, &shape, &QuantConfig::swis(n, 4)).unwrap();
            let pc = quantize(&w, &shape, &QuantConfig::swis_c(n, 4)).unwrap();
            let es = super::super::metrics::rmse(&w, &ps.to_f64());
            let ec = super::super::metrics::rmse(&w, &pc.to_f64());
            assert!(
                es <= ec + 1e-12,
                "SWIS rmse {es} should be <= SWIS-C rmse {ec} at n={n}"
            );
        }
    }

    #[test]
    fn more_shifts_never_hurt() {
        let mut rng = crate::util::rng::Rng::new(7);
        let w: Vec<f64> = (0..128).map(|_| rng.normal_ms(0.0, 0.1)).collect();
        let shape = [4usize, 32];
        let mut last = f64::INFINITY;
        for n in 1..=6 {
            let p = quantize(&w, &shape, &QuantConfig::swis(n, 4)).unwrap();
            let e = super::super::metrics::rmse(&w, &p.to_f64());
            assert!(e <= last + 1e-12, "rmse increased at n={n}: {e} > {last}");
            last = e;
        }
    }

    #[test]
    fn group_padding() {
        let w = vec![0.5, -0.25, 0.125]; // fan_in 3, group 2 -> pad 1
        let gm = group_mags(&w, &[1, 3], 2).unwrap();
        assert_eq!(gm.n_groups(), 2);
        assert_eq!(gm.group(1)[1], 0); // padded lane
        assert_eq!(gm.signs[3], 1);
    }

    #[test]
    fn packed_validates() {
        let mut rng = crate::util::rng::Rng::new(3);
        let w: Vec<f64> = (0..96).map(|_| rng.normal_ms(0.0, 0.2)).collect();
        let p = quantize(&w, &[8, 12], &QuantConfig::swis(3, 4)).unwrap();
        p.validate().unwrap();
        assert_eq!(p.n_groups(), 8 * 3);
        assert_eq!(p.effective_shifts(), 3.0);
    }

    #[test]
    fn rejects_bad_config() {
        assert!(quantize(&[0.0], &[1, 1], &QuantConfig::swis(0, 4)).is_err());
        assert!(quantize(&[0.0], &[1, 1], &QuantConfig::swis(9, 4)).is_err());
        assert!(quantize(&[0.0, 0.0], &[1, 1], &QuantConfig::swis(2, 1)).is_err());
    }
}
