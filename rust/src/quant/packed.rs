//! The packed SWIS weight format (paper Sec. 3.3): per group of
//! `group_size` weights we store signs (1 b/weight), shift values
//! (3 b/shift/group — or one 3 b offset for SWIS-C) and shift masks
//! (1 b/weight/shift). This is both the storage-compression model and the
//! operand format the simulator and the PJRT runtime consume.

use anyhow::{bail, Result};

/// A SWIS-quantized weight layer.
///
/// Grouping is row-major over the filters-first matrix `(K, fan_in)`:
/// each filter's fan-in is split into groups of `group_size`, zero-padded
/// at the tail (padded lanes carry sign +1). Group `g` covers filter
/// `g / groups_per_filter`.
#[derive(Clone, Debug)]
pub struct PackedLayer {
    /// Original tensor shape, filters on axis 0.
    pub shape: Vec<usize>,
    pub group_size: usize,
    /// Shift planes stored per group (the per-layer max when filter
    /// scheduling assigns heterogeneous counts).
    pub n_shifts: usize,
    /// Dequantization scale (max|w| / 127).
    pub scale: f64,
    /// (n_groups, n_shifts) shift positions, ascending within a group.
    pub shifts: Vec<u8>,
    /// (n_groups, group_size, n_shifts) mask bits in {0,1}.
    pub masks: Vec<u8>,
    /// (n_groups, group_size) signs in {-1,+1}.
    pub signs: Vec<i8>,
    /// SWIS-C: shifts are consecutive; storage drops to one offset/group.
    pub consecutive: bool,
    /// Per-filter shift counts when produced by the Sec. 4.3 scheduler.
    pub filter_shifts: Option<Vec<usize>>,
}

impl PackedLayer {
    pub fn n_groups(&self) -> usize {
        if self.n_shifts == 0 {
            0
        } else {
            self.shifts.len() / self.n_shifts
        }
    }

    pub fn fan_in(&self) -> usize {
        self.shape[1..].iter().product()
    }

    pub fn n_filters(&self) -> usize {
        self.shape[0]
    }

    pub fn groups_per_filter(&self) -> usize {
        let fi = self.fan_in();
        fi.div_ceil(self.group_size)
    }

    /// Reconstructed integer magnitude of lane `(g, i)`.
    #[inline]
    pub fn mag(&self, g: usize, i: usize) -> i64 {
        let base = (g * self.group_size + i) * self.n_shifts;
        let srow = &self.shifts[g * self.n_shifts..(g + 1) * self.n_shifts];
        let mrow = &self.masks[base..base + self.n_shifts];
        let mut v = 0i64;
        for (j, &s) in srow.iter().enumerate() {
            v += (mrow[j] as i64) << s;
        }
        v
    }

    /// Dequantize to the original float shape (row-major).
    pub fn to_f64(&self) -> Vec<f64> {
        let k = self.n_filters();
        let fan_in = self.fan_in();
        let gpf = self.groups_per_filter();
        let mut out = Vec::with_capacity(k * fan_in);
        for f in 0..k {
            for c in 0..fan_in {
                let g = f * gpf + c / self.group_size;
                let i = c % self.group_size;
                let sign = self.signs[g * self.group_size + i] as f64;
                out.push(self.mag(g, i) as f64 * sign * self.scale);
            }
        }
        out
    }

    /// Storage bits of the packed representation (Sec. 3.3 accounting):
    /// signs + masks + per-group shift storage (3 b/shift for SWIS, a
    /// single 3 b offset for SWIS-C).
    pub fn storage_bits(&self) -> u64 {
        let g = self.n_groups() as u64;
        let gs = self.group_size as u64;
        let n = self.n_shifts as u64;
        let sign_bits = g * gs;
        let mask_bits = g * gs * n;
        let shift_bits = if self.consecutive { 3 } else { 3 * n };
        sign_bits + mask_bits + g * shift_bits
    }

    /// Effective bits per (unpadded) weight.
    pub fn bits_per_weight(&self) -> f64 {
        self.storage_bits() as f64 / (self.n_filters() * self.fan_in()) as f64
    }

    /// Compression ratio vs the 8-bit baseline.
    pub fn compression_ratio(&self) -> f64 {
        8.0 / self.bits_per_weight()
    }

    /// Mask plane `j` as a dense (fan_in, n_filters) 0/1 matrix restricted
    /// to groups that actually use >= j+1 shifts — the operand layout the
    /// Pallas kernel / PJRT artifact expects (column-major filters).
    pub fn mask_plane(&self, j: usize) -> Result<Vec<f32>> {
        if j >= self.n_shifts {
            bail!("plane {j} out of range (n_shifts={})", self.n_shifts);
        }
        let k = self.n_filters();
        let fan_in = self.fan_in();
        let gpf = self.groups_per_filter();
        let mut out = vec![0f32; fan_in * k];
        for f in 0..k {
            for c in 0..fan_in {
                let g = f * gpf + c / self.group_size;
                let i = c % self.group_size;
                out[c * k + f] = self.masks[(g * self.group_size + i) * self.n_shifts + j] as f32;
            }
        }
        Ok(out)
    }

    /// Per-group shift values are non-uniform in general; uniform layers
    /// (no scheduling) expose them as plane powers for the kernel path.
    pub fn uniform_shifts(&self) -> Option<Vec<u8>> {
        if self.n_groups() == 0 {
            return None;
        }
        let first = &self.shifts[..self.n_shifts];
        for g in 1..self.n_groups() {
            if &self.shifts[g * self.n_shifts..(g + 1) * self.n_shifts] != first {
                return None;
            }
        }
        Some(first.to_vec())
    }

    /// Validate internal consistency (used by property tests and loaders).
    pub fn validate(&self) -> Result<()> {
        let g = self.n_groups();
        if self.shifts.len() != g * self.n_shifts
            || self.masks.len() != g * self.group_size * self.n_shifts
            || self.signs.len() != g * self.group_size
        {
            bail!("inconsistent packed buffer lengths");
        }
        if g != self.n_filters() * self.groups_per_filter() {
            bail!(
                "group count {} does not cover shape {:?} with group_size {}",
                g,
                self.shape,
                self.group_size
            );
        }
        for &s in &self.shifts {
            if s >= 8 {
                bail!("shift value {s} out of range");
            }
        }
        for &m in &self.masks {
            if m > 1 {
                bail!("mask bit {m} not boolean");
            }
        }
        for &s in &self.signs {
            if s != 1 && s != -1 {
                bail!("sign {s} not in {{-1,1}}");
            }
        }
        // shifts ascending within each group over the active prefix
        for gi in 0..g {
            let row = &self.shifts[gi * self.n_shifts..(gi + 1) * self.n_shifts];
            let active = self.active_shifts(gi);
            for w in row[..active].windows(2) {
                if w[0] >= w[1] {
                    bail!("group {gi} shifts not strictly ascending: {row:?}");
                }
            }
        }
        Ok(())
    }

    /// Number of active shift planes for group `g` (scheduled layers store
    /// trailing zero planes for filters quantized with fewer shifts).
    pub fn active_shifts(&self, g: usize) -> usize {
        match &self.filter_shifts {
            None => self.n_shifts,
            Some(fs) => fs[g / self.groups_per_filter()],
        }
    }

    /// Effective (average) number of shifts across weights — the paper's
    /// reporting convention for scheduled layers.
    pub fn effective_shifts(&self) -> f64 {
        match &self.filter_shifts {
            None => self.n_shifts as f64,
            Some(fs) => fs.iter().sum::<usize>() as f64 / fs.len() as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> PackedLayer {
        // 1 filter, fan_in 2, group 2, shifts {0, 2}
        PackedLayer {
            shape: vec![1, 2],
            group_size: 2,
            n_shifts: 2,
            scale: 1.0,
            shifts: vec![0, 2],
            masks: vec![1, 1, 0, 1], // lane0: 1+4=5, lane1: 0+4=4
            signs: vec![1, -1],
            consecutive: false,
            filter_shifts: None,
        }
    }

    #[test]
    fn mag_and_dequant() {
        let p = tiny();
        assert_eq!(p.mag(0, 0), 5);
        assert_eq!(p.mag(0, 1), 4);
        assert_eq!(p.to_f64(), vec![5.0, -4.0]);
        p.validate().unwrap();
    }

    #[test]
    fn storage_accounting() {
        let p = tiny();
        // signs 2 + masks 4 + shifts 6 = 12 bits over 2 weights
        assert_eq!(p.storage_bits(), 12);
        assert!((p.bits_per_weight() - 6.0).abs() < 1e-12);
        assert!((p.compression_ratio() - 8.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn swis_c_storage_smaller() {
        let mut p = tiny();
        p.consecutive = true;
        p.shifts = vec![0, 1];
        // signs 2 + masks 4 + offset 3 = 9 bits
        assert_eq!(p.storage_bits(), 9);
    }

    #[test]
    fn mask_plane_layout() {
        let p = tiny();
        let plane0 = p.mask_plane(0).unwrap(); // (fan_in=2, k=1)
        assert_eq!(plane0, vec![1.0, 0.0]);
        let plane1 = p.mask_plane(1).unwrap();
        assert_eq!(plane1, vec![1.0, 1.0]);
        assert!(p.mask_plane(2).is_err());
    }

    #[test]
    fn validate_catches_bad_sign() {
        let mut p = tiny();
        p.signs[0] = 0;
        assert!(p.validate().is_err());
    }

    #[test]
    fn uniform_shift_detection() {
        let p = tiny();
        assert_eq!(p.uniform_shifts(), Some(vec![0, 2]));
    }
}
