//! SWIS quantization core (paper Sec. 2 & 4): int8 pre-quantization,
//! shift-subset enumeration, MSE++ scoring, packed storage format, the
//! truncation baselines, and the [`planner`] — the cached/parallel
//! engine behind `quantize` and the scheduler's cost oracle.

pub mod alpha_tune;
pub mod combos;
pub mod int8;
pub mod metrics;
pub mod packed;
pub mod planner;
pub mod serialize;
pub mod swis;
pub mod truncation;

pub use metrics::Alpha;
pub use packed::PackedLayer;
pub use swis::{quantize, QuantConfig};
