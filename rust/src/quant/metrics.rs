//! Error metrics: MSE, RMSE, and the paper's MSE++ (Eq. 11/12).
//!
//! Combo selection compares MSE++ in EXACT integer arithmetic: the errors
//! are int magnitudes (<= 255), alpha is a rational num/den, so the score
//! `den*sum(e^2) + num*(sum e)^2` fits comfortably in i64 for any group
//! size we use and is bit-identical across Rust and numpy.

/// Rational MSE++ coefficient alpha = num/den (default 1).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Alpha {
    pub num: i64,
    pub den: i64,
}

impl Alpha {
    pub const ONE: Alpha = Alpha { num: 1, den: 1 };

    /// Mirror of python `_alpha_ratio`: den=100, num=round(alpha*100).
    pub fn from_f64(alpha: f64) -> Alpha {
        Alpha { num: (alpha * 100.0).round() as i64, den: 100 }
    }

    pub fn as_f64(&self) -> f64 {
        self.num as f64 / self.den as f64
    }
}

/// Integer MSE++ score (numerator; the 1/N normalization is a shared
/// constant and irrelevant for argmin): den*Σe² + num*(Σe)².
#[inline]
pub fn msepp_int(errs: &[i64], alpha: Alpha) -> i64 {
    let mut se = 0i64;
    let mut sq = 0i64;
    for &e in errs {
        se += e;
        sq += e * e;
    }
    alpha.den * sq + alpha.num * se * se
}

/// Incremental form for hot loops: given (sum_e, sum_e2).
#[inline]
pub fn msepp_from_sums(sum_e: i64, sum_e2: i64, alpha: Alpha) -> i64 {
    alpha.den * sum_e2 + alpha.num * sum_e * sum_e
}

/// Float MSE++ (Eq. 12) for reporting, normalized by group size.
pub fn msepp(x: &[f64], xq: &[f64], alpha: f64) -> f64 {
    assert_eq!(x.len(), xq.len());
    let n = x.len() as f64;
    let mut se = 0.0;
    let mut sq = 0.0;
    for (a, b) in x.iter().zip(xq) {
        let e = a - b;
        se += e;
        sq += e * e;
    }
    (alpha * se * se + sq) / n
}

pub fn mse(x: &[f64], xq: &[f64]) -> f64 {
    assert_eq!(x.len(), xq.len());
    if x.is_empty() {
        return 0.0;
    }
    x.iter()
        .zip(xq)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        / x.len() as f64
}

pub fn rmse(x: &[f64], xq: &[f64]) -> f64 {
    mse(x, xq).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alpha_one_reduces_to_mse_plus_signed() {
        // errs [1, -1]: sum=0 -> msepp == sum of squares
        assert_eq!(msepp_int(&[1, -1], Alpha::ONE), 2);
        // errs [1, 1]: sum=2 -> 2 + 4 = 6
        assert_eq!(msepp_int(&[1, 1], Alpha::ONE), 6);
    }

    #[test]
    fn alpha_zero_is_pure_mse() {
        let a = Alpha { num: 0, den: 1 };
        assert_eq!(msepp_int(&[3, -2], a), 13);
    }

    #[test]
    fn rational_alpha_matches_python() {
        let a = Alpha::from_f64(0.5);
        assert_eq!(a.num, 50);
        assert_eq!(a.den, 100);
        // den*Σe² + num*(Σe)² = 100*5 + 50*1 = 550 for errs [2,-1]... Σe=1, Σe²=5
        assert_eq!(msepp_int(&[2, -1], a), 550);
    }

    #[test]
    fn float_msepp_penalizes_drift() {
        // same MSE, different drift
        let x = [1.0, 1.0];
        let drift = msepp(&x, &[0.9, 0.9], 1.0);
        let balanced = msepp(&x, &[0.9, 1.1], 1.0);
        assert!(drift > balanced);
    }

    #[test]
    fn sums_form_matches() {
        let errs = [3i64, -1, 2];
        let se: i64 = errs.iter().sum();
        let sq: i64 = errs.iter().map(|e| e * e).sum();
        assert_eq!(
            msepp_int(&errs, Alpha::ONE),
            msepp_from_sums(se, sq, Alpha::ONE)
        );
    }

    #[test]
    fn rmse_basic() {
        assert!((rmse(&[1.0, 2.0], &[1.0, 0.0]) - (2.0f64).sqrt()).abs() < 1e-12);
    }
}
