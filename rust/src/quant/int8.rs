//! Symmetric int8 pre-quantization (sign-magnitude, B = 8, |q| <= 127).
//!
//! This is the underlying 8-bit representation SWIS decomposes (paper
//! Eq. 2). Conventions are shared bit-for-bit with
//! `python/compile/swis_quant.py::to_int8` (cross-checked by goldens):
//! scale = max|w| / 127, round HALF-TO-EVEN (numpy's `np.round`), zero
//! weights carry sign +1.

pub const BITS: u32 = 8;
pub const MAG_MAX: i64 = 127;

/// Round half to even (banker's rounding), matching `np.round`.
#[inline]
pub fn round_half_even(x: f64) -> f64 {
    let r = x.round(); // half away from zero
    if (x - x.trunc()).abs() == 0.5 {
        // exactly .5 -> round to even neighbor
        let f = x.floor();
        if (f as i64) % 2 == 0 {
            f
        } else {
            f + 1.0
        }
    } else {
        r
    }
}

/// int8 view of a float layer: magnitudes in [0,127], signs in {-1,+1}.
#[derive(Clone, Debug)]
pub struct Int8Layer {
    pub mags: Vec<u8>,
    pub signs: Vec<i8>,
    pub scale: f64,
}

impl Int8Layer {
    pub fn from_f64(w: &[f64]) -> Int8Layer {
        let amax = w.iter().fold(0.0f64, |m, &x| m.max(x.abs()));
        let scale = if amax > 0.0 { amax / MAG_MAX as f64 } else { 1.0 };
        let mut mags = Vec::with_capacity(w.len());
        let mut signs = Vec::with_capacity(w.len());
        for &x in w {
            let q = round_half_even(x / scale).clamp(-(MAG_MAX as f64), MAG_MAX as f64)
                as i64;
            signs.push(if q < 0 { -1 } else { 1 });
            mags.push(q.unsigned_abs() as u8);
        }
        Int8Layer { mags, signs, scale }
    }

    /// Dequantize back to floats.
    pub fn to_f64(&self) -> Vec<f64> {
        self.mags
            .iter()
            .zip(&self.signs)
            .map(|(&m, &s)| m as f64 * s as f64 * self.scale)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_half_even_matches_numpy() {
        // np.round semantics
        assert_eq!(round_half_even(0.5), 0.0);
        assert_eq!(round_half_even(1.5), 2.0);
        assert_eq!(round_half_even(2.5), 2.0);
        assert_eq!(round_half_even(-0.5), 0.0);
        assert_eq!(round_half_even(-1.5), -2.0);
        assert_eq!(round_half_even(1.4999), 1.0);
        assert_eq!(round_half_even(1.5001), 2.0);
    }

    #[test]
    fn scale_maps_max_to_127() {
        let l = Int8Layer::from_f64(&[0.5, -1.0, 0.25]);
        assert_eq!(l.mags, vec![64, 127, 32]);
        assert_eq!(l.signs, vec![1, -1, 1]);
        assert!((l.scale - 1.0 / 127.0).abs() < 1e-15);
    }

    #[test]
    fn zero_layer_uses_unit_scale() {
        let l = Int8Layer::from_f64(&[0.0, 0.0]);
        assert_eq!(l.scale, 1.0);
        assert_eq!(l.mags, vec![0, 0]);
        assert_eq!(l.signs, vec![1, 1]);
    }

    #[test]
    fn roundtrip_error_bounded() {
        let w: Vec<f64> = (0..100).map(|i| (i as f64 - 50.0) / 37.0).collect();
        let l = Int8Layer::from_f64(&w);
        let r = l.to_f64();
        for (a, b) in w.iter().zip(&r) {
            assert!((a - b).abs() <= l.scale * 0.5 + 1e-12);
        }
    }
}
