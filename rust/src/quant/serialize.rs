//! Binary `.swis` container: the packed weight format as actual
//! bit-packed bytes — the file a deployment flashes next to the
//! accelerator. The payload layout is exactly the Sec. 3.3 accounting
//! ([`PackedLayer::storage_bits`]), so the measured file size *is* the
//! compression the paper reports (plus a fixed 28-byte header and, for
//! scheduled layers, 4 bits/filter of shift counts).
//!
//! Layout (bit-packed, LSB-first within bytes):
//!   magic "SWIS"  version:u8  flags:u8  group_size:u16  n_shifts:u16
//!   n_filters:u32 fan_in:u32  scale:f64                      (header)
//!   signs    1 bit / lane            (n_groups * group_size)
//!   shifts   SWIS:  3 bits / shift / group
//!            SWIS-C: 3 bits / group (window offset)
//!   masks    1 bit / lane / shift
//!   [filter_shifts 4 bits / filter when flags & SCHEDULED]

use anyhow::{bail, Result};

use super::packed::PackedLayer;

const MAGIC: &[u8; 4] = b"SWIS";
const VERSION: u8 = 1;
const FLAG_CONSECUTIVE: u8 = 1;
const FLAG_SCHEDULED: u8 = 2;

/// LSB-first bit writer.
struct BitWriter {
    bytes: Vec<u8>,
    nbits: usize,
}

impl BitWriter {
    fn new() -> BitWriter {
        BitWriter { bytes: Vec::new(), nbits: 0 }
    }

    fn push(&mut self, value: u32, width: usize) {
        for b in 0..width {
            let bit = (value >> b) & 1;
            if self.nbits % 8 == 0 {
                self.bytes.push(0);
            }
            let byte = self.nbits / 8;
            self.bytes[byte] |= (bit as u8) << (self.nbits % 8);
            self.nbits += 1;
        }
    }
}

/// LSB-first bit reader.
struct BitReader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> BitReader<'a> {
    fn new(bytes: &'a [u8]) -> BitReader<'a> {
        BitReader { bytes, pos: 0 }
    }

    fn pull(&mut self, width: usize) -> Result<u32> {
        let mut v = 0u32;
        for b in 0..width {
            let byte = self.pos / 8;
            if byte >= self.bytes.len() {
                bail!("truncated .swis payload at bit {}", self.pos);
            }
            let bit = (self.bytes[byte] >> (self.pos % 8)) & 1;
            v |= (bit as u32) << b;
            self.pos += 1;
        }
        Ok(v)
    }
}

/// Serialize to the binary container.
pub fn to_bytes(p: &PackedLayer) -> Result<Vec<u8>> {
    p.validate()?;
    if p.shape.len() != 2 {
        // layers are always stored filters-first 2-D (K, fan_in)
        bail!("serialize expects a 2-D filters-first shape, got {:?}", p.shape);
    }
    let mut out = Vec::with_capacity(28 + p.storage_bits() as usize / 8 + 8);
    out.extend_from_slice(MAGIC);
    out.push(VERSION);
    let mut flags = 0u8;
    if p.consecutive {
        flags |= FLAG_CONSECUTIVE;
    }
    if p.filter_shifts.is_some() {
        flags |= FLAG_SCHEDULED;
    }
    out.push(flags);
    out.extend_from_slice(&(p.group_size as u16).to_le_bytes());
    out.extend_from_slice(&(p.n_shifts as u16).to_le_bytes());
    out.extend_from_slice(&(p.n_filters() as u32).to_le_bytes());
    out.extend_from_slice(&(p.fan_in() as u32).to_le_bytes());
    out.extend_from_slice(&p.scale.to_le_bytes());

    let g = p.n_groups();
    let gs = p.group_size;
    let n = p.n_shifts;
    let mut w = BitWriter::new();
    for &s in &p.signs {
        w.push(if s < 0 { 1 } else { 0 }, 1);
    }
    if p.consecutive {
        for gi in 0..g {
            w.push(p.shifts[gi * n] as u32, 3); // window offset
        }
    } else {
        for &s in &p.shifts {
            w.push(s as u32, 3);
        }
    }
    for &m in &p.masks {
        w.push(m as u32, 1);
    }
    if let Some(fs) = &p.filter_shifts {
        for &f in fs {
            w.push(f as u32, 4);
        }
    }
    let _ = gs;
    out.extend_from_slice(&w.bytes);
    Ok(out)
}

/// Deserialize from the binary container.
pub fn from_bytes(bytes: &[u8]) -> Result<PackedLayer> {
    if bytes.len() < 28 || &bytes[..4] != MAGIC {
        bail!("not a .swis container");
    }
    if bytes[4] != VERSION {
        bail!("unsupported .swis version {}", bytes[4]);
    }
    let flags = bytes[5];
    let consecutive = flags & FLAG_CONSECUTIVE != 0;
    let scheduled = flags & FLAG_SCHEDULED != 0;
    let group_size = u16::from_le_bytes([bytes[6], bytes[7]]) as usize;
    let n_shifts = u16::from_le_bytes([bytes[8], bytes[9]]) as usize;
    let n_filters = u32::from_le_bytes(bytes[10..14].try_into().unwrap()) as usize;
    let fan_in = u32::from_le_bytes(bytes[14..18].try_into().unwrap()) as usize;
    let scale = f64::from_le_bytes(bytes[18..26].try_into().unwrap());
    if group_size == 0 || n_shifts == 0 || n_shifts > 8 {
        bail!("corrupt .swis header: G={group_size} N={n_shifts}");
    }
    let gpf = fan_in.div_ceil(group_size);
    let g = n_filters
        .checked_mul(gpf)
        .ok_or_else(|| anyhow::anyhow!("corrupt .swis header: group count overflows"))?;
    let gs = group_size;
    let n = n_shifts;
    // a forged header must fail as a typed error BEFORE any group-sized
    // allocation: the payload the header promises has to fit the bytes
    // actually present (u128 arithmetic — the products cannot overflow)
    let lanes = g as u128 * gs as u128;
    let mut need_bits = lanes // signs
        + lanes * n as u128 // masks
        + if consecutive { g as u128 * 3 } else { g as u128 * n as u128 * 3 };
    if scheduled {
        need_bits += n_filters as u128 * 4;
    }
    let avail_bits = (bytes.len() as u128 - 26) * 8;
    if need_bits > avail_bits {
        bail!(
            "truncated .swis payload: header promises {need_bits} bits, container has {avail_bits}"
        );
    }

    let mut r = BitReader::new(&bytes[26..]);
    let mut signs = vec![1i8; g * gs];
    for s in signs.iter_mut() {
        if r.pull(1)? != 0 {
            *s = -1;
        }
    }
    let mut shifts = vec![0u8; g * n];
    if consecutive {
        for gi in 0..g {
            let off = r.pull(3)? as u8;
            for j in 0..n {
                shifts[gi * n + j] = (off + j as u8).min(7);
            }
        }
    } else {
        for s in shifts.iter_mut() {
            *s = r.pull(3)? as u8;
        }
    }
    let mut masks = vec![0u8; g * gs * n];
    for m in masks.iter_mut() {
        *m = r.pull(1)? as u8;
    }
    let filter_shifts = if scheduled {
        let mut fs = vec![0usize; n_filters];
        for f in fs.iter_mut() {
            *f = r.pull(4)? as usize;
        }
        Some(fs)
    } else {
        None
    };
    let p = PackedLayer {
        shape: vec![n_filters, fan_in],
        group_size,
        n_shifts,
        scale,
        shifts,
        masks,
        signs,
        consecutive,
        filter_shifts,
    };
    p.validate()?;
    Ok(p)
}

/// Measured payload size in bits (excluding the fixed header) — must
/// equal [`PackedLayer::storage_bits`] for unscheduled layers.
pub fn payload_bits(p: &PackedLayer) -> u64 {
    let extra = p.filter_shifts.as_ref().map_or(0, |fs| 4 * fs.len() as u64);
    p.storage_bits() + extra
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize, Alpha, QuantConfig};
    use crate::schedule::quantize_or_schedule;
    use crate::util::rng::Rng;

    fn layer(seed: u64, n: usize, g: usize, consecutive: bool) -> PackedLayer {
        let mut rng = Rng::new(seed);
        let w = rng.normal_vec(16 * 30, 0.0, 0.07);
        let cfg = QuantConfig { n_shifts: n, group_size: g, alpha: Alpha::ONE, consecutive };
        quantize(&w, &[16, 30], &cfg).unwrap()
    }

    fn assert_equal(a: &PackedLayer, b: &PackedLayer) {
        assert_eq!(a.shape, b.shape);
        assert_eq!(a.shifts, b.shifts);
        assert_eq!(a.masks, b.masks);
        assert_eq!(a.signs, b.signs);
        assert_eq!(a.scale, b.scale);
        assert_eq!(a.consecutive, b.consecutive);
        assert_eq!(a.filter_shifts, b.filter_shifts);
    }

    #[test]
    fn roundtrip_swis_and_swis_c() {
        for consecutive in [false, true] {
            for (n, g) in [(2usize, 4usize), (3, 4), (4, 1), (3, 8)] {
                let p = layer(7, n, g, consecutive);
                let bytes = to_bytes(&p).unwrap();
                let q = from_bytes(&bytes).unwrap();
                assert_equal(&p, &q);
            }
        }
    }

    #[test]
    fn roundtrip_scheduled_layer() {
        let mut rng = Rng::new(3);
        let w = rng.normal_vec(16 * 16, 0.0, 0.05);
        let p = quantize_or_schedule(&w, &[16, 16], 2.5, 4, false, Alpha::ONE).unwrap();
        let q = from_bytes(&to_bytes(&p).unwrap()).unwrap();
        assert_equal(&p, &q);
        assert_eq!(q.effective_shifts(), 2.5);
    }

    #[test]
    fn file_size_is_the_papers_accounting() {
        let p = layer(9, 3, 4, false);
        let bytes = to_bytes(&p).unwrap();
        let payload = bytes.len() as u64 - 26;
        assert_eq!(payload, payload_bits(&p).div_ceil(8));
        // SWIS-C container is strictly smaller at the same (N, G)
        let pc = layer(9, 3, 4, true);
        assert!(to_bytes(&pc).unwrap().len() < bytes.len());
    }

    #[test]
    fn roundtrip_packed_depthwise_layer() {
        // a depthwise layer packs filters-first (channels, k*k): fan-in 9
        // makes ragged groups (4+4+1 at G=4) — the container must carry
        // the pad-lane accounting exactly
        use crate::nets::{mobilenet_v2, surrogate_weights};
        let net = mobilenet_v2();
        let dw = net.layer("block0.dw").unwrap();
        let w = surrogate_weights(dw, 5);
        for (n, consecutive) in [(3usize, false), (2, true)] {
            let cfg = QuantConfig { n_shifts: n, group_size: 4, alpha: Alpha::ONE, consecutive };
            let p = quantize(&w, &[dw.out_c, dw.fan_in()], &cfg).unwrap();
            assert_eq!(p.shape, vec![32, 9]);
            let bytes = to_bytes(&p).unwrap();
            let q = from_bytes(&bytes).unwrap();
            assert_equal(&p, &q);
            // the measured file IS the paper's accounting (+ header)
            assert_eq!(bytes.len() as u64 - 26, payload_bits(&p).div_ceil(8));
            // and the round-tripped layer still drives the native kernel
            let prep = crate::exec::PreparedDepthwise::from_packed(&q).unwrap();
            assert_eq!(prep.channels(), 32);
        }
    }

    #[test]
    fn dequant_survives_roundtrip() {
        let p = layer(11, 3, 4, false);
        let q = from_bytes(&to_bytes(&p).unwrap()).unwrap();
        assert_eq!(p.to_f64(), q.to_f64());
    }

    #[test]
    fn rejects_corruption() {
        let p = layer(13, 2, 4, false);
        let mut bytes = to_bytes(&p).unwrap();
        assert!(from_bytes(&bytes[..10]).is_err()); // truncated
        bytes[0] = b'X';
        assert!(from_bytes(&bytes).is_err()); // bad magic
        let mut b2 = to_bytes(&p).unwrap();
        b2[4] = 99;
        assert!(from_bytes(&b2).is_err()); // bad version
        let mut b3 = to_bytes(&p).unwrap();
        b3[8] = 9; // n_shifts = 9
        b3[9] = 0;
        assert!(from_bytes(&b3).is_err());
    }
}
