//! The quantization planner: the single engine behind `quantize`,
//! `schedule_layer`, and `allocate_network` (paper Sec. 4.1/4.3 offline
//! decomposition, treated as one shared sweep in the style of bit-serial
//! weight-pool cost tables).
//!
//! Three ideas, layered:
//!
//! 1. **LUT bank** — combo lookup tables are data-independent, keyed only
//!    by the combo family `(n_shifts, consecutive)`. [`luts`] caches each
//!    family in a process-global `OnceLock`, so LUTs are built once per
//!    process instead of once per `quantize`/`per_filter_cost` call.
//! 2. **All-`n` sweep** — [`cost_table`] computes the best score for
//!    every shift count `n = 1..=max_n` in ONE pass over the groups,
//!    instead of `max_n` independent rescans. Two sound prunes keep it
//!    bit-identical to the naive per-`n` selection: within a family the
//!    combo scan stops as soon as the score hits the alpha floor (score
//!    0 ⇒ lossless ⇒ no later combo can be strictly smaller), and across
//!    families a lossless group stays lossless for every larger `n`
//!    (codebooks only grow), so the remaining rows are filled with 0.
//! 3. **Parallel group sweep** — groups are independent, so the sweep is
//!    chunked over `std::thread::scope` threads (no runtime deps). Every
//!    chunk writes a disjoint output slice, making results identical for
//!    any thread count — see the `*_chunked` variants and the
//!    thread-invariance property test.
//!
//! The argmin contract is unchanged: strict-less comparison, earliest
//! (lexicographic) combo wins ties — bit-identical with the Python
//! reference. The [`reference`] module keeps the pre-planner scalar path
//! (fresh LUTs per call, sequential full scans) alive for equivalence
//! tests and speedup benchmarking.

use std::sync::OnceLock;

use super::combos::{consecutive_combos, shift_combos};
use super::int8::BITS;
use super::metrics::{msepp_from_sums, Alpha};
use super::swis::{build_luts, packed_sums, ComboLut, GroupedMags, PACK_MAX_GS};

/// Const initializer for the bank cells (usable as an array-repeat
/// element because it is a `const` item, not a shared value).
#[allow(clippy::declare_interior_mutable_const)]
const LUT_CELL: OnceLock<Vec<ComboLut>> = OnceLock::new();

/// One `OnceLock` per combo family: `[consecutive][n_shifts - 1]`.
static LUT_BANK: [[OnceLock<Vec<ComboLut>>; BITS as usize]; 2] =
    [[LUT_CELL; BITS as usize]; 2];

/// The cached LUTs for a combo family. Built on first use, shared for
/// the life of the process; combo enumeration order (and hence tie
/// resolution) is identical to building them fresh.
pub fn luts(n_shifts: usize, consecutive: bool) -> &'static [ComboLut] {
    assert!(
        n_shifts >= 1 && n_shifts <= BITS as usize,
        "n_shifts out of range: {n_shifts}"
    );
    LUT_BANK[consecutive as usize][n_shifts - 1].get_or_init(|| {
        let combos = if consecutive {
            consecutive_combos(n_shifts, BITS)
        } else {
            shift_combos(n_shifts, BITS)
        };
        build_luts(&combos)
    })
}

/// Worker threads for the group sweep: `SWIS_THREADS` env override, else
/// available parallelism capped at 16.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("SWIS_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.clamp(1, 64);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(16)
}

/// Below this many magnitude lanes, spawn overhead beats the win and the
/// auto entry points run inline. Only the NON-`_chunked` wrappers apply
/// this — an explicit `n_threads` is always honored, so tests can force
/// the chunked path on small inputs.
const PARALLEL_MIN_LANES: usize = 1 << 13;

/// [`default_threads`], degraded to 1 for inputs too small to amortize
/// thread spawns.
pub(crate) fn auto_threads(lanes: usize) -> usize {
    if lanes < PARALLEL_MIN_LANES {
        1
    } else {
        default_threads()
    }
}

/// Whether `score == 0` is provably the global floor for this alpha, so
/// an argmin scan may stop there: den·Σe² + num·(Σe)² ≥ 0 whenever both
/// coefficients are non-negative (den > 0 additionally forces Σe² = 0,
/// i.e. a lossless group).
#[inline]
fn zero_is_floor(alpha: Alpha) -> bool {
    alpha.num >= 0 && alpha.den > 0
}

/// Argmin over a family's combos for one magnitude pattern, returning
/// `(combo index, score)`. Strict-less comparison, earliest combo wins
/// ties; when zero is the alpha floor the scan stops at the first
/// lossless combo (no later combo can be strictly smaller).
#[inline]
pub fn best_combo_scored(mags: &[u8], luts: &[ComboLut], alpha: Alpha) -> (u32, i64) {
    let floor_exit = zero_is_floor(alpha);
    let mut best_err = i64::MAX;
    let mut best = 0u32;
    if mags.len() <= PACK_MAX_GS {
        for (ci, lut) in luts.iter().enumerate() {
            let (se, sq) = packed_sums(lut, mags);
            let score = msepp_from_sums(se, sq, alpha);
            if score < best_err {
                best_err = score;
                best = ci as u32;
                if floor_exit && score == 0 {
                    break;
                }
            }
        }
    } else {
        for (ci, lut) in luts.iter().enumerate() {
            let mut se = 0i64;
            let mut sq = 0i64;
            for &m in mags {
                let e = lut.e[m as usize] as i64;
                se += e;
                sq += e * e;
            }
            let score = msepp_from_sums(se, sq, alpha);
            if score < best_err {
                best_err = score;
                best = ci as u32;
                if floor_exit && score == 0 {
                    break;
                }
            }
        }
    }
    (best, best_err)
}

// ---------------------------------------------------------------------
// group selection (the `quantize` hot path)
// ---------------------------------------------------------------------

/// Select the best combo per group, in parallel over [`default_threads`].
/// Returns `(combo index per group, per-lane qmags)` — bit-identical to
/// the sequential scan for any thread count.
pub fn select_groups(
    gm: &GroupedMags,
    n_shifts: usize,
    consecutive: bool,
    alpha: Alpha,
) -> (Vec<u32>, Vec<u8>) {
    select_groups_chunked(
        gm,
        luts(n_shifts, consecutive),
        alpha,
        auto_threads(gm.mags.len()),
    )
}

/// [`select_groups`] with an explicit LUT family and thread count. The
/// requested `n_threads` is honored exactly (capped at one group per
/// thread) so the chunked path is testable on inputs of any size.
pub fn select_groups_chunked(
    gm: &GroupedMags,
    luts: &[ComboLut],
    alpha: Alpha,
    n_threads: usize,
) -> (Vec<u32>, Vec<u8>) {
    let n_groups = gm.n_groups();
    let gs = gm.group_size;
    let mut best_idx = vec![0u32; n_groups];
    let mut best_q = vec![0u8; n_groups * gs];

    let nt = n_threads.clamp(1, n_groups.max(1));
    if nt <= 1 {
        select_span(gm, luts, alpha, 0, n_groups, &mut best_idx, &mut best_q);
        return (best_idx, best_q);
    }

    let chunk = n_groups.div_ceil(nt);
    std::thread::scope(|s| {
        let mut idx_rest: &mut [u32] = &mut best_idx;
        let mut q_rest: &mut [u8] = &mut best_q;
        let mut g0 = 0usize;
        while g0 < n_groups {
            let take = chunk.min(n_groups - g0);
            let tmp_idx = std::mem::take(&mut idx_rest);
            let (idx_chunk, ir) = tmp_idx.split_at_mut(take);
            idx_rest = ir;
            let tmp_q = std::mem::take(&mut q_rest);
            let (q_chunk, qr) = tmp_q.split_at_mut(take * gs);
            q_rest = qr;
            let start = g0;
            s.spawn(move || {
                select_span(gm, luts, alpha, start, start + take, idx_chunk, q_chunk);
            });
            g0 += take;
        }
    });
    (best_idx, best_q)
}

/// Sequential selection over groups `[g0, g1)`; output slices are indexed
/// relative to `g0` (each parallel chunk owns a disjoint slice).
fn select_span(
    gm: &GroupedMags,
    luts: &[ComboLut],
    alpha: Alpha,
    g0: usize,
    g1: usize,
    out_idx: &mut [u32],
    out_q: &mut [u8],
) {
    let gs = gm.group_size;
    for g in g0..g1 {
        let mags = gm.group(g);
        let (best, _) = best_combo_scored(mags, luts, alpha);
        out_idx[g - g0] = best;
        let lut = &luts[best as usize];
        for (i, &m) in mags.iter().enumerate() {
            out_q[(g - g0) * gs + i] = lut.q[m as usize];
        }
    }
}

// ---------------------------------------------------------------------
// all-n cost sweep (the scheduler / allocator cost oracle)
// ---------------------------------------------------------------------

/// Per-filter cost table for ALL shift counts in one pass over the
/// groups: `table[n-1][f]` = integer MSE++ of filter `f` quantized
/// uniformly at `n` shifts. Parallel over [`default_threads`].
pub fn cost_table(
    gm: &GroupedMags,
    max_n: usize,
    consecutive: bool,
    alpha: Alpha,
) -> Vec<Vec<i64>> {
    cost_table_chunked(gm, max_n, consecutive, alpha, auto_threads(gm.mags.len()))
}

/// [`cost_table`] with an explicit thread count, honored exactly (capped
/// at one filter per thread) so the chunked path is testable on inputs
/// of any size.
pub fn cost_table_chunked(
    gm: &GroupedMags,
    max_n: usize,
    consecutive: bool,
    alpha: Alpha,
    n_threads: usize,
) -> Vec<Vec<i64>> {
    assert!(max_n >= 1 && max_n <= BITS as usize, "max_n out of range: {max_n}");
    let k = gm.n_filters;
    let families: Vec<&'static [ComboLut]> =
        (1..=max_n).map(|n| luts(n, consecutive)).collect();
    if k == 0 {
        return vec![Vec::new(); max_n];
    }

    let nt = n_threads.clamp(1, k);
    if nt <= 1 {
        return sweep_filter_span(gm, &families, alpha, 0, k);
    }

    let mut table = vec![vec![0i64; k]; max_n];
    let chunk = k.div_ceil(nt);
    let mut parts: Vec<(usize, usize, Vec<Vec<i64>>)> = Vec::new();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        let mut f0 = 0usize;
        while f0 < k {
            let f1 = (f0 + chunk).min(k);
            let fam = &families;
            handles.push((f0, f1, s.spawn(move || sweep_filter_span(gm, fam, alpha, f0, f1))));
            f0 = f1;
        }
        for (f0, f1, h) in handles {
            parts.push((f0, f1, h.join().expect("planner sweep thread panicked")));
        }
    });
    for (f0, f1, part) in parts {
        for (ni, row) in part.into_iter().enumerate() {
            table[ni][f0..f1].copy_from_slice(&row);
        }
    }
    table
}

/// The single-pass core: for filters `[f0, f1)`, accumulate the best
/// score of every group under every family. Families are visited in
/// ascending `n`; once a group scores 0 (lossless) at some `n`, every
/// larger family also scores 0 — codebooks only grow with `n` — so the
/// remaining families are skipped (their contribution is exactly 0).
fn sweep_filter_span(
    gm: &GroupedMags,
    families: &[&[ComboLut]],
    alpha: Alpha,
    f0: usize,
    f1: usize,
) -> Vec<Vec<i64>> {
    let gpf = gm.groups_per_filter;
    let prune = zero_is_floor(alpha);
    let mut out = vec![vec![0i64; f1 - f0]; families.len()];
    for f in f0..f1 {
        for gl in 0..gpf {
            let mags = gm.group(f * gpf + gl);
            for (ni, fam) in families.iter().enumerate() {
                let (_, score) = best_combo_scored(mags, fam, alpha);
                out[ni][f - f0] += score;
                if prune && score == 0 {
                    break;
                }
            }
        }
    }
    out
}

/// Per-filter cost at a single shift count — the drop-in replacement for
/// the old `per_filter_cost` scan, now routed through the LUT bank and
/// the shared argmin helper.
pub fn per_filter_cost_at(
    gm: &GroupedMags,
    n_shifts: usize,
    consecutive: bool,
    alpha: Alpha,
) -> Vec<i64> {
    let family = luts(n_shifts, consecutive);
    let k = gm.n_filters;
    let gpf = gm.groups_per_filter;
    let mut out = vec![0i64; k];
    for f in 0..k {
        for gl in 0..gpf {
            let (_, score) = best_combo_scored(gm.group(f * gpf + gl), family, alpha);
            out[f] += score;
        }
    }
    out
}

// ---------------------------------------------------------------------
// pre-planner reference path (equivalence tests + speedup benchmarks)
// ---------------------------------------------------------------------

/// The pre-planner scalar path, kept bit-for-bit: fresh LUTs on every
/// call, sequential full combo scans, no floor pruning. Benchmarks
/// measure the planner's speedup against this; the equivalence property
/// test pins the planner's outputs to it.
pub mod reference {
    use super::*;

    /// Full-scan argmin with no early exit (the pre-planner loop body).
    pub fn best_combo_full(mags: &[u8], luts: &[ComboLut], alpha: Alpha) -> (u32, i64) {
        let mut best_err = i64::MAX;
        let mut best = 0u32;
        for (ci, lut) in luts.iter().enumerate() {
            let (se, sq) = if mags.len() <= PACK_MAX_GS {
                packed_sums(lut, mags)
            } else {
                let mut se = 0i64;
                let mut sq = 0i64;
                for &m in mags {
                    let e = lut.e[m as usize] as i64;
                    se += e;
                    sq += e * e;
                }
                (se, sq)
            };
            let score = msepp_from_sums(se, sq, alpha);
            if score < best_err {
                best_err = score;
                best = ci as u32;
            }
        }
        (best, best_err)
    }

    /// Sequential group selection with freshly built LUTs.
    pub fn select_groups_rebuild(
        gm: &GroupedMags,
        n_shifts: usize,
        consecutive: bool,
        alpha: Alpha,
    ) -> (Vec<u32>, Vec<u8>) {
        let combos = if consecutive {
            consecutive_combos(n_shifts, BITS)
        } else {
            shift_combos(n_shifts, BITS)
        };
        let luts = build_luts(&combos);
        let n_groups = gm.n_groups();
        let gs = gm.group_size;
        let mut best_idx = vec![0u32; n_groups];
        let mut best_q = vec![0u8; n_groups * gs];
        for g in 0..n_groups {
            let mags = gm.group(g);
            let (best, _) = best_combo_full(mags, &luts, alpha);
            best_idx[g] = best;
            let lut = &luts[best as usize];
            for (i, &m) in mags.iter().enumerate() {
                best_q[g * gs + i] = lut.q[m as usize];
            }
        }
        (best_idx, best_q)
    }

    /// The pre-planner cost oracle: one full rescan per call, fresh LUTs.
    pub fn per_filter_cost_rebuild(
        gm: &GroupedMags,
        n_shifts: usize,
        consecutive: bool,
        alpha: Alpha,
    ) -> Vec<i64> {
        let combos = if consecutive {
            consecutive_combos(n_shifts, BITS)
        } else {
            shift_combos(n_shifts, BITS)
        };
        let luts = build_luts(&combos);
        let mut out = vec![0i64; gm.n_filters];
        for g in 0..gm.n_groups() {
            let (_, score) = best_combo_full(gm.group(g), &luts, alpha);
            out[g / gm.groups_per_filter] += score;
        }
        out
    }

    /// The pre-planner cost table: `max_n` independent full passes.
    pub fn cost_table_rebuild(
        gm: &GroupedMags,
        max_n: usize,
        consecutive: bool,
        alpha: Alpha,
    ) -> Vec<Vec<i64>> {
        (1..=max_n)
            .map(|n| per_filter_cost_rebuild(gm, n, consecutive, alpha))
            .collect()
    }

    /// The pre-planner `quantize` end-to-end: fresh LUTs, sequential
    /// selection, same packing. Benchmarks measure the planner's
    /// speedup against this.
    pub fn quantize_rebuild(
        w: &[f64],
        shape: &[usize],
        cfg: &crate::quant::QuantConfig,
    ) -> anyhow::Result<crate::quant::PackedLayer> {
        if cfg.n_shifts == 0 || cfg.n_shifts > BITS as usize {
            anyhow::bail!("n_shifts must be in [1,8], got {}", cfg.n_shifts);
        }
        let gm = crate::quant::swis::group_mags(w, shape, cfg.group_size)?;
        let combos = cfg.combos();
        let luts = build_luts(&combos);
        let n_groups = gm.n_groups();
        let gs = gm.group_size;
        let mut best_idx = vec![0u32; n_groups];
        let mut best_q = vec![0u8; n_groups * gs];
        for g in 0..n_groups {
            let mags = gm.group(g);
            let (best, _) = best_combo_full(mags, &luts, cfg.alpha);
            best_idx[g] = best;
            let lut = &luts[best as usize];
            for (i, &m) in mags.iter().enumerate() {
                best_q[g * gs + i] = lut.q[m as usize];
            }
        }
        Ok(crate::quant::swis::pack(
            &gm, &luts, &best_idx, &best_q, shape, cfg, None,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::swis::group_mags;
    use crate::util::rng::Rng;

    fn gm(seed: u64, k: usize, fan_in: usize, gs: usize) -> GroupedMags {
        let mut rng = Rng::new(seed);
        let w = rng.normal_vec(k * fan_in, 0.0, 0.08);
        group_mags(&w, &[k, fan_in], gs).unwrap()
    }

    #[test]
    fn bank_caches_and_matches_fresh_build() {
        let a = luts(3, false);
        let b = luts(3, false);
        // same allocation both times
        assert!(std::ptr::eq(a.as_ptr(), b.as_ptr()));
        let fresh = build_luts(&shift_combos(3, BITS));
        assert_eq!(a.len(), fresh.len());
        for (x, y) in a.iter().zip(&fresh) {
            assert_eq!(x.combo, y.combo);
            assert_eq!(x.q, y.q);
            assert_eq!(x.e, y.e);
            assert_eq!(x.packed, y.packed);
        }
        assert_eq!(luts(2, true).len(), 7); // SWIS-C windows: 9 - N
    }

    #[test]
    fn selection_matches_reference_scan() {
        let g = gm(3, 8, 24, 4);
        for n in 1..=4 {
            for consecutive in [false, true] {
                let (pi, pq) =
                    select_groups_chunked(&g, luts(n, consecutive), Alpha::ONE, 4);
                let (ri, rq) =
                    reference::select_groups_rebuild(&g, n, consecutive, Alpha::ONE);
                assert_eq!(pi, ri, "combo indices diverged at n={n} cons={consecutive}");
                assert_eq!(pq, rq, "qmags diverged at n={n} cons={consecutive}");
            }
        }
    }

    #[test]
    fn cost_table_matches_per_n_rescans() {
        let g = gm(7, 6, 32, 4);
        for consecutive in [false, true] {
            let fast = cost_table_chunked(&g, 5, consecutive, Alpha::ONE, 3);
            let slow = reference::cost_table_rebuild(&g, 5, consecutive, Alpha::ONE);
            assert_eq!(fast, slow, "cost table diverged (cons={consecutive})");
        }
    }

    #[test]
    fn lossless_groups_early_exit_is_exact() {
        // An all-zero layer is lossless for EVERY combo: every score is
        // 0, so this exercises both the floor prune (first combo wins)
        // and the all-ties path of the argmin contract (earliest combo,
        // index 0, must be selected everywhere).
        let w = vec![0.0f64; 32];
        let g = group_mags(&w, &[4, 8], 4).unwrap();
        let fast = cost_table_chunked(&g, 4, false, Alpha::ONE, 1);
        let slow = reference::cost_table_rebuild(&g, 4, false, Alpha::ONE);
        assert_eq!(fast, slow);
        assert!(fast.iter().all(|row| row.iter().all(|&c| c == 0)));
        let (idx, q) = select_groups_chunked(&g, luts(3, false), Alpha::ONE, 2);
        let (ridx, rq) = reference::select_groups_rebuild(&g, 3, false, Alpha::ONE);
        assert_eq!(idx, ridx);
        assert_eq!(q, rq);
        assert!(idx.iter().all(|&i| i == 0), "ties must resolve to combo 0");
    }

    #[test]
    fn thread_count_invariance() {
        let g = gm(11, 16, 64, 4);
        let base_sel = select_groups_chunked(&g, luts(3, false), Alpha::ONE, 1);
        let base_tab = cost_table_chunked(&g, 4, false, Alpha::ONE, 1);
        for nt in [2usize, 3, 8] {
            assert_eq!(
                select_groups_chunked(&g, luts(3, false), Alpha::ONE, nt),
                base_sel,
                "selection depends on thread count {nt}"
            );
            assert_eq!(
                cost_table_chunked(&g, 4, false, Alpha::ONE, nt),
                base_tab,
                "cost table depends on thread count {nt}"
            );
        }
    }

    #[test]
    fn per_filter_cost_at_matches_reference() {
        let g = gm(13, 5, 40, 16);
        for n in [1usize, 3, 6] {
            assert_eq!(
                per_filter_cost_at(&g, n, false, Alpha::ONE),
                reference::per_filter_cost_rebuild(&g, n, false, Alpha::ONE)
            );
        }
    }
}
