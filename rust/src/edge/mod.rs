//! The network edge (L4): a dependency-free TCP serving front for the
//! coordinator, speaking the length-prefixed `SWIS1` wire protocol.
//!
//! ```text
//!  TCP clients ──SWIS1 frames──▶ EdgeServer (accept loop, std only)
//!                                  │ per-tenant token-bucket quota
//!                                  │ per-model WorkerPool routing
//!                                  │ queue-depth worker rebalancing
//!                                  ▼
//!                               coordinator (admission → pool → engine)
//! ```
//!
//! Layout:
//!
//! * [`frame`] — the wire codec: `SWIS1` magic, 10-byte header, typed
//!   request/response frames, allocation-safe bounded decode.
//! * [`status`] — the single [`SwisError`](crate::error::SwisError) ↔
//!   wire status-code mapping (exhaustive both ways, round-trip
//!   property-tested).
//! * [`quota`] — deterministic per-tenant token buckets.
//! * [`server`] — [`EdgeServer`]: accept loop, reader/writer pair per
//!   connection, [`PlanCache`]-backed pools, rebalancer.
//! * [`client`] — [`EdgeClient`]: the blocking client `loadgen
//!   --connect` and the tests use.
//!
//! The wire frame is a serialized
//! [`InferRequest`](crate::coordinator::InferRequest) — in-process and
//! networked callers build the exact same request type, so the two
//! paths cannot drift. See the "Network edge" chapter in the crate docs
//! for the byte-level frame layout and the status-code table.

pub mod client;
pub mod frame;
pub mod quota;
pub mod server;
pub mod status;

pub use client::{EdgeClient, WireResponse};
pub use frame::{Frame, FrameError, ModelInfo, MAX_FRAME};
pub use quota::{QuotaConfig, TenantQuotas};
pub use server::{allocate, EdgeConfig, EdgeServer, PlanCache, PoolTotals};
pub use status::WireStatus;
