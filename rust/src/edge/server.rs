//! The SWIS1 TCP serving edge: a std-`TcpListener` accept loop (the
//! same dependency-free style as [`crate::obs::http`]) with one
//! reader/writer thread pair per connection, feeding per-model
//! [`WorkerPool`]s through per-tenant token-bucket quotas.
//!
//! ```text
//!   TCP conn ──reader──▶ quota check ──▶ route by model id ──▶ try_submit
//!      ▲                    │  over-quota: Status(rejected)       │
//!      │                    │  unknown model: Status(invalid)     ▼
//!   writer ◀── mpsc (FIFO per conn) ◀── Ready(Status) | Pending(Ticket)
//! ```
//!
//! Design rules, each pinned by `tests/edge_serving.rs`:
//!
//! * **Refusals are frames, not hangups.** Over-quota, Busy and
//!   malformed-request refusals answer with a typed status frame on the
//!   open connection; only protocol faults (bad magic, oversized
//!   prefix, stalls, truncation) cost the client its connection.
//! * **Faults are counted, never fatal.** Every adversarial-client
//!   class bumps a [`WireFault`] counter on the edge [`Metrics`] and
//!   the server keeps serving other connections.
//! * **Pools are swappable.** Each model's pool is an
//!   `Arc<WorkerPool>` built from a shared [`PlanCache`] (warm-up from
//!   a cached plan does zero quantization), so the rebalancer can
//!   rebuild a pool at a new worker count and swap it in while
//!   in-flight tickets on the old pool still answer — the old pool
//!   drains on drop.

use std::collections::HashMap;
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::util::sync::atomic::{AtomicBool, Ordering};
use crate::util::sync::{lock_unpoisoned, Mutex};

use super::frame::{self, Frame, FrameError, ModelInfo};
use super::quota::{QuotaConfig, TenantQuotas};
use super::status::WireStatus;
use crate::api::EnginePlan;
use crate::coordinator::{
    Admission, Metrics, PoolConfig, Ticket, WireFault, WorkerPool,
};
use crate::error::{AdmissionReason, SwisError, SwisResult};
use crate::runtime::NativeFactory;

/// Accept-loop poll interval (shutdown latency bound for the listener).
const ACCEPT_POLL: Duration = Duration::from_millis(50);

/// Edge-level knobs. `Default` is tuned for production-ish patience;
/// tests shrink the stall budgets to milliseconds.
#[derive(Clone, Debug)]
pub struct EdgeConfig {
    /// Per-tenant token-bucket quota; `None` admits everything.
    pub quota: Option<QuotaConfig>,
    /// How long the writer waits on a pool ticket before answering
    /// with a timeout status.
    pub patience: Duration,
    /// Mid-frame read stall budget: a client that starts a frame and
    /// stops sending for this long is cut off (counted `stalled_read`).
    /// Also the idle-poll interval, so it bounds shutdown latency.
    pub read_stall: Duration,
    /// Socket write timeout: a client that stops reading until our
    /// write blocks this long is cut off (counted `stalled_write`).
    pub write_stall: Duration,
    /// Worker threads shared across ALL model pools; the rebalancer
    /// re-splits this budget by queue depth. Clamped to >= 1 per model.
    pub worker_budget: usize,
    /// How often the rebalancer re-splits `worker_budget`; `None`
    /// freezes the initial even split.
    pub rebalance: Option<Duration>,
}

impl Default for EdgeConfig {
    fn default() -> EdgeConfig {
        EdgeConfig {
            quota: None,
            patience: Duration::from_secs(10),
            read_stall: Duration::from_secs(2),
            write_stall: Duration::from_secs(2),
            worker_budget: 2,
            rebalance: None,
        }
    }
}

/// `.swisplan` loader that hands out one shared `Arc<EnginePlan>` per
/// distinct path — N model ids over one plan file cost one
/// quantize-free load, and their pools share prepared weights.
#[derive(Default)]
pub struct PlanCache {
    plans: Mutex<HashMap<PathBuf, Arc<EnginePlan>>>,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// Load (or reuse) the plan at `path`.
    pub fn load(&self, path: &Path) -> SwisResult<Arc<EnginePlan>> {
        let mut plans = lock_unpoisoned(&self.plans);
        if let Some(p) = plans.get(path) {
            return Ok(Arc::clone(p));
        }
        let plan = Arc::new(EnginePlan::load(path)?);
        plans.insert(path.to_path_buf(), Arc::clone(&plan));
        Ok(plan)
    }

    /// Distinct plans resident in the cache.
    pub fn len(&self) -> usize {
        lock_unpoisoned(&self.plans).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Split `budget` workers across models proportionally to their queue
/// depths (largest-remainder rounding, every model keeps >= 1 worker,
/// deterministic tie-break by index). Pure — unit-testable without a
/// single thread.
pub fn allocate(budget: usize, loads: &[usize]) -> Vec<usize> {
    let n = loads.len();
    if n == 0 {
        return Vec::new();
    }
    let budget = budget.max(n);
    // +1 so an idle model still weighs something and division is total
    let weights: Vec<u64> = loads.iter().map(|&l| l as u64 + 1).collect();
    let total: u64 = weights.iter().sum();
    let extra = (budget - n) as u64;
    let mut out = vec![1usize; n];
    let mut used = n;
    let mut fracs: Vec<(u64, usize)> = Vec::with_capacity(n);
    for (i, &w) in weights.iter().enumerate() {
        let exact = extra * w;
        out[i] += (exact / total) as usize;
        used += (exact / total) as usize;
        fracs.push((exact % total, i));
    }
    fracs.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
    for (_, i) in fracs {
        if used >= budget {
            break;
        }
        out[i] += 1;
        used += 1;
    }
    out
}

struct ModelEntry {
    plan: Arc<EnginePlan>,
    pool: Arc<WorkerPool>,
}

/// Counters accumulated from pools retired by the rebalancer, so the
/// serve-loop summary survives pool swaps.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolTotals {
    pub requests: u64,
    pub batches: u64,
    pub shed: u64,
    pub rejected: u64,
    pub degraded: u64,
    pub errors: u64,
    pub panics: u64,
}

impl PoolTotals {
    fn absorb(&mut self, s: &crate::coordinator::MetricsSnapshot) {
        self.requests += s.requests;
        self.batches += s.batches;
        self.shed += s.shed;
        self.rejected += s.rejected;
        self.degraded += s.degraded;
        self.errors += s.errors;
        self.panics += s.panics;
    }
}

struct Shared {
    // BTreeMap-like determinism matters for allocate(): keep a sorted
    // id list alongside the map.
    models: Mutex<HashMap<String, ModelEntry>>,
    model_ids: Vec<String>,
    quotas: TenantQuotas,
    /// Wire-level counters (faults, quota refusals, connections); pool
    /// counters live on each pool's own `Metrics`.
    metrics: Arc<Metrics>,
    retired: Mutex<PoolTotals>,
    cfg: EdgeConfig,
    pool_cfg: PoolConfig,
    stop: AtomicBool,
}

/// Handle to a running SWIS1 edge server.
pub struct EdgeServer {
    shared: Arc<Shared>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
    rebalancer: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl EdgeServer {
    /// Bind `addr` (port 0 picks a free port) and serve `models` —
    /// `(model id, prepared plan)` pairs, e.g. from a [`PlanCache`].
    /// `pool_cfg.workers` is ignored; the edge splits
    /// `cfg.worker_budget` across models instead.
    pub fn serve(
        addr: &str,
        models: Vec<(String, Arc<EnginePlan>)>,
        pool_cfg: PoolConfig,
        cfg: EdgeConfig,
    ) -> SwisResult<EdgeServer> {
        if models.is_empty() {
            return Err(SwisError::config("edge server needs at least one model"));
        }
        let mut model_ids: Vec<String> = models.iter().map(|(id, _)| id.clone()).collect();
        model_ids.sort();
        model_ids.dedup();
        if model_ids.len() != models.len() {
            return Err(SwisError::config("duplicate model id in edge model list"));
        }
        let shares = allocate(cfg.worker_budget, &vec![0; models.len()]);
        let mut map = HashMap::new();
        for ((id, plan), workers) in models.into_iter().zip(shares) {
            let pool = start_pool(&plan, workers, &pool_cfg)
                .map_err(|e| e.context(format!("starting pool for model '{id}'")))?;
            map.insert(id, ModelEntry { plan, pool });
        }
        let listener = TcpListener::bind(addr)
            .map_err(|e| SwisError::config(format!("edge bind {addr}: {e}")))?;
        let bound = listener
            .local_addr()
            .map_err(|e| SwisError::config(format!("edge addr: {e}")))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| SwisError::config(format!("edge nonblocking: {e}")))?;
        let shared = Arc::new(Shared {
            models: Mutex::new(map),
            model_ids,
            quotas: TenantQuotas::new(cfg.quota),
            metrics: Arc::new(Metrics::default()),
            retired: Mutex::new(PoolTotals::default()),
            cfg,
            pool_cfg,
            stop: AtomicBool::new(false),
        });
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let shared = Arc::clone(&shared);
            let conns = Arc::clone(&conns);
            std::thread::Builder::new()
                .name("swis-edge-accept".into())
                .spawn(move || accept_loop(listener, shared, conns))
                .map_err(|e| SwisError::backend(format!("spawning edge accept: {e}")))?
        };
        let rebalancer = match shared.cfg.rebalance {
            Some(every) => {
                let shared = Arc::clone(&shared);
                Some(
                    std::thread::Builder::new()
                        .name("swis-edge-rebalance".into())
                        .spawn(move || rebalance_loop(shared, every))
                        .map_err(|e| {
                            SwisError::backend(format!("spawning edge rebalancer: {e}"))
                        })?,
                )
            }
            None => None,
        };
        Ok(EdgeServer { shared, addr: bound, accept: Some(accept), rebalancer, conns })
    }

    /// The address actually bound (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Wire-level counters (faults, quota refusals, connections).
    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.shared.metrics)
    }

    /// Per-model worker counts, in sorted model-id order — the
    /// rebalancer's current split.
    pub fn worker_split(&self) -> Vec<(String, usize)> {
        let models = lock_unpoisoned(&self.shared.models);
        self.shared
            .model_ids
            .iter()
            .map(|id| (id.clone(), models[id].workers()))
            .collect()
    }

    /// Aggregate pool counters: live pools plus everything retired by
    /// the rebalancer.
    pub fn pool_totals(&self) -> PoolTotals {
        let mut t = *lock_unpoisoned(&self.shared.retired);
        let models = lock_unpoisoned(&self.shared.models);
        for e in models.values() {
            t.absorb(&e.pool.metrics.snapshot());
        }
        t
    }

    /// Tenants the quota table has seen.
    pub fn tenants_seen(&self) -> usize {
        self.shared.quotas.tenants()
    }

    /// Stop accepting, close every connection, join every thread, and
    /// shut the model pools down (draining queued jobs).
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        // Release pairs with the Acquire loads in the accept / reader /
        // rebalancer loops: whatever the stopping thread wrote before
        // the flag flip is visible to loops that observe it.
        self.shared.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.rebalancer.take() {
            let _ = h.join();
        }
        let handles: Vec<JoinHandle<()>> = std::mem::take(&mut *lock_unpoisoned(&self.conns));
        for h in handles {
            let _ = h.join();
        }
        // dropping the entries drops the pool Arcs; WorkerPool::drop
        // closes admission and joins workers, draining queued jobs
        lock_unpoisoned(&self.shared.models).clear();
    }
}

impl Drop for EdgeServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn start_pool(
    plan: &Arc<EnginePlan>,
    workers: usize,
    pool_cfg: &PoolConfig,
) -> SwisResult<Arc<WorkerPool>> {
    let cfg = PoolConfig { workers, ..*pool_cfg };
    let factory = Arc::new(NativeFactory::from_plan(Arc::clone(plan)));
    Ok(Arc::new(WorkerPool::start_with_factory(factory, cfg)?))
}

impl ModelEntry {
    fn workers(&self) -> usize {
        self.pool.workers()
    }
}

fn accept_loop(
    listener: TcpListener,
    shared: Arc<Shared>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
) {
    while !shared.stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _)) => {
                shared.metrics.record_conn_opened();
                let shared2 = Arc::clone(&shared);
                match std::thread::Builder::new()
                    .name("swis-edge-conn".into())
                    .spawn(move || conn_main(stream, shared2))
                {
                    Ok(h) => lock_unpoisoned(&conns).push(h),
                    Err(_) => shared.metrics.record_conn_closed(),
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
}

/// What the reader hands the writer, in submission order (the writer
/// preserves FIFO response order per connection).
enum Reply {
    /// Answer immediately (refusals, info).
    Ready(Frame),
    /// Wait for the pool, then answer.
    Pending { seq: u64, ticket: Ticket },
}

fn status_frame(seq: u64, e: &SwisError) -> Frame {
    Frame::Status { seq, code: WireStatus::of(e).code(), msg: e.message().to_string() }
}

fn conn_main(stream: TcpStream, shared: Arc<Shared>) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(shared.cfg.read_stall));
    let writer_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => {
            shared.metrics.record_conn_closed();
            return;
        }
    };
    let (tx, rx) = mpsc::channel::<Reply>();
    let writer = {
        let shared = Arc::clone(&shared);
        std::thread::Builder::new()
            .name("swis-edge-write".into())
            .spawn(move || writer_main(writer_stream, rx, shared))
    };
    let Ok(writer) = writer else {
        shared.metrics.record_conn_closed();
        return;
    };

    let mut reader = stream;
    loop {
        match frame::read_frame(&mut reader) {
            Ok(Frame::Infer { seq, model, req }) => {
                let reply = handle_infer(&shared, seq, &model, req);
                if tx.send(reply).is_err() {
                    break; // writer gone (stalled write shut us down)
                }
            }
            Ok(Frame::InfoRequest { seq }) => {
                let models = model_table(&shared);
                if tx.send(Reply::Ready(Frame::Info { seq, models })).is_err() {
                    break;
                }
            }
            Ok(Frame::Ok { seq, .. } | Frame::Status { seq, .. } | Frame::Info { seq, .. }) => {
                // a client sending server->client frames is malformed
                // traffic; answer typed, then drop the connection
                shared.metrics.record_wire_fault(WireFault::BadFrame);
                let e = SwisError::admission(
                    AdmissionReason::Invalid,
                    "server-to-client frame type on the request path",
                );
                let _ = tx.send(Reply::Ready(status_frame(seq, &e)));
                break;
            }
            Err(FrameError::Stalled { mid_frame: false }) => {
                // idle poll tick; also our shutdown check
                if shared.stop.load(Ordering::Acquire) {
                    break;
                }
            }
            Err(FrameError::Stalled { mid_frame: true }) => {
                shared.metrics.record_wire_fault(WireFault::StalledRead);
                break;
            }
            Err(FrameError::Closed) => break,
            Err(FrameError::Truncated) => {
                shared.metrics.record_wire_fault(WireFault::BadFrame);
                break;
            }
            Err(FrameError::BadMagic(_)) => {
                shared.metrics.record_wire_fault(WireFault::BadMagic);
                break;
            }
            Err(FrameError::Oversized(n)) => {
                shared.metrics.record_wire_fault(WireFault::Oversized);
                let e = SwisError::admission(
                    AdmissionReason::Invalid,
                    format!("frame length {n} exceeds cap {}", frame::MAX_FRAME),
                );
                // we cannot resync past an unread oversized body: answer
                // typed, then close
                let _ = tx.send(Reply::Ready(status_frame(0, &e)));
                break;
            }
            Err(FrameError::Malformed(msg)) => {
                shared.metrics.record_wire_fault(WireFault::BadFrame);
                let e = SwisError::admission(AdmissionReason::Invalid, msg);
                let _ = tx.send(Reply::Ready(status_frame(0, &e)));
                break;
            }
            Err(FrameError::Io(_)) => break,
        }
    }
    drop(tx); // writer drains queued replies, then exits
    let _ = writer.join();
    shared.metrics.record_conn_closed();
}

fn handle_infer(
    shared: &Shared,
    seq: u64,
    model: &str,
    req: crate::coordinator::InferRequest,
) -> Reply {
    if !shared.quotas.admit(&req.tenant) {
        shared.metrics.record_quota_rejected();
        let e = SwisError::admission(
            AdmissionReason::Rejected,
            format!("tenant '{}' over quota", req.tenant),
        );
        return Reply::Ready(status_frame(seq, &e));
    }
    let pool = {
        let models = lock_unpoisoned(&shared.models);
        models.get(model).map(|e| Arc::clone(&e.pool))
    };
    let Some(pool) = pool else {
        let e = SwisError::admission(
            AdmissionReason::Invalid,
            format!("unknown model '{model}' (serving: {})", shared.model_ids.join(", ")),
        );
        return Reply::Ready(status_frame(seq, &e));
    };
    match pool.try_submit(req) {
        Ok(Admission::Accepted(ticket)) => Reply::Pending { seq, ticket },
        Ok(Admission::Busy) => {
            let e = SwisError::admission(
                AdmissionReason::Busy,
                "admission queue at capacity — retry with backoff",
            );
            Reply::Ready(status_frame(seq, &e))
        }
        Err(e) => Reply::Ready(status_frame(seq, &e)),
    }
}

fn model_table(shared: &Shared) -> Vec<ModelInfo> {
    let models = lock_unpoisoned(&shared.models);
    shared
        .model_ids
        .iter()
        .filter_map(|id| models.get(id).map(|e| (id, e)))
        .map(|(id, e)| {
            let plan = &e.plan;
            ModelInfo {
                id: id.clone(),
                input: plan.input_shape(),
                variants: plan.variants().iter().map(|v| v.name.clone()).collect(),
                tiered: plan.tier_policy().is_some(),
            }
        })
        .collect()
}

fn writer_main(mut stream: TcpStream, rx: Receiver<Reply>, shared: Arc<Shared>) {
    let _ = stream.set_write_timeout(Some(shared.cfg.write_stall));
    for reply in rx {
        let frame = match reply {
            Reply::Ready(f) => f,
            Reply::Pending { seq, ticket } => match ticket.recv_timeout(shared.cfg.patience) {
                Ok(Ok(resp)) => Frame::Ok {
                    seq,
                    degraded: resp.degraded,
                    variant: resp.variant,
                    logits: resp.logits,
                },
                Ok(Err(e)) => status_frame(seq, &e),
                Err(_) => status_frame(
                    seq,
                    &SwisError::backend(format!(
                        "no response within {:?} (pool overloaded or dropped the batch)",
                        shared.cfg.patience
                    )),
                ),
            },
        };
        let bytes = frame::encode(&frame);
        if let Err(e) = stream.write_all(&bytes) {
            if e.kind() == std::io::ErrorKind::WouldBlock
                || e.kind() == std::io::ErrorKind::TimedOut
            {
                shared.metrics.record_wire_fault(WireFault::StalledWrite);
            }
            // unblock the reader whatever the write failure was; it
            // observes EOF/reset and winds the connection down
            let _ = stream.shutdown(std::net::Shutdown::Both);
            return;
        }
    }
    let _ = stream.flush();
}

fn rebalance_loop(shared: Arc<Shared>, every: Duration) {
    let tick = every.min(Duration::from_millis(100)).max(Duration::from_millis(10));
    let mut since = Duration::ZERO;
    while !shared.stop.load(Ordering::Acquire) {
        std::thread::sleep(tick);
        since += tick;
        if since < every {
            continue;
        }
        since = Duration::ZERO;
        rebalance_once(&shared);
    }
}

/// One rebalance pass: re-split the worker budget by queue depth and
/// swap rebuilt pools in. Old pools drop OUTSIDE the model lock so
/// their drain/join never blocks request routing.
fn rebalance_once(shared: &Shared) {
    let loads: Vec<usize> = {
        let models = lock_unpoisoned(&shared.models);
        shared.model_ids.iter().map(|id| models[id].pool.queue_len()).collect()
    };
    let targets = allocate(shared.cfg.worker_budget, &loads);
    let mut retired: Vec<Arc<WorkerPool>> = Vec::new();
    for (id, target) in shared.model_ids.iter().zip(&targets) {
        let plan = {
            let models = lock_unpoisoned(&shared.models);
            let e = &models[id];
            if e.workers() == *target {
                continue;
            }
            Arc::clone(&e.plan)
        };
        // warm-up outside the lock: plan-cached, so no quantization —
        // milliseconds, not seconds
        let Ok(pool) = start_pool(&plan, *target, &shared.pool_cfg) else {
            continue; // keep the old pool on any build failure
        };
        let mut models = lock_unpoisoned(&shared.models);
        if let Some(e) = models.get_mut(id) {
            let old = std::mem::replace(&mut e.pool, pool);
            lock_unpoisoned(&shared.retired).absorb(&old.metrics.snapshot());
            retired.push(old);
        }
    }
    // drains happen here, lock-free; in-flight tickets on old pools
    // still deliver (each job owns its response channel)
    drop(retired);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocate_is_proportional_and_total_preserving() {
        // even split when idle
        assert_eq!(allocate(4, &[0, 0]), vec![2, 2]);
        // everything beyond the 1-per-model floor follows load
        assert_eq!(allocate(6, &[90, 0, 10]), vec![4, 1, 1]);
        // the floor holds even when the budget is short
        assert_eq!(allocate(1, &[5, 5, 5]), vec![1, 1, 1]);
        // sums are exact for awkward splits
        for budget in 1..20 {
            for loads in [vec![0usize, 3, 9], vec![7, 7], vec![1], vec![0, 0, 0, 0, 5]] {
                let out = allocate(budget, &loads);
                assert_eq!(out.len(), loads.len());
                assert!(out.iter().all(|&w| w >= 1));
                assert_eq!(out.iter().sum::<usize>(), budget.max(loads.len()));
            }
        }
        // deterministic: same inputs, same split
        assert_eq!(allocate(7, &[3, 3, 1]), allocate(7, &[3, 3, 1]));
        assert_eq!(allocate(5, &[]), Vec::<usize>::new());
    }

    #[test]
    fn heavier_queues_win_workers() {
        let split = allocate(8, &[100, 1]);
        assert!(split[0] > split[1], "loaded model must out-rank idle one: {split:?}");
        assert_eq!(split.iter().sum::<usize>(), 8);
    }
}
