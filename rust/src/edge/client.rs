//! Minimal blocking SWIS1 client — the counterpart `loadgen` and the
//! tests drive the [`super::EdgeServer`] with. One socket, sequential
//! request/response (the server answers in FIFO order per connection),
//! sequence numbers checked on every reply.

use std::net::TcpStream;
use std::time::Duration;

use super::frame::{self, Frame, FrameError, ModelInfo};
use super::status::WireStatus;
use crate::coordinator::InferRequest;
use crate::error::{SwisError, SwisResult};

/// The answer to one inference round-trip.
#[derive(Clone, Debug)]
pub struct WireResponse {
    pub logits: Vec<f32>,
    /// The variant that actually served the request.
    pub variant: String,
    /// Pressure-degraded below the (hint-resolved) requested tier.
    pub degraded: bool,
}

/// Blocking SWIS1 connection. Not `Clone` — one in-flight exchange at a
/// time; open more connections for concurrency.
pub struct EdgeClient {
    stream: TcpStream,
    seq: u64,
}

impl EdgeClient {
    /// Connect to a serving edge, with read/write timeouts so a dead
    /// server surfaces as a typed error instead of a hang.
    pub fn connect(addr: &str, timeout: Duration) -> SwisResult<EdgeClient> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| SwisError::io(format!("edge connect {addr}: {e}")))?;
        let _ = stream.set_nodelay(true);
        stream
            .set_read_timeout(Some(timeout))
            .map_err(|e| SwisError::io(format!("edge timeout: {e}")))?;
        stream
            .set_write_timeout(Some(timeout))
            .map_err(|e| SwisError::io(format!("edge timeout: {e}")))?;
        Ok(EdgeClient { stream, seq: 0 })
    }

    /// Ask the server what it serves (model ids, input shapes,
    /// variants, tiering).
    pub fn info(&mut self) -> SwisResult<Vec<ModelInfo>> {
        self.seq += 1;
        let seq = self.seq;
        self.send(&Frame::InfoRequest { seq })?;
        match self.recv(seq)? {
            Frame::Info { models, .. } => Ok(models),
            Frame::Status { code, msg, .. } => Err(wire_error(code, msg)),
            _ => Err(SwisError::io("unexpected frame type answering info")),
        }
    }

    /// One inference round-trip. Server-side refusals (over quota,
    /// Busy, shed, unknown variant/model) come back as the
    /// [`SwisError`] the status code decodes to — the same taxonomy an
    /// in-process `try_submit` caller sees.
    pub fn infer(&mut self, model: &str, req: InferRequest) -> SwisResult<WireResponse> {
        self.seq += 1;
        let seq = self.seq;
        self.send(&Frame::Infer { seq, model: model.to_string(), req })?;
        match self.recv(seq)? {
            Frame::Ok { degraded, variant, logits, .. } => {
                Ok(WireResponse { logits, variant, degraded })
            }
            Frame::Status { code, msg, .. } => Err(wire_error(code, msg)),
            _ => Err(SwisError::io("unexpected frame type answering infer")),
        }
    }

    /// Send raw bytes on the socket — adversarial-client test hook.
    pub fn send_raw(&mut self, bytes: &[u8]) -> SwisResult<()> {
        use std::io::Write;
        self.stream
            .write_all(bytes)
            .map_err(|e| SwisError::io(format!("edge write: {e}")))
    }

    fn send(&mut self, f: &Frame) -> SwisResult<()> {
        self.send_raw(&frame::encode(f))
    }

    fn recv(&mut self, want_seq: u64) -> SwisResult<Frame> {
        let f = match frame::read_frame(&mut self.stream) {
            Ok(f) => f,
            Err(FrameError::Closed) => {
                return Err(SwisError::io("server closed the connection"))
            }
            Err(e) => return Err(SwisError::io(format!("edge read: {e}"))),
        };
        let seq = match &f {
            Frame::Infer { seq, .. }
            | Frame::Ok { seq, .. }
            | Frame::Status { seq, .. }
            | Frame::InfoRequest { seq }
            | Frame::Info { seq, .. } => *seq,
        };
        // seq 0 marks server-initiated faults (oversized/malformed)
        // that could not echo a request sequence
        if seq != want_seq && seq != 0 {
            return Err(SwisError::io(format!(
                "response sequence {seq} does not match request {want_seq}"
            )));
        }
        Ok(f)
    }
}

/// Decode a wire status into the error the server mapped it from.
fn wire_error(code: u16, msg: String) -> SwisError {
    match WireStatus::from_code(code) {
        Some(s) => s
            .into_error(msg)
            .unwrap_or_else(|| SwisError::io("status frame carried code 0 (ok)")),
        None => SwisError::io(format!("unknown wire status code {code}: {msg}")),
    }
}
