//! The single [`SwisError`] ↔ wire status-code mapping. Every status a
//! SWIS1 response frame can carry is minted HERE and nowhere else, with
//! an exhaustive `match` (no `_` arm) in both directions — adding a
//! `SwisError` variant or an [`AdmissionReason`] is a compile error in
//! this module until the new class gets a documented, stable code.
//!
//! Code blocks are stable and append-only (wire compatibility):
//!
//! | code | status | meaning |
//! |------|--------|---------|
//! | 0    | `ok`                 | logits follow in an OK frame |
//! | 10   | `config`             | invalid configuration |
//! | 11   | `plan`               | plan build / container failure |
//! | 12   | `io`                 | filesystem IO failure |
//! | 13   | `backend`            | backend construction/execution failure |
//! | 14   | `eval`               | accuracy/compression sweep failure |
//! | 20   | `admission_busy`     | backpressure: queue at capacity — retry with backoff |
//! | 21   | `admission_shed`     | deadline shed: queue residency exceeded the budget |
//! | 22   | `admission_closed`   | pool shut down / no live workers |
//! | 23   | `admission_invalid`  | malformed request (wrong image size, unknown model) |
//! | 24   | `admission_rejected` | tenant over its token-bucket quota — slow down |

use crate::error::{AdmissionReason, SwisError};

/// One wire status code. `Ok` (0) accompanies logits; every other
/// status maps 1:1 onto a [`SwisError`] class (and, for admission, its
/// typed reason), so a client can reconstruct the same typed error the
/// in-process caller would have seen.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum WireStatus {
    Ok,
    Config,
    Plan,
    Io,
    Backend,
    Eval,
    AdmissionBusy,
    AdmissionShed,
    AdmissionClosed,
    AdmissionInvalid,
    AdmissionRejected,
}

/// Every wire status, in code order — the property test round-trips
/// this list, so a status added without joining it fails the test.
pub const ALL_STATUSES: [WireStatus; 11] = [
    WireStatus::Ok,
    WireStatus::Config,
    WireStatus::Plan,
    WireStatus::Io,
    WireStatus::Backend,
    WireStatus::Eval,
    WireStatus::AdmissionBusy,
    WireStatus::AdmissionShed,
    WireStatus::AdmissionClosed,
    WireStatus::AdmissionInvalid,
    WireStatus::AdmissionRejected,
];

impl WireStatus {
    /// The stable u16 carried in status response frames.
    pub fn code(self) -> u16 {
        match self {
            WireStatus::Ok => 0,
            WireStatus::Config => 10,
            WireStatus::Plan => 11,
            WireStatus::Io => 12,
            WireStatus::Backend => 13,
            WireStatus::Eval => 14,
            WireStatus::AdmissionBusy => 20,
            WireStatus::AdmissionShed => 21,
            WireStatus::AdmissionClosed => 22,
            WireStatus::AdmissionInvalid => 23,
            WireStatus::AdmissionRejected => 24,
        }
    }

    /// Decode a wire code; `None` for codes this build does not know
    /// (newer peer) — callers surface those as a `Backend` error with
    /// the raw code in the message rather than guessing a class.
    pub fn from_code(code: u16) -> Option<WireStatus> {
        ALL_STATUSES.into_iter().find(|s| s.code() == code)
    }

    /// Short label, used in logs and the README status table.
    pub fn as_str(self) -> &'static str {
        match self {
            WireStatus::Ok => "ok",
            WireStatus::Config => "config",
            WireStatus::Plan => "plan",
            WireStatus::Io => "io",
            WireStatus::Backend => "backend",
            WireStatus::Eval => "eval",
            WireStatus::AdmissionBusy => "admission_busy",
            WireStatus::AdmissionShed => "admission_shed",
            WireStatus::AdmissionClosed => "admission_closed",
            WireStatus::AdmissionInvalid => "admission_invalid",
            WireStatus::AdmissionRejected => "admission_rejected",
        }
    }

    /// Classify a [`SwisError`] for the wire. Exhaustive on BOTH the
    /// error enum and the admission reason — no `_` arm, by design:
    /// extending either type forces a decision here.
    pub fn of(e: &SwisError) -> WireStatus {
        match e {
            SwisError::Config(_) => WireStatus::Config,
            SwisError::Plan(_) => WireStatus::Plan,
            SwisError::Io(_) => WireStatus::Io,
            SwisError::Backend(_) => WireStatus::Backend,
            SwisError::Eval(_) => WireStatus::Eval,
            SwisError::Admission { reason, msg: _ } => match reason {
                AdmissionReason::Busy => WireStatus::AdmissionBusy,
                AdmissionReason::Shed => WireStatus::AdmissionShed,
                AdmissionReason::Closed => WireStatus::AdmissionClosed,
                AdmissionReason::Invalid => WireStatus::AdmissionInvalid,
                AdmissionReason::Rejected => WireStatus::AdmissionRejected,
            },
        }
    }

    /// Reconstruct the typed error a status frame stands for (`None`
    /// for `Ok`, which carries logits instead). The inverse of
    /// [`WireStatus::of`]: `of(&into_error(s, m).unwrap()) == s` for
    /// every non-Ok status — pinned by the round-trip test.
    pub fn into_error(self, msg: &str) -> Option<SwisError> {
        match self {
            WireStatus::Ok => None,
            WireStatus::Config => Some(SwisError::config(msg)),
            WireStatus::Plan => Some(SwisError::plan(msg)),
            WireStatus::Io => Some(SwisError::io(msg)),
            WireStatus::Backend => Some(SwisError::backend(msg)),
            WireStatus::Eval => Some(SwisError::eval(msg)),
            WireStatus::AdmissionBusy => {
                Some(SwisError::admission(AdmissionReason::Busy, msg))
            }
            WireStatus::AdmissionShed => {
                Some(SwisError::admission(AdmissionReason::Shed, msg))
            }
            WireStatus::AdmissionClosed => {
                Some(SwisError::admission(AdmissionReason::Closed, msg))
            }
            WireStatus::AdmissionInvalid => {
                Some(SwisError::admission(AdmissionReason::Invalid, msg))
            }
            WireStatus::AdmissionRejected => {
                Some(SwisError::admission(AdmissionReason::Rejected, msg))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Property: every status round-trips through its wire code, and
    /// every non-Ok status round-trips through the typed error and
    /// back — so the table cannot drift in either direction.
    #[test]
    fn every_status_round_trips() {
        let mut seen = std::collections::HashSet::new();
        for s in ALL_STATUSES {
            assert!(seen.insert(s.code()), "duplicate wire code {}", s.code());
            assert_eq!(WireStatus::from_code(s.code()), Some(s));
            match s.into_error("ctx") {
                None => assert_eq!(s, WireStatus::Ok),
                Some(e) => {
                    assert_eq!(WireStatus::of(&e), s, "of/into_error disagree for {s:?}");
                    assert_eq!(e.message(), "ctx");
                }
            }
        }
        assert_eq!(WireStatus::from_code(9999), None);
    }

    /// Every SwisError constructor lands on a distinct admission-aware
    /// status (the forward direction of the exhaustive match).
    #[test]
    fn error_classes_map_to_documented_codes() {
        assert_eq!(WireStatus::of(&SwisError::config("x")).code(), 10);
        assert_eq!(WireStatus::of(&SwisError::plan("x")).code(), 11);
        assert_eq!(WireStatus::of(&SwisError::io("x")).code(), 12);
        assert_eq!(WireStatus::of(&SwisError::backend("x")).code(), 13);
        assert_eq!(WireStatus::of(&SwisError::eval("x")).code(), 14);
        for (reason, code) in [
            (AdmissionReason::Busy, 20),
            (AdmissionReason::Shed, 21),
            (AdmissionReason::Closed, 22),
            (AdmissionReason::Invalid, 23),
            (AdmissionReason::Rejected, 24),
        ] {
            assert_eq!(WireStatus::of(&SwisError::admission(reason, "x")).code(), code);
        }
    }
}
