//! Per-tenant token-bucket admission quotas for the network edge.
//!
//! Each tenant id (a free-form string from the infer frame; "" is the
//! anonymous tenant) owns one bucket refilled at `rate` tokens/s up to
//! `burst`. A request costs one token; an empty bucket is a typed
//! `Admission { reason: Rejected }` refusal on the wire — the
//! connection stays open and later requests are admitted again once
//! the bucket refills. No configured quota means every request is
//! admitted (the in-process default).

use std::collections::HashMap;
use std::time::Instant;

use crate::util::sync::{lock_unpoisoned, Mutex};

/// Token-bucket parameters applied to EVERY tenant individually.
#[derive(Clone, Copy, Debug)]
pub struct QuotaConfig {
    /// Sustained refill rate, tokens (= requests) per second.
    pub rate: f64,
    /// Bucket capacity: how much short-term burst a tenant may spend
    /// above the sustained rate. Also the initial fill.
    pub burst: f64,
}

struct Bucket {
    tokens: f64,
    last: Instant,
}

/// The edge's tenant → bucket table. Buckets are created on first
/// sight of a tenant id, pre-filled to `burst`.
pub struct TenantQuotas {
    cfg: Option<QuotaConfig>,
    buckets: Mutex<HashMap<String, Bucket>>,
}

impl TenantQuotas {
    pub fn new(cfg: Option<QuotaConfig>) -> TenantQuotas {
        TenantQuotas { cfg, buckets: Mutex::new(HashMap::new()) }
    }

    /// Whether quotas are configured at all.
    pub fn enabled(&self) -> bool {
        self.cfg.is_some()
    }

    /// Spend one token for `tenant` now. `true` = admitted.
    pub fn admit(&self, tenant: &str) -> bool {
        self.admit_at(tenant, Instant::now())
    }

    /// [`TenantQuotas::admit`] with an explicit clock, so refill
    /// arithmetic is deterministic under test.
    pub fn admit_at(&self, tenant: &str, now: Instant) -> bool {
        let Some(cfg) = self.cfg else { return true };
        let mut buckets = lock_unpoisoned(&self.buckets);
        let b = buckets
            .entry(tenant.to_string())
            .or_insert_with(|| Bucket { tokens: cfg.burst, last: now });
        let dt = now.saturating_duration_since(b.last).as_secs_f64();
        b.tokens = (b.tokens + dt * cfg.rate).min(cfg.burst);
        b.last = now;
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            true
        } else {
            false
        }
    }

    /// Tenants seen so far (for the serve-loop summary line).
    pub fn tenants(&self) -> usize {
        lock_unpoisoned(&self.buckets).len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn unconfigured_quota_admits_everything() {
        let q = TenantQuotas::new(None);
        assert!(!q.enabled());
        for _ in 0..10_000 {
            assert!(q.admit("anyone"));
        }
    }

    #[test]
    fn burst_then_refill_per_tenant() {
        let q = TenantQuotas::new(Some(QuotaConfig { rate: 10.0, burst: 3.0 }));
        let t0 = Instant::now();
        // the burst allowance spends down...
        assert!(q.admit_at("a", t0));
        assert!(q.admit_at("a", t0));
        assert!(q.admit_at("a", t0));
        assert!(!q.admit_at("a", t0), "4th instant request must be rejected");
        // ...tenants are isolated...
        assert!(q.admit_at("b", t0), "tenant b has its own bucket");
        // ...and the bucket refills at `rate`: 100 ms at 10/s = 1 token
        assert!(q.admit_at("a", t0 + Duration::from_millis(100)));
        assert!(!q.admit_at("a", t0 + Duration::from_millis(101)));
        assert_eq!(q.tenants(), 2);
    }

    #[test]
    fn refill_never_exceeds_burst() {
        let q = TenantQuotas::new(Some(QuotaConfig { rate: 1000.0, burst: 2.0 }));
        let t0 = Instant::now();
        assert!(q.admit_at("a", t0));
        // a long idle gap refills to burst, not beyond
        let later = t0 + Duration::from_secs(3600);
        assert!(q.admit_at("a", later));
        assert!(q.admit_at("a", later));
        assert!(!q.admit_at("a", later), "cap is `burst`, not rate * idle");
    }
}
