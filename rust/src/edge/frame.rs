//! The SWIS1 length-prefixed binary wire format — the network face of
//! the serving stack, deliberately dependency-free (no serde; explicit
//! little-endian codec, mirroring the `.swisplan` container's style).
//!
//! Every frame starts with a 10-byte header:
//!
//! ```text
//!   offset  size  field
//!   0       5     magic "SWIS1" (protocol version rides in the magic)
//!   5       1     frame type (FT_* constants)
//!   6       4     body length, u32 LE (<= MAX_FRAME, checked BEFORE
//!                 any allocation — an adversarial length prefix cannot
//!                 balloon server memory)
//!   10      len   body
//! ```
//!
//! Body layouts (all integers LE, strings are length-prefixed UTF-8):
//!
//! ```text
//!   infer request (FT_INFER):
//!     seq u64 | tenant str8 | model str8 | variant str8
//!     | tier u8 | lane u8 (0 interactive, 1 batch)
//!     | flags u8 (bit0 = trace) | deadline_us u64 (0 = none)
//!     | n_vals u32 | image f32 x n_vals
//!   ok response (FT_OK):
//!     seq u64 | flags u8 (bit0 = degraded) | served variant str8
//!     | n u32 | logits f32 x n
//!   status response (FT_STATUS):
//!     seq u64 | code u16 (see edge::status) | msg str16
//!   info request (FT_INFO_REQ):   seq u64
//!   info response (FT_INFO):
//!     seq u64 | n_models u8 | per model:
//!       id str8 | h u16 | w u16 | c u16 | tiered u8
//!       | n_variants u8 | variant str8 x n_variants
//! ```
//!
//! The infer frame is just a serialized
//! [`InferRequest`](crate::coordinator::InferRequest) plus a routing
//! model id and a client sequence number — the wire and in-process
//! submission surfaces share one type, so they cannot drift.

use std::io::Read;
use std::time::Duration;

use crate::coordinator::{InferRequest, Priority};

/// Frame magic; the trailing `1` is the protocol version.
pub const MAGIC: [u8; 5] = *b"SWIS1";

/// Hard cap on a frame body. Checked against the length prefix before
/// any buffer is allocated; larger prefixes are a protocol fault.
pub const MAX_FRAME: u32 = 16 * 1024 * 1024;

pub const FT_INFER: u8 = 1;
pub const FT_OK: u8 = 2;
pub const FT_STATUS: u8 = 3;
pub const FT_INFO_REQ: u8 = 4;
pub const FT_INFO: u8 = 5;

/// One served model, as advertised in the info response — enough for a
/// client (`swis loadgen --connect`) to self-configure image sizes and
/// variant names without out-of-band coordination.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelInfo {
    pub id: String,
    /// Input shape `[h, w, c]`.
    pub input: [usize; 3],
    pub variants: Vec<String>,
    /// Whether the model's plan carries a degrade ladder.
    pub tiered: bool,
}

/// A decoded SWIS1 frame.
#[derive(Clone, Debug)]
pub enum Frame {
    /// Client → server: run `req` on `model`.
    Infer { seq: u64, model: String, req: InferRequest },
    /// Server → client: logits, possibly served below the requested
    /// precision tier.
    Ok { seq: u64, degraded: bool, variant: String, logits: Vec<f32> },
    /// Server → client: a typed refusal/failure (`code` is an
    /// [`edge::status::WireStatus`](super::status::WireStatus) code).
    Status { seq: u64, code: u16, msg: String },
    /// Client → server: describe your models.
    InfoRequest { seq: u64 },
    /// Server → client: the model table.
    Info { seq: u64, models: Vec<ModelInfo> },
}

/// Why a frame could not be read — the server maps each case onto its
/// own wire-fault counter, so adversarial-client tests can assert the
/// exact failure class.
#[derive(Debug)]
pub enum FrameError {
    /// Clean EOF on a frame boundary (normal client close).
    Closed,
    /// EOF mid-frame — a partial frame then disconnect.
    Truncated,
    /// A read timed out. `mid_frame` distinguishes an idle connection
    /// (poll again) from a client that stalled while sending a frame
    /// (protocol fault).
    Stalled { mid_frame: bool },
    /// The 5 bytes where the magic should be.
    BadMagic([u8; 5]),
    /// Length prefix above [`MAX_FRAME`]; refused before allocation.
    Oversized(u32),
    /// Structurally invalid body (bad type tag, short fields, non-UTF8
    /// strings, inconsistent counts).
    Malformed(String),
    /// Any other socket error.
    Io(String),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Closed => write!(f, "connection closed"),
            FrameError::Truncated => write!(f, "partial frame then disconnect"),
            FrameError::Stalled { mid_frame } => {
                write!(f, "read stalled (mid_frame={mid_frame})")
            }
            FrameError::BadMagic(m) => write!(f, "bad magic {m:?}"),
            FrameError::Oversized(n) => {
                write!(f, "length prefix {n} exceeds MAX_FRAME {MAX_FRAME}")
            }
            FrameError::Malformed(m) => write!(f, "malformed frame: {m}"),
            FrameError::Io(m) => write!(f, "socket error: {m}"),
        }
    }
}

// ---------------------------------------------------------------- encode

fn put_str8(out: &mut Vec<u8>, s: &str) {
    let b = s.as_bytes();
    let n = b.len().min(255);
    out.push(n as u8);
    out.extend_from_slice(&b[..n]);
}

fn put_str16(out: &mut Vec<u8>, s: &str) {
    let b = s.as_bytes();
    let n = b.len().min(u16::MAX as usize);
    out.extend_from_slice(&(n as u16).to_le_bytes());
    out.extend_from_slice(&b[..n]);
}

/// Serialize a frame (header + body) into one write-ready buffer.
pub fn encode(frame: &Frame) -> Vec<u8> {
    let (ftype, body) = encode_body(frame);
    let mut out = Vec::with_capacity(10 + body.len());
    out.extend_from_slice(&MAGIC);
    out.push(ftype);
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.extend_from_slice(&body);
    out
}

fn encode_body(frame: &Frame) -> (u8, Vec<u8>) {
    match frame {
        Frame::Infer { seq, model, req } => {
            let mut b = Vec::with_capacity(64 + req.image.len() * 4);
            b.extend_from_slice(&seq.to_le_bytes());
            put_str8(&mut b, &req.tenant);
            put_str8(&mut b, model);
            put_str8(&mut b, &req.variant);
            b.push(req.tier_hint.min(255) as u8);
            b.push(match req.priority {
                Priority::Interactive => 0,
                Priority::Batch => 1,
            });
            b.push(u8::from(req.trace));
            let deadline_us = req.deadline.map_or(0u64, |d| d.as_micros() as u64);
            b.extend_from_slice(&deadline_us.to_le_bytes());
            b.extend_from_slice(&(req.image.len() as u32).to_le_bytes());
            for v in &req.image {
                b.extend_from_slice(&v.to_le_bytes());
            }
            (FT_INFER, b)
        }
        Frame::Ok { seq, degraded, variant, logits } => {
            let mut b = Vec::with_capacity(32 + logits.len() * 4);
            b.extend_from_slice(&seq.to_le_bytes());
            b.push(u8::from(*degraded));
            put_str8(&mut b, variant);
            b.extend_from_slice(&(logits.len() as u32).to_le_bytes());
            for v in logits {
                b.extend_from_slice(&v.to_le_bytes());
            }
            (FT_OK, b)
        }
        Frame::Status { seq, code, msg } => {
            let mut b = Vec::with_capacity(16 + msg.len());
            b.extend_from_slice(&seq.to_le_bytes());
            b.extend_from_slice(&code.to_le_bytes());
            put_str16(&mut b, msg);
            (FT_STATUS, b)
        }
        Frame::InfoRequest { seq } => (FT_INFO_REQ, seq.to_le_bytes().to_vec()),
        Frame::Info { seq, models } => {
            let mut b = Vec::with_capacity(64);
            b.extend_from_slice(&seq.to_le_bytes());
            b.push(models.len().min(255) as u8);
            for m in models.iter().take(255) {
                put_str8(&mut b, &m.id);
                for d in m.input {
                    b.extend_from_slice(&(d.min(u16::MAX as usize) as u16).to_le_bytes());
                }
                b.push(u8::from(m.tiered));
                b.push(m.variants.len().min(255) as u8);
                for v in m.variants.iter().take(255) {
                    put_str8(&mut b, v);
                }
            }
            (FT_INFO, b)
        }
    }
}

// ---------------------------------------------------------------- decode

/// Cursor over a fully-read frame body.
struct Cur<'b> {
    b: &'b [u8],
    at: usize,
}

impl<'b> Cur<'b> {
    fn take(&mut self, n: usize) -> Result<&'b [u8], FrameError> {
        if self.at + n > self.b.len() {
            return Err(FrameError::Malformed(format!(
                "body short: wanted {n} bytes at offset {}, have {}",
                self.at,
                self.b.len()
            )));
        }
        let s = &self.b[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, FrameError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, FrameError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, FrameError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, FrameError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str8(&mut self) -> Result<String, FrameError> {
        let n = self.u8()? as usize;
        let raw = self.take(n)?;
        String::from_utf8(raw.to_vec())
            .map_err(|_| FrameError::Malformed("non-UTF8 string field".into()))
    }

    fn str16(&mut self) -> Result<String, FrameError> {
        let n = self.u16()? as usize;
        let raw = self.take(n)?;
        String::from_utf8(raw.to_vec())
            .map_err(|_| FrameError::Malformed("non-UTF8 string field".into()))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>, FrameError> {
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn done(&self) -> Result<(), FrameError> {
        if self.at != self.b.len() {
            return Err(FrameError::Malformed(format!(
                "{} trailing bytes after body",
                self.b.len() - self.at
            )));
        }
        Ok(())
    }
}

/// Decode a frame body that was already read off the socket.
pub fn decode_body(ftype: u8, body: &[u8]) -> Result<Frame, FrameError> {
    let mut c = Cur { b: body, at: 0 };
    let frame = match ftype {
        FT_INFER => {
            let seq = c.u64()?;
            let tenant = c.str8()?;
            let model = c.str8()?;
            let variant = c.str8()?;
            let tier = c.u8()? as usize;
            let lane = c.u8()?;
            let flags = c.u8()?;
            let deadline_us = c.u64()?;
            let n = c.u32()? as usize;
            let image = c.f32s(n)?;
            let pri = match lane {
                0 => Priority::Interactive,
                1 => Priority::Batch,
                other => {
                    return Err(FrameError::Malformed(format!("unknown lane {other}")));
                }
            };
            let mut req = InferRequest::new(variant)
                .image(image)
                .priority(pri)
                .tier_hint(tier)
                .trace(flags & 1 != 0)
                .tenant(tenant);
            if deadline_us > 0 {
                req = req.deadline(Duration::from_micros(deadline_us));
            }
            Frame::Infer { seq, model, req }
        }
        FT_OK => {
            let seq = c.u64()?;
            let degraded = c.u8()? & 1 != 0;
            let variant = c.str8()?;
            let n = c.u32()? as usize;
            let logits = c.f32s(n)?;
            Frame::Ok { seq, degraded, variant, logits }
        }
        FT_STATUS => {
            let seq = c.u64()?;
            let code = c.u16()?;
            let msg = c.str16()?;
            Frame::Status { seq, code, msg }
        }
        FT_INFO_REQ => Frame::InfoRequest { seq: c.u64()? },
        FT_INFO => {
            let seq = c.u64()?;
            let n_models = c.u8()? as usize;
            let mut models = Vec::with_capacity(n_models);
            for _ in 0..n_models {
                let id = c.str8()?;
                let h = c.u16()? as usize;
                let w = c.u16()? as usize;
                let ch = c.u16()? as usize;
                let tiered = c.u8()? != 0;
                let n_variants = c.u8()? as usize;
                let mut variants = Vec::with_capacity(n_variants);
                for _ in 0..n_variants {
                    variants.push(c.str8()?);
                }
                models.push(ModelInfo { id, input: [h, w, ch], variants, tiered });
            }
            Frame::Info { seq, models }
        }
        other => return Err(FrameError::Malformed(format!("unknown frame type {other}"))),
    };
    c.done()?;
    Ok(frame)
}

/// Fill `buf` from `r`, classifying the interruption. `*consumed`
/// tracks bytes of the CURRENT frame already read, so a timeout on a
/// frame boundary reads as idle while the same timeout mid-frame reads
/// as a stalled sender.
fn fill(r: &mut impl Read, buf: &mut [u8], consumed: &mut usize) -> Result<(), FrameError> {
    let mut at = 0;
    while at < buf.len() {
        match r.read(&mut buf[at..]) {
            Ok(0) => {
                return Err(if *consumed == 0 && at == 0 {
                    FrameError::Closed
                } else {
                    FrameError::Truncated
                });
            }
            Ok(n) => {
                at += n;
                *consumed += n;
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                return Err(FrameError::Stalled { mid_frame: *consumed > 0 || at > 0 });
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(FrameError::Io(e.to_string())),
        }
    }
    Ok(())
}

/// Read one frame. The oversize check runs on the raw length prefix —
/// before any body buffer exists — so a hostile 4 GiB prefix costs the
/// server 10 bytes of header read, not an allocation.
pub fn read_frame(r: &mut impl Read) -> Result<Frame, FrameError> {
    let mut consumed = 0usize;
    let mut head = [0u8; 10];
    fill(r, &mut head, &mut consumed)?;
    let magic: [u8; 5] = head[..5].try_into().unwrap();
    if magic != MAGIC {
        return Err(FrameError::BadMagic(magic));
    }
    let ftype = head[5];
    let len = u32::from_le_bytes(head[6..10].try_into().unwrap());
    if len > MAX_FRAME {
        return Err(FrameError::Oversized(len));
    }
    let mut body = vec![0u8; len as usize];
    fill(r, &mut body, &mut consumed)?;
    decode_body(ftype, &body)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(f: &Frame) -> Frame {
        let bytes = encode(f);
        read_frame(&mut &bytes[..]).unwrap()
    }

    #[test]
    fn infer_frame_round_trips_the_full_request() {
        let req = InferRequest::new("swis@3")
            .image(vec![0.25, -1.5, 3.25])
            .priority(Priority::Batch)
            .deadline(Duration::from_millis(20))
            .tier_hint(2)
            .trace(true)
            .tenant("acme");
        let f = Frame::Infer { seq: 42, model: "tinycnn".into(), req };
        match round_trip(&f) {
            Frame::Infer { seq, model, req } => {
                assert_eq!(seq, 42);
                assert_eq!(model, "tinycnn");
                assert_eq!(req.variant, "swis@3");
                assert_eq!(req.image, vec![0.25, -1.5, 3.25]);
                assert_eq!(req.priority, Priority::Batch);
                assert_eq!(req.deadline, Some(Duration::from_millis(20)));
                assert_eq!(req.tier_hint, 2);
                assert!(req.trace);
                assert_eq!(req.tenant, "acme");
            }
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn response_and_info_frames_round_trip() {
        match round_trip(&Frame::Ok {
            seq: 7,
            degraded: true,
            variant: "swis@2".into(),
            logits: vec![1.0, 2.0],
        }) {
            Frame::Ok { seq, degraded, variant, logits } => {
                assert_eq!((seq, degraded, variant.as_str()), (7, true, "swis@2"));
                assert_eq!(logits, vec![1.0, 2.0]);
            }
            other => panic!("wrong frame: {other:?}"),
        }
        match round_trip(&Frame::Status { seq: 9, code: 24, msg: "over quota".into() }) {
            Frame::Status { seq, code, msg } => {
                assert_eq!((seq, code, msg.as_str()), (9, 24, "over quota"));
            }
            other => panic!("wrong frame: {other:?}"),
        }
        let models = vec![ModelInfo {
            id: "tinycnn".into(),
            input: [32, 32, 3],
            variants: vec!["fp32".into(), "swis@3".into()],
            tiered: true,
        }];
        match round_trip(&Frame::Info { seq: 1, models: models.clone() }) {
            Frame::Info { seq, models: got } => {
                assert_eq!(seq, 1);
                assert_eq!(got, models);
            }
            other => panic!("wrong frame: {other:?}"),
        }
        assert!(matches!(round_trip(&Frame::InfoRequest { seq: 3 }), Frame::InfoRequest {
            seq: 3
        }));
    }

    #[test]
    fn adversarial_bytes_are_typed_faults() {
        // garbage magic
        let mut bytes = encode(&Frame::InfoRequest { seq: 1 });
        bytes[0] = b'X';
        assert!(matches!(read_frame(&mut &bytes[..]), Err(FrameError::BadMagic(_))));
        // oversized length prefix: refused straight off the header
        let mut huge = Vec::new();
        huge.extend_from_slice(&MAGIC);
        huge.push(FT_INFER);
        huge.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(read_frame(&mut &huge[..]), Err(FrameError::Oversized(_))));
        // partial frame then disconnect
        let good = encode(&Frame::InfoRequest { seq: 1 });
        assert!(matches!(
            read_frame(&mut &good[..good.len() - 3]),
            Err(FrameError::Truncated)
        ));
        // clean EOF on a boundary
        assert!(matches!(read_frame(&mut &[][..]), Err(FrameError::Closed)));
        // inconsistent counts inside the body
        let mut lying = encode(&Frame::Ok {
            seq: 1,
            degraded: false,
            variant: "v".into(),
            logits: vec![1.0],
        });
        // body claims 2 logits but carries 1 (n field sits after seq(8)+flag(1)+str8("v")=2)
        let n_off = 10 + 8 + 1 + 2;
        lying[n_off] = 2;
        assert!(matches!(
            decode_body(FT_OK, &lying[10..]),
            Err(FrameError::Malformed(_))
        ));
        // unknown frame type
        assert!(matches!(
            decode_body(99, &1u64.to_le_bytes()),
            Err(FrameError::Malformed(_))
        ));
    }
}
