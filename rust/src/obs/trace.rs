//! Request tracing: a [`TraceId`] minted at admission rides the job
//! through queue -> batch assembly -> worker -> backend, stamping span
//! timestamps into a [`RequestTrace`]. Completed (and shed) traces land
//! in bounded per-worker [`TraceRing`]s the pool drains, and ride the
//! response so loadgen can attribute tail latency to queue wait vs.
//! batch assembly vs. compute.
//!
//! All span timestamps are microseconds since the trace's own birth
//! instant, pushed in event order from one owner at a time — monotone by
//! construction, so `queue_us + batch_us + compute_us <= total_us`
//! always holds.

use std::collections::VecDeque;
use std::time::Instant;

use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::{lock_unpoisoned, Mutex};

/// Process-unique request trace identifier.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TraceId(pub u64);

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

impl TraceId {
    /// Mint the next id (also the sampling counter: `--trace-sample N`
    /// traces every Nth minted id).
    pub fn mint() -> TraceId {
        TraceId(NEXT_ID.fetch_add(1, Ordering::Relaxed))
    }
}

/// One lifecycle event inside a request trace.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// Admitted into the queue (always the first span, at 0 us).
    Enqueue,
    /// Admission rewrote the variant down the precision ladder.
    Degrade,
    /// Dropped by the deadline sweep (terminal).
    Shed,
    /// Popped off the queue into a worker's pending batch.
    BatchOpen,
    /// The batch was sealed for dispatch.
    BatchClose,
    /// Backend inference started for this request's chunk.
    InferStart,
    /// Backend inference finished.
    InferEnd,
    /// Response delivered (terminal).
    Done,
    /// Routed error delivered (terminal).
    Error,
}

impl SpanKind {
    pub fn as_str(self) -> &'static str {
        match self {
            SpanKind::Enqueue => "enqueue",
            SpanKind::Degrade => "degrade",
            SpanKind::Shed => "shed",
            SpanKind::BatchOpen => "batch_open",
            SpanKind::BatchClose => "batch_close",
            SpanKind::InferStart => "infer_start",
            SpanKind::InferEnd => "infer_end",
            SpanKind::Done => "done",
            SpanKind::Error => "error",
        }
    }
}

/// One timestamped event: microseconds since the trace's birth.
#[derive(Clone, Copy, Debug)]
pub struct Span {
    pub kind: SpanKind,
    pub at_us: u64,
}

/// The span record of one admitted request.
#[derive(Clone, Debug)]
pub struct RequestTrace {
    pub id: TraceId,
    /// Variant as requested at admission.
    pub variant: String,
    /// Variant that actually ran (differs when admission degraded).
    pub served_variant: String,
    pub spans: Vec<Span>,
    birth: Instant,
}

impl RequestTrace {
    /// Open a trace at admission time (pushes the `Enqueue` span at 0).
    pub fn begin(id: TraceId, variant: &str) -> RequestTrace {
        let mut t = RequestTrace {
            id,
            variant: variant.to_string(),
            served_variant: variant.to_string(),
            spans: Vec::with_capacity(8),
            birth: Instant::now(),
        };
        t.push(SpanKind::Enqueue);
        t
    }

    /// Stamp one event now.
    pub fn push(&mut self, kind: SpanKind) {
        let at_us = self.birth.elapsed().as_micros() as u64;
        self.spans.push(Span { kind, at_us });
    }

    /// Record the degrade rewrite (`from` is already in `variant`).
    pub fn degraded_to(&mut self, served: &str) {
        self.served_variant = served.to_string();
        self.push(SpanKind::Degrade);
    }

    /// Timestamp of the first span of `kind`, if recorded.
    pub fn at(&self, kind: SpanKind) -> Option<u64> {
        self.spans.iter().find(|s| s.kind == kind).map(|s| s.at_us)
    }

    fn terminal(&self) -> Option<u64> {
        self.spans
            .iter()
            .rev()
            .find(|s| matches!(s.kind, SpanKind::Done | SpanKind::Error | SpanKind::Shed))
            .map(|s| s.at_us)
    }

    /// Time spent in the admission queue (enqueue -> batch open; for
    /// shed requests, enqueue -> shed).
    pub fn queue_us(&self) -> u64 {
        self.at(SpanKind::BatchOpen)
            .or_else(|| self.at(SpanKind::Shed))
            .unwrap_or(0)
    }

    /// Batch-assembly wait (batch open -> infer start).
    pub fn batch_us(&self) -> u64 {
        match (self.at(SpanKind::BatchOpen), self.at(SpanKind::InferStart)) {
            (Some(o), Some(s)) => s.saturating_sub(o),
            _ => 0,
        }
    }

    /// Backend compute time (infer start -> infer end).
    pub fn compute_us(&self) -> u64 {
        match (self.at(SpanKind::InferStart), self.at(SpanKind::InferEnd)) {
            (Some(s), Some(e)) => e.saturating_sub(s),
            _ => 0,
        }
    }

    /// Admission -> terminal span (done/error/shed), or the last span.
    pub fn total_us(&self) -> u64 {
        self.terminal()
            .or_else(|| self.spans.last().map(|s| s.at_us))
            .unwrap_or(0)
    }

    /// Did this request reach a terminal span exactly once, with
    /// non-decreasing timestamps? (The propagation-test invariant.)
    pub fn well_formed(&self) -> bool {
        let terminals = self
            .spans
            .iter()
            .filter(|s| matches!(s.kind, SpanKind::Done | SpanKind::Error | SpanKind::Shed))
            .count();
        let monotone = self.spans.windows(2).all(|w| w[0].at_us <= w[1].at_us);
        let starts = matches!(self.spans.first().map(|s| s.kind), Some(SpanKind::Enqueue));
        terminals == 1 && monotone && starts
    }
}

/// Default per-worker trace ring capacity.
pub const TRACE_RING_CAP: usize = 256;

/// Bounded ring of finished traces (oldest evicted first). One per pool
/// worker, so the only contention is drain vs. that worker.
pub struct TraceRing {
    inner: Mutex<VecDeque<RequestTrace>>,
    cap: usize,
}

impl TraceRing {
    pub fn new(cap: usize) -> TraceRing {
        TraceRing { inner: Mutex::new(VecDeque::new()), cap: cap.max(1) }
    }

    pub fn push(&self, t: RequestTrace) {
        let mut q = lock_unpoisoned(&self.inner);
        if q.len() == self.cap {
            q.pop_front();
        }
        q.push_back(t);
    }

    pub fn len(&self) -> usize {
        lock_unpoisoned(&self.inner).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Take every buffered trace (oldest first).
    pub fn drain(&self) -> Vec<RequestTrace> {
        lock_unpoisoned(&self.inner).drain(..).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_are_unique_and_increasing() {
        let a = TraceId::mint();
        let b = TraceId::mint();
        assert!(b > a);
    }

    #[test]
    fn spans_are_monotone_and_decompose() {
        let mut t = RequestTrace::begin(TraceId::mint(), "swis@4");
        t.degraded_to("swis@3");
        t.push(SpanKind::BatchOpen);
        t.push(SpanKind::BatchClose);
        t.push(SpanKind::InferStart);
        std::thread::sleep(std::time::Duration::from_millis(1));
        t.push(SpanKind::InferEnd);
        t.push(SpanKind::Done);
        assert!(t.well_formed(), "spans: {:?}", t.spans);
        assert_eq!(t.variant, "swis@4");
        assert_eq!(t.served_variant, "swis@3");
        assert!(t.compute_us() >= 1000, "compute {}", t.compute_us());
        assert!(t.queue_us() + t.batch_us() + t.compute_us() <= t.total_us());
    }

    #[test]
    fn shed_trace_is_terminal_and_well_formed() {
        let mut t = RequestTrace::begin(TraceId::mint(), "fp32");
        t.push(SpanKind::Shed);
        assert!(t.well_formed());
        assert_eq!(t.queue_us(), t.total_us());
        assert_eq!(t.compute_us(), 0);
    }

    #[test]
    fn ring_is_bounded_and_drains_in_order() {
        let ring = TraceRing::new(3);
        for i in 0..5 {
            let mut t = RequestTrace::begin(TraceId(100 + i), "fp32");
            t.push(SpanKind::Done);
            ring.push(t);
        }
        assert_eq!(ring.len(), 3);
        let got = ring.drain();
        assert!(ring.is_empty());
        let ids: Vec<u64> = got.iter().map(|t| t.id.0).collect();
        assert_eq!(ids, vec![102, 103, 104]);
    }
}
