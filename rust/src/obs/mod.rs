//! Crate-wide observability: kernel sparsity accounting, request
//! tracing, and live metrics export.
//!
//! The paper's value proposition is *work removed* — shift planes
//! dropped by shared weight bit sparsity, lanes masked by activation
//! zeros, precision tiers degraded under load. This module turns those
//! wins into numbers the serving stack reports live, in three layers:
//!
//! 1. **Kernel sparsity accounting** ([`ExecTally`]): the bit-serial
//!    kernels count planes visited vs. dropped-empty vs. masked-skipped,
//!    lanes masked by the zero-lane fold, scalar demotions and SIMD
//!    dispatches. Counting never touches the SIMD inner loops: unmasked
//!    tiles charge `O(1)` per tile from the prepared plane offsets, and
//!    masked tiles take one metadata pass over the `Plane` structs using
//!    the exact skip predicate the walk itself applies — so the numbers
//!    match the work done, bit for bit. Per-worker tallies merge through
//!    a per-call mutex after the scoped row threads join, then land in a
//!    thread-local accumulator the per-layer scopes diff.
//! 2. **Per-layer attribution** ([`LayerStats`] / [`ForwardStats`]):
//!    `exec::model` brackets every node with [`layer_begin`] /
//!    [`layer_end`]; `api::Session` exposes the last forward's breakdown
//!    as `Session::last_stats()`. Each layer also folds into a global
//!    per-layer registry ([`global_layers`]) the Prometheus exporter
//!    renders with `{layer="..."}` labels.
//! 3. **Request tracing + export** ([`trace`], [`registry`], [`http`]):
//!    span-stamped per-request traces through the pool, rendered with
//!    pool metrics into Prometheus text exposition served by
//!    `swis serve --metrics-addr`.
//!
//! Everything is gated on the runtime [`ObsLevel`] knob (CLI `--obs`,
//! env `SWIS_OBS`): at `Off` the only cost on the hot path is one
//! relaxed atomic load per GEMM/depthwise *call* (never per plane), a
//! tax the `obs_overhead` bench section gates at <= 3%.

pub mod http;
pub mod registry;
pub mod trace;

use std::cell::{Cell, RefCell};
use std::time::Instant;

use crate::error::{SwisError, SwisResult};
use crate::util::sync::atomic::{AtomicU8, Ordering};
use crate::util::sync::{lock_unpoisoned, Mutex};

/// How much the process observes itself. Ordered: each level includes
/// everything below it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum ObsLevel {
    /// No accounting at all — one relaxed atomic load per kernel call.
    #[default]
    Off = 0,
    /// Kernel sparsity counters + per-layer attribution + wall time.
    Counters = 1,
    /// Counters plus request tracing through the pool.
    Full = 2,
}

impl ObsLevel {
    pub fn parse(s: &str) -> SwisResult<ObsLevel> {
        Ok(match s {
            "off" | "0" => ObsLevel::Off,
            "counters" | "1" => ObsLevel::Counters,
            "full" | "2" => ObsLevel::Full,
            other => {
                return Err(SwisError::config(format!(
                    "unknown obs level '{other}' (expected off|counters|full)"
                )))
            }
        })
    }

    pub fn as_str(self) -> &'static str {
        match self {
            ObsLevel::Off => "off",
            ObsLevel::Counters => "counters",
            ObsLevel::Full => "full",
        }
    }
}

/// Process-global observability level. Relaxed everywhere: a transition
/// mid-forward at worst misattributes one layer, never corrupts state.
static LEVEL: AtomicU8 = AtomicU8::new(0);

pub fn set_level(l: ObsLevel) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn level() -> ObsLevel {
    match LEVEL.load(Ordering::Relaxed) {
        0 => ObsLevel::Off,
        1 => ObsLevel::Counters,
        _ => ObsLevel::Full,
    }
}

/// Kernel accounting enabled? The ONE check the kernels make per call.
#[inline]
pub fn counters_on() -> bool {
    LEVEL.load(Ordering::Relaxed) >= ObsLevel::Counters as u8
}

/// Request tracing enabled?
#[inline]
pub fn tracing_on() -> bool {
    LEVEL.load(Ordering::Relaxed) >= ObsLevel::Full as u8
}

/// Adopt `SWIS_OBS` (off|counters|full) if set; unknown values are
/// ignored (observability must never fail a serving process).
pub fn init_from_env() {
    if let Ok(v) = std::env::var("SWIS_OBS") {
        if let Ok(l) = ObsLevel::parse(&v) {
            set_level(l);
        }
    }
}

/// Number of [`crate::exec::simd::KernelVariant`] flavors (dispatch
/// counter width).
pub const N_VARIANTS: usize = 5;

/// One bundle of kernel sparsity counters. Plain `u64`s — accumulated
/// locally per scoped-thread chunk, merged under a per-call mutex, added
/// to a thread-local by [`record_exec`]; no atomics on the counting path.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ExecTally {
    /// Plane-walk iterations actually executed.
    pub planes_visited: u64,
    /// Plane walks skipped because the zero-lane mask emptied the plane
    /// (`(pos | neg) & mask == 0` — the kernels' exact predicate).
    pub planes_skipped_masked: u64,
    /// Plane-walk slots that never existed because the plane was dropped
    /// empty at prepare time (weight bit sparsity), charged once per
    /// sweep the walk would otherwise have made.
    pub planes_dropped_empty: u64,
    /// Lanes zeroed out of masked tiles by the activation zero fold.
    pub lanes_masked: u64,
    /// (row-tile x group-chunk) units processed.
    pub tiles_total: u64,
    /// Of those, units that ran with a real (non-all-ones) lane mask.
    pub tiles_masked: u64,
    /// Kernel calls demoted to the scalar walk (forced scalar or the
    /// i32-partial overflow screen) despite a vector tune.
    pub scalar_demotions: u64,
    /// Kernel calls per [`crate::exec::simd::KernelVariant`], indexed by
    /// `KernelVariant::index()`.
    pub dispatch: [u64; N_VARIANTS],
}

impl ExecTally {
    pub fn add(&mut self, o: &ExecTally) {
        self.planes_visited += o.planes_visited;
        self.planes_skipped_masked += o.planes_skipped_masked;
        self.planes_dropped_empty += o.planes_dropped_empty;
        self.lanes_masked += o.lanes_masked;
        self.tiles_total += o.tiles_total;
        self.tiles_masked += o.tiles_masked;
        self.scalar_demotions += o.scalar_demotions;
        for (d, s) in self.dispatch.iter_mut().zip(o.dispatch.iter()) {
            *d += s;
        }
    }

    /// `self - earlier` field-wise (counters are monotone, so the diff of
    /// two snapshots of one accumulator never underflows).
    pub fn diff(&self, earlier: &ExecTally) -> ExecTally {
        let mut d = ExecTally {
            planes_visited: self.planes_visited - earlier.planes_visited,
            planes_skipped_masked: self.planes_skipped_masked - earlier.planes_skipped_masked,
            planes_dropped_empty: self.planes_dropped_empty - earlier.planes_dropped_empty,
            lanes_masked: self.lanes_masked - earlier.lanes_masked,
            tiles_total: self.tiles_total - earlier.tiles_total,
            tiles_masked: self.tiles_masked - earlier.tiles_masked,
            scalar_demotions: self.scalar_demotions - earlier.scalar_demotions,
            dispatch: [0; N_VARIANTS],
        };
        for i in 0..N_VARIANTS {
            d.dispatch[i] = self.dispatch[i] - earlier.dispatch[i];
        }
        d
    }

    /// Plane-walk slots a sparsity-blind kernel would have executed.
    pub fn planes_total(&self) -> u64 {
        self.planes_visited + self.planes_skipped_masked + self.planes_dropped_empty
    }

    /// Slots removed by sparsity (weight bits + activation zeros).
    pub fn planes_skipped(&self) -> u64 {
        self.planes_skipped_masked + self.planes_dropped_empty
    }

    pub fn is_zero(&self) -> bool {
        *self == ExecTally::default()
    }
}

thread_local! {
    /// Per-thread running tally the layer scopes diff.
    static CURRENT: Cell<ExecTally> = Cell::new(ExecTally::default());
    /// Layer breakdown of the forward pass running on this thread.
    static FORWARD: RefCell<Vec<LayerStats>> = const { RefCell::new(Vec::new()) };
}

/// Merge one kernel call's tally into this thread's accumulator. Called
/// by `exec::kernel` on the session thread after its scoped row threads
/// join — and only when [`counters_on`].
pub fn record_exec(t: &ExecTally) {
    CURRENT.with(|c| {
        let mut v = c.get();
        v.add(t);
        c.set(v);
    });
}

/// Snapshot of this thread's accumulator (for external diffing).
pub fn current() -> ExecTally {
    CURRENT.with(|c| c.get())
}

/// One layer's slice of a forward pass.
#[derive(Clone, Debug)]
pub struct LayerStats {
    pub label: String,
    pub tally: ExecTally,
    pub time_ms: f64,
}

/// Per-layer breakdown of one `Session::run` forward pass.
#[derive(Clone, Debug, Default)]
pub struct ForwardStats {
    pub layers: Vec<LayerStats>,
    /// End-to-end forward wall time.
    pub time_ms: f64,
}

impl ForwardStats {
    /// Whole-forward tally (sum over layers).
    pub fn tally(&self) -> ExecTally {
        let mut t = ExecTally::default();
        for l in &self.layers {
            t.add(&l.tally);
        }
        t
    }
}

/// Open layer scope: snapshot of the thread tally + wall clock.
pub struct LayerToken {
    snap: ExecTally,
    t0: Instant,
}

/// Reset this thread's forward collector (start of a model forward).
pub fn forward_begin() {
    if counters_on() {
        FORWARD.with(|f| f.borrow_mut().clear());
    }
}

/// Open a per-layer scope (`None` when counters are off — the matching
/// [`layer_end`] is then a no-op).
pub fn layer_begin() -> Option<LayerToken> {
    counters_on().then(|| LayerToken { snap: current(), t0: Instant::now() })
}

/// Close a per-layer scope: diff the thread tally, stamp wall time, push
/// into this thread's forward collector AND the global per-layer
/// registry.
pub fn layer_end(tok: Option<LayerToken>, label: &str) {
    let Some(tok) = tok else { return };
    let tally = current().diff(&tok.snap);
    let time_ms = tok.t0.elapsed().as_secs_f64() * 1e3;
    FORWARD.with(|f| {
        f.borrow_mut().push(LayerStats { label: label.to_string(), tally, time_ms });
    });
    global_add(label, &tally, time_ms);
}

/// Take this thread's collected forward breakdown (the per-`Session::run`
/// aggregation point). `None` when counters are off.
pub fn take_forward(total_ms: f64) -> Option<ForwardStats> {
    if !counters_on() {
        return None;
    }
    let layers = FORWARD.with(|f| std::mem::take(&mut *f.borrow_mut()));
    Some(ForwardStats { layers, time_ms: total_ms })
}

/// One layer's process-lifetime aggregate (all forwards, all threads).
#[derive(Clone, Debug)]
pub struct LayerAgg {
    pub label: String,
    pub tally: ExecTally,
    /// Total wall time spent in this layer.
    pub time_ms: f64,
    /// Forward passes that executed this layer.
    pub calls: u64,
}

/// Global per-layer registry, insertion-ordered (graph order for the
/// first net observed). Locked once per (layer, forward) — never inside
/// a kernel.
static GLOBAL: Mutex<Vec<LayerAgg>> = Mutex::new(Vec::new());

fn global_add(label: &str, t: &ExecTally, time_ms: f64) {
    let mut g = lock_unpoisoned(&GLOBAL);
    if let Some(agg) = g.iter_mut().find(|a| a.label == label) {
        agg.tally.add(t);
        agg.time_ms += time_ms;
        agg.calls += 1;
    } else {
        g.push(LayerAgg { label: label.to_string(), tally: *t, time_ms, calls: 1 });
    }
}

/// Snapshot of the process-lifetime per-layer aggregates.
pub fn global_layers() -> Vec<LayerAgg> {
    lock_unpoisoned(&GLOBAL).clone()
}

/// Clear the global registry and this thread's accumulators (benches and
/// tests isolate their measurements with this).
pub fn reset() {
    lock_unpoisoned(&GLOBAL).clear();
    CURRENT.with(|c| c.set(ExecTally::default()));
    FORWARD.with(|f| f.borrow_mut().clear());
}

/// Unit tests across the crate share one process-global [`ObsLevel`];
/// any lib test that flips it must hold this guard so parallel test
/// threads never observe each other's level.
#[cfg(test)]
pub(crate) fn test_level_guard() -> std::sync::MutexGuard<'static, ()> {
    static GUARD: std::sync::Mutex<()> = std::sync::Mutex::new(());
    GUARD.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_knob_round_trips() {
        assert_eq!(ObsLevel::parse("off").unwrap(), ObsLevel::Off);
        assert_eq!(ObsLevel::parse("counters").unwrap(), ObsLevel::Counters);
        assert_eq!(ObsLevel::parse("full").unwrap(), ObsLevel::Full);
        assert!(ObsLevel::parse("loud").is_err());
        for l in [ObsLevel::Counters, ObsLevel::Full, ObsLevel::Off] {
            assert_eq!(ObsLevel::parse(l.as_str()).unwrap(), l);
        }
        assert!(ObsLevel::Full > ObsLevel::Counters);
    }

    #[test]
    fn tally_add_diff_total() {
        let mut a = ExecTally { planes_visited: 10, planes_skipped_masked: 3, ..Default::default() };
        a.dispatch[2] = 1;
        let snap = a;
        let mut b = a;
        b.add(&ExecTally { planes_visited: 5, planes_dropped_empty: 7, ..Default::default() });
        let d = b.diff(&snap);
        assert_eq!(d.planes_visited, 5);
        assert_eq!(d.planes_dropped_empty, 7);
        assert_eq!(d.dispatch[2], 0);
        assert_eq!(b.planes_total(), 25);
        assert_eq!(b.planes_skipped(), 10);
        assert!(!b.is_zero() && ExecTally::default().is_zero());
    }

    #[test]
    fn layer_scopes_attribute_to_thread_and_global() {
        let _g = test_level_guard();
        set_level(ObsLevel::Counters);
        reset();
        forward_begin();
        let tok = layer_begin();
        record_exec(&ExecTally { planes_visited: 42, lanes_masked: 4, ..Default::default() });
        layer_end(tok, "conv0");
        let tok = layer_begin();
        record_exec(&ExecTally { planes_visited: 8, ..Default::default() });
        layer_end(tok, "conv0"); // same label aggregates globally
        let fwd = take_forward(1.5).unwrap();
        assert_eq!(fwd.layers.len(), 2);
        assert_eq!(fwd.layers[0].tally.planes_visited, 42);
        assert_eq!(fwd.layers[1].tally.planes_visited, 8);
        assert_eq!(fwd.tally().planes_visited, 50);
        let g = global_layers();
        assert_eq!(g.len(), 1);
        assert_eq!(g[0].calls, 2);
        assert_eq!(g[0].tally.planes_visited, 50);
        set_level(ObsLevel::Off);
        assert!(layer_begin().is_none());
        assert!(take_forward(0.0).is_none());
        reset();
    }
}
