//! Minimal blocking metrics endpoint on a std [`TcpListener`] — no HTTP
//! dependency. One responder thread accepts connections, reads the
//! request head, and answers every `GET` with the registry's Prometheus
//! exposition page (`Content-Type: text/plain; version=0.0.4`). Good for
//! a scrape target; deliberately not a general web server.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::registry::MetricsRegistry;
use crate::error::{SwisError, SwisResult};
use crate::util::sync::atomic::{AtomicBool, Ordering};

/// Poll interval of the non-blocking accept loop (also the shutdown
/// latency bound).
const ACCEPT_POLL: Duration = Duration::from_millis(50);

/// Handle to a running metrics endpoint.
pub struct MetricsServer {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
    addr: SocketAddr,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9184`; port 0 picks a free port) and
    /// serve `registry` until [`MetricsServer::stop`] or drop.
    pub fn serve(addr: &str, registry: MetricsRegistry) -> SwisResult<MetricsServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| SwisError::config(format!("metrics endpoint bind {addr}: {e}")))?;
        let bound = listener
            .local_addr()
            .map_err(|e| SwisError::config(format!("metrics endpoint addr: {e}")))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| SwisError::config(format!("metrics endpoint nonblocking: {e}")))?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("swis-metrics".into())
            .spawn(move || accept_loop(listener, registry, stop2))
            .map_err(|e| SwisError::backend(format!("spawning metrics thread: {e}")))?;
        Ok(MetricsServer { stop, handle: Some(handle), addr: bound })
    }

    /// The address actually bound (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the responder thread.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        // Release pairs with the accept loop's Acquire load.
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: TcpListener, registry: MetricsRegistry, stop: Arc<AtomicBool>) {
    while !stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _)) => {
                // serve inline: a scrape is one small read + one write,
                // and serialized responses keep the server trivially
                // bounded
                let _ = respond(stream, &registry);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
}

fn respond(mut stream: TcpStream, registry: &MetricsRegistry) -> std::io::Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(Duration::from_millis(500)))?;
    stream.set_write_timeout(Some(Duration::from_secs(2)))?;
    // read the request head (we answer any method/path with the page;
    // a scrape target has exactly one resource)
    let mut buf = [0u8; 2048];
    let mut head = Vec::new();
    loop {
        match stream.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => {
                head.extend_from_slice(&buf[..n]);
                if head.windows(4).any(|w| w == b"\r\n\r\n") || head.len() > 16 * 1024 {
                    break;
                }
            }
            Err(_) => break, // timeout or reset: answer anyway
        }
    }
    let body = registry.render();
    let resp = format!(
        "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
        body.len(),
        body
    );
    stream.write_all(resp.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serves_exposition_text_over_tcp() {
        let reg = MetricsRegistry::new();
        let srv = MetricsServer::serve("127.0.0.1:0", reg).unwrap();
        let addr = srv.addr();
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 200 OK"), "got: {out}");
        assert!(out.contains("text/plain; version=0.0.4"));
        assert!(out.contains("swis_obs_level"));
        srv.stop();
    }

    #[test]
    fn bad_bind_is_a_typed_error() {
        assert!(MetricsServer::serve("definitely-not-an-addr", MetricsRegistry::new()).is_err());
    }
}
