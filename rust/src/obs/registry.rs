//! The export layer: [`MetricsRegistry`] snapshots pool metrics +
//! admission depths and renders them — together with the live per-layer
//! kernel aggregates ([`super::global_layers`]) — into Prometheus text
//! exposition format (`text/plain; version=0.0.4`), plus the
//! `BENCH_observability.json` builder the CLI paths share.
//!
//! Metric names are documented next to the fields they export
//! ([`crate::coordinator::Metrics`] for the pool counters, the per-layer
//! families below for the kernel tallies).

use std::sync::Arc;

use super::trace::RequestTrace;
use super::LayerAgg;
use crate::coordinator::MetricsSnapshot;
use crate::util::json::Json;
use crate::util::sync::{lock_unpoisoned, Mutex};

/// Human label of an admission lane index (`Priority::lane()` order).
pub fn lane_label(lane: usize) -> &'static str {
    if lane == 0 {
        "interactive"
    } else {
        "batch"
    }
}

#[derive(Default)]
struct RegInner {
    pool: Option<MetricsSnapshot>,
    depths: [usize; 2],
}

/// Sampled registry the metrics endpoint renders from. The serve driver
/// refreshes the pool snapshot on its own cadence ([`update_pool`]);
/// kernel-layer aggregates are pulled live at render time, so
/// `swis_planes_*` counters are always current.
///
/// [`update_pool`]: MetricsRegistry::update_pool
#[derive(Clone, Default)]
pub struct MetricsRegistry {
    inner: Arc<Mutex<RegInner>>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Install the latest pool metrics snapshot + per-lane queue depths.
    pub fn update_pool(&self, snap: MetricsSnapshot, depths: [usize; 2]) {
        let mut g = lock_unpoisoned(&self.inner);
        g.pool = Some(snap);
        g.depths = depths;
    }

    /// Render the full exposition page.
    pub fn render(&self) -> String {
        let mut out = String::with_capacity(4096);
        let g = lock_unpoisoned(&self.inner);
        push_metric(
            &mut out,
            "swis_obs_level",
            "gauge",
            "Current ObsLevel (0=off 1=counters 2=full)",
            &[(&[], super::level() as u8 as f64)],
        );
        if let Some(s) = &g.pool {
            render_pool(&mut out, s, g.depths);
        }
        drop(g);
        render_layers(&mut out, &super::global_layers());
        out
    }
}

fn push_metric(out: &mut String, name: &str, kind: &str, help: &str, series: &[(&[(&str, &str)], f64)]) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
    for (labels, v) in series {
        out.push_str(name);
        if !labels.is_empty() {
            out.push('{');
            for (i, (k, val)) in labels.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(&format!("{k}=\"{}\"", escape_label(val)));
            }
            out.push('}');
        }
        // counters are exact u64s below 2^53; render without exponent
        if v.fract() == 0.0 && v.abs() < 9.0e15 {
            out.push_str(&format!(" {}\n", *v as i64));
        } else {
            out.push_str(&format!(" {v}\n"));
        }
    }
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

fn render_pool(out: &mut String, s: &MetricsSnapshot, depths: [usize; 2]) {
    push_metric(out, "swis_requests_total", "counter", "Requests completed through a batch", &[(&[], s.requests as f64)]);
    push_metric(out, "swis_batches_total", "counter", "Batches dispatched", &[(&[], s.batches as f64)]);
    let il = [("lane", lane_label(0))];
    let bl = [("lane", lane_label(1))];
    push_metric(
        out,
        "swis_shed_total",
        "counter",
        "Requests dropped by deadline shedding, per admission lane",
        &[(&il, s.shed_by_lane[0] as f64), (&bl, s.shed_by_lane[1] as f64)],
    );
    push_metric(
        out,
        "swis_rejected_total",
        "counter",
        "Requests refused Busy at admission, per lane",
        &[(&il, s.rejected_by_lane[0] as f64), (&bl, s.rejected_by_lane[1] as f64)],
    );
    push_metric(out, "swis_degraded_total", "counter", "Requests served below their requested precision tier", &[(&[], s.degraded as f64)]);
    push_metric(out, "swis_errors_total", "counter", "Requests answered with a routed error", &[(&[], s.errors as f64)]);
    push_metric(out, "swis_panics_total", "counter", "Worker panics contained by the pool", &[(&[], s.panics as f64)]);
    push_metric(
        out,
        "swis_queue_depth",
        "gauge",
        "Requests currently queued, per admission lane",
        &[(&il, depths[0] as f64), (&bl, depths[1] as f64)],
    );
    push_metric(out, "swis_mean_batch", "gauge", "Mean dispatched batch size", &[(&[], s.mean_batch)]);
    if s.wire != crate::coordinator::WireCounters::default() {
        push_metric(
            out,
            "swis_wire_faults_total",
            "counter",
            "Protocol faults observed at the TCP edge, per class",
            &[
                (&[("kind", "bad_magic")], s.wire.bad_magic as f64),
                (&[("kind", "bad_frame")], s.wire.bad_frame as f64),
                (&[("kind", "oversized")], s.wire.oversized as f64),
                (&[("kind", "stalled_read")], s.wire.stalled_read as f64),
                (&[("kind", "stalled_write")], s.wire.stalled_write as f64),
            ],
        );
        push_metric(
            out,
            "swis_quota_rejected_total",
            "counter",
            "Requests refused by per-tenant token-bucket quota",
            &[(&[], s.wire.quota_rejected as f64)],
        );
        push_metric(
            out,
            "swis_conns_total",
            "counter",
            "TCP edge connections, by lifecycle event",
            &[
                (&[("event", "opened")], s.wire.conns_opened as f64),
                (&[("event", "closed")], s.wire.conns_closed as f64),
            ],
        );
    }
    push_metric(
        out,
        "swis_total_latency_us",
        "gauge",
        "End-to-end latency percentiles over the metrics reservoir",
        &[
            (&[("quantile", "0.5")], s.p50_total_us),
            (&[("quantile", "0.99")], s.p99_total_us),
        ],
    );
}

fn render_layers(out: &mut String, layers: &[LayerAgg]) {
    if layers.is_empty() {
        return;
    }
    let series = |f: &dyn Fn(&LayerAgg) -> f64| -> Vec<(Vec<(&str, &str)>, f64)> {
        layers.iter().map(|l| (vec![("layer", l.label.as_str())], f(l))).collect()
    };
    for (name, help, f) in [
        (
            "swis_planes_visited_total",
            "Shift-plane walks executed, per layer",
            &(|l: &LayerAgg| l.tally.planes_visited as f64) as &dyn Fn(&LayerAgg) -> f64,
        ),
        (
            "swis_planes_skipped_total",
            "Shift-plane walks removed by sparsity (empty at prepare + masked by activation zeros), per layer",
            &|l: &LayerAgg| l.tally.planes_skipped() as f64,
        ),
        (
            "swis_lanes_masked_total",
            "Lanes zeroed out of masked tiles by the activation zero fold, per layer",
            &|l: &LayerAgg| l.tally.lanes_masked as f64,
        ),
        (
            "swis_layer_time_ms_total",
            "Wall time spent in each layer's kernels",
            &|l: &LayerAgg| l.time_ms,
        ),
        (
            "swis_scalar_demotions_total",
            "Kernel calls demoted to the scalar walk, per layer",
            &|l: &LayerAgg| l.tally.scalar_demotions as f64,
        ),
    ] {
        let rows = series(f);
        let borrowed: Vec<(&[(&str, &str)], f64)> =
            rows.iter().map(|(l, v)| (l.as_slice(), *v)).collect();
        push_metric(out, name, "counter", help, &borrowed);
    }
}

/// Cap on full span dumps embedded in `BENCH_observability.json` (the
/// decomposition means still cover every trace).
const MAX_TRACE_SAMPLES: usize = 64;

/// Build the `BENCH_observability.json` root: per-layer sparsity
/// accounting + trace-derived latency decomposition. Callers stamp their
/// own context keys (net, probe, variants, p50/p95) on the returned
/// object.
pub fn observability_json(layers: &[LayerAgg], traces: &[RequestTrace]) -> Json {
    let mut root = Json::obj();
    root.set("bench", "observability");
    root.set("obs_level", super::level().as_str());
    root.set("unit_time", "ms");
    root.set("unit_latency", "us");
    let lj: Vec<Json> = layers
        .iter()
        .map(|l| {
            let mut j = Json::obj();
            j.set("layer", l.label.as_str());
            j.set("calls", l.calls);
            j.set("planes_total", l.tally.planes_total());
            j.set("planes_visited", l.tally.planes_visited);
            j.set("planes_skipped", l.tally.planes_skipped());
            j.set("planes_skipped_masked", l.tally.planes_skipped_masked);
            j.set("planes_dropped_empty", l.tally.planes_dropped_empty);
            j.set("lanes_masked", l.tally.lanes_masked);
            j.set("tiles_masked", l.tally.tiles_masked);
            j.set("tiles_total", l.tally.tiles_total);
            j.set("time_ms", l.time_ms);
            j
        })
        .collect();
    root.set("layers", Json::Arr(lj));
    let mut tj = Json::obj();
    tj.set("sampled", traces.len() as u64);
    let n = traces.len().max(1) as f64;
    let mean = |f: &dyn Fn(&RequestTrace) -> u64| {
        traces.iter().map(|t| f(t) as f64).sum::<f64>() / n
    };
    let mut decomp = Json::obj();
    decomp.set("queue_wait_us_mean", mean(&|t| t.queue_us()));
    decomp.set("batch_us_mean", mean(&|t| t.batch_us()));
    decomp.set("compute_us_mean", mean(&|t| t.compute_us()));
    decomp.set("total_us_mean", mean(&|t| t.total_us()));
    tj.set("decomposition", decomp);
    let samples: Vec<Json> = traces
        .iter()
        .take(MAX_TRACE_SAMPLES)
        .map(|t| {
            let mut j = Json::obj();
            j.set("id", t.id.0);
            j.set("variant", t.variant.as_str());
            j.set("served_variant", t.served_variant.as_str());
            j.set("queue_us", t.queue_us());
            j.set("batch_us", t.batch_us());
            j.set("compute_us", t.compute_us());
            j.set("total_us", t.total_us());
            let spans: Vec<Json> = t
                .spans
                .iter()
                .map(|s| {
                    let mut sj = Json::obj();
                    sj.set("kind", s.kind.as_str());
                    sj.set("at_us", s.at_us);
                    sj
                })
                .collect();
            j.set("spans", Json::Arr(spans));
            j
        })
        .collect();
    tj.set("samples", Json::Arr(samples));
    root.set("traces", tj);
    root
}

#[cfg(test)]
mod tests {
    use super::super::trace::{SpanKind, TraceId};
    use super::super::{ExecTally, LayerAgg};
    use super::*;

    fn agg(label: &str, visited: u64, skipped: u64, masked: u64) -> LayerAgg {
        LayerAgg {
            label: label.to_string(),
            tally: ExecTally {
                planes_visited: visited,
                planes_dropped_empty: skipped,
                lanes_masked: masked,
                ..Default::default()
            },
            time_ms: 1.25,
            calls: 2,
        }
    }

    #[test]
    fn renders_parseable_exposition_text() {
        let reg = MetricsRegistry::new();
        let text = reg.render();
        assert!(text.contains("# TYPE swis_obs_level gauge"));
        // every non-comment line is `name[{labels}] value`
        for line in text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
            let (_, value) = line.rsplit_once(' ').expect("metric line has a value");
            value.parse::<f64>().expect("metric value parses");
        }
    }

    #[test]
    fn pool_snapshot_and_layers_reach_the_page() {
        let m = crate::coordinator::Metrics::default();
        m.record_rejected(crate::coordinator::Priority::Batch);
        let reg = MetricsRegistry::new();
        reg.update_pool(m.snapshot(), [3, 1]);
        let text = reg.render();
        assert!(text.contains("swis_rejected_total{lane=\"batch\"} 1"));
        assert!(text.contains("swis_queue_depth{lane=\"interactive\"} 3"));
        assert!(text.contains("swis_total_latency_us{quantile=\"0.99\"}"));
    }

    #[test]
    fn observability_json_schema() {
        let layers = vec![agg("conv0", 100, 20, 7), agg("fc1", 50, 5, 0)];
        let mut t = RequestTrace::begin(TraceId(9), "swis@3");
        t.push(SpanKind::BatchOpen);
        t.push(SpanKind::InferStart);
        t.push(SpanKind::InferEnd);
        t.push(SpanKind::Done);
        let j = observability_json(&layers, &[t]);
        assert_eq!(j.get("bench").unwrap().as_str(), Some("observability"));
        for key in ["layer", "planes_total", "planes_skipped", "lanes_masked", "time_ms"] {
            assert!(j.path(&["layers", "0", key]).is_some(), "missing layers[0].{key}");
        }
        assert!(j.path(&["traces", "decomposition", "compute_us_mean"]).is_some());
        assert!(j.path(&["traces", "samples", "0", "total_us"]).is_some());
    }
}
