//! BitFusion comparator model (Sharma et al., ISCA'18) at the 4x8
//! configuration the paper evaluates (Table 4): a systolic array of
//! fusion units built from 2-bit BitBricks that compose dynamically.
//!
//! At 4-bit weights x 8-bit activations each fusion unit delivers 2x the
//! MAC throughput of the same-area 8x8 fixed-point datapath (16 bricks
//! re-fused from one 8x8 product into two 4x8 products), at slightly
//! higher per-MAC energy from the composition network. Weights are
//! stored at 4 bits (+ sign folded in two's complement).

use super::calib::ge_to_pj;
use super::pe::{PeKind, PeModel};

/// BitFusion fusion-unit group model, aligned with the PE cost framework.
#[derive(Clone, Copy, Debug)]
pub struct BitFusionModel {
    pub group_size: usize,
    pub area_ge: f64,
    pub pj_per_cycle: f64,
    /// weight precision the array is configured for (bits)
    pub weight_bits: usize,
}

impl BitFusionModel {
    /// 4x8 configuration (the paper's comparison point).
    pub fn new_4x8(group_size: usize) -> BitFusionModel {
        let fx = PeModel::new(PeKind::Fixed, group_size);
        // composition overhead: +6% area over the fixed-point datapath
        // (paper Table 4 reports 0.57 mm^2 vs 0.54 mm^2 iso-config);
        // the brick-level shift-add network raises per-cycle energy ~28%
        // while doubling 4x8 throughput.
        let area = fx.area_ge * 1.06;
        let e = fx.pj_per_cycle * 1.28 + ge_to_pj(fx.area_ge * 0.02);
        BitFusionModel {
            group_size,
            area_ge: area,
            pj_per_cycle: e,
            weight_bits: 4,
        }
    }

    /// Group-ops per cycle: 2x fixed-point at 4-bit weights.
    pub fn cycles_per_group_op(&self) -> f64 {
        0.5
    }

    pub fn throughput(&self) -> f64 {
        self.group_size as f64 / self.cycles_per_group_op()
    }

    pub fn pj_per_mac(&self) -> f64 {
        self.pj_per_cycle * self.cycles_per_group_op() / self.group_size as f64
    }

    /// Storage bits per weight (two's-complement 4-bit).
    pub fn bits_per_weight(&self) -> f64 {
        self.weight_bits as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn doubles_fixed_point_throughput() {
        let bf = BitFusionModel::new_4x8(4);
        let fx = PeModel::new(PeKind::Fixed, 4);
        assert!((bf.throughput() / fx.throughput(1.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn per_mac_energy_between_half_and_full_fixed() {
        let bf = BitFusionModel::new_4x8(4);
        let fx = PeModel::new(PeKind::Fixed, 4);
        let r = bf.pj_per_mac() / fx.pj_per_mac(1.0);
        // half the cycles but composition overhead: 0.5 < r < 1.0
        assert!(r > 0.5 && r < 1.0, "ratio {r}");
    }

    #[test]
    fn area_overhead_modest() {
        let bf = BitFusionModel::new_4x8(4);
        let fx = PeModel::new(PeKind::Fixed, 4);
        let r = bf.area_ge / fx.area_ge;
        assert!(r > 1.0 && r < 1.12, "area ratio {r}");
    }

    #[test]
    fn halves_weight_storage() {
        assert_eq!(BitFusionModel::new_4x8(4).bits_per_weight(), 4.0);
    }
}
