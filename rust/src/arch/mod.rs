//! Hardware cost models: PE area/energy (Fig. 3), storage compression
//! (Fig. 5), memory energies and the BitFusion comparator (Table 4).

pub mod bitfusion;
pub mod calib;
pub mod compression;
pub mod pe;
pub mod pe_functional;
