//! Calibration constants for the 28 nm component cost model.
//!
//! The paper synthesized its PEs with a commercial 28 nm TSMC library and
//! reports *normalized* area / energy-per-MAC / throughput-per-area
//! (Fig. 3). We reproduce those curves from first-principles component
//! costs expressed in gate equivalents (GE, area) and femtojoules per
//! activation (energy), with values taken from standard-cell intuition
//! (NAND2 = 1 GE) and tuned so the paper's crossovers hold:
//!
//!   * single-shift bit-serial beats fixed-point energy/MAC and
//!     throughput/area only below ~4 shifts and at group size >= 8;
//!   * a double-shift PE at group G dominates a single-shift PE at 2G.
//!
//! All downstream results (Table 4) consume only RELATIVE numbers, so the
//! absolute unit is arbitrary; `PJ_PER_GE_ACT` anchors it to picojoules
//! for the energy roll-up.

/// Area of an 8x8 Baugh-Wooley multiplier (GE).
pub const A_MULT8: f64 = 345.0;
/// Area per full-adder bit in an adder tree / accumulator (GE).
pub const A_FA: f64 = 6.0;
/// Area per flip-flop bit (GE).
pub const A_FF: f64 = 6.5;
/// Area per 2-input AND gate (mask stage) (GE).
pub const A_AND: f64 = 1.4;
/// Area per 2:1 mux bit (sign-invert / shifter stages) (GE).
pub const A_MUX: f64 = 2.2;
/// Fixed per-PE control overhead (decoders, shift-count counter) (GE).
pub const A_CTRL: f64 = 60.0;
/// Extra control for the double-shift PE (second plane sequencing) (GE).
pub const A_CTRL_DS: f64 = 25.0;

/// Switching energy per GE per active cycle, in femtojoules. Datapath
/// activity factors are folded into per-component multipliers below.
pub const FJ_PER_GE: f64 = 0.45;

/// Relative switching activity of each component class (dimensionless).
pub const ACT_MULT: f64 = 1.0;
pub const ACT_TREE: f64 = 0.75;
pub const ACT_AND: f64 = 0.5;
pub const ACT_MUX: f64 = 0.35;
pub const ACT_FF: f64 = 0.6;
pub const ACT_CTRL: f64 = 0.25;

/// Accumulator width (output-stationary partial sums).
pub const ACC_BITS: f64 = 24.0;

/// Memory energies, picojoules per byte (28 nm-class, Horowitz-scaled).
pub const PJ_SRAM_BYTE: f64 = 1.2;
/// DRAM access energy, pJ/byte (LPDDR-class interface).
pub const PJ_DRAM_BYTE: f64 = 84.0;

/// Accelerator clock (Hz) used to convert cycles to seconds.
pub const CLOCK_HZ: f64 = 1.0e9;

/// Convert GE-cycles to picojoules.
#[inline]
pub fn ge_to_pj(ge_active: f64) -> f64 {
    ge_active * FJ_PER_GE / 1000.0
}
