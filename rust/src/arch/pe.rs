//! Processing-element cost model (paper Sec. 3.1, Fig. 3): fixed-point,
//! single-shift bit-serial, and double-shift bit-serial PEs with group
//! sizes 2..16, including their activation/weight buffers (the paper's
//! synthesis included buffers, which is what limits bit-serial gains at
//! small group sizes).

use super::calib::*;

/// PE flavor.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PeKind {
    /// Conventional 8-bit fixed-point MAC group (1 group-op/cycle).
    Fixed,
    /// Bit-serial, one shift plane per cycle (paper "single-shift").
    SingleShift,
    /// Bit-serial, two shift planes per cycle (paper "double-shift").
    DoubleShift,
}

/// Synthesized-PE surrogate: area (GE), energy per cycle (pJ), and
/// throughput accounting.
#[derive(Clone, Copy, Debug)]
pub struct PeModel {
    pub kind: PeKind,
    /// Weights multiplied-accumulated in parallel per group-op.
    pub group_size: usize,
    /// Total area in gate equivalents (incl. act/wgt buffers).
    pub area_ge: f64,
    /// Energy per active cycle, picojoules.
    pub pj_per_cycle: f64,
}

fn log2ceil(x: usize) -> f64 {
    (x.max(1) as f64).log2().ceil()
}

impl PeModel {
    pub fn new(kind: PeKind, group_size: usize) -> PeModel {
        let g = group_size as f64;
        // shared buffers: activation regs G x 8b, double-buffered
        let a_act_buf = g * 8.0 * A_FF * 2.0;
        match kind {
            PeKind::Fixed => {
                // G multipliers + 16b adder tree + accumulator + wgt regs
                let a_mult = g * A_MULT8;
                let a_tree = (g - 1.0).max(0.0) * 16.0 * A_FA;
                let a_acc = ACC_BITS * (A_FA + A_FF);
                let a_wbuf = g * 8.0 * A_FF * 2.0;
                let area = a_mult + a_tree + a_acc + a_act_buf + a_wbuf + A_CTRL;
                let e = ge_to_pj(
                    a_mult * ACT_MULT
                        + a_tree * ACT_TREE
                        + a_acc * ACT_TREE
                        + (a_act_buf + a_wbuf) * ACT_FF * 0.5
                        + A_CTRL * ACT_CTRL,
                );
                PeModel { kind, group_size, area_ge: area, pj_per_cycle: e }
            }
            PeKind::SingleShift => {
                // G 8b AND masks + G 9b sign inverters + 9..12b adder tree
                // + barrel shifter + accumulator + mask/shift regs
                let tree_bits = 9.0 + log2ceil(group_size);
                let a_and = g * 8.0 * A_AND;
                let a_sign = g * 9.0 * A_MUX;
                let a_tree = (g - 1.0).max(0.0) * tree_bits * A_FA;
                let a_shift = (tree_bits + 7.0) * 3.0 * A_MUX; // 8-way barrel
                let a_acc = ACC_BITS * (A_FA + A_FF);
                // weight-side regs: G mask bits x2 planes + 3b shift value
                let a_wbuf = (g * 2.0 + 3.0) * A_FF * 2.0;
                let area = a_and + a_sign + a_tree + a_shift + a_acc + a_act_buf + a_wbuf + A_CTRL;
                let e = ge_to_pj(
                    a_and * ACT_AND
                        + a_sign * ACT_MUX
                        + a_tree * ACT_TREE
                        + a_shift * ACT_MUX
                        + a_acc * ACT_TREE
                        + (a_act_buf * 0.25 + a_wbuf) * ACT_FF // act regs mostly held
                        + A_CTRL * ACT_CTRL,
                );
                PeModel { kind, group_size, area_ge: area, pj_per_cycle: e }
            }
            PeKind::DoubleShift => {
                // two mask+tree+shifter lanes sharing act buffer, sign
                // stage and accumulator (+ a combining adder)
                let tree_bits = 9.0 + log2ceil(group_size);
                let a_and = 2.0 * g * 8.0 * A_AND;
                let a_sign = g * 9.0 * A_MUX;
                let a_tree = 2.0 * (g - 1.0).max(0.0) * tree_bits * A_FA;
                let a_shift = 2.0 * (tree_bits + 7.0) * 3.0 * A_MUX;
                let a_comb = (tree_bits + 8.0) * A_FA;
                let a_acc = ACC_BITS * (A_FA + A_FF);
                let a_wbuf = (2.0 * g * 2.0 + 6.0) * A_FF * 2.0;
                let area = a_and + a_sign + a_tree + a_shift + a_comb + a_acc + a_act_buf
                    + a_wbuf
                    + A_CTRL
                    + A_CTRL_DS;
                let e = ge_to_pj(
                    a_and * ACT_AND
                        + a_sign * ACT_MUX
                        + a_tree * ACT_TREE
                        + a_shift * ACT_MUX
                        + (a_comb + a_acc) * ACT_TREE
                        + (a_act_buf * 0.25 + a_wbuf) * ACT_FF
                        + (A_CTRL + A_CTRL_DS) * ACT_CTRL,
                );
                PeModel { kind, group_size, area_ge: area, pj_per_cycle: e }
            }
        }
    }

    /// Cycles for one group-op at `n_shifts` shift planes.
    pub fn cycles_per_group_op(&self, n_shifts: f64) -> f64 {
        match self.kind {
            PeKind::Fixed => 1.0,
            PeKind::SingleShift => n_shifts.max(1.0),
            PeKind::DoubleShift => (n_shifts / 2.0).ceil().max(1.0),
        }
    }

    /// MACs per cycle.
    pub fn throughput(&self, n_shifts: f64) -> f64 {
        self.group_size as f64 / self.cycles_per_group_op(n_shifts)
    }

    /// Energy per MAC (pJ) at a given shift count.
    pub fn pj_per_mac(&self, n_shifts: f64) -> f64 {
        self.pj_per_cycle * self.cycles_per_group_op(n_shifts) / self.group_size as f64
    }

    /// Throughput per area (MACs/cycle/GE) — Fig. 3(c)'s metric.
    pub fn throughput_per_area(&self, n_shifts: f64) -> f64 {
        self.throughput(n_shifts) / self.area_ge
    }
}

/// Fig. 3 row: metrics normalized to the fixed-point PE of the same
/// group size.
#[derive(Clone, Copy, Debug)]
pub struct NormalizedPe {
    pub group_size: usize,
    pub n_shifts: usize,
    pub area: f64,
    pub energy_per_mac: f64,
    pub throughput_per_area: f64,
}

pub fn normalized(kind: PeKind, group_size: usize, n_shifts: usize) -> NormalizedPe {
    let fx = PeModel::new(PeKind::Fixed, group_size);
    let pe = PeModel::new(kind, group_size);
    let n = n_shifts as f64;
    NormalizedPe {
        group_size,
        n_shifts,
        area: pe.area_ge / fx.area_ge,
        energy_per_mac: pe.pj_per_mac(n) / fx.pj_per_mac(1.0),
        throughput_per_area: pe.throughput_per_area(n) / fx.throughput_per_area(1.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_pe_smaller_than_fixed() {
        for g in [2, 4, 8, 16] {
            let n = normalized(PeKind::SingleShift, g, 2);
            assert!(n.area < 1.0, "SS area ratio {} at G={g}", n.area);
        }
    }

    #[test]
    fn area_ratio_shrinks_with_group_size() {
        // buffers amortize: serial PE relative area falls as G grows
        let a2 = normalized(PeKind::SingleShift, 2, 2).area;
        let a16 = normalized(PeKind::SingleShift, 16, 2).area;
        assert!(a16 < a2, "a16={a16} a2={a2}");
    }

    #[test]
    fn single_shift_crossover_at_4_shifts() {
        // the paper's headline Fig. 3 observation: SS wins on energy and
        // T/A only below 4 shifts (at reasonable group sizes)
        for g in [8, 16] {
            let e2 = normalized(PeKind::SingleShift, g, 2);
            let e4 = normalized(PeKind::SingleShift, g, 4);
            let e6 = normalized(PeKind::SingleShift, g, 6);
            assert!(e2.energy_per_mac < 1.0, "G={g} e2={}", e2.energy_per_mac);
            assert!(e2.throughput_per_area > 1.0, "G={g} t2={}", e2.throughput_per_area);
            assert!(e6.energy_per_mac > 1.0, "G={g} e6={}", e6.energy_per_mac);
            assert!(e6.throughput_per_area < 1.0, "G={g} t6={}", e6.throughput_per_area);
            // 4 shifts sits near break-even
            assert!(e4.energy_per_mac > 0.7 && e4.energy_per_mac < 1.4,
                "G={g} e4={}", e4.energy_per_mac);
        }
    }

    #[test]
    fn small_groups_are_not_worth_it() {
        // below group size 8, gains are modest at best (Sec. 3.1)
        let n = normalized(PeKind::SingleShift, 2, 2);
        assert!(n.throughput_per_area < 1.25, "t/a {} at G=2", n.throughput_per_area);
    }

    #[test]
    fn double_shift_dominates_single_at_double_group() {
        // DS at G has lower normalized E/MAC and higher T/A than SS at 2G
        for (g_ds, g_ss) in [(4, 8), (8, 16)] {
            for s in [2usize, 4, 6] {
                let ds = normalized(PeKind::DoubleShift, g_ds, s);
                let ss = normalized(PeKind::SingleShift, g_ss, s);
                assert!(
                    ds.energy_per_mac < ss.energy_per_mac * 1.05,
                    "DS(G={g_ds}) {} vs SS(G={g_ss}) {} at {s} shifts",
                    ds.energy_per_mac,
                    ss.energy_per_mac
                );
            }
        }
    }

    #[test]
    fn double_shift_halves_cycles() {
        let ds = PeModel::new(PeKind::DoubleShift, 4);
        assert_eq!(ds.cycles_per_group_op(4.0), 2.0);
        assert_eq!(ds.cycles_per_group_op(3.0), 2.0); // odd N underutilizes
        assert_eq!(ds.cycles_per_group_op(2.0), 1.0);
        let ss = PeModel::new(PeKind::SingleShift, 4);
        assert_eq!(ss.cycles_per_group_op(3.0), 3.0);
    }

    #[test]
    fn fixed_point_energy_scale_sane() {
        // an 8-bit MAC should land in the right pJ ballpark (0.1-1 pJ)
        let fx = PeModel::new(PeKind::Fixed, 4);
        let pj = fx.pj_per_mac(1.0);
        assert!(pj > 0.05 && pj < 1.5, "fx pj/mac = {pj}");
    }
}
