//! Weight-storage compression models (paper Sec. 3.3, Fig. 5).
//!
//! * SWIS: per group — 1 sign bit/weight, N mask bits/weight, 3 bits per
//!   shift value per group.
//! * SWIS-C: same masks/signs, but a single 3-bit offset per group.
//! * DPRed [3]: lossless per-group bitwidth — each group stores its
//!   weights at the width of its largest magnitude (+ sign), plus a 3-bit
//!   per-group width tag. Profiled over actual weight data.
//! * Weight truncation: N magnitude bits + sign per weight (layer-wide).

use crate::quant::int8::Int8Layer;

/// Bits per weight for SWIS with group size `g` and `n` shifts.
pub fn swis_bits_per_weight(g: usize, n: usize) -> f64 {
    1.0 + n as f64 + 3.0 * n as f64 / g as f64
}

/// Bits per weight for SWIS-C (single 3-bit offset per group).
pub fn swis_c_bits_per_weight(g: usize, n: usize) -> f64 {
    1.0 + n as f64 + 3.0 / g as f64
}

/// Bits per weight for layer-wise weight truncation to `n` bits.
pub fn trunc_bits_per_weight(n: usize) -> f64 {
    1.0 + n as f64
}

/// Compression ratio vs the 8-bit baseline.
pub fn ratio(bits_per_weight: f64) -> f64 {
    8.0 / bits_per_weight
}

/// DPRed bits/weight profiled over a weight tensor: per group of `g`,
/// width = bits of the largest magnitude in the group; storage = sign +
/// width per weight + 3-bit width tag per group.
pub fn dpred_bits_per_weight(w: &[f64], g: usize) -> f64 {
    let q = Int8Layer::from_f64(w);
    let mut total_bits = 0u64;
    let mut n_weights = 0u64;
    for chunk in q.mags.chunks(g) {
        let max_mag = chunk.iter().copied().max().unwrap_or(0) as u32;
        let width = if max_mag == 0 {
            1
        } else {
            32 - max_mag.leading_zeros()
        } as u64;
        total_bits += chunk.len() as u64 * (width + 1) + 3;
        n_weights += chunk.len() as u64;
    }
    total_bits as f64 / n_weights as f64
}

/// Fig. 5 series: compression ratios for a sweep of shifts and group
/// sizes, DPRed profiled on the supplied example layer.
pub struct CompressionRow {
    pub group_size: usize,
    pub n_shifts: usize,
    pub swis: f64,
    pub swis_c: f64,
    pub dpred: f64,
}

pub fn fig5_rows(example_layer: &[f64], groups: &[usize], shifts: &[usize]) -> Vec<CompressionRow> {
    let mut out = Vec::new();
    for &g in groups {
        let dp = ratio(dpred_bits_per_weight(example_layer, g));
        for &n in shifts {
            out.push(CompressionRow {
                group_size: g,
                n_shifts: n,
                swis: ratio(swis_bits_per_weight(g, n)),
                swis_c: ratio(swis_c_bits_per_weight(g, n)),
                dpred: dp,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn paper_quoted_group4_range() {
        // paper Sec. 3.3: for group 4, SWIS 1.1-2.9x, SWIS-C 1.5-2.9x
        let lo_s = ratio(swis_bits_per_weight(4, 4));
        let hi_s = ratio(swis_bits_per_weight(4, 1));
        assert!((0.95..=1.25).contains(&lo_s), "swis low {lo_s}");
        assert!((2.7..=3.1).contains(&hi_s), "swis high {hi_s}");
        let lo_c = ratio(swis_c_bits_per_weight(4, 4));
        let hi_c = ratio(swis_c_bits_per_weight(4, 1));
        assert!((1.3..=1.6).contains(&lo_c), "swis-c low {lo_c}");
        assert!((2.7..=3.1).contains(&hi_c), "swis-c high {hi_c}");
    }

    #[test]
    fn max_compression_near_3_7x() {
        // large groups + 1 shift: the paper's 3.7x headline
        let r = ratio(swis_bits_per_weight(16, 1));
        assert!((3.4..=3.8).contains(&r), "r={r}");
    }

    #[test]
    fn swis_c_never_below_swis() {
        for g in [2, 4, 8, 16] {
            for n in 1..=6 {
                assert!(
                    swis_c_bits_per_weight(g, n) <= swis_bits_per_weight(g, n),
                    "g={g} n={n}"
                );
            }
        }
    }

    #[test]
    fn dpred_lossless_but_weak_at_8bit() {
        // near-Gaussian weights: most groups have a large max -> little
        // width reduction, exactly the paper's observation
        let mut rng = Rng::new(17);
        let w: Vec<f64> = (0..4096).map(|_| rng.normal_ms(0.0, 0.08)).collect();
        let bits = dpred_bits_per_weight(&w, 4);
        let r = ratio(bits);
        assert!(r < 2.0, "DPRed ratio should be modest, got {r}");
        assert!(r > 1.0, "DPRed should still compress, got {r}");
    }

    #[test]
    fn dpred_degrades_with_group_size() {
        let mut rng = Rng::new(18);
        let w: Vec<f64> = (0..4096).map(|_| rng.normal_ms(0.0, 0.08)).collect();
        let r4 = ratio(dpred_bits_per_weight(&w, 4));
        let r16 = ratio(dpred_bits_per_weight(&w, 16));
        assert!(r16 <= r4, "larger groups hit worst-case width: {r16} vs {r4}");
    }

    #[test]
    fn zero_group_width_one() {
        let w = vec![0.0; 8];
        let bits = dpred_bits_per_weight(&w, 4);
        // width 1 + sign + tag 3/4
        assert!((bits - (2.0 + 0.75)).abs() < 1e-12);
    }
}
