//! Functional (bit-accurate) model of the SWIS processing element
//! (paper Fig. 4a): the datapath that the cost model in [`super::pe`]
//! prices. Executes Eq. 7 the way the hardware does — one (or two) shift
//! planes per cycle through mask-AND, conditional sign inversion, an
//! adder tree, a barrel shifter and a serial accumulator — and must
//! agree exactly with the packed format's dequantized dot product.
//!
//! This is the cross-check between the *storage* contract
//! ([`crate::quant::PackedLayer`]) and the *compute* contract (the
//! systolic array in [`crate::sim::functional`]): if either side
//! mis-lays-out masks or shifts, these tests catch it.
//!
//! The arithmetic of a shift plane lives in [`crate::exec::core`] — the
//! one definition shared with the functional simulator and the native
//! serving kernel; this type adds the PE's *timing* (single- vs
//! double-shift cycles) and accumulator-width modeling on top.

use crate::exec::core;
use crate::quant::PackedLayer;

/// One group-MAC datapath. `group_size` parallel lanes; `double_shift`
/// processes two shift planes per cycle (paper Sec. 3.1).
#[derive(Clone, Debug)]
pub struct FunctionalPe {
    pub group_size: usize,
    pub double_shift: bool,
    /// Output-stationary accumulator (24-bit in hardware; i64 here with a
    /// width check).
    acc: i64,
    pub cycles: u64,
}

/// Accumulator width the cost model provisions (paper-matched).
pub const ACC_WIDTH_BITS: u32 = 24;

impl FunctionalPe {
    pub fn new(group_size: usize, double_shift: bool) -> FunctionalPe {
        FunctionalPe { group_size, double_shift, acc: 0, cycles: 0 }
    }

    pub fn reset(&mut self) {
        self.acc = 0;
        self.cycles = 0;
    }

    pub fn accumulator(&self) -> i64 {
        self.acc
    }

    /// Process ONE shift plane: mask-AND, sign invert and adder tree
    /// (the shared [`core::plane_partial`] semantics), then the barrel
    /// shift and serial accumulate with the width check.
    fn shift_cycle(&mut self, layer: &PackedLayer, g: usize, j: usize, acts: &[i32], shift: u8) {
        debug_assert_eq!(acts.len(), self.group_size);
        let tree = core::plane_partial(layer, g, j, acts);
        self.acc += tree << shift;
        debug_assert!(
            self.acc.unsigned_abs() < 1 << (ACC_WIDTH_BITS + 8),
            "accumulator overflow: {}",
            self.acc
        );
    }

    /// Execute a full group-op against packed group `g` of `layer`,
    /// returning the integer MAC result. Cycle count follows the PE
    /// flavor: N for single-shift, ceil(N/2) for double-shift.
    pub fn group_op(&mut self, layer: &PackedLayer, g: usize, acts: &[i32]) -> i64 {
        let n = layer.active_shifts(g);
        debug_assert_eq!(layer.group_size, self.group_size);
        let shifts = &layer.shifts[g * layer.n_shifts..g * layer.n_shifts + n];
        let start = self.acc;
        let mut j = 0;
        while j < n {
            // one or two planes per cycle, depending on the PE flavor
            let planes = if self.double_shift && j + 1 < n { 2 } else { 1 };
            for p in 0..planes {
                self.shift_cycle(layer, g, j + p, acts, shifts[j + p]);
            }
            self.cycles += 1;
            j += planes;
        }
        self.acc - start
    }
}

/// Reference: the integer dot product the packed group implies,
/// sum_i act_i * sign_i * mag_i — deliberately lane-major over
/// [`PackedLayer::mag`], independent of the plane-major execution path
/// in [`core`].
pub fn group_dot_reference(layer: &PackedLayer, g: usize, acts: &[i32]) -> i64 {
    let gs = layer.group_size;
    (0..gs)
        .map(|i| {
            let m = layer.mag(g, i);
            let s = layer.signs[g * gs + i] as i64;
            acts[i] as i64 * s * m
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize, QuantConfig};
    use crate::util::rng::Rng;

    fn packed(seed: u64, n: usize, g: usize, consecutive: bool) -> PackedLayer {
        let mut rng = Rng::new(seed);
        let w = rng.normal_vec(16 * 32, 0.0, 0.07);
        let cfg = QuantConfig { n_shifts: n, group_size: g, alpha: crate::quant::Alpha::ONE, consecutive };
        quantize(&w, &[16, 32], &cfg).unwrap()
    }

    #[test]
    fn single_shift_matches_reference() {
        let p = packed(1, 3, 4, false);
        let mut pe = FunctionalPe::new(4, false);
        let mut rng = Rng::new(2);
        for g in 0..p.n_groups() {
            let acts: Vec<i32> = (0..4).map(|_| rng.range_u64(0, 255) as i32).collect();
            pe.reset();
            let got = pe.group_op(&p, g, &acts);
            assert_eq!(got, group_dot_reference(&p, g, &acts), "group {g}");
            assert_eq!(pe.cycles, 3);
        }
    }

    #[test]
    fn double_shift_matches_reference_at_half_cycles() {
        for n in [2usize, 3, 4, 5] {
            let p = packed(3 + n as u64, n, 4, false);
            let mut pe = FunctionalPe::new(4, true);
            let mut rng = Rng::new(5);
            let acts: Vec<i32> = (0..4).map(|_| rng.range_u64(0, 255) as i32).collect();
            for g in [0usize, 7, p.n_groups() - 1] {
                pe.reset();
                let got = pe.group_op(&p, g, &acts);
                assert_eq!(got, group_dot_reference(&p, g, &acts));
                assert_eq!(pe.cycles as usize, n.div_ceil(2), "N={n}");
            }
        }
    }

    #[test]
    fn swis_c_packed_runs_identically() {
        let p = packed(11, 3, 4, true);
        let mut pe = FunctionalPe::new(4, false);
        let acts = vec![100, -5, 17, 63];
        for g in 0..p.n_groups() {
            pe.reset();
            assert_eq!(pe.group_op(&p, g, &acts), group_dot_reference(&p, g, &acts));
        }
    }

    #[test]
    fn accumulates_across_group_ops() {
        // output-stationary: multiple group-ops accumulate one output
        let p = packed(13, 2, 4, false);
        let mut pe = FunctionalPe::new(4, false);
        let acts = vec![10, 20, 30, 40];
        let mut expect = 0i64;
        for g in 0..4 {
            pe.group_op(&p, g, &acts);
            expect += group_dot_reference(&p, g, &acts);
        }
        assert_eq!(pe.accumulator(), expect);
        assert_eq!(pe.cycles, 8);
    }

    #[test]
    fn scheduled_layer_heterogeneous_shift_counts() {
        // filters packed by the scheduler carry different active shift
        // counts; the PE must honor per-group counts, not n_shifts.
        let mut rng = Rng::new(17);
        let w = rng.normal_vec(16 * 16, 0.0, 0.05);
        let p = crate::schedule::quantize_or_schedule(&w, &[16, 16], 2.5, 4, false, crate::quant::Alpha::ONE)
            .unwrap();
        let mut pe = FunctionalPe::new(4, false);
        let acts = vec![1, 2, 3, 4];
        let mut seen_cycles = std::collections::BTreeSet::new();
        for g in 0..p.n_groups() {
            pe.reset();
            assert_eq!(pe.group_op(&p, g, &acts), group_dot_reference(&p, g, &acts));
            seen_cycles.insert(pe.cycles);
        }
        assert!(seen_cycles.len() >= 2, "expected mixed shift counts, got {seen_cycles:?}");
    }

    #[test]
    fn property_random_activations_and_configs() {
        crate::util::check::props(200, |rng| {
            let n = 1 + (rng.below(5) as usize);
            let g = [1usize, 2, 4, 8][rng.below(4) as usize];
            let consecutive = rng.bool(0.5);
            let w = rng.normal_vec(8 * 16, 0.0, 0.06);
            let cfg = QuantConfig { n_shifts: n, group_size: g, alpha: crate::quant::Alpha::ONE, consecutive };
            let p = quantize(&w, &[8, 16], &cfg).map_err(|e| e.to_string())?;
            let gi = rng.below(p.n_groups() as u64) as usize;
            let acts: Vec<i32> = (0..g).map(|_| rng.range_u64(0, 255) as i32 - 128).collect();
            let mut pe = FunctionalPe::new(g, rng.bool(0.5));
            let got = pe.group_op(&p, gi, &acts);
            let want = group_dot_reference(&p, gi, &acts);
            if got != want {
                return Err(format!("PE {got} != ref {want} (N={n} G={g})"));
            }
            Ok(())
        });
    }
}
