//! Across-layer shift allocation — one granularity up from the paper's
//! within-layer filter scheduling (Sec. 4.3): not all LAYERS are equally
//! sensitive either, so a network-wide shift budget is distributed over
//! layers by the same greedy-demotion principle, weighted by layer size
//! (the effective-shifts reporting convention averages over weights).
//! Each layer then runs the within-layer scheduler at its assigned
//! budget, so the two granularities compose.

use anyhow::{bail, Result};

use super::{schedule_layer, ScheduleConfig, ScheduledLayer};
use crate::quant::metrics::Alpha;
use crate::quant::planner;
use crate::quant::swis::group_mags;

/// One layer's weights, filters-first.
pub struct LayerWeights<'a> {
    pub name: String,
    pub w: &'a [f64],
    pub shape: [usize; 2],
}

/// Result of a network-level allocation.
#[derive(Clone, Debug)]
pub struct NetworkAllocation {
    /// Integer shift budget per layer.
    pub layer_shifts: Vec<usize>,
    /// Weight-weighted average (== the requested target up to rounding).
    pub effective_shifts: f64,
    /// Total float-domain MSE++ of the allocation vs uniform-at-ceil.
    pub err_allocated: f64,
    pub err_uniform: f64,
}

/// Distribute a weight-weighted average shift budget across layers.
///
/// Greedy: start every layer at ceil(target)+1, repeatedly demote the
/// layer whose next demotion costs the least MSE++ *per weight removed*,
/// until the weighted average reaches the target.
pub fn allocate_network(
    layers: &[LayerWeights],
    target: f64,
    group_size: usize,
    consecutive: bool,
    alpha: Alpha,
) -> Result<NetworkAllocation> {
    if layers.is_empty() {
        bail!("no layers");
    }
    if !(1.0..=8.0).contains(&target) {
        bail!("target {target} out of [1, 8]");
    }
    let hi = ((target.ceil() as usize) + 1).min(8);

    // Per-layer cost at each shift count (sum over filters, uniform).
    // Integer MSE++ lives in each layer's own magnitude domain; scale^2
    // converts it to the shared float-weight domain so costs are
    // comparable ACROSS layers (a layer of tiny weights contributes
    // proportionally tiny reconstruction error).
    let mut costs = Vec::with_capacity(layers.len()); // [layer][n-1], f64
    let mut sizes = Vec::with_capacity(layers.len());
    for l in layers {
        let gm = group_mags(l.w, &l.shape, group_size)?;
        let s2 = gm.scale * gm.scale;
        // one planner sweep per layer yields every shift count at once
        let table = planner::cost_table(&gm, hi, consecutive, alpha);
        let per_n: Vec<f64> = table
            .iter()
            .map(|row| row.iter().sum::<i64>() as f64 * s2)
            .collect();
        costs.push(per_n);
        sizes.push(l.w.len() as i64);
    }
    let total_weights: i64 = sizes.iter().sum();
    let target_budget = (target * total_weights as f64).round() as i64;

    let mut shifts = vec![hi; layers.len()];
    let mut budget: i64 = sizes.iter().map(|&s| s * hi as i64).sum();
    while budget > target_budget {
        // cheapest demotion per weight removed
        let mut best: Option<(f64, usize)> = None;
        for (li, &n) in shifts.iter().enumerate() {
            if n <= 1 {
                continue;
            }
            let d_cost = costs[li][n - 2] - costs[li][n - 1];
            let rate = d_cost / sizes[li] as f64;
            if best.map_or(true, |(r, _)| rate < r) {
                best = Some((rate, li));
            }
        }
        let Some((_, li)) = best else { break };
        // don't overshoot the budget: a big layer's demotion may cross it;
        // allow it only if it brings us closer to the target
        let after = budget - sizes[li];
        if (after - target_budget).abs() > (budget - target_budget).abs() {
            break;
        }
        shifts[li] -= 1;
        budget = after;
    }

    let err_allocated: f64 = shifts.iter().zip(&costs).map(|(&n, c)| c[n - 1]).sum();
    let ceil_n = (target.ceil() as usize).clamp(1, hi);
    let err_uniform: f64 = costs.iter().map(|c| c[ceil_n - 1]).sum();
    Ok(NetworkAllocation {
        effective_shifts: budget as f64 / total_weights as f64,
        layer_shifts: shifts,
        err_allocated,
        err_uniform,
    })
}

/// Allocate, then run the within-layer scheduler per layer at its budget.
pub fn schedule_network(
    layers: &[LayerWeights],
    target: f64,
    group_size: usize,
    consecutive: bool,
    alpha: Alpha,
    sa_cols: usize,
) -> Result<(NetworkAllocation, Vec<ScheduledLayer>)> {
    let alloc = allocate_network(layers, target, group_size, consecutive, alpha)?;
    let scheduled = layers
        .iter()
        .zip(&alloc.layer_shifts)
        .map(|(l, &n)| {
            let mut cfg = ScheduleConfig::new(n as f64, group_size);
            cfg.consecutive = consecutive;
            cfg.alpha = alpha;
            cfg.sa_cols = sa_cols;
            schedule_layer(l.w, &l.shape, &cfg)
        })
        .collect::<Result<Vec<_>>>()?;
    Ok((alloc, scheduled))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn layers(seeds: &[(u64, f64)]) -> Vec<(Vec<f64>, [usize; 2])> {
        // layers with different sigmas -> different sensitivity
        seeds
            .iter()
            .map(|&(seed, sigma)| {
                let mut rng = Rng::new(seed);
                (rng.normal_vec(16 * 32, 0.0, sigma), [16usize, 32usize])
            })
            .collect()
    }

    fn views(ls: &[(Vec<f64>, [usize; 2])]) -> Vec<LayerWeights<'_>> {
        ls.iter()
            .enumerate()
            .map(|(i, (w, shape))| LayerWeights { name: format!("l{i}"), w, shape: *shape })
            .collect()
    }

    #[test]
    fn hits_weighted_target() {
        let ls = layers(&[(1, 0.02), (2, 0.05), (3, 0.10), (4, 0.03)]);
        let v = views(&ls);
        let a = allocate_network(&v, 3.0, 4, false, Alpha::ONE).unwrap();
        assert!((a.effective_shifts - 3.0).abs() < 0.3, "{}", a.effective_shifts);
        assert_eq!(a.layer_shifts.len(), 4);
    }

    #[test]
    fn allocation_no_worse_than_uniform() {
        let ls = layers(&[(5, 0.02), (6, 0.08), (7, 0.04)]);
        let v = views(&ls);
        let a = allocate_network(&v, 3.0, 4, false, Alpha::ONE).unwrap();
        assert!(
            a.err_allocated <= a.err_uniform,
            "allocated {} > uniform {}",
            a.err_allocated,
            a.err_uniform
        );
    }

    #[test]
    fn heterogeneous_layers_get_heterogeneous_budgets() {
        // a much-harder layer (wide sigma) should keep more shifts than an
        // easy one at a tight budget
        let ls = layers(&[(8, 0.005), (9, 0.15)]);
        let v = views(&ls);
        let a = allocate_network(&v, 2.5, 4, false, Alpha::ONE).unwrap();
        assert!(
            a.layer_shifts[1] >= a.layer_shifts[0],
            "hard layer got fewer shifts: {:?}",
            a.layer_shifts
        );
    }

    #[test]
    fn composes_with_filter_scheduler() {
        let ls = layers(&[(10, 0.02), (11, 0.06)]);
        let v = views(&ls);
        let (alloc, scheduled) = schedule_network(&v, 3.0, 4, false, Alpha::ONE, 8).unwrap();
        assert_eq!(scheduled.len(), 2);
        for (s, &n) in scheduled.iter().zip(&alloc.layer_shifts) {
            let avg = s.filter_shifts.iter().sum::<usize>() as f64 / s.filter_shifts.len() as f64;
            assert!((avg - n as f64).abs() < 1e-9);
            s.packed.validate().unwrap();
        }
    }

    #[test]
    fn rejects_bad_input() {
        assert!(allocate_network(&[], 3.0, 4, false, Alpha::ONE).is_err());
        let ls = layers(&[(1, 0.02)]);
        let v = views(&ls);
        assert!(allocate_network(&v, 0.5, 4, false, Alpha::ONE).is_err());
    }
}
