//! Phase-2 enumeration: non-decreasing shift assignments over systolic
//! array column blocks that hit the layer's total shift budget exactly
//! (paper Sec. 4.3 — co-scheduled filters must share a shift count).

/// Enumerate non-decreasing sequences `n_b` (one per block, weighted by
/// `block_sizes[b]`) with values in [lo, hi] and
/// `sum_b n_b * block_sizes[b] == target_total`. Pruned recursion — block
/// counts are small (K / sa_cols, typically <= 64).
pub fn nondecreasing_sequences(
    block_sizes: &[usize],
    lo: usize,
    hi: usize,
    target_total: i64,
) -> Vec<Vec<usize>> {
    let vals: Vec<usize> = (lo..=hi).collect();
    nondecreasing_sequences_vals(block_sizes, &vals, target_total)
}

/// The general form: per-block values drawn from an ascending `vals` set.
/// The double-shift PE restricts filters to even shift counts (odd counts
/// waste a cycle, Sec. 3.1), which callers express as `vals = [2,4,6,8]`.
pub fn nondecreasing_sequences_vals(
    block_sizes: &[usize],
    vals: &[usize],
    target_total: i64,
) -> Vec<Vec<usize>> {
    let n_blocks = block_sizes.len();
    let mut out = Vec::new();
    if vals.is_empty() || n_blocks == 0 {
        return out;
    }
    debug_assert!(vals.windows(2).all(|w| w[0] < w[1]), "vals must ascend");
    let hi = *vals.last().unwrap();
    let mut cur = Vec::with_capacity(n_blocks);
    // suffix weight sums for pruning
    let mut suffix: Vec<i64> = vec![0; n_blocks + 1];
    for b in (0..n_blocks).rev() {
        suffix[b] = suffix[b + 1] + block_sizes[b] as i64;
    }
    #[allow(clippy::too_many_arguments)]
    fn rec(
        out: &mut Vec<Vec<usize>>,
        cur: &mut Vec<usize>,
        b: usize,
        min_vi: usize,
        tot: i64,
        block_sizes: &[usize],
        suffix: &[i64],
        vals: &[usize],
        hi: usize,
        target: i64,
    ) {
        let n_blocks = block_sizes.len();
        if b == n_blocks {
            if tot == target {
                out.push(cur.clone());
            }
            return;
        }
        for vi in min_vi..vals.len() {
            let n = vals[vi];
            let nt = tot + (n * block_sizes[b]) as i64;
            let rest = suffix[b + 1];
            // prune: remaining blocks are >= n (non-decreasing) and <= hi
            if nt + rest * (n as i64) > target {
                break; // n only grows from here
            }
            if nt + rest * (hi as i64) < target {
                continue;
            }
            cur.push(n);
            rec(out, cur, b + 1, vi, nt, block_sizes, suffix, vals, hi, target);
            cur.pop();
        }
    }
    rec(
        &mut out, &mut cur, 0, 0, 0, block_sizes, &suffix, vals, hi, target_total,
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_budget_uniform() {
        // 2 blocks of 8 filters, target 2.5 avg -> total 40
        let seqs = nondecreasing_sequences(&[8, 8], 1, 4, 40);
        assert!(!seqs.is_empty());
        for s in &seqs {
            assert!(s.windows(2).all(|w| w[0] <= w[1]));
            let tot: usize = s.iter().zip([8, 8]).map(|(n, w)| n * w).sum();
            assert_eq!(tot, 40);
        }
        // (2,3) must be among them
        assert!(seqs.contains(&vec![2, 3]));
    }

    #[test]
    fn integral_target_includes_uniform() {
        let seqs = nondecreasing_sequences(&[8, 8, 8, 8], 1, 5, 3 * 32);
        assert!(seqs.contains(&vec![3, 3, 3, 3]));
        // and mixed assignments like (2,3,3,4) — total 2*8+3*8+3*8+4*8 = 96
        assert!(seqs.contains(&vec![2, 3, 3, 4]));
    }

    #[test]
    fn ragged_tail_block() {
        // 12 filters in blocks of 8 + 4, avg 2 -> total 24
        let seqs = nondecreasing_sequences(&[8, 4], 1, 4, 24);
        for s in &seqs {
            assert_eq!(s[0] * 8 + s[1] * 4, 24);
            assert!(s[0] <= s[1]);
        }
        assert!(seqs.contains(&vec![2, 2]));
        assert!(seqs.contains(&vec![1, 4]));
    }

    #[test]
    fn impossible_budget_is_empty() {
        assert!(nondecreasing_sequences(&[8], 1, 2, 100).is_empty());
    }

    #[test]
    fn even_only_values_for_double_shift() {
        // 2 blocks of 8 filters, avg 3 -> total 48, DS values {2,4,6,8}:
        // only (2,4) hits it
        let seqs = nondecreasing_sequences_vals(&[8, 8], &[2, 4, 6, 8], 48);
        assert_eq!(seqs, vec![vec![2, 4]]);
        // avg 2.5 -> total 40: no even-only combo over equal halves
        assert!(nondecreasing_sequences_vals(&[8, 8], &[2, 4, 6, 8], 40).is_empty());
        // but 4 blocks of 4 can do 2,2,2,4 (total 40)
        let seqs = nondecreasing_sequences_vals(&[4, 4, 4, 4], &[2, 4, 6, 8], 40);
        assert!(seqs.contains(&vec![2, 2, 2, 4]));
    }
}
