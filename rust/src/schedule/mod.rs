//! Filter scheduling (paper Sec. 4.3): distribute a fractional layer-level
//! shift budget across filters so accuracy-insensitive filters give up
//! shifts to sensitive ones, then snap the assignment to systolic-array
//! column groups so co-scheduled filters share a shift count.

mod assignment;
pub mod network;
pub use assignment::{nondecreasing_sequences, nondecreasing_sequences_vals};
pub use network::{allocate_network, schedule_network, LayerWeights, NetworkAllocation};

use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::quant::metrics::Alpha;
use crate::quant::planner;
use crate::quant::swis::{group_mags, select_groups, GroupedMags, QuantConfig};
use crate::quant::int8::BITS;
use crate::quant::PackedLayer;

/// Scheduling configuration.
#[derive(Clone, Copy, Debug)]
pub struct ScheduleConfig {
    /// Target average number of shifts across the layer (may be
    /// fractional, e.g. 2.5 — the point of scheduling).
    pub target_shifts: f64,
    pub group_size: usize,
    pub alpha: Alpha,
    pub consecutive: bool,
    /// Filters co-scheduled per systolic-array column block.
    pub sa_cols: usize,
    /// Upper bound on per-filter shifts (defaults to 8).
    pub max_shifts: usize,
    /// Per-filter shift counts must be multiples of this (1 for
    /// single-shift PEs; 2 for double-shift, whose odd counts waste a
    /// cycle — Sec. 3.1).
    pub shift_step: usize,
}

impl ScheduleConfig {
    pub fn new(target_shifts: f64, group_size: usize) -> Self {
        ScheduleConfig {
            target_shifts,
            group_size,
            alpha: Alpha::ONE,
            consecutive: false,
            sa_cols: 8,
            max_shifts: BITS as usize,
            shift_step: 1,
        }
    }

    /// Double-shift variant: filters restricted to even shift counts.
    pub fn double_shift(mut self) -> Self {
        self.shift_step = 2;
        self
    }
}

/// Result of scheduling a layer.
#[derive(Clone, Debug)]
pub struct ScheduledLayer {
    /// Shifts assigned to each filter (post phase 2).
    pub filter_shifts: Vec<usize>,
    /// The layer packed with heterogeneous per-filter shift counts.
    pub packed: PackedLayer,
    /// Total integer MSE++ of the scheduled assignment.
    pub err_scheduled: i64,
    /// Total integer MSE++ of uniform quantization at ceil(target).
    pub err_uniform: i64,
}

/// Per-filter cost table: cost[n-1][f] = integer MSE++ of filter f at n
/// shifts, for n in 1..=max_n. Shared by both phases. One planner sweep
/// computes every shift count at once (previously `max_n` independent
/// rescans with freshly built LUTs each).
fn cost_table(
    gm: &GroupedMags,
    max_n: usize,
    consecutive: bool,
    alpha: Alpha,
) -> Vec<Vec<i64>> {
    planner::cost_table(gm, max_n, consecutive, alpha)
}

/// Schedule a filters-first weight tensor (paper Sec. 4.3, both phases).
pub fn schedule_layer(w: &[f64], shape: &[usize], cfg: &ScheduleConfig) -> Result<ScheduledLayer> {
    if cfg.target_shifts < 1.0 || cfg.target_shifts > cfg.max_shifts as f64 {
        bail!("target_shifts {} out of range", cfg.target_shifts);
    }
    if cfg.max_shifts > BITS as usize || cfg.max_shifts == 0 {
        bail!("max_shifts {} out of [1, {}]", cfg.max_shifts, BITS);
    }
    if cfg.shift_step.max(1) > cfg.max_shifts {
        bail!(
            "shift_step {} exceeds max_shifts {}",
            cfg.shift_step,
            cfg.max_shifts
        );
    }
    let gm = group_mags(w, shape, cfg.group_size)?;
    let k = gm.n_filters;
    let step = cfg.shift_step.max(1);
    // align the starting ceiling up to a step multiple
    let hi = ((cfg.target_shifts.ceil() as usize + 1).div_ceil(step) * step).min(cfg.max_shifts / step * step);
    let costs = cost_table(&gm, hi, cfg.consecutive, cfg.alpha);
    let cost_at = |f: usize, n: usize| -> i64 { costs[n - 1][f] };

    // ---- phase 1: greedy demotion from `hi` down to the target budget,
    // moving one step (1 for SS, 2 for DS) at a time
    let target_total = (cfg.target_shifts * k as f64).round() as i64;
    let mut shifts = vec![hi as i64; k];
    let mut total: i64 = shifts.iter().sum();
    while total > target_total {
        // cost of demoting each filter by one step (floor = step)
        let mut order: Vec<usize> = (0..k).filter(|&f| shifts[f] > step as i64).collect();
        if order.is_empty() {
            break;
        }
        order.sort_by_key(|&f| {
            let n = shifts[f] as usize;
            cost_at(f, n - step) - cost_at(f, n)
        });
        let n_demote = ((total - target_total) as usize / step).max(1).min((k / 8).max(1));
        for &f in order.iter().take(n_demote) {
            shifts[f] -= step as i64;
            total -= step as i64;
            if total <= target_total {
                break;
            }
        }
    }

    // uniform reference at ceil(target)
    let ceil_n = (cfg.target_shifts.ceil() as usize).clamp(1, cfg.max_shifts);
    let err_uniform: i64 = (0..k).map(|f| cost_at(f, ceil_n)).sum();

    // ---- phase 2: snap to SA column blocks, non-decreasing over sorted filters
    let mut order: Vec<usize> = (0..k).collect();
    order.sort_by_key(|&f| shifts[f]);
    let n_blocks = k.div_ceil(cfg.sa_cols);
    let block_sizes: Vec<usize> = (0..n_blocks)
        .map(|b| cfg.sa_cols.min(k - b * cfg.sa_cols))
        .collect();
    let vals: Vec<usize> = (1..=hi).filter(|n| n % step == 0 || step == 1).collect();
    let seqs = assignment::nondecreasing_sequences_vals(&block_sizes, &vals, target_total);
    let mut best: Option<(i64, Vec<usize>)> = None;
    for seq in &seqs {
        let mut tot = 0i64;
        for (b, &n) in seq.iter().enumerate() {
            for &f in &order[b * cfg.sa_cols..(b * cfg.sa_cols + block_sizes[b])] {
                tot += cost_at(f, n);
            }
        }
        if best.as_ref().map_or(true, |(e, _)| tot < *e) {
            best = Some((tot, seq.clone()));
        }
    }
    let (err_scheduled, seq) = best.unwrap_or_else(|| {
        // fall back: uniform at the rounded (step-aligned) target
        let n = (((cfg.target_shifts / step as f64).round() as usize).max(1) * step).clamp(step, hi);
        let tot = (0..k).map(|f| cost_at(f, n)).sum();
        (tot, vec![n; n_blocks])
    });

    let mut final_shifts = vec![0usize; k];
    for (b, &n) in seq.iter().enumerate() {
        for &f in &order[b * cfg.sa_cols..(b * cfg.sa_cols + block_sizes[b])] {
            final_shifts[f] = n;
        }
    }

    let packed = pack_with_filter_shifts(&gm, shape, &final_shifts, cfg)?;
    Ok(ScheduledLayer {
        filter_shifts: final_shifts,
        packed,
        err_scheduled,
        err_uniform,
    })
}

/// Pack a layer whose filters use heterogeneous shift counts: storage is
/// sized for the max count; filters with fewer shifts leave trailing mask
/// planes zero (hardware skips them — the SA schedule knows the counts).
pub fn pack_with_filter_shifts(
    gm: &GroupedMags,
    shape: &[usize],
    filter_shifts: &[usize],
    cfg: &ScheduleConfig,
) -> Result<PackedLayer> {
    if filter_shifts.len() != gm.n_filters {
        bail!("filter_shifts length mismatch");
    }
    let n_max = *filter_shifts.iter().max().unwrap_or(&1);
    let gs = gm.group_size;
    let gpf = gm.groups_per_filter;
    let n_groups = gm.n_groups();
    let mut shifts = vec![0u8; n_groups * n_max];
    let mut masks = vec![0u8; n_groups * gs * n_max];

    // quantize filters sharing a shift count together (shared LUTs)
    let mut by_n: std::collections::BTreeMap<usize, Vec<usize>> = Default::default();
    for (f, &n) in filter_shifts.iter().enumerate() {
        by_n.entry(n).or_default().push(f);
    }
    for (&n, filters) in &by_n {
        // cached LUT family for this shift count (no per-call rebuild)
        let luts = planner::luts(n, cfg.consecutive);
        // build a sub-view of the groups belonging to these filters
        let mut sub_mags = Vec::with_capacity(filters.len() * gpf * gs);
        for &f in filters {
            sub_mags.extend_from_slice(
                &gm.mags[f * gpf * gs..(f + 1) * gpf * gs],
            );
        }
        let sub = GroupedMags {
            mags: sub_mags,
            signs: vec![1; filters.len() * gpf * gs],
            scale: gm.scale,
            n_filters: filters.len(),
            groups_per_filter: gpf,
            group_size: gs,
        };
        let (best_idx, best_q) = select_groups(&sub, luts, cfg.alpha);
        for (si, &f) in filters.iter().enumerate() {
            for gl in 0..gpf {
                let g_sub = si * gpf + gl;
                let g = f * gpf + gl;
                let combo = &luts[best_idx[g_sub] as usize].combo;
                shifts[g * n_max..g * n_max + n].copy_from_slice(combo);
                for i in 0..gs {
                    let q = best_q[g_sub * gs + i] as i64;
                    let mb = crate::quant::combos::mask_bits(combo, q);
                    let base = (g * gs + i) * n_max;
                    masks[base..base + n].copy_from_slice(&mb);
                }
            }
        }
    }
    Ok(PackedLayer {
        shape: shape.to_vec(),
        group_size: gs,
        n_shifts: n_max,
        scale: gm.scale,
        shifts,
        masks,
        signs: gm.signs.clone(),
        consecutive: cfg.consecutive,
        filter_shifts: Some(filter_shifts.to_vec()),
    })
}

/// Process-wide count of layer quantize/schedule invocations through
/// [`quantize_or_schedule`] — the planner-work odometer. The pool
/// warm-up tests read it to PROVE that starting workers from a loaded
/// `.swisplan` performs zero quantization (the whole point of shipping
/// plans); see `tests/plan_warmup.rs`.
static PREPARE_CALLS: AtomicU64 = AtomicU64::new(0);

/// Read the planner-work odometer (monotonic across the process).
pub fn prepare_call_count() -> u64 {
    PREPARE_CALLS.load(Ordering::Relaxed)
}

/// Convenience wrapper: quantize uniformly when the target is integral,
/// schedule otherwise.
pub fn quantize_or_schedule(
    w: &[f64],
    shape: &[usize],
    target_shifts: f64,
    group_size: usize,
    consecutive: bool,
    alpha: Alpha,
) -> Result<PackedLayer> {
    PREPARE_CALLS.fetch_add(1, Ordering::Relaxed);
    if target_shifts.fract() == 0.0 {
        let cfg = QuantConfig {
            n_shifts: target_shifts as usize,
            group_size,
            alpha,
            consecutive,
        };
        crate::quant::swis::quantize(w, shape, &cfg)
    } else {
        let mut cfg = ScheduleConfig::new(target_shifts, group_size);
        cfg.consecutive = consecutive;
        cfg.alpha = alpha;
        Ok(schedule_layer(w, shape, &cfg)?.packed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_layer(k: usize, fan_in: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        // filters with varying magnitude spread -> varying sensitivity
        (0..k)
            .flat_map(|f| {
                let sigma = 0.02 + 0.01 * (f % 7) as f64;
                (0..fan_in).map(move |_| sigma).collect::<Vec<_>>()
            })
            .zip(0..)
            .map(|(s, _)| s)
            .collect::<Vec<_>>()
            .iter()
            .map(|&s| rng.normal_ms(0.0, s))
            .collect()
    }

    #[test]
    fn average_hits_target() {
        let w = random_layer(16, 36, 5);
        let cfg = ScheduleConfig::new(2.5, 4);
        let s = schedule_layer(&w, &[16, 36], &cfg).unwrap();
        let avg =
            s.filter_shifts.iter().sum::<usize>() as f64 / s.filter_shifts.len() as f64;
        assert!((avg - 2.5).abs() < 1e-9, "avg={avg}");
        assert_eq!(s.packed.effective_shifts(), 2.5);
    }

    #[test]
    fn blocks_share_shift_counts() {
        let w = random_layer(16, 36, 6);
        let cfg = ScheduleConfig::new(2.5, 4);
        let s = schedule_layer(&w, &[16, 36], &cfg).unwrap();
        // filters sorted by shifts: within each SA block of 8 all equal
        let mut sorted = s.filter_shifts.clone();
        sorted.sort();
        for block in sorted.chunks(8) {
            assert!(block.iter().all(|&n| n == block[0]));
        }
    }

    #[test]
    fn scheduled_error_not_worse_than_uniform_ceiling_average() {
        // scheduling at an integral target should match or beat uniform
        let w = random_layer(32, 64, 7);
        let cfg = ScheduleConfig::new(3.0, 4);
        let s = schedule_layer(&w, &[32, 64], &cfg).unwrap();
        assert!(
            s.err_scheduled <= s.err_uniform,
            "scheduled {} > uniform {}",
            s.err_scheduled,
            s.err_uniform
        );
    }

    #[test]
    fn fractional_target_packs_heterogeneous() {
        let w = random_layer(16, 16, 8);
        let p = quantize_or_schedule(&w, &[16, 16], 2.5, 4, false, Alpha::ONE).unwrap();
        let fs = p.filter_shifts.clone().unwrap();
        assert!(fs.iter().any(|&n| n == 2) && fs.iter().any(|&n| n == 3));
        p.validate().unwrap();
    }

    #[test]
    fn double_shift_filters_even_only() {
        // DS at target 3.0: filters mix even counts (2 and 4), average 3
        let w = random_layer(16, 36, 10);
        let cfg = ScheduleConfig::new(3.0, 4).double_shift();
        let s = schedule_layer(&w, &[16, 36], &cfg).unwrap();
        assert!(s.filter_shifts.iter().all(|&n| n % 2 == 0), "{:?}", s.filter_shifts);
        let avg = s.filter_shifts.iter().sum::<usize>() as f64 / 16.0;
        assert!((avg - 3.0).abs() < 1e-9, "avg={avg}");
        // DS at the same budget cannot beat SS (strict subset of choices)
        let ss = schedule_layer(&w, &[16, 36], &ScheduleConfig::new(3.0, 4)).unwrap();
        assert!(ss.err_scheduled <= s.err_scheduled);
    }

    #[test]
    fn scheduled_dequant_matches_budget() {
        let w = random_layer(8, 16, 9);
        let p = quantize_or_schedule(&w, &[8, 16], 2.0, 4, false, Alpha::ONE).unwrap();
        assert!(p.filter_shifts.is_none()); // integral -> uniform path
        let p2 = quantize_or_schedule(&w, &[8, 16], 2.5, 4, false, Alpha::ONE).unwrap();
        // scheduled layer reconstructs with bounded error
        let deq = p2.to_f64();
        assert_eq!(deq.len(), w.len());
    }
}
