//! The crate-wide error taxonomy: every public seam of the library —
//! the [`crate::api`] facade, the [`crate::runtime::Backend`] trait, the
//! [`crate::coordinator::WorkerPool`] submission/response paths, the
//! [`crate::eval`] sweep — fails with a [`SwisError`], so callers match
//! on the *class* of a failure instead of grepping message strings.
//! `anyhow` remains in use only inside binaries (`main.rs`, examples,
//! benches) and for crate-internal math plumbing; a `SwisError` crossing
//! into an `anyhow::Result` converts losslessly through `?` (it
//! implements `std::error::Error` and its `Display` carries the full
//! context chain).
//!
//! Classes:
//!
//! | variant | failure class |
//! |---------|---------------|
//! | [`SwisError::Config`] | invalid configuration: bad variant spec, unknown scheme/net, out-of-range knobs |
//! | [`SwisError::Plan`] | plan build / `.swisplan` container failures: corrupt header, version mismatch, operand/descriptor mismatch |
//! | [`SwisError::Io`] | filesystem reads/writes behind plans and bench emitters |
//! | [`SwisError::Backend`] | backend construction or execution failures (PJRT or native) |
//! | [`SwisError::Admission`] | serving-edge refusals, with a typed [`AdmissionReason`] |
//! | [`SwisError::Eval`] | accuracy/compression sweep failures |
//!
//! Context is accumulated with [`SwisError::context`] (outermost-first,
//! `": "`-joined in `Display`), mirroring the anyhow `{:#}` convention so
//! log lines keep their shape across the migration.

use std::fmt;

/// Why the serving edge refused or failed a request — the typed payload
/// of [`SwisError::Admission`] that lets callers (and the loadgen
/// recorder) tell backpressure from shedding from shutdown without
/// string matching.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionReason {
    /// Refused by backpressure: the bounded queue is at capacity.
    Busy,
    /// Dropped by deadline shedding before execution.
    Shed,
    /// The pool is shut down (or lost all workers).
    Closed,
    /// The request itself is malformed (wrong image size, empty batch).
    Invalid,
    /// Refused by policy before queueing: the tenant is over its
    /// token-bucket quota at the network edge. Distinct from `Busy`
    /// (capacity backpressure) so clients can tell "slow down" from
    /// "the server is full".
    Rejected,
}

impl AdmissionReason {
    pub fn as_str(self) -> &'static str {
        match self {
            AdmissionReason::Busy => "busy",
            AdmissionReason::Shed => "shed",
            AdmissionReason::Closed => "closed",
            AdmissionReason::Invalid => "invalid",
            AdmissionReason::Rejected => "rejected",
        }
    }
}

/// The crate-wide error type. Each variant carries its full
/// (`": "`-joined, outermost-first) context chain as the message.
#[derive(Clone, Debug)]
pub enum SwisError {
    /// Invalid configuration (variant specs, schemes, nets, CLI knobs).
    Config(String),
    /// Plan preparation / `.swisplan` (de)serialization failures.
    Plan(String),
    /// Filesystem IO failures (paths are included in the message).
    Io(String),
    /// Backend construction/execution failures.
    Backend(String),
    /// Serving-edge refusals with a typed reason.
    Admission { reason: AdmissionReason, msg: String },
    /// Accuracy/compression sweep failures.
    Eval(String),
}

impl SwisError {
    pub fn config(msg: impl fmt::Display) -> SwisError {
        SwisError::Config(msg.to_string())
    }

    pub fn plan(msg: impl fmt::Display) -> SwisError {
        SwisError::Plan(msg.to_string())
    }

    pub fn io(msg: impl fmt::Display) -> SwisError {
        SwisError::Io(msg.to_string())
    }

    pub fn backend(msg: impl fmt::Display) -> SwisError {
        SwisError::Backend(msg.to_string())
    }

    pub fn admission(reason: AdmissionReason, msg: impl fmt::Display) -> SwisError {
        SwisError::Admission { reason, msg: msg.to_string() }
    }

    pub fn eval(msg: impl fmt::Display) -> SwisError {
        SwisError::Eval(msg.to_string())
    }

    /// Short class tag for logs/metrics ("config", "plan", ...).
    pub fn class(&self) -> &'static str {
        match self {
            SwisError::Config(_) => "config",
            SwisError::Plan(_) => "plan",
            SwisError::Io(_) => "io",
            SwisError::Backend(_) => "backend",
            SwisError::Admission { .. } => "admission",
            SwisError::Eval(_) => "eval",
        }
    }

    /// The full context chain (outermost first, `": "`-joined).
    pub fn message(&self) -> &str {
        match self {
            SwisError::Config(m)
            | SwisError::Plan(m)
            | SwisError::Io(m)
            | SwisError::Backend(m)
            | SwisError::Eval(m) => m,
            SwisError::Admission { msg, .. } => msg,
        }
    }

    /// Wrap with an outer context message, preserving the variant (and
    /// the admission reason) — the typed analogue of anyhow's
    /// `.context(..)`.
    pub fn context(self, ctx: impl fmt::Display) -> SwisError {
        let wrap = |m: String| format!("{ctx}: {m}");
        match self {
            SwisError::Config(m) => SwisError::Config(wrap(m)),
            SwisError::Plan(m) => SwisError::Plan(wrap(m)),
            SwisError::Io(m) => SwisError::Io(wrap(m)),
            SwisError::Backend(m) => SwisError::Backend(wrap(m)),
            SwisError::Admission { reason, msg } => {
                SwisError::Admission { reason, msg: wrap(msg) }
            }
            SwisError::Eval(m) => SwisError::Eval(wrap(m)),
        }
    }

    /// True for deadline-shed responses (the SLO accounting class).
    pub fn is_shed(&self) -> bool {
        matches!(self, SwisError::Admission { reason: AdmissionReason::Shed, .. })
    }

    /// Capture an `anyhow` error (full `{:#}` context chain) under the
    /// [`SwisError::Backend`] class — the seam where crate-internal math
    /// errors surface to callers.
    pub fn backend_from(e: anyhow::Error) -> SwisError {
        SwisError::Backend(format!("{e:#}"))
    }

    /// Capture an `anyhow` error under the [`SwisError::Plan`] class.
    pub fn plan_from(e: anyhow::Error) -> SwisError {
        SwisError::Plan(format!("{e:#}"))
    }

    /// Capture an `anyhow` error under the [`SwisError::Config`] class.
    pub fn config_from(e: anyhow::Error) -> SwisError {
        SwisError::Config(format!("{e:#}"))
    }

    /// Capture an `anyhow` error under the [`SwisError::Eval`] class.
    pub fn eval_from(e: anyhow::Error) -> SwisError {
        SwisError::Eval(format!("{e:#}"))
    }

    /// An IO failure at a path.
    pub fn io_at(path: &std::path::Path, e: impl fmt::Display) -> SwisError {
        SwisError::Io(format!("{}: {e}", path.display()))
    }
}

impl fmt::Display for SwisError {
    /// Prints the full context chain (both `{}` and `{:#}`): the error
    /// frequently crosses into `anyhow` at binary boundaries, whose
    /// wrapping would otherwise drop everything but the outermost
    /// message.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SwisError::Admission { reason, msg } => write!(f, "{}: {msg}", reason.as_str()),
            other => f.write_str(other.message()),
        }
    }
}

impl std::error::Error for SwisError {}

/// Result alias for every typed public seam.
pub type SwisResult<T> = Result<T, SwisError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classes_and_context_chain() {
        let e = SwisError::plan("bad magic").context("loading plan.swisplan");
        assert_eq!(e.class(), "plan");
        assert_eq!(format!("{e}"), "loading plan.swisplan: bad magic");
        assert_eq!(format!("{e:#}"), format!("{e}"));
        assert!(matches!(e, SwisError::Plan(_)));
    }

    #[test]
    fn admission_reason_survives_context() {
        let e = SwisError::admission(AdmissionReason::Shed, "deadline exceeded")
            .context("request 7");
        assert!(e.is_shed());
        assert_eq!(format!("{e}"), "shed: request 7: deadline exceeded");
        let busy = SwisError::admission(AdmissionReason::Busy, "queue full");
        assert!(!busy.is_shed());
        assert!(matches!(
            busy,
            SwisError::Admission { reason: AdmissionReason::Busy, .. }
        ));
    }

    #[test]
    fn converts_into_anyhow_without_losing_context() {
        fn through_anyhow() -> anyhow::Result<()> {
            Err::<(), SwisError>(SwisError::backend("boom").context("worker 3"))?;
            Ok(())
        }
        let e = through_anyhow().unwrap_err();
        assert!(format!("{e:#}").contains("worker 3: boom"));
    }

    #[test]
    fn anyhow_capture_keeps_the_chain() {
        use anyhow::Context as _;
        let a: anyhow::Result<()> =
            Err(anyhow::anyhow!("root cause")).context("outer frame");
        let e = SwisError::backend_from(a.unwrap_err());
        assert_eq!(format!("{e}"), "outer frame: root cause");
    }
}
