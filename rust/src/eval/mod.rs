//! Accuracy / compression sweep over the model zoo (paper Sec. 5's
//! evaluation shape): walk networks x bit-widths x quantization schemes
//! on the NATIVE executor, measuring per-layer output MSE vs the fp32
//! reference, top-1 agreement on a fixed probe batch, and the measured
//! packed-storage compression ratio ([`serialize::payload_bits`] over
//! the actual `.swis` container bits). The sweep reproduces the paper's
//! headline *trend* — SWIS beats weight truncation at equal effective
//! bits, most dramatically on MobileNet-v2 — and emits the repo-root
//! `BENCH_accuracy.json` trajectory record.
//!
//! With no trained `<net>_weights.npz` present, weights are the
//! deterministic He surrogates; every record is stamped with its weight
//! provenance (`"weights": "surrogate" | "npz"`) so trajectory points
//! are never silently compared across provenances. Against surrogates
//! the MSE/compression columns are fully meaningful (they depend on
//! weight *statistics*); top-1 agreement is structural only.

use std::path::Path;

use crate::api::EnginePlan;
use crate::coordinator::{Scheme, TierPolicy};
use crate::error::{SwisError, SwisResult};
use crate::exec::{net_weights, NativeModel, WeightProvenance, WeightTransform};
use crate::nets::by_name;
use crate::quant::serialize;
use crate::util::bench::Emitter;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::tensor::Tensor;

/// One sweep configuration.
#[derive(Clone, Debug)]
pub struct EvalConfig {
    /// Zoo net names ([`by_name`] spellings).
    pub nets: Vec<String>,
    /// Schemes to sweep (typed; the fp32 reference row is always
    /// emitted and never listed here).
    pub schemes: Vec<Scheme>,
    /// Effective bit-widths (shift counts; truncation needs integers).
    pub bits: Vec<f64>,
    pub group_size: usize,
    /// Probe batch size (fixed, deterministic in `seed`).
    pub batch: usize,
    pub seed: u64,
    pub threads: usize,
    /// Artifact dir probed for `<net>_weights.npz`.
    pub artifacts: Option<std::path::PathBuf>,
}

impl Default for EvalConfig {
    fn default() -> EvalConfig {
        EvalConfig {
            nets: vec![
                "tinycnn".into(),
                "mobilenet_v2".into(),
                "resnet18".into(),
                "vgg16_cifar100".into(),
            ],
            schemes: Scheme::quantized().to_vec(),
            bits: vec![2.0, 3.0, 4.0],
            group_size: 4,
            batch: 4,
            seed: 2021,
            threads: crate::quant::planner::default_threads(),
            artifacts: None,
        }
    }
}

/// Per-node output MSE vs the fp32 reference (cumulative error — each
/// node is compared after the full quantized prefix ran).
#[derive(Clone, Debug)]
pub struct LayerMse {
    pub layer: String,
    pub mse: f64,
}

/// One sweep point: a (net, scheme, bits) cell.
#[derive(Clone, Debug)]
pub struct EvalRecord {
    pub net: String,
    /// Canonical variant label (`fp32`, `swis@3`, `swis_c@2.5/g8`) —
    /// disambiguates cells that share scheme+bits at different group
    /// sizes.
    pub variant: String,
    /// `fp32` reference rows appear once per net.
    pub scheme: String,
    /// Effective bits of the cell; the fp32 reference row carries 32
    /// (consistent with its `bits_per_weight`), never a quantized bit
    /// count it did not run at.
    pub bits: f64,
    /// Logits MSE vs the fp32 reference on the probe batch.
    pub mse: f64,
    /// Fraction of probe images whose argmax matches fp32.
    pub top1_agree: f64,
    /// vs the 8-bit baseline: measured packed bits for SWIS/SWIS-C
    /// (`payload_bits / n_weights`), `8 / bits` for truncation, `8/32`
    /// for the fp32 row.
    pub compression_ratio: f64,
    /// Measured storage bits per weight.
    pub bits_per_weight: f64,
    pub weights: WeightProvenance,
    pub per_layer: Vec<LayerMse>,
}

fn transform_for(scheme: Scheme, bits: f64, group_size: usize) -> Option<WeightTransform> {
    match scheme {
        Scheme::Swis => {
            Some(WeightTransform::Swis { n_shifts: bits, group_size, consecutive: false })
        }
        Scheme::SwisC => {
            Some(WeightTransform::Swis { n_shifts: bits, group_size, consecutive: true })
        }
        Scheme::WgtTrunc => {
            if bits.fract() != 0.0 || !(1.0..=8.0).contains(&bits) {
                // truncation has no fractional operating points — skip the
                // cell loudly rather than fake one
                eprintln!("eval: skipping wgt_trunc@{bits} (needs an integer bit count in 1..=8)");
                None
            } else {
                Some(WeightTransform::Truncate { bits: bits as usize })
            }
        }
        // the reference row is emitted unconditionally per net
        Scheme::Fp32 => None,
    }
}

/// Deterministic probe batch for one net: uniform [0, 1) pixels, seeded
/// by (config seed, net name) so every scheme/bits cell of a net sees
/// the SAME images.
fn probe_images(
    net: &str,
    shape: [usize; 3],
    batch: usize,
    seed: u64,
) -> anyhow::Result<Tensor<f32>> {
    let tag = net.bytes().fold(0u64, |h, b| h.wrapping_mul(131).wrapping_add(b as u64));
    let mut rng = Rng::new(seed ^ tag);
    let n = batch * shape[0] * shape[1] * shape[2];
    let data: Vec<f32> = (0..n).map(|_| rng.range_f64(0.0, 1.0) as f32).collect();
    Tensor::new(&[batch, shape[0], shape[1], shape[2]], data)
}

fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best
}

fn mse(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>() / a.len() as f64
}

/// The fp32 reference of one net on its probe batch: logits, the full
/// labelled activation trace, and per-image top-1.
struct FpReference {
    logits: Tensor<f32>,
    trace: Vec<(String, Vec<f32>)>,
    top1: Vec<usize>,
}

fn fp_reference(
    fp: &NativeModel,
    probe: &Tensor<f32>,
    batch: usize,
    threads: usize,
) -> SwisResult<FpReference> {
    let (logits, trace) = fp.forward_trace(probe, threads).map_err(SwisError::eval_from)?;
    let n = fp.n_classes();
    let top1 = (0..batch).map(|b| argmax(&logits.data()[b * n..(b + 1) * n])).collect();
    Ok(FpReference { logits, trace, top1 })
}

/// The fp32 reference row emitted once per net.
fn fp_record(net: &str, prov: WeightProvenance) -> EvalRecord {
    EvalRecord {
        net: net.to_string(),
        variant: "fp32".into(),
        scheme: "fp32".into(),
        bits: 32.0,
        mse: 0.0,
        top1_agree: 1.0,
        compression_ratio: 8.0 / 32.0,
        bits_per_weight: 32.0,
        weights: prov,
        per_layer: Vec::new(),
    }
}

/// One quantized sweep record: shared by the grid and plan paths, so
/// the bits-per-weight accounting (measured packed payload for
/// SWIS/SWIS-C, nominal bits for truncation) lives in exactly one place.
#[allow(clippy::too_many_arguments)]
fn quantized_record(
    net: &str,
    variant: &str,
    scheme: Scheme,
    bits: f64,
    m: &NativeModel,
    prov: WeightProvenance,
    cell: (f64, f64, Vec<LayerMse>),
) -> EvalRecord {
    let (mse, top1_agree, per_layer) = cell;
    let bpw = match scheme {
        Scheme::WgtTrunc => bits,
        _ => m.packed_payload_bits as f64 / m.quantized_weights.max(1) as f64,
    };
    EvalRecord {
        net: net.to_string(),
        variant: variant.to_string(),
        scheme: scheme.as_str().into(),
        bits,
        mse,
        top1_agree,
        compression_ratio: 8.0 / bpw,
        bits_per_weight: bpw,
        weights: prov,
        per_layer,
    }
}

/// Measure one quantized model against the fp32 reference: logits MSE,
/// top-1 agreement, cumulative per-layer MSE. The per-layer fold runs
/// against the ONE retained fp32 trace as each node's output is produced
/// — never a second full activation snapshot of a 224x224 net.
fn eval_cell(
    m: &NativeModel,
    reference: &FpReference,
    probe: &Tensor<f32>,
    batch: usize,
    threads: usize,
    label: &str,
) -> SwisResult<(f64, f64, Vec<LayerMse>)> {
    let mut per_layer: Vec<LayerMse> = Vec::with_capacity(reference.trace.len());
    let mut idx = 0usize;
    let logits = {
        let mut obs = |label: &str, y: &[f32]| {
            if let Some((flabel, fy)) = reference.trace.get(idx) {
                debug_assert_eq!(label, flabel.as_str());
                per_layer.push(LayerMse { layer: label.to_string(), mse: mse(y, fy) });
            }
            idx += 1;
        };
        m.forward_observed(probe, threads, &mut obs)
            .map_err(|e| SwisError::eval_from(e).context(format!("evaluating {label}")))?
    };
    if idx != reference.trace.len() {
        return Err(SwisError::eval(format!(
            "trace length diverged between fp32 and {label}"
        )));
    }
    let agree = (0..batch)
        .filter(|&b| {
            argmax(&logits.data()[b * m.n_classes()..(b + 1) * m.n_classes()])
                == reference.top1[b]
        })
        .count();
    Ok((
        mse(logits.data(), reference.logits.data()),
        agree as f64 / batch as f64,
        per_layer,
    ))
}

/// Run the full sweep. Each net is prepared once per (scheme, bits) cell
/// and compared against its fp32 reference trace; the fp32 row itself is
/// emitted first per net.
pub fn run_eval(cfg: &EvalConfig) -> SwisResult<Vec<EvalRecord>> {
    if cfg.batch == 0 {
        return Err(SwisError::eval("eval needs a probe batch of at least 1"));
    }
    let mut records = Vec::new();
    for net_name in &cfg.nets {
        let net = by_name(net_name)
            .ok_or_else(|| SwisError::config(format!("unknown network '{net_name}'")))?
            .with_fc();
        let (weights, prov) =
            net_weights(cfg.artifacts.as_deref(), &net).map_err(SwisError::eval_from)?;
        let fp = NativeModel::prepare_net(&net, &weights, WeightTransform::Fp32).map_err(
            |e| SwisError::eval_from(e).context(format!("preparing fp32 '{}'", net.name)),
        )?;
        let probe = probe_images(&net.name, fp.input_shape(), cfg.batch, cfg.seed)
            .map_err(SwisError::eval_from)?;
        let reference = fp_reference(&fp, &probe, cfg.batch, cfg.threads)?;
        records.push(fp_record(&net.name, prov));

        for &scheme in &cfg.schemes {
            for &bits in &cfg.bits {
                let Some(tf) = transform_for(scheme, bits, cfg.group_size) else {
                    continue;
                };
                let m = NativeModel::prepare_net(&net, &weights, tf).map_err(|e| {
                    SwisError::eval_from(e)
                        .context(format!("preparing {scheme}@{bits} '{}'", net.name))
                })?;
                // the canonical spec name, so grid records carry the
                // SAME variant labels the plan path emits
                let label =
                    crate::coordinator::VariantSpec::new(scheme, bits, cfg.group_size)?.name;
                let cell = eval_cell(&m, &reference, &probe, cfg.batch, cfg.threads, &label)?;
                records.push(quantized_record(&net.name, &label, scheme, bits, &m, prov, cell));
            }
        }
    }
    Ok(records)
}

/// Evaluate a prepared [`EnginePlan`] instead of re-quantizing a sweep
/// grid: every non-fp32 variant of the plan is measured against the
/// plan's own fp32 variant (required — a plan without one cannot anchor
/// the comparison). This is the `swis eval --plan` path: the numbers
/// describe exactly the operands a deployment ships.
pub fn run_eval_plan(
    plan: &EnginePlan,
    batch: usize,
    seed: u64,
    threads: usize,
) -> SwisResult<Vec<EvalRecord>> {
    if batch == 0 {
        return Err(SwisError::eval("eval needs a probe batch of at least 1"));
    }
    let fp = plan.model("fp32").ok_or_else(|| {
        SwisError::eval(format!(
            "plan for '{}' has no fp32 variant to anchor the comparison",
            plan.net_name()
        ))
    })?;
    let probe = probe_images(plan.net_name(), plan.input_shape(), batch, seed)
        .map_err(SwisError::eval_from)?;
    let reference = fp_reference(fp, &probe, batch, threads)?;
    let mut records = vec![fp_record(plan.net_name(), plan.provenance())];
    for spec in plan.variants() {
        if spec.scheme == Scheme::Fp32 {
            continue;
        }
        let m = plan.model(&spec.name).expect("plan variant without model");
        let cell = eval_cell(m, &reference, &probe, batch, threads, &spec.name)?;
        records.push(quantized_record(
            plan.net_name(),
            &spec.name,
            spec.scheme,
            spec.n_shifts,
            m,
            plan.provenance(),
            cell,
        ));
    }
    Ok(records)
}

/// Default worst-layer MSE-ratio cap for [`derive_tier_policy`]: a tier
/// qualifies as a degradation target while its worst per-layer MSE
/// stays within this factor of the top tier's.
pub const DEFAULT_TIER_MSE_CAP: f64 = 64.0;

/// Derive a serving [`TierPolicy`] from a plan's own measured accuracy.
///
/// The ladder is the plan's quantized variants ordered by shift budget
/// descending (most precise first); each tier is measured against the
/// plan's fp32 anchor via [`run_eval_plan`], and the degradation floor
/// is the DEEPEST tier whose worst per-layer MSE stays within
/// `mse_cap` times the top tier's — so admission's degrade-don't-shed
/// path can never push a request past a measured accuracy bound.
/// Needs at least two quantized variants (one tier is not a ladder)
/// and the fp32 anchor `run_eval_plan` requires.
pub fn derive_tier_policy(
    plan: &EnginePlan,
    batch: usize,
    seed: u64,
    threads: usize,
    mse_cap: f64,
) -> SwisResult<TierPolicy> {
    if !(mse_cap.is_finite() && mse_cap > 0.0) {
        return Err(SwisError::eval(format!("tier MSE cap {mse_cap} must be a finite > 0")));
    }
    let mut specs: Vec<_> = plan.variants().iter().filter(|s| s.scheme != Scheme::Fp32).collect();
    if specs.len() < 2 {
        return Err(SwisError::eval(format!(
            "deriving a tier policy needs at least 2 quantized variants, plan has {}",
            specs.len()
        )));
    }
    // highest shift budget = most planes = highest precision; name as a
    // deterministic tiebreak for equal budgets at different group sizes
    specs.sort_by(|a, b| {
        b.n_shifts
            .partial_cmp(&a.n_shifts)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| a.name.cmp(&b.name))
    });
    let records = run_eval_plan(plan, batch, seed, threads)?;
    let worst = |name: &str| -> SwisResult<f64> {
        let r = records.iter().find(|r| r.variant == name).ok_or_else(|| {
            SwisError::eval(format!("no eval record for plan variant '{name}'"))
        })?;
        Ok(r.per_layer.iter().map(|l| l.mse).fold(0.0, f64::max))
    };
    let top = worst(&specs[0].name)?.max(f64::MIN_POSITIVE);
    let mut names = Vec::with_capacity(specs.len());
    let mut ratios = Vec::with_capacity(specs.len());
    for (i, s) in specs.iter().enumerate() {
        names.push(s.name.clone());
        ratios.push(if i == 0 { 1.0 } else { worst(&s.name)? / top });
    }
    // deepest tier still inside the accuracy budget; tiers past it stay
    // in the plan but are served only on explicit request
    let floor = (0..ratios.len()).rev().find(|&i| ratios[i] <= mse_cap).unwrap_or(0);
    TierPolicy::new(names, ratios, floor)
}

/// Serialize the sweep into the `BENCH_accuracy.json` trajectory record.
pub fn bench_json(records: &[EvalRecord], cfg: &EvalConfig) -> Json {
    let mut root = Json::obj();
    root.set("bench", "accuracy");
    root.set("backend", "native");
    let mut c = Json::obj();
    c.set("nets", cfg.nets.clone());
    c.set(
        "schemes",
        cfg.schemes.iter().map(|s| s.as_str().to_string()).collect::<Vec<_>>(),
    );
    c.set("bits", cfg.bits.clone());
    c.set("group_size", cfg.group_size);
    c.set("batch", cfg.batch);
    c.set("seed", cfg.seed);
    root.set("config", c);
    let rows: Vec<Json> = records
        .iter()
        .map(|r| {
            let mut j = Json::obj();
            j.set("net", r.net.as_str());
            j.set("variant", r.variant.as_str());
            j.set("scheme", r.scheme.as_str());
            j.set("bits", r.bits);
            j.set("mse", r.mse);
            j.set("top1_agree", r.top1_agree);
            j.set("compression_ratio", r.compression_ratio);
            j.set("bits_per_weight", r.bits_per_weight);
            j.set("weights", r.weights.as_str());
            let pl: Vec<Json> = r
                .per_layer
                .iter()
                .map(|l| {
                    let mut o = Json::obj();
                    o.set("layer", l.layer.as_str());
                    o.set("mse", l.mse);
                    o
                })
                .collect();
            j.set("per_layer", Json::Arr(pl));
            j
        })
        .collect();
    root.set("records", Json::Arr(rows));
    root
}

/// Write `BENCH_accuracy.json` (pretty, stable key order) — atomically,
/// through the shared [`Emitter`].
pub fn write_bench_json(
    records: &[EvalRecord],
    cfg: &EvalConfig,
    path: &Path,
) -> SwisResult<()> {
    Emitter::at(path).write(&bench_json(records, cfg))
}

/// Serialize one layer of a net under SWIS and report the container
/// payload — the compression the sweep's ratio column measures, exposed
/// for spot checks and the CLI report.
pub fn packed_container_bits(
    w: &[f64],
    shape: &[usize; 2],
    bits: f64,
    group_size: usize,
    consecutive: bool,
) -> SwisResult<u64> {
    let p = crate::schedule::quantize_or_schedule(
        w,
        shape,
        bits,
        group_size,
        consecutive,
        crate::quant::Alpha::ONE,
    )
    .map_err(SwisError::eval_from)?;
    Ok(serialize::payload_bits(&p))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> EvalConfig {
        EvalConfig {
            nets: vec!["tinycnn".into()],
            schemes: vec![Scheme::Swis, Scheme::WgtTrunc],
            bits: vec![3.0],
            batch: 2,
            threads: 2,
            ..EvalConfig::default()
        }
    }

    #[test]
    fn tinycnn_sweep_produces_trend_and_schema() {
        let cfg = tiny_cfg();
        let recs = run_eval(&cfg).unwrap();
        // fp32 row + swis@3 + wgt_trunc@3
        assert_eq!(recs.len(), 3);
        assert_eq!(recs[0].scheme, "fp32");
        let swis = recs.iter().find(|r| r.scheme == "swis").unwrap();
        let trunc = recs.iter().find(|r| r.scheme == "wgt_trunc").unwrap();
        // the paper's core claim, here at the logits level: SWIS beats
        // truncation at equal effective bits
        assert!(
            swis.mse < trunc.mse,
            "SWIS logits MSE {} not below truncation {}",
            swis.mse,
            trunc.mse
        );
        assert!(swis.mse > 0.0);
        assert_eq!(swis.weights, WeightProvenance::Surrogate);
        // measured SWIS storage at n=3, G=4: 1 sign + 3 masks + 9/4 shift
        // bits per weight ≈ 6.3 — more than truncation's 3, but bought
        // with far lower error (the trade the paper quantifies)
        assert!(swis.bits_per_weight > 3.0 && swis.bits_per_weight < 8.0);
        assert!((trunc.compression_ratio - 8.0 / 3.0).abs() < 1e-12);
        assert_eq!(swis.per_layer.len(), 9); // 6 convs + gap + 2 fc
        // per-layer error is cumulative: the logits-row MSE equals the
        // last trace entry's
        let last = swis.per_layer.last().unwrap();
        assert!((last.mse - swis.mse).abs() < 1e-12);
    }

    #[test]
    fn bench_json_is_wellformed() {
        let cfg = tiny_cfg();
        let recs = run_eval(&cfg).unwrap();
        let j = bench_json(&recs, &cfg);
        let parsed = crate::util::json::parse(&j.pretty()).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("accuracy"));
        let rows = parsed.get("records").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), recs.len());
        for key in ["net", "scheme", "bits", "mse", "top1_agree", "compression_ratio", "weights"] {
            assert!(rows[0].get(key).is_some(), "missing {key}");
        }
    }

    #[test]
    fn fractional_trunc_cells_are_skipped() {
        assert!(transform_for(Scheme::WgtTrunc, 2.5, 4).is_none());
        assert!(transform_for(Scheme::Swis, 2.5, 4).is_some());
        // unknown schemes are now unrepresentable: they fail at the
        // typed parse boundary instead
        assert!(matches!("int4".parse::<Scheme>().unwrap_err(), SwisError::Config(_)));
    }

    #[test]
    fn plan_eval_matches_the_grid_sweep() {
        use crate::api::{Engine, EngineConfig, VariantSpec};
        // a plan carrying fp32 + swis@3 must produce the same cells as
        // the (tinycnn, swis, 3.0) grid sweep — same probe, same math
        let cfg = tiny_cfg();
        let grid = run_eval(&cfg).unwrap();
        let plan = Engine::prepare(
            EngineConfig::for_net("tinycnn")
                .unwrap()
                .variant(VariantSpec::fp32())
                .variant(VariantSpec::swis(3.0, 4))
                .threads(2),
        )
        .unwrap();
        let recs = run_eval_plan(&plan, cfg.batch, cfg.seed, cfg.threads).unwrap();
        assert_eq!(recs.len(), 2); // fp32 + swis@3
        let plan_swis = recs.iter().find(|r| r.scheme == "swis").unwrap();
        let grid_swis = grid.iter().find(|r| r.scheme == "swis").unwrap();
        assert_eq!(plan_swis.mse, grid_swis.mse);
        assert_eq!(plan_swis.top1_agree, grid_swis.top1_agree);
        assert_eq!(plan_swis.bits_per_weight, grid_swis.bits_per_weight);
        // a plan without the fp32 anchor is a typed Eval error
        let no_anchor = Engine::prepare(
            EngineConfig::for_net("tinycnn")
                .unwrap()
                .variant(VariantSpec::swis(3.0, 4))
                .threads(2),
        )
        .unwrap();
        assert!(matches!(
            run_eval_plan(&no_anchor, 2, 7, 2).unwrap_err(),
            SwisError::Eval(_)
        ));
    }

    #[test]
    fn tier_policy_derivation_orders_and_floors_by_measured_mse() {
        use crate::api::{Engine, EngineConfig, VariantSpec};
        let plan = Engine::prepare(
            EngineConfig::for_net("tinycnn")
                .unwrap()
                .variant(VariantSpec::fp32())
                .variant(VariantSpec::swis(2.0, 4))
                .variant(VariantSpec::swis(4.0, 4))
                .variant(VariantSpec::swis(3.0, 4))
                .threads(2),
        )
        .unwrap();
        // a generous cap admits the whole ladder as degradation targets
        let p = derive_tier_policy(&plan, 2, 7, 2, 1e12).unwrap();
        let names: Vec<&str> = p.tier_names().iter().map(|s| s.as_str()).collect();
        assert_eq!(names, ["swis@4", "swis@3", "swis@2"], "ladder must sort by precision");
        assert_eq!(p.mse_ratios()[0], 1.0);
        assert!(p.mse_ratios().iter().all(|r| r.is_finite() && *r >= 0.0));
        assert_eq!(p.floor(), 2);
        // a cap below 1.0 disqualifies every deeper tier: the floor
        // stays at the top and admission can never degrade
        let tight = derive_tier_policy(&plan, 2, 7, 2, 0.5).unwrap();
        assert_eq!(tight.floor(), 0);
        // one quantized variant is not a ladder
        let single = Engine::prepare(
            EngineConfig::for_net("tinycnn")
                .unwrap()
                .variant(VariantSpec::fp32())
                .variant(VariantSpec::swis(3.0, 4))
                .threads(2),
        )
        .unwrap();
        assert!(matches!(
            derive_tier_policy(&single, 2, 7, 2, 64.0).unwrap_err(),
            SwisError::Eval(_)
        ));
    }

    #[test]
    fn probe_is_deterministic_per_net() {
        let a = probe_images("tinycnn", [32, 32, 3], 2, 7).unwrap();
        let b = probe_images("tinycnn", [32, 32, 3], 2, 7).unwrap();
        assert_eq!(a.data(), b.data());
        let c = probe_images("vgg16_cifar100", [32, 32, 3], 2, 7).unwrap();
        assert_ne!(a.data(), c.data());
    }
}
