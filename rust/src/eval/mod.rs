//! Accuracy / compression sweep over the model zoo (paper Sec. 5's
//! evaluation shape): walk networks x bit-widths x quantization schemes
//! on the NATIVE executor, measuring per-layer output MSE vs the fp32
//! reference, top-1 agreement on a fixed probe batch, and the measured
//! packed-storage compression ratio ([`serialize::payload_bits`] over
//! the actual `.swis` container bits). The sweep reproduces the paper's
//! headline *trend* — SWIS beats weight truncation at equal effective
//! bits, most dramatically on MobileNet-v2 — and emits the repo-root
//! `BENCH_accuracy.json` trajectory record.
//!
//! With no trained `<net>_weights.npz` present, weights are the
//! deterministic He surrogates; every record is stamped with its weight
//! provenance (`"weights": "surrogate" | "npz"`) so trajectory points
//! are never silently compared across provenances. Against surrogates
//! the MSE/compression columns are fully meaningful (they depend on
//! weight *statistics*); top-1 agreement is structural only.

use anyhow::{bail, Context, Result};
use std::path::Path;

use crate::exec::{net_weights, NativeModel, WeightProvenance, WeightTransform};
use crate::nets::by_name;
use crate::quant::serialize;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::tensor::Tensor;

/// One sweep configuration.
#[derive(Clone, Debug)]
pub struct EvalConfig {
    /// Zoo net names ([`by_name`] spellings).
    pub nets: Vec<String>,
    /// Schemes to sweep: `swis`, `swis_c`, `wgt_trunc`.
    pub schemes: Vec<String>,
    /// Effective bit-widths (shift counts; truncation needs integers).
    pub bits: Vec<f64>,
    pub group_size: usize,
    /// Probe batch size (fixed, deterministic in `seed`).
    pub batch: usize,
    pub seed: u64,
    pub threads: usize,
    /// Artifact dir probed for `<net>_weights.npz`.
    pub artifacts: Option<std::path::PathBuf>,
}

impl Default for EvalConfig {
    fn default() -> EvalConfig {
        EvalConfig {
            nets: vec![
                "tinycnn".into(),
                "mobilenet_v2".into(),
                "resnet18".into(),
                "vgg16_cifar100".into(),
            ],
            schemes: vec!["swis".into(), "swis_c".into(), "wgt_trunc".into()],
            bits: vec![2.0, 3.0, 4.0],
            group_size: 4,
            batch: 4,
            seed: 2021,
            threads: crate::quant::planner::default_threads(),
            artifacts: None,
        }
    }
}

/// Per-node output MSE vs the fp32 reference (cumulative error — each
/// node is compared after the full quantized prefix ran).
#[derive(Clone, Debug)]
pub struct LayerMse {
    pub layer: String,
    pub mse: f64,
}

/// One sweep point: a (net, scheme, bits) cell.
#[derive(Clone, Debug)]
pub struct EvalRecord {
    pub net: String,
    /// `fp32` reference rows appear once per net.
    pub scheme: String,
    /// Effective bits of the cell; the fp32 reference row carries 32
    /// (consistent with its `bits_per_weight`), never a quantized bit
    /// count it did not run at.
    pub bits: f64,
    /// Logits MSE vs the fp32 reference on the probe batch.
    pub mse: f64,
    /// Fraction of probe images whose argmax matches fp32.
    pub top1_agree: f64,
    /// vs the 8-bit baseline: measured packed bits for SWIS/SWIS-C
    /// (`payload_bits / n_weights`), `8 / bits` for truncation, `8/32`
    /// for the fp32 row.
    pub compression_ratio: f64,
    /// Measured storage bits per weight.
    pub bits_per_weight: f64,
    pub weights: WeightProvenance,
    pub per_layer: Vec<LayerMse>,
}

fn transform_for(scheme: &str, bits: f64, group_size: usize) -> Result<Option<WeightTransform>> {
    Ok(match scheme {
        "swis" => Some(WeightTransform::Swis { n_shifts: bits, group_size, consecutive: false }),
        "swis_c" => Some(WeightTransform::Swis { n_shifts: bits, group_size, consecutive: true }),
        "wgt_trunc" => {
            if bits.fract() != 0.0 || !(1.0..=8.0).contains(&bits) {
                // truncation has no fractional operating points — skip the
                // cell loudly rather than fake one
                eprintln!("eval: skipping wgt_trunc@{bits} (needs an integer bit count in 1..=8)");
                None
            } else {
                Some(WeightTransform::Truncate { bits: bits as usize })
            }
        }
        other => bail!("unknown eval scheme '{other}' (expected swis|swis_c|wgt_trunc)"),
    })
}

/// Deterministic probe batch for one net: uniform [0, 1) pixels, seeded
/// by (config seed, net name) so every scheme/bits cell of a net sees
/// the SAME images.
fn probe_images(net: &str, shape: [usize; 3], batch: usize, seed: u64) -> Result<Tensor<f32>> {
    let tag = net.bytes().fold(0u64, |h, b| h.wrapping_mul(131).wrapping_add(b as u64));
    let mut rng = Rng::new(seed ^ tag);
    let n = batch * shape[0] * shape[1] * shape[2];
    let data: Vec<f32> = (0..n).map(|_| rng.range_f64(0.0, 1.0) as f32).collect();
    Tensor::new(&[batch, shape[0], shape[1], shape[2]], data)
}

fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best
}

fn mse(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>() / a.len() as f64
}

/// Run the full sweep. Each net is prepared once per (scheme, bits) cell
/// and compared against its fp32 reference trace; the fp32 row itself is
/// emitted first per net.
pub fn run_eval(cfg: &EvalConfig) -> Result<Vec<EvalRecord>> {
    if cfg.batch == 0 {
        bail!("eval needs a probe batch of at least 1");
    }
    let mut records = Vec::new();
    for net_name in &cfg.nets {
        let net = by_name(net_name)
            .with_context(|| format!("unknown network '{net_name}'"))?
            .with_fc();
        let (weights, prov) = net_weights(cfg.artifacts.as_deref(), &net)?;
        let fp = NativeModel::prepare_net(&net, &weights, WeightTransform::Fp32)
            .with_context(|| format!("preparing fp32 '{}'", net.name))?;
        let probe = probe_images(&net.name, fp.input_shape(), cfg.batch, cfg.seed)?;
        let (flogits, ftrace) = fp.forward_trace(&probe, cfg.threads)?;
        let fp_top1: Vec<usize> = (0..cfg.batch)
            .map(|b| argmax(&flogits.data()[b * fp.n_classes()..(b + 1) * fp.n_classes()]))
            .collect();
        records.push(EvalRecord {
            net: net.name.clone(),
            scheme: "fp32".into(),
            bits: 32.0,
            mse: 0.0,
            top1_agree: 1.0,
            compression_ratio: 8.0 / 32.0,
            bits_per_weight: 32.0,
            weights: prov,
            per_layer: Vec::new(),
        });

        for scheme in &cfg.schemes {
            for &bits in &cfg.bits {
                let Some(tf) = transform_for(scheme, bits, cfg.group_size)? else {
                    continue;
                };
                let m = NativeModel::prepare_net(&net, &weights, tf)
                    .with_context(|| format!("preparing {scheme}@{bits} '{}'", net.name))?;
                // per-layer MSE folds against the ONE retained fp32 trace
                // as each node's output is produced — never a second full
                // activation snapshot of a 224x224 net
                let mut per_layer: Vec<LayerMse> = Vec::with_capacity(ftrace.len());
                let mut idx = 0usize;
                let logits = {
                    let mut obs = |label: &str, y: &[f32]| {
                        if let Some((flabel, fy)) = ftrace.get(idx) {
                            debug_assert_eq!(label, flabel.as_str());
                            per_layer.push(LayerMse { layer: label.to_string(), mse: mse(y, fy) });
                        }
                        idx += 1;
                    };
                    m.forward_observed(&probe, cfg.threads, &mut obs)?
                };
                if idx != ftrace.len() {
                    bail!("trace length diverged between fp32 and {scheme}@{bits}");
                }
                let agree = (0..cfg.batch)
                    .filter(|&b| {
                        argmax(&logits.data()[b * m.n_classes()..(b + 1) * m.n_classes()])
                            == fp_top1[b]
                    })
                    .count();
                let bpw = match scheme.as_str() {
                    "wgt_trunc" => bits,
                    _ => m.packed_payload_bits as f64 / m.quantized_weights.max(1) as f64,
                };
                records.push(EvalRecord {
                    net: net.name.clone(),
                    scheme: scheme.clone(),
                    bits,
                    mse: mse(logits.data(), flogits.data()),
                    top1_agree: agree as f64 / cfg.batch as f64,
                    compression_ratio: 8.0 / bpw,
                    bits_per_weight: bpw,
                    weights: prov,
                    per_layer,
                });
            }
        }
    }
    Ok(records)
}

/// Serialize the sweep into the `BENCH_accuracy.json` trajectory record.
pub fn bench_json(records: &[EvalRecord], cfg: &EvalConfig) -> Json {
    let mut root = Json::obj();
    root.set("bench", "accuracy");
    root.set("backend", "native");
    let mut c = Json::obj();
    c.set("nets", cfg.nets.clone());
    c.set("schemes", cfg.schemes.clone());
    c.set("bits", cfg.bits.clone());
    c.set("group_size", cfg.group_size);
    c.set("batch", cfg.batch);
    c.set("seed", cfg.seed);
    root.set("config", c);
    let rows: Vec<Json> = records
        .iter()
        .map(|r| {
            let mut j = Json::obj();
            j.set("net", r.net.as_str());
            j.set("scheme", r.scheme.as_str());
            j.set("bits", r.bits);
            j.set("mse", r.mse);
            j.set("top1_agree", r.top1_agree);
            j.set("compression_ratio", r.compression_ratio);
            j.set("bits_per_weight", r.bits_per_weight);
            j.set("weights", r.weights.as_str());
            let pl: Vec<Json> = r
                .per_layer
                .iter()
                .map(|l| {
                    let mut o = Json::obj();
                    o.set("layer", l.layer.as_str());
                    o.set("mse", l.mse);
                    o
                })
                .collect();
            j.set("per_layer", Json::Arr(pl));
            j
        })
        .collect();
    root.set("records", Json::Arr(rows));
    root
}

/// Write `BENCH_accuracy.json` (pretty, stable key order).
pub fn write_bench_json(records: &[EvalRecord], cfg: &EvalConfig, path: &Path) -> Result<()> {
    std::fs::write(path, bench_json(records, cfg).pretty())
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

/// Serialize one layer of a net under SWIS and report the container
/// payload — the compression the sweep's ratio column measures, exposed
/// for spot checks and the CLI report.
pub fn packed_container_bits(
    w: &[f64],
    shape: &[usize; 2],
    bits: f64,
    group_size: usize,
    consecutive: bool,
) -> Result<u64> {
    let p = crate::schedule::quantize_or_schedule(
        w,
        shape,
        bits,
        group_size,
        consecutive,
        crate::quant::Alpha::ONE,
    )?;
    Ok(serialize::payload_bits(&p))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> EvalConfig {
        EvalConfig {
            nets: vec!["tinycnn".into()],
            schemes: vec!["swis".into(), "wgt_trunc".into()],
            bits: vec![3.0],
            batch: 2,
            threads: 2,
            ..EvalConfig::default()
        }
    }

    #[test]
    fn tinycnn_sweep_produces_trend_and_schema() {
        let cfg = tiny_cfg();
        let recs = run_eval(&cfg).unwrap();
        // fp32 row + swis@3 + wgt_trunc@3
        assert_eq!(recs.len(), 3);
        assert_eq!(recs[0].scheme, "fp32");
        let swis = recs.iter().find(|r| r.scheme == "swis").unwrap();
        let trunc = recs.iter().find(|r| r.scheme == "wgt_trunc").unwrap();
        // the paper's core claim, here at the logits level: SWIS beats
        // truncation at equal effective bits
        assert!(
            swis.mse < trunc.mse,
            "SWIS logits MSE {} not below truncation {}",
            swis.mse,
            trunc.mse
        );
        assert!(swis.mse > 0.0);
        assert_eq!(swis.weights, WeightProvenance::Surrogate);
        // measured SWIS storage at n=3, G=4: 1 sign + 3 masks + 9/4 shift
        // bits per weight ≈ 6.3 — more than truncation's 3, but bought
        // with far lower error (the trade the paper quantifies)
        assert!(swis.bits_per_weight > 3.0 && swis.bits_per_weight < 8.0);
        assert!((trunc.compression_ratio - 8.0 / 3.0).abs() < 1e-12);
        assert_eq!(swis.per_layer.len(), 9); // 6 convs + gap + 2 fc
        // per-layer error is cumulative: the logits-row MSE equals the
        // last trace entry's
        let last = swis.per_layer.last().unwrap();
        assert!((last.mse - swis.mse).abs() < 1e-12);
    }

    #[test]
    fn bench_json_is_wellformed() {
        let cfg = tiny_cfg();
        let recs = run_eval(&cfg).unwrap();
        let j = bench_json(&recs, &cfg);
        let parsed = crate::util::json::parse(&j.pretty()).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("accuracy"));
        let rows = parsed.get("records").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), recs.len());
        for key in ["net", "scheme", "bits", "mse", "top1_agree", "compression_ratio", "weights"] {
            assert!(rows[0].get(key).is_some(), "missing {key}");
        }
    }

    #[test]
    fn fractional_trunc_cells_are_skipped() {
        assert!(transform_for("wgt_trunc", 2.5, 4).unwrap().is_none());
        assert!(transform_for("swis", 2.5, 4).unwrap().is_some());
        assert!(transform_for("int4", 4.0, 4).is_err());
    }

    #[test]
    fn probe_is_deterministic_per_net() {
        let a = probe_images("tinycnn", [32, 32, 3], 2, 7).unwrap();
        let b = probe_images("tinycnn", [32, 32, 3], 2, 7).unwrap();
        assert_eq!(a.data(), b.data());
        let c = probe_images("vgg16_cifar100", [32, 32, 3], 2, 7).unwrap();
        assert_ne!(a.data(), c.data());
    }
}
