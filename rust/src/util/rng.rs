//! Deterministic PRNG utilities (SplitMix64 + xoshiro256**) with normal
//! sampling — the offline build vendors no `rand`, so the library carries
//! its own small, well-tested generator. Streams are reproducible across
//! platforms (pure integer arithmetic + libm-free Box-Muller via `f64::ln`).

/// xoshiro256** seeded via SplitMix64. Passes practical equidistribution
/// needs for simulation workloads; NOT cryptographic.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal deviate from Box-Muller
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (for per-thread / per-layer use).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) (Lemire-style, unbiased enough for sims).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        if n == 0 {
            return 0;
        }
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (deterministic given the stream).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            let u2 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// N(mu, sigma^2).
    pub fn normal_ms(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize, mu: f64, sigma: f64) -> Vec<f64> {
        (0..n).map(|_| self.normal_ms(mu, sigma)).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(4);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let xs = r.normal_vec(50_000, 0.0, 1.0);
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
