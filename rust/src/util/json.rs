//! Minimal JSON: a writer (for reports/metrics) and a small recursive
//! parser (for manifest.json / retrain_results.json). No serde in the
//! offline vendor set, so this ~300-line module covers the subset we emit
//! and consume: objects, arrays, strings, numbers, bools, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Result};

/// JSON value tree.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, v: impl Into<Json>) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), v.into());
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path access: `j.path(&["artifacts", "0", "file"])`.
    pub fn path(&self, parts: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in parts {
            cur = match cur {
                Json::Obj(m) => m.get(*p)?,
                Json::Arr(a) => a.get(p.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn keys(&self) -> Vec<&str> {
        match self {
            Json::Obj(m) => m.keys().map(|s| s.as_str()).collect(),
            _ => vec![],
        }
    }

    /// Compact serialization.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * depth),
                " ".repeat(w * (depth + 1)),
            ),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

// ---------------------------------------------------------------------
// parser
// ---------------------------------------------------------------------

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

pub fn parse(s: &str) -> Result<Json> {
    let mut p = Parser { b: s.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        bail!("trailing characters at byte {}", p.i);
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => bail!("unexpected character at byte {}", self.i),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy the full utf-8 code point
                    let s = std::str::from_utf8(&self.b[self.i..])?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.i),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            out.insert(k, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.i),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut j = Json::obj();
        j.set("a", 1.5).set("b", "hi").set("c", vec![1usize, 2, 3]);
        let parsed = parse(&j.dump()).unwrap();
        assert_eq!(parsed, j);
        let parsed2 = parse(&j.pretty()).unwrap();
        assert_eq!(parsed2, j);
    }

    #[test]
    fn parses_python_json() {
        let s = r#"{"baseline_accuracy": 0.918, "artifacts": [{"file": "m.hlo.txt", "batch": 8}], "flag": true, "none": null}"#;
        let j = parse(s).unwrap();
        assert_eq!(j.path(&["artifacts", "0", "file"]).unwrap().as_str(), Some("m.hlo.txt"));
        assert_eq!(j.get("baseline_accuracy").unwrap().as_f64(), Some(0.918));
        assert_eq!(j.get("flag").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("none"), Some(&Json::Null));
    }

    #[test]
    fn string_escapes() {
        let j = parse(r#""a\n\"b\"A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\n\"b\"A"));
    }

    #[test]
    fn rejects_trailing() {
        assert!(parse("{} junk").is_err());
        assert!(parse("[1,").is_err());
    }

    #[test]
    fn integers_stay_integers() {
        let j = Json::Num(42.0);
        assert_eq!(j.dump(), "42");
        assert_eq!(Json::Num(1.25).dump(), "1.25");
    }
}
