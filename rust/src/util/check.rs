//! Mini property-testing harness (proptest is not in the offline vendor
//! set). `props!` runs a closure over many seeded random cases and reports
//! the first failing seed so failures are reproducible:
//!
//! ```ignore
//! check::props(100, |rng| {
//!     let n = rng.range_u64(1, 8) as usize;
//!     let w = rng.normal_vec(32, 0.0, 0.1);
//!     /* ... assert invariant ... */
//!     Ok(())
//! });
//! ```

use super::rng::Rng;

/// Run `f` over `cases` seeded RNG streams; panics with the failing seed.
pub fn props(cases: u64, f: impl Fn(&mut Rng) -> Result<(), String>) {
    for seed in 0..cases {
        let mut rng = Rng::new(0xC0FFEE ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
        if let Err(msg) = f(&mut rng) {
            panic!("property failed at seed {seed}: {msg}");
        }
    }
}

/// Assert-like helper returning Result for use inside `props`.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

/// Approximate float equality for property bodies.
pub fn close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn props_pass() {
        props(20, |rng| {
            let x = rng.f64();
            prop_assert!((0.0..1.0).contains(&x), "x out of range: {x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn props_fail_reports_seed() {
        props(5, |rng| {
            let x = rng.f64();
            prop_assert!(x < 0.0, "always fails: {x}");
            Ok(())
        });
    }

    #[test]
    fn close_tolerance() {
        assert!(close(1.0, 1.0 + 1e-12, 1e-9));
        assert!(!close(1.0, 2.0, 1e-9));
    }
}
