//! `BENCH_*.json` trajectory-file emitter — the ONE writer behind
//! `benches/hotpath.rs`, `loadgen::sweep` and `eval::` (previously three
//! hand-rolled `std::fs::write` calls). Writes are atomic: the document
//! lands in a sibling temp file first and is `rename`d into place, so a
//! bench that panics (or a machine that dies) mid-write can truncate
//! only the temp file, never a previously recorded trajectory point.

use std::path::{Path, PathBuf};

use crate::error::{SwisError, SwisResult};
use crate::util::json::Json;

/// Atomic JSON emitter bound to one output path.
pub struct Emitter {
    path: PathBuf,
}

impl Emitter {
    /// Emitter for an explicit path.
    pub fn at(path: impl Into<PathBuf>) -> Emitter {
        Emitter { path: path.into() }
    }

    /// Emitter for a `BENCH_*.json` file at the repository root (one
    /// level above the `rust/` package — where every trajectory file
    /// lives).
    pub fn repo_root(file_name: &str) -> Emitter {
        Emitter { path: Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join(file_name) }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Write `doc` (pretty, stable key order) atomically via
    /// [`write_atomic`].
    pub fn write(&self, doc: &Json) -> SwisResult<()> {
        write_atomic(&self.path, doc.pretty().as_bytes())
    }
}

/// The ONE atomic file write behind every emitted artifact (`BENCH_*`
/// trajectory files here, `.swisplan` containers in `crate::api`): the
/// bytes land in a sibling `<name>.tmp` first and are `rename`d into
/// place — rename within a directory is atomic on POSIX, so readers
/// only ever observe the old or the new complete file, and a crash
/// mid-write can truncate only the temp file.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> SwisResult<()> {
    // (pid, counter)-unique temp name: concurrent writers to the same
    // target — across processes OR threads — each stage privately and
    // the LAST rename wins with a complete file; a shared tmp name
    // would let one writer publish another's half-written bytes
    static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let seq = SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(format!(".tmp.{}.{seq}", std::process::id()));
    let tmp = PathBuf::from(tmp);
    std::fs::write(&tmp, bytes).map_err(|e| SwisError::io_at(&tmp, e))?;
    std::fs::rename(&tmp, path).map_err(|e| SwisError::io_at(path, e))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_atomically_and_parses_back() {
        let dir = std::env::temp_dir().join(format!("swis_emitter_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        let em = Emitter::at(&path);
        let mut doc = Json::obj();
        doc.set("bench", "test").set("value", 1.5);
        em.write(&doc).unwrap();
        let back = crate::util::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(back.get("bench").unwrap().as_str(), Some("test"));
        // no temp residue after a successful write
        assert!(std::fs::read_dir(&dir).unwrap().count() == 1);
        // overwrite goes through the same atomic path
        doc.set("value", 2.0);
        em.write(&doc).unwrap();
        let back = crate::util::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(back.get("value").unwrap().as_f64(), Some(2.0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unwritable_path_is_a_typed_io_error() {
        let em = Emitter::at("/definitely/not/here/BENCH_x.json");
        let e = em.write(&Json::obj()).unwrap_err();
        assert!(matches!(e, SwisError::Io(_)));
    }
}
