//! Tiny CLI argument parser (no clap in the offline vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.
//! Subcommand dispatch is done by the binary (`main.rs`) on the first
//! positional token.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

/// Parsed command line: options + positionals.
#[derive(Clone, Debug, Default)]
pub struct Args {
    opts: HashMap<String, String>,
    flags: Vec<String>,
    pos: Vec<String>,
}

/// Option keys that take a value; everything else starting with `--` is a
/// boolean flag. Single-character keys listed in `value_keys` are also
/// accepted with one dash (`-o out.swisplan`); unknown single-dash
/// tokens stay positional (so negative numbers pass through).
pub fn parse(argv: &[String], value_keys: &[&str]) -> Result<Args> {
    let mut a = Args::default();
    let mut i = 0;
    while i < argv.len() {
        let tok = &argv[i];
        if let Some(stripped) = tok.strip_prefix("--") {
            if let Some((k, v)) = stripped.split_once('=') {
                a.opts.insert(k.to_string(), v.to_string());
            } else if value_keys.contains(&stripped) {
                i += 1;
                let v = argv
                    .get(i)
                    .with_context(|| format!("--{stripped} expects a value"))?;
                a.opts.insert(stripped.to_string(), v.clone());
            } else {
                a.flags.push(stripped.to_string());
            }
        } else if let Some(short) = tok.strip_prefix('-') {
            if short.len() == 1 && value_keys.contains(&short) {
                i += 1;
                let v = argv.get(i).with_context(|| format!("-{short} expects a value"))?;
                a.opts.insert(short.to_string(), v.clone());
            } else {
                a.pos.push(tok.clone());
            }
        } else {
            a.pos.push(tok.clone());
        }
        i += 1;
    }
    Ok(a)
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<usize>()
                .with_context(|| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<f64>()
                .with_context(|| format!("--{name} expects a number, got '{v}'")),
        }
    }

    /// Comma-separated list of usize, e.g. `--groups 1,2,4,8`.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse::<usize>()
                        .with_context(|| format!("--{name}: bad element '{t}'"))
                })
                .collect(),
        }
    }

    /// Comma-separated list of f64, e.g. `--rates 100,250.5`.
    pub fn get_f64_list(&self, name: &str, default: &[f64]) -> Result<Vec<f64>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse::<f64>()
                        .with_context(|| format!("--{name}: bad element '{t}'"))
                })
                .collect(),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.pos
    }

    /// Every `--key value` / `--key=value` option name seen (for
    /// table-driven validation by the binary).
    pub fn opt_keys(&self) -> impl Iterator<Item = &str> {
        self.opts.keys().map(|s| s.as_str())
    }

    /// Every boolean `--flag` seen.
    pub fn flag_names(&self) -> impl Iterator<Item = &str> {
        self.flags.iter().map(|s| s.as_str())
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.pos.first().map(|s| s.as_str())
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        match self.get(name) {
            Some(v) => Ok(v),
            None => bail!("missing required option --{name}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = parse(
            &sv(&["simulate", "--net", "resnet18", "--verbose", "--shifts=3"]),
            &["net"],
        )
        .unwrap();
        assert_eq!(a.subcommand(), Some("simulate"));
        assert_eq!(a.get("net"), Some("resnet18"));
        assert_eq!(a.get("shifts"), Some("3"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_getters() {
        let a = parse(&sv(&["--n", "5", "--x", "1.5", "--l", "1,2,4"]), &["n", "x", "l"]).unwrap();
        assert_eq!(a.get_usize("n", 0).unwrap(), 5);
        assert_eq!(a.get_f64("x", 0.0).unwrap(), 1.5);
        assert_eq!(a.get_usize_list("l", &[]).unwrap(), vec![1, 2, 4]);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        let b = parse(&sv(&["--r", "100,250.5"]), &["r"]).unwrap();
        assert_eq!(b.get_f64_list("r", &[]).unwrap(), vec![100.0, 250.5]);
        assert_eq!(b.get_f64_list("missing", &[1.5]).unwrap(), vec![1.5]);
    }

    #[test]
    fn short_value_keys_parse() {
        let a = parse(&sv(&["plan", "-o", "out.swisplan", "-5"]), &["o"]).unwrap();
        assert_eq!(a.get("o"), Some("out.swisplan"));
        // unknown single-dash tokens stay positional
        assert_eq!(a.positional(), &["plan".to_string(), "-5".to_string()]);
        assert!(parse(&sv(&["-o"]), &["o"]).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(parse(&sv(&["--net"]), &["net"]).is_err());
        let a = parse(&sv(&["--n", "x"]), &["n"]).unwrap();
        assert!(a.get_usize("n", 0).is_err());
    }
}
