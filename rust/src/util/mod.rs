//! Foundation utilities: tensors, NPY/NPZ + JSON IO, deterministic RNG,
//! CLI parsing, statistics, and a mini property-test harness. These exist
//! because the offline build vendors no serde/clap/rand/proptest — see
//! DESIGN.md §7.

pub mod bench;
pub mod check;
pub mod cli;
pub mod json;
pub mod npy;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod tensor;
