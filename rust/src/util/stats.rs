//! Small statistics helpers shared by the bench harness, the
//! coordinator's latency metrics, and the loadgen recorder.

use crate::util::rng::Rng;

/// Summary statistics over a sample of f64s.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi.min(sorted.len() - 1)] * frac
}

pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary::default();
    }
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile(&sorted, 50.0),
        p95: percentile(&sorted, 95.0),
        p99: percentile(&sorted, 99.0),
    }
}

/// Geometric mean (used for cross-layer speedup roll-ups).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Fixed-capacity uniform sample of an unbounded stream (Vitter's
/// Algorithm R), deterministic in its seed: after `seen()` pushes every
/// element had probability `cap / seen` of being retained, so percentile
/// estimates over [`Reservoir::as_slice`] stay valid under sustained load
/// while memory stays bounded — the fix for the metrics vectors that
/// previously grew one entry per request forever.
#[derive(Clone, Debug)]
pub struct Reservoir {
    cap: usize,
    seen: u64,
    rng: Rng,
    buf: Vec<f64>,
}

impl Reservoir {
    pub fn new(cap: usize, seed: u64) -> Reservoir {
        Reservoir { cap: cap.max(1), seen: 0, rng: Rng::new(seed), buf: Vec::new() }
    }

    /// Offer one sample; replaces a uniformly-chosen slot once full.
    pub fn push(&mut self, x: f64) {
        self.seen += 1;
        if self.buf.len() < self.cap {
            self.buf.push(x);
        } else {
            // element i (1-based) keeps a cap/i retention probability
            let j = self.rng.below(self.seen);
            if (j as usize) < self.cap {
                self.buf[j as usize] = x;
            }
        }
    }

    /// Total elements offered (>= the retained count).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// The retained sample, unordered.
    pub fn as_slice(&self) -> &[f64] {
        &self.buf
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Sorted copy of the sample plus its [`Summary`] (percentiles are
    /// exact below capacity, an unbiased estimate beyond it).
    pub fn summary(&self) -> Summary {
        summarize(&self.buf)
    }
}

/// Root mean square error between two slices.
pub fn rmse(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    let s: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    (s / a.len() as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
    }

    #[test]
    fn geomean_of_twos() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn rmse_zero_for_equal() {
        assert_eq!(rmse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((rmse(&[0.0], &[2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn reservoir_below_capacity_is_exact() {
        let mut r = Reservoir::new(16, 1);
        for i in 0..10 {
            r.push(i as f64);
        }
        assert_eq!(r.seen(), 10);
        assert_eq!(r.as_slice(), (0..10).map(|i| i as f64).collect::<Vec<_>>());
    }

    #[test]
    fn reservoir_is_bounded_and_deterministic() {
        let run = || {
            let mut r = Reservoir::new(64, 9);
            for i in 0..10_000 {
                r.push(i as f64);
            }
            r.as_slice().to_vec()
        };
        let a = run();
        assert_eq!(a.len(), 64);
        assert_eq!(a, run(), "same seed must retain the same sample");
    }

    #[test]
    fn reservoir_sample_is_roughly_uniform() {
        // push 0..20k; the retained sample's mean must sit near the
        // stream mean (a sample biased toward early or late entries —
        // the classic off-by-one in Algorithm R — lands far away)
        let mut r = Reservoir::new(512, 3);
        let n = 20_000usize;
        for i in 0..n {
            r.push(i as f64);
        }
        let mean = r.as_slice().iter().sum::<f64>() / r.as_slice().len() as f64;
        let want = (n as f64 - 1.0) / 2.0;
        assert!(
            (mean - want).abs() < 0.08 * n as f64,
            "sample mean {mean} vs stream mean {want}"
        );
    }
}
