//! Small statistics helpers shared by the bench harness, the
//! coordinator's latency metrics, and the loadgen recorder.

use crate::util::rng::Rng;

/// Summary statistics over a sample of f64s.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi.min(sorted.len() - 1)] * frac
}

pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary::default();
    }
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile(&sorted, 50.0),
        p95: percentile(&sorted, 95.0),
        p99: percentile(&sorted, 99.0),
    }
}

/// Geometric mean (used for cross-layer speedup roll-ups).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Fixed-capacity uniform sample of an unbounded stream (Vitter's
/// Algorithm R), deterministic in its seed: after `seen()` pushes every
/// element had probability `cap / seen` of being retained, so percentile
/// estimates over [`Reservoir::as_slice`] stay valid under sustained load
/// while memory stays bounded — the fix for the metrics vectors that
/// previously grew one entry per request forever.
#[derive(Clone, Debug)]
pub struct Reservoir {
    cap: usize,
    seen: u64,
    rng: Rng,
    buf: Vec<f64>,
}

impl Reservoir {
    pub fn new(cap: usize, seed: u64) -> Reservoir {
        Reservoir { cap: cap.max(1), seen: 0, rng: Rng::new(seed), buf: Vec::new() }
    }

    /// Offer one sample; replaces a uniformly-chosen slot once full.
    pub fn push(&mut self, x: f64) {
        self.seen += 1;
        if self.buf.len() < self.cap {
            self.buf.push(x);
        } else {
            // element i (1-based) keeps a cap/i retention probability
            let j = self.rng.below(self.seen);
            if (j as usize) < self.cap {
                self.buf[j as usize] = x;
            }
        }
    }

    /// Total elements offered (>= the retained count).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// The retained sample, unordered.
    pub fn as_slice(&self) -> &[f64] {
        &self.buf
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Sorted copy of the sample plus its [`Summary`] (percentiles are
    /// exact below capacity, an unbiased estimate beyond it).
    pub fn summary(&self) -> Summary {
        summarize(&self.buf)
    }

    /// Fold another reservoir into this one WITHOUT bias: after the
    /// merge, every element ever offered to either side has retention
    /// probability `cap / (seen_a + seen_b)` (up to sampling noise).
    ///
    /// Re-offering the other side's retained slice through [`push`]
    /// (what the loadgen recorder used to do) over-weights it badly:
    /// each retained element stands for `seen_b / |buf_b|` originals but
    /// was offered as one, so a worker that saw 10x the traffic counted
    /// the same as one that saw a trickle. Here each retained element
    /// carries its stream weight and slots are filled by mass-weighted
    /// source draws — the weighted-Algorithm-R equivalent for merging
    /// two finished reservoirs. Draws come from `self`'s rng stream, so
    /// the merge is deterministic in (self, other).
    ///
    /// [`push`]: Reservoir::push
    pub fn merge(&mut self, other: &Reservoir) {
        if other.seen == 0 {
            return;
        }
        if self.seen == 0 {
            self.seen = other.seen;
            self.buf = other.buf.clone();
            return;
        }
        let total = self.seen + other.seen;
        let both_exact =
            self.seen == self.buf.len() as u64 && other.seen == other.buf.len() as u64;
        if both_exact && (self.buf.len() + other.buf.len()) <= self.cap {
            // both sides fully retained their streams: exact union
            self.buf.extend_from_slice(&other.buf);
            self.seen = total;
            return;
        }
        // mass-weighted two-stage resampling: side X holds stream mass
        // seen_x spread over |buf_x| retained elements. Every output
        // slot independently draws its source with probability
        // proportional to the STREAM mass (seen_x / total — constant,
        // not depleting: elements are drawn with replacement anyway, and
        // depleting the mass per draw would bias the heavy side low),
        // then a uniform element from that side — so a side that
        // retained few elements (smaller cap) still contributes exactly
        // its stream share, cap * seen_x / total in expectation, for any
        // buffer sizes.
        let a: Vec<f64> = std::mem::take(&mut self.buf);
        let b: &[f64] = &other.buf;
        let pa = self.seen as f64 / total as f64;
        let mut out = Vec::with_capacity(self.cap);
        for _ in 0..self.cap {
            let from_a = if b.is_empty() {
                true
            } else if a.is_empty() {
                false
            } else {
                self.rng.range_f64(0.0, 1.0) < pa
            };
            let src = if from_a { &a } else { b };
            let j = self.rng.below(src.len() as u64) as usize;
            out.push(src[j]);
        }
        self.buf = out;
        self.seen = total;
    }
}

/// Root mean square error between two slices.
pub fn rmse(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    let s: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    (s / a.len() as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
    }

    #[test]
    fn geomean_of_twos() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn rmse_zero_for_equal() {
        assert_eq!(rmse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((rmse(&[0.0], &[2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn reservoir_below_capacity_is_exact() {
        let mut r = Reservoir::new(16, 1);
        for i in 0..10 {
            r.push(i as f64);
        }
        assert_eq!(r.seen(), 10);
        assert_eq!(r.as_slice(), (0..10).map(|i| i as f64).collect::<Vec<_>>());
    }

    #[test]
    fn reservoir_is_bounded_and_deterministic() {
        let run = || {
            let mut r = Reservoir::new(64, 9);
            for i in 0..10_000 {
                r.push(i as f64);
            }
            r.as_slice().to_vec()
        };
        let a = run();
        assert_eq!(a.len(), 64);
        assert_eq!(a, run(), "same seed must retain the same sample");
    }

    #[test]
    fn merge_below_capacity_is_exact_union() {
        let mut a = Reservoir::new(16, 1);
        let mut b = Reservoir::new(16, 2);
        for i in 0..5 {
            a.push(i as f64);
            b.push(100.0 + i as f64);
        }
        a.merge(&b);
        assert_eq!(a.seen(), 10);
        let mut got = a.as_slice().to_vec();
        got.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert_eq!(got, vec![0.0, 1.0, 2.0, 3.0, 4.0, 100.0, 101.0, 102.0, 103.0, 104.0]);
        // merging an empty side is a no-op; merging INTO empty copies
        let empty = Reservoir::new(16, 3);
        let before = a.as_slice().to_vec();
        a.merge(&empty);
        assert_eq!(a.as_slice(), before);
        let mut fresh = Reservoir::new(16, 4);
        fresh.merge(&a);
        assert_eq!(fresh.seen(), 10);
        assert_eq!(fresh.as_slice().len(), 10);
    }

    #[test]
    fn merge_is_deterministic_and_bounded() {
        let build = |seed: u64, lo: usize, hi: usize| {
            let mut r = Reservoir::new(64, seed);
            for i in lo..hi {
                r.push(i as f64);
            }
            r
        };
        let run = || {
            let mut a = build(7, 0, 5000);
            let b = build(8, 5000, 9000);
            a.merge(&b);
            a.as_slice().to_vec()
        };
        let got = run();
        assert_eq!(got.len(), 64);
        assert_eq!(got, run(), "merge must be deterministic in (self, other)");
    }

    #[test]
    fn merge_retention_is_proportional_to_stream_mass() {
        // Property test for the weighted merge: worker A saw n_a zeros,
        // worker B saw n_b ones (both far past capacity, so both sides
        // are downsampled). After the merge the fraction of ones must be
        // ~ n_b / (n_a + n_b) — the per-element retention probability
        // cap/total the doc promises. The old re-push merge lands near
        // |buf_b| / (|buf_a| + |buf_b|) = 0.5 instead, far outside the
        // tolerance for the 4:1 mass split below.
        for (seed, n_a, n_b) in [(11u64, 40_000u64, 10_000u64), (12, 8_000, 32_000), (13, 20_000, 20_000)] {
            let mut a = Reservoir::new(512, seed);
            for _ in 0..n_a {
                a.push(0.0);
            }
            let mut b = Reservoir::new(512, seed ^ 0x9E37);
            for _ in 0..n_b {
                b.push(1.0);
            }
            a.merge(&b);
            assert_eq!(a.seen(), n_a + n_b);
            assert_eq!(a.as_slice().len(), 512);
            let ones = a.as_slice().iter().filter(|&&x| x == 1.0).count() as f64;
            let frac = ones / 512.0;
            let want = n_b as f64 / (n_a + n_b) as f64;
            assert!(
                (frac - want).abs() < 0.07,
                "seed {seed}: merged one-fraction {frac} vs stream share {want}"
            );
        }
    }

    #[test]
    fn merge_with_unequal_caps_keeps_mass_weights() {
        // the other side retaining fewer elements (smaller cap) must not
        // shrink its influence: weights are per-stream, not per-slot
        let mut a = Reservoir::new(256, 21);
        for _ in 0..10_000 {
            a.push(0.0);
        }
        let mut b = Reservoir::new(32, 22);
        for _ in 0..10_000 {
            b.push(1.0);
        }
        a.merge(&b);
        let ones = a.as_slice().iter().filter(|&&x| x == 1.0).count() as f64;
        let frac = ones / a.as_slice().len() as f64;
        assert!((frac - 0.5).abs() < 0.1, "equal masses must merge ~50/50, got {frac}");
    }

    #[test]
    fn reservoir_sample_is_roughly_uniform() {
        // push 0..20k; the retained sample's mean must sit near the
        // stream mean (a sample biased toward early or late entries —
        // the classic off-by-one in Algorithm R — lands far away)
        let mut r = Reservoir::new(512, 3);
        let n = 20_000usize;
        for i in 0..n {
            r.push(i as f64);
        }
        let mean = r.as_slice().iter().sum::<f64>() / r.as_slice().len() as f64;
        let want = (n as f64 - 1.0) / 2.0;
        assert!(
            (mean - want).abs() < 0.08 * n as f64,
            "sample mean {mean} vs stream mean {want}"
        );
    }
}
