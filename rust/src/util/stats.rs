//! Small statistics helpers shared by the bench harness and the
//! coordinator's latency metrics.

/// Summary statistics over a sample of f64s.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi.min(sorted.len() - 1)] * frac
}

pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary::default();
    }
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile(&sorted, 50.0),
        p95: percentile(&sorted, 95.0),
        p99: percentile(&sorted, 99.0),
    }
}

/// Geometric mean (used for cross-layer speedup roll-ups).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Root mean square error between two slices.
pub fn rmse(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    let s: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    (s / a.len() as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
    }

    #[test]
    fn geomean_of_twos() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn rmse_zero_for_equal() {
        assert_eq!(rmse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((rmse(&[0.0], &[2.0]) - 2.0).abs() < 1e-12);
    }
}
