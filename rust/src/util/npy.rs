//! Minimal NPY/NPZ reader + NPY writer — the interchange format between
//! the Python build path (`np.savez`) and the Rust runtime/tests. Supports
//! C-order arrays of f32/f64/i8/u8/i32/i64 which is all the build emits.

use std::collections::HashMap;
use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::tensor::Tensor;

/// A loaded array, type-erased.
#[derive(Clone, Debug)]
pub enum NpyArray {
    F32(Tensor<f32>),
    F64(Tensor<f64>),
    I8(Tensor<i8>),
    U8(Tensor<u8>),
    I32(Tensor<i32>),
    I64(Tensor<i64>),
}

impl NpyArray {
    pub fn shape(&self) -> &[usize] {
        match self {
            NpyArray::F32(t) => t.shape(),
            NpyArray::F64(t) => t.shape(),
            NpyArray::I8(t) => t.shape(),
            NpyArray::U8(t) => t.shape(),
            NpyArray::I32(t) => t.shape(),
            NpyArray::I64(t) => t.shape(),
        }
    }

    /// Convert to f32 tensor (lossy for i64 > 2^24 — fine for our data).
    pub fn as_f32(&self) -> Tensor<f32> {
        match self {
            NpyArray::F32(t) => t.clone(),
            NpyArray::F64(t) => t.map(|x| x as f32),
            NpyArray::I8(t) => t.map(|x| x as f32),
            NpyArray::U8(t) => t.map(|x| x as f32),
            NpyArray::I32(t) => t.map(|x| x as f32),
            NpyArray::I64(t) => t.map(|x| x as f32),
        }
    }

    pub fn as_f64(&self) -> Tensor<f64> {
        match self {
            NpyArray::F32(t) => t.map(|x| x as f64),
            NpyArray::F64(t) => t.clone(),
            NpyArray::I8(t) => t.map(|x| x as f64),
            NpyArray::U8(t) => t.map(|x| x as f64),
            NpyArray::I32(t) => t.map(|x| x as f64),
            NpyArray::I64(t) => t.map(|x| x as f64),
        }
    }

    pub fn as_i64(&self) -> Tensor<i64> {
        match self {
            NpyArray::F32(t) => t.map(|x| x as i64),
            NpyArray::F64(t) => t.map(|x| x as i64),
            NpyArray::I8(t) => t.map(|x| x as i64),
            NpyArray::U8(t) => t.map(|x| x as i64),
            NpyArray::I32(t) => t.map(|x| x as i64),
            NpyArray::I64(t) => t.clone(),
        }
    }
}

fn parse_header(hdr: &str) -> Result<(String, bool, Vec<usize>)> {
    // header is a python dict literal:
    // {'descr': '<f8', 'fortran_order': False, 'shape': (8, 64), }
    let descr = hdr
        .split("'descr':")
        .nth(1)
        .and_then(|s| s.split('\'').nth(1))
        .context("npy header missing descr")?
        .to_string();
    let fortran = hdr
        .split("'fortran_order':")
        .nth(1)
        .context("npy header missing fortran_order")?
        .trim_start()
        .starts_with("True");
    let shape_str = hdr
        .split("'shape':")
        .nth(1)
        .and_then(|s| s.split('(').nth(1))
        .and_then(|s| s.split(')').next())
        .context("npy header missing shape")?;
    let shape: Vec<usize> = shape_str
        .split(',')
        .map(|t| t.trim())
        .filter(|t| !t.is_empty())
        .map(|t| t.parse::<usize>().context("bad shape dim"))
        .collect::<Result<_>>()?;
    Ok((descr, fortran, shape))
}

/// Parse a full .npy byte buffer.
pub fn parse_npy(buf: &[u8]) -> Result<NpyArray> {
    if buf.len() < 10 || &buf[..6] != b"\x93NUMPY" {
        bail!("not an npy file");
    }
    let major = buf[6];
    let (hlen, hstart) = if major == 1 {
        (u16::from_le_bytes([buf[8], buf[9]]) as usize, 10)
    } else {
        (
            u32::from_le_bytes([buf[8], buf[9], buf[10], buf[11]]) as usize,
            12,
        )
    };
    let hdr = std::str::from_utf8(&buf[hstart..hstart + hlen])?;
    let (descr, fortran, shape) = parse_header(hdr)?;
    if fortran {
        bail!("fortran-order npy not supported");
    }
    let n: usize = shape.iter().product();
    let data = &buf[hstart + hlen..];
    macro_rules! load {
        ($t:ty, $w:expr, $variant:ident) => {{
            if data.len() < n * $w {
                bail!("npy data truncated: want {} bytes, have {}", n * $w, data.len());
            }
            let mut v = Vec::with_capacity(n);
            for i in 0..n {
                let mut b = [0u8; $w];
                b.copy_from_slice(&data[i * $w..(i + 1) * $w]);
                v.push(<$t>::from_le_bytes(b));
            }
            Ok(NpyArray::$variant(Tensor::new(&shape, v)?))
        }};
    }
    match descr.as_str() {
        "<f4" => load!(f32, 4, F32),
        "<f8" => load!(f64, 8, F64),
        "|i1" | "<i1" => load!(i8, 1, I8),
        "|u1" | "<u1" => load!(u8, 1, U8),
        "<i4" => load!(i32, 4, I32),
        "<i8" => load!(i64, 8, I64),
        other => bail!("unsupported npy dtype {other}"),
    }
}

/// Load a standalone .npy file.
pub fn load_npy(path: &Path) -> Result<NpyArray> {
    let buf = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    parse_npy(&buf)
}

/// Load every array in an .npz (zip of .npy entries).
pub fn load_npz(path: &Path) -> Result<HashMap<String, NpyArray>> {
    let f = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let mut zip = zip::ZipArchive::new(f)?;
    let mut out = HashMap::new();
    for i in 0..zip.len() {
        let mut entry = zip.by_index(i)?;
        let name = entry
            .name()
            .strip_suffix(".npy")
            .unwrap_or(entry.name())
            .to_string();
        let mut buf = Vec::with_capacity(entry.size() as usize);
        entry.read_to_end(&mut buf)?;
        out.insert(name, parse_npy(&buf)?);
    }
    Ok(out)
}

/// Serialize an f32 tensor as .npy bytes (version 1.0).
pub fn to_npy_f32(t: &Tensor<f32>) -> Vec<u8> {
    let shape = t
        .shape()
        .iter()
        .map(|d| d.to_string())
        .collect::<Vec<_>>()
        .join(", ");
    let trail = if t.shape().len() == 1 { "," } else { "" };
    let mut hdr = format!(
        "{{'descr': '<f4', 'fortran_order': False, 'shape': ({shape}{trail}), }}"
    );
    // pad to 64-byte alignment incl. 10-byte preamble, newline-terminated
    let total = 10 + hdr.len() + 1;
    let pad = (64 - total % 64) % 64;
    hdr.push_str(&" ".repeat(pad));
    hdr.push('\n');
    let mut out = Vec::with_capacity(10 + hdr.len() + t.len() * 4);
    out.extend_from_slice(b"\x93NUMPY\x01\x00");
    out.extend_from_slice(&(hdr.len() as u16).to_le_bytes());
    out.extend_from_slice(hdr.as_bytes());
    for &x in t.data() {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Write a single .npy file.
pub fn save_npy_f32(path: &Path, t: &Tensor<f32>) -> Result<()> {
    std::fs::write(path, to_npy_f32(t))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn npy_roundtrip_f32() {
        let t = Tensor::new(&[2, 3], vec![1.0f32, -2.0, 3.5, 0.0, 7.25, -0.5]).unwrap();
        let bytes = to_npy_f32(&t);
        match parse_npy(&bytes).unwrap() {
            NpyArray::F32(u) => assert_eq!(u, t),
            _ => panic!("wrong dtype"),
        }
    }

    #[test]
    fn npy_1d_roundtrip() {
        let t = Tensor::new(&[4], vec![1.0f32, 2.0, 3.0, 4.0]).unwrap();
        let arr = parse_npy(&to_npy_f32(&t)).unwrap();
        assert_eq!(arr.shape(), &[4]);
    }

    #[test]
    fn npy_scalar_roundtrip() {
        let t = Tensor::new(&[], vec![42.0f32]).unwrap();
        let arr = parse_npy(&to_npy_f32(&t)).unwrap();
        assert_eq!(arr.shape(), &[] as &[usize]);
        assert_eq!(arr.as_f32().data(), &[42.0]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_npy(b"not an npy").is_err());
    }

    #[test]
    fn header_parser() {
        let (d, f, s) =
            parse_header("{'descr': '<f8', 'fortran_order': False, 'shape': (8, 64), }")
                .unwrap();
        assert_eq!(d, "<f8");
        assert!(!f);
        assert_eq!(s, vec![8, 64]);
        let (_, _, s) =
            parse_header("{'descr': '<i8', 'fortran_order': False, 'shape': (), }").unwrap();
        assert!(s.is_empty());
    }
}
