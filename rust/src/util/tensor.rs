//! Minimal dense tensor (row-major f32/f64/i64) — the library's common
//! currency for weights, activations and golden data. Deliberately small:
//! shape + flat buffer + a few views; heavy math lives in the consumers.

use anyhow::{bail, Result};

/// Row-major dense tensor over `T`.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor<T> {
    shape: Vec<usize>,
    data: Vec<T>,
}

pub type TensorF32 = Tensor<f32>;
pub type TensorF64 = Tensor<f64>;

impl<T: Copy + Default> Tensor<T> {
    pub fn new(shape: &[usize], data: Vec<T>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!(
                "shape {:?} (= {} elems) does not match data length {}",
                shape,
                n,
                data.len()
            );
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor {
            shape: shape.to_vec(),
            data: vec![T::default(); shape.iter().product()],
        }
    }

    pub fn scalar(v: T) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    #[inline]
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn data(&self) -> &[T] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<T> {
        self.data
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Reshape (must preserve element count).
    pub fn reshape(&self, shape: &[usize]) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("cannot reshape {:?} -> {:?}", self.shape, shape);
        }
        Ok(Tensor { shape: shape.to_vec(), data: self.data.clone() })
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1usize; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    #[inline]
    pub fn at(&self, idx: &[usize]) -> T {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        let strides = self.strides();
        for (i, &ix) in idx.iter().enumerate() {
            debug_assert!(ix < self.shape[i]);
            off += ix * strides[i];
        }
        self.data[off]
    }

    /// 2-D element access.
    #[inline]
    pub fn at2(&self, r: usize, c: usize) -> T {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[r * self.shape[1] + c]
    }

    /// Rows view for 2-D tensors.
    pub fn row(&self, r: usize) -> &[T] {
        debug_assert_eq!(self.shape.len(), 2);
        let c = self.shape[1];
        &self.data[r * c..(r + 1) * c]
    }

    pub fn map<U: Copy + Default>(&self, f: impl Fn(T) -> U) -> Tensor<U> {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }
}

impl Tensor<f32> {
    pub fn to_f64(&self) -> Tensor<f64> {
        self.map(|x| x as f64)
    }
}

impl Tensor<f64> {
    pub fn to_f32(&self) -> Tensor<f32> {
        self.map(|x| x as f32)
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &x| m.max(x.abs()))
    }
}

/// Max |a-b| over two equal-shaped tensors.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .fold(0.0f32, |m, (&x, &y)| m.max((x - y).abs()))
}

/// allclose with absolute + relative tolerance (numpy semantics).
pub fn allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> bool {
    a.len() == b.len()
        && a.iter()
            .zip(b)
            .all(|(&x, &y)| (x - y).abs() <= atol + rtol * y.abs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_shape() {
        assert!(Tensor::new(&[2, 3], vec![0f32; 6]).is_ok());
        assert!(Tensor::new(&[2, 3], vec![0f32; 5]).is_err());
    }

    #[test]
    fn strides_row_major() {
        let t = Tensor::<f32>::zeros(&[2, 3, 4]);
        assert_eq!(t.strides(), vec![12, 4, 1]);
    }

    #[test]
    fn index_access() {
        let t = Tensor::new(&[2, 3], (0..6).map(|x| x as f32).collect()).unwrap();
        assert_eq!(t.at(&[1, 2]), 5.0);
        assert_eq!(t.at2(0, 1), 1.0);
        assert_eq!(t.row(1), &[3.0, 4.0, 5.0]);
    }

    #[test]
    fn reshape_roundtrip() {
        let t = Tensor::new(&[4, 2], vec![1f32; 8]).unwrap();
        let r = t.reshape(&[2, 2, 2]).unwrap();
        assert_eq!(r.shape(), &[2, 2, 2]);
        assert!(t.reshape(&[3, 3]).is_err());
    }

    #[test]
    fn allclose_tolerances() {
        assert!(allclose(&[1.0, 2.0], &[1.0 + 1e-6, 2.0], 1e-5, 0.0));
        assert!(!allclose(&[1.0], &[1.1], 1e-5, 1e-5));
    }
}
