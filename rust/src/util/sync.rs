//! Sync-primitive facade: `std::sync` normally, `loom`'s modeled
//! primitives under `--cfg loom`.
//!
//! The concurrency modules the loom models exercise (admission queue,
//! trace ring, edge token bucket, obs level gate, edge server
//! stop/rebalance flags) import their primitives from here instead of
//! `std::sync`, so `RUSTFLAGS="--cfg loom" cargo test --test
//! loom_models` swaps in the model checker's instrumented types without
//! touching the call sites. In a normal build every re-export below is
//! exactly the `std` type — zero runtime difference.

#[cfg(loom)]
pub use loom::sync::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};
#[cfg(loom)]
pub use loom::thread;

#[cfg(not(loom))]
pub use std::sync::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};
#[cfg(not(loom))]
pub use std::thread;

pub mod atomic {
    #[cfg(loom)]
    pub use loom::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};

    #[cfg(not(loom))]
    pub use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
}

/// Lock a mutex, recovering the data from a poisoned lock instead of
/// panicking.
///
/// Every shared structure in the serving path guards plain data (queues,
/// rings, maps) whose invariants hold between operations: a panic in one
/// holder cannot leave them half-updated in a way later readers
/// mis-handle, so continuing past poison is strictly better than
/// cascading the panic into every other request thread.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}
