//! # SWIS — Shared Weight bIt Sparsity
//!
//! Reproduction of *SWIS — Shared Weight bIt Sparsity for Efficient Neural
//! Network Acceleration* (Li, Romaszkan, Graening, Gupta — TinyML Research
//! Symposium 2021) as a three-layer Rust + JAX + Pallas system:
//!
//! * [`quant`] — the SWIS / SWIS-C quantizers, MSE++ metric, packed
//!   storage format, truncation baselines (paper Sec. 2, 4.1).
//! * [`schedule`] — filter scheduling across systolic-array column groups
//!   (paper Sec. 4.3).
//! * [`arch`] — 28 nm PE area/energy models (single/double-shift,
//!   fixed-point, BitFusion) and storage-compression models incl. DPRed
//!   (paper Sec. 3.1, 3.3).
//! * [`sim`] — output-stationary systolic-array cycle & memory-traffic
//!   simulator, SCALE-Sim-class (paper Sec. 3.2, 5.2).
//! * [`nets`] — layer shape tables: ResNet-18, MobileNet-v2, VGG-16 and
//!   the TinyCNN accuracy proxy.
//! * [`analysis`] — lossless-quantization probability (paper Eq. 8-10).
//! * [`runtime`] — PJRT client wrapper executing AOT-lowered HLO-text
//!   artifacts produced by `python/compile/aot.py`.
//! * [`coordinator`] — the serving layer: dynamic batcher, router,
//!   metrics; Python never runs on the request path.
//! * [`util`] — tensors, NPY/NPZ + JSON IO, RNG, CLI, property-testing.

pub mod analysis;
pub mod arch;
pub mod coordinator;
pub mod nets;
pub mod quant;
pub mod runtime;
pub mod sim;
pub mod schedule;
pub mod util;
