//! # SWIS — Shared Weight bIt Sparsity
//!
//! Reproduction of *SWIS — Shared Weight bIt Sparsity for Efficient Neural
//! Network Acceleration* (Li, Romaszkan, Graening, Gupta — TinyML Research
//! Symposium 2021) as a three-layer Rust + JAX + Pallas system.
//!
//! ## The public facade: config → plan → session
//!
//! Every consumer enters through [`api`]: a typed, builder-style
//! [`api::EngineConfig`] feeds [`api::Engine::prepare`], which runs the
//! paper's offline decomposition/scheduling step ONCE and returns an
//! [`api::EnginePlan`] — the planner output, packed layers and prepared
//! kernel planes as a first-class, `Arc`-shareable object that
//! serializes to/from a versioned `.swisplan` container.
//! [`api::Session`] (sync `run`, plus a batched streaming handle) is the
//! single inference entry; serving (`swis serve --plan`), evaluation,
//! load generation and the benches all load plans instead of
//! re-quantizing. Failures on every facade seam are the typed
//! [`SwisError`] taxonomy ([`error`]) — match the class, not the
//! message.
//!
//! ## Layer map
//!
//! * [`quant`] — the SWIS / SWIS-C quantizers, MSE++ metric, packed
//!   storage format, truncation baselines (paper Sec. 2, 4.1). The
//!   compile-path hot loop is `quant::planner`: a process-global LUT
//!   bank (combo LUTs are data-independent, built once per family and
//!   cached in `OnceLock`s), a single sweep that scores ALL shift counts
//!   `n = 1..=8` per group at once (with lossless early-exit and
//!   monotonicity pruning), and `std::thread::scope` chunking of the
//!   group sweep — so `quantize`, `schedule_layer`, and
//!   `allocate_network` scale across cores while staying bit-identical
//!   to the sequential scalar path (strict-less argmin, earliest-combo
//!   tie-break).
//! * [`schedule`] — filter scheduling across systolic-array column groups
//!   (paper Sec. 4.3); consumes the planner's all-`n` cost table in one
//!   pass instead of one `per_filter_cost` rescan per candidate count.
//! * [`arch`] — 28 nm PE area/energy models (single/double-shift,
//!   fixed-point, BitFusion) and storage-compression models incl. DPRed
//!   (paper Sec. 3.1, 3.3).
//! * [`sim`] — output-stationary systolic-array cycle & memory-traffic
//!   simulator, SCALE-Sim-class (paper Sec. 3.2, 5.2).
//! * [`exec`] — the NATIVE SWIS engine: cache-blocked, thread-parallel
//!   packed bit-serial GEMM + depthwise kernels consuming
//!   [`quant::PackedLayer`] directly, an op-graph IR ([`exec::graph`]:
//!   conv / depthwise / FC / pool / residual-add) lowered from any
//!   [`nets::Network`] descriptor, and the graph executor
//!   ([`exec::NativeModel`]) that runs the WHOLE zoo — TinyCNN,
//!   MobileNet-v2 (inverted residuals), ResNet-18 (skips + downsample),
//!   VGG-16 — under fp32 / SWIS / SWIS-C / truncation transforms.
//!   The kernel inner loop dispatches at runtime across SIMD backends
//!   ([`exec::simd`]: AVX2 / NEON / portable-vector / scalar, selected
//!   by `is_x86_feature_detected!` with the scalar plane walk as the
//!   always-correct fallback, `SWIS_FORCE_SCALAR=1` as the escape
//!   hatch), and [`exec::tune`] is the bench-driven autotuner whose
//!   winning [`exec::TuneParams`] (variant x row-block x group-chunk x
//!   thread-split) persist inside `.swisplan` containers — pinned to
//!   the CPU signature that produced them, dropped and re-derivable on
//!   any other host. Every kernel path also skips zero activation
//!   lanes: per-row-tile zero masks AND into the packed sign-split
//!   bitmasks before the plane walk (exact, since a zero activation
//!   contributes exactly zero), with a density screen that disables
//!   masking on near-dense tiles so the adversarial dense case stays
//!   regression-free. `tests/simd_equiv.rs` holds every variant
//!   bit-identical to the scalar walk, masked and unmasked.
//! * [`nets`] — layer shape tables: ResNet-18, MobileNet-v2, VGG-16 and
//!   the TinyCNN accuracy proxy.
//! * [`eval`] — the accuracy/compression sweep: nets x schemes x
//!   bit-widths on the native executor, per-layer MSE vs fp32, top-1
//!   agreement on a fixed probe batch, measured `.swis` container
//!   compression; emits `BENCH_accuracy.json` (`swis eval`).
//! * [`analysis`] — lossless-quantization probability (paper Eq. 8-10).
//! * [`runtime`] — the execution backends behind serving: the
//!   [`runtime::Backend`] trait (PJRT/AOT over HLO-text artifacts from
//!   `python/compile/aot.py`, native over [`exec`]) and the
//!   [`runtime::BackendFactory`] recipe the pool uses to build one
//!   backend per worker (native: `Arc`-shared prepared variants, warm-up
//!   once; PJRT: per-thread compiles).
//! * [`coordinator`] — the serving layer: bounded two-lane
//!   [`coordinator::AdmissionQueue`] (`try_submit -> Busy` backpressure,
//!   deadline shedding, priority lanes), the
//!   [`coordinator::WorkerPool`] of N backend-owning workers with
//!   variant affinity, per-worker dynamic batching, metrics; the
//!   single-worker [`coordinator::Coordinator`] facade keeps the
//!   pre-pool API. Python never runs on the request path.
//! * [`edge`] — the network edge: the length-prefixed `SWIS1` wire
//!   protocol over a std `TcpListener` (thread-per-connection
//!   reader/writer pairs), per-tenant token-bucket quotas, per-model
//!   pools from a shared plan cache, queue-depth worker rebalancing.
//!   See the "Network edge" chapter below for the byte-level contract.
//! * [`loadgen`] — open/closed-loop arrival generators, the scenario
//!   suite (steady / diurnal / flash-crowd / slow-client /
//!   deadline-mix, runnable in-process or over TCP), SLO recording
//!   (p50/p95/p99, shed/busy/timeout counts) and the sweep driver that
//!   walks worker count x batch policy x arrival rate and emits
//!   scenario-tagged `BENCH_serving.json`.
//! * [`api`] — the facade over all of the above: `EngineConfig` →
//!   `Engine::prepare` → `EnginePlan` (`.swisplan`) → `Session`.
//! * [`error`] — the crate-wide [`SwisError`] taxonomy
//!   (`Config`/`Plan`/`Io`/`Backend`/`Admission`/`Eval`).
//! * [`util`] — tensors, NPY/NPZ + JSON IO, RNG, CLI, the atomic
//!   [`util::bench::Emitter`] behind every `BENCH_*.json`,
//!   property-testing.
//!
//! ## Execution tiers — which one is authoritative for what
//!
//! Packed SWIS operands execute at four fidelities; they agree where
//! their contracts overlap, and tests pin those overlaps:
//!
//! | tier | where | computes | authoritative for |
//! |------|-------|----------|-------------------|
//! | analytic sim | [`sim`] | cycle/energy/traffic models, no data | paper performance figures (Sec. 5) |
//! | functional machine | [`sim::functional`], [`arch::pe_functional`] | exact integer MACs, cycle-faithful | hardware semantics: fold schedule, PE timing, accumulator width |
//! | native engine | [`exec`], driven via [`api::Session`] over an [`api::EnginePlan`] | the SAME integer MACs at software speed, SIMD-dispatched ([`exec::simd`]) and machine-tuned ([`exec::tune`]) | serving + zoo accuracy sweeps when PJRT is absent; bit-exact vs the functional machine (`tests/native_equiv.rs`, `tests/graph_equiv.rs`), across SIMD variants (`tests/simd_equiv.rs`) and across the `.swisplan` round-trip (`tests/plan_roundtrip.rs`) |
//! | PJRT | [`runtime`] | fp32 graph over (de)quantized weights | trained-model accuracy vs build-time goldens |
//!
//! The shared group-op arithmetic lives once, in [`exec::core`]; the
//! functional machine layers cycle accounting on top of it, the native
//! kernel layers blocking/threading, and the analytic sim prices the
//! same plane counts it executes.
//!
//! ## Model zoo coverage (native tier)
//!
//! | network | executes natively | serves via pool | weights |
//! |---------|-------------------|-----------------|---------|
//! | tinycnn | yes (graph) | `swis serve` (default; PJRT eligible) | `tinycnn_weights.npz` or surrogate |
//! | mobilenet_v2 | yes (depthwise + inverted residuals) | `swis serve --net mobilenet_v2` | `mobilenet_v2_weights.npz` or surrogate |
//! | resnet18 | yes (skips + downsample, stem max-pool) | `swis serve --net resnet18` | `resnet18_weights.npz` or surrogate |
//! | vgg16_cifar100 | yes (stage max-pools) | `swis serve --net vgg16` | `vgg16_cifar100_weights.npz` or surrogate |
//!
//! Surrogate (He-init) weights are announced loudly and stamped into
//! every `BENCH_accuracy.json` record (`"weights": "surrogate" | "npz"`)
//! so trajectory points never silently mix provenances.
//!
//! ## Precision tiers — degrade-don't-shed serving
//!
//! A `.swisplan` can carry SEVERAL shift-count variants of one network
//! as an ordered precision ladder ([`coordinator::TierPolicy`], embedded
//! via `swis plan --tiers` / [`api::EnginePlan::set_tier_policy`] as a
//! version-3 container section). Tier 0 is the highest-precision
//! quantized variant; each deeper tier trades accuracy (tracked as the
//! measured worst-layer MSE ratio vs tier 0, from
//! [`eval::derive_tier_policy`]) for latency. At admission, queue
//! pressure maps to a down-tier step (≥50% full → one tier, ≥80% → two,
//! never past the plan's floor), so an overloaded pool serves
//! lower-precision responses — counted in the `degraded` metric —
//! instead of shedding them. Per-request hints ride
//! [`coordinator::InferRequest::tier_hint`] (served in-process by
//! [`api::Session::serve`] and over the wire unchanged); a hint or
//! pressure can only LOWER precision, never raise it above what the
//! request asked for.
//!
//! | tier | meaning | typical source |
//! |------|---------|----------------|
//! | 0 | full requested precision (e.g. `swis@4`) | the request's own variant |
//! | 1..floor-1 | intermediate shift counts | queue pressure ≥ 50% / 80% |
//! | floor | deepest tier with MSE ratio ≤ the `--tier-cap` | overload ceiling; never exceeded |
//!
//! ## Network edge — the SWIS1 wire protocol
//!
//! `swis serve --listen HOST:PORT --models id=plan.swisplan,...` fronts
//! the coordinator with [`edge::EdgeServer`]: a std-`TcpListener`
//! accept loop (no HTTP/RPC dependency, same idiom as the metrics
//! exporter) with one reader/writer thread pair per connection. The
//! wire request *is* a serialized [`coordinator::InferRequest`] — the
//! networked and in-process submission paths share one type and cannot
//! drift.
//!
//! **Frame layout.** Every frame is a 10-byte header plus a bounded
//! body; all integers little-endian, `str8`/`str16` are
//! `u8`/`u16`-length-prefixed UTF-8:
//!
//! ```text
//! header: magic "SWIS1" (5 B, version in the magic) | type u8 | body_len u32
//! type 1 INFER:    seq u64 | tenant str8 | model str8 | variant str8
//!                  | tier_hint u8 | lane u8 (0=interactive,1=batch)
//!                  | flags u8 (bit0=trace) | deadline_us u64 (0=none)
//!                  | n_vals u32 | image f32 x n_vals
//! type 2 OK:       seq u64 | flags u8 (bit0=degraded) | variant str8
//!                  | n u32 | logits f32 x n
//! type 3 STATUS:   seq u64 | code u16 | msg str16
//! type 4 INFO_REQ: seq u64
//! type 5 INFO:     seq u64 | n_models u8 | per model: id str8,
//!                  h u16, w u16, c u16, tiered u8, n_variants u8,
//!                  variant str8 x n
//! ```
//!
//! `body_len` is validated against [`edge::MAX_FRAME`] (16 MiB)
//! *before* any allocation, so an adversarial length prefix costs
//! nothing. A frame that decodes short, long, or mid-stream EOF is a
//! counted protocol fault, never a panic.
//!
//! **Status codes.** One exhaustive mapping ([`edge::WireStatus`],
//! property-tested to round-trip every [`SwisError`] class):
//!
//! | code | meaning | `SwisError` class |
//! |------|---------|-------------------|
//! | 0 | ok (never in a STATUS frame) | — |
//! | 10-14 | config / plan / io / backend / eval | same-named class |
//! | 20 | admission queue full — retry with backoff | `Admission{Busy}` |
//! | 21 | deadline shed | `Admission{Shed}` |
//! | 22 | server shutting down | `Admission{Closed}` |
//! | 23 | malformed request (bad image len, unknown model/variant) | `Admission{Invalid}` |
//! | 24 | tenant over quota | `Admission{Rejected}` |
//!
//! **Tenant quotas.** Each INFER frame carries a tenant id; the edge
//! holds a per-tenant token bucket ([`edge::TenantQuotas`], `--quota-rps
//! R --quota-burst B`): buckets start full at `B`, refill at `R`
//! tokens/s capped at `B`, each admitted request spends one token.
//! Over-quota requests are answered with status 24 **on the open
//! connection** — quota refusal is a typed response, never a hangup —
//! and counted in `swis_quota_rejected_total`. No `--quota-rps` means
//! every tenant is admitted. Protocol faults (garbage magic, oversized
//! prefix, stalled reads/writes, truncation) DO close the connection,
//! each counted by class in `swis_wire_faults_total{kind=...}`.
//!
//! Workers are a shared budget (`--workers` total across all models):
//! a background rebalancer re-splits them by admission queue depth
//! (largest-remainder proportional split, every model keeps >= 1
//! worker) and swaps rebuilt pools in place — plan-cached warm-up does
//! zero re-quantization, and in-flight tickets on a retired pool still
//! answer while it drains.
//!
//! ## Observability — sparsity accounting, request tracing, metrics export
//!
//! The [`obs`] module makes the paper's "work removed" claim observable
//! at runtime, gated on a process [`obs::ObsLevel`] knob (CLI `--obs
//! off|counters|full`, env `SWIS_OBS`; default `off` costs one relaxed
//! atomic load per kernel *call*):
//!
//! * **Kernel sparsity counters** ([`obs::ExecTally`]): shift planes
//!   visited vs. dropped-empty at prepare time (weight bit sparsity) vs.
//!   skipped by the activation zero-lane mask, lanes masked, SIMD
//!   dispatch counts and scalar demotions — counted from plane metadata
//!   with the kernels' exact skip predicate, never inside a SIMD inner
//!   loop, and reconciled in kernel unit tests
//!   (`visited + skipped + dropped == walks x plane slots`).
//! * **Per-layer attribution**: `exec::model` brackets every graph node;
//!   [`api::Session::last_stats`] returns the last forward's per-layer
//!   breakdown, and a process-lifetime registry
//!   ([`obs::global_layers`]) feeds the exporters.
//! * **Request tracing** ([`obs::trace`]): at `full`, sampled requests
//!   carry span-stamped [`obs::trace::RequestTrace`]s through the pool
//!   (enqueue → degrade/shed → batch open/close → infer start/end →
//!   done/error), land in bounded per-worker rings, and ride
//!   `InferResponse` so `swis loadgen --trace-sample N` can decompose
//!   p99 into queue wait vs. batch assembly vs. compute
//!   (`BENCH_observability.json`).
//! * **Export** ([`obs::registry`], [`obs::http`]): `swis serve
//!   --metrics-addr HOST:PORT` serves Prometheus text exposition
//!   (`swis_planes_skipped_total{layer=...}`,
//!   `swis_lanes_masked_total{layer=...}`, per-lane
//!   `swis_shed_total{lane=...}`, queue-depth gauges, latency quantiles)
//!   over a std `TcpListener` — no HTTP dependency.
//!
//! ## Correctness tooling — lint, loom, sanitizers, plan verification
//!
//! The paper's claims rest on bit-exact contracts, so the repo carries
//! its own correctness layer (CI jobs `lint`, `loom`, `miri`, `tsan`):
//!
//! * **`swis lint`** (crate `rust/lint`, also `swis lint` on the CLI):
//!   a dependency-free, comment/string-aware static pass. Non-test
//!   `.unwrap()`/`.expect(` sites must fit the ratchet-down budgets in
//!   `lint/unwrap.allow`; every `unsafe` block needs an adjacent
//!   `// SAFETY:` comment and every `unsafe fn` a `# Safety` doc
//!   section; `Ordering::Relaxed`/`SeqCst` sites must match the
//!   justified manifest in `lint/atomics.allow`
//!   (Acquire/Release/AcqRel are the reviewed default); `Err(format!`/
//!   `anyhow!`/`bail!` are refused on the public seams (api,
//!   coordinator, edge, obs — seams speak [`SwisError`]); `todo!`/
//!   `unimplemented!`/`dbg!` are refused everywhere. `swis lint
//!   --fix-list` prints the allowlisted debt as a burn-down worklist.
//!   Amending an allowlist = lowering a number freely, raising one in
//!   review with a justification comment.
//! * **Loom models** (`tests/loom_models.rs`, built only under
//!   `RUSTFLAGS="--cfg loom"`): [`util::sync`] swaps `std::sync` for
//!   the vendored `loom` shim, which exhaustively explores every
//!   sequentially-consistent interleaving of the modeled serving
//!   primitives — admission two-lane push/pop/shed/close, trace-ring
//!   push vs drain, the edge token bucket race, the rebalancer's
//!   pool-swap handoff, the obs level gate — plus regression models
//!   that prove the checker still catches each pinned bug class
//!   (double-admit, lost count, missed wakeup, ABBA deadlock).
//! * **Sanitizers**: Miri runs the pointer-heavy single-threaded logic
//!   (frame codec, planner, container serialize, scalar kernels);
//!   ThreadSanitizer (nightly, `-Zsanitizer=thread`) runs the
//!   pool/edge/obs integration suites for races the extracted loom
//!   models can't see.
//! * **`swis verify-plan FILE.swisplan`** ([`api::verify_plan_file`]):
//!   statically checks every container invariant *without executing
//!   anything* — magic/version/checksum, enum tags, operand shape
//!   consistency against the layer table, the packed `.swis`
//!   plane-accounting identity, shift counts within scheme bounds, and
//!   the tagged trailer (tune shape, tier ladders that name only
//!   declared variants with monotone MSE ratios). Stricter than the
//!   loader where CI needs it: what the loader tolerates by silently
//!   dropping (foreign ladders) is an error here. CI verifies every
//!   artifact it builds before serving it.

pub mod analysis;
pub mod api;
pub mod arch;
pub mod coordinator;
pub mod edge;
pub mod error;
pub mod eval;
pub mod exec;
pub mod flags;
pub mod loadgen;
pub mod nets;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod sim;
pub mod schedule;
pub mod util;

pub use error::{AdmissionReason, SwisError, SwisResult};
