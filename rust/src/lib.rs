//! # SWIS — Shared Weight bIt Sparsity
//!
//! Reproduction of *SWIS — Shared Weight bIt Sparsity for Efficient Neural
//! Network Acceleration* (Li, Romaszkan, Graening, Gupta — TinyML Research
//! Symposium 2021) as a three-layer Rust + JAX + Pallas system:
//!
//! * [`quant`] — the SWIS / SWIS-C quantizers, MSE++ metric, packed
//!   storage format, truncation baselines (paper Sec. 2, 4.1). The
//!   compile-path hot loop is `quant::planner`: a process-global LUT
//!   bank (combo LUTs are data-independent, built once per family and
//!   cached in `OnceLock`s), a single sweep that scores ALL shift counts
//!   `n = 1..=8` per group at once (with lossless early-exit and
//!   monotonicity pruning), and `std::thread::scope` chunking of the
//!   group sweep — so `quantize`, `schedule_layer`, and
//!   `allocate_network` scale across cores while staying bit-identical
//!   to the sequential scalar path (strict-less argmin, earliest-combo
//!   tie-break).
//! * [`schedule`] — filter scheduling across systolic-array column groups
//!   (paper Sec. 4.3); consumes the planner's all-`n` cost table in one
//!   pass instead of one `per_filter_cost` rescan per candidate count.
//! * [`arch`] — 28 nm PE area/energy models (single/double-shift,
//!   fixed-point, BitFusion) and storage-compression models incl. DPRed
//!   (paper Sec. 3.1, 3.3).
//! * [`sim`] — output-stationary systolic-array cycle & memory-traffic
//!   simulator, SCALE-Sim-class (paper Sec. 3.2, 5.2).
//! * [`nets`] — layer shape tables: ResNet-18, MobileNet-v2, VGG-16 and
//!   the TinyCNN accuracy proxy.
//! * [`analysis`] — lossless-quantization probability (paper Eq. 8-10).
//! * [`runtime`] — PJRT client wrapper executing AOT-lowered HLO-text
//!   artifacts produced by `python/compile/aot.py`.
//! * [`coordinator`] — the serving layer: dynamic batcher, router,
//!   metrics; Python never runs on the request path.
//! * [`util`] — tensors, NPY/NPZ + JSON IO, RNG, CLI, property-testing.

pub mod analysis;
pub mod arch;
pub mod coordinator;
pub mod nets;
pub mod quant;
pub mod runtime;
pub mod sim;
pub mod schedule;
pub mod util;
