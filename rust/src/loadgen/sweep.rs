//! The serving sweep driver: walk worker count x batch policy x arrival
//! process, run one bounded trial per point against a fresh
//! [`WorkerPool`], and emit the `BENCH_serving.json` trajectory record
//! (throughput, tail latency, shed/busy counts per point).
//!
//! The backend factory is created ONCE for the whole sweep and shared by
//! every pool, so quantization/warm-up is paid once no matter how many
//! grid points run (the `Arc`-shared prepared variants the pool design
//! exists for).

use std::path::Path;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::arrival::{exp_gap, Arrival};
use super::recorder::{PointStats, Recorder};
use crate::coordinator::{
    Admission, BatchPolicy, InferRequest, PoolConfig, Priority, VariantSpec, WorkerPool,
};
use crate::error::{SwisError, SwisResult};
use crate::runtime::{create_factory, BackendFactory, BackendKind};
use crate::util::bench::Emitter;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// How long the collector waits for any single response before counting
/// it as a timeout (far beyond any sane service time; a hit means the
/// pool lost the request).
const CLIENT_PATIENCE: Duration = Duration::from_secs(10);

/// Synthetic probe-input generator mode.
///
/// Dense uniform pixels are the adversarial worst case for the
/// activation zero-skipping kernels (essentially nothing to skip);
/// ReLU-realistic sparse inputs show the speedup natural images (and
/// every post-ReLU interior layer) actually present.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProbeMode {
    /// Uniform-random `[0, 1)` pixels — every lane live.
    Dense,
    /// Natural-image-like sparsity: [`SPARSE_ZERO_FRACTION`] of pixels
    /// exactly zero (mimicking post-ReLU activation statistics from
    /// EIE), the rest uniform `[0, 1)`.
    Sparse,
}

/// Fraction of exactly-zero pixels in [`ProbeMode::Sparse`] probes —
/// the middle of EIE's reported 50-70% post-ReLU zero range.
pub const SPARSE_ZERO_FRACTION: f64 = 0.6;

impl ProbeMode {
    pub fn parse(s: &str) -> SwisResult<ProbeMode> {
        Ok(match s {
            "dense" => ProbeMode::Dense,
            "sparse" => ProbeMode::Sparse,
            other => {
                return Err(SwisError::config(format!(
                    "unknown probe mode '{other}' (expected dense|sparse)"
                )))
            }
        })
    }

    pub fn as_str(self) -> &'static str {
        match self {
            ProbeMode::Dense => "dense",
            ProbeMode::Sparse => "sparse",
        }
    }
}

/// The sweep grid + per-trial knobs.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    /// Worker counts to sweep.
    pub workers: Vec<usize>,
    /// Arrival processes to sweep (open-loop rates and/or closed-loop
    /// concurrencies).
    pub arrivals: Vec<Arrival>,
    /// Batch-policy straggler windows to sweep.
    pub max_waits: Vec<Duration>,
    pub max_batch: usize,
    /// Wall-clock submission window per point.
    pub duration: Duration,
    pub queue_depth: usize,
    /// Shed budget stamped on every request (None = never shed).
    pub deadline: Option<Duration>,
    pub variants: Vec<VariantSpec>,
    pub seed: u64,
    /// Probe-input generator (dense = adversarial worst case for
    /// activation sparsity; sparse = ReLU-realistic).
    pub probe: ProbeMode,
    /// Request-trace sampling passed to every pool (every Nth request;
    /// 0 = off). Traces also require the obs level to be `full` — the
    /// CLI's `--trace-sample N` raises it.
    pub trace_sample: usize,
}

impl Default for SweepConfig {
    fn default() -> SweepConfig {
        SweepConfig {
            workers: vec![1, 2, 4],
            arrivals: vec![Arrival::Poisson { rate: 150.0 }, Arrival::Poisson { rate: 300.0 }],
            max_waits: vec![Duration::from_millis(2)],
            max_batch: 64,
            duration: Duration::from_millis(400),
            queue_depth: 256,
            deadline: Some(Duration::from_millis(100)),
            variants: vec![VariantSpec::fp32(), VariantSpec::swis(3.0, 4)],
            seed: 2026,
            probe: ProbeMode::Dense,
            trace_sample: 0,
        }
    }
}

/// One grid point's result.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub workers: usize,
    /// Traffic shape that produced this point ("steady" for the classic
    /// grid sweep; scenario names from [`super::scenario`] otherwise).
    pub scenario: String,
    pub arrival: String,
    /// Offered rate (req/s) for open-loop points, 0 for closed loop.
    pub rate: f64,
    pub max_wait_ms: f64,
    /// Client-side outcome summary.
    pub stats: PointStats,
    /// Pool-side counters for the same trial.
    pub shed: u64,
    pub rejected: u64,
    /// Shed split `[interactive, batch]` — which lane paid the SLO.
    pub shed_by_lane: [u64; 2],
    /// Busy-refusal split `[interactive, batch]`.
    pub rejected_by_lane: [u64; 2],
    /// Requests the pool down-tiered under queue pressure
    /// (degrade-don't-shed; 0 unless the plan carries a tier ladder).
    pub degraded: u64,
    pub mean_batch: f64,
    /// Sampled request traces drained from the pool after the trial
    /// (empty unless `trace_sample` > 0 and the obs level is `full`).
    pub traces: Vec<crate::obs::trace::RequestTrace>,
}

/// Resolve one factory, then run every grid point on its own fresh
/// pool. Returns the points plus the RESOLVED backend name (what
/// actually served — "pjrt" | "native" — not the requested kind, so
/// trajectory records from different environments stay comparable).
pub fn run_sweep(
    dir: &Path,
    kind: BackendKind,
    cfg: &SweepConfig,
) -> SwisResult<(Vec<SweepPoint>, &'static str)> {
    let factory: Arc<dyn BackendFactory> = Arc::from(create_factory(kind, dir, &cfg.variants)?);
    run_sweep_with(factory, cfg)
}

/// [`run_sweep`] over an explicit factory — the entry the `--plan` flow
/// uses (a [`crate::runtime::NativeFactory`] over a loaded
/// [`crate::api::EnginePlan`]), so the sweep measures exactly the plan a
/// deployment would ship and pays zero quantization per grid point.
pub fn run_sweep_with(
    factory: Arc<dyn BackendFactory>,
    cfg: &SweepConfig,
) -> SwisResult<(Vec<SweepPoint>, &'static str)> {
    let backend = factory.name();
    let names: Vec<String> = cfg.variants.iter().map(|v| v.name.clone()).collect();
    // sized lazily off the first pool's reported image length, so plans
    // for non-32x32x3 nets sweep with right-sized requests
    let mut images: Vec<Vec<f32>> = Vec::new();
    let mut out = Vec::new();
    for &workers in &cfg.workers {
        for &max_wait in &cfg.max_waits {
            for (ai, arrival) in cfg.arrivals.iter().enumerate() {
                let pool = WorkerPool::start_with_factory(
                    Arc::clone(&factory),
                    PoolConfig {
                        workers,
                        policy: BatchPolicy { max_batch: cfg.max_batch, max_wait },
                        queue_depth: cfg.queue_depth,
                        trace_sample: cfg.trace_sample,
                    },
                )?;
                if images.is_empty() {
                    images = gen_images_mode(16, pool.image_len(), cfg.seed, cfg.probe);
                }
                let seed = cfg.seed ^ ((workers as u64) << 32) ^ (ai as u64 + 1);
                let stats = match *arrival {
                    Arrival::Poisson { rate } => {
                        run_open_loop(&pool, rate, cfg, &names, &images, seed)?
                    }
                    Arrival::Closed { concurrency } => {
                        run_closed_loop(&pool, concurrency, cfg, &names, &images, seed)
                    }
                };
                let snap = pool.metrics.snapshot();
                let traces = pool.drain_traces();
                out.push(SweepPoint {
                    workers,
                    scenario: "steady".to_string(),
                    arrival: arrival.label(),
                    rate: arrival.rate(),
                    max_wait_ms: max_wait.as_secs_f64() * 1e3,
                    stats,
                    shed: snap.shed,
                    rejected: snap.rejected,
                    shed_by_lane: snap.shed_by_lane,
                    rejected_by_lane: snap.rejected_by_lane,
                    degraded: snap.degraded,
                    mean_batch: snap.mean_batch,
                    traces,
                });
                pool.shutdown()?;
            }
        }
    }
    Ok((out, backend))
}

/// Open loop: paced Poisson submission on this thread, collection on a
/// companion thread so slow responses never distort the arrival process.
fn run_open_loop(
    pool: &WorkerPool,
    rate: f64,
    cfg: &SweepConfig,
    names: &[String],
    images: &[Vec<f32>],
    seed: u64,
) -> SwisResult<PointStats> {
    let (tx, rx) = mpsc::channel::<crate::coordinator::Ticket>();
    let collector = std::thread::spawn(move || {
        let mut rec = Recorder::new(1);
        for ticket in rx {
            match ticket.recv_timeout(CLIENT_PATIENCE) {
                Ok(Ok(resp)) => {
                    rec.record_ok(resp.total);
                    if resp.degraded {
                        rec.record_degraded();
                    }
                }
                Ok(Err(e)) => rec.record_err(&e),
                Err(_) => rec.record_timeout(),
            }
        }
        rec
    });

    let mut rng = Rng::new(seed);
    let t0 = Instant::now();
    let end = t0 + cfg.duration;
    let mut next = t0;
    let mut busy = 0u64;
    let mut i = 0usize;
    while next < end {
        let now = Instant::now();
        if now < next {
            std::thread::sleep(next - now);
        }
        let pri = if i % 2 == 0 { Priority::Interactive } else { Priority::Batch };
        let req = InferRequest::new(names[i % names.len()].as_str())
            .image(images[i % images.len()].clone())
            .priority(pri)
            .deadline_opt(cfg.deadline);
        match pool.try_submit(req)? {
            Admission::Accepted(t) => {
                let _ = tx.send(t);
            }
            Admission::Busy => busy += 1,
        }
        i += 1;
        next += Duration::from_secs_f64(exp_gap(&mut rng, rate));
    }
    drop(tx);
    let mut rec = collector
        .join()
        .map_err(|_| SwisError::backend("loadgen collector panicked"))?;
    rec.busy = busy;
    Ok(rec.stats(t0.elapsed()))
}

/// Closed loop: `concurrency` clients, zero think time, client-measured
/// latency (submit -> response receipt).
fn run_closed_loop(
    pool: &WorkerPool,
    concurrency: usize,
    cfg: &SweepConfig,
    names: &[String],
    images: &[Vec<f32>],
    seed: u64,
) -> PointStats {
    let t0 = Instant::now();
    let end = t0 + cfg.duration;
    let recs: Vec<Recorder> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..concurrency.max(1))
            .map(|c| {
                s.spawn(move || {
                    let mut rec = Recorder::new(seed ^ c as u64);
                    let pri =
                        if c % 2 == 0 { Priority::Interactive } else { Priority::Batch };
                    let mut i = c;
                    while Instant::now() < end {
                        let req = InferRequest::new(names[i % names.len()].as_str())
                            .image(images[i % images.len()].clone())
                            .priority(pri)
                            .deadline_opt(cfg.deadline);
                        let t = Instant::now();
                        match pool.submit(req) {
                            Ok(ticket) => match ticket.recv_timeout(CLIENT_PATIENCE) {
                                Ok(Ok(resp)) => {
                                    rec.record_ok(t.elapsed());
                                    if resp.degraded {
                                        rec.record_degraded();
                                    }
                                }
                                Ok(Err(e)) => rec.record_err(&e),
                                Err(_) => rec.record_timeout(),
                            },
                            // blocking submit never refuses with Busy: a
                            // submit-time Err is a hard fault (pool down)
                            // and must land in the error column, not be
                            // dressed up as healthy backpressure
                            Err(e) => rec.record_err(&e),
                        }
                        i += concurrency;
                    }
                    rec
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let mut merged = Recorder::new(seed);
    for r in &recs {
        merged.merge(r);
    }
    merged.stats(t0.elapsed())
}

/// Deterministic synthetic 32x32x3 images for the generators.
pub fn gen_images(n: usize, seed: u64) -> Vec<Vec<f32>> {
    gen_images_len(n, 32 * 32 * 3, seed)
}

/// Deterministic synthetic images of an arbitrary per-request length
/// (`hw * hw * c` of the served net).
pub fn gen_images_len(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
    gen_images_mode(n, len, seed, ProbeMode::Dense)
}

/// [`gen_images_len`] with an explicit [`ProbeMode`]. Sparse probes zero
/// each pixel independently with probability [`SPARSE_ZERO_FRACTION`],
/// approximating post-ReLU activation statistics; the zero pattern is
/// part of the deterministic stream, so a (n, len, seed, mode) tuple
/// always yields the same images.
pub fn gen_images_mode(n: usize, len: usize, seed: u64, mode: ProbeMode) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n.max(1))
        .map(|_| {
            (0..len)
                .map(|_| {
                    let x = rng.range_f64(0.0, 1.0);
                    match mode {
                        ProbeMode::Dense => x as f32,
                        // reuse the value draw as the zero coin so dense
                        // and sparse consume the stream identically
                        ProbeMode::Sparse if x < SPARSE_ZERO_FRACTION => 0.0,
                        ProbeMode::Sparse => x as f32,
                    }
                })
                .collect()
        })
        .collect()
}

/// Machine-readable sweep record (the serving perf trajectory).
pub fn sweep_json(points: &[SweepPoint], cfg: &SweepConfig, backend: &str) -> Json {
    let mut root = Json::obj();
    root.set("bench", "serving");
    root.set("backend", backend);
    root.set("unit_latency", "us");
    root.set("unit_throughput", "req/s (completed ok)");
    root.set("duration_ms", cfg.duration.as_secs_f64() * 1e3);
    root.set("queue_depth", cfg.queue_depth as u64);
    root.set("max_batch", cfg.max_batch as u64);
    root.set(
        "deadline_ms",
        match cfg.deadline {
            Some(d) => Json::Num(d.as_secs_f64() * 1e3),
            None => Json::Null,
        },
    );
    root.set("probe", cfg.probe.as_str());
    let variants: Vec<Json> =
        cfg.variants.iter().map(|v| Json::Str(v.name.clone())).collect();
    root.set("variants", Json::Arr(variants));
    let records: Vec<Json> = points
        .iter()
        .map(|p| {
            let mut j = Json::obj();
            j.set("workers", p.workers as u64);
            j.set("scenario", p.scenario.as_str());
            j.set("arrival", p.arrival.as_str());
            j.set("rate", p.rate);
            j.set("max_wait_ms", p.max_wait_ms);
            j.set("throughput_rps", p.stats.throughput_rps);
            j.set("p50_us", p.stats.p50_us);
            j.set("p95_us", p.stats.p95_us);
            j.set("p99_us", p.stats.p99_us);
            j.set("offered", p.stats.offered);
            j.set("ok", p.stats.ok);
            j.set("shed", p.shed);
            j.set("busy", p.rejected);
            j.set("shed_interactive", p.shed_by_lane[0]);
            j.set("shed_batch", p.shed_by_lane[1]);
            j.set("busy_interactive", p.rejected_by_lane[0]);
            j.set("busy_batch", p.rejected_by_lane[1]);
            j.set("degraded", p.degraded);
            j.set("timeout", p.stats.timeout);
            j.set("error", p.stats.error);
            j.set("mean_batch", p.mean_batch);
            j.set("wall_s", p.stats.wall_s);
            j
        })
        .collect();
    root.set("records", Json::Arr(records));
    root
}

/// Write the sweep record to `path` (the repo-root `BENCH_serving.json`
/// for the CLI and the hotpath bench) — atomically, through the shared
/// [`Emitter`].
pub fn write_bench_json(
    points: &[SweepPoint],
    cfg: &SweepConfig,
    backend: &str,
    path: &Path,
) -> SwisResult<()> {
    Emitter::at(path).write(&sweep_json(points, cfg, backend))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> SweepConfig {
        SweepConfig {
            workers: vec![1],
            arrivals: vec![Arrival::Poisson { rate: 120.0 }],
            max_waits: vec![Duration::from_millis(1)],
            max_batch: 8,
            duration: Duration::from_millis(120),
            queue_depth: 64,
            deadline: Some(Duration::from_secs(5)),
            variants: vec![VariantSpec::swis(3.0, 4)],
            seed: 11,
            probe: ProbeMode::Dense,
            trace_sample: 0,
        }
    }

    #[test]
    fn open_loop_sweep_runs_and_serializes() {
        let cfg = tiny_cfg();
        let (pts, backend) =
            run_sweep(Path::new("/nonexistent"), BackendKind::Native, &cfg).unwrap();
        assert_eq!(backend, "native");
        assert_eq!(pts.len(), 1);
        let p = &pts[0];
        assert!(p.stats.offered > 0, "no requests offered");
        assert!(p.stats.ok > 0, "no requests completed");
        assert_eq!(p.stats.timeout, 0, "requests timed out");
        assert!(p.stats.p99_us >= p.stats.p50_us);
        let j = sweep_json(&pts, &cfg, "native");
        for key in [
            "workers",
            "scenario",
            "arrival",
            "throughput_rps",
            "p50_us",
            "p99_us",
            "shed",
            "busy",
            "shed_interactive",
            "shed_batch",
            "busy_interactive",
            "busy_batch",
            "degraded",
        ] {
            assert!(
                j.path(&["records", "0", key]).is_some(),
                "missing '{key}' in sweep record"
            );
        }
        assert_eq!(j.get("bench").unwrap().as_str(), Some("serving"));
        assert_eq!(j.get("probe").unwrap().as_str(), Some("dense"));
    }

    #[test]
    fn closed_loop_trial_completes() {
        let mut cfg = tiny_cfg();
        cfg.arrivals = vec![Arrival::Closed { concurrency: 2 }];
        cfg.duration = Duration::from_millis(80);
        let (pts, _) = run_sweep(Path::new("/nonexistent"), BackendKind::Native, &cfg).unwrap();
        assert_eq!(pts.len(), 1);
        assert!(pts[0].stats.ok > 0, "closed loop completed nothing");
        assert_eq!(pts[0].rate, 0.0);
    }

    #[test]
    fn gen_images_shape_and_determinism() {
        let a = gen_images(3, 5);
        let b = gen_images(3, 5);
        assert_eq!(a.len(), 3);
        assert!(a.iter().all(|im| im.len() == 32 * 32 * 3));
        assert_eq!(a, b);
    }

    #[test]
    fn sparse_probe_hits_the_target_zero_fraction() {
        assert_eq!(ProbeMode::parse("sparse").unwrap(), ProbeMode::Sparse);
        assert!(ProbeMode::parse("noise").is_err());
        let a = gen_images_mode(4, 1024, 7, ProbeMode::Sparse);
        let b = gen_images_mode(4, 1024, 7, ProbeMode::Sparse);
        assert_eq!(a, b, "sparse probes must be deterministic");
        let total = (4 * 1024) as f64;
        let zeros = a.iter().flatten().filter(|&&x| x == 0.0).count() as f64;
        let frac = zeros / total;
        assert!(
            (frac - SPARSE_ZERO_FRACTION).abs() < 0.05,
            "zero fraction {frac} far from target {SPARSE_ZERO_FRACTION}"
        );
        // dense probes from the same seed have essentially no exact zeros
        let d = gen_images_mode(4, 1024, 7, ProbeMode::Dense);
        assert!(d.iter().flatten().filter(|&&x| x == 0.0).count() < 8);
    }
}
