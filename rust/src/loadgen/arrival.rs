//! Arrival processes for the load generator: open-loop Poisson (offered
//! load is independent of the system — the honest way to measure tail
//! latency, since a closed loop self-throttles under congestion and
//! hides queueing collapse) and closed-loop concurrency (the classic
//! "N clients, think time zero" saturation probe).

use crate::util::rng::Rng;

/// How requests arrive at the pool during one trial.
#[derive(Clone, Copy, Debug)]
pub enum Arrival {
    /// Open loop: Poisson arrivals at `rate` requests/second
    /// (exponentially distributed inter-arrival gaps).
    Poisson { rate: f64 },
    /// Closed loop: `concurrency` clients, each submitting its next
    /// request the moment the previous response lands.
    Closed { concurrency: usize },
}

impl Arrival {
    /// Stable label for reports ("poisson@200" / "closed@4").
    pub fn label(&self) -> String {
        match self {
            Arrival::Poisson { rate } => format!("poisson@{rate}"),
            Arrival::Closed { concurrency } => format!("closed@{concurrency}"),
        }
    }

    /// Offered rate in req/s (0 for closed loop, where the offered load
    /// is whatever the system sustains).
    pub fn rate(&self) -> f64 {
        match self {
            Arrival::Poisson { rate } => *rate,
            Arrival::Closed { .. } => 0.0,
        }
    }
}

/// One exponential inter-arrival gap in seconds — deterministic in the
/// RNG stream, mean `1/rate`.
pub fn exp_gap(rng: &mut Rng, rate: f64) -> f64 {
    -rng.f64().max(1e-12).ln() / rate.max(1e-9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp_gap_mean_matches_rate() {
        let mut rng = Rng::new(42);
        let rate = 250.0;
        let n = 50_000;
        let mean = (0..n).map(|_| exp_gap(&mut rng, rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.05 / rate, "mean gap {mean} vs {}", 1.0 / rate);
    }

    #[test]
    fn exp_gap_is_deterministic() {
        let a: Vec<f64> = {
            let mut r = Rng::new(7);
            (0..32).map(|_| exp_gap(&mut r, 100.0)).collect()
        };
        let b: Vec<f64> = {
            let mut r = Rng::new(7);
            (0..32).map(|_| exp_gap(&mut r, 100.0)).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn labels_and_rates() {
        assert_eq!(Arrival::Poisson { rate: 200.0 }.label(), "poisson@200");
        assert_eq!(Arrival::Closed { concurrency: 4 }.label(), "closed@4");
        assert_eq!(Arrival::Poisson { rate: 200.0 }.rate(), 200.0);
        assert_eq!(Arrival::Closed { concurrency: 4 }.rate(), 0.0);
    }
}
