//! The load-generation scenario suite: shaped arrival schedules beyond
//! steady Poisson — diurnal ramps, flash crowds, deadline mixes, and
//! slow/abusive wire clients — each runnable IN-PROCESS against a
//! [`WorkerPool`] or OVER TCP against an [`crate::edge::EdgeServer`].
//!
//! Both runners consume the exact same pre-computed [`Schedule`]
//! (arrival times, lanes, deadlines, tier hints are all drawn from the
//! scenario seed before the trial starts), so a TCP run and an
//! in-process run of the same `(scenario, seed)` offer identical
//! request streams — the parity the edge tests pin: same offered count,
//! zero protocol errors.

use std::io::Write as _;
use std::net::TcpStream;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::arrival::exp_gap;
use super::recorder::{PointStats, Recorder};
use crate::coordinator::{Admission, InferRequest, Priority, Ticket, WorkerPool};
use crate::edge::{frame, EdgeClient};
use crate::error::{AdmissionReason, SwisError, SwisResult};
use crate::util::rng::Rng;

/// How long scenario clients wait for any single response.
const PATIENCE: Duration = Duration::from_secs(10);

/// The traffic shapes the suite can generate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScenarioKind {
    /// Constant-rate Poisson — the pre-suite behaviour.
    Steady,
    /// Raised-cosine ramp: baseline at the edges of the window, `peak`
    /// in the middle — a compressed day of traffic.
    Diurnal,
    /// Baseline rate with a sudden `peak` burst over the middle fifth
    /// of the window — the overload case degrade-don't-shed exists for.
    FlashCrowd,
    /// Light legitimate traffic PLUS abusive wire clients (garbage
    /// magic, oversized length prefix, partial frame then disconnect,
    /// stalled mid-frame reads). The abuse is TCP-only; the in-process
    /// runner serves just the legitimate stream.
    SlowClient,
    /// Steady rate where every third request carries a tight deadline
    /// and a 1-tier relaxation hint; the rest ride the loose deadline.
    DeadlineMix,
}

/// Every scenario, in the order the CLI lists them.
pub const ALL_SCENARIOS: [ScenarioKind; 5] = [
    ScenarioKind::Steady,
    ScenarioKind::Diurnal,
    ScenarioKind::FlashCrowd,
    ScenarioKind::SlowClient,
    ScenarioKind::DeadlineMix,
];

impl ScenarioKind {
    pub fn parse(s: &str) -> SwisResult<ScenarioKind> {
        Ok(match s {
            "steady" => ScenarioKind::Steady,
            "diurnal" => ScenarioKind::Diurnal,
            "flash_crowd" => ScenarioKind::FlashCrowd,
            "slow_client" => ScenarioKind::SlowClient,
            "deadline_mix" => ScenarioKind::DeadlineMix,
            other => {
                return Err(SwisError::config(format!(
                    "unknown scenario '{other}' (expected \
                     steady|diurnal|flash_crowd|slow_client|deadline_mix)"
                )))
            }
        })
    }

    pub fn as_str(self) -> &'static str {
        match self {
            ScenarioKind::Steady => "steady",
            ScenarioKind::Diurnal => "diurnal",
            ScenarioKind::FlashCrowd => "flash_crowd",
            ScenarioKind::SlowClient => "slow_client",
            ScenarioKind::DeadlineMix => "deadline_mix",
        }
    }

    /// Instantaneous arrival rate at normalized time `u` in `[0, 1)`.
    fn lambda(self, u: f64, rate: f64, peak: f64) -> f64 {
        match self {
            ScenarioKind::Steady | ScenarioKind::DeadlineMix => rate,
            // abusive connections ride alongside, off-schedule
            ScenarioKind::SlowClient => rate,
            ScenarioKind::Diurnal => {
                rate + (peak - rate) * 0.5 * (1.0 - (2.0 * std::f64::consts::PI * u).cos())
            }
            ScenarioKind::FlashCrowd => {
                if (0.4..0.6).contains(&u) {
                    peak
                } else {
                    rate
                }
            }
        }
    }
}

/// One scenario trial's knobs.
#[derive(Clone, Debug)]
pub struct ScenarioConfig {
    pub kind: ScenarioKind,
    /// Submission window.
    pub duration: Duration,
    /// Baseline arrival rate (req/s).
    pub rate: f64,
    /// Peak rate for the shaped scenarios (clamped to >= `rate`).
    pub peak_rate: f64,
    pub seed: u64,
    /// Loose deadline stamped on ordinary requests (None = never shed).
    pub deadline: Option<Duration>,
    /// Tight deadline for the deadline-mix scenario's hurried third.
    pub tight_deadline: Duration,
}

impl Default for ScenarioConfig {
    fn default() -> ScenarioConfig {
        ScenarioConfig {
            kind: ScenarioKind::Steady,
            duration: Duration::from_millis(400),
            rate: 150.0,
            peak_rate: 600.0,
            seed: 2026,
            deadline: Some(Duration::from_millis(100)),
            tight_deadline: Duration::from_millis(5),
        }
    }
}

/// One pre-drawn legitimate request.
#[derive(Clone, Debug)]
pub struct ScheduledReq {
    /// Offset from trial start.
    pub at: Duration,
    pub pri: Priority,
    pub deadline: Option<Duration>,
    pub tier_hint: usize,
}

/// Abusive wire behaviours the slow-client scenario interleaves
/// (TCP-only; each maps to one [`crate::coordinator::WireFault`] class
/// on the server).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AbuseKind {
    /// 5 junk bytes where the magic belongs.
    GarbageMagic,
    /// Valid header claiming a `u32::MAX`-byte body.
    OversizedPrefix,
    /// First half of a valid header, then disconnect.
    PartialFrame,
    /// First half of a valid header, then silence — held open until the
    /// server's mid-frame read-stall budget cuts it off.
    StalledRead,
}

/// The full pre-drawn trial: what both runners replay.
#[derive(Clone, Debug)]
pub struct Schedule {
    pub reqs: Vec<ScheduledReq>,
    /// `(offset, behaviour)` abusive connections (slow-client only).
    pub abuse: Vec<(Duration, AbuseKind)>,
}

/// Draw the whole trial up front, deterministically: Poisson arrivals
/// at the peak rate thinned to the scenario's `lambda(t)` (the standard
/// non-homogeneous-Poisson construction, one RNG stream, so the same
/// `(kind, seed, duration, rates)` always yields byte-identical
/// schedules).
pub fn schedule(cfg: &ScenarioConfig) -> Schedule {
    let peak = cfg.peak_rate.max(cfg.rate).max(1e-6);
    let dur = cfg.duration.as_secs_f64();
    let mut rng = Rng::new(cfg.seed);
    let mut reqs = Vec::new();
    let mut t = exp_gap(&mut rng, peak);
    let mut kept = 0usize;
    while t < dur {
        let keep_p = cfg.kind.lambda(t / dur, cfg.rate, peak) / peak;
        // consume the thinning draw unconditionally to keep the stream
        // aligned across kinds sharing a seed
        let coin = rng.range_f64(0.0, 1.0);
        if coin < keep_p {
            let (deadline, tier_hint) = match cfg.kind {
                ScenarioKind::DeadlineMix if kept % 3 == 0 => (Some(cfg.tight_deadline), 1),
                _ => (cfg.deadline, 0),
            };
            let pri =
                if kept % 2 == 0 { Priority::Interactive } else { Priority::Batch };
            reqs.push(ScheduledReq {
                at: Duration::from_secs_f64(t),
                pri,
                deadline,
                tier_hint,
            });
            kept += 1;
        }
        t += exp_gap(&mut rng, peak);
    }
    let abuse = if cfg.kind == ScenarioKind::SlowClient {
        [
            AbuseKind::GarbageMagic,
            AbuseKind::OversizedPrefix,
            AbuseKind::PartialFrame,
            AbuseKind::StalledRead,
        ]
        .iter()
        .enumerate()
        .map(|(i, &k)| (cfg.duration.mul_f64(0.1 + 0.2 * i as f64), k))
        .collect()
    } else {
        Vec::new()
    };
    Schedule { reqs, abuse }
}

/// One scenario trial's outcome.
#[derive(Clone, Debug)]
pub struct ScenarioRun {
    pub stats: PointStats,
    /// Transport/protocol failures the CLIENT observed (0 on a healthy
    /// run — the TCP-vs-in-process parity check pins this).
    pub protocol_errors: u64,
    /// Abusive connections actually opened (TCP runner only).
    pub abuse_sent: u64,
}

fn build_req(
    s: &ScheduledReq,
    i: usize,
    names: &[String],
    images: &[Vec<f32>],
) -> InferRequest {
    InferRequest::new(names[i % names.len()].as_str())
        .image(images[i % images.len()].clone())
        .priority(s.pri)
        .deadline_opt(s.deadline)
        .tier_hint(s.tier_hint)
}

/// Replay a scenario against an in-process pool: paced submission on
/// this thread, collection on a companion thread (the open-loop shape
/// from the sweep driver). Abusive wire behaviours have no in-process
/// analog and are skipped.
pub fn run_scenario_inproc(
    pool: &WorkerPool,
    cfg: &ScenarioConfig,
    names: &[String],
    images: &[Vec<f32>],
) -> SwisResult<ScenarioRun> {
    let sched = schedule(cfg);
    let (tx, rx) = mpsc::channel::<Ticket>();
    let collector = std::thread::spawn(move || {
        let mut rec = Recorder::new(1);
        for ticket in rx {
            match ticket.recv_timeout(PATIENCE) {
                Ok(Ok(resp)) => {
                    rec.record_ok(resp.total);
                    if resp.degraded {
                        rec.record_degraded();
                    }
                }
                Ok(Err(e)) => rec.record_err(&e),
                Err(_) => rec.record_timeout(),
            }
        }
        rec
    });
    let t0 = Instant::now();
    let mut busy = 0u64;
    for (i, s) in sched.reqs.iter().enumerate() {
        let target = t0 + s.at;
        let now = Instant::now();
        if now < target {
            std::thread::sleep(target - now);
        }
        match pool.try_submit(build_req(s, i, names, images))? {
            Admission::Accepted(t) => {
                let _ = tx.send(t);
            }
            Admission::Busy => busy += 1,
        }
    }
    drop(tx);
    let mut rec = collector
        .join()
        .map_err(|_| SwisError::backend("scenario collector panicked"))?;
    rec.busy = busy;
    Ok(ScenarioRun { stats: rec.stats(t0.elapsed()), protocol_errors: 0, abuse_sent: 0 })
}

/// Replay the SAME schedule over TCP against a serving edge: a feeder
/// paces arrivals onto a channel, `conns` blocking client connections
/// drain it, and (for the slow-client scenario) an abuse thread opens
/// the scheduled hostile connections alongside. Offered counts match
/// [`run_scenario_inproc`] exactly — abuse rides outside the recorder.
pub fn run_scenario_tcp(
    addr: &str,
    model: &str,
    cfg: &ScenarioConfig,
    names: &[String],
    images: &[Vec<f32>],
    conns: usize,
) -> SwisResult<ScenarioRun> {
    let sched = schedule(cfg);
    let (tx, rx) = mpsc::channel::<(usize, ScheduledReq)>();
    let rx = Arc::new(Mutex::new(rx));
    let t0 = Instant::now();
    let (recs, abuse_sent) = std::thread::scope(
        |s| -> SwisResult<(Vec<(Recorder, u64)>, u64)> {
            let workers: Vec<_> = (0..conns.max(1))
                .map(|c| {
                    let rx = Arc::clone(&rx);
                    s.spawn(move || drive_conn(addr, model, cfg.seed ^ c as u64, rx, names, images))
                })
                .collect();
            let abuser = (!sched.abuse.is_empty())
                .then(|| s.spawn(|| run_abuse(addr, t0, &sched.abuse)));
            for (i, req) in sched.reqs.iter().enumerate() {
                let target = t0 + req.at;
                let now = Instant::now();
                if now < target {
                    std::thread::sleep(target - now);
                }
                if tx.send((i, req.clone())).is_err() {
                    break;
                }
            }
            drop(tx);
            let mut recs = Vec::new();
            for w in workers {
                recs.push(
                    w.join()
                        .map_err(|_| SwisError::backend("scenario client panicked"))??,
                );
            }
            let abuse_sent = match abuser {
                Some(a) => a
                    .join()
                    .map_err(|_| SwisError::backend("abuse client panicked"))?,
                None => 0,
            };
            Ok((recs, abuse_sent))
        },
    )?;
    let mut merged = Recorder::new(cfg.seed);
    let mut protocol_errors = 0u64;
    for (r, perrs) in &recs {
        merged.merge(r);
        protocol_errors += perrs;
    }
    Ok(ScenarioRun { stats: merged.stats(t0.elapsed()), protocol_errors, abuse_sent })
}

/// One blocking client connection draining the shared request channel.
/// Returns its recorder plus the transport errors it hit (reconnecting
/// after each so one bad exchange never poisons the rest of the run).
fn drive_conn(
    addr: &str,
    model: &str,
    seed: u64,
    rx: Arc<Mutex<mpsc::Receiver<(usize, ScheduledReq)>>>,
    names: &[String],
    images: &[Vec<f32>],
) -> SwisResult<(Recorder, u64)> {
    let mut client = Some(EdgeClient::connect(addr, PATIENCE)?);
    let mut rec = Recorder::new(seed);
    let mut protocol_errors = 0u64;
    loop {
        let job = rx.lock().unwrap().recv();
        let Ok((i, s)) = job else { break };
        let c = match client.as_mut() {
            Some(c) => c,
            None => match EdgeClient::connect(addr, PATIENCE) {
                Ok(c) => client.insert(c),
                Err(e) => {
                    protocol_errors += 1;
                    rec.record_err(&e);
                    continue;
                }
            },
        };
        let t = Instant::now();
        match c.infer(model, build_req(&s, i, names, images)) {
            Ok(resp) => {
                rec.record_ok(t.elapsed());
                if resp.degraded {
                    rec.record_degraded();
                }
            }
            Err(SwisError::Admission { reason: AdmissionReason::Busy, .. }) => {
                rec.record_busy();
            }
            Err(e @ SwisError::Admission { .. }) => rec.record_err(&e),
            Err(e @ SwisError::Io(_)) => {
                // transport fault: count it, drop the socket, reconnect
                // for the next job
                protocol_errors += 1;
                rec.record_err(&e);
                client = None;
            }
            Err(e) => rec.record_err(&e),
        }
    }
    Ok((rec, protocol_errors))
}

/// Open the scheduled hostile connections. Every action is
/// fire-and-forget; stalled sockets are held open until the schedule is
/// done so the server's read-stall budget — not our disconnect — ends
/// them.
fn run_abuse(addr: &str, t0: Instant, abuse: &[(Duration, AbuseKind)]) -> u64 {
    let mut held: Vec<TcpStream> = Vec::new();
    let mut sent = 0u64;
    for &(at, kind) in abuse {
        let target = t0 + at;
        let now = Instant::now();
        if now < target {
            std::thread::sleep(target - now);
        }
        let Ok(mut stream) = TcpStream::connect(addr) else { continue };
        let ok = match kind {
            AbuseKind::GarbageMagic => stream.write_all(b"XXXXX\x01\x00\x00\x00\x00").is_ok(),
            AbuseKind::OversizedPrefix => {
                let mut h = Vec::new();
                h.extend_from_slice(&frame::MAGIC);
                h.push(frame::FT_INFER);
                h.extend_from_slice(&u32::MAX.to_le_bytes());
                stream.write_all(&h).is_ok()
            }
            AbuseKind::PartialFrame | AbuseKind::StalledRead => {
                stream.write_all(&frame::MAGIC[..3]).is_ok()
            }
        };
        if ok {
            sent += 1;
        }
        if kind == AbuseKind::StalledRead {
            held.push(stream);
        }
        // the others drop here (disconnect is part of the abuse)
    }
    drop(held);
    sent
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(kind: ScenarioKind) -> ScenarioConfig {
        ScenarioConfig {
            kind,
            duration: Duration::from_millis(500),
            rate: 200.0,
            peak_rate: 1000.0,
            seed: 42,
            ..ScenarioConfig::default()
        }
    }

    #[test]
    fn schedules_are_deterministic_per_seed() {
        for kind in ALL_SCENARIOS {
            let a = schedule(&cfg(kind));
            let b = schedule(&cfg(kind));
            assert_eq!(a.reqs.len(), b.reqs.len(), "{kind:?} not deterministic");
            for (x, y) in a.reqs.iter().zip(&b.reqs) {
                assert_eq!(x.at, y.at);
                assert_eq!(x.deadline, y.deadline);
                assert_eq!(x.tier_hint, y.tier_hint);
            }
            assert_eq!(a.abuse, b.abuse);
        }
        let c = schedule(&ScenarioConfig { seed: 43, ..cfg(ScenarioKind::Steady) });
        let d = schedule(&cfg(ScenarioKind::Steady));
        assert_ne!(
            c.reqs.iter().map(|r| r.at).collect::<Vec<_>>(),
            d.reqs.iter().map(|r| r.at).collect::<Vec<_>>(),
            "different seeds must draw different arrivals"
        );
    }

    #[test]
    fn flash_crowd_concentrates_arrivals_mid_window() {
        let s = schedule(&cfg(ScenarioKind::FlashCrowd));
        let dur = 0.5_f64;
        let mid = s
            .reqs
            .iter()
            .filter(|r| {
                let u = r.at.as_secs_f64() / dur;
                (0.4..0.6).contains(&u)
            })
            .count() as f64;
        let frac = mid / s.reqs.len() as f64;
        // burst fifth carries peak/(rate*0.8 + peak*0.2) ≈ 56% of traffic
        assert!(frac > 0.35, "flash burst carried only {frac:.2} of arrivals");
        // and steady traffic from the same seed has no such concentration
        let st = schedule(&cfg(ScenarioKind::Steady));
        assert!(st.reqs.len() < s.reqs.len(), "flash crowd must offer more than steady");
    }

    #[test]
    fn diurnal_rate_peaks_mid_window() {
        let k = ScenarioKind::Diurnal;
        assert!(k.lambda(0.5, 100.0, 900.0) > k.lambda(0.05, 100.0, 900.0));
        assert!((k.lambda(0.5, 100.0, 900.0) - 900.0).abs() < 1e-9);
        assert!((k.lambda(0.0, 100.0, 900.0) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn deadline_mix_alternates_budgets_and_hints() {
        let s = schedule(&cfg(ScenarioKind::DeadlineMix));
        assert!(s.reqs.len() > 10);
        let tight: Vec<_> = s.reqs.iter().filter(|r| r.tier_hint == 1).collect();
        assert!(!tight.is_empty());
        for r in &tight {
            assert_eq!(r.deadline, Some(ScenarioConfig::default().tight_deadline));
        }
        let loose = s.reqs.iter().filter(|r| r.tier_hint == 0).count();
        assert_eq!(loose + tight.len(), s.reqs.len());
        // roughly a third are tight
        let frac = tight.len() as f64 / s.reqs.len() as f64;
        assert!((0.2..0.5).contains(&frac), "tight fraction {frac:.2} off");
    }

    #[test]
    fn slow_client_schedules_every_abuse_kind_in_order() {
        let s = schedule(&cfg(ScenarioKind::SlowClient));
        let kinds: Vec<_> = s.abuse.iter().map(|&(_, k)| k).collect();
        assert_eq!(
            kinds,
            vec![
                AbuseKind::GarbageMagic,
                AbuseKind::OversizedPrefix,
                AbuseKind::PartialFrame,
                AbuseKind::StalledRead,
            ]
        );
        for w in s.abuse.windows(2) {
            assert!(w[0].0 < w[1].0, "abuse times must ascend");
        }
        // every other scenario schedules none
        assert!(schedule(&cfg(ScenarioKind::FlashCrowd)).abuse.is_empty());
    }

    #[test]
    fn scenario_names_round_trip() {
        for kind in ALL_SCENARIOS {
            assert_eq!(ScenarioKind::parse(kind.as_str()).unwrap(), kind);
        }
        assert!(ScenarioKind::parse("tsunami").is_err());
    }
}
