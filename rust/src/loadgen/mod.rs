//! Load generation + SLO benchmarking for the serving stack (the
//! measurement side of the worker-pool subsystem):
//!
//! * [`arrival`] — open-loop Poisson and closed-loop concurrency arrival
//!   processes (deterministic, seeded);
//! * [`recorder`] — per-trial latency percentiles (bounded reservoir)
//!   and shed/busy/timeout/error counts;
//! * [`sweep`] — the driver that walks worker count x batch policy x
//!   arrival rate, one fresh [`crate::coordinator::WorkerPool`] per
//!   point over ONE shared backend factory (warm-up paid once), and
//!   emits the repo-root `BENCH_serving.json` trajectory record;
//! * [`scenario`] — shaped traffic beyond steady Poisson (diurnal ramp,
//!   flash crowd, slow/abusive wire clients, deadline mixes), each
//!   pre-drawn into a deterministic [`scenario::Schedule`] replayable
//!   in-process or over TCP against the [`crate::edge`] server with
//!   identical offered load.
//!
//! Entry points: `swis loadgen` (CLI; `--scenario` picks shapes,
//! `--connect HOST:PORT` replays them over the wire), the serving
//! section of `benches/hotpath.rs`, and [`sweep::run_sweep`] for tests.

mod arrival;
mod recorder;
pub mod scenario;
mod sweep;

pub use arrival::{exp_gap, Arrival};
pub use recorder::{PointStats, Recorder};
pub use scenario::{
    run_scenario_inproc, run_scenario_tcp, schedule, AbuseKind, ScenarioConfig, ScenarioKind,
    ScenarioRun, Schedule, ScheduledReq, ALL_SCENARIOS,
};
pub use sweep::{
    gen_images, gen_images_mode, run_sweep, run_sweep_with, sweep_json, write_bench_json,
    ProbeMode, SweepConfig, SweepPoint, SPARSE_ZERO_FRACTION,
};
