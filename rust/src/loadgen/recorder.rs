//! Client-side outcome recording for one load-generation trial: latency
//! percentiles over a bounded reservoir plus shed/busy/timeout/error
//! counts — the SLO view of the serving stack.

use std::time::Duration;

use crate::error::SwisError;
use crate::util::stats::{percentile, Reservoir};

/// Collects per-request outcomes during a trial.
pub struct Recorder {
    lat_us: Reservoir,
    pub ok: u64,
    /// Of the OK responses, how many were served at a lower precision
    /// tier than requested (degrade-don't-shed under queue pressure).
    pub degraded: u64,
    /// Responses refused by deadline shedding.
    pub shed: u64,
    /// Admissions refused with Busy (backpressure at the edge).
    pub busy: u64,
    /// Responses that never arrived within the client patience window.
    pub timeout: u64,
    /// Any other routed error.
    pub error: u64,
}

impl Recorder {
    pub fn new(seed: u64) -> Recorder {
        let lat_us = Reservoir::new(4096, seed);
        Recorder { lat_us, ok: 0, degraded: 0, shed: 0, busy: 0, timeout: 0, error: 0 }
    }

    pub fn record_ok(&mut self, latency: Duration) {
        self.ok += 1;
        self.lat_us.push(latency.as_secs_f64() * 1e6);
    }

    /// Mark the most recent OK response as down-tiered. Degraded
    /// responses still count in `ok` — degradation trades accuracy,
    /// not completion.
    pub fn record_degraded(&mut self) {
        self.degraded += 1;
    }

    /// Classify a routed error by its TYPED class: deadline sheds are
    /// `Admission { reason: Shed }`, everything else counts as an error.
    /// (Before the error taxonomy this sniffed a "shed:" message prefix
    /// — a refactor of the message would have silently reclassified
    /// sheds as errors.)
    pub fn record_err(&mut self, e: &SwisError) {
        if e.is_shed() {
            self.shed += 1;
        } else {
            self.error += 1;
        }
    }

    pub fn record_busy(&mut self) {
        self.busy += 1;
    }

    pub fn record_timeout(&mut self) {
        self.timeout += 1;
    }

    /// Fold another recorder (closed-loop per-client recorders merge
    /// into one trial view). The latency reservoirs merge with
    /// mass-weighted semantics ([`Reservoir::merge`]), so a client that
    /// saw 10x the traffic contributes ~10x the retained sample —
    /// re-offering the other buffer element by element would instead
    /// weight every client by its buffer length.
    pub fn merge(&mut self, other: &Recorder) {
        self.lat_us.merge(&other.lat_us);
        self.ok += other.ok;
        self.degraded += other.degraded;
        self.shed += other.shed;
        self.busy += other.busy;
        self.timeout += other.timeout;
        self.error += other.error;
    }

    /// Summarize against the trial wall-clock.
    pub fn stats(&self, wall: Duration) -> PointStats {
        let mut sorted = self.lat_us.as_slice().to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let wall_s = wall.as_secs_f64().max(1e-9);
        PointStats {
            offered: self.ok + self.shed + self.busy + self.timeout + self.error,
            ok: self.ok,
            degraded: self.degraded,
            shed: self.shed,
            busy: self.busy,
            timeout: self.timeout,
            error: self.error,
            wall_s,
            throughput_rps: self.ok as f64 / wall_s,
            p50_us: percentile(&sorted, 50.0),
            p95_us: percentile(&sorted, 95.0),
            p99_us: percentile(&sorted, 99.0),
        }
    }
}

/// One trial's SLO summary (one sweep point).
#[derive(Clone, Debug)]
pub struct PointStats {
    /// Requests the generator attempted (accepted + refused).
    pub offered: u64,
    pub ok: u64,
    /// OK responses served below the requested precision tier (subset
    /// of `ok`, never of `offered`'s failure columns).
    pub degraded: u64,
    pub shed: u64,
    pub busy: u64,
    pub timeout: u64,
    pub error: u64,
    pub wall_s: f64,
    /// Completed-OK requests per second of trial wall clock.
    pub throughput_rps: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classifies_and_summarizes() {
        let mut r = Recorder::new(1);
        for i in 0..100 {
            r.record_ok(Duration::from_micros(100 + i));
        }
        r.record_err(&SwisError::admission(
            crate::error::AdmissionReason::Shed,
            "deadline exceeded after 12.0 ms in queue",
        ));
        r.record_err(&SwisError::backend("unknown variant 'nope'"));
        r.record_busy();
        r.record_timeout();
        r.record_degraded();
        r.record_degraded();
        let s = r.stats(Duration::from_secs(2));
        assert_eq!((s.ok, s.shed, s.busy, s.timeout, s.error), (100, 1, 1, 1, 1));
        assert_eq!(s.degraded, 2);
        // degraded responses completed OK: they must not inflate offered
        assert_eq!(s.offered, 104);
        assert!((s.throughput_rps - 50.0).abs() < 1e-9);
        assert!(s.p50_us >= 100.0 && s.p50_us <= 200.0);
        assert!(s.p99_us >= s.p50_us);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = Recorder::new(1);
        a.record_ok(Duration::from_micros(10));
        let mut b = Recorder::new(2);
        b.record_ok(Duration::from_micros(30));
        b.record_busy();
        b.record_degraded();
        a.merge(&b);
        let s = a.stats(Duration::from_secs(1));
        assert_eq!(s.ok, 2);
        assert_eq!(s.busy, 1);
        assert_eq!(s.degraded, 1);
        assert!(s.p50_us >= 10.0 && s.p50_us <= 30.0);
    }
}
