//! Weight-variant router support: a served model exposes named weight
//! configurations (fp32 baseline, SWIS/SWIS-C at various shift budgets,
//! truncation baselines) over the SAME compiled graph — quantization is a
//! pure weight transform (paper Sec. 2), so variants cost no extra
//! compilation.
//!
//! [`VariantSpec`] is the TYPED description of one configuration: a
//! [`Scheme`] plus shift/group knobs. The string grammar
//! `fp32 | <scheme>[@<shifts>][/g<group>]` is a thin veneer over it —
//! `FromStr` parses into the typed spec and `Display` emits exactly the
//! inverse, so a spec can round-trip through logs, manifests and
//! `.swisplan` containers without loss (pinned by a property test
//! below). Programmatic callers build specs through the typed
//! constructors ([`VariantSpec::new`], [`VariantSpec::swis`], ...) and
//! never touch the grammar.

use anyhow::Result;
use std::collections::HashMap;
use std::fmt;
use std::str::FromStr;

use crate::error::{SwisError, SwisResult};
use crate::exec::kernel::MAX_GROUP_SIZE;
use crate::exec::model::filters_first;
use crate::exec::WeightTransform;
use crate::util::tensor::Tensor;

/// Quantization scheme of a served weight variant — the typed form of
/// the old stringly `"fp32" | "swis" | "swis_c" | "wgt_trunc"` field.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scheme {
    /// Serve the fp32 weights unchanged.
    Fp32,
    /// SWIS shared-shift quantization (paper Sec. 2).
    Swis,
    /// SWIS-C: consecutive shift windows (one 3-bit offset per group).
    SwisC,
    /// Weight-truncation baseline.
    WgtTrunc,
}

impl Scheme {
    pub fn as_str(self) -> &'static str {
        match self {
            Scheme::Fp32 => "fp32",
            Scheme::Swis => "swis",
            Scheme::SwisC => "swis_c",
            Scheme::WgtTrunc => "wgt_trunc",
        }
    }

    /// Schemes the quantized sweep walks (everything but the identity).
    pub fn quantized() -> [Scheme; 3] {
        [Scheme::Swis, Scheme::SwisC, Scheme::WgtTrunc]
    }
}

impl fmt::Display for Scheme {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

impl FromStr for Scheme {
    type Err = SwisError;

    fn from_str(s: &str) -> SwisResult<Scheme> {
        Ok(match s {
            "fp32" => Scheme::Fp32,
            "swis" => Scheme::Swis,
            "swis_c" => Scheme::SwisC,
            "wgt_trunc" => Scheme::WgtTrunc,
            other => {
                return Err(SwisError::config(format!(
                    "unknown scheme '{other}' (expected fp32, swis, swis_c or wgt_trunc)"
                )))
            }
        })
    }
}

/// A named weight configuration: scheme + shift budget + group size.
/// `name` is always the canonical `Display` form, so equal
/// configurations can never hide behind different names.
#[derive(Clone, Debug, PartialEq)]
pub struct VariantSpec {
    pub name: String,
    pub scheme: Scheme,
    /// Effective shifts (fractional triggers the Sec. 4.3 scheduler);
    /// bit count for `wgt_trunc`.
    pub n_shifts: f64,
    pub group_size: usize,
}

/// Default SWIS group size (the paper's G=4 operating point); elided
/// from the canonical string form.
const DEFAULT_GROUP: usize = 4;

impl VariantSpec {
    pub fn fp32() -> VariantSpec {
        VariantSpec {
            name: "fp32".into(),
            scheme: Scheme::Fp32,
            n_shifts: 8.0,
            group_size: DEFAULT_GROUP,
        }
    }

    pub fn swis(n: f64, g: usize) -> VariantSpec {
        VariantSpec::canonical(Scheme::Swis, n, g)
    }

    pub fn swis_c(n: f64, g: usize) -> VariantSpec {
        VariantSpec::canonical(Scheme::SwisC, n, g)
    }

    pub fn wgt_trunc(bits: usize) -> VariantSpec {
        VariantSpec::canonical(Scheme::WgtTrunc, bits as f64, DEFAULT_GROUP)
    }

    /// Validated typed constructor — the entry the builder-style
    /// [`crate::api::EngineConfig`] uses. Shifts must lie in `(0, 8]`
    /// (8-bit magnitudes), be integral for `wgt_trunc`, and the group
    /// size must fit the native kernel's lane masks (`1..=16`,
    /// [`MAX_GROUP_SIZE`]). `fp32` ignores both knobs and normalizes to
    /// the canonical spec.
    pub fn new(scheme: Scheme, n_shifts: f64, group_size: usize) -> SwisResult<VariantSpec> {
        if scheme == Scheme::Fp32 {
            return Ok(VariantSpec::fp32());
        }
        if !n_shifts.is_finite() || n_shifts <= 0.0 || n_shifts > 8.0 {
            return Err(SwisError::config(format!(
                "shift count {n_shifts} out of range (0, 8] for scheme '{scheme}'"
            )));
        }
        if scheme == Scheme::WgtTrunc && n_shifts.fract() != 0.0 {
            return Err(SwisError::config(format!(
                "wgt_trunc needs an integer bit count, got {n_shifts}"
            )));
        }
        if group_size == 0 || group_size > MAX_GROUP_SIZE {
            return Err(SwisError::config(format!(
                "group size {group_size} out of range 1..={MAX_GROUP_SIZE}"
            )));
        }
        Ok(VariantSpec::canonical(scheme, n_shifts, group_size))
    }

    fn canonical(scheme: Scheme, n_shifts: f64, group_size: usize) -> VariantSpec {
        let mut v = VariantSpec { name: String::new(), scheme, n_shifts, group_size };
        v.name = v.to_string();
        v
    }

    /// The backend-agnostic weight transform this variant denotes — the
    /// single scheme-to-math dispatch shared by the PJRT weight swap
    /// ([`quantize_jax_weight`]) and the native engine.
    pub fn transform(&self) -> SwisResult<WeightTransform> {
        Ok(match self.scheme {
            Scheme::Fp32 => WeightTransform::Fp32,
            Scheme::Swis | Scheme::SwisC => WeightTransform::Swis {
                n_shifts: self.n_shifts,
                group_size: self.group_size,
                consecutive: self.scheme == Scheme::SwisC,
            },
            Scheme::WgtTrunc => {
                if self.n_shifts.fract() != 0.0 {
                    return Err(SwisError::config(format!(
                        "wgt_trunc needs an integer bit count, got {} in '{}'",
                        self.n_shifts, self.name
                    )));
                }
                WeightTransform::Truncate { bits: self.n_shifts as usize }
            }
        })
    }

    /// Parse the string grammar (see the `FromStr` impl below) — kept
    /// as a named convenience for call sites that read specs from CLI
    /// flags or manifests.
    pub fn parse(s: &str) -> SwisResult<VariantSpec> {
        s.parse()
    }
}

impl fmt::Display for VariantSpec {
    /// Canonical string form, exactly inverse to `FromStr`:
    /// `fp32 | <scheme>@<shifts>[/g<group>]` — the group suffix is
    /// elided at the default G=4, a bare scheme is never emitted (the
    /// shift count is always explicit), so `parse(spec.to_string())`
    /// reconstructs the spec field-for-field.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.scheme == Scheme::Fp32 {
            return f.write_str("fp32");
        }
        write!(f, "{}@{}", self.scheme, self.n_shifts)?;
        if self.group_size != DEFAULT_GROUP {
            write!(f, "/g{}", self.group_size)?;
        }
        Ok(())
    }
}

impl FromStr for VariantSpec {
    type Err = SwisError;

    /// Parse `"fp32"` or `"<scheme>[@<shifts>][/g<group>]"` where scheme
    /// is one of `swis`, `swis_c`, `wgt_trunc`. A bare scheme name
    /// defaults to 3 shifts (the paper's headline operating point,
    /// Sec. 5) — so `"swis"` parses as `swis@3` — and an omitted group
    /// suffix means the paper's G=4. Unknown schemes, malformed or
    /// out-of-range shift counts and group sizes beyond the native
    /// kernel's lane masks are hard [`SwisError::Config`] errors.
    fn from_str(s: &str) -> SwisResult<VariantSpec> {
        let s = s.trim();
        if s.is_empty() {
            return Err(SwisError::config("empty variant spec"));
        }
        if s == "fp32" {
            return Ok(VariantSpec::fp32());
        }
        let (head, group) = match s.split_once("/g") {
            None => (s, DEFAULT_GROUP),
            Some((head, g)) => {
                let g = g.parse::<usize>().map_err(|_| {
                    SwisError::config(format!("malformed group size '{g}' in variant '{s}'"))
                })?;
                (head, g)
            }
        };
        let (scheme, shifts) = match head.split_once('@') {
            Some((sc, rest)) => (sc, Some(rest)),
            None => (head, None),
        };
        let scheme: Scheme = scheme
            .parse()
            .map_err(|e: SwisError| e.context(format!("in variant '{s}'")))?;
        if scheme == Scheme::Fp32 {
            // "fp32@3" / "fp32/g8" are contradictions, not configs
            return Err(SwisError::config(format!(
                "fp32 takes no shift count or group size (got '{s}')"
            )));
        }
        let n: f64 = match shifts {
            None => 3.0, // documented default: the paper's 3-shift point
            Some(r) => r.parse().map_err(|_| {
                SwisError::config(format!("malformed shift count '{r}' in variant '{s}'"))
            })?,
        };
        VariantSpec::new(scheme, n, group)
            .map_err(|e| e.context(format!("in variant '{s}'")))
    }
}

/// All weight sets a coordinator serves, keyed by variant name.
pub struct WeightVariants {
    pub sets: HashMap<String, HashMap<String, Tensor<f32>>>,
}

/// Quantize one flat weight tensor (jax layout) through a SWIS transform
/// that operates filters-first, and return it in the original layout.
///
/// jax layouts: conv HWIO (fan-in major, O last), fc (din, dout). Both
/// put the filter axis LAST, so the transpose is the same. The
/// scheme-to-math mapping is the shared
/// [`crate::exec::WeightTransform`] — the SAME dispatch the native
/// backend executes, so a variant name cannot mean different numerics on
/// different backends.
pub fn quantize_jax_weight(
    t: &Tensor<f32>,
    spec: &VariantSpec,
) -> Result<Tensor<f32>> {
    let shape = t.shape().to_vec();
    let (wf, k, fan_in) = filters_first(t);
    let dq = spec.transform()?.dequantize(&wf, k, fan_in)?;
    // transpose back to the original fan-in-major layout
    let mut back = vec![0.0f32; k * fan_in];
    for i in 0..fan_in {
        for o in 0..k {
            back[i * k + o] = dq[o * fan_in + i] as f32;
        }
    }
    Tensor::new(&shape, back)
}

impl WeightVariants {
    /// Build every variant's weight set from the FP32 bundle weights.
    /// Biases pass through untouched (the paper quantizes weights only).
    pub fn build(
        fp32: &HashMap<String, Tensor<f32>>,
        specs: &[VariantSpec],
    ) -> Result<WeightVariants> {
        let mut sets = HashMap::new();
        for spec in specs {
            let mut set = HashMap::new();
            for (name, t) in fp32 {
                let q = if name.ends_with("_b") || spec.scheme == Scheme::Fp32 {
                    t.clone()
                } else {
                    quantize_jax_weight(t, spec)?
                };
                set.insert(name.clone(), q);
            }
            sets.insert(spec.name.clone(), set);
        }
        Ok(WeightVariants { sets })
    }

    pub fn get(&self, name: &str) -> Option<&HashMap<String, Tensor<f32>>> {
        self.sets.get(name)
    }

    pub fn names(&self) -> Vec<&str> {
        let mut n: Vec<&str> = self.sets.keys().map(|s| s.as_str()).collect();
        n.sort();
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn toy_weights() -> HashMap<String, Tensor<f32>> {
        let mut rng = Rng::new(5);
        let mut m = HashMap::new();
        let w: Vec<f32> = (0..3 * 3 * 4 * 8).map(|_| rng.normal_ms(0.0, 0.1) as f32).collect();
        m.insert("conv1".into(), Tensor::new(&[3, 3, 4, 8], w).unwrap());
        m.insert("conv1_b".into(), Tensor::new(&[8], vec![0.5; 8]).unwrap());
        m
    }

    #[test]
    fn variants_build_and_biases_pass_through() {
        let fp32 = toy_weights();
        let specs = vec![VariantSpec::fp32(), VariantSpec::swis(3.0, 4), VariantSpec::swis_c(2.0, 4)];
        let v = WeightVariants::build(&fp32, &specs).unwrap();
        assert_eq!(v.names(), vec!["fp32", "swis@3", "swis_c@2"]);
        let s3 = v.get("swis@3").unwrap();
        assert_eq!(s3["conv1_b"].data(), fp32["conv1_b"].data());
        assert_ne!(s3["conv1"].data(), fp32["conv1"].data());
        // fp32 variant is the identity
        assert_eq!(v.get("fp32").unwrap()["conv1"].data(), fp32["conv1"].data());
    }

    #[test]
    fn quantized_weights_are_close() {
        let fp32 = toy_weights();
        let q = quantize_jax_weight(&fp32["conv1"], &VariantSpec::swis(4.0, 4)).unwrap();
        let a = fp32["conv1"].data();
        let b = q.data();
        let rmse = (a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>()
            / a.len() as f64)
            .sqrt();
        assert!(rmse < 0.01, "rmse {rmse}");
    }

    #[test]
    fn parse_specs() {
        assert_eq!(VariantSpec::parse("fp32").unwrap().scheme, Scheme::Fp32);
        let s = VariantSpec::parse("swis@2.5").unwrap();
        assert_eq!(s.n_shifts, 2.5);
        assert!(VariantSpec::parse("bogus@3").is_err());
        // group suffix
        let g = VariantSpec::parse("swis@3/g16").unwrap();
        assert_eq!((g.scheme, g.n_shifts, g.group_size), (Scheme::Swis, 3.0, 16));
        assert_eq!(g.name, "swis@3/g16");
        // explicit default group canonicalizes away
        assert_eq!(VariantSpec::parse("swis@3/g4").unwrap().name, "swis@3");
    }

    #[test]
    fn parse_round_trips_constructed_names() {
        for spec in [
            VariantSpec::fp32(),
            VariantSpec::swis(3.0, 4),
            VariantSpec::swis(2.5, 4),
            VariantSpec::swis(3.0, 16),
            VariantSpec::swis_c(4.0, 4),
            VariantSpec::wgt_trunc(3),
        ] {
            let p = VariantSpec::parse(&spec.name).unwrap();
            assert_eq!(p, spec);
        }
    }

    #[test]
    fn display_is_exactly_inverse_to_from_str_property() {
        // property round-trip over the whole typed domain: random
        // scheme x shifts x group — parse(display(spec)) == spec
        // field-for-field, and the name IS the display form
        let mut rng = Rng::new(2026);
        let schemes = [Scheme::Fp32, Scheme::Swis, Scheme::SwisC, Scheme::WgtTrunc];
        let groups = [1usize, 2, 3, 4, 8, 16];
        for trial in 0..500 {
            let scheme = schemes[rng.below(schemes.len() as u64) as usize];
            let g = groups[rng.below(groups.len() as u64) as usize];
            let n = if scheme == Scheme::WgtTrunc {
                1.0 + rng.below(8) as f64
            } else {
                // integral and fractional (quarter-step) shift budgets
                (1.0 + rng.below(29) as f64 * 0.25).min(8.0)
            };
            let spec = VariantSpec::new(scheme, n, g).unwrap();
            let shown = spec.to_string();
            assert_eq!(spec.name, shown, "name must be the canonical form (trial {trial})");
            let back: VariantSpec = shown.parse().unwrap();
            assert_eq!(back, spec, "round-trip failed for '{shown}' (trial {trial})");
        }
    }

    #[test]
    fn bare_scheme_defaults_to_three_shifts() {
        for (s, scheme) in [
            ("swis", Scheme::Swis),
            ("swis_c", Scheme::SwisC),
            ("wgt_trunc", Scheme::WgtTrunc),
        ] {
            let v = VariantSpec::parse(s).unwrap();
            assert_eq!(v.scheme, scheme);
            assert_eq!(v.n_shifts, 3.0, "{s} must default to @3");
            assert_eq!(v.name, format!("{scheme}@3"));
        }
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        // unknown scheme WITHOUT an @ used to silently mean <scheme>@3
        assert!(VariantSpec::parse("bogus").is_err());
        assert!(VariantSpec::parse("").is_err());
        assert!(VariantSpec::parse("swis@").is_err());
        assert!(VariantSpec::parse("swis@abc").is_err());
        assert!(VariantSpec::parse("swis@0").is_err());
        assert!(VariantSpec::parse("swis@-2").is_err());
        assert!(VariantSpec::parse("swis@9").is_err());
        assert!(VariantSpec::parse("swis@inf").is_err());
        assert!(VariantSpec::parse("swis@nan").is_err());
        assert!(VariantSpec::parse("wgt_trunc@2.5").is_err());
        // group sizes beyond the native kernel's lane masks
        assert!(VariantSpec::parse("swis@3/g0").is_err());
        assert!(VariantSpec::parse("swis@3/g32").is_err());
        assert!(VariantSpec::parse("swis@3/gx").is_err());
        // fp32 takes no shift count or group
        assert!(VariantSpec::parse("fp32@3").is_err());
        assert!(VariantSpec::parse("fp32/g8").is_err());
        // rejections are typed: callers match the class, not the string
        assert!(matches!(
            VariantSpec::parse("bogus").unwrap_err(),
            SwisError::Config(_)
        ));
    }

    #[test]
    fn fractional_shifts_schedule() {
        let fp32 = toy_weights();
        let q = quantize_jax_weight(&fp32["conv1"], &VariantSpec::swis(2.5, 4)).unwrap();
        assert_eq!(q.shape(), &[3, 3, 4, 8]);
    }
}
