//! Weight-variant router support: a served model exposes named weight
//! configurations (fp32 baseline, SWIS/SWIS-C at various shift budgets,
//! truncation baselines) over the SAME compiled graph — quantization is a
//! pure weight transform (paper Sec. 2), so variants cost no extra
//! compilation.

use anyhow::{bail, Result};
use std::collections::HashMap;

use crate::quant::{Alpha, quantize, QuantConfig};
use crate::quant::truncation::truncate_weights;
use crate::schedule::quantize_or_schedule;
use crate::util::tensor::Tensor;

/// A named weight configuration.
#[derive(Clone, Debug)]
pub struct VariantSpec {
    pub name: String,
    /// "fp32" | "swis" | "swis_c" | "wgt_trunc"
    pub scheme: String,
    /// Effective shifts (fractional triggers the Sec. 4.3 scheduler).
    pub n_shifts: f64,
    pub group_size: usize,
}

impl VariantSpec {
    pub fn fp32() -> VariantSpec {
        VariantSpec { name: "fp32".into(), scheme: "fp32".into(), n_shifts: 8.0, group_size: 4 }
    }

    pub fn swis(n: f64, g: usize) -> VariantSpec {
        VariantSpec { name: format!("swis@{n}"), scheme: "swis".into(), n_shifts: n, group_size: g }
    }

    pub fn swis_c(n: f64, g: usize) -> VariantSpec {
        VariantSpec { name: format!("swis_c@{n}"), scheme: "swis_c".into(), n_shifts: n, group_size: g }
    }

    pub fn parse(s: &str) -> Result<VariantSpec> {
        if s == "fp32" {
            return Ok(VariantSpec::fp32());
        }
        let (scheme, rest) = s.split_once('@').unwrap_or((s, "3"));
        let n: f64 = rest.parse()?;
        match scheme {
            "swis" => Ok(VariantSpec::swis(n, 4)),
            "swis_c" => Ok(VariantSpec::swis_c(n, 4)),
            "wgt_trunc" => Ok(VariantSpec {
                name: format!("wgt_trunc@{n}"),
                scheme: "wgt_trunc".into(),
                n_shifts: n,
                group_size: 4,
            }),
            _ => bail!("unknown variant scheme '{scheme}'"),
        }
    }
}

/// All weight sets a coordinator serves, keyed by variant name.
pub struct WeightVariants {
    pub sets: HashMap<String, HashMap<String, Tensor<f32>>>,
}

/// Quantize one flat weight tensor (jax layout) through a SWIS transform
/// that operates filters-first, and return it in the original layout.
///
/// jax layouts: conv HWIO (fan-in major, O last), fc (din, dout). Both
/// put the filter axis LAST, so the transpose is the same.
pub fn quantize_jax_weight(
    t: &Tensor<f32>,
    spec: &VariantSpec,
) -> Result<Tensor<f32>> {
    let shape = t.shape().to_vec();
    let k = *shape.last().unwrap();
    let fan_in: usize = shape[..shape.len() - 1].iter().product();
    let data = t.to_f64();
    // transpose (fan_in, K) -> (K, fan_in)
    let mut wf = vec![0.0f64; k * fan_in];
    for i in 0..fan_in {
        for o in 0..k {
            wf[o * fan_in + i] = data.data()[i * k + o];
        }
    }
    let dq: Vec<f64> = match spec.scheme.as_str() {
        "swis" | "swis_c" => {
            let consecutive = spec.scheme == "swis_c";
            if spec.n_shifts.fract() == 0.0 {
                let cfg = QuantConfig {
                    n_shifts: spec.n_shifts as usize,
                    group_size: spec.group_size,
                    alpha: Alpha::ONE,
                    consecutive,
                };
                quantize(&wf, &[k, fan_in], &cfg)?.to_f64()
            } else {
                quantize_or_schedule(&wf, &[k, fan_in], spec.n_shifts, spec.group_size, consecutive, Alpha::ONE)?
                    .to_f64()
            }
        }
        "wgt_trunc" => truncate_weights(&wf, spec.n_shifts as usize),
        "fp32" => wf.clone(),
        other => bail!("unknown scheme {other}"),
    };
    let mut back = vec![0.0f32; k * fan_in];
    for i in 0..fan_in {
        for o in 0..k {
            back[i * k + o] = dq[o * fan_in + i] as f32;
        }
    }
    Tensor::new(&shape, back)
}

impl WeightVariants {
    /// Build every variant's weight set from the FP32 bundle weights.
    /// Biases pass through untouched (the paper quantizes weights only).
    pub fn build(
        fp32: &HashMap<String, Tensor<f32>>,
        specs: &[VariantSpec],
    ) -> Result<WeightVariants> {
        let mut sets = HashMap::new();
        for spec in specs {
            let mut set = HashMap::new();
            for (name, t) in fp32 {
                let q = if name.ends_with("_b") || spec.scheme == "fp32" {
                    t.clone()
                } else {
                    quantize_jax_weight(t, spec)?
                };
                set.insert(name.clone(), q);
            }
            sets.insert(spec.name.clone(), set);
        }
        Ok(WeightVariants { sets })
    }

    pub fn get(&self, name: &str) -> Option<&HashMap<String, Tensor<f32>>> {
        self.sets.get(name)
    }

    pub fn names(&self) -> Vec<&str> {
        let mut n: Vec<&str> = self.sets.keys().map(|s| s.as_str()).collect();
        n.sort();
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn toy_weights() -> HashMap<String, Tensor<f32>> {
        let mut rng = Rng::new(5);
        let mut m = HashMap::new();
        let w: Vec<f32> = (0..3 * 3 * 4 * 8).map(|_| rng.normal_ms(0.0, 0.1) as f32).collect();
        m.insert("conv1".into(), Tensor::new(&[3, 3, 4, 8], w).unwrap());
        m.insert("conv1_b".into(), Tensor::new(&[8], vec![0.5; 8]).unwrap());
        m
    }

    #[test]
    fn variants_build_and_biases_pass_through() {
        let fp32 = toy_weights();
        let specs = vec![VariantSpec::fp32(), VariantSpec::swis(3.0, 4), VariantSpec::swis_c(2.0, 4)];
        let v = WeightVariants::build(&fp32, &specs).unwrap();
        assert_eq!(v.names(), vec!["fp32", "swis@3", "swis_c@2"]);
        let s3 = v.get("swis@3").unwrap();
        assert_eq!(s3["conv1_b"].data(), fp32["conv1_b"].data());
        assert_ne!(s3["conv1"].data(), fp32["conv1"].data());
        // fp32 variant is the identity
        assert_eq!(v.get("fp32").unwrap()["conv1"].data(), fp32["conv1"].data());
    }

    #[test]
    fn quantized_weights_are_close() {
        let fp32 = toy_weights();
        let q = quantize_jax_weight(&fp32["conv1"], &VariantSpec::swis(4.0, 4)).unwrap();
        let a = fp32["conv1"].data();
        let b = q.data();
        let rmse = (a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>()
            / a.len() as f64)
            .sqrt();
        assert!(rmse < 0.01, "rmse {rmse}");
    }

    #[test]
    fn parse_specs() {
        assert_eq!(VariantSpec::parse("fp32").unwrap().scheme, "fp32");
        let s = VariantSpec::parse("swis@2.5").unwrap();
        assert_eq!(s.n_shifts, 2.5);
        assert!(VariantSpec::parse("bogus@3").is_err());
    }

    #[test]
    fn fractional_shifts_schedule() {
        let fp32 = toy_weights();
        let q = quantize_jax_weight(&fp32["conv1"], &VariantSpec::swis(2.5, 4)).unwrap();
        assert_eq!(q.shape(), &[3, 3, 4, 8]);
    }
}
