//! Weight-variant router support: a served model exposes named weight
//! configurations (fp32 baseline, SWIS/SWIS-C at various shift budgets,
//! truncation baselines) over the SAME compiled graph — quantization is a
//! pure weight transform (paper Sec. 2), so variants cost no extra
//! compilation.

use anyhow::{bail, Result};
use std::collections::HashMap;

use crate::exec::model::filters_first;
use crate::exec::WeightTransform;
use crate::util::tensor::Tensor;

/// A named weight configuration.
#[derive(Clone, Debug)]
pub struct VariantSpec {
    pub name: String,
    /// "fp32" | "swis" | "swis_c" | "wgt_trunc"
    pub scheme: String,
    /// Effective shifts (fractional triggers the Sec. 4.3 scheduler).
    pub n_shifts: f64,
    pub group_size: usize,
}

impl VariantSpec {
    pub fn fp32() -> VariantSpec {
        VariantSpec { name: "fp32".into(), scheme: "fp32".into(), n_shifts: 8.0, group_size: 4 }
    }

    pub fn swis(n: f64, g: usize) -> VariantSpec {
        VariantSpec { name: format!("swis@{n}"), scheme: "swis".into(), n_shifts: n, group_size: g }
    }

    pub fn swis_c(n: f64, g: usize) -> VariantSpec {
        VariantSpec { name: format!("swis_c@{n}"), scheme: "swis_c".into(), n_shifts: n, group_size: g }
    }

    /// The backend-agnostic weight transform this variant denotes — the
    /// single scheme-to-math dispatch shared by the PJRT weight swap
    /// ([`quantize_jax_weight`]) and the native engine.
    pub fn transform(&self) -> Result<WeightTransform> {
        Ok(match self.scheme.as_str() {
            "fp32" => WeightTransform::Fp32,
            "swis" | "swis_c" => WeightTransform::Swis {
                n_shifts: self.n_shifts,
                group_size: self.group_size,
                consecutive: self.scheme == "swis_c",
            },
            "wgt_trunc" => WeightTransform::Truncate { bits: self.n_shifts as usize },
            other => bail!("unknown scheme '{other}'"),
        })
    }

    /// Parse `"fp32"` or `"<scheme>[@<shifts>]"` where scheme is one of
    /// `swis`, `swis_c`, `wgt_trunc`. A bare scheme name defaults to 3
    /// shifts (the paper's headline operating point, Sec. 5) — so
    /// `"swis"` parses as `swis@3`. Unknown schemes and malformed or
    /// out-of-range shift counts are hard errors; shifts must be in
    /// `(0, 8]` (8-bit magnitudes) and integral for `wgt_trunc`.
    pub fn parse(s: &str) -> Result<VariantSpec> {
        let s = s.trim();
        if s.is_empty() {
            bail!("empty variant spec");
        }
        if s == "fp32" {
            return Ok(VariantSpec::fp32());
        }
        let (scheme, shifts) = match s.split_once('@') {
            Some((sc, rest)) => (sc, Some(rest)),
            None => (s, None),
        };
        if !matches!(scheme, "swis" | "swis_c" | "wgt_trunc") {
            bail!(
                "unknown variant scheme '{scheme}' in '{s}' \
                 (expected fp32, swis[@N], swis_c[@N] or wgt_trunc[@N])"
            );
        }
        let n: f64 = match shifts {
            None => 3.0, // documented default: the paper's 3-shift point
            Some(r) => r.parse().map_err(|_| {
                anyhow::anyhow!("malformed shift count '{r}' in variant '{s}'")
            })?,
        };
        if !n.is_finite() || n <= 0.0 || n > 8.0 {
            bail!("shift count {n} out of range (0, 8] in variant '{s}'");
        }
        match scheme {
            "swis" => Ok(VariantSpec::swis(n, 4)),
            "swis_c" => Ok(VariantSpec::swis_c(n, 4)),
            _ => {
                if n.fract() != 0.0 {
                    bail!("wgt_trunc needs an integer bit count, got {n} in '{s}'");
                }
                Ok(VariantSpec {
                    name: format!("wgt_trunc@{n}"),
                    scheme: "wgt_trunc".into(),
                    n_shifts: n,
                    group_size: 4,
                })
            }
        }
    }
}

/// All weight sets a coordinator serves, keyed by variant name.
pub struct WeightVariants {
    pub sets: HashMap<String, HashMap<String, Tensor<f32>>>,
}

/// Quantize one flat weight tensor (jax layout) through a SWIS transform
/// that operates filters-first, and return it in the original layout.
///
/// jax layouts: conv HWIO (fan-in major, O last), fc (din, dout). Both
/// put the filter axis LAST, so the transpose is the same. The
/// scheme-to-math mapping is the shared
/// [`crate::exec::WeightTransform`] — the SAME dispatch the native
/// backend executes, so a variant name cannot mean different numerics on
/// different backends.
pub fn quantize_jax_weight(
    t: &Tensor<f32>,
    spec: &VariantSpec,
) -> Result<Tensor<f32>> {
    let shape = t.shape().to_vec();
    let (wf, k, fan_in) = filters_first(t);
    let dq = spec.transform()?.dequantize(&wf, k, fan_in)?;
    // transpose back to the original fan-in-major layout
    let mut back = vec![0.0f32; k * fan_in];
    for i in 0..fan_in {
        for o in 0..k {
            back[i * k + o] = dq[o * fan_in + i] as f32;
        }
    }
    Tensor::new(&shape, back)
}

impl WeightVariants {
    /// Build every variant's weight set from the FP32 bundle weights.
    /// Biases pass through untouched (the paper quantizes weights only).
    pub fn build(
        fp32: &HashMap<String, Tensor<f32>>,
        specs: &[VariantSpec],
    ) -> Result<WeightVariants> {
        let mut sets = HashMap::new();
        for spec in specs {
            let mut set = HashMap::new();
            for (name, t) in fp32 {
                let q = if name.ends_with("_b") || spec.scheme == "fp32" {
                    t.clone()
                } else {
                    quantize_jax_weight(t, spec)?
                };
                set.insert(name.clone(), q);
            }
            sets.insert(spec.name.clone(), set);
        }
        Ok(WeightVariants { sets })
    }

    pub fn get(&self, name: &str) -> Option<&HashMap<String, Tensor<f32>>> {
        self.sets.get(name)
    }

    pub fn names(&self) -> Vec<&str> {
        let mut n: Vec<&str> = self.sets.keys().map(|s| s.as_str()).collect();
        n.sort();
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn toy_weights() -> HashMap<String, Tensor<f32>> {
        let mut rng = Rng::new(5);
        let mut m = HashMap::new();
        let w: Vec<f32> = (0..3 * 3 * 4 * 8).map(|_| rng.normal_ms(0.0, 0.1) as f32).collect();
        m.insert("conv1".into(), Tensor::new(&[3, 3, 4, 8], w).unwrap());
        m.insert("conv1_b".into(), Tensor::new(&[8], vec![0.5; 8]).unwrap());
        m
    }

    #[test]
    fn variants_build_and_biases_pass_through() {
        let fp32 = toy_weights();
        let specs = vec![VariantSpec::fp32(), VariantSpec::swis(3.0, 4), VariantSpec::swis_c(2.0, 4)];
        let v = WeightVariants::build(&fp32, &specs).unwrap();
        assert_eq!(v.names(), vec!["fp32", "swis@3", "swis_c@2"]);
        let s3 = v.get("swis@3").unwrap();
        assert_eq!(s3["conv1_b"].data(), fp32["conv1_b"].data());
        assert_ne!(s3["conv1"].data(), fp32["conv1"].data());
        // fp32 variant is the identity
        assert_eq!(v.get("fp32").unwrap()["conv1"].data(), fp32["conv1"].data());
    }

    #[test]
    fn quantized_weights_are_close() {
        let fp32 = toy_weights();
        let q = quantize_jax_weight(&fp32["conv1"], &VariantSpec::swis(4.0, 4)).unwrap();
        let a = fp32["conv1"].data();
        let b = q.data();
        let rmse = (a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>()
            / a.len() as f64)
            .sqrt();
        assert!(rmse < 0.01, "rmse {rmse}");
    }

    #[test]
    fn parse_specs() {
        assert_eq!(VariantSpec::parse("fp32").unwrap().scheme, "fp32");
        let s = VariantSpec::parse("swis@2.5").unwrap();
        assert_eq!(s.n_shifts, 2.5);
        assert!(VariantSpec::parse("bogus@3").is_err());
    }

    #[test]
    fn parse_round_trips_constructed_names() {
        for spec in [
            VariantSpec::fp32(),
            VariantSpec::swis(3.0, 4),
            VariantSpec::swis(2.5, 4),
            VariantSpec::swis_c(4.0, 4),
            VariantSpec::parse("wgt_trunc@3").unwrap(),
        ] {
            let p = VariantSpec::parse(&spec.name).unwrap();
            assert_eq!(p.name, spec.name);
            assert_eq!(p.scheme, spec.scheme);
            assert_eq!(p.n_shifts, spec.n_shifts);
            assert_eq!(p.group_size, spec.group_size);
        }
    }

    #[test]
    fn bare_scheme_defaults_to_three_shifts() {
        for (s, scheme) in [("swis", "swis"), ("swis_c", "swis_c"), ("wgt_trunc", "wgt_trunc")] {
            let v = VariantSpec::parse(s).unwrap();
            assert_eq!(v.scheme, scheme);
            assert_eq!(v.n_shifts, 3.0, "{s} must default to @3");
            assert_eq!(v.name, format!("{scheme}@3"));
        }
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        // unknown scheme WITHOUT an @ used to silently mean <scheme>@3
        assert!(VariantSpec::parse("bogus").is_err());
        assert!(VariantSpec::parse("").is_err());
        assert!(VariantSpec::parse("swis@").is_err());
        assert!(VariantSpec::parse("swis@abc").is_err());
        assert!(VariantSpec::parse("swis@0").is_err());
        assert!(VariantSpec::parse("swis@-2").is_err());
        assert!(VariantSpec::parse("swis@9").is_err());
        assert!(VariantSpec::parse("swis@inf").is_err());
        assert!(VariantSpec::parse("swis@nan").is_err());
        assert!(VariantSpec::parse("wgt_trunc@2.5").is_err());
        // fp32 takes no shift count
        assert!(VariantSpec::parse("fp32@3").is_err());
    }

    #[test]
    fn fractional_shifts_schedule() {
        let fp32 = toy_weights();
        let q = quantize_jax_weight(&fp32["conv1"], &VariantSpec::swis(2.5, 4)).unwrap();
        assert_eq!(q.shape(), &[3, 3, 4, 8]);
    }
}
