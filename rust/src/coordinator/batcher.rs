//! Dynamic batching policy: drain the queue up to `max_batch`, waiting at
//! most `max_wait` for stragglers once the first request of a batch has
//! arrived (the standard serving trade-off between p50 latency and
//! throughput).

use std::time::{Duration, Instant};

/// Batching knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Never assemble more than this many requests (should match the
    /// largest compiled batch variant).
    pub max_batch: usize,
    /// How long to hold an under-full batch open for stragglers.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(2) }
    }
}

/// Accumulates requests into a batch under a [`BatchPolicy`].
pub struct PendingBatch<T> {
    pub items: Vec<T>,
    opened: Option<Instant>,
    policy: BatchPolicy,
}

impl<T> PendingBatch<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        PendingBatch { items: Vec::with_capacity(policy.max_batch), opened: None, policy }
    }

    pub fn push(&mut self, item: T) {
        if self.items.is_empty() {
            self.opened = Some(Instant::now());
        }
        self.items.push(item);
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// Should the batch be dispatched now?
    pub fn ready(&self) -> bool {
        if self.items.is_empty() {
            return false;
        }
        self.items.len() >= self.policy.max_batch
            || self.opened.map_or(false, |t| t.elapsed() >= self.policy.max_wait)
    }

    /// Time left before the wait deadline forces dispatch (None if empty).
    pub fn time_left(&self) -> Option<Duration> {
        self.opened
            .map(|t| self.policy.max_wait.saturating_sub(t.elapsed()))
    }

    /// Take the assembled batch.
    pub fn take(&mut self) -> Vec<T> {
        self.opened = None;
        std::mem::take(&mut self.items)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fills_to_max_batch() {
        let mut b = PendingBatch::new(BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(10) });
        for i in 0..3 {
            b.push(i);
            assert!(!b.ready(), "not ready at {}", i + 1);
        }
        b.push(3);
        assert!(b.ready());
        assert_eq!(b.take(), vec![0, 1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn dispatches_on_deadline() {
        let mut b = PendingBatch::new(BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(1) });
        b.push(42);
        assert!(!b.ready());
        std::thread::sleep(Duration::from_millis(3));
        assert!(b.ready());
    }

    #[test]
    fn empty_never_ready() {
        let b: PendingBatch<u32> = PendingBatch::new(BatchPolicy::default());
        assert!(!b.ready());
        assert!(b.time_left().is_none());
    }

    #[test]
    fn deadline_expiry_reports_zero_time_left() {
        let policy = BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(1) };
        let mut b = PendingBatch::new(policy);
        b.push(1);
        assert!(b.time_left().is_some());
        std::thread::sleep(Duration::from_millis(3));
        // past the wait deadline: ready, and the countdown saturates at 0
        assert!(b.ready());
        assert_eq!(b.time_left(), Some(Duration::ZERO));
    }

    #[test]
    fn burst_drains_past_max_batch_stay_ready() {
        // the pool stops topping up at ready(); a burst that lands before
        // the check must still dispatch in full, not wedge the batch
        let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(10) };
        let mut b = PendingBatch::new(policy);
        for i in 0..6 {
            b.push(i);
        }
        assert!(b.ready());
        assert_eq!(b.len(), 6);
        assert_eq!(b.take(), vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn take_resets_opened_so_next_push_restarts_the_clock() {
        let policy = BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(2) };
        let mut b = PendingBatch::new(policy);
        b.push(1);
        std::thread::sleep(Duration::from_millis(4));
        assert!(b.ready(), "first window expired");
        assert_eq!(b.take(), vec![1]);
        assert!(b.time_left().is_none(), "empty batch has no deadline");
        // a fresh push after take() must open a FRESH window, not inherit
        // the expired one
        b.push(2);
        assert!(!b.ready(), "new window must not be born expired");
        let left = b.time_left().unwrap();
        assert!(left > Duration::from_micros(500), "window not reset: {left:?}");
    }
}
