//! The serving coordinator (L3): admission control + worker pool +
//! dynamic batching + variant routing + metrics over pluggable execution
//! backends ([`crate::runtime::Backend`]). Python never runs on the
//! request path — each pool worker owns one backend (compiled PJRT
//! executables, or the native SWIS engine executing packed operands
//! directly) and serves whichever SWIS weight configuration a request
//! names.
//!
//! Dispatch path (edge -> admission queue -> pool -> backend):
//!
//! ```text
//!  clients ──try_submit──▶ AdmissionQueue (bounded two-lane queue)
//!     ▲          │           lane 0: interactive  ▸ always popped first
//!     │  Busy ◀──┘ full      lane 1: batch
//!     │                      │ deadline sweep ──▶ Err("shed: ...")
//!     │                      ▼ per-worker pop, variant affinity
//!     │            ┌─ worker 0 ─ PendingBatch ─ Box<dyn Backend> ─┐
//!     │            ├─ worker 1 ─ PendingBatch ─ Box<dyn Backend> ─┤
//!     │            └─ worker N ─ PendingBatch ─ Box<dyn Backend> ─┘
//!     │                      │   native: Arc-shared prepared models
//!     │                      │   pjrt:   per-thread compiled artifacts
//!     └────── per-request response channel ◀────┘
//! ```
//!
//! * [`WorkerPool`] — N workers, bounded admission, `try_submit -> Busy`
//!   backpressure, deadline-based load shedding, priority lanes.
//! * [`Coordinator`] — the single-worker facade (the pre-pool API).
//! * [`crate::loadgen`] — arrival generators + SLO sweep driver that
//!   measure this stack and emit `BENCH_serving.json`.
//!
//! The environment vendors no tokio; the event loop is plain threads +
//! mutex/condvar queues, which for CPU backends is also the
//! lower-overhead choice (see EXPERIMENTS.md §Perf).

mod admission;
mod batcher;
mod metrics;
mod pool;
mod server;
mod variants;

pub use admission::{
    Admit, AdmissionQueue, Popped, Priority, SubmitError, TierPolicy, PRESSURE_DOWN_ONE,
    PRESSURE_DOWN_TWO,
};
pub use batcher::{BatchPolicy, PendingBatch};
pub use metrics::{Metrics, MetricsSnapshot, WireCounters, WireFault, RESERVOIR_CAP};
pub use pool::{Admission, PoolConfig, Ticket, WorkerPool, DEFAULT_QUEUE_DEPTH};
pub use server::{Coordinator, InferRequest, InferResponse};
pub use variants::{quantize_jax_weight, Scheme, VariantSpec, WeightVariants};

// Backend selection lives in the runtime layer; re-exported here because
// callers choose it where they start the coordinator or pool.
pub use crate::runtime::BackendKind;
