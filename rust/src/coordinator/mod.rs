//! The serving coordinator (L3): dynamic batcher + variant router +
//! metrics over the PJRT runtime. Python never runs on the request path —
//! the worker thread owns compiled executables for every batch-size
//! variant and serves whichever SWIS weight configuration a request
//! names.
//!
//! Architecture (vLLM-router-style, scaled to this paper's scope):
//!
//! ```text
//!   clients --> Coordinator::submit --> [queue] --> worker thread
//!                                                    |  drain <= max_batch
//!                                                    |  pick compiled variant
//!                                                    |  PJRT execute
//!                                     response <-----+  per-request channel
//! ```
//!
//! The environment vendors no tokio; the event loop is a plain
//! thread + mpsc design, which for a single-device CPU backend is also
//! the lower-overhead choice (see EXPERIMENTS.md §Perf).

mod batcher;
mod metrics;
mod server;
mod variants;

pub use batcher::{BatchPolicy, PendingBatch};
pub use metrics::{Metrics, MetricsSnapshot};
pub use server::{Coordinator, InferRequest, InferResponse};
pub use variants::{quantize_jax_weight, VariantSpec, WeightVariants};
