//! The serving coordinator (L3): dynamic batcher + variant router +
//! metrics over a pluggable execution backend
//! ([`crate::runtime::Backend`]). Python never runs on the request path —
//! the worker thread owns one backend (compiled PJRT executables, or the
//! native SWIS engine executing packed operands directly) and serves
//! whichever SWIS weight configuration a request names.
//!
//! Architecture (vLLM-router-style, scaled to this paper's scope):
//!
//! ```text
//!   clients --> Coordinator::submit --> [queue] --> worker thread
//!                                                    |  drain <= max_batch
//!                                                    |  group by variant
//!                                                    |  backend.plan_chunks
//!                                                    v
//!                                     +--------------+--------------+
//!                                     | Backend (chosen at start)   |
//!                                     |   pjrt:   compiled HLO,     |
//!                                     |           batch variants    |
//!                                     |   native: packed bit-serial |
//!                                     |           kernel, dynamic   |
//!                                     |           batch             |
//!                                     +--------------+--------------+
//!                                                    |
//!                                     response <-----+  per-request channel
//! ```
//!
//! The environment vendors no tokio; the event loop is a plain
//! thread + mpsc design, which for a single-device CPU backend is also
//! the lower-overhead choice (see EXPERIMENTS.md §Perf).

mod batcher;
mod metrics;
mod server;
mod variants;

pub use batcher::{BatchPolicy, PendingBatch};
pub use metrics::{Metrics, MetricsSnapshot};
pub use server::{Coordinator, InferRequest, InferResponse};
pub use variants::{quantize_jax_weight, VariantSpec, WeightVariants};

// Backend selection lives in the runtime layer; re-exported here because
// callers choose it where they start the coordinator.
pub use crate::runtime::BackendKind;
