//! The scale-out serving layer: a [`WorkerPool`] of N worker threads,
//! each owning one `Box<dyn Backend>`, fed through the bounded
//! [`AdmissionQueue`].
//!
//! ```text
//!  clients ──try_submit──▶ AdmissionQueue (bounded, capacity = queue_depth)
//!     ▲         │            lane 0: interactive   lane 1: batch
//!     │ Busy ◀──┘ full        │ deadline sweep ──▶ Err("shed: ...")
//!     │                       ▼ pop (interactive first, variant affinity)
//!     │              ┌─ worker 0 ─ PendingBatch ─ Box<dyn Backend> ─┐
//!     │              ├─ worker 1 ─ PendingBatch ─ Box<dyn Backend> ─┤
//!     │              └─ worker N ─ PendingBatch ─ Box<dyn Backend> ─┘
//!     │                       │  (native: Arc-shared prepared models;
//!     │                       │   pjrt: per-thread compiled artifacts)
//!     └── per-request response channel ◀─────────┘
//! ```
//!
//! Each worker seeds a batch from the queue (preferring its last-served
//! variant so its hot variant stays hot), tops it up with same-variant
//! jobs until `max_batch`/`max_wait`, then dispatches through its own
//! backend. A worker panic is caught: the in-flight batch's callers see a
//! routed error (their response channels close), the worker and the rest
//! of the pool keep serving. The single-worker [`super::Coordinator`] is
//! a thin facade over this type.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::admission::{Admit, AdmissionQueue, Popped, Priority, SubmitError, TierPolicy};
use super::batcher::{BatchPolicy, PendingBatch};
use super::metrics::Metrics;
use super::server::{InferRequest, InferResponse};
use super::variants::VariantSpec;
use crate::error::{AdmissionReason, SwisError, SwisResult};
use crate::obs;
use crate::obs::trace::{RequestTrace, SpanKind, TraceId, TraceRing, TRACE_RING_CAP};
use crate::runtime::{create_factory, Backend, BackendFactory, BackendKind};
use crate::util::tensor::Tensor;

/// Default admission depth for the single-worker facade — generous so the
/// pre-pool unbounded-submit semantics hold for every existing caller.
pub const DEFAULT_QUEUE_DEPTH: usize = 4096;

/// Pool sizing + batching knobs.
#[derive(Clone, Copy, Debug)]
pub struct PoolConfig {
    /// Worker threads (each owns one backend instance).
    pub workers: usize,
    pub policy: BatchPolicy,
    /// Admission queue capacity across both lanes.
    pub queue_depth: usize,
    /// Request-trace sampling: every Nth minted [`TraceId`] carries a
    /// span trace through the pool (0 disables). Only active while the
    /// [`crate::obs`] level is `full`.
    pub trace_sample: usize,
}

impl Default for PoolConfig {
    fn default() -> PoolConfig {
        PoolConfig {
            workers: 1,
            policy: BatchPolicy::default(),
            queue_depth: DEFAULT_QUEUE_DEPTH,
            trace_sample: 1,
        }
    }
}

/// The response side of one accepted request. Failures arrive as the
/// typed [`SwisError`] (shed deadlines are `Admission { reason: Shed }`,
/// execution failures are `Backend`), so callers classify outcomes by
/// matching, never by message prefix.
pub type Ticket = Receiver<Result<InferResponse, SwisError>>;

/// Outcome of a non-blocking submission.
pub enum Admission {
    Accepted(Ticket),
    /// Refused by backpressure — the admission queue is at capacity.
    Busy,
}

/// One queued request: payload + response channel + timing/SLO state.
struct Job {
    req: InferRequest,
    respond: Sender<Result<InferResponse, SwisError>>,
    enqueued: Instant,
    deadline: Option<Instant>,
    /// Admission rewrote `req.variant` down the precision ladder
    /// (degrade-don't-shed); surfaced on the response.
    degraded: bool,
    /// Lane this job was admitted on (per-lane shed/reject accounting).
    pri: Priority,
    /// Sampled span trace (admission → terminal), when tracing is on.
    trace: Option<RequestTrace>,
}

impl Admit for Job {
    fn variant(&self) -> &str {
        &self.req.variant
    }

    fn deadline(&self) -> Option<Instant> {
        self.deadline
    }
}

/// Handle to a running worker pool.
pub struct WorkerPool {
    queue: Arc<AdmissionQueue<Job>>,
    pub metrics: Arc<Metrics>,
    workers: Vec<JoinHandle<()>>,
    alive: Arc<AtomicUsize>,
    backend_name: &'static str,
    image_len: usize,
    /// Precision ladder from the factory's plan (multi-tier
    /// `.swisplan`): under queue pressure, admission rewrites requests
    /// down the ladder instead of letting them queue toward their shed
    /// deadline. `None` = never rewrite (the single-tier behavior).
    tiers: Option<TierPolicy>,
    /// Every Nth minted trace id is sampled (0 = tracing off).
    trace_sample: usize,
    /// One bounded trace ring per worker — completed/shed traces land
    /// here; [`WorkerPool::drain_traces`] collects them.
    rings: Vec<Arc<TraceRing>>,
}

impl WorkerPool {
    /// Resolve a backend factory for `artifacts` and start the pool
    /// (TinyCNN — the pre-zoo entry point).
    pub fn start(
        artifacts: &Path,
        cfg: PoolConfig,
        variants: Vec<VariantSpec>,
        kind: BackendKind,
    ) -> SwisResult<WorkerPool> {
        let factory: Arc<dyn BackendFactory> =
            Arc::from(create_factory(kind, artifacts, &variants)?);
        WorkerPool::start_with_factory(factory, cfg)
    }

    /// [`WorkerPool::start`] for any zoo network (served natively; pass
    /// the net with its FC head). Request images must carry the net's
    /// own `hw * hw * c` elements — the pool learns the shape from the
    /// backend at warm-up.
    pub fn start_net(
        artifacts: &Path,
        cfg: PoolConfig,
        net: &crate::nets::Network,
        variants: Vec<VariantSpec>,
        kind: BackendKind,
    ) -> SwisResult<WorkerPool> {
        let factory: Arc<dyn BackendFactory> =
            Arc::from(crate::runtime::create_factory_net(kind, artifacts, net, &variants)?);
        WorkerPool::start_with_factory(factory, cfg)
    }

    /// Start N workers over an explicit factory (shared across pools by
    /// the loadgen sweep so warm-up happens once). Returns after every
    /// worker finished warm-up; any warm-up failure fails the start.
    pub fn start_with_factory(
        factory: Arc<dyn BackendFactory>,
        cfg: PoolConfig,
    ) -> SwisResult<WorkerPool> {
        if cfg.workers == 0 {
            return Err(SwisError::config("worker pool needs at least one worker"));
        }
        if cfg.queue_depth == 0 {
            return Err(SwisError::config("queue depth must be at least 1"));
        }
        let queue = Arc::new(AdmissionQueue::new(cfg.queue_depth));
        let metrics = Arc::new(Metrics::default());
        let alive = Arc::new(AtomicUsize::new(0));
        // warm-up handshake: each worker reports its backend's name and
        // per-request image shape (the pool sizes admission checks off it)
        let (ready_tx, ready_rx) =
            mpsc::channel::<Result<(&'static str, [usize; 3]), SwisError>>();
        let mut workers = Vec::with_capacity(cfg.workers);
        let mut rings = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let ring = Arc::new(TraceRing::new(TRACE_RING_CAP));
            rings.push(Arc::clone(&ring));
            let (f, q, m, a, rt) = (
                Arc::clone(&factory),
                Arc::clone(&queue),
                Arc::clone(&metrics),
                Arc::clone(&alive),
                ready_tx.clone(),
            );
            let (n_workers, policy) = (cfg.workers, cfg.policy);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("swis-worker-{w}"))
                    .spawn(move || worker_main(n_workers, f, q, policy, m, a, rt, ring))
                    .map_err(|e| SwisError::backend(format!("spawning pool worker: {e}")))?,
            );
        }
        drop(ready_tx);
        let mut backend_name: &'static str = "";
        let mut image_len = 32 * 32 * 3;
        for _ in 0..cfg.workers {
            match ready_rx.recv() {
                Ok(Ok((name, shape))) => {
                    backend_name = name;
                    image_len = shape.iter().product();
                }
                Ok(Err(e)) => {
                    queue.close();
                    for h in workers {
                        let _ = h.join();
                    }
                    return Err(e.context("pool worker failed to start"));
                }
                Err(_) => {
                    queue.close();
                    for h in workers {
                        let _ = h.join();
                    }
                    return Err(SwisError::backend("pool worker died during warm-up"));
                }
            }
        }
        Ok(WorkerPool {
            queue,
            metrics,
            workers,
            alive,
            backend_name,
            image_len,
            tiers: factory.tier_policy(),
            trace_sample: cfg.trace_sample,
            rings,
        })
    }

    /// The precision ladder admission degrades along, if the serving
    /// plan carries one.
    pub fn tier_policy(&self) -> Option<&TierPolicy> {
        self.tiers.as_ref()
    }

    /// Which backend the workers run on ("pjrt" | "native" | test name).
    pub fn backend(&self) -> &'static str {
        self.backend_name
    }

    /// Elements one request image must carry (`hw * hw * c` of the
    /// served network, learned from the backend at warm-up).
    pub fn image_len(&self) -> usize {
        self.image_len
    }

    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Requests currently queued (admitted, not yet dispatched).
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Per-lane queue depths `[interactive, batch]` — the
    /// `swis_queue_depth{lane=...}` gauges.
    pub fn queue_depths(&self) -> [usize; 2] {
        self.queue.depths()
    }

    /// Drain every worker's trace ring: completed, shed, and errored
    /// sampled requests, oldest-first per worker. Rings are bounded
    /// ([`TRACE_RING_CAP`] each), so under sustained load drain often.
    pub fn drain_traces(&self) -> Vec<RequestTrace> {
        let mut out = Vec::new();
        for r in &self.rings {
            out.extend(r.drain());
        }
        out
    }

    /// Non-blocking admission: `Ok(Busy)` is backpressure (counted in
    /// metrics as rejected); `Err` is a typed hard fault — `Admission`
    /// with reason `Invalid` (bad request) or `Closed` (pool down).
    /// Priority, shed deadline (measured from now), tier hint and trace
    /// flag all ride on the [`InferRequest`].
    pub fn try_submit(&self, req: InferRequest) -> SwisResult<Admission> {
        let pri = req.priority;
        let (job, rx) = self.make_job(req)?;
        let degraded = job.degraded;
        match self.queue.try_push(job, pri) {
            Ok(()) => {
                if degraded {
                    self.metrics.record_degraded(1);
                }
                Ok(Admission::Accepted(rx))
            }
            Err(SubmitError::Busy(_)) => {
                self.metrics.record_rejected(pri);
                Ok(Admission::Busy)
            }
            Err(SubmitError::Closed(_)) => Err(SwisError::admission(
                AdmissionReason::Closed,
                "worker pool is shut down",
            )),
        }
    }

    /// Blocking admission: waits for queue space instead of refusing.
    pub fn submit(&self, req: InferRequest) -> SwisResult<Ticket> {
        let pri = req.priority;
        let (job, rx) = self.make_job(req)?;
        let degraded = job.degraded;
        self.queue.push_wait(job, pri).map_err(|_| {
            SwisError::admission(AdmissionReason::Closed, "worker pool is shut down")
        })?;
        if degraded {
            self.metrics.record_degraded(1);
        }
        Ok(rx)
    }

    /// Convenience: interactive submit + block for the result. A
    /// response channel that closes without an answer is a BACKEND
    /// failure (a contained worker panic dropped the in-flight batch —
    /// the pool may well still be serving), not `Admission::Closed`.
    pub fn infer(&self, req: InferRequest) -> SwisResult<InferResponse> {
        let rx = self.submit(req)?;
        rx.recv().map_err(|_| {
            SwisError::backend("pool dropped the request (in-flight batch failed)")
        })?
    }

    fn make_job(&self, mut req: InferRequest) -> SwisResult<(Job, Ticket)> {
        if req.image.len() != self.image_len {
            return Err(SwisError::admission(
                AdmissionReason::Invalid,
                format!("image must have {} elements, got {}", self.image_len, req.image.len()),
            ));
        }
        // Acquire pairs with the workers' AcqRel increments: observing a
        // non-zero count happens-after that worker's warm-up completed.
        if self.alive.load(Ordering::Acquire) == 0 {
            return Err(SwisError::admission(
                AdmissionReason::Closed,
                "no live workers in the pool",
            ));
        }
        // Sampled request trace, minted at admission: the Enqueue span
        // opens the timeline the queue/batch/compute attribution hangs
        // off. Records the variant as REQUESTED; a degrade rewrite below
        // is stamped on top.
        let mut trace = if obs::tracing_on() && (req.trace || self.trace_sample > 0) {
            let id = TraceId::mint();
            (req.trace || id.0 % self.trace_sample as u64 == 0)
                .then(|| RequestTrace::begin(id, &req.variant))
        } else {
            None
        };
        // Client-sanctioned tier relaxation: resolve the hint against the
        // ladder BEFORE pressure degrade. Not counted as `degraded` —
        // the client asked for the relaxation.
        if req.tier_hint > 0 {
            if let Some(policy) = &self.tiers {
                let (eff, _) = policy.resolve(&req.variant, req.tier_hint);
                let eff = eff.to_string();
                req.variant = eff;
            }
        }
        // Degrade-don't-shed: under queue pressure, rewrite the variant
        // down the precision ladder BEFORE enqueueing, so affinity
        // batching groups jobs by the variant that will actually run and
        // the queue drains faster per job. Counted in metrics only once
        // the push succeeds (Busy-refused requests are `rejected`).
        let degraded = if let Some(policy) = &self.tiers {
            let pressure = self.queue.len() as f64 / self.queue.capacity() as f64;
            let (eff, degraded) = policy.degrade(&req.variant, pressure);
            if degraded {
                let eff = eff.to_string();
                if let Some(t) = trace.as_mut() {
                    t.degraded_to(&eff);
                }
                req.variant = eff;
            }
            degraded
        } else {
            false
        };
        let now = Instant::now();
        let (respond, rx) = mpsc::channel();
        let pri = req.priority;
        let deadline = req.deadline.map(|d| now + d);
        let job = Job { req, respond, enqueued: now, deadline, degraded, pri, trace };
        Ok((job, rx))
    }

    /// Graceful shutdown: close admission, drain, join every worker.
    pub fn shutdown(mut self) -> SwisResult<()> {
        self.queue.close();
        let mut result = Ok(());
        for h in self.workers.drain(..) {
            if h.join().is_err() {
                result = Err(SwisError::backend("pool worker panicked"));
            }
        }
        result
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.queue.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Decrements the live-worker count however the thread exits.
struct AliveGuard(Arc<AtomicUsize>);

impl Drop for AliveGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::AcqRel);
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_main(
    n_workers: usize,
    factory: Arc<dyn BackendFactory>,
    queue: Arc<AdmissionQueue<Job>>,
    policy: BatchPolicy,
    metrics: Arc<Metrics>,
    alive: Arc<AtomicUsize>,
    ready: Sender<Result<(&'static str, [usize; 3]), SwisError>>,
    ring: Arc<TraceRing>,
) {
    // Warm-up on this thread: thread-affine backends (PJRT) must be
    // constructed where they execute. A panicking factory is reported as
    // a start-up error, never a hang.
    let backend = match catch_unwind(AssertUnwindSafe(|| factory.make(n_workers))) {
        Ok(Ok(b)) => b,
        Ok(Err(e)) => {
            let _ = ready.send(Err(e));
            return;
        }
        Err(_) => {
            let _ = ready.send(Err(SwisError::backend("backend construction panicked")));
            return;
        }
    };
    alive.fetch_add(1, Ordering::AcqRel);
    let _alive = AliveGuard(alive);
    let _ = ready.send(Ok((backend.name(), backend.input_shape())));

    let mut affinity: Option<String> = None;
    let mut shed: Vec<Job> = Vec::new();
    loop {
        let popped = queue.pop_seed(affinity.as_deref(), &mut shed);
        flush_shed(&mut shed, &metrics, &ring);
        let mut seed = match popped {
            Popped::Job(j) => j,
            Popped::Shed => continue,
            Popped::Closed => return,
        };
        if let Some(t) = seed.trace.as_mut() {
            t.push(SpanKind::BatchOpen);
        }

        // Assemble one same-variant batch under the policy: the seed
        // opens the wait window; top-up pops only this variant.
        let variant = seed.req.variant.clone();
        let mut batch: PendingBatch<Job> = PendingBatch::new(policy);
        batch.push(seed);
        while !batch.ready() && !queue.is_closed() {
            let wait = batch.time_left().unwrap_or(Duration::ZERO);
            if wait.is_zero() {
                break;
            }
            let until = Instant::now() + wait;
            let got = queue.pop_match(&variant, until, &mut shed);
            flush_shed(&mut shed, &metrics, &ring);
            match got {
                Some(mut j) => {
                    if let Some(t) = j.trace.as_mut() {
                        t.push(SpanKind::BatchOpen);
                    }
                    batch.push(j);
                }
                None => {
                    if Instant::now() >= until || queue.is_closed() {
                        break;
                    }
                }
            }
        }
        affinity = Some(variant);

        // A panicking backend fails only this batch: the jobs moved into
        // dispatch are dropped during unwind, closing their response
        // channels (callers observe a routed error, not a hang); the
        // worker and the rest of the pool keep serving. `resolved`
        // counts the jobs dispatch already answered (ok/err/shed) so the
        // panic path charges errors only for the ones left dangling.
        let mut jobs = batch.take();
        for j in jobs.iter_mut() {
            if let Some(t) = j.trace.as_mut() {
                t.push(SpanKind::BatchClose);
            }
        }
        let n = jobs.len();
        // `resolved` never crosses threads: dispatch runs inside
        // catch_unwind on THIS worker thread and the post-panic load is
        // the same thread, so Relaxed is sufficient (atomic only because
        // the closure takes it by shared reference).
        let resolved = AtomicUsize::new(0);
        let run = || dispatch(jobs, backend.as_ref(), &metrics, &resolved, &ring);
        if catch_unwind(AssertUnwindSafe(run)).is_err() {
            metrics.record_panic();
            metrics.record_errors(n - resolved.load(Ordering::Relaxed).min(n));
        }
    }
}

/// Count one shed job per lane and finish its trace (terminal `Shed`
/// span straight into the worker's ring — a shed response carries no
/// trace payload, the ring is its only record).
fn shed_job(mut j: Job, metrics: &Metrics, ring: &TraceRing, why: &str) {
    metrics.record_shed(j.pri, 1);
    if let Some(mut t) = j.trace.take() {
        t.push(SpanKind::Shed);
        ring.push(t);
    }
    let _ = j.respond.send(Err(SwisError::admission(AdmissionReason::Shed, why)));
}

fn flush_shed(shed: &mut Vec<Job>, metrics: &Metrics, ring: &TraceRing) {
    for j in shed.drain(..) {
        let waited = j.enqueued.elapsed();
        let why =
            format!("deadline exceeded after {:.1} ms in queue", waited.as_secs_f64() * 1e3);
        shed_job(j, metrics, ring, &why);
    }
}

/// Execute one assembled same-variant batch: final deadline sweep, then
/// backend-planned chunks, then per-request delivery. Every job answered
/// (ok, routed error, or shed) bumps `resolved`, so a mid-batch panic
/// can tell the dangling jobs from the already-delivered ones.
fn dispatch(
    jobs: Vec<Job>,
    backend: &dyn Backend,
    metrics: &Metrics,
    resolved: &AtomicUsize,
    ring: &TraceRing,
) {
    let Some(first) = jobs.first() else { return };
    let variant = first.req.variant.clone();
    debug_assert!(jobs.iter().all(|j| j.req.variant == variant), "mixed-variant batch");
    if !backend.has_variant(&variant) {
        metrics.record_errors(jobs.len());
        resolved.fetch_add(jobs.len(), Ordering::Relaxed);
        for mut j in jobs {
            if let Some(mut t) = j.trace.take() {
                t.push(SpanKind::Error);
                ring.push(t);
            }
            let _ = j
                .respond
                .send(Err(SwisError::backend(format!("unknown variant '{variant}'"))));
        }
        return;
    }
    // shed anything that expired while the batch was assembling
    let now = Instant::now();
    let (mut live, expired): (Vec<Job>, Vec<Job>) =
        jobs.into_iter().partition(|j| j.deadline.map_or(true, |d| d > now));
    if !expired.is_empty() {
        resolved.fetch_add(expired.len(), Ordering::Relaxed);
        for j in expired {
            shed_job(j, metrics, ring, "deadline exceeded before execution");
        }
    }
    // execute in backend-planned chunks rather than padding the whole
    // group up to the largest compiled size (PJRT cost ~affine in batch;
    // the native backend takes the group in one dynamic chunk)
    let mut start = 0usize;
    for chunk in backend.plan_chunks(live.len()) {
        let end = (start + chunk).min(live.len());
        run_chunk(&mut live[start..end], &variant, backend, metrics, ring);
        resolved.fetch_add(end - start, Ordering::Relaxed);
        start = end;
    }
}

/// Finish a chunk's traces on an error path: terminal `Error` span into
/// the ring, then the routed error to every caller.
fn fail_chunk(group: &mut [Job], err: &SwisError, metrics: &Metrics, ring: &TraceRing) {
    metrics.record_errors(group.len());
    for j in group.iter_mut() {
        if let Some(mut t) = j.trace.take() {
            t.push(SpanKind::Error);
            ring.push(t);
        }
        let _ = j.respond.send(Err(err.clone()));
    }
}

/// Execute one chunk of same-variant jobs.
fn run_chunk(
    group: &mut [Job],
    variant: &str,
    backend: &dyn Backend,
    metrics: &Metrics,
    ring: &TraceRing,
) {
    let t0 = Instant::now();
    let n = group.len();
    let s = backend.input_shape();
    let mut data = Vec::with_capacity(n * s[0] * s[1] * s[2]);
    for j in group.iter() {
        data.extend_from_slice(&j.req.image);
    }
    let images = match Tensor::new(&[n, s[0], s[1], s[2]], data) {
        Ok(t) => t,
        Err(e) => {
            fail_chunk(group, &SwisError::backend_from(e), metrics, ring);
            return;
        }
    };
    for j in group.iter_mut() {
        if let Some(t) = j.trace.as_mut() {
            t.push(SpanKind::InferStart);
        }
    }
    match backend.infer(variant, &images) {
        Ok(logits) => {
            let exec = t0.elapsed();
            let classes = logits.shape()[1];
            let now = Instant::now();
            let queue_ts: Vec<Duration> =
                group.iter().map(|j| t0.duration_since(j.enqueued)).collect();
            let total_ts: Vec<Duration> =
                group.iter().map(|j| now.duration_since(j.enqueued)).collect();
            // record before delivery so a caller that has all its
            // responses also sees them reflected in the metrics
            metrics.record_batch(n, &queue_ts, exec, &total_ts);
            for (i, j) in group.iter_mut().enumerate() {
                // finish the trace (a clone stays in the worker's ring;
                // the original rides the response for per-request
                // attribution by the caller)
                let trace = j.trace.take().map(|mut t| {
                    t.push(SpanKind::InferEnd);
                    t.push(SpanKind::Done);
                    ring.push(t.clone());
                    t
                });
                let _ = j.respond.send(Ok(InferResponse {
                    logits: logits.data()[i * classes..(i + 1) * classes].to_vec(),
                    variant: variant.to_string(),
                    queue: queue_ts[i],
                    total: total_ts[i],
                    batch_size: n,
                    degraded: j.degraded,
                    trace,
                }));
            }
        }
        Err(e) => {
            fail_chunk(group, &e, metrics, ring);
        }
    }
}
